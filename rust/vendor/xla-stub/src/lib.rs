//! Compile-only stub of the `xla` (xla_extension 0.5.x) API surface the
//! WTF reproduction uses.  Everything type-checks; every fallible entry
//! point returns [`Error`], so `XlaRuntime::load` fails cleanly at
//! runtime and callers fall back to the pure-rust `NativeCompute`
//! oracle.  Replace the `vendor/xla-stub` path dependency with the real
//! bindings to run the AOT-compiled Pallas kernels through PJRT.

use std::fmt;

/// The bindings' error type.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable<T>() -> Result<T, Error> {
    Err(Error(
        "xla stub: PJRT backend unavailable (the real xla_extension \
         bindings are not vendored in this build)"
            .to_string(),
    ))
}

/// Element types a [`Literal`] can carry.
pub trait NativeType: Copy {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for u32 {}
impl NativeType for u64 {}

/// A host-side tensor.
#[derive(Clone, Debug)]
pub struct Literal;

impl Literal {
    /// Build a rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(_values: &[T]) -> Literal {
        Literal
    }

    /// Destructure a tuple literal.
    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        unavailable()
    }

    /// Copy the elements out.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        unavailable()
    }
}

/// An HLO module parsed from text.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        unavailable()
    }
}

/// A computation ready to compile.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A device-resident buffer produced by an execution.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable()
    }
}

/// A compiled, loaded executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with one replica/partition; returns per-device outputs.
    pub fn execute<T: Clone>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }
}

/// A PJRT client bound to one platform.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    /// The CPU platform client.
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_fails_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        assert!(Literal::vec1(&[1i32, 2]).to_vec::<i32>().is_err());
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("stub"));
    }
}
