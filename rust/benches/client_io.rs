//! End-to-end client hot paths on a real in-process cluster: the write
//! path (slices + blind metadata txn), the read path (resolve + fetch),
//! appends, and the slicing ops whose cost is the paper's headline.

use wtf::bench::Bench;
use wtf::cluster::Cluster;
use wtf::config::Config;
use wtf::util::Rng;

fn main() {
    let cluster = Cluster::builder()
        .config(Config {
            region_size: 1 << 22, // 4 MB regions
            ..Config::default()
        })
        .build()
        .unwrap();
    let c = cluster.client();

    let mut payload = vec![0u8; 256 * 1024];
    Rng::new(7).fill_bytes(&mut payload);

    // Sequential write path: 256 kB per op.
    let mut fd = c.create("/bench-w").unwrap();
    Bench::new("client/write-256k")
        .iters(40)
        .run_bytes(payload.len() as u64, || c.write(&mut fd, &payload).unwrap());

    // Append fast path.
    let fda = c.create("/bench-a").unwrap();
    Bench::new("client/append-256k")
        .iters(40)
        .run_bytes(payload.len() as u64, || {
            c.append_bytes(&fda, &payload).unwrap()
        });

    // Read path over the written file.
    let fr = c.open("/bench-w").unwrap();
    let mut off = 0u64;
    Bench::new("client/read-256k")
        .iters(40)
        .run_bytes(payload.len() as u64, || {
            let r = c.read_at(&fr, off, payload.len() as u64).unwrap();
            off = (off + payload.len() as u64) % (payload.len() as u64 * 16);
            r
        });

    // yank+paste: the metadata-only "write".
    let mut dst = c.create("/bench-paste").unwrap();
    Bench::new("client/yank+paste-256k (0 data bytes)")
        .iters(40)
        .run_bytes(payload.len() as u64, || {
            let s = c.yank_at(fr.inode(), 0, payload.len() as u64).unwrap();
            c.paste(&mut dst, &s).unwrap()
        });

    // concat of 8 files.
    for i in 0..8 {
        let mut f = c.create(&format!("/part{i}")).unwrap();
        c.write(&mut f, &payload).unwrap();
    }
    let parts: Vec<String> = (0..8).map(|i| format!("/part{i}")).collect();
    let refs: Vec<&str> = parts.iter().map(|s| s.as_str()).collect();
    let mut n = 0;
    Bench::new("client/concat-8x256k (metadata only)")
        .iters(30)
        .run(|| {
            n += 1;
            c.concat(&refs, &format!("/cat{n}")).unwrap()
        });

    // Transaction commit (small read-modify-write).
    let mut seed = c.create("/bench-txn").unwrap();
    c.write(&mut seed, b"0123456789abcdef").unwrap();
    Bench::new("client/txn(read+write+commit)").iters(40).run(|| {
        let mut t = c.begin();
        let fd = t.open("/bench-txn").unwrap();
        let data = t.read(fd, 8).unwrap();
        t.seek(fd, wtf::client::SeekFrom::End(0)).unwrap();
        t.write(fd, &data[..4]).unwrap();
        t.commit().unwrap()
    });
}
