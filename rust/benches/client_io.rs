//! End-to-end client hot paths on a real in-process cluster: the write
//! path (slices + blind metadata txn), the read path (resolve + fetch),
//! appends, the slicing ops whose cost is the paper's headline, and the
//! replication fan-out sweep under the simulated GbE link (the transport
//! scatter-gather's raison d'être).
//!
//! Set `WTF_BENCH_JSON=<path>` to also write the fan-out results as
//! JSON (committed as `BENCH_client_io.json` for cross-PR trajectory).

use wtf::bench::stats::Summary;
use wtf::bench::Bench;
use wtf::client::WtfClient;
use wtf::cluster::Cluster;
use wtf::config::Config;
use wtf::mapreduce::records::generate_records;
use wtf::mapreduce::{sort_slicing, BulkFs, SortJob};
use wtf::net::LinkModel;
use wtf::runtime::NativeCompute;
use wtf::util::Rng;

/// Replication sweep under `LinkModel::gigabit()`: with the transport
/// scattering every replica upload, a replication-r `write_at` should
/// cost ~1 wire time, not r (acceptance: r=3 within 1.5x of r=1).
fn fanout_sweep() -> Vec<(u8, Summary)> {
    let mut payload = vec![0u8; 256 * 1024];
    Rng::new(9).fill_bytes(&mut payload);
    let mut rows = Vec::new();
    for r in [1u8, 2, 3] {
        let cluster = Cluster::builder()
            .config(Config {
                region_size: 1 << 22,
                storage_servers: 4,
                replication: r,
                ..Config::default()
            })
            .link(LinkModel::gigabit())
            .build()
            .unwrap();
        let c = cluster.client();
        let fd = c.create("/fanout").unwrap();
        let s = Bench::new(format!("client/write_at-256k-gigabit-r{r}"))
            .warmup(2)
            .iters(12)
            .run(|| c.write_at(fd.inode(), 0, &payload).unwrap());
        rows.push((r, s));
    }
    let r1 = rows[0].1.mean;
    let r3 = rows[2].1.mean;
    println!(
        "  └─ fan-out ratio r3/r1 = {:.2}x (serial RPC would be ~3x)",
        r3 / r1.max(1.0)
    );
    rows
}

/// Emit the fan-out rows in the `BENCH_client_io.json` schema (status
/// "measured"; re-running this bench is how the committed "modeled"
/// placeholder gets replaced with real wall-clock rows).
fn write_json(path: &str, rows: &[(u8, Summary)]) {
    let wire_ns = LinkModel::gigabit()
        .transfer_time(256 * 1024)
        .as_nanos() as u64;
    let mut out = String::from("{\n  \"bench\": \"client_io/fanout\",\n");
    out.push_str(
        "  \"description\": \"Replication sweep of 256 KiB write_at under \
         LinkModel::gigabit() (0.1 ms half-rtt, 125 MB/s). Produced by \
         `cargo bench --bench client_io` with WTF_BENCH_JSON set; see \
         rust/benches/client_io.rs.\",\n",
    );
    out.push_str("  \"status\": \"measured\",\n");
    out.push_str("  \"link\": \"gigabit (0.1 ms half-rtt, 125 MB/s)\",\n");
    out.push_str("  \"payload_bytes\": 262144,\n");
    out.push_str(&format!(
        "  \"wire_time_per_transfer_ns\": {wire_ns},\n  \"rows\": [\n"
    ));
    for (i, (r, s)) in rows.iter().enumerate() {
        // serial_model_ns: what a serial per-replica charge would cost —
        // the pre-transport baseline the measurement is compared to.
        out.push_str(&format!(
            "    {{\"replication\": {r}, \"mean_ns\": {:.0}, \"p50_ns\": {}, \
             \"p95_ns\": {}, \"serial_model_ns\": {}}}{}\n",
            s.mean,
            s.p50,
            s.p95,
            wire_ns * u64::from(*r),
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    let r1 = rows.first().map(|(_, s)| s.mean).unwrap_or(1.0);
    let r3 = rows.last().map(|(_, s)| s.mean).unwrap_or(1.0);
    out.push_str(&format!(
        "  ],\n  \"r3_over_r1\": {:.3}\n}}\n",
        r3 / r1.max(1.0)
    ));
    std::fs::write(path, out).expect("write WTF_BENCH_JSON");
    println!("  └─ wrote {path}");
}

/// One row of the read-path sweep (BENCH_read_path.json).
struct ReadRow {
    row: &'static str,
    config: &'static str,
    envelopes: u64,
    mean_ns: f64,
}

/// Build a 4 MiB file of 64 KiB writes over 256 KiB regions on a
/// cluster with the given read-path knobs: 16 regions x 4 extents.
fn read_path_cluster(cache: bool, coalesce: bool, readahead: u64) -> Cluster {
    let cluster = Cluster::builder()
        .config(Config {
            region_size: 256 * 1024,
            storage_servers: 4,
            metadata_cache: cache,
            read_coalescing: coalesce,
            readahead,
            ..Config::default()
        })
        .build()
        .unwrap();
    let c = cluster.client();
    let mut fd = c.create("/seq").unwrap();
    let mut chunk = vec![0u8; 64 * 1024];
    Rng::new(11).fill_bytes(&mut chunk);
    for _ in 0..64 {
        c.write(&mut fd, &chunk).unwrap();
    }
    cluster
}

/// Read-path sweep: cache on/off x coalescing x readahead over a
/// multi-region, multi-extent file.  Reports the warm-pass envelope
/// count (deterministic) and wall time for (a) one whole-file
/// `read_at` and (b) a sequential 64 KiB `read()` stream.
fn read_path_sweep() -> Vec<ReadRow> {
    let total: u64 = 4 * 1024 * 1024;
    let variants: [(&str, bool, bool, u64); 4] = [
        ("seed", false, false, 0),
        ("cache", true, false, 0),
        ("cache+coalesce", true, true, 0),
        ("cache+coalesce+readahead", true, true, 1 << 20),
    ];
    let mut rows = Vec::new();
    for (name, cache, coalesce, ra) in variants {
        let cluster = read_path_cluster(cache, coalesce, ra);
        let c = cluster.client();
        let fd = c.open("/seq").unwrap();

        // (a) whole-file read_at: the coalescing showcase.
        let whole = |c: &WtfClient| c.read_at(&fd, 0, total).unwrap();
        let _ = whole(&c); // cold pass warms the cache
        let e0 = cluster.transport_envelopes();
        let data = whole(&c);
        assert_eq!(data.len() as u64, total);
        let whole_env = cluster.transport_envelopes() - e0;
        let s = Bench::new(format!("client/read_at-4MiB [{name}]"))
            .warmup(1)
            .iters(8)
            .run(|| whole(&c));
        println!("  └─ warm envelopes/pass: {whole_env}");
        rows.push(ReadRow {
            row: "seq-read-whole-warm",
            config: name,
            envelopes: whole_env,
            mean_ns: s.mean,
        });

        // (b) sequential 64 KiB read() stream: the readahead showcase.
        let stream = |c: &WtfClient| {
            let mut fd = c.open("/seq").unwrap();
            let mut n = 0u64;
            loop {
                let got = c.read(&mut fd, 64 * 1024).unwrap();
                if got.is_empty() {
                    break;
                }
                n += got.len() as u64;
            }
            assert_eq!(n, total);
        };
        stream(&c);
        let e1 = cluster.transport_envelopes();
        stream(&c);
        let stream_env = cluster.transport_envelopes() - e1;
        let s = Bench::new(format!("client/read-stream-4MiB [{name}]"))
            .warmup(1)
            .iters(8)
            .run(|| stream(&c));
        println!("  └─ warm envelopes/pass: {stream_env}");
        rows.push(ReadRow {
            row: "seq-read-stepped-warm",
            config: name,
            envelopes: stream_env,
            mean_ns: s.mean,
        });
    }
    rows
}

/// The §4.1 sort under the paper's GbE link, seed vs fast-read config:
/// the shuffle's bucket files are patchworks of slices scattered over
/// the cluster, so coalescing their fetches cuts the wire rounds.
fn sort_read_path() -> Vec<ReadRow> {
    let run = |name: &'static str, fast: bool| -> ReadRow {
        let mut cfg = Config::test();
        if fast {
            cfg.metadata_cache = true;
            cfg.read_coalescing = true;
            cfg.readahead = 2 * cfg.region_size;
        }
        let cluster = Cluster::builder()
            .config(cfg)
            .link(LinkModel::gigabit())
            .build()
            .unwrap();
        let c = cluster.client();
        let mut job = SortJob::new(64, 8);
        job.chunk_records = 128;
        let data = generate_records(2048, job.fmt, 2015);
        c.write_file("/input", &data).unwrap();
        let mut n = 0u32;
        // One instrumented pass for the envelope count...
        let e0 = cluster.transport_envelopes();
        sort_slicing(&c, &NativeCompute, "/input", "/warm", &job).unwrap();
        let envelopes = cluster.transport_envelopes() - e0;
        // ...then timed passes.
        let s = Bench::new(format!("client/sort-128KiB-gigabit [{name}]"))
            .warmup(0)
            .iters(3)
            .run(|| {
                n += 1;
                sort_slicing(&c, &NativeCompute, "/input", &format!("/out{n}"), &job).unwrap()
            });
        println!("  └─ envelopes/sort: {envelopes}");
        ReadRow {
            row: "sort-small",
            config: name,
            envelopes,
            mean_ns: s.mean,
        }
    };
    vec![run("seed", false), run("fast-read", true)]
}

/// Emit the read-path rows as `BENCH_read_path.json` (status
/// "measured"); the committed modeled placeholder is overwritten by
/// running this bench with `WTF_BENCH_READ_JSON` set.
fn write_read_json(path: &str, rows: &[ReadRow]) {
    // A missing row is a bug in the sweep, not a 1 — silently
    // defaulting would feed bogus ratios into the CI regression gate.
    let env_of = |row: &str, config: &str| {
        rows.iter()
            .find(|r| r.row == row && r.config == config)
            .map(|r| r.envelopes.max(1))
            .unwrap_or_else(|| panic!("read-path sweep produced no row {row} [{config}]"))
    };
    let seq_ratio = env_of("seq-read-whole-warm", "seed") as f64
        / env_of("seq-read-whole-warm", "cache+coalesce") as f64;
    let stepped_ratio = env_of("seq-read-stepped-warm", "seed") as f64
        / env_of("seq-read-stepped-warm", "cache+coalesce+readahead") as f64;
    let sort_ratio =
        env_of("sort-small", "seed") as f64 / env_of("sort-small", "fast-read") as f64;
    let mut out = String::from("{\n  \"bench\": \"client_io/read_path\",\n");
    out.push_str(
        "  \"description\": \"Hot read path: versioned metadata cache x per-server \
         RetrieveMany coalescing x readahead, over a 4 MiB file of 16 regions x 4 \
         extents (envelopes counted per warm pass), plus the §4.1 slicing sort under \
         LinkModel::gigabit(). Produced by `cargo bench --bench client_io` with \
         WTF_BENCH_READ_JSON set; see rust/benches/client_io.rs.\",\n",
    );
    out.push_str("  \"status\": \"measured\",\n  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"row\": \"{}\", \"config\": \"{}\", \"envelopes\": {}, \"mean_ns\": {:.0}}}{}\n",
            r.row,
            r.config,
            r.envelopes,
            r.mean_ns,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    out.push_str(&format!(
        "  ],\n  \"envelope_ratio_seq\": {seq_ratio:.3},\n  \
         \"envelope_ratio_stepped\": {stepped_ratio:.3},\n  \
         \"envelope_ratio_sort\": {sort_ratio:.3},\n  \
         \"acceptance\": \"envelope_ratio_seq >= 4.0; envelope_ratio_sort >= 1.0\"\n}}\n"
    ));
    std::fs::write(path, out).expect("write WTF_BENCH_READ_JSON");
    println!("  └─ wrote {path}");
}

fn main() {
    let cluster = Cluster::builder()
        .config(Config {
            region_size: 1 << 22, // 4 MB regions
            ..Config::default()
        })
        .build()
        .unwrap();
    let c = cluster.client();

    let mut payload = vec![0u8; 256 * 1024];
    Rng::new(7).fill_bytes(&mut payload);

    // Sequential write path: 256 kB per op.
    let mut fd = c.create("/bench-w").unwrap();
    Bench::new("client/write-256k")
        .iters(40)
        .run_bytes(payload.len() as u64, || c.write(&mut fd, &payload).unwrap());

    // Append fast path.
    let fda = c.create("/bench-a").unwrap();
    Bench::new("client/append-256k")
        .iters(40)
        .run_bytes(payload.len() as u64, || {
            c.append_bytes(&fda, &payload).unwrap()
        });

    // Read path over the written file.
    let fr = c.open("/bench-w").unwrap();
    let mut off = 0u64;
    Bench::new("client/read-256k")
        .iters(40)
        .run_bytes(payload.len() as u64, || {
            let r = c.read_at(&fr, off, payload.len() as u64).unwrap();
            off = (off + payload.len() as u64) % (payload.len() as u64 * 16);
            r
        });

    // yank+paste: the metadata-only "write".
    let mut dst = c.create("/bench-paste").unwrap();
    Bench::new("client/yank+paste-256k (0 data bytes)")
        .iters(40)
        .run_bytes(payload.len() as u64, || {
            let s = c.yank_at(fr.inode(), 0, payload.len() as u64).unwrap();
            c.paste(&mut dst, &s).unwrap()
        });

    // concat of 8 files.
    for i in 0..8 {
        let mut f = c.create(&format!("/part{i}")).unwrap();
        c.write(&mut f, &payload).unwrap();
    }
    let parts: Vec<String> = (0..8).map(|i| format!("/part{i}")).collect();
    let refs: Vec<&str> = parts.iter().map(|s| s.as_str()).collect();
    let mut n = 0;
    Bench::new("client/concat-8x256k (metadata only)")
        .iters(30)
        .run(|| {
            n += 1;
            c.concat(&refs, &format!("/cat{n}")).unwrap()
        });

    // Transaction commit (small read-modify-write).
    let mut seed = c.create("/bench-txn").unwrap();
    c.write(&mut seed, b"0123456789abcdef").unwrap();
    Bench::new("client/txn(read+write+commit)").iters(40).run(|| {
        let mut t = c.begin();
        let fd = t.open("/bench-txn").unwrap();
        let data = t.read(fd, 8).unwrap();
        t.seek(fd, wtf::client::SeekFrom::End(0)).unwrap();
        t.write(fd, &data[..4]).unwrap();
        t.commit().unwrap()
    });

    // Replication fan-out under the paper's GbE model.
    let rows = fanout_sweep();
    if let Ok(path) = std::env::var("WTF_BENCH_JSON") {
        write_json(&path, &rows);
    }

    // Hot read path: cache x coalescing x readahead, plus the §4.1 sort.
    let mut read_rows = read_path_sweep();
    read_rows.extend(sort_read_path());
    if let Ok(path) = std::env::var("WTF_BENCH_READ_JSON") {
        write_read_json(&path, &read_rows);
    }
}
