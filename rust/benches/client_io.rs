//! End-to-end client hot paths on a real in-process cluster: the write
//! path (slices + blind metadata txn), the read path (resolve + fetch),
//! appends, the slicing ops whose cost is the paper's headline, and the
//! replication fan-out sweep under the simulated GbE link (the transport
//! scatter-gather's raison d'être).
//!
//! Set `WTF_BENCH_JSON=<path>` to also write the fan-out results as
//! JSON (committed as `BENCH_client_io.json` for cross-PR trajectory).

use wtf::bench::stats::Summary;
use wtf::bench::Bench;
use wtf::cluster::Cluster;
use wtf::config::Config;
use wtf::net::LinkModel;
use wtf::util::Rng;

/// Replication sweep under `LinkModel::gigabit()`: with the transport
/// scattering every replica upload, a replication-r `write_at` should
/// cost ~1 wire time, not r (acceptance: r=3 within 1.5x of r=1).
fn fanout_sweep() -> Vec<(u8, Summary)> {
    let mut payload = vec![0u8; 256 * 1024];
    Rng::new(9).fill_bytes(&mut payload);
    let mut rows = Vec::new();
    for r in [1u8, 2, 3] {
        let cluster = Cluster::builder()
            .config(Config {
                region_size: 1 << 22,
                storage_servers: 4,
                replication: r,
                ..Config::default()
            })
            .link(LinkModel::gigabit())
            .build()
            .unwrap();
        let c = cluster.client();
        let fd = c.create("/fanout").unwrap();
        let s = Bench::new(format!("client/write_at-256k-gigabit-r{r}"))
            .warmup(2)
            .iters(12)
            .run(|| c.write_at(fd.inode(), 0, &payload).unwrap());
        rows.push((r, s));
    }
    let r1 = rows[0].1.mean;
    let r3 = rows[2].1.mean;
    println!(
        "  └─ fan-out ratio r3/r1 = {:.2}x (serial RPC would be ~3x)",
        r3 / r1.max(1.0)
    );
    rows
}

/// Emit the fan-out rows in the `BENCH_client_io.json` schema (status
/// "measured"; re-running this bench is how the committed "modeled"
/// placeholder gets replaced with real wall-clock rows).
fn write_json(path: &str, rows: &[(u8, Summary)]) {
    let wire_ns = LinkModel::gigabit()
        .transfer_time(256 * 1024)
        .as_nanos() as u64;
    let mut out = String::from("{\n  \"bench\": \"client_io/fanout\",\n");
    out.push_str(
        "  \"description\": \"Replication sweep of 256 KiB write_at under \
         LinkModel::gigabit() (0.1 ms half-rtt, 125 MB/s). Produced by \
         `cargo bench --bench client_io` with WTF_BENCH_JSON set; see \
         rust/benches/client_io.rs.\",\n",
    );
    out.push_str("  \"status\": \"measured\",\n");
    out.push_str("  \"link\": \"gigabit (0.1 ms half-rtt, 125 MB/s)\",\n");
    out.push_str("  \"payload_bytes\": 262144,\n");
    out.push_str(&format!(
        "  \"wire_time_per_transfer_ns\": {wire_ns},\n  \"rows\": [\n"
    ));
    for (i, (r, s)) in rows.iter().enumerate() {
        // serial_model_ns: what a serial per-replica charge would cost —
        // the pre-transport baseline the measurement is compared to.
        out.push_str(&format!(
            "    {{\"replication\": {r}, \"mean_ns\": {:.0}, \"p50_ns\": {}, \
             \"p95_ns\": {}, \"serial_model_ns\": {}}}{}\n",
            s.mean,
            s.p50,
            s.p95,
            wire_ns * u64::from(*r),
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    let r1 = rows.first().map(|(_, s)| s.mean).unwrap_or(1.0);
    let r3 = rows.last().map(|(_, s)| s.mean).unwrap_or(1.0);
    out.push_str(&format!(
        "  ],\n  \"r3_over_r1\": {:.3}\n}}\n",
        r3 / r1.max(1.0)
    ));
    std::fs::write(path, out).expect("write WTF_BENCH_JSON");
    println!("  └─ wrote {path}");
}

fn main() {
    let cluster = Cluster::builder()
        .config(Config {
            region_size: 1 << 22, // 4 MB regions
            ..Config::default()
        })
        .build()
        .unwrap();
    let c = cluster.client();

    let mut payload = vec![0u8; 256 * 1024];
    Rng::new(7).fill_bytes(&mut payload);

    // Sequential write path: 256 kB per op.
    let mut fd = c.create("/bench-w").unwrap();
    Bench::new("client/write-256k")
        .iters(40)
        .run_bytes(payload.len() as u64, || c.write(&mut fd, &payload).unwrap());

    // Append fast path.
    let fda = c.create("/bench-a").unwrap();
    Bench::new("client/append-256k")
        .iters(40)
        .run_bytes(payload.len() as u64, || {
            c.append_bytes(&fda, &payload).unwrap()
        });

    // Read path over the written file.
    let fr = c.open("/bench-w").unwrap();
    let mut off = 0u64;
    Bench::new("client/read-256k")
        .iters(40)
        .run_bytes(payload.len() as u64, || {
            let r = c.read_at(&fr, off, payload.len() as u64).unwrap();
            off = (off + payload.len() as u64) % (payload.len() as u64 * 16);
            r
        });

    // yank+paste: the metadata-only "write".
    let mut dst = c.create("/bench-paste").unwrap();
    Bench::new("client/yank+paste-256k (0 data bytes)")
        .iters(40)
        .run_bytes(payload.len() as u64, || {
            let s = c.yank_at(fr.inode(), 0, payload.len() as u64).unwrap();
            c.paste(&mut dst, &s).unwrap()
        });

    // concat of 8 files.
    for i in 0..8 {
        let mut f = c.create(&format!("/part{i}")).unwrap();
        c.write(&mut f, &payload).unwrap();
    }
    let parts: Vec<String> = (0..8).map(|i| format!("/part{i}")).collect();
    let refs: Vec<&str> = parts.iter().map(|s| s.as_str()).collect();
    let mut n = 0;
    Bench::new("client/concat-8x256k (metadata only)")
        .iters(30)
        .run(|| {
            n += 1;
            c.concat(&refs, &format!("/cat{n}")).unwrap()
        });

    // Transaction commit (small read-modify-write).
    let mut seed = c.create("/bench-txn").unwrap();
    c.write(&mut seed, b"0123456789abcdef").unwrap();
    Bench::new("client/txn(read+write+commit)").iters(40).run(|| {
        let mut t = c.begin();
        let fd = t.open("/bench-txn").unwrap();
        let data = t.read(fd, 8).unwrap();
        t.seek(fd, wtf::client::SeekFrom::End(0)).unwrap();
        t.write(fd, &data[..4]).unwrap();
        t.commit().unwrap()
    });

    // Replication fan-out under the paper's GbE model.
    let rows = fanout_sweep();
    if let Ok(path) = std::env::var("WTF_BENCH_JSON") {
        write_json(&path, &rows);
    }
}
