//! Transactional read-through bench (PR 9): metadata-plane envelopes
//! for a warm WTF transaction, cached vs uncached.
//!
//! Two scenarios, identical op sequences in both configs:
//!
//! * **txn-concat** — open two warm 8 KiB files, read both fully, and
//!   append the concatenation to a third file, in one transaction.
//!   Uncached, every `t.open`/`t.read`/`t.seek` pays a `MetaGet`
//!   (2 path + 2 inode + 4 region + 1 path + 1 inode = 10) plus the
//!   `MetaCommit` — 11 metadata envelopes.  With the versioned cache
//!   warm, every read is served locally with its version recorded in
//!   the read set, so the whole transaction is ONE envelope (the
//!   commit, which also validates the cached versions).
//! * **txn-rmw** — read-modify-write of one warm 8 KiB file: uncached
//!   1 path + 1 inode + 2 region + 1 commit = 5 envelopes; cached 1.
//!
//! Envelope counts are exact deterministic integers (no timers), so the
//! gated figures are regression pins:
//!
//!   `meta_envelope_ratio_concat = uncached / cached   (gate: >= 2.0)`
//!
//! Set `WTF_BENCH_TXN_READ_JSON=<path>` to emit the results as JSON
//! (committed as `BENCH_txn_read.json` for the CI regression gate).

use wtf::client::SeekFrom;
use wtf::cluster::Cluster;
use wtf::config::Config;
use wtf::net::Plane;

struct Row {
    row: &'static str,
    config: &'static str,
    meta_envelopes: u64,
}

/// Warm one file end-to-end through the plain client: path + inode +
/// every region + the data bytes.
fn warm(c: &wtf::client::WtfClient, path: &str, len: u64) {
    let fd = c.open(path).unwrap();
    assert_eq!(c.read_at(&fd, 0, len).unwrap().len() as u64, len);
}

/// One transactional concat over a fresh cluster; returns the metadata
/// envelopes the transaction itself cost.
fn txn_concat(cfg: Config) -> u64 {
    let cluster = Cluster::builder().config(cfg).build().unwrap();
    let c = cluster.client();
    for path in ["/a", "/b"] {
        let mut fd = c.create(path).unwrap();
        c.write(&mut fd, &[b'v'; 8192]).unwrap();
    }
    c.create("/out").unwrap();
    warm(&c, "/a", 8192);
    warm(&c, "/b", 8192);
    let _ = c.open("/out").unwrap(); // path + inode
    let before = cluster.transport_envelopes_on(Plane::Meta);
    let mut t = c.begin();
    let a = t.open("/a").unwrap();
    let b = t.open("/b").unwrap();
    let xs = t.read(a, 8192).unwrap();
    let ys = t.read(b, 8192).unwrap();
    let o = t.open("/out").unwrap();
    t.seek(o, SeekFrom::End(0)).unwrap();
    t.write(o, &xs).unwrap();
    t.write(o, &ys).unwrap();
    t.commit().unwrap();
    cluster.transport_envelopes_on(Plane::Meta) - before
}

/// One transactional read-modify-write over a fresh cluster.
fn txn_rmw(cfg: Config) -> u64 {
    let cluster = Cluster::builder().config(cfg).build().unwrap();
    let c = cluster.client();
    let mut fd = c.create("/f").unwrap();
    c.write(&mut fd, &[b'v'; 8192]).unwrap();
    warm(&c, "/f", 8192);
    let before = cluster.transport_envelopes_on(Plane::Meta);
    let mut t = c.begin();
    let f = t.open("/f").unwrap();
    let bytes = t.read(f, 8192).unwrap();
    t.seek(f, SeekFrom::Start(0)).unwrap();
    let flipped: Vec<u8> = bytes.iter().map(|b| !b).collect();
    t.write(f, &flipped).unwrap();
    t.commit().unwrap();
    cluster.transport_envelopes_on(Plane::Meta) - before
}

fn write_json(path: &str, rows: &[Row], concat_ratio: f64, rmw_ratio: f64) {
    let mut out = String::from("{\n  \"bench\": \"client_io/txn_read\",\n");
    out.push_str(
        "  \"description\": \"Transactional read-through (PR 9): metadata-plane \
         envelopes for one warm WTF transaction, uncached vs versioned-cache-warm. \
         txn-concat opens two warm 8 KiB files, reads both, and appends the concat \
         to a third file; txn-rmw read-modify-writes one warm file.  Counts are \
         exact deterministic integers.  Produced by `cargo bench --bench txn_read` \
         with WTF_BENCH_TXN_READ_JSON set; see rust/benches/txn_read.rs.\",\n",
    );
    out.push_str("  \"status\": \"measured\",\n  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"row\": \"{}\", \"config\": \"{}\", \"meta_envelopes\": {}}}{}\n",
            r.row,
            r.config,
            r.meta_envelopes,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    out.push_str(&format!(
        "  ],\n  \"meta_envelope_ratio_concat\": {concat_ratio:.3},\n  \
         \"meta_envelope_ratio_rmw\": {rmw_ratio:.3},\n  \
         \"acceptance\": \"meta_envelope_ratio_concat >= 2.0 (a warm transactional \
         concat must cost at least 2x fewer metadata-plane envelopes with the \
         versioned cache than without; stale cached reads are caught by commit-time \
         validation, so the discount is free of staleness)\"\n}}\n"
    ));
    std::fs::write(path, out).expect("write WTF_BENCH_TXN_READ_JSON");
    println!("  └─ wrote {path}");
}

fn main() {
    let cached = || {
        let mut cfg = Config::fast_read_test();
        cfg.readahead = 0; // isolate the cache: no readahead in the count
        cfg
    };
    let concat_uncached = txn_concat(Config::test());
    let concat_cached = txn_concat(cached());
    let rmw_uncached = txn_rmw(Config::test());
    let rmw_cached = txn_rmw(cached());
    let rows = vec![
        Row { row: "txn-concat", config: "uncached", meta_envelopes: concat_uncached },
        Row { row: "txn-concat", config: "cached-warm", meta_envelopes: concat_cached },
        Row { row: "txn-rmw", config: "uncached", meta_envelopes: rmw_uncached },
        Row { row: "txn-rmw", config: "cached-warm", meta_envelopes: rmw_cached },
    ];
    let concat_ratio = concat_uncached as f64 / concat_cached.max(1) as f64;
    let rmw_ratio = rmw_uncached as f64 / rmw_cached.max(1) as f64;
    for r in &rows {
        println!(
            "txn_read/{} [{}]: {} metadata envelopes",
            r.row, r.config, r.meta_envelopes
        );
    }
    println!("txn_read: concat ratio {concat_ratio:.2}x, rmw ratio {rmw_ratio:.2}x");
    assert!(
        concat_ratio >= 2.0,
        "warm transactional concat must save >= 2x metadata envelopes \
         (uncached {concat_uncached}, cached {concat_cached})"
    );
    if let Ok(path) = std::env::var("WTF_BENCH_TXN_READ_JSON") {
        write_json(&path, &rows, concat_ratio, rmw_ratio);
    }
}
