//! Partition-heal convergence sweep (PR 8): how quickly a replicated
//! store comes back after the network does its worst.
//!
//! For each seed: establish a leader, lay background dup/reorder noise
//! on the Paxos plane, partition the majority of every shard group away
//! from its leader (writes must fail promptly, not hang), then heal and
//! count the commit ROUNDS until the first post-heal commit lands.  The
//! retry budget is 16 rounds; the gated figure is
//!
//!   `convergence_ratio = budget / max(rounds_after_heal)`
//!
//! so a store that converges on the first post-heal commit scores 16.0
//! and anything that eats the whole budget scores 1.0 — the CI gate
//! requires > 1.0.  Round counts are fully deterministic in the seed
//! (integer dice, manual clock), so this bench doubles as a regression
//! pin on recovery behavior, not just a timer.
//!
//! Set `WTF_BENCH_CHAOS_JSON=<path>` to emit the results as JSON
//! (committed as `BENCH_chaos.json` for the CI regression gate).

use std::sync::Arc;
use wtf::coordinator::lease::LeaseClock;
use wtf::meta::{Commit, MetaOp, ReplicatedMetaStore};
use wtf::net::{CutMode, Peer, Plane, Transport, Turbulence, TurbulenceRule};
use wtf::types::{Key, SliceData, SlicePtr, Space};

const REPLICAS: usize = 3;
const SHARDS: u32 = 2;
const BUDGET: u64 = 16;

struct Row {
    seed: u64,
    rounds_after_heal: u64,
    faults_injected: u64,
}

fn append_commit(key: &Key) -> Commit {
    Commit {
        reads: vec![],
        ops: vec![MetaOp::RegionAppendEof {
            key: key.clone(),
            data: SliceData::Stored(vec![SlicePtr {
                server: 1,
                backing: 0,
                offset: 0,
                len: 8,
            }]),
            len: 8,
            cap: 1 << 30,
        }],
    }
}

/// One seeded partition-heal cycle; returns the row for the JSON.
fn convergence(seed: u64) -> Row {
    let clock = LeaseClock::manual();
    let transport = Arc::new(Transport::instant());
    let chaos = Turbulence::new(seed, clock.clone());
    transport.set_turbulence(Some(chaos.clone()));
    let store = Arc::new(
        ReplicatedMetaStore::new(SHARDS, REPLICAS as u8, transport, clock.clone(), 20)
            .two_pc(true),
    );
    let key = |i: u64| Key::new(Space::Region, format!("cvg{i}"));

    // Clean air: elect leaders and land one commit per shard.
    for i in 0..u64::from(SHARDS) {
        store.commit(&append_commit(&key(i)), true).unwrap();
    }

    // Storm: background duplicate/reorder noise, then every group's
    // majority drops off the network — each leader is minority-side.
    chaos.add_rule(TurbulenceRule {
        plane: Some(Plane::Paxos),
        dup: 128,
        reorder: 128,
        ..TurbulenceRule::default()
    });
    for g in store.groups() {
        for r in 1..REPLICAS {
            let peer: Peer = g.replica(r).expect("replica index").clone();
            chaos.cut(&peer, CutMode::Both);
        }
    }
    // Partitioned writes fail promptly (no quorum), never hang.
    assert!(
        store.commit(&append_commit(&key(40)), true).is_err(),
        "seed {seed}: a minority side must not commit"
    );

    // Heal, expire the partition-era leases, and count commit rounds
    // until the store takes writes again.
    chaos.clear_rules();
    chaos.heal_all_cuts();
    clock.advance(64);
    let mut rounds = BUDGET;
    for attempt in 0..BUDGET {
        if store.commit(&append_commit(&key(100 + attempt)), true).is_ok() {
            rounds = attempt + 1;
            break;
        }
    }
    assert!(
        rounds <= BUDGET,
        "seed {seed}: no commit landed within the {BUDGET}-round budget"
    );
    assert!(store.converged(), "seed {seed}: replicas diverged after heal");
    println!(
        "chaos/convergence [seed {seed}]: {rounds} round(s) after heal, \
         {} faults injected",
        chaos.faults_injected()
    );
    Row {
        seed,
        rounds_after_heal: rounds,
        faults_injected: chaos.faults_injected(),
    }
}

fn write_json(path: &str, rows: &[Row], ratio: f64) {
    let mut out = String::from("{\n  \"bench\": \"chaos/convergence\",\n");
    out.push_str(
        "  \"description\": \"Partition-heal convergence: per seed, a leader is \
         established, dup/reorder noise is laid on the Paxos plane, the majority of \
         every shard group is cut away (writes fail promptly), then the network heals \
         and the sweep counts commit rounds until the first post-heal commit lands \
         (budget 16).  Deterministic in the seed.  Produced by `cargo bench --bench \
         chaos` with WTF_BENCH_CHAOS_JSON set; see rust/benches/chaos.rs.\",\n",
    );
    out.push_str("  \"status\": \"measured\",\n  \"budget_rounds\": 16,\n  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"seed\": {}, \"rounds_after_heal\": {}, \"faults_injected\": {}}}{}\n",
            r.seed,
            r.rounds_after_heal,
            r.faults_injected,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    out.push_str(&format!(
        "  ],\n  \"convergence_ratio\": {ratio:.3},\n  \
         \"acceptance\": \"convergence_ratio > 1.0 (after every seeded partition \
         heals, the store takes commits again in strictly fewer rounds than the \
         16-round retry budget)\"\n}}\n"
    ));
    std::fs::write(path, out).expect("write WTF_BENCH_CHAOS_JSON");
    println!("  └─ wrote {path}");
}

fn main() {
    let rows: Vec<Row> = [1u64, 7, 1234].iter().map(|&s| convergence(s)).collect();
    let worst = rows
        .iter()
        .map(|r| r.rounds_after_heal)
        .max()
        .unwrap()
        .max(1);
    let ratio = BUDGET as f64 / worst as f64;
    assert!(
        ratio > 1.0,
        "post-heal convergence ate the whole retry budget (worst {worst} rounds)"
    );
    if let Ok(path) = std::env::var("WTF_BENCH_CHAOS_JSON") {
        write_json(&path, &rows, ratio);
    }
}
