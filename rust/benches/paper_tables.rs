//! End-to-end regeneration of every table and figure (quick mode) —
//! `cargo bench` therefore reproduces the paper's evaluation shapes in
//! one command.  Use `repro bench --all` (without --quick) for the
//! full-size runs.

use wtf::bench::exps;

fn main() {
    for id in exps::all_experiments() {
        let t0 = std::time::Instant::now();
        match exps::run(id, true) {
            Ok(report) => {
                report.print();
                println!("  [{id} regenerated in {:.2?}]\n", t0.elapsed());
            }
            Err(e) => {
                eprintln!("experiment {id} FAILED: {e}");
                std::process::exit(1);
            }
        }
    }
}
