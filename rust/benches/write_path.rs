//! Write-path symmetry sweep (PR 6): Paxos group commit x single-scatter
//! prepare batching x client write-behind.
//!
//! Three independent measurements, each against its unbatched seed:
//!
//! * `commit-storm`: N=8 concurrent single-shard commits.  With group
//!   commit on, the concurrently-arriving proposals pack into shared
//!   `Batch` log entries — fewer Paxos commit rounds and fewer
//!   Paxos-plane envelopes for the same N durable transactions.
//! * `2pc-cross-shard`: one two-participant 2PC commit.  With prepare
//!   batching on, each phase's per-group proposals collapse into shared
//!   transport scatters (envelope count identical by construction —
//!   the win is scatter/wakeup rounds, not wire bytes).
//! * `append-burst`: 8 client appends to one file.  With write-behind
//!   on, the queue aims ONCE for the whole burst (one fresh inode fetch
//!   plus one flush fence) where the synchronous path pays a fresh
//!   fetch per append.
//!
//! Set `WTF_BENCH_WRITE_JSON=<path>` to emit the results as JSON
//! (committed as `BENCH_write_path.json` for the CI regression gate).

use std::sync::{Arc, Barrier};
use std::time::Duration;
use wtf::bench::Bench;
use wtf::cluster::Cluster;
use wtf::config::Config;
use wtf::coordinator::lease::LeaseClock;
use wtf::meta::{Commit, MetaOp, ReplicatedMetaStore};
use wtf::net::{Plane, Transport};
use wtf::types::{Key, SliceData, SlicePtr, Space};

const STORM: usize = 8;

struct Row {
    row: &'static str,
    config: &'static str,
    rounds: u64,
    envelopes: u64,
    scatters: u64,
    mean_ns: f64,
}

fn append_commit(key: &Key) -> Commit {
    Commit {
        reads: vec![],
        ops: vec![MetaOp::RegionAppendEof {
            key: key.clone(),
            data: SliceData::Stored(vec![SlicePtr {
                server: 1,
                backing: 0,
                offset: 0,
                len: 8,
            }]),
            len: 8,
            cap: 1 << 30,
        }],
    }
}

/// `n` keys guaranteed to land on ONE shard group (single-shard commits
/// are what group commit can pack).
fn same_shard_keys(store: &ReplicatedMetaStore, n: usize, tag: &str) -> Vec<Key> {
    let mut found: Vec<Key> = Vec::new();
    let mut shard = None;
    for i in 0..100_000 {
        let k = Key::new(Space::Region, format!("{tag}{i}"));
        let s = store.group_of(&k).shard();
        match shard {
            None => {
                shard = Some(s);
                found.push(k);
            }
            Some(t) if t == s => found.push(k),
            _ => {}
        }
        if found.len() == n {
            break;
        }
    }
    assert_eq!(found.len(), n, "could not find {n} same-shard keys");
    found
}

/// Two keys on distinct shard groups (a cross-shard 2PC commit).
fn cross_shard_keys(store: &ReplicatedMetaStore, tag: &str) -> Vec<Key> {
    let mut found: Vec<(u32, Key)> = Vec::new();
    for i in 0..100_000 {
        let k = Key::new(Space::Region, format!("{tag}{i}"));
        let s = store.group_of(&k).shard();
        if !found.iter().any(|(t, _)| *t == s) {
            found.push((s, k));
            if found.len() == 2 {
                break;
            }
        }
    }
    found.into_iter().map(|(_, k)| k).collect()
}

fn storm_store(batched: bool) -> (Arc<ReplicatedMetaStore>, Arc<Transport>) {
    let transport = Arc::new(Transport::instant());
    let mut store = ReplicatedMetaStore::new(4, 3, transport.clone(), LeaseClock::manual(), 20)
        .two_pc(true);
    if batched {
        store = store
            .group_commit(Duration::from_millis(2), STORM)
            .prepare_batching(true);
    }
    (Arc::new(store), transport)
}

/// One storm pass: N threads, one single-shard commit each, released by
/// a barrier so the arrivals genuinely overlap.
fn run_storm(store: &Arc<ReplicatedMetaStore>, keys: &[Key]) {
    let barrier = Arc::new(Barrier::new(keys.len()));
    let threads: Vec<_> = keys
        .iter()
        .cloned()
        .map(|k| {
            let store = store.clone();
            let barrier = barrier.clone();
            std::thread::spawn(move || {
                barrier.wait();
                store.commit(&append_commit(&k), true).unwrap();
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
}

fn commit_storm(config: &'static str, batched: bool) -> Row {
    let (store, transport) = storm_store(batched);
    let keys = same_shard_keys(&store, STORM, "storm");
    // Warm the group: elections and first-proposal prepares happen
    // here, not inside the measured window.
    store.commit(&append_commit(&keys[0]), true).unwrap();

    // One instrumented storm for rounds + Paxos-plane envelopes...
    let r0 = store.commit_rounds();
    let e0 = transport.envelopes_sent_on(Plane::Paxos);
    run_storm(&store, &keys);
    let rounds = store.commit_rounds() - r0;
    let envelopes = transport.envelopes_sent_on(Plane::Paxos) - e0;
    assert!(store.converged(), "storm diverged [{config}]");

    // ...then timed passes.
    let s = Bench::new(format!("write_path/commit-storm-x{STORM} [{config}]"))
        .warmup(1)
        .iters(8)
        .run(|| run_storm(&store, &keys));
    println!("  └─ storm rounds: {rounds}, paxos envelopes: {envelopes}");
    Row {
        row: "commit-storm",
        config,
        rounds,
        envelopes,
        scatters: 0,
        mean_ns: s.mean,
    }
}

fn two_pc_commit(config: &'static str, batched: bool) -> Row {
    let transport = Arc::new(Transport::instant());
    let mut store = ReplicatedMetaStore::new(4, 3, transport.clone(), LeaseClock::manual(), 20)
        .two_pc(true);
    if batched {
        store = store.prepare_batching(true);
    }
    let store = Arc::new(store);
    let keys = cross_shard_keys(&store, "xs");
    // Warm BOTH participant groups: a fresh group's first proposal
    // takes the slow (prepare) path, which isn't what this measures.
    for k in &keys {
        store.commit(&append_commit(k), true).unwrap();
    }

    let commit = Commit {
        reads: vec![],
        ops: keys
            .iter()
            .map(|k| {
                let mut one = append_commit(k);
                one.ops.remove(0)
            })
            .collect(),
    };
    let e0 = transport.envelopes_sent_on(Plane::Paxos);
    let s0 = transport.scatters_sent();
    store.commit(&commit, true).unwrap();
    let envelopes = transport.envelopes_sent_on(Plane::Paxos) - e0;
    let scatters = transport.scatters_sent() - s0;

    let s = Bench::new(format!("write_path/2pc-cross-shard [{config}]"))
        .warmup(2)
        .iters(16)
        .run(|| store.commit(&commit, true).unwrap());
    println!("  └─ 2pc scatters: {scatters}, paxos envelopes: {envelopes}");
    Row {
        row: "2pc-cross-shard",
        config,
        rounds: 0,
        envelopes,
        scatters,
        mean_ns: s.mean,
    }
}

fn append_burst(config: &'static str, write_behind: bool) -> Row {
    let mut cfg = Config::replicated_test();
    cfg.write_behind = write_behind;
    let cl = Cluster::builder().config(cfg).build().unwrap();
    let c = cl.client();
    let fd = c.create("/burst").unwrap();
    let payload = [7u8; 256];
    // Warm (and drain, so the instrumented window is only the burst).
    c.append_bytes(&fd, &payload).unwrap();
    c.flush().unwrap();

    let e0 = cl.transport_envelopes_on(Plane::Meta);
    for _ in 0..8 {
        c.append_bytes(&fd, &payload).unwrap();
    }
    c.flush().unwrap();
    let envelopes = cl.transport_envelopes_on(Plane::Meta) - e0;

    let s = Bench::new(format!("write_path/append-burst-x8 [{config}]"))
        .warmup(1)
        .iters(8)
        .run(|| {
            for _ in 0..8 {
                c.append_bytes(&fd, &payload).unwrap();
            }
            c.flush().unwrap();
        });
    println!("  └─ burst meta envelopes: {envelopes}");
    Row {
        row: "append-burst",
        config,
        rounds: 0,
        envelopes,
        scatters: 0,
        mean_ns: s.mean,
    }
}

/// Emit `BENCH_write_path.json` (status "measured"); running this bench
/// with `WTF_BENCH_WRITE_JSON` set replaces the committed modeled
/// placeholder with real rows.
fn write_json(path: &str, rows: &[Row]) {
    let find = |row: &str, config: &str| {
        rows.iter()
            .find(|r| r.row == row && r.config == config)
            .unwrap_or_else(|| panic!("write-path sweep produced no row {row} [{config}]"))
    };
    let storm_seed = find("commit-storm", "seed");
    let storm_batched = find("commit-storm", "group-commit");
    let rounds_ratio = storm_seed.rounds as f64 / storm_batched.rounds.max(1) as f64;
    let envelope_ratio =
        storm_seed.envelopes as f64 / storm_batched.envelopes.max(1) as f64;
    let scatter_ratio = find("2pc-cross-shard", "seed").scatters as f64
        / find("2pc-cross-shard", "prepare-batching").scatters.max(1) as f64;
    let meta_ratio = find("append-burst", "seed").envelopes as f64
        / find("append-burst", "write-behind").envelopes.max(1) as f64;
    let mut out = String::from("{\n  \"bench\": \"write_path/symmetry\",\n");
    out.push_str(
        "  \"description\": \"Write path: Paxos group commit (N=8 same-shard commit \
         storm, rounds + Paxos-plane envelopes per storm), single-scatter 2PC prepare \
         batching (transport scatters per cross-shard commit; envelopes identical by \
         construction), and client write-behind (metadata-plane envelopes per 8-append \
         burst; one hoisted aim fetch per queue). Produced by `cargo bench --bench \
         write_path` with WTF_BENCH_WRITE_JSON set; see rust/benches/write_path.rs.\",\n",
    );
    out.push_str("  \"status\": \"measured\",\n  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"row\": \"{}\", \"config\": \"{}\", \"rounds\": {}, \"envelopes\": {}, \
             \"scatters\": {}, \"mean_ns\": {:.0}}}{}\n",
            r.row,
            r.config,
            r.rounds,
            r.envelopes,
            r.scatters,
            r.mean_ns,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    out.push_str(&format!(
        "  ],\n  \"commit_rounds_ratio_storm\": {rounds_ratio:.3},\n  \
         \"envelope_ratio_batched\": {envelope_ratio:.3},\n  \
         \"scatter_ratio_2pc\": {scatter_ratio:.3},\n  \
         \"meta_envelope_ratio_write_behind\": {meta_ratio:.3},\n  \
         \"acceptance\": \"commit_rounds_ratio_storm > 1.0; envelope_ratio_batched >= 2.0; \
         scatter_ratio_2pc > 1.0\"\n}}\n"
    ));
    std::fs::write(path, out).expect("write WTF_BENCH_WRITE_JSON");
    println!("  └─ wrote {path}");
}

fn main() {
    let rows = vec![
        commit_storm("seed", false),
        commit_storm("group-commit", true),
        two_pc_commit("seed", false),
        two_pc_commit("prepare-batching", true),
        append_burst("seed", false),
        append_burst("write-behind", true),
    ];

    // The tentpole claim, asserted where the numbers are made: a storm
    // of N concurrent commits consumes measurably fewer Paxos commit
    // rounds and envelopes batched than N independent commits do.
    let seed = rows.iter().find(|r| r.row == "commit-storm" && r.config == "seed");
    let batched = rows
        .iter()
        .find(|r| r.row == "commit-storm" && r.config == "group-commit");
    if let (Some(seed), Some(batched)) = (seed, batched) {
        assert!(
            batched.rounds < seed.rounds,
            "group commit must pack rounds: {} !< {}",
            batched.rounds,
            seed.rounds
        );
        assert!(
            batched.envelopes < seed.envelopes,
            "group commit must save Paxos envelopes: {} !< {}",
            batched.envelopes,
            seed.envelopes
        );
    }

    if let Ok(path) = std::env::var("WTF_BENCH_WRITE_JSON") {
        write_json(&path, &rows);
    }
}
