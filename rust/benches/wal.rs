//! Durable WAL sweep (PR 7): what durability costs on the commit path
//! and what checkpointing buys back at restart.
//!
//! Four measurements:
//!
//! * `commit`: mean latency of a single-shard durable commit, by fsync
//!   policy (`in-memory` seed, then `sync-none`, `sync-batch`,
//!   `sync-always`).  Every durable mode writes the record BEFORE the
//!   ack; the modes differ only in when the write is forced to media.
//! * `replay`: wall-clock to reopen a replica WAL, against log length
//!   (100 vs 300 chosen records, no checkpoint).  Replay cost is linear
//!   in the un-checkpointed suffix.
//! * `replay-checkpointed`: the same 300-record history with a
//!   checkpoint every 64 chosen records: recovery loads one image and
//!   replays only the 44-record suffix.  The deterministic record-count
//!   ratio (`replay_ratio_checkpointed`) is what the CI gate checks —
//!   checkpointed replay must beat full replay.
//! * `fsync`: the PR-8 fsync group commit under `sync-always` — 64
//!   records appended one-by-one (64 forced syncs) versus as one acked
//!   batch (1).  `fsync_ratio_group_commit` is the gated ratio.
//!
//! Set `WTF_BENCH_WAL_JSON=<path>` to emit the results as JSON
//! (committed as `BENCH_wal.json` for the CI regression gate).

use std::path::Path;
use std::sync::Arc;
use wtf::bench::Bench;
use wtf::config::WalSync;
use wtf::coordinator::lease::LeaseClock;
use wtf::meta::{Checkpoint, Commit, LogEntry, MetaOp, ReplicatedMetaStore};
use wtf::meta::{ReplicaWal, WalRecord, WalSetup};
use wtf::net::Transport;
use wtf::types::{Key, SliceData, SlicePtr, Space, Value};
use wtf::util::TempDir;

struct Row {
    row: &'static str,
    config: &'static str,
    /// Chosen records in the history this row measured.
    records: u64,
    /// Records a restart replays beyond the checkpoint image.
    replayed: u64,
    /// Segment fsyncs one acked unit of this row's work paid (fsync
    /// rows only; 0 where fsync accounting is not the measurement).
    fsyncs: u64,
    mean_ns: f64,
}

fn append_commit(key: &Key) -> Commit {
    Commit {
        reads: vec![],
        ops: vec![MetaOp::RegionAppendEof {
            key: key.clone(),
            data: SliceData::Stored(vec![SlicePtr {
                server: 1,
                backing: 0,
                offset: 0,
                len: 8,
            }]),
            len: 8,
            cap: 1 << 30,
        }],
    }
}

/// Mean single-shard commit latency with the given durability mode
/// (`None` = the in-memory seed).  A huge checkpoint interval keeps
/// checkpoint installs out of the measured window: this row is the cost
/// of the append+sync discipline alone.
fn commit_latency(config: &'static str, sync: Option<WalSync>) -> Row {
    let dir = TempDir::new("wtf-bench-wal-commit").unwrap();
    let mut store = ReplicatedMetaStore::new(
        2,
        3,
        Arc::new(Transport::instant()),
        LeaseClock::manual(),
        20,
    )
    .two_pc(true);
    if let Some(s) = sync {
        store = store.durable(dir.path(), s, 1 << 30).unwrap();
    }
    let store = Arc::new(store);
    let key = Key::new(Space::Region, "walbench");
    // Warm: the election and first-proposal prepare happen here.
    store.commit(&append_commit(&key), true).unwrap();

    let s = Bench::new(format!("wal/commit [{config}]"))
        .warmup(8)
        .iters(64)
        .run(|| store.commit(&append_commit(&key), true).unwrap());
    assert!(store.converged(), "commit sweep diverged [{config}]");
    Row {
        row: "commit",
        config,
        records: 64,
        replayed: 0,
        fsyncs: 0,
        mean_ns: s.mean,
    }
}

fn chosen(slot: u64) -> WalRecord {
    WalRecord::Chosen {
        slot,
        entry: LogEntry::apply(
            slot + 1,
            vec![],
            vec![MetaOp::Put {
                key: Key::new(Space::Region, format!("r{slot}")),
                value: Value::U64(slot),
            }],
        ),
    }
}

/// Write `n` chosen records (checkpointing per `checkpoint_every`),
/// then measure the wall-clock of reopening the directory — the replay
/// a restarted replica pays before it can vote again.
fn replay(
    row: &'static str,
    config: &'static str,
    n: u64,
    checkpoint_every: u64,
) -> Row {
    let dir = TempDir::new("wtf-bench-wal-replay").unwrap();
    let setup = WalSetup {
        dir: dir.path().to_path_buf(),
        sync: WalSync::None, // replay cost is read-side; don't meter fsync
        checkpoint_every,
    };
    let (mut wal, recovered) = ReplicaWal::open(setup.clone(), 0, 0).unwrap();
    assert!(recovered.fresh);
    for slot in 0..n {
        wal.append(&chosen(slot)).unwrap();
        if wal.checkpoint_due() {
            // The image's exact content is the replica's business; the
            // replay path only cares that loading it replaces replaying
            // the truncated prefix.
            wal.install_checkpoint(&Checkpoint::default()).unwrap();
        }
    }
    drop(wal);

    let (_, recovered) = ReplicaWal::open(setup.clone(), 0, 0).unwrap();
    let replayed = recovered.records.len() as u64;
    assert_eq!(
        replayed,
        n % checkpoint_every.min(n + 1),
        "unexpected post-checkpoint suffix [{config}]"
    );
    let s = Bench::new(format!("wal/{row} [{config}]"))
        .warmup(2)
        .iters(16)
        .run(|| {
            ReplicaWal::open(setup.clone(), 0, 0).unwrap();
        });
    println!("  └─ {config}: {n} records, {replayed} replayed");
    Row {
        row,
        config,
        records: n,
        replayed,
        fsyncs: 0,
        mean_ns: s.mean,
    }
}

/// The PR-8 fsync group commit, measured where it lives: 64 chosen
/// records appended one-by-one versus as ONE acked batch, both under
/// `WalSync::Always`.  The per-record discipline forces media once per
/// record; `append_batch` applies the policy once for the whole acked
/// run.  The deterministic fsync-count ratio
/// (`fsync_ratio_group_commit`) is what the CI gate checks.
fn fsync_sweep(config: &'static str, batched: bool) -> Row {
    let dir = TempDir::new("wtf-bench-wal-fsync").unwrap();
    let setup = WalSetup {
        dir: dir.path().to_path_buf(),
        sync: WalSync::Always,
        checkpoint_every: u64::MAX,
    };
    let (mut wal, recovered) = ReplicaWal::open(setup, 0, 0).unwrap();
    assert!(recovered.fresh);
    let recs: Vec<WalRecord> = (0..64).map(chosen).collect();
    let mut runs = 0u64;
    let s = Bench::new(format!("wal/fsync [{config}]"))
        .warmup(2)
        .iters(16)
        .run(|| {
            runs += 1;
            if batched {
                wal.append_batch(&recs).unwrap();
            } else {
                for r in &recs {
                    wal.append(r).unwrap();
                }
            }
        });
    // Fsync accounting is exact, not sampled: under `Always` every
    // append_batch call is one forced sync, so one acked unit of 64
    // records costs 64 syncs per-record and 1 batched.
    let per_unit = wal.fsyncs() / runs.max(1);
    assert_eq!(
        per_unit,
        if batched { 1 } else { 64 },
        "unexpected fsync count per acked unit [{config}]"
    );
    println!("  └─ {config}: {per_unit} fsyncs per 64-record acked unit");
    Row {
        row: "fsync",
        config,
        records: 64,
        replayed: 0,
        fsyncs: per_unit,
        mean_ns: s.mean,
    }
}

/// Emit `BENCH_wal.json` (status "measured"); running this bench with
/// `WTF_BENCH_WAL_JSON` set replaces the committed modeled placeholder
/// with real rows.
fn write_json(path: &str, rows: &[Row]) {
    let find = |row: &str, config: &str| {
        rows.iter()
            .find(|r| r.row == row && r.config == config)
            .unwrap_or_else(|| panic!("wal sweep produced no row {row} [{config}]"))
    };
    let full = find("replay", "full-300");
    let ckpt = find("replay-checkpointed", "checkpointed-300");
    let ratio = full.replayed as f64 / ckpt.replayed.max(1) as f64;
    let per_rec = find("fsync", "per-record-64");
    let grouped = find("fsync", "group-commit-64");
    let fsync_ratio = per_rec.fsyncs as f64 / grouped.fsyncs.max(1) as f64;
    let mut out = String::from("{\n  \"bench\": \"wal/durability\",\n");
    out.push_str(
        "  \"description\": \"Durable replica WAL: single-shard commit latency by fsync \
         policy (in-memory seed vs sync-none/batch/always; the record is written before \
         every ack in all durable modes), replay wall-clock vs log length, \
         checkpoint-amortized replay (checkpoint every 64 chosen records truncates the \
         replayable prefix), and the fsync group commit (one forced sync per acked \
         batch under sync-always instead of one per record).  Produced by \
         `cargo bench --bench wal` with WTF_BENCH_WAL_JSON set; see rust/benches/wal.rs.\",\n",
    );
    out.push_str("  \"status\": \"measured\",\n  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"row\": \"{}\", \"config\": \"{}\", \"records\": {}, \
             \"replayed\": {}, \"fsyncs\": {}, \"mean_ns\": {:.0}}}{}\n",
            r.row,
            r.config,
            r.records,
            r.replayed,
            r.fsyncs,
            r.mean_ns,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    out.push_str(&format!(
        "  ],\n  \"replay_ratio_checkpointed\": {ratio:.3},\n  \
         \"fsync_ratio_group_commit\": {fsync_ratio:.3},\n  \
         \"acceptance\": \"replay_ratio_checkpointed > 1.0 (a checkpointed restart \
         replays strictly fewer records than a full-log restart of the same history); \
         fsync_ratio_group_commit > 1.0 (an acked batch pays strictly fewer forced \
         syncs than the same records appended one-by-one)\"\
         \n}}\n"
    ));
    std::fs::write(path, out).expect("write WTF_BENCH_WAL_JSON");
    println!("  └─ wrote {path}");
}

fn main() {
    let rows = vec![
        commit_latency("in-memory", None),
        commit_latency("sync-none", Some(WalSync::None)),
        commit_latency("sync-batch", Some(WalSync::Batch)),
        commit_latency("sync-always", Some(WalSync::Always)),
        replay("replay", "full-100", 100, u64::MAX),
        replay("replay", "full-300", 300, u64::MAX),
        replay("replay-checkpointed", "checkpointed-300", 300, 64),
        fsync_sweep("per-record-64", false),
        fsync_sweep("group-commit-64", true),
    ];

    // The tentpole claim, asserted where the numbers are made: the same
    // 300-record history restarts by replaying only its post-checkpoint
    // suffix when checkpoints ran.
    let full = rows
        .iter()
        .find(|r| r.row == "replay" && r.config == "full-300")
        .unwrap();
    let ckpt = rows
        .iter()
        .find(|r| r.row == "replay-checkpointed")
        .unwrap();
    assert_eq!(full.replayed, 300);
    assert_eq!(ckpt.replayed, 44, "300 records, checkpoint every 64");
    assert!(
        ckpt.replayed < full.replayed,
        "checkpointing must shrink the replayable prefix"
    );
    // And the PR-8 claim: one acked batch, one forced sync.
    let per_rec = rows
        .iter()
        .find(|r| r.row == "fsync" && r.config == "per-record-64")
        .unwrap();
    let grouped = rows
        .iter()
        .find(|r| r.row == "fsync" && r.config == "group-commit-64")
        .unwrap();
    assert!(
        grouped.fsyncs < per_rec.fsyncs,
        "the fsync group commit must pay fewer forced syncs per acked batch"
    );

    if let Ok(path) = std::env::var("WTF_BENCH_WAL_JSON") {
        write_json(&path, &rows);
    }
}
