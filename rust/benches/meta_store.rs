//! Metadata-store hot paths: gets, blind appends, conditional appends,
//! multi-key commits, conflict detection.  The paper's write path costs
//! one metadata transaction per write — this is the L3 floor.

use wtf::bench::Bench;
use wtf::meta::{Commit, MetaOp, MetaStore};
use wtf::types::{Key, Placement, RegionEntry, RegionMeta, SliceData, SlicePtr, Value};

fn stored(len: u64) -> SliceData {
    SliceData::Stored(vec![SlicePtr {
        server: 1,
        backing: 0,
        offset: 0,
        len,
    }])
}

fn main() {
    let store = MetaStore::new(8, 2);

    // Point gets on a populated store.
    for i in 0..10_000u64 {
        store
            .commit(&Commit {
                reads: vec![],
                ops: vec![MetaOp::Put {
                    key: Key::sys(format!("warm{i}")),
                    value: Value::U64(i),
                }],
            })
            .unwrap();
    }
    let mut i = 0u64;
    Bench::new("meta/get").iters(50).run(|| {
        i = (i + 1) % 10_000;
        store.get(&Key::sys(format!("warm{i}")))
    });

    // Blind region append (the common write-path op).
    let mut n = 0u64;
    Bench::new("meta/region-append(blind)").iters(50).run(|| {
        n += 1;
        let rid = Key::new(wtf::types::Space::Region, format!("r{}", n % 64));
        store.commit(&Commit {
            reads: vec![],
            ops: vec![MetaOp::RegionAppend {
                key: rid,
                entry: RegionEntry {
                    placement: Placement::At(n * 8),
                    len: 8,
                    data: stored(8),
                },
            }],
        })
    });

    // Conditional EOF append (the §2.5 fast path).
    let mut m = 0u64;
    Bench::new("meta/region-append(eof-cond)").iters(50).run(|| {
        m += 1;
        let rid = Key::new(wtf::types::Space::Region, format!("e{m}"));
        store.commit(&Commit {
            reads: vec![],
            ops: vec![MetaOp::RegionAppendEof {
                key: rid,
                data: stored(8),
                len: 8,
                cap: 1 << 26,
            }],
        })
    });

    // Multi-key transaction (create-file shape: 3 ops across spaces).
    let mut c = 0u64;
    Bench::new("meta/multi-key-create-txn").iters(50).run(|| {
        c += 1;
        store.commit(&Commit {
            reads: vec![(Key::path("/"), store.version(&Key::path("/")))],
            ops: vec![
                MetaOp::PathInsert {
                    key: Key::path(format!("/bench{c}")),
                    inode: c,
                    expect_absent: true,
                },
                MetaOp::Put {
                    key: Key::inode(c),
                    value: Value::Inode(wtf::types::Inode::new_file(c, 0o644, 2)),
                },
                MetaOp::Put {
                    key: Key::new(wtf::types::Space::Region, format!("br{c}")),
                    value: Value::Region(RegionMeta::default()),
                },
            ],
        })
    });

    // Bulk transaction: thousands of appends to ONE region in a single
    // commit (the shape of `concat` on a large file).
    let mut b = 0u64;
    Bench::new("meta/bulk-4096-appends-one-txn").iters(10).run(|| {
        b += 1;
        let key = Key::new(wtf::types::Space::Region, format!("bulk{b}"));
        let ops = (0..4096u64)
            .map(|i| MetaOp::RegionAppend {
                key: key.clone(),
                entry: RegionEntry {
                    placement: Placement::At(i * 8),
                    len: 8,
                    data: stored(8),
                },
            })
            .collect();
        store.commit(&Commit { reads: vec![], ops })
    });

    // Conflict detection cost (validation failure path).
    let key = Key::sys("conflict");
    store
        .commit(&Commit {
            reads: vec![],
            ops: vec![MetaOp::Put {
                key: key.clone(),
                value: Value::U64(0),
            }],
        })
        .unwrap();
    Bench::new("meta/conflict-detect").iters(50).run(|| {
        let stale = Commit {
            reads: vec![(key.clone(), 0)], // always stale
            ops: vec![],
        };
        let _ = store.commit(&stale);
    });
}
