//! Metadata-store hot paths: gets, blind appends, conditional appends,
//! multi-key commits, conflict detection — plus the replicated-commit
//! sweep (unreplicated chain store vs a 3-replica Paxos shard group).
//! The paper's write path costs one metadata transaction per write —
//! this is the L3 floor.
//!
//! Set `WTF_BENCH_JSON=<path>` to also write the replicated-commit rows
//! as JSON (committed as `BENCH_meta_store.json` for cross-PR
//! trajectory).

use std::sync::Arc;
use wtf::bench::stats::Summary;
use wtf::bench::Bench;
use wtf::coordinator::lease::LeaseClock;
use wtf::meta::{Commit, MetaOp, MetaStore, ReplicatedMetaStore};
use wtf::net::{LinkModel, Transport};
use wtf::types::{Key, Placement, RegionEntry, RegionMeta, SliceData, SlicePtr, Value};

fn stored(len: u64) -> SliceData {
    SliceData::Stored(vec![SlicePtr {
        server: 1,
        backing: 0,
        offset: 0,
        len,
    }])
}

/// Replicated-commit sweep: the same single-op commit against the
/// unreplicated chain store and a 3-replica Paxos group (lease fast
/// path: one scatter-gathered accept round per commit).  Measured
/// wall-clock is CPU cost (instant link); the JSON also carries the
/// gigabit wire model, where a quorum commit costs 2 wire rounds vs 1
/// unreplicated — the ≤2x acceptance bound, and ~1.06x once the
/// paper's ~3 ms HyperDex transaction floor is included.
fn replicated_sweep() -> (Summary, Summary) {
    let unrep = MetaStore::new(8, 2);
    let mut n = 0u64;
    let s_un = Bench::new("meta/commit-unreplicated").iters(50).run(|| {
        n += 1;
        unrep.commit(&Commit {
            reads: vec![],
            ops: vec![MetaOp::Put {
                key: Key::sys(format!("u{n}")),
                value: Value::U64(n),
            }],
        })
    });
    // Long lease so the sweep measures the fast path, not renewals.
    let rep = ReplicatedMetaStore::new(
        8,
        3,
        Arc::new(Transport::instant()),
        LeaseClock::auto(),
        60_000,
    );
    let mut m = 0u64;
    let s_rep = Bench::new("meta/commit-paxos3-quorum").iters(50).run(|| {
        m += 1;
        rep.commit(
            &Commit {
                reads: vec![],
                ops: vec![MetaOp::Put {
                    key: Key::sys(format!("r{m}")),
                    value: Value::U64(m),
                }],
            },
            true,
        )
    });
    println!(
        "  └─ quorum/unreplicated (measured CPU): {:.2}x; wire model: 2 rounds vs 1",
        s_rep.mean / s_un.mean.max(1.0)
    );
    (s_un, s_rep)
}

/// Emit the replicated-commit rows in the `BENCH_meta_store.json`
/// schema (status "measured"; re-running this bench replaces the
/// committed "modeled" placeholder with real wall-clock rows).
fn write_json(path: &str, s_un: &Summary, s_rep: &Summary) {
    let half_rtt_ns = LinkModel::gigabit().transfer_time(0).as_nanos() as u64;
    let txn_floor_ns = 3_000_000u64; // the paper's ~3 ms HyperDex floor
    let wire_un = 2 * half_rtt_ns; // request + response
    let wire_rep = 4 * half_rtt_ns; // + accept scatter + ack gather
    let mut out = String::from("{\n  \"bench\": \"meta_store/replicated_commit\",\n");
    out.push_str(
        "  \"description\": \"Single-op commit: unreplicated chain store vs \
         3-replica Paxos shard group on the leader-lease fast path (one \
         scatter-gathered accept round; learn piggybacks). Produced by \
         `cargo bench --bench meta_store` with WTF_BENCH_JSON set; see \
         rust/benches/meta_store.rs.\",\n",
    );
    out.push_str("  \"status\": \"measured\",\n");
    out.push_str("  \"link_model\": \"gigabit (0.1 ms half-rtt, 125 MB/s)\",\n");
    out.push_str(&format!("  \"txn_floor_ns\": {txn_floor_ns},\n"));
    out.push_str("  \"rows\": [\n");
    for (i, (mode, rounds, wire, s)) in [
        ("unreplicated", 1u32, wire_un, s_un),
        ("paxos-3-quorum", 2u32, wire_rep, s_rep),
    ]
    .iter()
    .enumerate()
    {
        out.push_str(&format!(
            "    {{\"mode\": \"{mode}\", \"wire_rounds\": {rounds}, \
             \"model_wire_ns\": {wire}, \"model_with_floor_ns\": {}, \
             \"mean_ns\": {:.0}, \"p50_ns\": {}, \"p95_ns\": {}}}{}\n",
            wire + txn_floor_ns,
            s.mean,
            s.p50,
            s.p95,
            if i == 0 { "," } else { "" }
        ));
    }
    out.push_str(&format!(
        "  ],\n  \"quorum_over_unreplicated_wire\": {:.3},\n",
        wire_rep as f64 / wire_un as f64
    ));
    out.push_str(&format!(
        "  \"quorum_over_unreplicated_with_floor\": {:.3},\n",
        (wire_rep + txn_floor_ns) as f64 / (wire_un + txn_floor_ns) as f64
    ));
    out.push_str(&format!(
        "  \"quorum_over_unreplicated_measured_cpu\": {:.3}\n}}\n",
        s_rep.mean / s_un.mean.max(1.0)
    ));
    std::fs::write(path, out).expect("write WTF_BENCH_JSON");
    println!("  └─ wrote {path}");
}

fn main() {
    let store = MetaStore::new(8, 2);

    // Point gets on a populated store.
    for i in 0..10_000u64 {
        store
            .commit(&Commit {
                reads: vec![],
                ops: vec![MetaOp::Put {
                    key: Key::sys(format!("warm{i}")),
                    value: Value::U64(i),
                }],
            })
            .unwrap();
    }
    let mut i = 0u64;
    Bench::new("meta/get").iters(50).run(|| {
        i = (i + 1) % 10_000;
        store.get(&Key::sys(format!("warm{i}")))
    });

    // Blind region append (the common write-path op).
    let mut n = 0u64;
    Bench::new("meta/region-append(blind)").iters(50).run(|| {
        n += 1;
        let rid = Key::new(wtf::types::Space::Region, format!("r{}", n % 64));
        store.commit(&Commit {
            reads: vec![],
            ops: vec![MetaOp::RegionAppend {
                key: rid,
                entry: RegionEntry {
                    placement: Placement::At(n * 8),
                    len: 8,
                    data: stored(8),
                },
            }],
        })
    });

    // Conditional EOF append (the §2.5 fast path).
    let mut m = 0u64;
    Bench::new("meta/region-append(eof-cond)").iters(50).run(|| {
        m += 1;
        let rid = Key::new(wtf::types::Space::Region, format!("e{m}"));
        store.commit(&Commit {
            reads: vec![],
            ops: vec![MetaOp::RegionAppendEof {
                key: rid,
                data: stored(8),
                len: 8,
                cap: 1 << 26,
            }],
        })
    });

    // Multi-key transaction (create-file shape: 3 ops across spaces).
    let mut c = 0u64;
    Bench::new("meta/multi-key-create-txn").iters(50).run(|| {
        c += 1;
        store.commit(&Commit {
            reads: vec![(Key::path("/"), store.version(&Key::path("/")))],
            ops: vec![
                MetaOp::PathInsert {
                    key: Key::path(format!("/bench{c}")),
                    inode: c,
                    expect_absent: true,
                },
                MetaOp::Put {
                    key: Key::inode(c),
                    value: Value::Inode(wtf::types::Inode::new_file(c, 0o644, 2)),
                },
                MetaOp::Put {
                    key: Key::new(wtf::types::Space::Region, format!("br{c}")),
                    value: Value::Region(RegionMeta::default()),
                },
            ],
        })
    });

    // Bulk transaction: thousands of appends to ONE region in a single
    // commit (the shape of `concat` on a large file).
    let mut b = 0u64;
    Bench::new("meta/bulk-4096-appends-one-txn").iters(10).run(|| {
        b += 1;
        let key = Key::new(wtf::types::Space::Region, format!("bulk{b}"));
        let ops = (0..4096u64)
            .map(|i| MetaOp::RegionAppend {
                key: key.clone(),
                entry: RegionEntry {
                    placement: Placement::At(i * 8),
                    len: 8,
                    data: stored(8),
                },
            })
            .collect();
        store.commit(&Commit { reads: vec![], ops })
    });

    // Conflict detection cost (validation failure path).
    let key = Key::sys("conflict");
    store
        .commit(&Commit {
            reads: vec![],
            ops: vec![MetaOp::Put {
                key: key.clone(),
                value: Value::U64(0),
            }],
        })
        .unwrap();
    Bench::new("meta/conflict-detect").iters(50).run(|| {
        let stale = Commit {
            reads: vec![(key.clone(), 0)], // always stale
            ops: vec![],
        };
        let _ = store.commit(&stale);
    });

    // Unreplicated vs quorum-replicated commit latency.
    let (s_un, s_rep) = replicated_sweep();
    if let Ok(path) = std::env::var("WTF_BENCH_JSON") {
        write_json(&path, &s_un, &s_rep);
    }
}
