//! Metadata compaction hot path: resolve/fuse cost as fragmentation
//! grows — tier-1 GC runs this over every region (§2.8), so it must be
//! cheap even for pathological overlay lists.

use wtf::bench::Bench;
use wtf::client::compact::{compact, fuse_extents, resolve_entries};
use wtf::types::{Placement, RegionEntry, RegionMeta, SliceData, SlicePtr};
use wtf::util::Rng;

fn sequential_entries(n: u64) -> Vec<RegionEntry> {
    (0..n)
        .map(|i| RegionEntry {
            placement: Placement::At(i * 64),
            len: 64,
            data: SliceData::Stored(vec![SlicePtr {
                server: (i % 4) as u32,
                backing: 0,
                offset: i * 64,
                len: 64,
            }]),
        })
        .collect()
}

fn random_entries(n: u64, span: u64, seed: u64) -> Vec<RegionEntry> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let at = rng.next_below(span);
            let len = 1 + rng.next_below(256);
            RegionEntry {
                placement: Placement::At(at),
                len,
                data: SliceData::Stored(vec![SlicePtr {
                    server: (i % 8) as u32,
                    backing: (i % 3) as u32,
                    offset: i * 1024,
                    len,
                }]),
            }
        })
        .collect()
}

fn main() {
    for n in [64u64, 512, 4096] {
        let seq = sequential_entries(n);
        Bench::new(format!("compact/resolve-seq-{n}"))
            .iters(30)
            .run(|| resolve_entries(&seq));

        let rand = random_entries(n, 1 << 20, n);
        Bench::new(format!("compact/resolve-rand-{n}"))
            .iters(30)
            .run(|| resolve_entries(&rand));

        let region = RegionMeta {
            spill: None,
            entries: rand.clone(),
            eof: 1 << 20,
        };
        Bench::new(format!("compact/full-compact-rand-{n}"))
            .iters(30)
            .run(|| compact(&region));
    }

    // Fusion of a fully-sequential overlay (the locality payoff).
    let seq = sequential_entries(4096);
    Bench::new("compact/fuse-seq-4096").iters(30).run(|| {
        let extents = resolve_entries(&seq);
        fuse_extents(extents)
    });

    // Spill encode/decode round trip.
    let entries = random_entries(4096, 1 << 26, 1);
    Bench::new("spill/encode-4096").iters(30).run(|| {
        wtf::client::spill::encode_entries(&entries).unwrap()
    });
    let bytes = wtf::client::spill::encode_entries(&entries).unwrap();
    Bench::new("spill/decode-4096").iters(30).run(|| {
        wtf::client::spill::decode_entries(&bytes).unwrap()
    });
}
