//! Simulator engine throughput: events (resource serves) per second.
//! The paper-scale figure sweeps issue millions of serves; the engine
//! must not be the bottleneck of `repro bench --all`.

use wtf::bench::Bench;
use wtf::sim::engine::{run_pipelined, Sim};
use wtf::sim::model::{ClusterModel, OpKind};
use wtf::sim::Testbed;

fn main() {
    // Raw serve throughput.
    let mut sim = Sim::new();
    let rs: Vec<_> = (0..16).map(|_| sim.resource()).collect();
    let mut i = 0usize;
    Bench::new("sim/serve-1M").iters(10).run(|| {
        let mut t = 0;
        for k in 0..1_000_000u64 {
            i = (i + 1) % rs.len();
            t = sim.serve(rs[i], t, k % 97);
        }
        t
    });

    // Full write-model op.
    Bench::new("sim/wtf-write-op-100k").iters(10).run(|| {
        let mut model = ClusterModel::new(Testbed::default(), 12, 1);
        run_pipelined(12, 100_000 / 12, |c, _, now| {
            model.wtf_write_op(c, 4 << 20, OpKind::SeqWrite, now)
        })
    });

    Bench::new("sim/hdfs-read-op-100k").iters(10).run(|| {
        let mut model = ClusterModel::new(Testbed::default(), 12, 1);
        run_pipelined(12, 100_000 / 12, |c, _, now| {
            let done = model.hdfs_seq_read(c, 1 << 20, now);
            (done, done)
        })
    });
}
