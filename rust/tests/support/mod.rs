//! Shared test support: the deterministic fault-schedule driver for the
//! cross-group 2PC suites.
//!
//! A [`Schedule`] is a list of `(At, Fault)` steps.  The driver installs
//! a fault hook on a [`ReplicatedMetaStore`] and runs one commit; each
//! time the commit passes a named protocol instant ([`At::matches`] a
//! [`CommitPhase`]), the matching steps fire — crashing replica quorums
//! and/or abandoning the coordinating front-end at exactly that point.
//! Schedules are plain data, so the property suite derives them from a
//! seeded [`Rng`] and every failure replays from its printed seed.
//!
//! After a run, [`heal_all`] rejoins every crashed replica and
//! [`assert_all_or_nothing`] checks the §3 contract: every participant
//! settles to the decision record's outcome (presumed abort when the
//! coordinator died before deciding), no intent stays pending, no
//! duplicate applies, and all live replicas converge.

#![allow(dead_code)] // each test crate uses a subset of this toolkit

use std::path::Path;
use std::sync::{Arc, Mutex};
use wtf::config::WalSync;
use wtf::coordinator::lease::LeaseClock;
use wtf::error::Result;
use wtf::meta::{Commit, CommitPhase, FaultAction, MetaOp, OpOutcome, ReplicatedMetaStore};
use wtf::net::{CutMode, Peer, Transport, Turbulence};
use wtf::types::{Key, SliceData, SlicePtr, Space};
use wtf::util::Rng;

/// Replicas per shard group in driver-built stores (quorum = 2).
pub const GROUP_REPLICAS: usize = 3;

/// Base seed for the seeded suites, taken from the CI matrix via
/// `WTF_TEST_SEED` (0 when unset).  Failures print this base seed (and
/// the case number derived from it), so re-exporting the printed
/// `WTF_TEST_SEED` value replays the exact failing schedule.
pub fn base_seed() -> u64 {
    std::env::var("WTF_TEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0)
}

/// True under the CI production matrix entry (`WTF_TEST_PRODUCTION=1`):
/// the fault-schedule jobs rerun with the deployment shape — every
/// driver-built store picks up the PR-6 write-path knobs exactly as
/// under `WTF_TEST_WRITE_PATH=1`, and the preset-parameterized storms
/// already run [`production_test_config`] unconditionally.
pub fn production_matrix() -> bool {
    std::env::var("WTF_TEST_PRODUCTION").as_deref() == Ok("1")
}

/// Whether driver-built stores should carry the batched write path
/// (group commit + prepare batching): either matrix dimension asks.
fn batched_write_path() -> bool {
    std::env::var("WTF_TEST_WRITE_PATH").as_deref() == Ok("1") || production_matrix()
}

/// [`Config::production`] scaled to test dimensions (PR 9): every
/// deployment knob — Paxos + 2PC metadata, the versioned metadata
/// cache, read coalescing, and the cache-TTL-strictly-below-GC-window
/// bound — kept, but on test-sized regions and a millisecond timescale
/// (a fault schedule must not wait out a 30 s TTL).  `validate()` runs
/// here so a preset drift that breaks the TTL/GC bound fails loudly in
/// every suite that uses this, not just in `config.rs` unit tests.
pub fn production_test_config() -> wtf::config::Config {
    let p = wtf::config::Config::production();
    let mut cfg = wtf::config::Config::test();
    cfg.meta_paxos = p.meta_paxos;
    cfg.meta_group_replicas = p.meta_group_replicas;
    cfg.meta_2pc = p.meta_2pc;
    cfg.metadata_cache = p.metadata_cache;
    cfg.read_coalescing = p.read_coalescing;
    cfg.cache_ttl = std::time::Duration::from_millis(50);
    cfg.gc_scan_interval = std::time::Duration::from_millis(500);
    cfg.validate().expect("scaled production preset must validate");
    cfg
}

/// A fresh `shards`-group, 3-replica, manually-clocked replicated store
/// with the intent-logged 2PC enabled — the fault-schedule testbed
/// (manual clock: lease waits advance deterministically, never block).
///
/// With `WTF_TEST_WRITE_PATH=1` or `WTF_TEST_PRODUCTION=1` (CI matrix
/// dimensions), the PR-6 write-path knobs ride along — group commit with a 1 ms window and
/// prepare batching — so every fault schedule also exercises the
/// batched proposal paths without changing any test.
pub fn store_2pc(shards: u32) -> Arc<ReplicatedMetaStore> {
    let mut store = ReplicatedMetaStore::new(
        shards,
        GROUP_REPLICAS as u8,
        Arc::new(Transport::instant()),
        LeaseClock::manual(),
        20,
    )
    .two_pc(true);
    if batched_write_path() {
        store = store
            .group_commit(std::time::Duration::from_millis(1), 8)
            .prepare_batching(true);
    }
    Arc::new(store)
}

/// A [`store_2pc`]-shaped store with a seeded [`Turbulence`] layer
/// installed on its transport — the chaos testbed.  Returns the store,
/// the turbulence handle (script probabilistic rules and partitions on
/// it) and the shared manual clock (delay faults advance it; tests
/// advance it too, so "a message arrived late" and "the lease window
/// passed" stay one fact).
pub fn noisy_store_2pc(
    shards: u32,
    seed: u64,
) -> (Arc<ReplicatedMetaStore>, Arc<Turbulence>, LeaseClock) {
    let clock = LeaseClock::manual();
    let transport = Arc::new(Transport::instant());
    let chaos = Turbulence::new(seed, clock.clone());
    transport.set_turbulence(Some(chaos.clone()));
    let mut store = ReplicatedMetaStore::new(
        shards,
        GROUP_REPLICAS as u8,
        transport,
        clock.clone(),
        20,
    )
    .two_pc(true);
    if batched_write_path() {
        store = store
            .group_commit(std::time::Duration::from_millis(1), 8)
            .prepare_batching(true);
    }
    (Arc::new(store), chaos, clock)
}

/// Partition `shard`'s group so its leader sits on the MINORITY side:
/// cut the links to every replica except replica 0 (the stable lowest
/// candidate).  The quorum becomes unreachable while the leaseholder
/// stays addressable — the paper's dangerous partition shape.
pub fn cut_group_majority(
    store: &ReplicatedMetaStore,
    chaos: &Turbulence,
    shard: u32,
    mode: CutMode,
) {
    let group = &store.groups()[shard as usize];
    for r in 1..GROUP_REPLICAS {
        let peer: Peer = group.replica(r).expect("replica index in range").clone();
        chaos.cut(&peer, mode);
    }
}

/// A [`store_2pc`]-shaped store whose replicas additionally carry
/// on-disk write-ahead logs under `wal_root` — the crash-recovery
/// testbed.  `WalSync::Always` and a small checkpoint interval so every
/// schedule exercises both replay-from-segment and
/// replay-from-checkpoint within a handful of commits.
pub fn store_durable(shards: u32, wal_root: &Path) -> Arc<ReplicatedMetaStore> {
    let mut store = ReplicatedMetaStore::new(
        shards,
        GROUP_REPLICAS as u8,
        Arc::new(Transport::instant()),
        LeaseClock::manual(),
        20,
    )
    .two_pc(true);
    if batched_write_path() {
        store = store
            .group_commit(std::time::Duration::from_millis(1), 8)
            .prepare_batching(true);
    }
    let store = store
        .durable(wal_root, WalSync::Always, 4)
        .expect("enable durable WALs");
    Arc::new(store)
}

/// Named instants of the 2PC protocol a scripted fault can fire at
/// (matched against the store's [`CommitPhase`] events).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum At {
    /// Gates held, ops staged, nothing proposed.
    Staged,
    /// This shard's `Prepare` intent just landed in its group's log.
    Prepared(u32),
    /// Every participant's intent is logged; no decision yet.
    AllPrepared,
    /// The decision record is replicated in the coordinator group.
    Decided,
    /// Phase 2 just resolved this (non-coordinator) shard.
    Applied(u32),
}

impl At {
    pub fn matches(self, phase: &CommitPhase) -> bool {
        match (self, phase) {
            (At::Staged, CommitPhase::Staged) => true,
            (At::Prepared(s), CommitPhase::Prepared { shard }) => s == *shard,
            (At::AllPrepared, CommitPhase::AllPrepared) => true,
            (At::Decided, CommitPhase::Decided { .. }) => true,
            (At::Applied(s), CommitPhase::Applied { shard }) => s == *shard,
            _ => false,
        }
    }
}

/// What a scripted step does when its instant fires.
#[derive(Clone, Copy, Debug)]
pub enum Fault {
    /// Crash the `count` highest-numbered replicas of `shard`'s group
    /// (count 2 of 3 = quorum loss; the lowest replica stays alive so
    /// the group is recoverable by log replay and keeps a leader view).
    Kill { shard: u32, count: usize },
    /// Restart the `count` highest-numbered replicas of `shard`'s group
    /// the durable way: each is torn down to its WAL directory —
    /// memory and modeled acceptor storage both die — and rebuilt from
    /// disk alone, mid-protocol.  Requires a [`store_durable`] store.
    Restart { shard: u32, count: usize },
    /// The coordinating front-end dies right here: the commit call
    /// returns an error with its gates released and any intents
    /// orphaned, exactly like a crashed client machine.
    Abandon,
}

/// A deterministic fault schedule: steps fire (and are consumed) in the
/// order their instants occur during the commit.
pub type Schedule = Vec<(At, Fault)>;

/// Run `commit` against `store` under `schedule`.  Returns the commit's
/// result and the transaction id the fault hook observed (0 when the
/// commit never reached the staging hook).
pub fn run_scheduled_commit(
    store: &Arc<ReplicatedMetaStore>,
    schedule: Schedule,
    commit: &Commit,
) -> (Result<Vec<OpOutcome>>, u64) {
    let seen_txn = Arc::new(Mutex::new(0u64));
    let remaining = Arc::new(Mutex::new(schedule));
    // The hook lives inside the store; a weak ref avoids an Arc cycle.
    let weak = Arc::downgrade(store);
    let hook_txn = seen_txn.clone();
    store.set_fault_hook(Some(Arc::new(move |phase, txn| {
        *hook_txn.lock().unwrap() = txn;
        let mut rem = remaining.lock().unwrap();
        let mut action = FaultAction::Continue;
        let mut i = 0;
        while i < rem.len() {
            if rem[i].0.matches(&phase) {
                let (_, fault) = rem.remove(i);
                match fault {
                    Fault::Kill { shard, count } => {
                        if let Some(s) = weak.upgrade() {
                            let group = &s.groups()[shard as usize];
                            for r in (GROUP_REPLICAS - count)..GROUP_REPLICAS {
                                group.kill_replica(r);
                            }
                        }
                    }
                    Fault::Restart { shard, count } => {
                        if let Some(s) = weak.upgrade() {
                            let group = &s.groups()[shard as usize];
                            for r in (GROUP_REPLICAS - count)..GROUP_REPLICAS {
                                group
                                    .restart_replica(r)
                                    .expect("durable restart mid-protocol");
                            }
                        }
                    }
                    Fault::Abandon => action = FaultAction::Abandon,
                }
            } else {
                i += 1;
            }
        }
        action
    })));
    let result = store.commit(commit, true);
    store.set_fault_hook(None);
    let txn = *seen_txn.lock().unwrap();
    (result, txn)
}

/// Rejoin every crashed replica of every group by deterministic log
/// replay (best-effort, like the deployment's recovery sweep), then
/// resolve any orphaned intents the replay brought back.
pub fn heal_all(store: &ReplicatedMetaStore) {
    for idx in 0..GROUP_REPLICAS {
        let _ = store.recover_replica(idx);
    }
    store.resolve_orphans();
}

/// The all-or-nothing agreement assertion: after healing, every
/// participant must settle to the coordinator's decision record —
/// `Some(true)` means every participant applied, anything else means no
/// participant applied — with no pending intents and converged
/// replicas.  Returns the decision for outcome-specific assertions.
pub fn assert_all_or_nothing(
    store: &ReplicatedMetaStore,
    txn_id: u64,
    participants: &[u32],
) -> Option<bool> {
    store.resolve_orphans();
    assert!(
        store.pending_intents().is_empty(),
        "intents left pending after resolution: {:?}",
        store.pending_intents()
    );
    let coordinator = *participants.iter().min().expect("participants nonempty");
    let decision = store.decision_of(coordinator, txn_id);
    for &s in participants {
        let outcome = store.txn_outcome(s, txn_id);
        match decision {
            Some(true) => assert_eq!(
                outcome,
                Some(true),
                "shard {s} did not apply committed txn {txn_id}"
            ),
            Some(false) => assert_ne!(
                outcome,
                Some(true),
                "shard {s} applied txn {txn_id} against an abort decision"
            ),
            None => assert_ne!(
                outcome,
                Some(true),
                "shard {s} applied txn {txn_id} with no decision recorded"
            ),
        }
    }
    assert!(store.converged(), "live replicas diverged");
    decision
}

/// `n` keys in `space` guaranteed to live in `n` distinct shard groups.
pub fn keys_on_distinct_groups(store: &ReplicatedMetaStore, space: Space, n: usize) -> Vec<Key> {
    let mut found: Vec<(u32, Key)> = Vec::new();
    for i in 0..10_000 {
        let k = Key::new(space, format!("fs{i}"));
        let shard = store.group_of(&k).shard();
        if !found.iter().any(|(s, _)| *s == shard) {
            found.push((shard, k));
            if found.len() == n {
                break;
            }
        }
    }
    assert_eq!(found.len(), n, "store has fewer than {n} shard groups");
    found.into_iter().map(|(_, k)| k).collect()
}

/// The participant shard ids a commit over `keys` touches, ascending.
pub fn participants_of(store: &ReplicatedMetaStore, keys: &[Key]) -> Vec<u32> {
    let mut p: Vec<u32> = keys.iter().map(|k| store.group_of(k).shard()).collect();
    p.sort_unstable();
    p.dedup();
    p
}

/// A commit appending one 8-byte extent to every key's region — the
/// duplicate-apply canary: a committed run leaves every region at
/// eof 8 / version 1; any replayed apply would double both.
pub fn append_commit(keys: &[Key]) -> Commit {
    Commit {
        reads: vec![],
        ops: keys
            .iter()
            .map(|k| MetaOp::RegionAppendEof {
                key: k.clone(),
                data: SliceData::Stored(vec![SlicePtr {
                    server: 1,
                    backing: 0,
                    offset: 0,
                    len: 8,
                }]),
                len: 8,
                cap: 1 << 20,
            })
            .collect(),
    }
}

/// Assert the exactly-once outcome of an [`append_commit`] after its
/// transaction resolved: committed ⇒ every region is at eof 8, version
/// 1 (applied once, never twice); aborted ⇒ every key is untouched.
pub fn assert_append_exactly_once(
    store: &ReplicatedMetaStore,
    keys: &[Key],
    committed: bool,
) {
    for k in keys {
        let got = store.get(k, true).unwrap();
        if committed {
            let (v, ver) = got.expect("committed append missing");
            assert_eq!(v.as_region().unwrap().eof, 8, "applied other than once");
            assert_eq!(ver, 1, "version bumped more than once");
        } else {
            assert!(got.is_none(), "aborted append left state behind at {k:?}");
        }
    }
}

/// Derive a random-but-reproducible schedule for one commit over
/// `participants`: at each protocol instant, maybe crash a random
/// participant's replicas (1 = follower loss, 2 = quorum loss) or kill
/// the coordinating front-end (after which nothing later can fire).
pub fn random_schedule(rng: &mut Rng, participants: &[u32]) -> Schedule {
    let mut points: Vec<At> = vec![At::Staged];
    points.extend(participants.iter().map(|&p| At::Prepared(p)));
    points.push(At::AllPrepared);
    points.push(At::Decided);
    points.extend(participants.iter().map(|&p| At::Applied(p)));
    let mut steps = Schedule::new();
    for at in points {
        match rng.next_below(6) {
            0 => {
                let victim = participants[rng.next_below(participants.len() as u64) as usize];
                let count = 1 + rng.next_below(2) as usize;
                steps.push((at, Fault::Kill { shard: victim, count }));
            }
            1 => {
                steps.push((at, Fault::Abandon));
                break; // the dead front-end reaches no later instant
            }
            _ => {}
        }
    }
    steps
}

/// The durable counterpart of [`random_schedule`]: instead of crashing
/// replicas dead, each firing tears 1-2 of a random participant's
/// replicas down to their WAL directories and rebuilds them from disk
/// mid-protocol (or abandons the front-end).  Restart density is
/// doubled relative to `random_schedule`'s kills because a restart is
/// self-healing — the schedule can batter every instant and the commit
/// must still resolve.  Requires a [`store_durable`] store.
pub fn random_restart_schedule(rng: &mut Rng, participants: &[u32]) -> Schedule {
    let mut points: Vec<At> = vec![At::Staged];
    points.extend(participants.iter().map(|&p| At::Prepared(p)));
    points.push(At::AllPrepared);
    points.push(At::Decided);
    points.extend(participants.iter().map(|&p| At::Applied(p)));
    let mut steps = Schedule::new();
    for at in points {
        match rng.next_below(6) {
            0 | 1 => {
                let victim = participants[rng.next_below(participants.len() as u64) as usize];
                let count = 1 + rng.next_below(2) as usize;
                steps.push((at, Fault::Restart { shard: victim, count }));
            }
            2 => {
                steps.push((at, Fault::Abandon));
                break; // the dead front-end reaches no later instant
            }
            _ => {}
        }
    }
    steps
}
