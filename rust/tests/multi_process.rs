//! Multi-process crash-recovery: real `wtf-cluster meta` OS processes
//! under the frontend's 2PC, SIGKILLed mid-protocol.
//!
//! The in-process suites (`chaos.rs`, `fault_injection.rs`) crash
//! replicas by flipping an atomic; here the replica is a separate
//! process holding a real WAL on disk, and the "crash" is `SIGKILL` —
//! nothing flushes, sockets die mid-stream, and the only surviving
//! state is what `WalSync::Always` forced to media before the ack.
//! Recovery is a genuine process respawn over the same WAL directory.
//!
//! The invariants asserted are exactly PR 5/PR 7's, now across process
//! boundaries: after the survivors respawn and the orphan sweep runs,
//! every participant settles to the coordinator group's decision record
//! (presumed abort once the coordinator CLAIM expires with no
//! decision), no intent stays pending, and a committed append applied
//! exactly once (eof 8 / version 1 — never doubled by WAL replay).
//!
//! `WTF_TEST_SEED` (CI matrix: 1, 7, 1234) seeds which protocol
//! instant the kill fires at, which replica processes die, and whether
//! the coordinating frontend abandons the commit — failures print the
//! seed for replay.

mod support;

use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};
use support::At;
use wtf::coordinator::lease::LeaseClock;
use wtf::deploy::{frontend_store, DeployConfig};
use wtf::meta::{FaultAction, ReplicatedMetaStore};
use wtf::net::{Peer, SocketPeer, Transport};
use wtf::types::{Key, Space};
use wtf::util::{Rng, TempDir};

/// Lease window for the real-clock cluster: long enough that a healthy
/// commit never loses its lease mid-protocol on a loaded CI box, short
/// enough that waiting out a coordinator claim (2 leases + skew) stays
/// test-sized.
const LEASE_MS: u64 = 400;
const SKEW_MS: u64 = 50;
const SHARDS: u32 = 2;

/// One `wtf-cluster meta` child process: replica `replica` of every
/// shard, WAL under the shared root, bound to an ephemeral port
/// announced through a ready file.
struct MetaChild {
    child: Child,
    addr: String,
    replica: u32,
    config: PathBuf,
    ready_dir: PathBuf,
    generation: u32,
}

impl MetaChild {
    fn spawn(config: &Path, ready_dir: &Path, replica: u32) -> MetaChild {
        let (child, addr) = Self::launch(config, ready_dir, replica, 0);
        MetaChild {
            child,
            addr,
            replica,
            config: config.to_path_buf(),
            ready_dir: ready_dir.to_path_buf(),
            generation: 0,
        }
    }

    fn launch(config: &Path, ready_dir: &Path, replica: u32, generation: u32) -> (Child, String) {
        let ready = ready_dir.join(format!("ready-{replica}-{generation}"));
        let mut child = Command::new(env!("CARGO_BIN_EXE_wtf-cluster"))
            .arg("meta")
            .arg("--config")
            .arg(config)
            .arg("--replica")
            .arg(replica.to_string())
            .arg("--bind")
            .arg("127.0.0.1:0")
            .arg("--ready-file")
            .arg(&ready)
            .stdout(Stdio::null())
            .stderr(Stdio::inherit())
            .spawn()
            .expect("spawn wtf-cluster meta");
        // Readiness handshake: the child writes its bound address to the
        // ready file (tmp + rename) once the listener is up.
        let deadline = Instant::now() + Duration::from_secs(20);
        let addr = loop {
            if let Ok(text) = std::fs::read_to_string(&ready) {
                if text.parse::<std::net::SocketAddr>().is_ok() {
                    break text;
                }
            }
            if let Ok(Some(status)) = child.try_wait() {
                panic!("meta replica {replica} exited during startup: {status}");
            }
            assert!(
                Instant::now() < deadline,
                "meta replica {replica} never announced readiness"
            );
            std::thread::sleep(Duration::from_millis(20));
        };
        (child, addr)
    }

    /// The crash under test: SIGKILL, no shutdown path of any kind.
    fn sigkill(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }

    /// Respawn over the SAME WAL directories (the config pins them by
    /// replica id); a fresh ephemeral port avoids racing the kernel for
    /// the old one.  Returns the new address.
    fn respawn(&mut self) -> String {
        self.sigkill();
        self.generation += 1;
        let (child, addr) = Self::launch(&self.config, &self.ready_dir, self.replica, self.generation);
        self.child = child;
        self.addr = addr.clone();
        addr
    }
}

impl Drop for MetaChild {
    fn drop(&mut self) {
        self.sigkill();
    }
}

/// The deployment under test: 2 shards, 3 replicas (frontend-local
/// replica 0 + two child processes), durable child WALs, real anchored
/// clocks in every process with the skew budget between them.
fn write_config(tmp: &TempDir) -> PathBuf {
    let doc = format!(
        r#"{{
            "shards": {SHARDS},
            "replicas": 3,
            "lease_ms": {LEASE_MS},
            "max_clock_skew_ms": {SKEW_MS},
            "replication": 1,
            "meta": ["127.0.0.1:1", "127.0.0.1:1"],
            "storage": ["127.0.0.1:1"],
            "wal_dir": {:?}
        }}"#,
        tmp.path().join("wal")
    );
    let path = tmp.path().join("deploy.json");
    std::fs::write(&path, doc).expect("write deploy config");
    path
}

struct Cluster {
    store: Arc<ReplicatedMetaStore>,
    children: Arc<Mutex<Vec<MetaChild>>>,
    peers: Vec<Arc<SocketPeer>>,
    _tmp: TempDir,
}

fn boot() -> Cluster {
    let tmp = TempDir::new("wtf-multi-process").expect("tempdir");
    let config = write_config(&tmp);
    let ready_dir = tmp.path().to_path_buf();
    let children = vec![
        MetaChild::spawn(&config, &ready_dir, 1),
        MetaChild::spawn(&config, &ready_dir, 2),
    ];
    let peers: Vec<Arc<SocketPeer>> = children
        .iter()
        .map(|c| Arc::new(SocketPeer::new(c.addr.clone())))
        .collect();
    let cfg = DeployConfig::load(&config).expect("reload deploy config");
    let store = frontend_store(
        &cfg,
        Arc::new(Transport::instant()),
        LeaseClock::auto_anchored(),
        peers.iter().map(|p| p.clone() as Peer).collect(),
    );
    Cluster {
        store: Arc::new(store),
        children: Arc::new(Mutex::new(children)),
        peers,
        _tmp: tmp,
    }
}

/// `n` fresh keys (unique per `tag`) on `n` distinct shard groups.
fn fresh_keys(store: &ReplicatedMetaStore, tag: &str, n: usize) -> Vec<Key> {
    let mut found: Vec<(u32, Key)> = Vec::new();
    for i in 0..10_000 {
        let k = Key::new(Space::Region, format!("{tag}-{i}"));
        let shard = store.group_of(&k).shard();
        if !found.iter().any(|(s, _)| *s == shard) {
            found.push((shard, k));
            if found.len() == n {
                return found.into_iter().map(|(_, k)| k).collect();
            }
        }
    }
    panic!("store has fewer than {n} shard groups");
}

/// Respawn the named children and re-point the frontend's socket peers
/// at their new addresses (children index 0/1 = replica 1/2).
fn respawn(cluster: &Cluster, victims: &[usize]) {
    let mut children = cluster.children.lock().unwrap();
    for &v in victims {
        let addr = children[v].respawn();
        cluster.peers[v].set_addr(addr);
    }
}

/// Drive the orphan sweep until no intent stays pending.  Claim waits
/// are real-time here (2 leases + skew ≈ 1 s), so give the sweep a
/// generous deadline before declaring the cluster stuck.
fn resolve_until_quiet(store: &Arc<ReplicatedMetaStore>) {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        store.resolve_orphans();
        if store.pending_intents().is_empty() {
            return;
        }
        assert!(
            Instant::now() < deadline,
            "intents still pending after respawn + 30s of resolution: {:?}",
            store.pending_intents()
        );
        std::thread::sleep(Duration::from_millis(50));
    }
}

#[test]
fn sigkill_mid_2pc_converges_all_or_nothing_across_processes() {
    let cluster = boot();
    let store = &cluster.store;

    // Baseline: with both replica processes up, a cross-shard commit
    // round-trips the full socket plane (claim, prepares, decision,
    // phase 2 — every quorum over real TCP).
    let keys = fresh_keys(store, "baseline", 2);
    let participants = support::participants_of(store, &keys);
    assert_eq!(participants.len(), 2, "keys must straddle both shards");
    store
        .commit(&support::append_commit(&keys), true)
        .expect("healthy multi-process commit");
    support::assert_append_exactly_once(store, &keys, true);

    // Seeded kill cases: each picks a protocol instant, a victim set,
    // and whether the coordinating frontend abandons the commit.
    let seed = support::base_seed();
    for case in 0..3u64 {
        let mut rng = Rng::new(seed.wrapping_mul(0x9E37_79B9).wrapping_add(case + 1));
        let keys = fresh_keys(store, &format!("case{seed}-{case}"), 2);
        let participants = support::participants_of(store, &keys);
        let at = match rng.next_below(3) {
            0 => At::Prepared(participants[rng.next_below(2) as usize]),
            1 => At::AllPrepared,
            _ => At::Decided,
        };
        let victims: Vec<usize> = match rng.next_below(3) {
            0 => vec![0],
            1 => vec![1],
            _ => vec![0, 1],
        };
        // Killing both children takes the quorum with it; the frontend
        // must then abandon (a real client machine would time out and
        // die with it).  With one survivor the commit may press on.
        let abandon = victims.len() == 2 || rng.next_below(2) == 0;

        let hook_children = cluster.children.clone();
        let hook_victims = victims.clone();
        let fired = Arc::new(Mutex::new(false));
        let hook_fired = fired.clone();
        let seen_txn = Arc::new(Mutex::new(0u64));
        let hook_txn = seen_txn.clone();
        store.set_fault_hook(Some(Arc::new(move |phase, txn| {
            *hook_txn.lock().unwrap() = txn;
            let mut fired = hook_fired.lock().unwrap();
            if !*fired && at.matches(&phase) {
                *fired = true;
                let mut children = hook_children.lock().unwrap();
                for &v in &hook_victims {
                    children[v].sigkill();
                }
                if abandon {
                    return FaultAction::Abandon;
                }
            }
            FaultAction::Continue
        })));
        // A commit can fail BEFORE the scripted instant for boring
        // reasons — the previous case's respawned replicas hold off
        // lease grants for one window, so the first election after a
        // respawn may transiently find no quorum.  Retry until the
        // fault actually fires (the kill itself ends the loop).
        let mut result = store.commit(&support::append_commit(&keys), true);
        let warmup = Instant::now() + Duration::from_secs(10);
        while result.is_err() && !*fired.lock().unwrap() && Instant::now() < warmup {
            std::thread::sleep(Duration::from_millis(50));
            result = store.commit(&support::append_commit(&keys), true);
        }
        store.set_fault_hook(None);
        let txn = *seen_txn.lock().unwrap();
        assert!(
            *fired.lock().unwrap(),
            "seed {seed} case {case}: instant {at:?} never fired"
        );
        assert!(txn != 0, "seed {seed} case {case}: no transaction observed");

        // Recovery: respawn every victim over its WAL, re-point the
        // peers, and sweep orphans until the protocol is quiet.
        respawn(&cluster, &victims);
        resolve_until_quiet(store);

        let decision = support::assert_all_or_nothing(store, txn, &participants);
        support::assert_append_exactly_once(store, &keys, decision == Some(true));
        // A commit the frontend saw succeed must never settle as abort.
        if result.is_ok() {
            assert_eq!(
                decision,
                Some(true),
                "seed {seed} case {case}: acked commit settled as abort"
            );
        }
    }
}

/// A replica process that dies OUTSIDE any commit and respawns from its
/// WAL must rejoin the quorum transparently: the next commit simply
/// succeeds through the re-pointed peer.
#[test]
fn respawned_replica_rejoins_the_write_quorum() {
    let cluster = boot();
    let store = &cluster.store;
    let keys = fresh_keys(store, "rejoin", 2);
    store
        .commit(&support::append_commit(&keys), true)
        .expect("commit before the restart");

    respawn(&cluster, &[0]);

    let keys2 = fresh_keys(store, "rejoin2", 2);
    store
        .commit(&support::append_commit(&keys2), true)
        .expect("commit after the restart");
    support::assert_append_exactly_once(store, &keys2, true);
    assert!(store.pending_intents().is_empty());
}
