//! Concurrency tests for the §2.5 commutativity claims under the
//! transport's parallel replica fan-out: concurrent appends commute,
//! concurrent explicit-offset writes to disjoint ranges never lose
//! updates, and the file length is the monotone max of every writer's
//! end — with replication > 1 so every operation actually scatters.

use std::sync::Arc;
use wtf::cluster::Cluster;
use wtf::config::Config;
use wtf::net::LinkModel;

fn cluster_r3() -> Cluster {
    let mut cfg = Config::test(); // 4 KB regions, 4 servers
    cfg.replication = 3;
    Cluster::builder().config(cfg).build().unwrap()
}

#[test]
fn disjoint_concurrent_write_at_loses_nothing() {
    let cl = Arc::new(cluster_r3());
    let c = cl.client();
    let fd = c.create("/stripes").unwrap();
    let inode = fd.inode();

    // 8 writers x 16 disjoint 128-byte stripes each, interleaved across
    // region boundaries (stripe w*16+k at offset (k*8 + w) * 128).
    let threads: Vec<_> = (0..8u64)
        .map(|w| {
            let cl = cl.clone();
            std::thread::spawn(move || {
                let c = cl.client();
                for k in 0..16u64 {
                    let stripe = k * 8 + w;
                    let payload = vec![b'A' + w as u8; 128];
                    c.write_at(inode, stripe * 128, &payload).unwrap();
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }

    let len = c.len(&fd).unwrap();
    assert_eq!(len, 128 * 128, "every stripe's end must be published");
    let data = c.read_at(&fd, 0, len).unwrap();
    for (i, stripe) in data.chunks(128).enumerate() {
        let expect = b'A' + (i % 8) as u8;
        assert!(
            stripe.iter().all(|&b| b == expect),
            "stripe {i} corrupted: got {} want {}",
            stripe[0],
            expect
        );
    }
}

#[test]
fn concurrent_appends_commute_with_parallel_fanout() {
    let cl = Arc::new(cluster_r3());
    let c = cl.client();
    c.create("/log").unwrap();

    // Records big enough that several appends cross the 4 KB region
    // boundary and take the §2.5 validated-EOF fallback.
    let threads: Vec<_> = (0..6u64)
        .map(|w| {
            let cl = cl.clone();
            std::thread::spawn(move || {
                let c = cl.client();
                let fd = c.open("/log").unwrap();
                for _ in 0..12 {
                    c.append_bytes(&fd, &[b'a' + w as u8; 96]).unwrap();
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }

    let fd = c.open("/log").unwrap();
    let len = c.len(&fd).unwrap();
    assert_eq!(len, 6 * 12 * 96, "no append may be lost");
    let data = c.read_at(&fd, 0, len).unwrap();
    let mut counts = [0u32; 6];
    for rec in data.chunks(96) {
        assert!(rec.iter().all(|&b| b == rec[0]), "torn record");
        counts[(rec[0] - b'a') as usize] += 1;
    }
    assert!(counts.iter().all(|&n| n == 12), "{counts:?}");
}

#[test]
fn length_is_monotone_max_under_racing_extenders() {
    let cl = Arc::new(cluster_r3());
    let c = cl.client();
    let fd = c.create("/sparse").unwrap();
    let inode = fd.inode();

    // Each writer extends the file to its own (disjoint) high-water
    // mark; the final length must be the maximum end, regardless of the
    // interleaving of the blind InodeSetLenMax commits.
    let threads: Vec<_> = (1..=8u64)
        .map(|w| {
            let cl = cl.clone();
            std::thread::spawn(move || {
                let c = cl.client();
                c.write_at(inode, w * 1000, &[w as u8; 100]).unwrap();
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }

    assert_eq!(c.len(&fd).unwrap(), 8 * 1000 + 100);
    // Spot-check the highest writer's bytes and a hole.
    assert_eq!(c.read_at(&fd, 8000, 100).unwrap(), vec![8u8; 100]);
    assert_eq!(c.read_at(&fd, 500, 100).unwrap(), vec![0u8; 100]);
}

#[test]
fn replicated_fanout_matches_serial_transport_results() {
    // The same workload through a parallel transport and an inline
    // (workers == 0) transport must publish identical bytes — the
    // scatter changes latency, never semantics.
    let mut serial_cfg = Config::test();
    serial_cfg.replication = 3;
    serial_cfg.transport_workers = 0;
    let serial = Cluster::builder()
        .config(serial_cfg)
        .link(LinkModel::instant())
        .build()
        .unwrap();
    let parallel = cluster_r3();

    for cl in [&serial, &parallel] {
        let c = cl.client();
        let mut fd = c.create("/w").unwrap();
        c.write(&mut fd, &vec![1u8; 10_000]).unwrap();
        c.write_at(fd.inode(), 5_000, &vec![2u8; 2_500]).unwrap();
    }
    let a = {
        let c = serial.client();
        let fd = c.open("/w").unwrap();
        c.read_at(&fd, 0, 10_000).unwrap()
    };
    let b = {
        let c = parallel.client();
        let fd = c.open("/w").unwrap();
        c.read_at(&fd, 0, 10_000).unwrap()
    };
    assert_eq!(a, b);
    assert_eq!(&a[..5_000], &vec![1u8; 5_000][..]);
    assert_eq!(&a[5_000..7_500], &vec![2u8; 2_500][..]);
}

#[test]
fn cached_reader_storm_never_observes_stale_length_or_torn_records() {
    // Cache-coherence storm (mirrors the appends test above, with the
    // whole hot read path ON): writers append fixed-size records while
    // readers stream the file through their private caches.  The
    // contract under test: a reader's length view is always a length
    // the file actually had (monotone, record-aligned — never "stale"
    // in the sense of torn or retrograde), and every record it returns
    // is intact.  After the storm, a fresh client sees every append
    // exactly once.
    let mut cfg = Config::fast_read_test();
    cfg.replication = 2;
    let cl = Arc::new(Cluster::builder().config(cfg).build().unwrap());
    let c = cl.client();
    c.create("/storm").unwrap();
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));

    const REC: u64 = 16; // divides the 4 KiB region: appends never tear
    let writers: Vec<_> = (0..4u64)
        .map(|w| {
            let cl = cl.clone();
            std::thread::spawn(move || {
                let c = cl.client();
                let fd = c.open("/storm").unwrap();
                for _ in 0..24 {
                    c.append_bytes(&fd, &[b'a' + w as u8; REC as usize]).unwrap();
                }
            })
        })
        .collect();
    let readers: Vec<_> = (0..3u64)
        .map(|r| {
            let cl = cl.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let c = cl.client();
                let fd = c.open("/storm").unwrap();
                let marker = b'x' + r as u8;
                let mut prev_len = 0u64;
                let mut observations = 0u64;
                let mut appended = 0u64;
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    let len = c.len(&fd).unwrap();
                    assert_eq!(len % REC, 0, "stale/torn length {len}");
                    assert!(len >= prev_len, "length went backwards: {prev_len} -> {len}");
                    prev_len = len;
                    let data = c.read_at(&fd, 0, len).unwrap();
                    // The cached view may lag the writers (allowed), but
                    // whatever it returns must be record-intact.
                    assert!(data.len() as u64 % REC == 0, "torn read of {} B", data.len());
                    for rec in data.chunks(REC as usize) {
                        assert!(
                            rec.iter().all(|&b| b == rec[0]),
                            "torn record through the cache: {rec:?}"
                        );
                    }
                    // Every 8th pass, append a record of our own: the
                    // commit invalidates our cache, so the next len()
                    // MUST include it (read-your-writes through the
                    // cache, mid-storm).
                    if observations % 8 == 0 {
                        let at = c.append_bytes(&fd, &[marker; REC as usize]).unwrap();
                        appended += 1;
                        let fresh = c.len(&fd).unwrap();
                        assert!(
                            fresh >= at + REC,
                            "own append at {at} invisible: len {fresh}"
                        );
                        prev_len = prev_len.max(fresh);
                    }
                    observations += 1;
                }
                (observations, appended)
            })
        })
        .collect();
    for w in writers {
        w.join().unwrap();
    }
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let mut reader_appends = [0u64; 3];
    for (r, h) in readers.into_iter().enumerate() {
        let (observations, appended) = h.join().unwrap();
        assert!(observations > 0, "reader made no observations");
        reader_appends[r] = appended;
    }

    // A fresh client (cold cache) sees the exact final state.
    let c = cl.client();
    let fd = c.open("/storm").unwrap();
    let len = c.len(&fd).unwrap();
    let total_appends = 4 * 24 + reader_appends.iter().sum::<u64>();
    assert_eq!(len, total_appends * REC);
    let data = c.read_at(&fd, 0, len).unwrap();
    let mut counts = std::collections::HashMap::new();
    for rec in data.chunks(REC as usize) {
        assert!(rec.iter().all(|&b| b == rec[0]), "torn record");
        *counts.entry(rec[0]).or_insert(0u64) += 1;
    }
    for w in 0..4u8 {
        assert_eq!(counts.get(&(b'a' + w)).copied().unwrap_or(0), 24);
    }
    for r in 0..3usize {
        assert_eq!(
            counts.get(&(b'x' + r as u8)).copied().unwrap_or(0),
            reader_appends[r],
            "reader {r} appends lost or duplicated"
        );
    }
}

#[test]
fn cached_reader_storm_with_disjoint_overwrites_is_never_torn() {
    // The paste/overwrite flavor: writers overwrite their own disjoint
    // stripes in place while cached readers stream.  Every stripe a
    // reader returns must be all-one-writer's-byte or still-zero —
    // never a mix (a torn paste).
    let mut cfg = Config::fast_read_test();
    cfg.replication = 2;
    let cl = Arc::new(Cluster::builder().config(cfg).build().unwrap());
    let c = cl.client();
    let fd = c.create("/stripes").unwrap();
    let inode = fd.inode();
    const STRIPE: usize = 128;
    const STRIPES: u64 = 32;
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));

    let writers: Vec<_> = (0..4u64)
        .map(|w| {
            let cl = cl.clone();
            std::thread::spawn(move || {
                let c = cl.client();
                for round in 0..6u64 {
                    for k in 0..(STRIPES / 4) {
                        let stripe = k * 4 + w;
                        let fill = b'A' + ((w + round) % 8) as u8;
                        c.write_at(inode, stripe * STRIPE as u64, &[fill; STRIPE])
                            .unwrap();
                    }
                }
            })
        })
        .collect();
    // One warm reader (a single client whose cache serves the storm)
    // and one cold reader (a fresh client — and cache — every pass).
    let readers: Vec<_> = [true, false]
        .into_iter()
        .map(|warm| {
            let cl = cl.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let warm_client = cl.client();
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    let cold_client;
                    let c = if warm {
                        &warm_client
                    } else {
                        cold_client = cl.client();
                        &cold_client
                    };
                    let fd = c.open("/stripes").unwrap();
                    let len = c.len(&fd).unwrap();
                    let data = c.read_at(&fd, 0, len).unwrap();
                    for (i, stripe) in data.chunks(STRIPE).enumerate() {
                        assert!(
                            stripe.iter().all(|&b| b == stripe[0]),
                            "torn paste in stripe {i}: {} vs {}",
                            stripe[0],
                            stripe[STRIPE - 1]
                        );
                    }
                }
            })
        })
        .collect();
    for w in writers {
        w.join().unwrap();
    }
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    for r in readers {
        r.join().unwrap();
    }
    // Final state: every stripe intact and written.
    let c = cl.client();
    let fd = c.open("/stripes").unwrap();
    let data = c.read_at(&fd, 0, STRIPES * STRIPE as u64).unwrap();
    for (i, stripe) in data.chunks(STRIPE).enumerate() {
        assert!(stripe[0] != 0, "stripe {i} never written");
        assert!(stripe.iter().all(|&b| b == stripe[0]), "stripe {i} torn");
    }
}

#[test]
fn replication_three_write_hides_wire_time() {
    // The acceptance check at test scale: under a measurable link, a
    // replication-3 write_at must land well under 3x the replication-1
    // cost, because all three uploads scatter concurrently.
    let link = LinkModel {
        half_rtt: std::time::Duration::from_millis(4),
        bandwidth: None,
    };
    let time_write = |replication: u8| {
        let mut cfg = Config::test();
        cfg.replication = replication;
        let cl = Cluster::builder().config(cfg).link(link).build().unwrap();
        let c = cl.client();
        let fd = c.create("/t").unwrap();
        c.write_at(fd.inode(), 0, &[0u8; 64]).unwrap(); // warm
        let t0 = std::time::Instant::now();
        for _ in 0..6 {
            c.write_at(fd.inode(), 0, &[1u8; 64]).unwrap();
        }
        t0.elapsed()
    };
    let r1 = time_write(1);
    let r3 = time_write(3);
    let ratio = r3.as_secs_f64() / r1.as_secs_f64().max(1e-9);
    // Parallel fan-out lands near 1.0x; the serial pre-transport path
    // was ~3.0x.  The 2.2x bound keeps the test meaningful while
    // leaving slack for loaded CI machines.
    assert!(
        ratio < 2.2,
        "replication-3 write cost {ratio:.2}x replication-1 (serial would be ~3x; r1={r1:?} r3={r3:?})"
    );
}
