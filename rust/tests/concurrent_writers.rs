//! Concurrency tests for the §2.5 commutativity claims under the
//! transport's parallel replica fan-out: concurrent appends commute,
//! concurrent explicit-offset writes to disjoint ranges never lose
//! updates, and the file length is the monotone max of every writer's
//! end — with replication > 1 so every operation actually scatters.
//! Plus the reader-isolation storms: gate-free `readdir`/`get` readers
//! hammering the metadata plane while mixed create+unlink transactions
//! commit across shard groups must never observe an intermediate state
//! (a namespace root resolving to a referent the same transaction
//! removed or has not yet published).

mod support;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use wtf::cluster::Cluster;
use wtf::config::Config;
use wtf::coordinator::lease::LeaseClock;
use wtf::meta::{MetaOp, MetaTxn, ReplicatedMetaStore};
use wtf::net::{LinkModel, Transport};
use wtf::types::{Inode, Key, Value};

fn cluster_r3() -> Cluster {
    let mut cfg = Config::test(); // 4 KB regions, 4 servers
    cfg.replication = 3;
    Cluster::builder().config(cfg).build().unwrap()
}

#[test]
fn disjoint_concurrent_write_at_loses_nothing() {
    let cl = Arc::new(cluster_r3());
    let c = cl.client();
    let fd = c.create("/stripes").unwrap();
    let inode = fd.inode();

    // 8 writers x 16 disjoint 128-byte stripes each, interleaved across
    // region boundaries (stripe w*16+k at offset (k*8 + w) * 128).
    let threads: Vec<_> = (0..8u64)
        .map(|w| {
            let cl = cl.clone();
            std::thread::spawn(move || {
                let c = cl.client();
                for k in 0..16u64 {
                    let stripe = k * 8 + w;
                    let payload = vec![b'A' + w as u8; 128];
                    c.write_at(inode, stripe * 128, &payload).unwrap();
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }

    let len = c.len(&fd).unwrap();
    assert_eq!(len, 128 * 128, "every stripe's end must be published");
    let data = c.read_at(&fd, 0, len).unwrap();
    for (i, stripe) in data.chunks(128).enumerate() {
        let expect = b'A' + (i % 8) as u8;
        assert!(
            stripe.iter().all(|&b| b == expect),
            "stripe {i} corrupted: got {} want {}",
            stripe[0],
            expect
        );
    }
}

#[test]
fn concurrent_appends_commute_with_parallel_fanout() {
    let cl = Arc::new(cluster_r3());
    let c = cl.client();
    c.create("/log").unwrap();

    // Records big enough that several appends cross the 4 KB region
    // boundary and take the §2.5 validated-EOF fallback.
    let threads: Vec<_> = (0..6u64)
        .map(|w| {
            let cl = cl.clone();
            std::thread::spawn(move || {
                let c = cl.client();
                let fd = c.open("/log").unwrap();
                for _ in 0..12 {
                    c.append_bytes(&fd, &[b'a' + w as u8; 96]).unwrap();
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }

    let fd = c.open("/log").unwrap();
    let len = c.len(&fd).unwrap();
    assert_eq!(len, 6 * 12 * 96, "no append may be lost");
    let data = c.read_at(&fd, 0, len).unwrap();
    let mut counts = [0u32; 6];
    for rec in data.chunks(96) {
        assert!(rec.iter().all(|&b| b == rec[0]), "torn record");
        counts[(rec[0] - b'a') as usize] += 1;
    }
    assert!(counts.iter().all(|&n| n == 12), "{counts:?}");
}

#[test]
fn length_is_monotone_max_under_racing_extenders() {
    let cl = Arc::new(cluster_r3());
    let c = cl.client();
    let fd = c.create("/sparse").unwrap();
    let inode = fd.inode();

    // Each writer extends the file to its own (disjoint) high-water
    // mark; the final length must be the maximum end, regardless of the
    // interleaving of the blind InodeSetLenMax commits.
    let threads: Vec<_> = (1..=8u64)
        .map(|w| {
            let cl = cl.clone();
            std::thread::spawn(move || {
                let c = cl.client();
                c.write_at(inode, w * 1000, &[w as u8; 100]).unwrap();
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }

    assert_eq!(c.len(&fd).unwrap(), 8 * 1000 + 100);
    // Spot-check the highest writer's bytes and a hole.
    assert_eq!(c.read_at(&fd, 8000, 100).unwrap(), vec![8u8; 100]);
    assert_eq!(c.read_at(&fd, 500, 100).unwrap(), vec![0u8; 100]);
}

#[test]
fn replicated_fanout_matches_serial_transport_results() {
    // The same workload through a parallel transport and an inline
    // (workers == 0) transport must publish identical bytes — the
    // scatter changes latency, never semantics.
    let mut serial_cfg = Config::test();
    serial_cfg.replication = 3;
    serial_cfg.transport_workers = 0;
    let serial = Cluster::builder()
        .config(serial_cfg)
        .link(LinkModel::instant())
        .build()
        .unwrap();
    let parallel = cluster_r3();

    for cl in [&serial, &parallel] {
        let c = cl.client();
        let mut fd = c.create("/w").unwrap();
        c.write(&mut fd, &vec![1u8; 10_000]).unwrap();
        c.write_at(fd.inode(), 5_000, &vec![2u8; 2_500]).unwrap();
    }
    let a = {
        let c = serial.client();
        let fd = c.open("/w").unwrap();
        c.read_at(&fd, 0, 10_000).unwrap()
    };
    let b = {
        let c = parallel.client();
        let fd = c.open("/w").unwrap();
        c.read_at(&fd, 0, 10_000).unwrap()
    };
    assert_eq!(a, b);
    assert_eq!(&a[..5_000], &vec![1u8; 5_000][..]);
    assert_eq!(&a[5_000..7_500], &vec![2u8; 2_500][..]);
}

#[test]
fn cached_reader_storm_never_observes_stale_length_or_torn_records() {
    // Cache-coherence storm (mirrors the appends test above, with the
    // whole hot read path ON): writers append fixed-size records while
    // readers stream the file through their private caches.  The
    // contract under test: a reader's length view is always a length
    // the file actually had (monotone, record-aligned — never "stale"
    // in the sense of torn or retrograde), and every record it returns
    // is intact.  After the storm, a fresh client sees every append
    // exactly once.
    let mut cfg = Config::fast_read_test();
    cfg.replication = 2;
    let cl = Arc::new(Cluster::builder().config(cfg).build().unwrap());
    let c = cl.client();
    c.create("/storm").unwrap();
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));

    const REC: u64 = 16; // divides the 4 KiB region: appends never tear
    let writers: Vec<_> = (0..4u64)
        .map(|w| {
            let cl = cl.clone();
            std::thread::spawn(move || {
                let c = cl.client();
                let fd = c.open("/storm").unwrap();
                for _ in 0..24 {
                    c.append_bytes(&fd, &[b'a' + w as u8; REC as usize]).unwrap();
                }
            })
        })
        .collect();
    let readers: Vec<_> = (0..3u64)
        .map(|r| {
            let cl = cl.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let c = cl.client();
                let fd = c.open("/storm").unwrap();
                let marker = b'x' + r as u8;
                let mut prev_len = 0u64;
                let mut observations = 0u64;
                let mut appended = 0u64;
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    let len = c.len(&fd).unwrap();
                    assert_eq!(len % REC, 0, "stale/torn length {len}");
                    assert!(len >= prev_len, "length went backwards: {prev_len} -> {len}");
                    prev_len = len;
                    let data = c.read_at(&fd, 0, len).unwrap();
                    // The cached view may lag the writers (allowed), but
                    // whatever it returns must be record-intact.
                    assert!(data.len() as u64 % REC == 0, "torn read of {} B", data.len());
                    for rec in data.chunks(REC as usize) {
                        assert!(
                            rec.iter().all(|&b| b == rec[0]),
                            "torn record through the cache: {rec:?}"
                        );
                    }
                    // Every 8th pass, append a record of our own: the
                    // commit invalidates our cache, so the next len()
                    // MUST include it (read-your-writes through the
                    // cache, mid-storm).
                    if observations % 8 == 0 {
                        let at = c.append_bytes(&fd, &[marker; REC as usize]).unwrap();
                        appended += 1;
                        let fresh = c.len(&fd).unwrap();
                        assert!(
                            fresh >= at + REC,
                            "own append at {at} invisible: len {fresh}"
                        );
                        prev_len = prev_len.max(fresh);
                    }
                    observations += 1;
                }
                (observations, appended)
            })
        })
        .collect();
    for w in writers {
        w.join().unwrap();
    }
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let mut reader_appends = [0u64; 3];
    for (r, h) in readers.into_iter().enumerate() {
        let (observations, appended) = h.join().unwrap();
        assert!(observations > 0, "reader made no observations");
        reader_appends[r] = appended;
    }

    // A fresh client (cold cache) sees the exact final state.
    let c = cl.client();
    let fd = c.open("/storm").unwrap();
    let len = c.len(&fd).unwrap();
    let total_appends = 4 * 24 + reader_appends.iter().sum::<u64>();
    assert_eq!(len, total_appends * REC);
    let data = c.read_at(&fd, 0, len).unwrap();
    let mut counts = std::collections::HashMap::new();
    for rec in data.chunks(REC as usize) {
        assert!(rec.iter().all(|&b| b == rec[0]), "torn record");
        *counts.entry(rec[0]).or_insert(0u64) += 1;
    }
    for w in 0..4u8 {
        assert_eq!(counts.get(&(b'a' + w)).copied().unwrap_or(0), 24);
    }
    for r in 0..3usize {
        assert_eq!(
            counts.get(&(b'x' + r as u8)).copied().unwrap_or(0),
            reader_appends[r],
            "reader {r} appends lost or duplicated"
        );
    }
}

#[test]
fn cached_reader_storm_with_disjoint_overwrites_is_never_torn() {
    // The paste/overwrite flavor: writers overwrite their own disjoint
    // stripes in place while cached readers stream.  Every stripe a
    // reader returns must be all-one-writer's-byte or still-zero —
    // never a mix (a torn paste).
    let mut cfg = Config::fast_read_test();
    cfg.replication = 2;
    let cl = Arc::new(Cluster::builder().config(cfg).build().unwrap());
    let c = cl.client();
    let fd = c.create("/stripes").unwrap();
    let inode = fd.inode();
    const STRIPE: usize = 128;
    const STRIPES: u64 = 32;
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));

    let writers: Vec<_> = (0..4u64)
        .map(|w| {
            let cl = cl.clone();
            std::thread::spawn(move || {
                let c = cl.client();
                for round in 0..6u64 {
                    for k in 0..(STRIPES / 4) {
                        let stripe = k * 4 + w;
                        let fill = b'A' + ((w + round) % 8) as u8;
                        c.write_at(inode, stripe * STRIPE as u64, &[fill; STRIPE])
                            .unwrap();
                    }
                }
            })
        })
        .collect();
    // One warm reader (a single client whose cache serves the storm)
    // and one cold reader (a fresh client — and cache — every pass).
    let readers: Vec<_> = [true, false]
        .into_iter()
        .map(|warm| {
            let cl = cl.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let warm_client = cl.client();
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    let cold_client;
                    let c = if warm {
                        &warm_client
                    } else {
                        cold_client = cl.client();
                        &cold_client
                    };
                    let fd = c.open("/stripes").unwrap();
                    let len = c.len(&fd).unwrap();
                    let data = c.read_at(&fd, 0, len).unwrap();
                    for (i, stripe) in data.chunks(STRIPE).enumerate() {
                        assert!(
                            stripe.iter().all(|&b| b == stripe[0]),
                            "torn paste in stripe {i}: {} vs {}",
                            stripe[0],
                            stripe[STRIPE - 1]
                        );
                    }
                }
            })
        })
        .collect();
    for w in writers {
        w.join().unwrap();
    }
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    for r in readers {
        r.join().unwrap();
    }
    // Final state: every stripe intact and written.
    let c = cl.client();
    let fd = c.open("/stripes").unwrap();
    let data = c.read_at(&fd, 0, STRIPES * STRIPE as u64).unwrap();
    for (i, stripe) in data.chunks(STRIPE).enumerate() {
        assert!(stripe[0] != 0, "stripe {i} never written");
        assert!(stripe.iter().all(|&b| b == stripe[0]), "stripe {i} torn");
    }
}

// ---------------------------------------------------------------------
// Reader isolation under mixed create+unlink transactions.
//
// The oracle is the one cross-key invariant sequential gate-free reads
// CAN soundly assert (single-key reads are linearizable and monotone;
// names and inode ids are never reused): if a reader resolves a
// namespace root (a path entry or a directory entry) and then finds its
// referent inode ABSENT, re-reading the root must show it gone too.
// "Root still present, referent deleted" can only be a half-applied
// transaction — the intermediate state the entry holds (direct path)
// and the intent locks (`meta_2pc`) both exist to make unobservable.
// ---------------------------------------------------------------------

fn mixed_namespace_storm(cfg: Config) {
    const WRITERS: usize = 3;
    const ROUNDS: usize = 24;
    let cl = Arc::new(Cluster::builder().config(cfg).build().unwrap());
    let c = cl.client();
    c.mkdir("/d").unwrap();
    let d_id = c.lookup("/d").unwrap();
    let done = Arc::new(AtomicBool::new(false));

    // Each writer ping-pongs one logical file through a chain of fresh
    // names: round r commits ONE metadata transaction that creates
    // /d/w{w}-{r+1} (path + inode + direntry) and unlinks /d/w{w}-{r}
    // (all three removed) — namespace inserts and removes mixed, spread
    // across shard groups by key hash.
    let writers: Vec<_> = (0..WRITERS)
        .map(|w| {
            let cl = cl.clone();
            std::thread::spawn(move || {
                let meta = cl.meta().clone();
                let seed_id = meta.alloc_inode_id();
                let mut t = MetaTxn::new(meta.clone());
                t.push(MetaOp::PathInsert {
                    key: Key::path(format!("/d/w{w}-0")),
                    inode: seed_id,
                    expect_absent: true,
                });
                t.push(MetaOp::Put {
                    key: Key::inode(seed_id),
                    value: Value::Inode(Inode::new_file(seed_id, 0o644, 1)),
                });
                t.push(MetaOp::DirInsert {
                    key: Key::dir(d_id),
                    name: format!("w{w}-0"),
                    inode: seed_id,
                    expect_absent: true,
                });
                t.commit().unwrap();
                let mut old_id = seed_id;
                for r in 0..ROUNDS {
                    let new_id = meta.alloc_inode_id();
                    let mut t = MetaTxn::new(meta.clone());
                    t.push(MetaOp::PathInsert {
                        key: Key::path(format!("/d/w{w}-{}", r + 1)),
                        inode: new_id,
                        expect_absent: true,
                    });
                    t.push(MetaOp::Put {
                        key: Key::inode(new_id),
                        value: Value::Inode(Inode::new_file(new_id, 0o644, 1)),
                    });
                    t.push(MetaOp::DirInsert {
                        key: Key::dir(d_id),
                        name: format!("w{w}-{}", r + 1),
                        inode: new_id,
                        expect_absent: true,
                    });
                    t.push(MetaOp::Delete {
                        key: Key::path(format!("/d/w{w}-{r}")),
                    });
                    t.push(MetaOp::Delete {
                        key: Key::inode(old_id),
                    });
                    t.push(MetaOp::DirRemove {
                        key: Key::dir(d_id),
                        name: format!("w{w}-{r}"),
                    });
                    t.commit().unwrap();
                    old_id = new_id;
                }
            })
        })
        .collect();

    let readers: Vec<_> = (0..2)
        .map(|_| {
            let cl = cl.clone();
            let done = done.clone();
            std::thread::spawn(move || {
                let meta = cl.meta().clone();
                let c = cl.client();
                let mut probes = 0u64;
                loop {
                    let finished = done.load(Ordering::Relaxed);
                    // readdir oracle: every listed entry must resolve,
                    // or vanish from an immediate re-list.
                    for (name, ino) in c.readdir("/d").unwrap() {
                        probes += 1;
                        if meta.get_checked(&Key::inode(ino)).unwrap().0.is_none() {
                            let still = c
                                .readdir("/d")
                                .unwrap()
                                .into_iter()
                                .any(|(n, i)| n == name && i == ino);
                            assert!(
                                !still,
                                "intermediate state: direntry {name} still lists \
                                 deleted inode {ino}"
                            );
                        }
                    }
                    // path-map oracle over the whole name universe.
                    for w in 0..WRITERS {
                        for r in 0..=ROUNDS {
                            let pkey = Key::path(format!("/d/w{w}-{r}"));
                            let id = match meta.get_checked(&pkey).unwrap().0 {
                                Some(Value::PathEntry(id)) => id,
                                _ => continue,
                            };
                            probes += 1;
                            if meta.get_checked(&Key::inode(id)).unwrap().0.is_some() {
                                continue;
                            }
                            let again = matches!(
                                meta.get_checked(&pkey).unwrap().0,
                                Some(Value::PathEntry(i2)) if i2 == id
                            );
                            assert!(
                                !again,
                                "intermediate state: path {pkey:?} still resolves \
                                 to deleted inode {id}"
                            );
                        }
                    }
                    if finished {
                        return probes;
                    }
                }
            })
        })
        .collect();

    for w in writers {
        w.join().unwrap();
    }
    done.store(true, Ordering::Relaxed);
    for r in readers {
        assert!(r.join().unwrap() > 0, "reader probed nothing");
    }

    // Final state: each writer's last name, resolving to a live inode.
    let entries = c.readdir("/d").unwrap();
    assert_eq!(entries.len(), WRITERS, "{entries:?}");
    for w in 0..WRITERS {
        assert!(c.exists(&format!("/d/w{w}-{ROUNDS}")));
    }
    let r = cl.meta().replicated_store().expect("paxos backend");
    assert!(r.pending_intents().is_empty(), "no intent outlives the storm");
    assert!(r.converged());
}

#[test]
fn mixed_create_unlink_storm_direct_path_holds_protect_readers() {
    mixed_namespace_storm(Config::replicated_test());
}

#[test]
fn mixed_create_unlink_storm_2pc_intents_protect_readers() {
    mixed_namespace_storm(Config::replicated_2pc_test());
}

#[test]
fn mixed_create_unlink_storm_production_preset_protects_readers() {
    // The deployment shape (PR 9): the same reader-isolation contract
    // with the versioned metadata cache, read coalescing, and the
    // cache-TTL-below-GC-window bound all on at test timescale.
    mixed_namespace_storm(support::production_test_config());
}

/// The unorderable shape, forced: both path keys co-located in ONE
/// group (so its entry mixes a namespace insert and a remove — no
/// proposal order can protect it) with both inode keys in ANOTHER.
/// Only the entry hold (direct path) or the intent locks (2PC) keep
/// the mid-commit state invisible; this is the regression test for the
/// pre-existing reader-isolation hole.
fn colocated_mixed_entry_storm(two_pc: bool) {
    const ROUNDS: usize = 160;
    let store = Arc::new(
        ReplicatedMetaStore::new(
            4,
            3,
            Arc::new(Transport::instant()),
            LeaseClock::auto(),
            25,
        )
        .two_pc(two_pc),
    );
    // A pool of path keys on one group...
    let p_shard = store.group_of(&Key::path("/m0")).shard();
    let paths: Vec<Key> = (0..40_000u64)
        .map(|j| Key::path(format!("/m{j}")))
        .filter(|k| store.group_of(k).shard() == p_shard)
        .take(ROUNDS + 1)
        .collect();
    assert_eq!(paths.len(), ROUNDS + 1);
    // ...and a pool of inode keys on a different group.
    let i_shard = (2u64..)
        .map(|id| store.group_of(&Key::inode(id)).shard())
        .find(|s| *s != p_shard)
        .unwrap();
    let inodes: Vec<(u64, Key)> = (2..200_000u64)
        .map(|id| (id, Key::inode(id)))
        .filter(|(_, k)| store.group_of(k).shard() == i_shard)
        .take(ROUNDS + 1)
        .collect();
    assert_eq!(inodes.len(), ROUNDS + 1);

    // Seed round 0.
    let seed = wtf::meta::Commit {
        reads: vec![],
        ops: vec![
            MetaOp::PathInsert {
                key: paths[0].clone(),
                inode: inodes[0].0,
                expect_absent: true,
            },
            MetaOp::Put {
                key: inodes[0].1.clone(),
                value: Value::Inode(Inode::new_file(inodes[0].0, 0o644, 1)),
            },
        ],
    };
    store.commit(&seed, true).unwrap();

    let done = Arc::new(AtomicBool::new(false));
    let writer = {
        let store = store.clone();
        let paths = paths.clone();
        let inodes = inodes.clone();
        std::thread::spawn(move || {
            for r in 0..ROUNDS {
                let c = wtf::meta::Commit {
                    reads: vec![],
                    ops: vec![
                        MetaOp::PathInsert {
                            key: paths[r + 1].clone(),
                            inode: inodes[r + 1].0,
                            expect_absent: true,
                        },
                        MetaOp::Put {
                            key: inodes[r + 1].1.clone(),
                            value: Value::Inode(Inode::new_file(
                                inodes[r + 1].0,
                                0o644,
                                1,
                            )),
                        },
                        MetaOp::Delete {
                            key: paths[r].clone(),
                        },
                        MetaOp::Delete {
                            key: inodes[r].1.clone(),
                        },
                    ],
                };
                store.commit(&c, true).unwrap();
            }
        })
    };
    let reader = {
        let store = store.clone();
        let paths = paths.clone();
        let done = done.clone();
        std::thread::spawn(move || {
            let mut probes = 0u64;
            loop {
                let finished = done.load(Ordering::Relaxed);
                for p in &paths {
                    let id = match store.get(p, true).unwrap() {
                        Some((Value::PathEntry(id), _)) => id,
                        _ => continue,
                    };
                    probes += 1;
                    if store.get(&Key::inode(id), true).unwrap().is_some() {
                        continue;
                    }
                    // Referent gone: with atomic visibility the root
                    // must be gone too on an immediate re-read.
                    let again = matches!(
                        store.get(p, true).unwrap(),
                        Some((Value::PathEntry(i2), _)) if i2 == id
                    );
                    assert!(
                        !again,
                        "mid-commit state visible: {p:?} still maps to \
                         deleted inode {id} (two_pc={two_pc})"
                    );
                }
                if finished {
                    return probes;
                }
            }
        })
    };
    writer.join().unwrap();
    done.store(true, Ordering::Relaxed);
    assert!(reader.join().unwrap() > 0);
    // End state: only the last (path, inode) pair survives.
    assert!(matches!(
        store.get(&paths[ROUNDS], true).unwrap(),
        Some((Value::PathEntry(_), _))
    ));
    assert_eq!(store.get(&paths[ROUNDS - 1], true).unwrap(), None);
    assert!(store.pending_intents().is_empty());
    assert!(store.converged());
}

#[test]
fn colocated_mixed_entry_direct_path_entry_hold_protects_readers() {
    colocated_mixed_entry_storm(false);
}

#[test]
fn colocated_mixed_entry_2pc_intent_locks_protect_readers() {
    colocated_mixed_entry_storm(true);
}

#[test]
fn rename_churn_is_atomic_to_other_clients() {
    // The client-level face of the same contract: rename is one mixed
    // insert+remove transaction, and another client's reads resolve
    // the file at SOME name with a live inode at every probe.
    let cl = Arc::new(
        Cluster::builder()
            .config(Config::replicated_2pc_test())
            .build()
            .unwrap(),
    );
    let c = cl.client();
    c.mkdir("/r").unwrap();
    let mut fd = c.create("/r/f-0").unwrap();
    c.write(&mut fd, b"payload").unwrap();
    let inode = fd.inode();
    const MOVES: usize = 40;
    let mover = {
        let cl = cl.clone();
        std::thread::spawn(move || {
            let c = cl.client();
            for r in 0..MOVES {
                c.rename(&format!("/r/f-{r}"), &format!("/r/f-{}", r + 1))
                    .unwrap();
            }
        })
    };
    let prober = {
        let cl = cl.clone();
        std::thread::spawn(move || {
            let meta = cl.meta().clone();
            let c = cl.client();
            for _ in 0..200 {
                // The direntry oracle: whatever name the file currently
                // lists under, its inode is live (rename never drops it).
                for (name, ino) in c.readdir("/r").unwrap() {
                    assert_eq!(ino, inode, "foreign entry {name}");
                    assert!(
                        meta.get_checked(&Key::inode(ino)).unwrap().0.is_some(),
                        "direntry {name} dangles"
                    );
                }
            }
        })
    };
    mover.join().unwrap();
    prober.join().unwrap();
    // Exactly one name remains, the data moved with it.
    let entries = c.readdir("/r").unwrap();
    assert_eq!(entries.len(), 1);
    let fd = c.open(&format!("/r/f-{MOVES}")).unwrap();
    assert_eq!(c.read_at(&fd, 0, 7).unwrap(), b"payload");
    assert!(cl.meta().replicated_store().unwrap().converged());
}

#[test]
fn replication_three_write_hides_wire_time() {
    // The acceptance check at test scale: under a measurable link, a
    // replication-3 write_at must land well under 3x the replication-1
    // cost, because all three uploads scatter concurrently.
    let link = LinkModel {
        half_rtt: std::time::Duration::from_millis(4),
        bandwidth: None,
    };
    let time_write = |replication: u8| {
        let mut cfg = Config::test();
        cfg.replication = replication;
        let cl = Cluster::builder().config(cfg).link(link).build().unwrap();
        let c = cl.client();
        let fd = c.create("/t").unwrap();
        c.write_at(fd.inode(), 0, &[0u8; 64]).unwrap(); // warm
        let t0 = std::time::Instant::now();
        for _ in 0..6 {
            c.write_at(fd.inode(), 0, &[1u8; 64]).unwrap();
        }
        t0.elapsed()
    };
    let r1 = time_write(1);
    let r3 = time_write(3);
    let ratio = r3.as_secs_f64() / r1.as_secs_f64().max(1e-9);
    // Parallel fan-out lands near 1.0x; the serial pre-transport path
    // was ~3.0x.  The 2.2x bound keeps the test meaningful while
    // leaving slack for loaded CI machines.
    assert!(
        ratio < 2.2,
        "replication-3 write cost {ratio:.2}x replication-1 (serial would be ~3x; r1={r1:?} r3={r3:?})"
    );
}

#[test]
fn cached_txn_read_conflict_storm_never_commits_stale() {
    // PR-9 conflict storm: transactional reads are served from the
    // versioned client cache, so a reader can pick up a stale pair of
    // entries — and commit-time validation must catch EVERY one of
    // them.  The writer keeps /x and /y byte-identical (one atomic
    // transaction per round); reader transactions read both through
    // warm caches and append the concatenated pair to a private output
    // file.  An aborted attempt is the machinery working; a COMMITTED
    // mismatched pair is the stale-read bug this PR exists to prevent.
    use wtf::client::SeekFrom;
    use wtf::error::Error;
    let cl = Arc::new(
        Cluster::builder()
            .config(Config::fast_read_test())
            .build()
            .unwrap(),
    );
    let setup = cl.client();
    let mut fx = setup.create("/x").unwrap();
    let mut fy = setup.create("/y").unwrap();
    setup.write(&mut fx, &[b'a'; 512]).unwrap();
    setup.write(&mut fy, &[b'a'; 512]).unwrap();

    let stop = Arc::new(AtomicBool::new(false));
    let writer = {
        let cl = cl.clone();
        std::thread::spawn(move || {
            let c = cl.client();
            for r in 0..96u32 {
                let v = b'a' + (r % 26) as u8;
                loop {
                    let mut t = c.begin();
                    let x = t.open("/x").unwrap();
                    let y = t.open("/y").unwrap();
                    t.write(x, &[v; 512]).unwrap();
                    t.write(y, &[v; 512]).unwrap();
                    match t.commit() {
                        Ok(()) => break,
                        // Divergent replay / exhausted budget: retry the
                        // whole round; x and y only ever move together.
                        Err(Error::TxnAborted { .. })
                        | Err(Error::RetriesExhausted { .. }) => continue,
                        Err(e) => panic!("writer round {r}: {e:?}"),
                    }
                }
            }
        })
    };
    let readers: Vec<_> = (0..2u32)
        .map(|ri| {
            let cl = cl.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let c = cl.client();
                let out_path = format!("/out-{ri}");
                c.create(&out_path).unwrap();
                let (mut committed, mut aborted) = (0u64, 0u64);
                while !stop.load(Ordering::Relaxed) {
                    // Warm this client's cache, then read through it
                    // inside the transaction.
                    let fd = c.open("/x").unwrap();
                    let _ = c.read_at(&fd, 0, 1).unwrap();
                    let mut t = c.begin();
                    let x = t.open("/x").unwrap();
                    let y = t.open("/y").unwrap();
                    let xs = t.read(x, 512).unwrap();
                    let ys = t.read(y, 512).unwrap();
                    let o = t.open(&out_path).unwrap();
                    t.seek(o, SeekFrom::End(0)).unwrap();
                    t.write(o, &xs).unwrap();
                    t.write(o, &ys).unwrap();
                    match t.commit() {
                        Ok(()) => {
                            committed += 1;
                            assert_eq!(
                                xs, ys,
                                "stale cached read COMMITTED (reader {ri})"
                            );
                        }
                        Err(Error::TxnAborted { .. })
                        | Err(Error::RetriesExhausted { .. }) => aborted += 1,
                        Err(e) => panic!("reader {ri}: {e:?}"),
                    }
                }
                (committed, aborted, c.metadata_cache().hits())
            })
        })
        .collect();
    writer.join().unwrap();
    stop.store(true, Ordering::Relaxed);
    let mut total_committed = 0u64;
    let mut total_hits = 0u64;
    for h in readers {
        let (committed, aborted, hits) = h.join().unwrap();
        println!("reader: {committed} committed, {aborted} caught at validation");
        total_committed += committed;
        total_hits += hits;
    }
    assert!(total_committed > 0, "no reader transaction ever committed");
    assert!(
        total_hits > 0,
        "the storm never exercised the read-through cache"
    );

    // A fresh (cold-cache) client audits every committed pair: uniform
    // bytes, halves equal — across the whole output history.
    let c = cl.client();
    for ri in 0..2u32 {
        let fd = c.open(&format!("/out-{ri}")).unwrap();
        let len = c.len(&fd).unwrap();
        assert_eq!(len % 1024, 0, "torn pair append in /out-{ri}");
        let data = c.read_at(&fd, 0, len).unwrap();
        for (i, pair) in data.chunks(1024).enumerate() {
            let (xs, ys) = pair.split_at(512);
            assert!(
                xs.iter().all(|&b| b == xs[0]) && xs == ys,
                "committed pair {i} in /out-{ri} is stale or torn"
            );
        }
    }
}
