//! Network-turbulence chaos harness (PR 8): seeded drop / delay /
//! duplicate / reorder / partition-heal schedules over the cross-group
//! 2PC machinery, plus the three partition shapes the fault model calls
//! out — a minority-side leader, a coordinator partitioned mid-2PC, and
//! lease expiry under message delay.
//!
//! Every case derives its dice from `WTF_TEST_SEED` (the CI chaos
//! matrix) and every assertion message carries the effective seed, so a
//! red run replays bit-for-bit from its printed seed.  The invariants
//! are the §3 contract under an adversarial network: safety ALWAYS
//! (all-or-nothing, exactly-once, no stale lease reads), and liveness
//! after heal (the next commit and read land within the retry budget).

mod support;

use std::sync::{Arc, Mutex};
use wtf::error::Error;
use wtf::meta::{CommitPhase, FaultAction};
use wtf::net::{CutMode, Plane, TurbulenceRule};
use wtf::types::{Key, Space};
use wtf::util::Rng;

/// Eight-byte-append canary check for one key: committed ⇒ eof 8 /
/// version 1 exactly (never doubled), absent ⇒ untouched.
fn assert_once(store: &wtf::meta::ReplicatedMetaStore, key: &Key, ctx: &str) {
    let (v, ver) = store.get(key, true).unwrap().unwrap_or_else(|| panic!("{ctx}: key missing"));
    assert_eq!(v.as_region().unwrap().eof, 8, "{ctx}: applied other than once");
    assert_eq!(ver, 1, "{ctx}: version bumped more than once");
}

// ---------------------------------------------------------------------
// Seeded turbulence over the PR-5 fault schedules.
// ---------------------------------------------------------------------

/// Background drop/dup/delay/reorder noise on the Paxos plane layered
/// UNDER a random PR-5 crash schedule: the commit may land or fail, but
/// after the network and the replicas heal, every participant must
/// settle to the decision record with no double-applies.
#[test]
fn turbulent_2pc_schedules_preserve_all_or_nothing() {
    let base = support::base_seed();
    for case in 0..6u64 {
        let seed = base.wrapping_mul(0x9E37_79B9) ^ (0xC4A0 + case);
        let mut rng = Rng::new(seed);
        let (store, chaos, _clock) = support::noisy_store_2pc(4, seed);
        chaos.add_rule(TurbulenceRule {
            plane: Some(Plane::Paxos),
            drop: 16 + rng.next_below(80) as u32,
            dup: 16 + rng.next_below(80) as u32,
            delay: rng.next_below(48) as u32,
            delay_ms: 1 + rng.next_below(4),
            reorder: 64 + rng.next_below(192) as u32,
            ..TurbulenceRule::default()
        });
        let keys = support::keys_on_distinct_groups(&store, Space::Region, 3);
        let participants = support::participants_of(&store, &keys);
        let schedule = support::random_schedule(&mut rng, &participants);
        let (_, txn) =
            support::run_scheduled_commit(&store, schedule, &support::append_commit(&keys));
        // Heal sky and ground: rules off, links whole, replicas rejoined.
        chaos.clear_rules();
        chaos.heal_all_cuts();
        support::heal_all(&store);
        let decision = support::assert_all_or_nothing(&store, txn, &participants);
        support::assert_append_exactly_once(&store, &keys, decision == Some(true));
        println!(
            "turbulent schedule ok: WTF_TEST_SEED={base} case {case} (seed {seed}, \
             {} faults injected)",
            chaos.faults_injected()
        );
    }
}

/// An asymmetric (ack-loss) cut of one follower is the canonical
/// indeterminate generator: requests land, acks vanish.  A minority cut
/// must never block commits — the other two replicas are a quorum — and
/// duplicate re-delivery of the served-but-unacked traffic must stay
/// invisible.
#[test]
fn ack_loss_on_one_follower_neither_blocks_nor_double_applies() {
    let base = support::base_seed();
    let seed = base.wrapping_mul(0x9E37_79B9) ^ 0xACC5;
    let (store, chaos, _clock) = support::noisy_store_2pc(4, seed);
    let keys = support::keys_on_distinct_groups(&store, Space::Region, 2);
    let participants = support::participants_of(&store, &keys);
    // Cut the ack path of the highest replica of every participant group.
    for &shard in &participants {
        let group = &store.groups()[shard as usize];
        let peer: wtf::net::Peer = group
            .replica(support::GROUP_REPLICAS - 1)
            .unwrap()
            .clone();
        chaos.cut(&peer, CutMode::AckLoss);
    }
    let (result, txn) =
        support::run_scheduled_commit(&store, Vec::new(), &support::append_commit(&keys));
    result.unwrap_or_else(|e| panic!("seed {seed}: quorum of 2 clean links must commit: {e:?}"));
    assert!(chaos.acks_lost() > 0, "seed {seed}: the ack-loss cut never fired");
    chaos.heal_all_cuts();
    support::heal_all(&store);
    assert_eq!(
        support::assert_all_or_nothing(&store, txn, &participants),
        Some(true),
        "seed {seed}"
    );
    support::assert_append_exactly_once(&store, &keys, true);
}

/// The reproducibility contract behind every red chaos run: the same
/// seed replays the exact same fault stream and outcome.
#[test]
fn same_seed_replays_an_identical_fault_stream() {
    let base = support::base_seed();
    let seed = base.wrapping_mul(0x9E37_79B9) ^ 0xD1CE;
    let run = |seed: u64| {
        let (store, chaos, _clock) = support::noisy_store_2pc(2, seed);
        chaos.add_rule(TurbulenceRule {
            plane: Some(Plane::Paxos),
            drop: 64,
            dup: 64,
            delay: 32,
            delay_ms: 2,
            reorder: 128,
            ..TurbulenceRule::default()
        });
        let keys = support::keys_on_distinct_groups(&store, Space::Region, 2);
        let ok = store.commit(&support::append_commit(&keys), true).is_ok();
        (
            ok,
            chaos.dropped(),
            chaos.duplicated(),
            chaos.delayed(),
            chaos.reordered(),
        )
    };
    let first = run(seed);
    let second = run(seed);
    assert_eq!(
        first, second,
        "seed {seed}: the same seed must replay the identical fault stream"
    );
    // A different seed must still uphold safety (the run panics if not);
    // its dice stream is simply a different schedule.
    let _ = run(seed ^ 0x5555);
}

// ---------------------------------------------------------------------
// Partition shapes.
// ---------------------------------------------------------------------

/// Minority-side leader: the leaseholder keeps its link to the client
/// but loses both followers.  Writes must fail promptly and
/// indeterminately (never hang, never half-apply); reads stay legal
/// only while the granted lease covers them; past the window the
/// leaseholder must refuse rather than serve stale; after heal the
/// group converges within the retry budget.
#[test]
fn minority_side_leader_fails_fast_and_recovers_after_heal() {
    let base = support::base_seed();
    let seed = base.wrapping_mul(0x9E37_79B9) ^ 0x3A17;
    let (store, chaos, clock) = support::noisy_store_2pc(1, seed);
    let k1 = Key::new(Space::Region, "part-a");
    let k2 = Key::new(Space::Region, "part-b");
    let k3 = Key::new(Space::Region, "part-c");
    // A clean commit elects replica 0 and applies once.
    store.commit(&support::append_commit(&[k1.clone()]), true).unwrap();
    // Partition: the leader is alone on the minority side.
    support::cut_group_majority(&store, &chaos, 0, CutMode::Both);
    // Writes cannot assemble a quorum: a prompt, typed, indeterminate
    // failure (the entry may sit minority-accepted on the leader).
    let err = store
        .commit(&support::append_commit(&[k2.clone()]), true)
        .expect_err("a minority side must not commit");
    assert!(
        err.is_indeterminate(),
        "seed {seed}: minority-side write must surface indeterminate, got {err:?}"
    );
    // Inside the granted window the lease guarantee still holds — no
    // rival leader can exist before expiry — so local reads serve.
    assert_once(&store, &k1, &format!("seed {seed}: in-lease read"));
    // Past the window the leaseholder cannot refresh against a quorum:
    // it must fail the read, not serve on faith.
    clock.advance(64);
    let err = store.get(&k1, true).expect_err("stale leaseholder must not serve");
    assert!(
        matches!(err, Error::NoQuorum { .. } | Error::Timeout { .. } | Error::NotLeader { .. }),
        "seed {seed}: expected a quorum-loss read failure, got {err:?}"
    );
    assert!(chaos.dropped() > 0, "seed {seed}: the cut never fired");
    // Heal: the next commit and read land within the retry budget.
    chaos.heal_all_cuts();
    store.commit(&support::append_commit(&[k3.clone()]), true).unwrap();
    assert_once(&store, &k1, &format!("seed {seed}: post-heal read"));
    assert_once(&store, &k3, &format!("seed {seed}: post-heal commit"));
    // The partitioned-away write was indeterminate: it may have been
    // recovered and chosen, or lost — but never applied twice.
    if store.get(&k2, true).unwrap().is_some() {
        assert_once(&store, &k2, &format!("seed {seed}: recovered in-flight write"));
    }
    assert!(store.converged(), "seed {seed}: replicas diverged after heal");
}

/// The coordinator group's quorum drops off the network at the worst
/// instant — every participant's intent is logged, the decision is not
/// yet replicated.  The commit must fail indeterminately, and after the
/// partition heals the recovery sweep must settle every participant to
/// the decision record (presumed abort if none was ever chosen).
#[test]
fn coordinator_partitioned_mid_2pc_settles_all_or_nothing_after_heal() {
    let base = support::base_seed();
    let seed = base.wrapping_mul(0x9E37_79B9) ^ 0x2FC0;
    let (store, chaos, _clock) = support::noisy_store_2pc(4, seed);
    let keys = support::keys_on_distinct_groups(&store, Space::Region, 3);
    let participants = support::participants_of(&store, &keys);
    let coordinator = *participants.iter().min().unwrap();
    let seen = Arc::new(Mutex::new(0u64));
    let hook_seen = seen.clone();
    let weak = Arc::downgrade(&store);
    let hook_chaos = chaos.clone();
    store.set_fault_hook(Some(Arc::new(move |phase, txn| {
        *hook_seen.lock().unwrap() = txn;
        if matches!(phase, CommitPhase::AllPrepared) {
            if let Some(s) = weak.upgrade() {
                support::cut_group_majority(&s, &hook_chaos, coordinator, CutMode::Both);
            }
        }
        FaultAction::Continue
    })));
    let result = store.commit(&support::append_commit(&keys), true);
    store.set_fault_hook(None);
    let txn = *seen.lock().unwrap();
    let err = result.expect_err("the decision cannot replicate across the partition");
    assert!(
        err.is_indeterminate(),
        "seed {seed}: partitioned coordinator must surface indeterminate, got {err:?}"
    );
    assert!(chaos.dropped() > 0, "seed {seed}: the partition never fired");
    chaos.heal_all_cuts();
    support::heal_all(&store);
    let decision = support::assert_all_or_nothing(&store, txn, &participants);
    support::assert_append_exactly_once(&store, &keys, decision == Some(true));
}

/// Delay faults push lease-grant acknowledgments past the window they
/// grant: the round publishes a lease that already expired in flight.
/// The holder must STEP DOWN (re-run the quorum grant round) rather
/// than serve a leaseholder-local read on the stale window — and must
/// still never return a wrong value while doing so.
#[test]
fn lease_expiry_under_delay_steps_down_instead_of_serving_stale() {
    let base = support::base_seed();
    let seed = base.wrapping_mul(0x9E37_79B9) ^ 0x1EA5;
    let (store, chaos, clock) = support::noisy_store_2pc(1, seed);
    let k = Key::new(Space::Region, "lease-k");
    store.commit(&support::append_commit(&[k.clone()]), true).unwrap();
    // ~30% of Paxos envelopes arrive 30 ms late — past the 20 ms lease
    // window — so many grant rounds publish an already-dead lease.
    chaos.add_rule(TurbulenceRule {
        plane: Some(Plane::Paxos),
        delay: 300,
        delay_ms: 30,
        ..TurbulenceRule::default()
    });
    for round in 0..16 {
        clock.advance(64); // expire whatever lease the last round left
        assert_once(&store, &k, &format!("seed {seed} round {round}: read under delay"));
    }
    assert!(
        store.stepdowns() > 0,
        "seed {seed}: delayed grant rounds never forced a step-down"
    );
    assert!(chaos.delayed() > 0, "seed {seed}: the delay rule never fired");
    // Calm air: reads keep serving and the group is intact.
    chaos.clear_rules();
    clock.advance(64);
    assert_once(&store, &k, &format!("seed {seed}: post-chaos read"));
    assert!(store.converged(), "seed {seed}");
}

/// Re-delivered (duplicated) Paxos traffic must never corrupt state:
/// a replayed grant acks without extending, a replayed accept re-acks
/// the recorded value, a replayed prepare is refused (the promise was
/// already spent) — so with HALF of all Paxos envelopes served twice,
/// commits still land and apply exactly once.  (Not 1024/1024: a
/// duplicated prepare's returned second response is legitimately a
/// rejection, so an all-duplicated network denies phase 1 by design —
/// the retry's job is to find a round with enough clean promises.)
#[test]
fn duplicate_delivery_of_paxos_envelopes_is_invisible() {
    let base = support::base_seed();
    let seed = base.wrapping_mul(0x9E37_79B9) ^ 0xD0B1;
    let (store, chaos, _clock) = support::noisy_store_2pc(2, seed);
    chaos.add_rule(TurbulenceRule {
        plane: Some(Plane::Paxos),
        dup: 512,
        ..TurbulenceRule::default()
    });
    let keys = support::keys_on_distinct_groups(&store, Space::Region, 2);
    let participants = support::participants_of(&store, &keys);
    let (result, txn) =
        support::run_scheduled_commit(&store, Vec::new(), &support::append_commit(&keys));
    result.unwrap_or_else(|e| panic!("seed {seed}: duplicate delivery broke the commit: {e:?}"));
    assert!(chaos.duplicated() > 0, "seed {seed}: the dup rule never fired");
    chaos.clear_rules();
    assert_eq!(
        support::assert_all_or_nothing(&store, txn, &participants),
        Some(true),
        "seed {seed}"
    );
    support::assert_append_exactly_once(&store, &keys, true);
}

// ---------------------------------------------------------------------
// Data-plane turbulence (PR 9): the dice were always wired through
// `Plane::Data` envelopes, but nothing drove the client's slice ladders
// through them.  These close PR-8's follow-up.
// ---------------------------------------------------------------------

/// Seeded drops on the data plane against a replication-3 file: every
/// read that succeeds must return the right bytes (a dropped primary
/// fails over to the remaining replicas, never to garbage), and once
/// the rule clears, reads must succeed outright.
#[test]
fn data_plane_drops_fail_reads_over_to_replicas() {
    use wtf::cluster::Cluster;
    use wtf::config::Config;
    let base = support::base_seed();
    let seed = base.wrapping_mul(0x9E37_79B9) ^ 0xDA7A;
    let mut cfg = Config::test();
    cfg.replication = 3;
    let cluster = Cluster::builder().config(cfg).build().unwrap();
    let c = cluster.client();
    let mut fd = c.create("/dp").unwrap();
    let mut data = vec![0u8; 6 * 1024];
    Rng::new(seed).fill_bytes(&mut data);
    c.write(&mut fd, &data).unwrap();

    let chaos = wtf::net::Turbulence::new(seed, wtf::coordinator::lease::LeaseClock::manual());
    chaos.add_rule(TurbulenceRule {
        plane: Some(Plane::Data),
        drop: 200, // ~20% of data envelopes vanish
        ..TurbulenceRule::default()
    });
    cluster.transport().set_turbulence(Some(chaos.clone()));
    let fd = c.open("/dp").unwrap();
    let mut ok = 0;
    for round in 0..24 {
        match c.read_at(&fd, 0, data.len() as u64) {
            Ok(bytes) => {
                assert_eq!(
                    bytes, data,
                    "seed {seed} round {round}: failover returned wrong bytes"
                );
                ok += 1;
            }
            Err(e) => assert!(
                e.is_indeterminate(),
                "seed {seed} round {round}: unexpected error class {e:?}"
            ),
        }
    }
    assert!(chaos.dropped() > 0, "seed {seed}: the drop rule never fired");
    assert!(ok > 0, "seed {seed}: no read survived 20% drops at r=3");
    // Calm air: the ladder must succeed, not just fail cleanly.
    chaos.clear_rules();
    assert_eq!(
        c.read_at(&fd, 0, data.len() as u64).unwrap(),
        data,
        "seed {seed}: post-heal read"
    );
}

/// The same dice through the coalesced (`RetrieveMany`) read path: the
/// fetch planner's per-pointer failover must hold under drops AND
/// duplicated data envelopes (a re-served retrieve is idempotent).
#[test]
fn coalesced_reads_survive_data_plane_drops_and_dups() {
    use wtf::cluster::Cluster;
    use wtf::config::Config;
    let base = support::base_seed();
    let seed = base.wrapping_mul(0x9E37_79B9) ^ 0xC0A1;
    let mut cfg = Config::fast_read_test();
    cfg.replication = 2;
    let cluster = Cluster::builder().config(cfg).build().unwrap();
    let c = cluster.client();
    let mut fd = c.create("/dpc").unwrap();
    let mut data = vec![0u8; 12 * 1024];
    Rng::new(seed ^ 1).fill_bytes(&mut data);
    c.write(&mut fd, &data).unwrap();

    let chaos = wtf::net::Turbulence::new(seed, wtf::coordinator::lease::LeaseClock::manual());
    chaos.add_rule(TurbulenceRule {
        plane: Some(Plane::Data),
        drop: 128,
        dup: 128,
        ..TurbulenceRule::default()
    });
    cluster.transport().set_turbulence(Some(chaos.clone()));
    let fd = c.open("/dpc").unwrap();
    for round in 0..16 {
        // Cold-ish every round: drop the client cache so the metadata
        // AND data ladders both re-run under the dice.
        c.metadata_cache().clear();
        match c.read_at(&fd, 0, data.len() as u64) {
            Ok(bytes) => assert_eq!(
                bytes, data,
                "seed {seed} round {round}: coalesced failover returned wrong bytes"
            ),
            Err(e) => assert!(
                e.is_indeterminate(),
                "seed {seed} round {round}: unexpected error class {e:?}"
            ),
        }
    }
    assert!(
        chaos.faults_injected() > 0,
        "seed {seed}: no data-plane fault ever fired"
    );
    chaos.clear_rules();
    c.metadata_cache().clear();
    assert_eq!(
        c.read_at(&fd, 0, data.len() as u64).unwrap(),
        data,
        "seed {seed}: post-heal coalesced read"
    );
}

/// Ack loss on a `CreateSlice` store must never double-append: the
/// slice lands on the cut server but the ack vanishes, the client fails
/// over to another server, and ONLY the acked pointer is published.
/// The orphan is invisible to every reader and reclaimed by GC's
/// two-scan rule once the air clears.
#[test]
fn store_ack_loss_never_double_appends() {
    use wtf::cluster::Cluster;
    use wtf::config::Config;
    let base = support::base_seed();
    let seed = base.wrapping_mul(0x9E37_79B9) ^ 0x5708;
    let mut cfg = Config::test();
    cfg.replication = 2;
    let cluster = Cluster::builder().config(cfg).build().unwrap();
    let c = cluster.client();
    let fd = c.create("/ack").unwrap();

    let chaos = wtf::net::Turbulence::new(seed, wtf::coordinator::lease::LeaseClock::manual());
    // Two of the four servers lose their acks: any region whose scatter
    // set touches either one exercises the store-failover ladder, and
    // two servers always remain for the top-up pass.
    for sid in [0, 1] {
        let victim: wtf::net::Peer = cluster.storage().get(sid).unwrap().clone();
        chaos.cut(&victim, CutMode::AckLoss);
    }
    cluster.transport().set_turbulence(Some(chaos.clone()));
    // Append one 512-byte record at a time until a CreateSlice provably
    // hit a cut server (ring placement is deterministic, so bound the
    // hunt), then a few more for good measure.
    let mut expected: Vec<u8> = Vec::new();
    let mut i = 0u8;
    while chaos.acks_lost() == 0 {
        assert!(i < 64, "seed {seed}: no store ever landed on a cut server");
        let rec = vec![b'A' + (i % 26); 512];
        c.append_bytes(&fd, &rec).unwrap();
        expected.extend_from_slice(&rec);
        i += 1;
    }
    for _ in 0..4 {
        let rec = vec![b'A' + (i % 26); 512];
        c.append_bytes(&fd, &rec).unwrap();
        expected.extend_from_slice(&rec);
        i += 1;
    }
    chaos.heal_all_cuts();

    // Exactly one copy of every record, in order — nothing doubled,
    // nothing torn.
    let fd = c.open("/ack").unwrap();
    let len = c.len(&fd).unwrap();
    assert_eq!(
        len,
        expected.len() as u64,
        "seed {seed}: doubled or lost append"
    );
    assert_eq!(
        c.read_at(&fd, 0, len).unwrap(),
        expected,
        "seed {seed}: append content corrupt after ack-loss failover"
    );
    // The served-but-unacked slices are unreferenced orphans: GC's
    // two-scan rule reclaims them.
    cluster.run_gc().unwrap();
    let report = cluster.run_gc().unwrap();
    assert!(
        report.bytes_reclaimed > 0,
        "seed {seed}: orphaned ack-loss slices were never reclaimed"
    );
}
