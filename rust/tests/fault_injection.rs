//! Fault-injection tests: metadata replica failures, storage server
//! loss, coordinator quorum loss, concurrent-writer storms — and the
//! deterministic 2PC fault schedules (coordinator death, participant
//! quorum loss, decision replay) proving the cross-group all-or-nothing
//! contract.  §2.9 and §3's claims, exercised.

mod support;

use std::sync::Arc;
use support::{At, Fault};
use wtf::client::WtfClient;
use wtf::cluster::Cluster;
use wtf::config::Config;
use wtf::coordinator::CoordCmd;
use wtf::storage::StorageCluster;
use wtf::types::Space;
use wtf::util::Rng;

fn cluster() -> Cluster {
    Cluster::builder().config(Config::test()).build().unwrap()
}

#[test]
fn metadata_survives_chain_replica_failure_mid_workload() {
    let cl = cluster();
    let c = cl.client();
    let mut fd = c.create("/f").unwrap();
    c.write(&mut fd, b"before failure").unwrap();

    // Kill one replica of EVERY metadata shard (f=1 tolerance).
    cl.meta().kill_replica(0);
    assert_eq!(c.read_at(&fd, 0, 14).unwrap(), b"before failure");
    c.append_bytes(&fd, b" and after").unwrap();
    assert_eq!(c.read_at(&fd, 0, 24).unwrap(), b"before failure and after");

    // Recover; then kill the OTHER replica: the recovered one must have
    // the post-failure writes.
    cl.meta().recover_replica(0);
    cl.meta().kill_replica(1);
    assert_eq!(c.read_at(&fd, 0, 24).unwrap(), b"before failure and after");
    for s in cl.meta_shard_stats() {
        assert_eq!(s.live_replicas, 1);
    }
}

#[test]
fn reads_and_writes_survive_storage_server_loss() {
    let cl = Cluster::builder()
        .config(Config::test())
        .storage_servers(4)
        .replication(2)
        .build()
        .unwrap();
    let c = cl.client();
    let mut fd = c.create("/durable").unwrap();
    let mut data = vec![0u8; 6000];
    Rng::new(4).fill_bytes(&mut data);
    c.write(&mut fd, &data).unwrap();

    // Drop each server in turn (one at a time): every byte must remain
    // readable through the surviving replicas.
    for dead in 0..4u32 {
        let survivors: Vec<_> = cl
            .storage()
            .iter()
            .filter(|s| s.id() != dead)
            .cloned()
            .collect();
        let degraded = Arc::new(StorageCluster::new(survivors));
        let c2 = WtfClient::new(
            cl.config().clone(),
            cl.meta().clone(),
            degraded,
            cl.client().ring().clone(),
        );
        let fd2 = c2.open("/durable").unwrap();
        assert_eq!(
            c2.read_at(&fd2, 0, data.len() as u64).unwrap(),
            data,
            "data lost when server {dead} is down"
        );
        // Writes keep working too (degraded replication allowed).
        let f = c2.create(&format!("/during-loss-{dead}")).unwrap();
        c2.append_bytes(&f, b"alive").unwrap();
        assert_eq!(c2.read_at(&f, 0, 5).unwrap(), b"alive");
    }
}

#[test]
fn coordinator_quorum_loss_and_recovery() {
    let cl = cluster();
    let coord = cl.coordinator();
    // 3 replicas: killing one is fine.
    coord.kill_acceptor(0);
    coord.call(CoordCmd::RegisterServer { id: 90, weight: 1 }).unwrap();
    // Killing two: no progress.
    coord.kill_acceptor(1);
    assert!(coord.call(CoordCmd::RegisterServer { id: 91, weight: 1 }).is_err());
    // Recovery restores service with history intact.
    coord.recover_acceptor(1);
    coord.call(CoordCmd::RegisterServer { id: 91, weight: 1 }).unwrap();
    let cfg = coord.config().unwrap();
    assert!(cfg.online_servers.contains(&90));
    assert!(cfg.online_servers.contains(&91));
    assert!(coord.replicas_converged());
}

#[test]
fn concurrent_writer_storm_with_meta_replica_flapping() {
    let cl = Arc::new(cluster());
    let c = cl.client();
    c.create("/storm").unwrap();
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));

    // Flapper: kill/recover metadata replica 0 repeatedly.
    let flapper = {
        let cl = cl.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut i = 0;
            while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                if i % 2 == 0 {
                    cl.meta().kill_replica(0);
                } else {
                    cl.meta().recover_replica(0);
                }
                i += 1;
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
            cl.meta().recover_replica(0);
        })
    };

    let writers: Vec<_> = (0..6)
        .map(|w| {
            let cl = cl.clone();
            std::thread::spawn(move || {
                let c = cl.client();
                let fd = c.open("/storm").unwrap();
                for _ in 0..24 {
                    c.append_bytes(&fd, &[b'a' + w as u8; 16]).unwrap();
                }
            })
        })
        .collect();
    for w in writers {
        w.join().unwrap();
    }
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    flapper.join().unwrap();

    // Every append landed exactly once, untorn.
    let fd = c.open("/storm").unwrap();
    let len = c.len(&fd).unwrap();
    assert_eq!(len, 6 * 24 * 16);
    let data = c.read_at(&fd, 0, len).unwrap();
    let mut counts = [0u32; 6];
    for rec in data.chunks(16) {
        assert!(rec.iter().all(|&b| b == rec[0]), "torn record");
        counts[(rec[0] - b'a') as usize] += 1;
    }
    assert!(counts.iter().all(|&n| n == 24), "{counts:?}");
}

// ---------------------------------------------------------------------
// Paxos-replicated metadata: leader failover, lease reads, exactly-once.
// ---------------------------------------------------------------------

fn replicated_cluster() -> Cluster {
    Cluster::builder()
        .config(Config::replicated_test())
        .build()
        .unwrap()
}

#[test]
fn replicated_meta_survives_follower_loss_and_rejoins() {
    let cl = replicated_cluster();
    let c = cl.client();
    let mut fd = c.create("/f").unwrap();
    c.write(&mut fd, b"before failure").unwrap();

    // Replica 0 leads every group at bootstrap, so replica 2 is a
    // follower everywhere: killing it must not stall anything.
    cl.meta().kill_replica(2);
    assert_eq!(c.read_at(&fd, 0, 14).unwrap(), b"before failure");
    c.append_bytes(&fd, b" and after").unwrap();
    assert_eq!(c.read_at(&fd, 0, 24).unwrap(), b"before failure and after");

    // Rejoin: deterministic log replay rebuilds the replica's state.
    cl.meta().recover_replica(2);
    let r = cl.meta().replicated_store().unwrap();
    assert!(r.converged(), "rejoined replica replayed to the same state");
    for s in cl.meta_shard_stats() {
        assert_eq!(s.live_replicas, 3);
    }
}

#[test]
fn replicated_client_heals_after_leader_kill() {
    let cl = replicated_cluster();
    let c = cl.client();
    let fd = c.create("/heal").unwrap();
    c.append_bytes(&fd, b"one").unwrap();
    let elections_before = cl.meta().replicated_store().unwrap().elections();

    cl.meta().kill_replica(0); // every group's leader

    // The next op hits NotLeader on the envelope path; the client's
    // retry layer rediscovers the leader (waiting out the dead leader's
    // lease) and replays to success.
    c.append_bytes(&fd, b" two").unwrap();
    assert_eq!(c.read_at(&fd, 0, 7).unwrap(), b"one two");

    let r = cl.meta().replicated_store().unwrap();
    assert!(r.elections() > elections_before, "a failover election ran");
    assert!(r.converged());
}

#[test]
fn replicated_leader_failover_mid_transaction_is_exactly_once() {
    leader_failover_exactly_once(Config::replicated_test());
}

#[test]
fn two_pc_leader_failover_mid_transaction_is_exactly_once() {
    // The same client-visible contract with multi-shard commits running
    // the intent-logged 2PC: markers land in both files or neither,
    // never once-of-two and never twice.
    leader_failover_exactly_once(Config::replicated_2pc_test());
}

#[test]
fn production_preset_leader_failover_mid_transaction_is_exactly_once() {
    // The deployment shape (PR 9): the same exactly-once contract with
    // the versioned metadata cache and read coalescing layered on top
    // of paxos + 2PC — a failover retry must never replay against a
    // stale cached read set (commit-time validation is the backstop).
    leader_failover_exactly_once(support::production_test_config());
}

fn leader_failover_exactly_once(cfg: Config) {
    use std::sync::atomic::{AtomicBool, Ordering};

    let cl = Arc::new(Cluster::builder().config(cfg).build().unwrap());
    let c = cl.client();
    c.create("/a").unwrap();
    c.create("/b").unwrap();

    // Crash every group's leader while multi-file transactions are in
    // flight.
    let started = Arc::new(AtomicBool::new(false));
    let killer = {
        let cl = cl.clone();
        let started = started.clone();
        std::thread::spawn(move || {
            while !started.load(Ordering::Relaxed) {
                std::thread::yield_now();
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
            cl.meta().kill_replica(0);
        })
    };

    let mut committed = Vec::new();
    for i in 0..26u8 {
        let rec = [b'a' + i; 16];
        let mut t = c.begin();
        let fa = t.open("/a").unwrap();
        let fb = t.open("/b").unwrap();
        t.seek(fa, wtf::client::SeekFrom::End(0)).unwrap();
        t.write(fa, &rec).unwrap();
        t.seek(fb, wtf::client::SeekFrom::End(0)).unwrap();
        t.write(fb, &rec).unwrap();
        started.store(true, Ordering::Relaxed);
        // Stretch the stream so the kill lands between commits, not
        // after them all.
        std::thread::sleep(std::time::Duration::from_micros(300));
        match t.commit() {
            Ok(()) => committed.push(i),
            // A clean abort is acceptable under failover; losing or
            // double-applying a committed op is not (checked below).
            Err(e) => assert!(
                matches!(
                    e,
                    wtf::Error::TxnAborted { .. } | wtf::Error::RetriesExhausted { .. }
                ),
                "unexpected commit error under failover: {e}"
            ),
        }
    }
    killer.join().unwrap();

    // Every marker a successful commit wrote appears in BOTH files
    // exactly once; aborted markers appear in neither.
    for path in ["/a", "/b"] {
        let fd = c.open(path).unwrap();
        let len = c.len(&fd).unwrap();
        assert_eq!(len % 16, 0, "torn append in {path}");
        let data = c.read_at(&fd, 0, len).unwrap();
        let mut counts = [0u32; 26];
        for rec in data.chunks(16) {
            assert!(rec.iter().all(|&b| b == rec[0]), "torn record in {path}");
            counts[(rec[0] - b'a') as usize] += 1;
        }
        for i in 0..26u8 {
            let expect = u32::from(committed.contains(&i));
            assert_eq!(
                counts[i as usize], expect,
                "marker {i} in {path}: committed MetaOp lost or applied twice"
            );
        }
    }
    let r = cl.meta().replicated_store().unwrap();
    assert!(r.converged(), "all live replicas agree after failover");
}

#[test]
fn notleader_failover_invalidates_the_read_cache() {
    // The read-cache heal trigger: a client whose cache is warm issues
    // no metadata rounds at all, so a leader failover is only noticed
    // when some operation finally hits `NotLeader`.  That operation
    // must clear the cache before replaying — afterwards the client
    // observes everything committed through the new leader, including
    // writes by OTHER clients that its cached view had been allowed to
    // lag behind.
    let mut cfg = Config::replicated_test();
    cfg.metadata_cache = true;
    cfg.read_coalescing = true;
    let cl = Cluster::builder().config(cfg).build().unwrap();
    let a = cl.client();
    let b = cl.client();

    let fda = a.create("/c").unwrap();
    a.append_bytes(&fda, b"base").unwrap();
    // Warm A's cache; prove the next read actually serves from it.
    assert_eq!(a.read_at(&fda, 0, 4).unwrap(), b"base");
    let hits_before = a.metadata_cache().hits();
    assert_eq!(a.read_at(&fda, 0, 4).unwrap(), b"base");
    assert!(a.metadata_cache().hits() > hits_before, "cache not serving");

    // B extends the file; A's cached view may lag (the documented
    // contract for plain reads).
    let fdb = b.open("/c").unwrap();
    b.append_bytes(&fdb, b"+more").unwrap();

    // Kill every group's leader.  A's warm cache means its plain reads
    // issue no metadata rounds at all — the failover is first noticed
    // by the next operation that does go to the wire (here the
    // append's fresh inode read, or its commit), which heals and must
    // drop the cache.
    cl.meta().kill_replica(0);
    let invalidations_before = a.metadata_cache().invalidations();
    a.append_bytes(&fda, b"+mine").unwrap();
    assert!(
        a.metadata_cache().invalidations() > invalidations_before,
        "NotLeader heal did not invalidate the cache"
    );

    // Post-heal, A sees the full history: base + B's write + its own.
    let len = a.len(&fda).unwrap();
    assert_eq!(len, 4 + 5 + 5);
    assert_eq!(a.read_at(&fda, 0, len).unwrap(), b"base+more+mine");
    assert!(cl.meta().replicated_store().unwrap().converged());
}

#[test]
fn transactional_read_heal_also_invalidates_the_cache() {
    // The other heal path: `MetaTxn::get` heals NotLeader INTERNALLY
    // (the error never surfaces to with_retry or a commit arm), so the
    // cache clear must ride the transaction's heal hook.  After a
    // failover first noticed by a transactional read, the client's
    // plain reads must observe everything committed through the new
    // leader.
    let mut cfg = Config::replicated_test();
    cfg.metadata_cache = true;
    cfg.read_coalescing = true;
    let cl = Cluster::builder().config(cfg).build().unwrap();
    let a = cl.client();
    let b = cl.client();

    let fda = a.create("/t").unwrap();
    a.append_bytes(&fda, b"base").unwrap();
    assert_eq!(a.read_at(&fda, 0, 4).unwrap(), b"base"); // warm A's cache
    b.append_bytes(&b.open("/t").unwrap(), b"+more").unwrap();

    cl.meta().kill_replica(0); // every group's leader
    // concat's FIRST metadata round is a transactional get: it hits
    // NotLeader, heals in place, and must clear A's cache on the way.
    let inv_before = a.metadata_cache().invalidations();
    let copy = a.concat(&["/t"], "/t2").unwrap();
    assert!(
        a.metadata_cache().invalidations() > inv_before,
        "internal MetaTxn heal did not invalidate the cache"
    );
    // /t's inode was NOT mutated by the concat commit, so seeing the
    // new length proves the heal hook (not own-commit invalidation)
    // dropped the stale entry.
    let len = a.len(&fda).unwrap();
    assert_eq!(len, 9);
    assert_eq!(a.read_at(&fda, 0, len).unwrap(), b"base+more");
    assert_eq!(a.read_at(&copy, 0, 9).unwrap(), b"base+more");
    assert!(cl.meta().replicated_store().unwrap().converged());
}

#[test]
fn replicated_no_quorum_halts_commits_until_rejoin() {
    let cl = replicated_cluster();
    let c = cl.client();
    let fd = c.create("/nq").unwrap();
    c.append_bytes(&fd, b"safe").unwrap();

    cl.meta().kill_replica(1);
    cl.meta().kill_replica(2);
    assert!(
        c.append_bytes(&fd, b"lost").is_err(),
        "majority dead: commits must fail"
    );

    // A learner rejoins from the survivor's log (no quorum needed), and
    // service resumes.
    cl.meta().recover_replica(1);
    c.append_bytes(&fd, b" back").unwrap();
    let len = c.len(&fd).unwrap();
    let data = c.read_at(&fd, 0, len).unwrap();
    assert!(data.starts_with(b"safe"), "{data:?}");
    assert!(data.ends_with(b" back"), "{data:?}");
    assert!(cl.meta().replicated_store().unwrap().converged());
}

// ---------------------------------------------------------------------
// Cross-group 2PC fault schedules (meta_2pc): the all-or-nothing proof.
// ---------------------------------------------------------------------

#[test]
fn two_pc_participant_quorum_loss_before_decision_commits_after_heal() {
    let store = support::store_2pc(4);
    let keys = support::keys_on_distinct_groups(&store, Space::Region, 3);
    let participants = support::participants_of(&store, &keys);
    let target = participants[1]; // a non-coordinator participant
    // Kill the target group's quorum the instant its prepare lands —
    // i.e. between prepare and decision.
    let schedule = vec![(
        At::Prepared(target),
        Fault::Kill {
            shard: target,
            count: 2,
        },
    )];
    let commit = support::append_commit(&keys);
    let (result, txn) = support::run_scheduled_commit(&store, schedule, &commit);

    // The decision record replicated in the coordinator group, so the
    // transaction IS committed and the front-end reports success; the
    // dead group holds a durable intent it will resolve after healing.
    result.expect("decision was durable; the commit must report success");
    assert_eq!(store.decision_of(participants[0], txn), Some(true));
    assert!(
        store
            .pending_intents()
            .iter()
            .any(|(s, t, _)| *s == target && *t == txn),
        "the quorum-dead group must still hold its intent"
    );
    // Until the group heals, its staged keys are unreadable — NEVER
    // served half-committed: resolution needs a quorum it lacks.
    let dead_key = keys
        .iter()
        .find(|k| store.group_of(k).shard() == target)
        .unwrap();
    assert!(
        store.get(dead_key, true).is_err(),
        "an intent-locked key in a quorum-less group must error, not read"
    );

    support::heal_all(&store);
    assert_eq!(
        support::assert_all_or_nothing(&store, txn, &participants),
        Some(true)
    );
    support::assert_append_exactly_once(&store, &keys, true);
}

#[test]
fn two_pc_participant_quorum_loss_then_coordinator_death_aborts_after_heal() {
    let store = support::store_2pc(4);
    let keys = support::keys_on_distinct_groups(&store, Space::Region, 3);
    let participants = support::participants_of(&store, &keys);
    let target = participants[1];
    // Every intent is logged; then the target group loses its quorum
    // AND the coordinating front-end dies before any decision — the
    // abort direction of the same window.
    let schedule = vec![
        (
            At::AllPrepared,
            Fault::Kill {
                shard: target,
                count: 2,
            },
        ),
        (At::AllPrepared, Fault::Abandon),
    ];
    let commit = support::append_commit(&keys);
    let (result, txn) = support::run_scheduled_commit(&store, schedule, &commit);
    assert!(result.is_err(), "an abandoned commit must not report success");
    assert_eq!(
        store.decision_of(participants[0], txn),
        None,
        "the front-end died before deciding"
    );

    // Healing resolves every orphaned intent through the (absent)
    // decision record: presumed abort, recorded durably first.
    support::heal_all(&store);
    assert_eq!(
        support::assert_all_or_nothing(&store, txn, &participants),
        Some(false)
    );
    support::assert_append_exactly_once(&store, &keys, false);
}

#[test]
fn two_pc_coordinator_death_after_prepare_resolves_through_reads() {
    let store = support::store_2pc(4);
    let keys = support::keys_on_distinct_groups(&store, Space::Region, 2);
    let participants = support::participants_of(&store, &keys);
    let schedule = vec![(At::AllPrepared, Fault::Abandon)];
    let (result, txn) =
        support::run_scheduled_commit(&store, schedule, &support::append_commit(&keys));
    assert!(result.is_err());
    assert_eq!(store.pending_intents().len(), 2, "both intents orphaned");

    // No healing sweep at all: a plain leaseholder read of each locked
    // key is enough to resolve its intent (presumed abort) — a reader
    // can never observe the staged half of the dead transaction.
    for k in &keys {
        assert_eq!(store.get(k, true).unwrap(), None);
    }
    assert_eq!(
        support::assert_all_or_nothing(&store, txn, &participants),
        Some(false)
    );
    support::assert_append_exactly_once(&store, &keys, false);
}

#[test]
fn two_pc_seeded_schedule_smoke() {
    // A handful of WTF_TEST_SEED-derived random schedules (the CI seed
    // matrix varies them per entry; the full sweep lives in
    // tests/proptests.rs).  Prints the effective seed on failure so the
    // schedule reproduces.
    let base = support::base_seed();
    for case in 0..4u64 {
        let seed = base.wrapping_mul(0x9E37_79B9) ^ (0xFA17 + case);
        let mut rng = Rng::new(seed);
        let store = support::store_2pc(4);
        let keys = support::keys_on_distinct_groups(&store, Space::Region, 2);
        let participants = support::participants_of(&store, &keys);
        let schedule = support::random_schedule(&mut rng, &participants);
        let (_, txn) =
            support::run_scheduled_commit(&store, schedule, &support::append_commit(&keys));
        support::heal_all(&store);
        let decision = support::assert_all_or_nothing(&store, txn, &participants);
        support::assert_append_exactly_once(&store, &keys, decision == Some(true));
        println!("seeded schedule ok: WTF_TEST_SEED={base} case {case} (seed {seed})");
    }
}

#[test]
fn two_pc_decision_replay_through_crash_recovery_is_exactly_once() {
    let store = support::store_2pc(4);
    let keys = support::keys_on_distinct_groups(&store, Space::Region, 3);
    let participants = support::participants_of(&store, &keys);
    let (result, txn) =
        support::run_scheduled_commit(&store, Vec::new(), &support::append_commit(&keys));
    result.unwrap();
    support::assert_append_exactly_once(&store, &keys, true);

    // Crash and rejoin the followers of every group twice: each rejoin
    // REPLAYS the whole log — the prepare and the decision record land
    // again on every recovered replica — and the txn-id dedup keeps the
    // apply single.
    for _ in 0..2 {
        for idx in 1..support::GROUP_REPLICAS {
            store.kill_replica(idx);
        }
        support::heal_all(&store);
    }
    assert_eq!(
        support::assert_all_or_nothing(&store, txn, &participants),
        Some(true)
    );
    support::assert_append_exactly_once(&store, &keys, true);
}

// ---------------------------------------------------------------------
// Write-path symmetry (PR 6): group commit and write-behind under faults.
// ---------------------------------------------------------------------

#[test]
fn group_commit_batch_from_two_clients_survives_leader_death_mid_batch() {
    use std::collections::HashMap;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::time::Duration;
    use wtf::coordinator::lease::LeaseClock;
    use wtf::meta::{CommitPhase, FaultAction, ReplicatedMetaStore};
    use wtf::net::Transport;
    use wtf::types::Key;

    // A 2PC store with group commit on: a generous window and a batch
    // size of exactly two, so the first committer (the collector)
    // waits for the second and both ride ONE shared log entry.
    let store = Arc::new(
        ReplicatedMetaStore::new(
            4,
            support::GROUP_REPLICAS as u8,
            Arc::new(Transport::instant()),
            LeaseClock::manual(),
            20,
        )
        .two_pc(true)
        .group_commit(Duration::from_millis(200), 2),
    );

    // Two keys on the SAME shard: both commits are single-shard and
    // eligible for the same group's batch.
    let (sid, keys) = {
        let mut by_shard: HashMap<u32, Vec<Key>> = HashMap::new();
        let mut found = None;
        for i in 0..10_000 {
            let k = Key::new(Space::Region, format!("gc{i}"));
            let s = store.group_of(&k).shard();
            let bucket = by_shard.entry(s).or_default();
            bucket.push(k);
            if bucket.len() == 2 {
                found = Some((s, bucket.clone()));
                break;
            }
        }
        found.expect("two same-shard keys")
    };

    // Kill the shard's bootstrap leader (replica 0) the first time a
    // member stages inside the batch flush — mid-batch, before the
    // shared proposal goes to the wire.
    let killed = Arc::new(AtomicBool::new(false));
    {
        let weak = Arc::downgrade(&store);
        let killed = killed.clone();
        store.set_fault_hook(Some(Arc::new(move |phase, _txn| {
            if matches!(phase, CommitPhase::Staged) && !killed.swap(true, Ordering::SeqCst) {
                if let Some(s) = weak.upgrade() {
                    s.groups()[sid as usize].kill_replica(0);
                }
            }
            FaultAction::Continue
        })));
    }

    let threads: Vec<_> = keys
        .iter()
        .cloned()
        .map(|k| {
            let store = store.clone();
            std::thread::spawn(move || store.commit(&support::append_commit(&[k]), true))
        })
        .collect();
    let results: Vec<_> = threads.into_iter().map(|t| t.join().unwrap()).collect();
    store.set_fault_hook(None);
    assert!(killed.load(Ordering::SeqCst), "the fault hook never fired");
    for r in results {
        r.expect("a mid-batch leader death must elect through, not lose txns");
    }

    // Exactly-once per member: each key appended once (eof 8, version
    // 1), never doubled by the election-replayed batch entry.
    support::heal_all(&store);
    support::assert_append_exactly_once(&store, &keys, true);
    assert!(store.converged(), "live replicas diverged after the batch");
}

#[test]
fn write_behind_flush_boundary_publishes_queued_appends() {
    let mut cfg = Config::replicated_test();
    cfg.write_behind = true;
    let cl = Cluster::builder().config(cfg).build().unwrap();
    let c = cl.client();
    let fd = c.create("/wb").unwrap();

    // Enqueues return the ASSUMED offsets immediately — the pipeline's
    // promise, validated below once the flush boundary makes it real.
    for i in 0..8u8 {
        let at = c.append_bytes(&fd, &[b'a' + i; 16]).unwrap();
        assert_eq!(at, u64::from(i) * 16, "assumed offset drifted");
    }
    c.flush().unwrap();
    let data = c.read_at(&fd, 0, 128).unwrap();
    for (i, rec) in data.chunks(16).enumerate() {
        assert!(
            rec.iter().all(|&b| b == b'a' + i as u8),
            "append {i} landed out of order or torn"
        );
    }

    // A WTF transaction commit is also a reconciliation boundary: after
    // it returns, earlier queued writes are durably published.
    c.append_bytes(&fd, &[b'z'; 16]).unwrap();
    let mut t = c.begin();
    let tf = t.create("/wb-marker").unwrap();
    t.write(tf, b"marker").unwrap();
    t.commit().unwrap();
    assert_eq!(c.len(&c.open("/wb").unwrap()).unwrap(), 144);
    assert!(cl.meta().replicated_store().unwrap().converged());

    // close() is the third boundary (a no-op here: already drained).
    c.close(fd).unwrap();
}

#[test]
fn transaction_retry_budget_exhaustion_is_clean() {
    let mut cfg = Config::test();
    cfg.txn_retry_budget = 2;
    let cl = Cluster::builder().config(cfg).build().unwrap();
    let c = cl.client();
    let mut fd = c.create("/busy").unwrap();
    c.write(&mut fd, b"x").unwrap();
    // Normal operation still succeeds with a tiny budget.
    c.append_bytes(&fd, b"y").unwrap();
    assert_eq!(c.read_at(&fd, 0, 2).unwrap(), b"xy");
}

// ---------------------------------------------------------------------
// Durable WAL (PR 7): restart-from-disk recovery, mid-2PC intent
// replay, and refuse-to-vote on a damaged log.  The `durable_` name
// prefix is the crash-recovery CI job's test filter.
// ---------------------------------------------------------------------

/// The largest WAL artifact (segment or checkpoint) under
/// `replica_dir` — the one whose damage a restart cannot miss.
fn largest_wal_file(replica_dir: &std::path::Path) -> std::path::PathBuf {
    let mut largest: Option<(u64, std::path::PathBuf)> = None;
    for entry in std::fs::read_dir(replica_dir).unwrap() {
        let path = entry.unwrap().path();
        let name = path.file_name().unwrap().to_string_lossy().into_owned();
        if !(name.starts_with("seg-") || name.starts_with("ckpt-")) {
            continue;
        }
        let len = std::fs::metadata(&path).unwrap().len();
        if largest.as_ref().is_none_or(|(l, _)| len > *l) {
            largest = Some((len, path));
        }
    }
    let (len, path) = largest.expect("replica dir holds WAL artifacts");
    assert!(len > 3, "an acknowledged history cannot be this short");
    path
}

/// Flip one byte in the middle of the largest WAL artifact under
/// `replica_dir`.
fn corrupt_largest_wal_file(replica_dir: &std::path::Path) {
    let path = largest_wal_file(replica_dir);
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    std::fs::write(&path, bytes).unwrap();
}

#[test]
fn durable_restart_from_disk_alone_converges_exactly_once() {
    let wal_root = wtf::util::TempDir::new("wtf-durable-restart").unwrap();
    let store = support::store_durable(4, wal_root.path());
    let keys = support::keys_on_distinct_groups(&store, Space::Region, 3);
    let participants = support::participants_of(&store, &keys);
    let (result, txn) =
        support::run_scheduled_commit(&store, Vec::new(), &support::append_commit(&keys));
    result.expect("fault-free durable commit");

    // Kill followers first (quorum loss), then restart the survivor
    // from its WAL directory with NO live peer: the disk alone must
    // rebuild the acknowledged history.
    for g in store.groups() {
        for r in 1..support::GROUP_REPLICAS {
            g.kill_replica(r);
        }
        g.restart_replica(0).expect("restart from disk alone");
    }
    for idx in 1..support::GROUP_REPLICAS {
        store.recover_replica(idx).unwrap();
    }
    assert_eq!(
        support::assert_all_or_nothing(&store, txn, &participants),
        Some(true)
    );
    support::assert_append_exactly_once(&store, &keys, true);

    // The restarted store keeps serving: a second transaction commits
    // and the WAL keeps absorbing it (liveness after recovery).
    let keys2 = support::keys_on_distinct_groups(&store, Space::Inode, 2);
    let (result2, txn2) =
        support::run_scheduled_commit(&store, Vec::new(), &support::append_commit(&keys2));
    result2.expect("durable commit after restart");
    assert_ne!(txn2, txn);
    support::assert_append_exactly_once(&store, &keys2, true);
    assert!(store.converged());
}

#[test]
fn durable_restart_mid_2pc_replays_prepare_intent_bit_for_bit() {
    let wal_root = wtf::util::TempDir::new("wtf-durable-intent").unwrap();
    let store = support::store_durable(4, wal_root.path());
    let keys = support::keys_on_distinct_groups(&store, Space::Region, 3);
    let participants = support::participants_of(&store, &keys);
    let target = participants[1]; // a non-coordinator participant

    // Abandon the front-end once every Prepare intent is logged: the
    // target group is left holding a durable, undecided intent.
    let schedule = vec![(support::At::AllPrepared, support::Fault::Abandon)];
    let (result, txn) =
        support::run_scheduled_commit(&store, schedule, &support::append_commit(&keys));
    assert!(result.is_err(), "an abandoned commit must not report success");

    // Restart the target group's follower from its WAL directory while
    // the intent is pending.  ADR-007's contract: the replayed replica
    // is indistinguishable — intent, locks, acceptor state and all.
    let group = &store.groups()[target as usize];
    let victim = support::GROUP_REPLICAS - 1;
    let before = group
        .replica_durable_image(victim)
        .expect("live replica has an image");
    assert!(
        before.intents.iter().any(|i| i.txn_id == txn),
        "the Prepare intent must be staged before the restart"
    );
    assert!(
        !before.locks.is_empty(),
        "a staged intent holds its key locks"
    );
    group.restart_replica(victim).expect("durable restart");
    let after = group
        .replica_durable_image(victim)
        .expect("restarted replica is alive");
    assert_eq!(before, after, "WAL replay must be bit-for-bit");

    // Resolution still works on the replayed state: presumed abort
    // (the coordinator never decided), exactly once, nothing applied.
    support::heal_all(&store);
    assert_eq!(
        support::assert_all_or_nothing(&store, txn, &participants),
        Some(false)
    );
    support::assert_append_exactly_once(&store, &keys, false);
}

#[test]
fn durable_restart_mid_2pc_fault_schedule_commits_exactly_once() {
    let wal_root = wtf::util::TempDir::new("wtf-durable-sched").unwrap();
    let store = support::store_durable(4, wal_root.path());
    let keys = support::keys_on_distinct_groups(&store, Space::Region, 3);
    let participants = support::participants_of(&store, &keys);
    let target = participants[1];
    // Restart the target group's follower the instant its Prepare
    // lands — a full tear-down-to-disk mid-protocol, not just a kill.
    let schedule = vec![(
        support::At::Prepared(target),
        support::Fault::Restart {
            shard: target,
            count: 1,
        },
    )];
    let (result, txn) =
        support::run_scheduled_commit(&store, schedule, &support::append_commit(&keys));
    result.expect("a follower restart must not lose the commit");
    support::heal_all(&store);
    assert_eq!(
        support::assert_all_or_nothing(&store, txn, &participants),
        Some(true)
    );
    support::assert_append_exactly_once(&store, &keys, true);
}

#[test]
fn durable_seeded_restart_schedule_smoke() {
    // WTF_TEST_SEED-derived restart schedules (the CI crash-recovery
    // matrix varies them per seed entry): replicas are torn down to
    // their WAL directories and rebuilt from disk at random protocol
    // instants, sometimes alongside an abandoned front-end.  Whatever
    // the schedule, the decision oracles must hold — a replica that
    // recovers from its log alone is indistinguishable from one that
    // never went away.  Prints the effective seed on failure so the
    // schedule reproduces.
    let base = support::base_seed();
    for case in 0..3u64 {
        let seed = base.wrapping_mul(0x9E37_79B9) ^ (0xD15C + case);
        let mut rng = Rng::new(seed);
        let wal_root = wtf::util::TempDir::new("wtf-durable-seeded").unwrap();
        let store = support::store_durable(4, wal_root.path());
        let keys = support::keys_on_distinct_groups(&store, Space::Region, 2);
        let participants = support::participants_of(&store, &keys);
        let schedule = support::random_restart_schedule(&mut rng, &participants);
        let (_, txn) =
            support::run_scheduled_commit(&store, schedule, &support::append_commit(&keys));
        support::heal_all(&store);
        let decision = support::assert_all_or_nothing(&store, txn, &participants);
        support::assert_append_exactly_once(&store, &keys, decision == Some(true));
        println!("durable seeded schedule ok: WTF_TEST_SEED={base} case {case} (seed {seed})");
    }
}

#[test]
fn durable_corrupt_wal_refuses_to_vote_and_degrades_quorum() {
    let wal_root = wtf::util::TempDir::new("wtf-durable-corrupt").unwrap();
    let store = support::store_durable(2, wal_root.path());
    let keys = support::keys_on_distinct_groups(&store, Space::Region, 2);
    let (result, _) =
        support::run_scheduled_commit(&store, Vec::new(), &support::append_commit(&keys));
    result.expect("fault-free durable commit");

    // Crash shard 0's highest replica to disk, then flip one bit in its
    // largest WAL artifact.
    let victim = support::GROUP_REPLICAS - 1;
    store.groups()[0].kill_replica(victim);
    let replica_dir = wal_root
        .path()
        .join("shard-0")
        .join(format!("replica-{victim}"));
    corrupt_largest_wal_file(&replica_dir);

    // Restart must fail typed — and the replica must stay dead rather
    // than rejoin with partial state (it could re-promise a lower
    // ballot).  Shard 1's same-numbered replica restarts fine, so the
    // sweep reports exactly the corruption.
    let err = store.restart_replica(victim).expect_err("corrupt WAL");
    assert!(
        matches!(err, wtf::Error::WalCorrupt { shard: 0, .. }),
        "want WalCorrupt for shard 0, got {err:?}"
    );
    let stats = store.shard_stats();
    assert_eq!(stats[0].live_replicas, support::GROUP_REPLICAS - 1);
    assert_eq!(stats[1].live_replicas, support::GROUP_REPLICAS);

    // The degraded group still holds a 2/3 quorum: commits keep working.
    let keys2 = support::keys_on_distinct_groups(&store, Space::Inode, 2);
    let (result2, _) =
        support::run_scheduled_commit(&store, Vec::new(), &support::append_commit(&keys2));
    result2.expect("2/3 quorum still commits");
    support::assert_append_exactly_once(&store, &keys2, true);
}

#[test]
fn durable_truncated_wal_refuses_to_vote() {
    let wal_root = wtf::util::TempDir::new("wtf-durable-trunc").unwrap();
    let store = support::store_durable(2, wal_root.path());
    let keys = support::keys_on_distinct_groups(&store, Space::Region, 2);
    let (result, _) =
        support::run_scheduled_commit(&store, Vec::new(), &support::append_commit(&keys));
    result.expect("fault-free durable commit");

    let victim = support::GROUP_REPLICAS - 1;
    store.groups()[0].kill_replica(victim);
    // Chop the tail off the replica's segment: a mid-frame truncation,
    // as a crashed kernel write would leave it.
    let replica_dir = wal_root
        .path()
        .join("shard-0")
        .join(format!("replica-{victim}"));
    let path = largest_wal_file(&replica_dir);
    let bytes = std::fs::read(&path).unwrap();
    std::fs::write(&path, &bytes[..bytes.len() - 3]).unwrap();

    let err = store.restart_replica(victim).expect_err("truncated WAL");
    assert!(
        matches!(err, wtf::Error::WalCorrupt { shard: 0, .. }),
        "want WalCorrupt for shard 0, got {err:?}"
    );
    assert_eq!(
        store.shard_stats()[0].live_replicas,
        support::GROUP_REPLICAS - 1,
        "the damaged replica must stay dead"
    );
}
