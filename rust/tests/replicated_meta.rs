//! End-to-end coverage of the Paxos-replicated metadata plane: the full
//! client API running against 3-replica shard groups — POSIX ops,
//! read-lease locality, concurrent writer storms, and GC driving its
//! scan through the shard leaders.

use std::sync::Arc;
use wtf::cluster::Cluster;
use wtf::config::Config;

fn cluster() -> Cluster {
    Cluster::builder()
        .config(Config::replicated_test())
        .build()
        .unwrap()
}

#[test]
fn posix_surface_works_on_replicated_metadata() {
    let cl = cluster();
    let c = cl.client();
    c.mkdir("/dir").unwrap();
    let mut fd = c.create("/dir/file").unwrap();
    c.write(&mut fd, b"hello paxos").unwrap();
    assert_eq!(c.read_at(&fd, 0, 11).unwrap(), b"hello paxos");
    assert!(c.exists("/dir/file"));
    let entries = c.readdir("/dir").unwrap();
    assert_eq!(entries.len(), 1);
    assert_eq!(entries[0].0, "file");

    let r = cl.meta().replicated_store().unwrap();
    assert!(r.converged());
    // readdir/get were served from leaseholder-local state.
    assert!(r.lease_reads() > 0);
    // One election per touched shard group, no churn.
    assert!(r.elections() <= cl.config().meta_shards as u64);
}

#[test]
fn concurrent_writers_commute_on_replicated_metadata() {
    let cl = Arc::new(cluster());
    let c = cl.client();
    c.create("/storm").unwrap();

    let writers: Vec<_> = (0..6)
        .map(|w| {
            let cl = cl.clone();
            std::thread::spawn(move || {
                let c = cl.client();
                let fd = c.open("/storm").unwrap();
                for _ in 0..24 {
                    c.append_bytes(&fd, &[b'a' + w as u8; 16]).unwrap();
                }
            })
        })
        .collect();
    for w in writers {
        w.join().unwrap();
    }

    let fd = c.open("/storm").unwrap();
    let len = c.len(&fd).unwrap();
    assert_eq!(len, 6 * 24 * 16, "every append landed exactly once");
    let data = c.read_at(&fd, 0, len).unwrap();
    let mut counts = [0u32; 6];
    for rec in data.chunks(16) {
        assert!(rec.iter().all(|&b| b == rec[0]), "torn record");
        counts[(rec[0] - b'a') as usize] += 1;
    }
    assert!(counts.iter().all(|&n| n == 24), "{counts:?}");
    assert!(cl.meta().replicated_store().unwrap().converged());
}

#[test]
fn gc_scans_through_shard_leaders() {
    let cl = cluster();
    let c = cl.client();
    let f = c.create("/gc").unwrap();
    for i in 0..10u8 {
        c.write_at(f.inode(), 0, &[i; 1024]).unwrap();
    }
    c.compact_region(wtf::types::RegionId::new(f.inode(), 0))
        .unwrap();
    let resident_before = cl.storage_bytes_resident();
    cl.run_gc().unwrap(); // scan 1: records only
    let r = cl.run_gc().unwrap(); // scan 2: collects
    assert!(r.bytes_reclaimed >= 9 * 1024, "reclaimed {}", r.bytes_reclaimed);
    assert!(cl.storage_bytes_resident() < resident_before);
    assert_eq!(c.read_at(&f, 0, 4).unwrap(), vec![9u8; 4]);
}
