//! Randomized property tests (in-tree harness; proptest is unavailable
//! offline).  Each property runs many seeded cases; on failure the seed
//! is printed so the case replays deterministically.
//!
//! Invariants covered:
//! * filesystem equivalence to a byte-array model under random
//!   write/overwrite/append/punch/yank-paste/compact sequences
//! * compaction and spilling never change observable contents
//! * region metadata eof == max written end
//! * concat equals manual byte concatenation
//! * GC never touches live data under random workloads
//! * placement determinism + replica distinctness on random rings
//! * random 2PC fault schedules (kills + coordinator deaths at any
//!   protocol instant) always terminate with every participant agreeing
//!   on the decision record's outcome, with no duplicate applies

mod support;

use wtf::client::WtfClient;
use wtf::cluster::Cluster;
use wtf::config::Config;
use wtf::storage::Ring;
use wtf::types::{RegionId, Space};
use wtf::util::Rng;

fn cluster() -> Cluster {
    Cluster::builder().config(Config::test()).build().unwrap()
}

/// Run `f` for many seeds, reporting the failing seed.  The CI seed
/// matrix offsets the whole seed space through `WTF_TEST_SEED`, so
/// different matrix entries explore different cases; a failure prints
/// the EFFECTIVE seed, which replays the exact case deterministically
/// regardless of the env (`f` depends only on its argument).
fn forall(cases: u64, f: impl Fn(u64)) {
    let base = support::base_seed();
    for case in 0..cases {
        let seed = base.wrapping_mul(0x9E37_79B9) ^ case;
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(seed)));
        if let Err(e) = result {
            eprintln!(
                "PROPERTY FAILED at seed {seed} (WTF_TEST_SEED={base}, case {case})"
            );
            std::panic::resume_unwind(e);
        }
    }
}

/// Apply a random op to both WTF and a plain byte-array model.
fn random_op(
    c: &WtfClient,
    fd: &wtf::client::FileHandle,
    model: &mut Vec<u8>,
    rng: &mut Rng,
) {
    let file_size_cap = 12_000u64; // spans 3 test regions
    match rng.next_below(5) {
        // Random write.
        0 => {
            let off = rng.next_below(file_size_cap);
            let len = 1 + rng.next_below(600) as usize;
            let mut data = vec![0u8; len];
            rng.fill_bytes(&mut data);
            c.write_at(fd.inode(), off, &data).unwrap();
            if model.len() < off as usize + len {
                model.resize(off as usize + len, 0);
            }
            model[off as usize..off as usize + len].copy_from_slice(&data);
        }
        // Append.
        1 => {
            let len = 1 + rng.next_below(300) as usize;
            let mut data = vec![0u8; len];
            rng.fill_bytes(&mut data);
            c.append_bytes(fd, &data).unwrap();
            model.extend_from_slice(&data);
        }
        // Punch.
        2 => {
            if model.is_empty() {
                return;
            }
            let off = rng.next_below(model.len() as u64);
            let amount = 1 + rng.next_below(400);
            let mut h = fd.clone();
            h.offset = off;
            c.punch(&mut h, amount).unwrap();
            let end = (off + amount).min(model.len() as u64) as usize;
            model[off as usize..end].fill(0);
        }
        // yank+paste within the file (copy a range over another).
        3 => {
            if model.len() < 2 {
                return;
            }
            let src = rng.next_below(model.len() as u64 - 1);
            let len = 1 + rng.next_below((model.len() as u64 - src).min(300));
            let dst = rng.next_below(file_size_cap);
            let slice = c.yank_at(fd.inode(), src, len).unwrap();
            c.paste_at(fd.inode(), dst, &slice).unwrap();
            let bytes: Vec<u8> = model[src as usize..(src + len) as usize].to_vec();
            if model.len() < (dst + len) as usize {
                model.resize((dst + len) as usize, 0);
            }
            model[dst as usize..(dst + len) as usize].copy_from_slice(&bytes);
        }
        // Compact a random region (must be invisible).
        _ => {
            let region = rng.next_below(4) as u32;
            c.compact_region(RegionId::new(fd.inode(), region)).unwrap();
        }
    }
}

fn check_equals_model(c: &WtfClient, fd: &wtf::client::FileHandle, model: &[u8]) {
    let len = c.len(fd).unwrap();
    assert_eq!(len, model.len() as u64, "length mismatch");
    let data = c.read_at(fd, 0, len).unwrap();
    assert_eq!(data, model, "contents diverged from model");
}

#[test]
fn prop_filesystem_matches_byte_model() {
    forall(12, |seed| {
        let cl = cluster();
        let c = cl.client();
        let fd = c.create("/prop").unwrap();
        let mut model = Vec::new();
        let mut rng = Rng::new(seed * 7919 + 13);
        for _ in 0..40 {
            random_op(&c, &fd, &mut model, &mut rng);
        }
        check_equals_model(&c, &fd, &model);
    });
}

#[test]
fn prop_compaction_and_spill_preserve_contents() {
    forall(8, |seed| {
        let cl = cluster();
        let c = cl.client();
        let fd = c.create("/spillprop").unwrap();
        let mut model = Vec::new();
        let mut rng = Rng::new(seed ^ 0xABCD);
        for _ in 0..30 {
            random_op(&c, &fd, &mut model, &mut rng);
        }
        // Aggressive tier-2 spill of every region, then more ops.
        let meta = c.stat("/spillprop").unwrap();
        for r in 0..=meta.highest_region {
            c.spill_region(RegionId::new(fd.inode(), r)).unwrap();
        }
        check_equals_model(&c, &fd, &model);
        for _ in 0..15 {
            random_op(&c, &fd, &mut model, &mut rng);
        }
        check_equals_model(&c, &fd, &model);
    });
}

#[test]
fn prop_region_eof_matches_max_extent() {
    forall(10, |seed| {
        let cl = cluster();
        let c = cl.client();
        let fd = c.create("/eof").unwrap();
        let mut rng = Rng::new(seed + 31);
        let region_size = c.config().region_size;
        let mut max_end = 0u64;
        for _ in 0..20 {
            let off = rng.next_below(region_size - 700);
            let len = 1 + rng.next_below(600);
            let mut data = vec![0u8; len as usize];
            rng.fill_bytes(&mut data);
            c.write_at(fd.inode(), off, &data).unwrap();
            max_end = max_end.max(off + len);
        }
        let (region, _) = c.fetch_region_public(RegionId::new(fd.inode(), 0)).unwrap();
        assert_eq!(region.eof, max_end);
        assert_eq!(c.len(&fd).unwrap(), max_end);
    });
}

#[test]
fn prop_concat_equals_manual_concatenation() {
    forall(8, |seed| {
        let cl = cluster();
        let c = cl.client();
        let mut rng = Rng::new(seed * 3 + 5);
        let n = 2 + rng.next_below(4) as usize;
        let mut expected = Vec::new();
        let mut names = Vec::new();
        for i in 0..n {
            let len = 1 + rng.next_below(9000) as usize; // multi-region
            let mut data = vec![0u8; len];
            rng.fill_bytes(&mut data);
            let mut f = c.create(&format!("/part{i}")).unwrap();
            c.write(&mut f, &data).unwrap();
            expected.extend_from_slice(&data);
            names.push(format!("/part{i}"));
        }
        let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        let out = c.concat(&refs, "/all").unwrap();
        assert_eq!(c.len(&out).unwrap(), expected.len() as u64);
        assert_eq!(
            c.read_at(&out, 0, expected.len() as u64).unwrap(),
            expected
        );
    });
}

#[test]
fn prop_gc_never_harms_live_data() {
    forall(6, |seed| {
        let cl = cluster();
        let c = cl.client();
        let fd = c.create("/gcprop").unwrap();
        let mut model = Vec::new();
        let mut rng = Rng::new(seed ^ 0xFEED);
        for round in 0..3 {
            for _ in 0..12 {
                random_op(&c, &fd, &mut model, &mut rng);
            }
            c.compact_file(fd.inode(), 24).unwrap();
            cl.run_gc().unwrap();
            if round > 0 {
                // Second+ scans actually collect.
                cl.run_gc().unwrap();
            }
            check_equals_model(&c, &fd, &model);
        }
    });
}

#[test]
fn prop_random_2pc_fault_schedules_always_agree() {
    forall(12, |seed| {
        let mut rng = Rng::new(seed);
        let store = support::store_2pc(4);
        // A multi-shard append over 2–4 distinct groups, under a random
        // schedule of replica kills and front-end deaths.
        let nkeys = 2 + rng.next_below(3) as usize;
        let keys = support::keys_on_distinct_groups(&store, Space::Region, nkeys);
        let participants = support::participants_of(&store, &keys);
        let schedule = support::random_schedule(&mut rng, &participants);
        let commit = support::append_commit(&keys);
        let (result, txn) = support::run_scheduled_commit(&store, schedule, &commit);
        assert_ne!(txn, 0, "commit never reached staging");

        // Heal everything and resolve: every participant must agree
        // with the decision record (presumed abort when the front-end
        // died undecided), no intent pending, replicas converged.
        support::heal_all(&store);
        let decision = support::assert_all_or_nothing(&store, txn, &participants);
        if result.is_ok() {
            assert_eq!(
                decision,
                Some(true),
                "commit reported success without a durable commit decision"
            );
        }
        let committed = decision == Some(true);
        support::assert_append_exactly_once(&store, &keys, committed);

        // Crash-replay every follower and re-resolve: the outcome is
        // stable and still applied exactly once (txn-id dedup absorbs
        // the replayed prepare and decision entries).
        for idx in 1..support::GROUP_REPLICAS {
            store.kill_replica(idx);
        }
        support::heal_all(&store);
        assert_eq!(
            support::assert_all_or_nothing(&store, txn, &participants),
            decision,
            "outcome changed across crash-replay"
        );
        support::assert_append_exactly_once(&store, &keys, committed);
    });
}

#[test]
fn prop_ring_placement_properties() {
    forall(30, |seed| {
        let mut rng = Rng::new(seed);
        let n = 1 + rng.next_below(20) as u32;
        let servers: Vec<u32> = (0..n).collect();
        let ring = Ring::new(&servers, 16);
        for _ in 0..20 {
            let region = RegionId::new(rng.next_u64(), rng.next_below(100) as u32);
            let want = 1 + rng.next_below(5) as usize;
            let got = ring.servers_for(region, want);
            // Deterministic.
            assert_eq!(got, ring.servers_for(region, want));
            // Correct count (capped at cluster size) and distinct.
            assert_eq!(got.len(), want.min(n as usize));
            let mut d = got.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), got.len());
        }
    });
}
