//! Cross-module integration tests: full clusters, both filesystems, the
//! sort application end-to-end, and the PJRT runtime executing the
//! AOT-compiled Pallas kernels (requires `make artifacts`).

use wtf::baseline::{HdfsCluster, HdfsConfig};
use wtf::client::SeekFrom;
use wtf::cluster::Cluster;
use wtf::config::Config;
use wtf::mapreduce::bulkfs::BulkFs;
use wtf::mapreduce::records::{bucket_bounds, generate_records, is_sorted};
use wtf::mapreduce::{sort_conventional, sort_slicing, SortJob};
use wtf::net::LinkModel;
use wtf::runtime::{NativeCompute, SortCompute, XlaRuntime};
use wtf::util::Rng;

fn small() -> Cluster {
    Cluster::builder().config(Config::test()).build().unwrap()
}

fn job() -> SortJob {
    let mut j = SortJob::new(32, 4);
    j.chunk_records = 64;
    j
}

// ---------------------------------------------------------------- WTF e2e

#[test]
fn filesystem_end_to_end() {
    let cluster = small();
    let c = cluster.client();
    c.mkdir("/data").unwrap();
    let mut fd = c.create("/data/f").unwrap();
    let mut payload = vec![0u8; 20_000]; // spans several 4 KB regions
    Rng::new(1).fill_bytes(&mut payload);
    c.write(&mut fd, &payload).unwrap();
    // Random overwrite in the middle.
    c.write_at(fd.inode(), 9_000, b"OVERWRITE").unwrap();
    let mut expect = payload.clone();
    expect[9_000..9_009].copy_from_slice(b"OVERWRITE");
    assert_eq!(c.read_at(&fd, 0, 20_000).unwrap(), expect);
    // Compaction changes nothing observable.
    c.compact_file(fd.inode(), usize::MAX).unwrap();
    assert_eq!(c.read_at(&fd, 0, 20_000).unwrap(), expect);
    // Copy + concat share bytes; reads still correct afterwards.
    c.copy("/data/f", "/data/g").unwrap();
    c.concat(&["/data/f", "/data/g"], "/data/both").unwrap();
    assert_eq!(c.stat("/data/both").unwrap().len, 40_000);
    c.unlink("/data/f").unwrap();
    let both = c.open("/data/both").unwrap();
    assert_eq!(&c.read_at(&both, 0, 9).unwrap()[..], &expect[..9]);
}

#[test]
fn transaction_across_files_with_concurrent_conflict() {
    let cluster = small();
    let c = cluster.client();
    let mut src = c.create("/ledger").unwrap();
    c.write(&mut src, b"100").unwrap();

    // Transfer: read /ledger, write /audit, append marker to /ledger.
    let mut t = c.begin();
    let ledger = t.open("/ledger").unwrap();
    let audit = t.create("/audit").unwrap();
    let balance = t.read(ledger, 3).unwrap();
    t.write(audit, &balance).unwrap();
    t.seek(ledger, SeekFrom::End(0)).unwrap();
    t.write(ledger, b"#").unwrap();

    // A concurrent append moves the EOF but does NOT touch what we read:
    // the transaction must retry internally and commit.
    c.append_bytes(&src, b"???").unwrap();
    t.commit().unwrap();

    let audit = c.open("/audit").unwrap();
    assert_eq!(c.read_at(&audit, 0, 3).unwrap(), b"100");
    // Marker landed after the concurrent append.
    let ledger = c.open("/ledger").unwrap();
    let len = c.len(&ledger).unwrap();
    assert_eq!(c.read_at(&ledger, len - 1, 1).unwrap(), b"#");
}

// ------------------------------------------------------------- sort + XLA

/// The artifacts directory produced by `make artifacts` — only usable
/// when the PJRT backend is compiled in.
fn artifacts_available() -> bool {
    cfg!(feature = "xla-runtime") && XlaRuntime::default_dir().join("manifest.json").exists()
}

#[test]
fn xla_kernels_match_native_oracle() {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts/ missing; run `make artifacts`");
        return;
    }
    let rt = XlaRuntime::load_default().unwrap();
    let native = NativeCompute;
    let mut rng = Rng::new(0xA11CE);
    for &n in &[100usize, 1000, 5000, 16384, 20000] {
        let keys: Vec<i32> = (0..n)
            .map(|_| (rng.next_u64() & 0x7fffffff) as i32)
            .collect();
        let bounds = bucket_bounds(16);
        let (xi, xh) = rt.partition(&keys, &bounds).unwrap();
        let (ni, nh) = native.partition(&keys, &bounds).unwrap();
        assert_eq!(xi, ni, "partition ids diverge at n={n}");
        assert_eq!(xh, nh, "histogram diverges at n={n}");
    }
    for &n in &[1usize, 7, 512, 1024, 1500, 4096, 5000] {
        let keys: Vec<i32> = (0..n).map(|_| (rng.next_u64() & 0xffff) as i32).collect();
        let xp = rt.argsort(&keys).unwrap();
        let np = native.argsort(&keys).unwrap();
        assert_eq!(xp, np, "argsort diverges at n={n} (stability included)");
    }
}

#[test]
fn slicing_sort_with_xla_kernels_end_to_end() {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts/ missing; run `make artifacts`");
        return;
    }
    let rt = XlaRuntime::load_default().unwrap();
    let cluster = small();
    let c = cluster.client();
    let data = generate_records(256, job().fmt, 2026);
    c.write_file("/input", &data).unwrap();
    let written_before = cluster.storage_bytes_written();
    let stats = sort_slicing(&c, &rt, "/input", "/sorted", &job()).unwrap();
    assert_eq!(stats.records, 256);
    assert_eq!(
        cluster.storage_bytes_written(),
        written_before,
        "slicing sort writes zero bytes (Table 2)"
    );
    let out = c.read_range("/sorted", 0, data.len() as u64).unwrap();
    assert_eq!(out.len(), data.len());
    assert!(is_sorted(&out, job().fmt));
    // Identical output to the native-compute run.
    sort_slicing(&c, &NativeCompute, "/input", "/sorted-native", &job()).unwrap();
    let native_out = c
        .read_range("/sorted-native", 0, data.len() as u64)
        .unwrap();
    assert_eq!(out, native_out);
}

#[test]
fn sorters_agree_across_filesystems() {
    let data = generate_records(192, job().fmt, 5);

    let wtf_cluster = small();
    let wc = wtf_cluster.client();
    wc.write_file("/in", &data).unwrap();
    sort_conventional(&wc, &NativeCompute, "/in", "/out", &job()).unwrap();
    let wtf_out = wc.read_range("/out", 0, data.len() as u64).unwrap();

    let hdfs_cluster =
        HdfsCluster::new(HdfsConfig::test(), None, LinkModel::instant()).unwrap();
    let hc = hdfs_cluster.client();
    hc.write_file("/in", &data).unwrap();
    sort_conventional(&hc, &NativeCompute, "/in", "/out", &job()).unwrap();
    let hdfs_out = hc.read_range("/out", 0, data.len() as u64).unwrap();

    assert_eq!(wtf_out, hdfs_out);
    assert!(is_sorted(&wtf_out, job().fmt));
}

// -------------------------------------------------------- Table 2 shapes

#[test]
fn table2_io_shape_holds_at_test_scale() {
    let data = generate_records(256, job().fmt, 31);
    let size = data.len() as u64;

    // Conventional on WTF: bucketing R+W, sorting R+W, merging R+W.
    let cluster = small();
    let c = cluster.client();
    c.write_file("/in", &data).unwrap();
    let (r0, w0) = (
        cluster.storage_bytes_read(),
        cluster.storage_bytes_written(),
    );
    sort_conventional(&c, &NativeCompute, "/in", "/out", &job()).unwrap();
    let conv_read = cluster.storage_bytes_read() - r0;
    let conv_written = cluster.storage_bytes_written() - w0;
    // R = 3x input (bucketing + sorting + merging each read it once).
    assert_eq!(conv_read, 3 * size, "conventional reads 3x the input");
    // W >= 3x input (every stage writes; replication multiplies).
    assert!(conv_written >= 3 * size, "conventional writes >= 3x");

    // Slicing: R = 2x, W = 0.
    let cluster2 = small();
    let c2 = cluster2.client();
    c2.write_file("/in", &data).unwrap();
    let (r1, w1) = (
        cluster2.storage_bytes_read(),
        cluster2.storage_bytes_written(),
    );
    sort_slicing(&c2, &NativeCompute, "/in", "/out", &job()).unwrap();
    assert_eq!(cluster2.storage_bytes_read() - r1, 2 * size);
    assert_eq!(cluster2.storage_bytes_written() - w1, 0);
}
