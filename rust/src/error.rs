//! Error taxonomy for the whole stack.
//!
//! The split mirrors the paper's failure model: metadata transactions can
//! *conflict* (retryable by the client-side retry layer, §2.6) or fail a
//! *conditional append* (the EOF fast-path of §2.5, also retryable with a
//! fallback); everything else is an environmental or usage error.
//!
//! `Display`/`Error` are implemented by hand: the offline build carries
//! no third-party crates (no `thiserror`).

use crate::types::{ServerId, Space};
use std::fmt;
use std::time::Duration;

/// Library-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Library-wide error type.
#[derive(Debug)]
pub enum Error {
    /// A metadata transaction observed a version change in its read set.
    /// The WTF retry layer replays the op log on this error; it only
    /// surfaces to applications when replay observes a different outcome.
    TxnConflict { space: Space, key: String },

    /// A conditional EOF-relative append exceeded its region's capacity;
    /// the writer must fall back to an explicit-offset write (§2.5).
    CondAppendFailed { eof: u64, len: u64, cap: u64 },

    /// A transaction replay observed an application-visible divergence and
    /// must abort to the application (§2.6).
    TxnAborted { reason: String },

    /// Too many consecutive conflict-retries; the transaction gave up.
    RetriesExhausted { attempts: u32 },

    /// An end-to-end RPC deadline (`Config::rpc_deadline`) expired while
    /// `op` was still retrying, or the network (turbulence layer) ate an
    /// envelope outright.  The outcome of the last attempt is UNKNOWN:
    /// commit paths must treat this exactly like `NoQuorum`
    /// (indeterminate — see [`Error::is_indeterminate`]); pure reads may
    /// retry freely.
    Timeout { op: &'static str, elapsed: Duration },

    NotFound(String),

    AlreadyExists(String),

    IsDirectory(String),

    NotADirectory(String),

    DirectoryNotEmpty(String),

    InvalidArgument(String),

    Unsupported(String),

    ServerUnavailable(ServerId),

    SliceNotFound {
        server: ServerId,
        backing: u32,
        offset: u64,
        len: u64,
    },

    CorruptMetadata(String),

    NoQuorum { alive: usize, total: usize },

    /// The addressed metadata replica is not the current leaseholder of
    /// its shard group; `hint` names the lowest live replica, the next
    /// election's candidate.  Clients rediscover the leader and retry.
    NotLeader { shard: u32, hint: Option<u32> },

    /// A metadata-plane replica crashed (or its handler panicked) while
    /// serving.  Surfaced as a typed error so a dead replica merely
    /// degrades its group's quorum instead of poisoning the caller.
    ReplicaLost { shard: u32, replica: u32 },

    /// A replica's on-disk write-ahead log failed integrity checks on
    /// restart (truncated frame, CRC mismatch, foreign marker, missing
    /// checkpoint).  The replica must refuse to vote — rejoining with
    /// partial state could re-promise a lower ballot (equivocation) —
    /// so it stays dead and merely degrades its group's quorum.
    WalCorrupt {
        shard: u32,
        replica: u32,
        detail: String,
    },

    Artifact(String),

    Xla(String),

    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::TxnConflict { space, key } => {
                write!(f, "metadata transaction conflict on {space:?}:{key}")
            }
            Error::CondAppendFailed { eof, len, cap } => write!(
                f,
                "conditional append out of region bounds (eof={eof}, len={len}, cap={cap})"
            ),
            Error::TxnAborted { reason } => write!(f, "transaction aborted: {reason}"),
            Error::RetriesExhausted { attempts } => write!(
                f,
                "transaction retry budget exhausted after {attempts} attempts"
            ),
            Error::Timeout { op, elapsed } => write!(
                f,
                "{op} timed out after {elapsed:?} (outcome unknown)"
            ),
            Error::NotFound(p) => write!(f, "no such file or directory: {p}"),
            Error::AlreadyExists(p) => write!(f, "file exists: {p}"),
            Error::IsDirectory(p) => write!(f, "is a directory: {p}"),
            Error::NotADirectory(p) => write!(f, "not a directory: {p}"),
            Error::DirectoryNotEmpty(p) => write!(f, "directory not empty: {p}"),
            Error::InvalidArgument(m) => write!(f, "invalid argument: {m}"),
            Error::Unsupported(m) => write!(f, "operation not supported: {m}"),
            Error::ServerUnavailable(id) => write!(f, "storage server {id} unavailable"),
            Error::SliceNotFound {
                server,
                backing,
                offset,
                len,
            } => write!(
                f,
                "slice not found on server {server}: backing={backing} off={offset} len={len}"
            ),
            Error::CorruptMetadata(m) => write!(f, "corrupt metadata: {m}"),
            Error::NoQuorum { alive, total } => write!(
                f,
                "coordinator has no quorum ({alive}/{total} replicas alive)"
            ),
            Error::NotLeader { shard, hint } => match hint {
                Some(h) => write!(
                    f,
                    "not the leader of metadata shard {shard} (try replica {h})"
                ),
                None => write!(f, "metadata shard {shard} has no live leader"),
            },
            Error::ReplicaLost { shard, replica } => write!(
                f,
                "metadata replica {replica} of shard {shard} lost mid-request"
            ),
            Error::WalCorrupt {
                shard,
                replica,
                detail,
            } => write!(
                f,
                "write-ahead log of replica {replica} (shard {shard}) is corrupt, \
                 refusing to vote: {detail}"
            ),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Xla(m) => write!(f, "xla runtime error: {m}"),
            Error::Io(e) => e.fmt(f),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    /// True when the WTF retry layer may transparently retry the enclosing
    /// transaction (the state of the system was left unchanged).
    pub fn is_retryable(&self) -> bool {
        matches!(
            self,
            Error::TxnConflict { .. } | Error::CondAppendFailed { .. }
        )
    }

    /// True when the outcome of the attempted operation is UNKNOWN: the
    /// request may have landed — and may yet resolve to committed after
    /// a heal — even though the caller saw an error.  A commit path
    /// seeing one of these must NOT blindly retry under a fresh
    /// transaction id (double-apply hazard) and must drop any cached
    /// state the in-flight mutation covers.  Every indeterminate-outcome
    /// site (commit_txn's cache drop, the write-behind deferred failure,
    /// 2PC resolution) classifies through this one helper.
    pub fn is_indeterminate(&self) -> bool {
        matches!(
            self,
            Error::Timeout { .. }
                | Error::NoQuorum { .. }
                | Error::ReplicaLost { .. }
                | Error::RetriesExhausted { .. }
        )
    }
}

#[cfg(feature = "xla-runtime")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indeterminate_is_exactly_the_unknown_outcome_class() {
        let yes = [
            Error::Timeout {
                op: "commit",
                elapsed: Duration::from_millis(5),
            },
            Error::NoQuorum { alive: 1, total: 3 },
            Error::ReplicaLost { shard: 0, replica: 2 },
            Error::RetriesExhausted { attempts: 16 },
        ];
        for e in &yes {
            assert!(e.is_indeterminate(), "{e} should be indeterminate");
            assert!(!e.is_retryable(), "{e} must not be blindly retried");
        }
        let no = [
            Error::TxnConflict {
                space: Space::Inode,
                key: "k".into(),
            },
            Error::TxnAborted { reason: "r".into() },
            Error::NotLeader {
                shard: 0,
                hint: Some(1),
            },
            Error::NotFound("p".into()),
        ];
        for e in &no {
            assert!(!e.is_indeterminate(), "{e} has a determinate outcome");
        }
    }
}
