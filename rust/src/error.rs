//! Error taxonomy for the whole stack.
//!
//! The split mirrors the paper's failure model: metadata transactions can
//! *conflict* (retryable by the client-side retry layer, §2.6) or fail a
//! *conditional append* (the EOF fast-path of §2.5, also retryable with a
//! fallback); everything else is an environmental or usage error.

use crate::types::{ServerId, Space};

/// Library-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Library-wide error type.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// A metadata transaction observed a version change in its read set.
    /// The WTF retry layer replays the op log on this error; it only
    /// surfaces to applications when replay observes a different outcome.
    #[error("metadata transaction conflict on {space:?}:{key}")]
    TxnConflict { space: Space, key: String },

    /// A conditional EOF-relative append exceeded its region's capacity;
    /// the writer must fall back to an explicit-offset write (§2.5).
    #[error("conditional append out of region bounds (eof={eof}, len={len}, cap={cap})")]
    CondAppendFailed { eof: u64, len: u64, cap: u64 },

    /// A transaction replay observed an application-visible divergence and
    /// must abort to the application (§2.6).
    #[error("transaction aborted: {reason}")]
    TxnAborted { reason: String },

    /// Too many consecutive conflict-retries; the transaction gave up.
    #[error("transaction retry budget exhausted after {attempts} attempts")]
    RetriesExhausted { attempts: u32 },

    #[error("no such file or directory: {0}")]
    NotFound(String),

    #[error("file exists: {0}")]
    AlreadyExists(String),

    #[error("is a directory: {0}")]
    IsDirectory(String),

    #[error("not a directory: {0}")]
    NotADirectory(String),

    #[error("directory not empty: {0}")]
    DirectoryNotEmpty(String),

    #[error("invalid argument: {0}")]
    InvalidArgument(String),

    #[error("operation not supported: {0}")]
    Unsupported(String),

    #[error("storage server {0} unavailable")]
    ServerUnavailable(ServerId),

    #[error("slice not found on server {server}: backing={backing} off={offset} len={len}")]
    SliceNotFound {
        server: ServerId,
        backing: u32,
        offset: u64,
        len: u64,
    },

    #[error("corrupt metadata: {0}")]
    CorruptMetadata(String),

    #[error("coordinator has no quorum ({alive}/{total} replicas alive)")]
    NoQuorum { alive: usize, total: usize },

    #[error("artifact error: {0}")]
    Artifact(String),

    #[error("xla runtime error: {0}")]
    Xla(String),

    #[error(transparent)]
    Io(#[from] std::io::Error),
}

impl Error {
    /// True when the WTF retry layer may transparently retry the enclosing
    /// transaction (the state of the system was left unchanged).
    pub fn is_retryable(&self) -> bool {
        matches!(
            self,
            Error::TxnConflict { .. } | Error::CondAppendFailed { .. }
        )
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}
