//! "hyperdex-lite": the transactional metadata store WTF builds on.
//!
//! The paper stores all filesystem metadata in HyperDex with Warp
//! transactions (§2.1): linearizable multi-key transactions spanning
//! independent schemas, atomic list reads/appends, and conditional
//! operations.  This module reproduces the properties WTF relies on:
//!
//! * **Versioned gets** — every read returns `(value, version)`; a commit
//!   validates its read set against current versions (optimistic
//!   concurrency, like Warp).
//! * **Multi-key atomic commit** — all shards touched by a transaction are
//!   locked in canonical order; validation + apply are all-or-nothing.
//! * **Blind and conditional ops** — region-list appends, link-count
//!   deltas, and monotone length updates never conflict; EOF-relative
//!   appends validate their region-capacity condition at apply time
//!   (§2.5), and compaction swaps are CAS on the region version (§2.8).
//! * **Chain replication** — each shard is an f+1 replica chain
//!   (HyperDex's value-dependent chaining, §2.9); writes flow to every
//!   live replica, reads are served from the tail, and a recovered
//!   replica re-syncs from its neighbor.
//! * **Paxos-replicated shard groups** — alternatively (selected by
//!   `Config::meta_paxos`), each shard runs as a 3-replica Paxos group
//!   over the transport: leader leases serve reads locally, failover
//!   preserves every quorum-accepted commit, apply is deduplicated by
//!   transaction id, and a rejoining replica rebuilds by deterministic
//!   log replay ([`ShardGroup`], [`ReplicatedMetaStore`]).
//! * **Cross-group atomic commit** — with `Config::meta_2pc`, a
//!   multi-shard commit runs an intent-logged two-phase commit over
//!   the replicated logs: durable `Prepare` intents in every touched
//!   group, a decision record in the lowest-numbered participant
//!   group, and exactly-once phase-2 apply; leaseholder reads treat
//!   intent-locked keys as unreadable until the intent resolves, so a
//!   half-committed create/unlink is never observable (see
//!   [`ReplicatedMetaStore`] module docs for the protocol and its
//!   invariants, and [`CommitPhase`] for the fault-schedule surface).
//!
//! [`MetaStore`] is the raw sharded store; [`MetaService`] layers the
//! simulated transaction latency floor and metrics on top; [`MetaTxn`] is
//! the builder the WTF client uses to accumulate a read set + op list.

mod group;
mod ops;
mod replicated;
mod shard;
mod store;
mod txn;
pub(crate) mod wal;

pub use group::{EntryKind, GroupReplica, LogEntry, ShardGroup};
pub use ops::{MetaOp, OpOutcome};
pub use replicated::{CommitPhase, FaultAction, FaultHook, ReplicatedMetaStore};
pub use shard::{KvState, Shard, ShardStats};
pub use store::{Commit, MetaService, MetaSnapshot, MetaStore};
pub use txn::{MetaTxn, TxnReadCache};
pub use wal::{Checkpoint, Recovered, ReplicaWal, WalRecord, WalSetup};
