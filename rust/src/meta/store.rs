//! The sharded metadata store and its multi-key atomic commit.
//!
//! A [`Commit`] carries the transaction's read set (key → version
//! observed) and its ordered op list.  Commit locks every touched shard
//! in canonical order (no deadlocks), validates the read set and every
//! conditional op against a staged overlay (so ops in one transaction
//! observe their predecessors), and applies all-or-nothing.  This mirrors
//! the guarantee WTF takes from HyperDex Warp: one multi-key transaction
//! of gets + appends + conditional puts, linearizable, spanning schemas.

use super::ops::{self, MetaOp, OpOutcome};
use super::shard::{Shard, ShardInner, ShardStats};
use crate::error::{Error, Result};
use crate::metrics::Metrics;
use crate::types::{Key, Space, Value};
use std::sync::MutexGuard;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// A metadata transaction ready to commit.
#[derive(Clone, Debug, Default)]
pub struct Commit {
    /// `(key, version observed)` — version 0 means "observed absent and
    /// never-mutated".
    pub reads: Vec<(Key, u64)>,
    /// Mutations, applied in order.
    pub ops: Vec<MetaOp>,
}

impl Commit {
    pub fn is_empty(&self) -> bool {
        self.reads.is_empty() && self.ops.is_empty()
    }
}

/// The sharded, chain-replicated metadata store.
#[derive(Debug)]
pub struct MetaStore {
    shards: Vec<Shard>,
    next_inode: AtomicU64,
}

impl MetaStore {
    pub fn new(shards: u32, replicas_per_shard: u8) -> Self {
        assert!(shards >= 1);
        MetaStore {
            shards: (0..shards)
                .map(|_| Shard::new(replicas_per_shard.max(1) as usize))
                .collect(),
            // inode 1 is reserved for the root directory
            next_inode: AtomicU64::new(2),
        }
    }

    /// Stable FNV-1a shard placement (shared with the replicated store).
    fn shard_of(&self, key: &Key) -> usize {
        super::shard::shard_of_key(key, self.shards.len())
    }

    /// Versioned point read (linearizable: served by the shard tail).
    pub fn get(&self, key: &Key) -> Option<(Value, u64)> {
        let g = self.shards[self.shard_of(key)].lock();
        let v = g.version(key);
        g.get(key).map(|val| (val.clone(), v))
    }

    /// Value AND version in one shard-locked read (absent keys still
    /// report their version — read sets need the version of absence).
    pub fn entry(&self, key: &Key) -> (Option<Value>, u64) {
        let g = self.shards[self.shard_of(key)].lock();
        (g.get(key).cloned(), g.version(key))
    }

    /// Version of `key` without copying the value.
    pub fn version(&self, key: &Key) -> u64 {
        self.shards[self.shard_of(key)].lock().version(key)
    }

    /// Allocate a fresh inode id.  Ids allocated by aborted transactions
    /// are simply never used — the allocator needs no transactionality.
    pub fn alloc_inode_id(&self) -> u64 {
        self.next_inode.fetch_add(1, Ordering::Relaxed)
    }

    /// Atomically commit `commit`.  On success, returns one
    /// [`OpOutcome`] per op.  On failure nothing is mutated; the error
    /// distinguishes read-set conflicts (retryable by the WTF retry
    /// layer) from semantic failures (surfaced to the application).
    pub fn commit(&self, commit: &Commit) -> Result<Vec<OpOutcome>> {
        // 1. Canonically ordered shard lock acquisition.
        let mut shard_ids: Vec<usize> = commit
            .reads
            .iter()
            .map(|(k, _)| self.shard_of(k))
            .chain(
                commit
                    .ops
                    .iter()
                    .flat_map(|op| op.keys().into_iter().map(|k| self.shard_of(k))),
            )
            .collect();
        shard_ids.sort_unstable();
        shard_ids.dedup();
        let mut guards: HashMap<usize, MutexGuard<'_, ShardInner>> = HashMap::new();
        for sid in &shard_ids {
            guards.insert(*sid, self.shards[*sid].lock());
        }

        // 2. Validate the read set.
        for (key, observed) in &commit.reads {
            let g = &guards[&self.shard_of(key)];
            if g.version(key) != *observed {
                return Err(Error::TxnConflict {
                    space: key.space,
                    key: key.key.clone(),
                });
            }
        }

        // 3. Stage ops against an overlay so each op sees its
        //    predecessors; validation failures abort with nothing applied
        //    (the shared staging of [`ops::stage`]).
        let committed = |k: &Key| {
            let g = &guards[&self.shard_of(k)];
            Ok((g.get(k).cloned(), g.version(k)))
        };
        let (overlay, outcomes) = ops::stage(&commit.ops, &committed, |_, _| {})?;

        // 4. Apply the overlay; one version bump per mutated key.
        for (key, value) in overlay {
            guards
                .get_mut(&self.shard_of(&key))
                .expect("shard locked")
                .set(&key, value);
        }
        Ok(outcomes)
    }

    /// Full scan of one space (GC uses this to build the in-use slice
    /// lists, §2.8).  Not transactional: GC tolerates staleness by design
    /// (two-consecutive-scan rule).
    pub fn scan_space(&self, space: Space) -> Vec<(Key, Value)> {
        let mut out = Vec::new();
        for shard in &self.shards {
            let g = shard.lock();
            for (k, v) in g.iter_tail() {
                if k.space == space {
                    out.push((k.clone(), v.clone()));
                }
            }
        }
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    /// Kill replica `idx` of every shard (failure injection).
    pub fn kill_replica(&self, idx: usize) {
        for s in &self.shards {
            s.kill_replica(idx);
        }
    }

    /// Recover replica `idx` of every shard.
    pub fn recover_replica(&self, idx: usize) {
        for s in &self.shards {
            s.recover_replica(idx);
        }
    }

    pub fn shard_stats(&self) -> Vec<ShardStats> {
        self.shards.iter().map(|s| s.stats()).collect()
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }
}

/// A read-only snapshot view of the metadata — what GC scans.  Served by
/// the raw chain store (unit tests) or the deployed [`MetaService`],
/// whichever backend it runs.  Fallible on purpose: GC decides slice
/// liveness from these scans, so an unreadable shard must abort the
/// round, never read as empty.
pub trait MetaSnapshot {
    fn scan_space(&self, space: Space) -> Result<Vec<(Key, Value)>>;
}

impl MetaSnapshot for MetaStore {
    fn scan_space(&self, space: Space) -> Result<Vec<(Key, Value)>> {
        Ok(MetaStore::scan_space(self, space))
    }
}

impl MetaSnapshot for MetaService {
    fn scan_space(&self, space: Space) -> Result<Vec<(Key, Value)>> {
        MetaService::scan_space(self, space)
    }
}

/// Which engine holds the metadata: the in-process chain-replicated
/// store, or the Paxos-replicated shard groups.
#[derive(Debug)]
enum MetaBackend {
    Chain(MetaStore),
    Paxos(super::ReplicatedMetaStore),
}

/// The metadata engine plus the deployment concerns: the simulated
/// transaction latency floor (the paper measures ~3 ms per HyperDex
/// transaction) and metrics.  All client traffic goes through this type.
///
/// Direct method calls (`get_checked`, `commit`, …) perform blocking
/// leader discovery on the replicated backend; the transport envelope
/// path ([`crate::net::Handler`]) does not, surfacing
/// [`Error::NotLeader`] for the client's retry layer to handle.
#[derive(Debug)]
pub struct MetaService {
    backend: MetaBackend,
    txn_floor: Duration,
    metrics: Metrics,
}

impl MetaService {
    /// A service over the chain-replicated store.
    pub fn new(store: MetaStore, txn_floor: Duration, metrics: Metrics) -> Self {
        MetaService {
            backend: MetaBackend::Chain(store),
            txn_floor,
            metrics,
        }
    }

    /// A service over Paxos-replicated shard groups.
    pub fn replicated(
        store: super::ReplicatedMetaStore,
        txn_floor: Duration,
        metrics: Metrics,
    ) -> Self {
        MetaService {
            backend: MetaBackend::Paxos(store),
            txn_floor,
            metrics,
        }
    }

    /// The replicated backend, when this service runs one (tests, fault
    /// injection, leader introspection).
    pub fn replicated_store(&self) -> Option<&super::ReplicatedMetaStore> {
        match &self.backend {
            MetaBackend::Chain(_) => None,
            MetaBackend::Paxos(r) => Some(r),
        }
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Envelope-path read: no blocking leader discovery — a leaderless
    /// shard surfaces [`Error::NotLeader`] for the client to handle.
    /// Returns value AND version in one atomic view read (absent keys
    /// still report their version).
    pub fn try_get(&self, key: &Key) -> Result<(Option<Value>, u64)> {
        match &self.backend {
            MetaBackend::Chain(s) => Ok(s.entry(key)),
            MetaBackend::Paxos(r) => r.entry(key, false),
        }
    }

    /// Auto-electing versioned read.  There is deliberately NO
    /// infallible `get` on this service: an unreadable replicated shard
    /// must surface as an error, never read as "absent".
    pub fn get_checked(&self, key: &Key) -> Result<(Option<Value>, u64)> {
        match &self.backend {
            MetaBackend::Chain(s) => Ok(s.entry(key)),
            MetaBackend::Paxos(r) => r.entry(key, true),
        }
    }

    pub fn alloc_inode_id(&self) -> u64 {
        match &self.backend {
            MetaBackend::Chain(s) => s.alloc_inode_id(),
            MetaBackend::Paxos(r) => r.alloc_inode_id(),
        }
    }

    /// Commit with the latency floor charged once per transaction.
    pub fn commit(&self, commit: &Commit) -> Result<Vec<OpOutcome>> {
        self.commit_with(commit, true)
    }

    fn commit_with(&self, commit: &Commit, auto_elect: bool) -> Result<Vec<OpOutcome>> {
        if self.txn_floor > Duration::ZERO {
            std::thread::sleep(self.txn_floor);
        }
        self.metrics.add_meta_txns(1);
        let r = match &self.backend {
            MetaBackend::Chain(s) => s.commit(commit),
            MetaBackend::Paxos(rs) => rs.commit(commit, auto_elect),
        };
        if matches!(r, Err(Error::TxnConflict { .. })) {
            self.metrics.add_meta_conflicts(1);
        }
        r
    }

    /// Full scan of one space (GC's view; not transactional).  Errors —
    /// rather than reading as empty — when a replicated shard cannot
    /// serve (no leader electable / quorum gone).
    pub fn scan_space(&self, space: Space) -> Result<Vec<(Key, Value)>> {
        match &self.backend {
            MetaBackend::Chain(s) => Ok(s.scan_space(space)),
            MetaBackend::Paxos(r) => r.scan_space(space),
        }
    }

    /// Kill replica `idx` of every shard (chain member or group member).
    pub fn kill_replica(&self, idx: usize) {
        match &self.backend {
            MetaBackend::Chain(s) => s.kill_replica(idx),
            MetaBackend::Paxos(r) => r.kill_replica(idx),
        }
    }

    /// Recover replica `idx` of every shard (chain resync, or Paxos log
    /// replay; best-effort when a group has no quorum to replay from).
    /// On the Paxos backend, recovery also sweeps for orphaned 2PC
    /// intents the rejoining replica replayed back in — each resolves
    /// through its coordinator's decision record (presumed abort when
    /// none is recorded), so a quorum-loss mid-commit leaves no group
    /// permanently holding a phantom entry.
    pub fn recover_replica(&self, idx: usize) {
        match &self.backend {
            MetaBackend::Chain(s) => s.recover_replica(idx),
            MetaBackend::Paxos(r) => {
                let _ = r.recover_replica(idx);
                let _ = r.resolve_orphans();
            }
        }
    }

    /// Restart replica `idx` of every group from its write-ahead log:
    /// the in-memory incarnation — modeled acceptor storage included —
    /// is torn down and the on-disk WAL directory alone rebuilds it.
    /// Paxos backend only (the chain store has no WAL); errors are typed
    /// and surfaced — a replica whose WAL fails integrity checks refuses
    /// to vote and stays dead, degrading its group's quorum.  The same
    /// orphaned-intent sweep as [`Self::recover_replica`] runs after.
    pub fn restart_replica(&self, idx: usize) -> Result<()> {
        match &self.backend {
            MetaBackend::Chain(_) => Err(Error::Unsupported(
                "restart_replica needs the durable Paxos backend".into(),
            )),
            MetaBackend::Paxos(r) => {
                let out = r.restart_replica(idx);
                let _ = r.resolve_orphans();
                out
            }
        }
    }

    /// Blocking leader rediscovery for `shard` — the client's follow-up
    /// to [`Error::NotLeader`].  No-op on the chain backend.
    pub fn heal(&self, shard: u32) {
        if let MetaBackend::Paxos(r) = &self.backend {
            let _ = r.heal(shard);
        }
    }

    pub fn shard_stats(&self) -> Vec<ShardStats> {
        match &self.backend {
            MetaBackend::Chain(s) => s.shard_stats(),
            MetaBackend::Paxos(r) => r.shard_stats(),
        }
    }
}

/// The transport server side of the metadata plane: commits and
/// versioned point reads arrive as envelopes, same as storage traffic.
/// (The metadata plane's cost model is the transaction floor above, so
/// these envelopes report no wire bytes to the data-plane link.)
///
/// No fail-stop wrapper here on purpose: the service front-end is not a
/// quorum member — a panic in it (or the chain store) is a bug that
/// should stay loud on the caller.  The per-replica conversion to
/// [`Error::ReplicaLost`] lives on [`crate::meta::GroupReplica`], where
/// real (shard, replica) ids exist and a crash genuinely just degrades
/// a quorum.
impl crate::net::Handler for MetaService {
    fn serve(&self, req: &crate::net::Request) -> Result<crate::net::Response> {
        use crate::net::{Request, Response};
        match req {
            Request::MetaCommit { commit } => {
                Ok(Response::Outcomes(self.commit_with(commit, false)?))
            }
            Request::MetaGet { key } => {
                let (value, version) = self.try_get(key)?;
                Ok(Response::MetaValue { value, version })
            }
            other => Err(Error::Unsupported(format!(
                "metadata service cannot serve {other:?}"
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{Inode, Placement, RegionEntry, RegionMeta, SliceData, SlicePtr};

    fn store() -> MetaStore {
        MetaStore::new(4, 2)
    }

    fn skey(s: &str) -> Key {
        Key::new(Space::Sys, s)
    }

    fn put(key: &Key, v: Value) -> Commit {
        Commit {
            reads: vec![],
            ops: vec![MetaOp::Put {
                key: key.clone(),
                value: v,
            }],
        }
    }

    fn stored(len: u64) -> SliceData {
        SliceData::Stored(vec![SlicePtr {
            server: 1,
            backing: 0,
            offset: 0,
            len,
        }])
    }

    #[test]
    fn put_get_round_trip() {
        let s = store();
        let k = skey("a");
        s.commit(&put(&k, Value::U64(42))).unwrap();
        assert_eq!(s.get(&k), Some((Value::U64(42), 1)));
    }

    #[test]
    fn read_set_validation_conflicts() {
        let s = store();
        let k = skey("a");
        s.commit(&put(&k, Value::U64(1))).unwrap();
        let (_, v) = s.get(&k).unwrap();
        // Another writer moves the key.
        s.commit(&put(&k, Value::U64(2))).unwrap();
        let stale = Commit {
            reads: vec![(k.clone(), v)],
            ops: vec![MetaOp::Put {
                key: k.clone(),
                value: Value::U64(3),
            }],
        };
        assert!(matches!(
            s.commit(&stale),
            Err(Error::TxnConflict { .. })
        ));
        // Nothing applied.
        assert_eq!(s.get(&k).unwrap().0, Value::U64(2));
    }

    #[test]
    fn absent_read_validates_at_version_zero() {
        let s = store();
        let k = skey("never");
        let c = Commit {
            reads: vec![(k.clone(), 0)],
            ops: vec![],
        };
        s.commit(&c).unwrap();
        // After a mutation, version-0 reads conflict.
        s.commit(&put(&k, Value::U64(1))).unwrap();
        assert!(s.commit(&c).is_err());
    }

    #[test]
    fn multi_key_commit_is_atomic_across_shards() {
        let s = store();
        // Enough keys that several shards are involved.
        let keys: Vec<Key> = (0..16).map(|i| skey(&format!("k{i}"))).collect();
        let ops = keys
            .iter()
            .map(|k| MetaOp::Put {
                key: k.clone(),
                value: Value::U64(7),
            })
            .collect();
        s.commit(&Commit { reads: vec![], ops }).unwrap();
        for k in &keys {
            assert_eq!(s.get(k).unwrap().0, Value::U64(7));
        }
    }

    #[test]
    fn failed_op_rolls_back_entire_commit() {
        let s = store();
        let a = skey("a");
        let c = Commit {
            reads: vec![],
            ops: vec![
                MetaOp::Put {
                    key: a.clone(),
                    value: Value::U64(1),
                },
                // Fails: inode op against a U64.
                MetaOp::InodeSetLenMax {
                    key: a.clone(),
                    candidate: 1,
                    highest_region: 0,
                    mtime: 0,
                },
            ],
        };
        assert!(s.commit(&c).is_err());
        assert_eq!(s.get(&a), None); // first op not applied either
    }

    #[test]
    fn ops_in_one_txn_observe_predecessors() {
        let s = store();
        let r = Key::new(Space::Region, "r");
        let i = Key::inode(9);
        s.commit(&put(&i, Value::Inode(Inode::new_file(9, 0o644, 1))))
            .unwrap();
        let c = Commit {
            reads: vec![],
            ops: vec![
                MetaOp::RegionAppendEof {
                    key: r.clone(),
                    data: stored(10),
                    len: 10,
                    cap: 100,
                },
                MetaOp::RegionAppendEof {
                    key: r.clone(),
                    data: stored(5),
                    len: 5,
                    cap: 100,
                },
                MetaOp::InodeSetLenFromRegion {
                    inode_key: i.clone(),
                    region_key: r.clone(),
                    region_base: 1000,
                    mtime: 1,
                },
            ],
        };
        let outcomes = s.commit(&c).unwrap();
        assert_eq!(outcomes[0], OpOutcome::AppendedAt(0));
        assert_eq!(outcomes[1], OpOutcome::AppendedAt(10));
        assert_eq!(s.get(&i).unwrap().0.as_inode().unwrap().len, 1015);
        // Region has one version bump despite two ops.
        assert_eq!(s.version(&r), 1);
    }

    #[test]
    fn blind_appends_from_concurrent_writers_both_land() {
        let s = store();
        let r = Key::new(Space::Region, "r");
        let entry = |at: u64| MetaOp::RegionAppend {
            key: r.clone(),
            entry: RegionEntry {
                placement: Placement::At(at),
                len: 4,
                data: stored(4),
            },
        };
        s.commit(&Commit {
            reads: vec![],
            ops: vec![entry(0)],
        })
        .unwrap();
        s.commit(&Commit {
            reads: vec![],
            ops: vec![entry(100)],
        })
        .unwrap();
        let region = s.get(&r).unwrap().0;
        let region = region.as_region().unwrap().clone();
        assert_eq!(region.entries.len(), 2);
        assert_eq!(region.eof, 104);
    }

    #[test]
    fn scan_space_sees_only_that_space() {
        let s = store();
        s.commit(&put(&skey("a"), Value::U64(1))).unwrap();
        s.commit(&put(
            &Key::new(Space::Region, "r"),
            Value::Region(RegionMeta::default()),
        ))
        .unwrap();
        let sys = s.scan_space(Space::Sys);
        assert_eq!(sys.len(), 1);
        let reg = s.scan_space(Space::Region);
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn survives_replica_failure_and_recovery() {
        let s = store();
        let k = skey("a");
        s.commit(&put(&k, Value::U64(1))).unwrap();
        s.kill_replica(0);
        assert_eq!(s.get(&k).unwrap().0, Value::U64(1));
        s.commit(&put(&k, Value::U64(2))).unwrap();
        s.recover_replica(0);
        s.kill_replica(1); // only the recovered replica remains
        assert_eq!(s.get(&k).unwrap().0, Value::U64(2));
    }

    #[test]
    fn service_counts_txns_and_conflicts() {
        let svc = MetaService::new(store(), Duration::ZERO, Metrics::new());
        let k = skey("a");
        svc.commit(&put(&k, Value::U64(1))).unwrap();
        let stale = Commit {
            reads: vec![(k.clone(), 0)],
            ops: vec![],
        };
        let _ = svc.commit(&stale);
        assert_eq!(svc.metrics().meta_txns(), 2);
        assert_eq!(svc.metrics().meta_conflicts(), 1);
    }
}
