//! The sharded metadata store and its multi-key atomic commit.
//!
//! A [`Commit`] carries the transaction's read set (key → version
//! observed) and its ordered op list.  Commit locks every touched shard
//! in canonical order (no deadlocks), validates the read set and every
//! conditional op against a staged overlay (so ops in one transaction
//! observe their predecessors), and applies all-or-nothing.  This mirrors
//! the guarantee WTF takes from HyperDex Warp: one multi-key transaction
//! of gets + appends + conditional puts, linearizable, spanning schemas.

use super::ops::{self, MetaOp, OpOutcome};
use super::shard::{Shard, ShardInner, ShardStats};
use crate::error::{Error, Result};
use crate::metrics::Metrics;
use crate::types::{Key, Space, Value};
use std::sync::MutexGuard;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// A metadata transaction ready to commit.
#[derive(Clone, Debug, Default)]
pub struct Commit {
    /// `(key, version observed)` — version 0 means "observed absent and
    /// never-mutated".
    pub reads: Vec<(Key, u64)>,
    /// Mutations, applied in order.
    pub ops: Vec<MetaOp>,
}

impl Commit {
    pub fn is_empty(&self) -> bool {
        self.reads.is_empty() && self.ops.is_empty()
    }
}

/// The sharded, chain-replicated metadata store.
#[derive(Debug)]
pub struct MetaStore {
    shards: Vec<Shard>,
    next_inode: AtomicU64,
}

impl MetaStore {
    pub fn new(shards: u32, replicas_per_shard: u8) -> Self {
        assert!(shards >= 1);
        MetaStore {
            shards: (0..shards)
                .map(|_| Shard::new(replicas_per_shard.max(1) as usize))
                .collect(),
            // inode 1 is reserved for the root directory
            next_inode: AtomicU64::new(2),
        }
    }

    /// Stable FNV-1a shard placement (independent of process hash seeds).
    fn shard_of(&self, key: &Key) -> usize {
        let mut h: u64 = 0xcbf29ce484222325;
        let mut feed = |b: u8| {
            h ^= u64::from(b);
            h = h.wrapping_mul(0x100000001b3);
        };
        feed(key.space as u8);
        for b in key.key.as_bytes() {
            feed(*b);
        }
        (h % self.shards.len() as u64) as usize
    }

    /// Versioned point read (linearizable: served by the shard tail).
    pub fn get(&self, key: &Key) -> Option<(Value, u64)> {
        let g = self.shards[self.shard_of(key)].lock();
        let v = g.version(key);
        g.get(key).map(|val| (val.clone(), v))
    }

    /// Version of `key` without copying the value.
    pub fn version(&self, key: &Key) -> u64 {
        self.shards[self.shard_of(key)].lock().version(key)
    }

    /// Allocate a fresh inode id.  Ids allocated by aborted transactions
    /// are simply never used — the allocator needs no transactionality.
    pub fn alloc_inode_id(&self) -> u64 {
        self.next_inode.fetch_add(1, Ordering::Relaxed)
    }

    /// Atomically commit `commit`.  On success, returns one
    /// [`OpOutcome`] per op.  On failure nothing is mutated; the error
    /// distinguishes read-set conflicts (retryable by the WTF retry
    /// layer) from semantic failures (surfaced to the application).
    pub fn commit(&self, commit: &Commit) -> Result<Vec<OpOutcome>> {
        // 1. Canonically ordered shard lock acquisition.
        let mut shard_ids: Vec<usize> = commit
            .reads
            .iter()
            .map(|(k, _)| self.shard_of(k))
            .chain(
                commit
                    .ops
                    .iter()
                    .flat_map(|op| op.keys().into_iter().map(|k| self.shard_of(k))),
            )
            .collect();
        shard_ids.sort_unstable();
        shard_ids.dedup();
        let mut guards: HashMap<usize, MutexGuard<'_, ShardInner>> = HashMap::new();
        for sid in &shard_ids {
            guards.insert(*sid, self.shards[*sid].lock());
        }

        // 2. Validate the read set.
        for (key, observed) in &commit.reads {
            let g = &guards[&self.shard_of(key)];
            if g.version(key) != *observed {
                return Err(Error::TxnConflict {
                    space: key.space,
                    key: key.key.clone(),
                });
            }
        }

        // 3. Stage ops against an overlay so each op sees its
        //    predecessors; validation failures abort with nothing applied.
        let mut overlay: HashMap<Key, Option<Value>> = HashMap::new();
        let mut outcomes = Vec::with_capacity(commit.ops.len());
        for op in &commit.ops {
            let key = op.key().clone();
            let committed = |k: &Key| {
                guards[&self.shard_of(k)].get(k).cloned()
            };
            // Take (don't clone) the staged value: repeated ops on one
            // key — e.g. a concat appending thousands of entries to one
            // region — must stay O(total entries), not O(n^2).
            let current: Option<Value> = match overlay.remove(&key) {
                Some(staged) => staged,
                None => committed(&key),
            };
            // Committed version: conditional (CAS) ops compare against the
            // pre-transaction version, which is what their reads observed.
            let version = guards[&self.shard_of(&key)].version(&key);
            ops::validate(op, current.as_ref(), version)?;
            let peek = |k: &Key| match overlay.get(k) {
                Some(staged) => staged.clone(),
                None => committed(k),
            };
            let (next, outcome) = ops::apply(op, current, &peek)?;
            overlay.insert(key, next);
            outcomes.push(outcome);
        }

        // 4. Apply the overlay; one version bump per mutated key.
        for (key, value) in overlay {
            guards
                .get_mut(&self.shard_of(&key))
                .expect("shard locked")
                .set(&key, value);
        }
        Ok(outcomes)
    }

    /// Full scan of one space (GC uses this to build the in-use slice
    /// lists, §2.8).  Not transactional: GC tolerates staleness by design
    /// (two-consecutive-scan rule).
    pub fn scan_space(&self, space: Space) -> Vec<(Key, Value)> {
        let mut out = Vec::new();
        for shard in &self.shards {
            let g = shard.lock();
            for (k, v) in g.iter_tail() {
                if k.space == space {
                    out.push((k.clone(), v.clone()));
                }
            }
        }
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    /// Kill replica `idx` of every shard (failure injection).
    pub fn kill_replica(&self, idx: usize) {
        for s in &self.shards {
            s.kill_replica(idx);
        }
    }

    /// Recover replica `idx` of every shard.
    pub fn recover_replica(&self, idx: usize) {
        for s in &self.shards {
            s.recover_replica(idx);
        }
    }

    pub fn shard_stats(&self) -> Vec<ShardStats> {
        self.shards.iter().map(|s| s.stats()).collect()
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }
}

/// [`MetaStore`] plus the deployment concerns: the simulated transaction
/// latency floor (the paper measures ~3 ms per HyperDex transaction) and
/// metrics.  All client traffic goes through this type.
#[derive(Debug)]
pub struct MetaService {
    store: MetaStore,
    txn_floor: Duration,
    metrics: Metrics,
}

impl MetaService {
    pub fn new(store: MetaStore, txn_floor: Duration, metrics: Metrics) -> Self {
        MetaService {
            store,
            txn_floor,
            metrics,
        }
    }

    pub fn store(&self) -> &MetaStore {
        &self.store
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn get(&self, key: &Key) -> Option<(Value, u64)> {
        self.store.get(key)
    }

    pub fn alloc_inode_id(&self) -> u64 {
        self.store.alloc_inode_id()
    }

    /// Commit with the latency floor charged once per transaction.
    pub fn commit(&self, commit: &Commit) -> Result<Vec<OpOutcome>> {
        if self.txn_floor > Duration::ZERO {
            std::thread::sleep(self.txn_floor);
        }
        self.metrics.add_meta_txns(1);
        let r = self.store.commit(commit);
        if matches!(r, Err(Error::TxnConflict { .. })) {
            self.metrics.add_meta_conflicts(1);
        }
        r
    }
}

/// The transport server side of the metadata plane: commits and
/// versioned point reads arrive as envelopes, same as storage traffic.
/// (The metadata plane's cost model is the transaction floor above, so
/// these envelopes report no wire bytes to the data-plane link.)
impl crate::net::Handler for MetaService {
    fn serve(&self, req: &crate::net::Request) -> Result<crate::net::Response> {
        use crate::net::{Request, Response};
        match req {
            Request::MetaCommit { commit } => Ok(Response::Outcomes(self.commit(commit)?)),
            Request::MetaGet { key } => Ok(Response::MetaValue(self.get(key))),
            other => Err(Error::Unsupported(format!(
                "metadata service cannot serve {other:?}"
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{Inode, Placement, RegionEntry, RegionMeta, SliceData, SlicePtr};

    fn store() -> MetaStore {
        MetaStore::new(4, 2)
    }

    fn skey(s: &str) -> Key {
        Key::new(Space::Sys, s)
    }

    fn put(key: &Key, v: Value) -> Commit {
        Commit {
            reads: vec![],
            ops: vec![MetaOp::Put {
                key: key.clone(),
                value: v,
            }],
        }
    }

    fn stored(len: u64) -> SliceData {
        SliceData::Stored(vec![SlicePtr {
            server: 1,
            backing: 0,
            offset: 0,
            len,
        }])
    }

    #[test]
    fn put_get_round_trip() {
        let s = store();
        let k = skey("a");
        s.commit(&put(&k, Value::U64(42))).unwrap();
        assert_eq!(s.get(&k), Some((Value::U64(42), 1)));
    }

    #[test]
    fn read_set_validation_conflicts() {
        let s = store();
        let k = skey("a");
        s.commit(&put(&k, Value::U64(1))).unwrap();
        let (_, v) = s.get(&k).unwrap();
        // Another writer moves the key.
        s.commit(&put(&k, Value::U64(2))).unwrap();
        let stale = Commit {
            reads: vec![(k.clone(), v)],
            ops: vec![MetaOp::Put {
                key: k.clone(),
                value: Value::U64(3),
            }],
        };
        assert!(matches!(
            s.commit(&stale),
            Err(Error::TxnConflict { .. })
        ));
        // Nothing applied.
        assert_eq!(s.get(&k).unwrap().0, Value::U64(2));
    }

    #[test]
    fn absent_read_validates_at_version_zero() {
        let s = store();
        let k = skey("never");
        let c = Commit {
            reads: vec![(k.clone(), 0)],
            ops: vec![],
        };
        s.commit(&c).unwrap();
        // After a mutation, version-0 reads conflict.
        s.commit(&put(&k, Value::U64(1))).unwrap();
        assert!(s.commit(&c).is_err());
    }

    #[test]
    fn multi_key_commit_is_atomic_across_shards() {
        let s = store();
        // Enough keys that several shards are involved.
        let keys: Vec<Key> = (0..16).map(|i| skey(&format!("k{i}"))).collect();
        let ops = keys
            .iter()
            .map(|k| MetaOp::Put {
                key: k.clone(),
                value: Value::U64(7),
            })
            .collect();
        s.commit(&Commit { reads: vec![], ops }).unwrap();
        for k in &keys {
            assert_eq!(s.get(k).unwrap().0, Value::U64(7));
        }
    }

    #[test]
    fn failed_op_rolls_back_entire_commit() {
        let s = store();
        let a = skey("a");
        let c = Commit {
            reads: vec![],
            ops: vec![
                MetaOp::Put {
                    key: a.clone(),
                    value: Value::U64(1),
                },
                // Fails: inode op against a U64.
                MetaOp::InodeSetLenMax {
                    key: a.clone(),
                    candidate: 1,
                    highest_region: 0,
                    mtime: 0,
                },
            ],
        };
        assert!(s.commit(&c).is_err());
        assert_eq!(s.get(&a), None); // first op not applied either
    }

    #[test]
    fn ops_in_one_txn_observe_predecessors() {
        let s = store();
        let r = Key::new(Space::Region, "r");
        let i = Key::inode(9);
        s.commit(&put(&i, Value::Inode(Inode::new_file(9, 0o644, 1))))
            .unwrap();
        let c = Commit {
            reads: vec![],
            ops: vec![
                MetaOp::RegionAppendEof {
                    key: r.clone(),
                    data: stored(10),
                    len: 10,
                    cap: 100,
                },
                MetaOp::RegionAppendEof {
                    key: r.clone(),
                    data: stored(5),
                    len: 5,
                    cap: 100,
                },
                MetaOp::InodeSetLenFromRegion {
                    inode_key: i.clone(),
                    region_key: r.clone(),
                    region_base: 1000,
                    mtime: 1,
                },
            ],
        };
        let outcomes = s.commit(&c).unwrap();
        assert_eq!(outcomes[0], OpOutcome::AppendedAt(0));
        assert_eq!(outcomes[1], OpOutcome::AppendedAt(10));
        assert_eq!(s.get(&i).unwrap().0.as_inode().unwrap().len, 1015);
        // Region has one version bump despite two ops.
        assert_eq!(s.version(&r), 1);
    }

    #[test]
    fn blind_appends_from_concurrent_writers_both_land() {
        let s = store();
        let r = Key::new(Space::Region, "r");
        let entry = |at: u64| MetaOp::RegionAppend {
            key: r.clone(),
            entry: RegionEntry {
                placement: Placement::At(at),
                len: 4,
                data: stored(4),
            },
        };
        s.commit(&Commit {
            reads: vec![],
            ops: vec![entry(0)],
        })
        .unwrap();
        s.commit(&Commit {
            reads: vec![],
            ops: vec![entry(100)],
        })
        .unwrap();
        let region = s.get(&r).unwrap().0;
        let region = region.as_region().unwrap().clone();
        assert_eq!(region.entries.len(), 2);
        assert_eq!(region.eof, 104);
    }

    #[test]
    fn scan_space_sees_only_that_space() {
        let s = store();
        s.commit(&put(&skey("a"), Value::U64(1))).unwrap();
        s.commit(&put(
            &Key::new(Space::Region, "r"),
            Value::Region(RegionMeta::default()),
        ))
        .unwrap();
        let sys = s.scan_space(Space::Sys);
        assert_eq!(sys.len(), 1);
        let reg = s.scan_space(Space::Region);
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn survives_replica_failure_and_recovery() {
        let s = store();
        let k = skey("a");
        s.commit(&put(&k, Value::U64(1))).unwrap();
        s.kill_replica(0);
        assert_eq!(s.get(&k).unwrap().0, Value::U64(1));
        s.commit(&put(&k, Value::U64(2))).unwrap();
        s.recover_replica(0);
        s.kill_replica(1); // only the recovered replica remains
        assert_eq!(s.get(&k).unwrap().0, Value::U64(2));
    }

    #[test]
    fn service_counts_txns_and_conflicts() {
        let svc = MetaService::new(store(), Duration::ZERO, Metrics::new());
        let k = skey("a");
        svc.commit(&put(&k, Value::U64(1))).unwrap();
        let stale = Commit {
            reads: vec![(k.clone(), 0)],
            ops: vec![],
        };
        let _ = svc.commit(&stale);
        assert_eq!(svc.metrics().meta_txns(), 2);
        assert_eq!(svc.metrics().meta_conflicts(), 1);
    }
}
