//! Client-side transaction builder: accumulates a read set and op list
//! against a [`MetaService`], then commits atomically.
//!
//! This is the *metadata* transaction (one Warp/HyperDex transaction in
//! the paper); the WTF-level transaction with its retry-on-conflict
//! replay lives above it in `client::txn`.

use super::ops::{MetaOp, OpOutcome};
use super::store::{Commit, MetaService};
use crate::error::{Error, Result};
use crate::net::{Request, Transport};
use crate::types::{Key, Value};
use std::collections::HashMap;
use std::sync::Arc;

/// A versioned read-through source for transactional reads (PR 9).
///
/// The client's metadata cache implements this so [`MetaTxn::get`] can
/// serve a warm key with ZERO envelopes, recording the CACHED version
/// in the read set.  Commit-time validation then checks that version
/// against the store exactly as if the read had paid a leaseholder
/// round — a stale cache entry surfaces as [`Error::TxnConflict`], the
/// key is invalidated, and the retry re-reads fresh state.  §3
/// serializability is preserved by construction (the FaaS-FS recipe:
/// optimistic cached reads + unchanged commit-time validation).
pub trait TxnReadCache: Send + Sync {
    /// Cached `(value, version)` for `key` when present and fresh.
    /// `None` sends the read to the wire.
    fn lookup(&self, key: &Key) -> Option<(Option<Value>, u64)>;

    /// Invalidation epoch snapshotted BEFORE a wire read whose result
    /// will be offered back via [`TxnReadCache::fill`].
    fn epoch(&self) -> u64;

    /// Offer a wire-read result for caching.  Implementations drop the
    /// fill when `as_of` no longer matches their epoch (an invalidation
    /// won the race while the read was in flight).
    fn fill(&self, key: &Key, value: &Option<Value>, version: u64, as_of: u64);
}

/// An in-flight metadata transaction.
pub struct MetaTxn {
    service: Arc<MetaService>,
    /// When present, reads and the commit travel as transport envelopes
    /// (the deployment shape); otherwise they are direct method calls
    /// (bootstrap and unit tests).
    transport: Option<Arc<Transport>>,
    /// Version observed per key (first read wins; later reads of the same
    /// key are served from the cache for snapshot-consistency within the
    /// transaction).
    reads: HashMap<Key, (Option<Value>, u64)>,
    read_order: Vec<Key>,
    ops: Vec<MetaOp>,
    /// Max NotLeader heal-retries per read (the deployment threads
    /// `Config::txn_retry_budget` through here).
    heal_budget: u32,
    /// End-to-end wall-clock bound across ALL heal-retries of one read
    /// (`Config::rpc_deadline`); ZERO disables.  When exceeded the read
    /// surfaces [`Error::Timeout`] instead of healing again.
    rpc_deadline: std::time::Duration,
    /// Base for jittered exponential backoff between heal-retries
    /// (`Config::retry_backoff`); ZERO retries immediately.
    retry_backoff: std::time::Duration,
    /// Called with the shard id BEFORE every internal NotLeader heal.
    /// The client installs its read-cache clear here: every heal must
    /// drop the cache, including the ones this transaction performs on
    /// its own (the coherence contract's second trigger).
    heal_hook: Option<Arc<dyn Fn(u32) + Send + Sync>>,
    /// Optional versioned read-through cache ([`TxnReadCache`]): warm
    /// keys are served locally with their cached version recorded in
    /// the read set; commit-time validation catches staleness.
    read_cache: Option<Arc<dyn TxnReadCache>>,
    /// Reads served from `read_cache` (observability/benches).
    cached_reads: u64,
}

impl MetaTxn {
    pub fn new(service: Arc<MetaService>) -> Self {
        MetaTxn {
            service,
            transport: None,
            reads: HashMap::new(),
            read_order: Vec::new(),
            ops: Vec::new(),
            heal_budget: 16,
            rpc_deadline: std::time::Duration::ZERO,
            retry_backoff: std::time::Duration::ZERO,
            heal_hook: None,
            read_cache: None,
            cached_reads: 0,
        }
    }

    /// A transaction whose reads and commit go through `transport`.
    pub fn with_transport(service: Arc<MetaService>, transport: Arc<Transport>) -> Self {
        MetaTxn {
            transport: Some(transport),
            ..MetaTxn::new(service)
        }
    }

    /// Override the per-read NotLeader heal-retry budget.
    pub fn heal_budget(mut self, budget: u32) -> Self {
        self.heal_budget = budget.max(1);
        self
    }

    /// Bound the END-TO-END wall-clock of one read's heal-retry ladder;
    /// past it the read surfaces [`Error::Timeout`].  ZERO (the
    /// default) disables the bound.
    pub fn rpc_deadline(mut self, deadline: std::time::Duration) -> Self {
        self.rpc_deadline = deadline;
        self
    }

    /// Insert jittered exponential backoff (base `backoff`) between
    /// heal-retries.  ZERO (the default) retries immediately.
    pub fn retry_backoff(mut self, backoff: std::time::Duration) -> Self {
        self.retry_backoff = backoff;
        self
    }

    /// Install a hook run (with the shard id) before every internal
    /// NotLeader heal this transaction performs.
    pub fn on_heal(mut self, hook: Arc<dyn Fn(u32) + Send + Sync>) -> Self {
        self.heal_hook = Some(hook);
        self
    }

    /// Serve reads through `cache` optimistically ([`TxnReadCache`]):
    /// warm keys cost zero envelopes and their cached version enters
    /// the read set for commit-time validation.
    pub fn read_through(mut self, cache: Arc<dyn TxnReadCache>) -> Self {
        self.read_cache = Some(cache);
        self
    }

    /// Reads this transaction served from its [`TxnReadCache`].
    pub fn cached_reads(&self) -> u64 {
        self.cached_reads
    }

    /// Read `key`, recording its version in the read set.  Re-reads are
    /// answered from the transaction's cache so the transaction observes
    /// a stable snapshot of every key it touches.
    ///
    /// `NotLeader` answers trigger a blocking heal of the shard and a
    /// retry; any other failure (e.g. `NoQuorum`) SURFACES — a
    /// transactional read must never record a key as absent just
    /// because its shard is unreadable.
    pub fn get(&mut self, key: &Key) -> Result<Option<Value>> {
        if let Some((v, _)) = self.reads.get(key) {
            return Ok(v.clone());
        }
        // Optimistic cached read (PR 9): a warm `(value, version)` pair
        // enters the read set AS IF it came from the leaseholder —
        // commit-time validation rejects it if the key has since moved,
        // so a stale hit costs one conflict-retry, never serializability.
        if let Some(cache) = &self.read_cache {
            if let Some((value, version)) = cache.lookup(key) {
                self.cached_reads += 1;
                self.reads.insert(key.clone(), (value.clone(), version));
                self.read_order.push(key.clone());
                return Ok(value);
            }
        }
        // Epoch BEFORE the wire round: if an invalidation (own commit,
        // heal, conflict) lands while the read is in flight, the fill
        // below is dropped rather than re-installing pre-commit state.
        let as_of = self.read_cache.as_ref().map(|c| c.epoch());
        // Value + version arrive from ONE atomic view read (absent keys
        // included): a separate version fetch could race a concurrent
        // commit and record an (absence, version) pair that never
        // coexisted.
        let (value, version) = match &self.transport {
            Some(t) => {
                let started = std::time::Instant::now();
                let mut attempts = 0u32;
                loop {
                    match t
                        .call(
                            self.service.clone(),
                            Request::MetaGet { key: key.clone() },
                        )
                        .and_then(crate::net::Response::into_meta_value)
                    {
                        Ok(pair) => break pair,
                        Err(Error::NotLeader { shard, .. }) if attempts < self.heal_budget => {
                            attempts += 1;
                            if !self.rpc_deadline.is_zero()
                                && started.elapsed() >= self.rpc_deadline
                            {
                                return Err(Error::Timeout {
                                    op: "meta_txn.get",
                                    elapsed: started.elapsed(),
                                });
                            }
                            let pause =
                                crate::util::backoff_jitter(self.retry_backoff, attempts);
                            if !pause.is_zero() {
                                std::thread::sleep(pause);
                            }
                            if let Some(hook) = &self.heal_hook {
                                hook(shard);
                            }
                            self.service.heal(shard);
                        }
                        Err(e) => return Err(e),
                    }
                }
            }
            None => self.service.get_checked(key)?,
        };
        if let (Some(cache), Some(as_of)) = (&self.read_cache, as_of) {
            cache.fill(key, &value, version, as_of);
        }
        self.reads
            .insert(key.clone(), (value.clone(), version));
        self.read_order.push(key.clone());
        Ok(value)
    }

    /// Queue a mutation.
    pub fn push(&mut self, op: MetaOp) {
        self.ops.push(op);
    }

    /// Number of queued ops.
    pub fn num_ops(&self) -> usize {
        self.ops.len()
    }

    /// Every key the queued ops will mutate, deduplicated — the
    /// committing client invalidates its read cache with these
    /// (own-commit read-your-writes).
    pub fn mutated_keys(&self) -> Vec<Key> {
        let mut keys: Vec<Key> = self
            .ops
            .iter()
            .flat_map(|op| op.keys().into_iter().cloned())
            .collect();
        keys.sort_unstable();
        keys.dedup();
        keys
    }

    /// True when the transaction would commit nothing.
    pub fn is_empty(&self) -> bool {
        self.ops.is_empty() && self.reads.is_empty()
    }

    /// Commit: validate every recorded read and apply every op atomically.
    pub fn commit(self) -> Result<Vec<OpOutcome>> {
        let commit = Commit {
            reads: self
                .read_order
                .iter()
                .map(|k| (k.clone(), self.reads[k].1))
                .collect(),
            ops: self.ops,
        };
        match &self.transport {
            Some(t) => t
                .call(self.service.clone(), Request::MetaCommit { commit })?
                .into_outcomes(),
            None => self.service.commit(&commit),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::meta::MetaStore;
    use crate::metrics::Metrics;
    use crate::types::Space;
    use std::time::Duration;

    fn service() -> Arc<MetaService> {
        Arc::new(MetaService::new(
            MetaStore::new(4, 2),
            Duration::ZERO,
            Metrics::new(),
        ))
    }

    fn k(s: &str) -> Key {
        Key::new(Space::Sys, s)
    }

    #[test]
    fn read_then_write_commits() {
        let svc = service();
        let mut t = MetaTxn::new(svc.clone());
        assert_eq!(t.get(&k("a")).unwrap(), None);
        t.push(MetaOp::Put {
            key: k("a"),
            value: Value::U64(1),
        });
        t.commit().unwrap();
        assert_eq!(svc.get_checked(&k("a")).unwrap().0, Some(Value::U64(1)));
    }

    #[test]
    fn stale_read_aborts() {
        let svc = service();
        let mut t = MetaTxn::new(svc.clone());
        let _ = t.get(&k("a"));
        // Interleaved writer.
        let mut w = MetaTxn::new(svc.clone());
        w.push(MetaOp::Put {
            key: k("a"),
            value: Value::U64(9),
        });
        w.commit().unwrap();
        t.push(MetaOp::Put {
            key: k("a"),
            value: Value::U64(1),
        });
        assert!(t.commit().is_err());
        assert_eq!(svc.get_checked(&k("a")).unwrap().0, Some(Value::U64(9)));
    }

    #[test]
    fn rereads_are_snapshot_stable() {
        let svc = service();
        let mut t = MetaTxn::new(svc.clone());
        assert_eq!(t.get(&k("a")).unwrap(), None);
        // Another writer commits in between.
        let mut w = MetaTxn::new(svc.clone());
        w.push(MetaOp::Put {
            key: k("a"),
            value: Value::U64(9),
        });
        w.commit().unwrap();
        // The transaction still sees its snapshot.
        assert_eq!(t.get(&k("a")).unwrap(), None);
    }

    /// A deterministic [`TxnReadCache`] for unit tests: a plain map
    /// plus an epoch counter with the production guard semantics.
    #[derive(Default)]
    struct TestCache {
        entries: std::sync::Mutex<HashMap<Key, (Option<Value>, u64)>>,
        epoch: std::sync::atomic::AtomicU64,
        fills: std::sync::atomic::AtomicU64,
    }

    impl TxnReadCache for TestCache {
        fn lookup(&self, key: &Key) -> Option<(Option<Value>, u64)> {
            self.entries.lock().unwrap().get(key).cloned()
        }
        fn epoch(&self) -> u64 {
            self.epoch.load(std::sync::atomic::Ordering::Relaxed)
        }
        fn fill(&self, key: &Key, value: &Option<Value>, version: u64, as_of: u64) {
            if as_of != self.epoch() {
                return;
            }
            self.fills.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            self.entries
                .lock()
                .unwrap()
                .insert(key.clone(), (value.clone(), version));
        }
    }

    #[test]
    fn fresh_cached_read_commits_without_touching_the_store() {
        let svc = service();
        // Seed "a" and learn its authoritative version.
        let mut w = MetaTxn::new(svc.clone());
        w.push(MetaOp::Put {
            key: k("a"),
            value: Value::U64(1),
        });
        w.commit().unwrap();
        let (val, ver) = svc.get_checked(&k("a")).unwrap();
        let cache = Arc::new(TestCache::default());
        cache
            .entries
            .lock()
            .unwrap()
            .insert(k("a"), (val.clone(), ver));
        // The cached read is served locally, enters the read set, and
        // the commit validates clean (nothing moved).
        let mut t = MetaTxn::new(svc.clone()).read_through(cache);
        assert_eq!(t.get(&k("a")).unwrap(), Some(Value::U64(1)));
        assert_eq!(t.cached_reads(), 1);
        t.push(MetaOp::Put {
            key: k("b"),
            value: Value::U64(2),
        });
        t.commit().unwrap();
        assert_eq!(svc.get_checked(&k("b")).unwrap().0, Some(Value::U64(2)));
    }

    #[test]
    fn stale_cached_read_is_caught_at_validation() {
        let svc = service();
        let mut w = MetaTxn::new(svc.clone());
        w.push(MetaOp::Put {
            key: k("a"),
            value: Value::U64(1),
        });
        w.commit().unwrap();
        let (val, ver) = svc.get_checked(&k("a")).unwrap();
        let cache = Arc::new(TestCache::default());
        cache.entries.lock().unwrap().insert(k("a"), (val, ver));
        // The store moves on AFTER the cache snapshot...
        let mut w = MetaTxn::new(svc.clone());
        w.push(MetaOp::Put {
            key: k("a"),
            value: Value::U64(9),
        });
        w.commit().unwrap();
        // ...so the optimistic cached read MUST abort at commit — the
        // stale value can never be committed over.
        let mut t = MetaTxn::new(svc.clone()).read_through(cache);
        assert_eq!(t.get(&k("a")).unwrap(), Some(Value::U64(1)), "served stale, optimistically");
        t.push(MetaOp::Put {
            key: k("a"),
            value: Value::U64(2),
        });
        let err = t.commit().unwrap_err();
        assert!(matches!(err, Error::TxnConflict { .. }), "{err}");
        assert_eq!(
            svc.get_checked(&k("a")).unwrap().0,
            Some(Value::U64(9)),
            "the stale read never committed"
        );
    }

    #[test]
    fn wire_reads_fill_the_cache_unless_the_epoch_moved() {
        let svc = service();
        let mut w = MetaTxn::new(svc.clone());
        w.push(MetaOp::Put {
            key: k("a"),
            value: Value::U64(1),
        });
        w.commit().unwrap();
        let cache = Arc::new(TestCache::default());
        // Cold read goes to the store and fills the cache.
        let mut t = MetaTxn::new(svc.clone()).read_through(cache.clone());
        assert_eq!(t.get(&k("a")).unwrap(), Some(Value::U64(1)));
        assert_eq!(t.cached_reads(), 0);
        assert_eq!(cache.fills.load(std::sync::atomic::Ordering::Relaxed), 1);
        assert!(cache.entries.lock().unwrap().contains_key(&k("a")));
        // A second transaction now hits the cache...
        let mut t2 = MetaTxn::new(svc.clone()).read_through(cache.clone());
        let _ = t2.get(&k("a")).unwrap();
        assert_eq!(t2.cached_reads(), 1);
        // ...and a read whose epoch snapshot went stale mid-flight
        // drops its fill (the guard the client relies on).
        cache.entries.lock().unwrap().clear();
        cache
            .epoch
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        // Simulate the race by filling with a pre-bump snapshot.
        cache.fill(&k("a"), &Some(Value::U64(1)), 1, 0);
        assert!(cache.entries.lock().unwrap().is_empty(), "stale fill landed");
    }

    #[test]
    fn rereads_stay_snapshot_stable_over_a_cache_hit() {
        let svc = service();
        let mut w = MetaTxn::new(svc.clone());
        w.push(MetaOp::Put {
            key: k("a"),
            value: Value::U64(1),
        });
        w.commit().unwrap();
        let (val, ver) = svc.get_checked(&k("a")).unwrap();
        let cache = Arc::new(TestCache::default());
        cache.entries.lock().unwrap().insert(k("a"), (val, ver));
        let mut t = MetaTxn::new(svc.clone()).read_through(cache.clone());
        assert_eq!(t.get(&k("a")).unwrap(), Some(Value::U64(1)));
        // Evict + move the cache under the transaction: re-reads come
        // from the txn's own read set, not the cache.
        cache
            .entries
            .lock()
            .unwrap()
            .insert(k("a"), (Some(Value::U64(7)), ver + 1));
        assert_eq!(t.get(&k("a")).unwrap(), Some(Value::U64(1)));
        assert_eq!(t.cached_reads(), 1, "re-read did not consult the cache");
    }

    #[test]
    fn write_only_txns_do_not_conflict() {
        let svc = service();
        for i in 0..10 {
            let mut t = MetaTxn::new(svc.clone());
            t.push(MetaOp::Put {
                key: k("a"),
                value: Value::U64(i),
            });
            t.commit().unwrap();
        }
        assert_eq!(svc.metrics().meta_conflicts(), 0);
    }
}
