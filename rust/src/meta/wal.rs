//! Durable per-replica write-ahead log for the metadata shard groups.
//!
//! Until PR 7 the Paxos acceptor was "modeled as stable storage" in
//! memory: promises and accepts survived a simulated crash only because
//! the simulation chose not to wipe them.  This module makes the model
//! real, following the crash-recovery discipline of Malachite's ADR-007
//! (log every input that determines a promise; replay to a state
//! indistinguishable from the pre-crash replica) and the durable-commit
//! framing of DurableFS:
//!
//! * **Record format** — an append-only segment of CRC-framed records:
//!   `[len: u32 LE][crc32: u32 LE][payload]`.  The payload is a
//!   [`WalRecord`]: a `Promise` (slot + ballot), an `Accept` (slot +
//!   ballot + entry), or a `Chosen` (slot + entry).  2PC `Prepare`
//!   intents and `Decide` records are chosen log entries, so `Chosen`
//!   records carry them; replay rebuilds intents and locks through the
//!   same deterministic apply the live path uses.
//! * **Durability boundary** — the replica appends (and fsyncs, per
//!   [`WalSync`]) the record *before* the acknowledgment that depends
//!   on it: a `Promise` before `granted: true`, an `Accept` before
//!   `Accepted(true)`, a `Chosen` before `Learned`.  Lease grants are
//!   deliberately NOT logged: recovery re-applies the one-lease-window
//!   hold-off instead, which is strictly more conservative.
//! * **Checkpoint + truncation** — every `checkpoint_every` chosen
//!   records the replica serializes its whole durable image (acceptor
//!   slots, chosen log, materialized state, 2PC bookkeeping) into
//!   `ckpt-<gen>.bin`, opens a fresh `seg-<gen>.wal`, and deletes the
//!   previous generation, so logs do not grow without bound and replay
//!   cost is amortized to one generation's suffix.
//! * **Refuse-to-vote** — recovery is strict: a truncated frame, a CRC
//!   mismatch, a decode error, or a missing checkpoint is
//!   [`Error::WalCorrupt`], and the replica stays dead (degraded
//!   quorum) rather than rejoin with amnesia and re-promise a lower
//!   ballot (equivocation).
//!
//! Each replica owns one directory (`<wal_root>/shard-<s>/replica-<r>`)
//! stamped with a `MARKER` file (magic, format version, shard and
//! replica ids) so segments from two clusters — or two replicas — can
//! never be interleaved in one directory.

use super::group::{EntryKind, LogEntry};
use super::ops::{MetaOp, OpOutcome};
use crate::config::WalSync;
use crate::coordinator::paxos::Ballot;
use crate::error::{Error, Result};
use crate::types::{
    DirEntries, Inode, InodeKind, Key, Placement, RegionEntry, RegionMeta, SliceData, SlicePtr,
    Space, Value,
};
use std::fs::{self, File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};

/// Magic prefix of the per-replica `MARKER` file.
const MAGIC: &[u8; 6] = b"WTFWAL";
/// On-disk format version (bump on any incompatible codec change).
const FORMAT_VERSION: u16 = 1;
/// Upper bound on one framed record/checkpoint payload — anything
/// larger is treated as corruption, not an allocation request.
const MAX_FRAME: u32 = 64 << 20;
/// `WalSync::Batch`: force an fsync at least every this many appends
/// even when no `Chosen` record arrives to trigger one.
const BATCH_SYNC_EVERY: u64 = 32;

// ---------------------------------------------------------------------
// CRC32 (IEEE), table-driven; no external crates in the offline build.
// ---------------------------------------------------------------------

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

const CRC_TABLE: [u32; 256] = crc_table();

/// IEEE CRC-32 of `bytes` (the frame integrity check).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

// ---------------------------------------------------------------------
// Binary codec: hand-rolled (offline build — no serde), little-endian,
// length-prefixed strings and sequences, one tag byte per enum.
// ---------------------------------------------------------------------

pub(crate) type Corrupt = String;

pub(crate) fn put_u8(o: &mut Vec<u8>, v: u8) {
    o.push(v);
}

pub(crate) fn put_u16(o: &mut Vec<u8>, v: u16) {
    o.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_u32(o: &mut Vec<u8>, v: u32) {
    o.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_u64(o: &mut Vec<u8>, v: u64) {
    o.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_i64(o: &mut Vec<u8>, v: i64) {
    o.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_bool(o: &mut Vec<u8>, v: bool) {
    put_u8(o, v as u8);
}

pub(crate) fn put_str(o: &mut Vec<u8>, s: &str) {
    put_u32(o, s.len() as u32);
    o.extend_from_slice(s.as_bytes());
}

pub(crate) fn put_blob(o: &mut Vec<u8>, b: &[u8]) {
    put_u32(o, b.len() as u32);
    o.extend_from_slice(b);
}

/// A strict decoding cursor: every read is bounds-checked and every
/// failure carries the byte position, so corruption reports are exact.
pub(crate) struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    pub(crate) fn new(buf: &'a [u8]) -> Self {
        Dec { buf, pos: 0 }
    }

    pub(crate) fn take(&mut self, n: usize) -> std::result::Result<&'a [u8], Corrupt> {
        if self.buf.len() - self.pos < n {
            return Err(format!(
                "payload truncated: need {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub(crate) fn u8(&mut self) -> std::result::Result<u8, Corrupt> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u16(&mut self) -> std::result::Result<u16, Corrupt> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub(crate) fn u32(&mut self) -> std::result::Result<u32, Corrupt> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn u64(&mut self) -> std::result::Result<u64, Corrupt> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub(crate) fn i64(&mut self) -> std::result::Result<i64, Corrupt> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub(crate) fn bool(&mut self) -> std::result::Result<bool, Corrupt> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            v => Err(format!("invalid bool byte {v}")),
        }
    }

    pub(crate) fn str(&mut self) -> std::result::Result<String, Corrupt> {
        let n = self.seq()?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| "invalid utf-8 string".to_string())
    }

    pub(crate) fn blob(&mut self) -> std::result::Result<Vec<u8>, Corrupt> {
        let n = self.seq()?;
        Ok(self.take(n)?.to_vec())
    }

    /// Sequence length, sanity-bounded by the bytes actually remaining
    /// (every element costs >= 1 byte) so a corrupt length can never
    /// turn into a giant allocation.
    pub(crate) fn seq(&mut self) -> std::result::Result<usize, Corrupt> {
        let n = self.u32()? as usize;
        if n > self.buf.len() - self.pos {
            return Err(format!(
                "sequence length {n} exceeds remaining payload {}",
                self.buf.len() - self.pos
            ));
        }
        Ok(n)
    }

    pub(crate) fn done(&self) -> std::result::Result<(), Corrupt> {
        if self.pos != self.buf.len() {
            return Err(format!(
                "{} trailing bytes after a complete payload",
                self.buf.len() - self.pos
            ));
        }
        Ok(())
    }
}

pub(crate) fn enc_ballot(o: &mut Vec<u8>, b: &Ballot) {
    put_u64(o, b.round);
    put_u32(o, b.proposer);
}

pub(crate) fn dec_ballot(d: &mut Dec) -> std::result::Result<Ballot, Corrupt> {
    Ok(Ballot {
        round: d.u64()?,
        proposer: d.u32()?,
    })
}

pub(crate) fn enc_space(o: &mut Vec<u8>, s: Space) {
    put_u8(
        o,
        match s {
            Space::Path => 0,
            Space::Inode => 1,
            Space::Region => 2,
            Space::Dir => 3,
            Space::Sys => 4,
        },
    );
}

pub(crate) fn dec_space(d: &mut Dec) -> std::result::Result<Space, Corrupt> {
    match d.u8()? {
        0 => Ok(Space::Path),
        1 => Ok(Space::Inode),
        2 => Ok(Space::Region),
        3 => Ok(Space::Dir),
        4 => Ok(Space::Sys),
        t => Err(format!("invalid Space tag {t}")),
    }
}

pub(crate) fn enc_key(o: &mut Vec<u8>, k: &Key) {
    enc_space(o, k.space);
    put_str(o, &k.key);
}

pub(crate) fn dec_key(d: &mut Dec) -> std::result::Result<Key, Corrupt> {
    Ok(Key {
        space: dec_space(d)?,
        key: d.str()?,
    })
}

pub(crate) fn enc_slice_ptr(o: &mut Vec<u8>, p: &SlicePtr) {
    put_u32(o, p.server);
    put_u32(o, p.backing);
    put_u64(o, p.offset);
    put_u64(o, p.len);
}

pub(crate) fn dec_slice_ptr(d: &mut Dec) -> std::result::Result<SlicePtr, Corrupt> {
    Ok(SlicePtr {
        server: d.u32()?,
        backing: d.u32()?,
        offset: d.u64()?,
        len: d.u64()?,
    })
}

pub(crate) fn enc_slice_ptrs(o: &mut Vec<u8>, ptrs: &[SlicePtr]) {
    put_u32(o, ptrs.len() as u32);
    for p in ptrs {
        enc_slice_ptr(o, p);
    }
}

pub(crate) fn dec_slice_ptrs(d: &mut Dec) -> std::result::Result<Vec<SlicePtr>, Corrupt> {
    let n = d.seq()?;
    let mut v = Vec::with_capacity(n);
    for _ in 0..n {
        v.push(dec_slice_ptr(d)?);
    }
    Ok(v)
}

pub(crate) fn enc_slice_data(o: &mut Vec<u8>, s: &SliceData) {
    match s {
        SliceData::Stored(ptrs) => {
            put_u8(o, 0);
            enc_slice_ptrs(o, ptrs);
        }
        SliceData::Hole => put_u8(o, 1),
    }
}

pub(crate) fn dec_slice_data(d: &mut Dec) -> std::result::Result<SliceData, Corrupt> {
    match d.u8()? {
        0 => Ok(SliceData::Stored(dec_slice_ptrs(d)?)),
        1 => Ok(SliceData::Hole),
        t => Err(format!("invalid SliceData tag {t}")),
    }
}

pub(crate) fn enc_placement(o: &mut Vec<u8>, p: &Placement) {
    match p {
        Placement::At(off) => {
            put_u8(o, 0);
            put_u64(o, *off);
        }
        Placement::Eof => put_u8(o, 1),
    }
}

pub(crate) fn dec_placement(d: &mut Dec) -> std::result::Result<Placement, Corrupt> {
    match d.u8()? {
        0 => Ok(Placement::At(d.u64()?)),
        1 => Ok(Placement::Eof),
        t => Err(format!("invalid Placement tag {t}")),
    }
}

pub(crate) fn enc_region_entry(o: &mut Vec<u8>, e: &RegionEntry) {
    enc_placement(o, &e.placement);
    put_u64(o, e.len);
    enc_slice_data(o, &e.data);
}

pub(crate) fn dec_region_entry(d: &mut Dec) -> std::result::Result<RegionEntry, Corrupt> {
    Ok(RegionEntry {
        placement: dec_placement(d)?,
        len: d.u64()?,
        data: dec_slice_data(d)?,
    })
}

pub(crate) fn enc_region(o: &mut Vec<u8>, r: &RegionMeta) {
    match &r.spill {
        Some(ptrs) => {
            put_u8(o, 1);
            enc_slice_ptrs(o, ptrs);
        }
        None => put_u8(o, 0),
    }
    put_u32(o, r.entries.len() as u32);
    for e in &r.entries {
        enc_region_entry(o, e);
    }
    put_u64(o, r.eof);
}

pub(crate) fn dec_region(d: &mut Dec) -> std::result::Result<RegionMeta, Corrupt> {
    let spill = match d.u8()? {
        0 => None,
        1 => Some(dec_slice_ptrs(d)?),
        t => return Err(format!("invalid spill tag {t}")),
    };
    let n = d.seq()?;
    let mut entries = Vec::with_capacity(n);
    for _ in 0..n {
        entries.push(dec_region_entry(d)?);
    }
    Ok(RegionMeta {
        spill,
        entries,
        eof: d.u64()?,
    })
}

pub(crate) fn enc_inode(o: &mut Vec<u8>, i: &Inode) {
    put_u64(o, i.id);
    put_u8(
        o,
        match i.kind {
            InodeKind::File => 0,
            InodeKind::Directory => 1,
        },
    );
    put_u32(o, i.links);
    put_u64(o, i.len);
    put_u64(o, i.mtime);
    put_u32(o, i.mode);
    put_u32(o, i.owner);
    put_u32(o, i.group);
    put_u32(o, i.highest_region);
    put_u8(o, i.replication);
}

pub(crate) fn dec_inode(d: &mut Dec) -> std::result::Result<Inode, Corrupt> {
    Ok(Inode {
        id: d.u64()?,
        kind: match d.u8()? {
            0 => InodeKind::File,
            1 => InodeKind::Directory,
            t => return Err(format!("invalid InodeKind tag {t}")),
        },
        links: d.u32()?,
        len: d.u64()?,
        mtime: d.u64()?,
        mode: d.u32()?,
        owner: d.u32()?,
        group: d.u32()?,
        highest_region: d.u32()?,
        replication: d.u8()?,
    })
}

pub(crate) fn enc_value(o: &mut Vec<u8>, v: &Value) {
    match v {
        Value::PathEntry(id) => {
            put_u8(o, 0);
            put_u64(o, *id);
        }
        Value::Inode(i) => {
            put_u8(o, 1);
            enc_inode(o, i);
        }
        Value::Region(r) => {
            put_u8(o, 2);
            enc_region(o, r);
        }
        Value::Dir(entries) => {
            put_u8(o, 3);
            put_u32(o, entries.len() as u32);
            for (name, id) in entries {
                put_str(o, name);
                put_u64(o, *id);
            }
        }
        Value::U64(n) => {
            put_u8(o, 4);
            put_u64(o, *n);
        }
        Value::Bytes(b) => {
            put_u8(o, 5);
            put_blob(o, b);
        }
    }
}

pub(crate) fn dec_value(d: &mut Dec) -> std::result::Result<Value, Corrupt> {
    match d.u8()? {
        0 => Ok(Value::PathEntry(d.u64()?)),
        1 => Ok(Value::Inode(dec_inode(d)?)),
        2 => Ok(Value::Region(dec_region(d)?)),
        3 => {
            let n = d.seq()?;
            let mut entries = DirEntries::new();
            for _ in 0..n {
                let name = d.str()?;
                entries.insert(name, d.u64()?);
            }
            Ok(Value::Dir(entries))
        }
        4 => Ok(Value::U64(d.u64()?)),
        5 => Ok(Value::Bytes(d.blob()?)),
        t => Err(format!("invalid Value tag {t}")),
    }
}

pub(crate) fn enc_opt_value(o: &mut Vec<u8>, v: &Option<Value>) {
    match v {
        Some(v) => {
            put_u8(o, 1);
            enc_value(o, v);
        }
        None => put_u8(o, 0),
    }
}

pub(crate) fn dec_opt_value(d: &mut Dec) -> std::result::Result<Option<Value>, Corrupt> {
    match d.u8()? {
        0 => Ok(None),
        1 => Ok(Some(dec_value(d)?)),
        t => Err(format!("invalid Option<Value> tag {t}")),
    }
}

pub(crate) fn enc_outcome(o: &mut Vec<u8>, oc: &OpOutcome) {
    match oc {
        OpOutcome::Done => put_u8(o, 0),
        OpOutcome::AppendedAt(off) => {
            put_u8(o, 1);
            put_u64(o, *off);
        }
    }
}

pub(crate) fn dec_outcome(d: &mut Dec) -> std::result::Result<OpOutcome, Corrupt> {
    match d.u8()? {
        0 => Ok(OpOutcome::Done),
        1 => Ok(OpOutcome::AppendedAt(d.u64()?)),
        t => Err(format!("invalid OpOutcome tag {t}")),
    }
}

pub(crate) fn enc_outcomes(o: &mut Vec<u8>, ocs: &[OpOutcome]) {
    put_u32(o, ocs.len() as u32);
    for oc in ocs {
        enc_outcome(o, oc);
    }
}

pub(crate) fn dec_outcomes(d: &mut Dec) -> std::result::Result<Vec<OpOutcome>, Corrupt> {
    let n = d.seq()?;
    let mut v = Vec::with_capacity(n);
    for _ in 0..n {
        v.push(dec_outcome(d)?);
    }
    Ok(v)
}

pub(crate) fn enc_op(o: &mut Vec<u8>, op: &MetaOp) {
    match op {
        MetaOp::Put { key, value } => {
            put_u8(o, 0);
            enc_key(o, key);
            enc_value(o, value);
        }
        MetaOp::Delete { key } => {
            put_u8(o, 1);
            enc_key(o, key);
        }
        MetaOp::RegionAppend { key, entry } => {
            put_u8(o, 2);
            enc_key(o, key);
            enc_region_entry(o, entry);
        }
        MetaOp::RegionAppendEof { key, data, len, cap } => {
            put_u8(o, 3);
            enc_key(o, key);
            enc_slice_data(o, data);
            put_u64(o, *len);
            put_u64(o, *cap);
        }
        MetaOp::RegionSwap {
            key,
            expected_version,
            region,
        } => {
            put_u8(o, 4);
            enc_key(o, key);
            put_u64(o, *expected_version);
            enc_region(o, region);
        }
        MetaOp::InodeAdjustLinks { key, delta, mtime } => {
            put_u8(o, 5);
            enc_key(o, key);
            put_i64(o, *delta);
            put_u64(o, *mtime);
        }
        MetaOp::InodeSetLenMax {
            key,
            candidate,
            highest_region,
            mtime,
        } => {
            put_u8(o, 6);
            enc_key(o, key);
            put_u64(o, *candidate);
            put_u32(o, *highest_region);
            put_u64(o, *mtime);
        }
        MetaOp::InodeSetLenFromRegion {
            inode_key,
            region_key,
            region_base,
            mtime,
        } => {
            put_u8(o, 7);
            enc_key(o, inode_key);
            enc_key(o, region_key);
            put_u64(o, *region_base);
            put_u64(o, *mtime);
        }
        MetaOp::DirInsert {
            key,
            name,
            inode,
            expect_absent,
        } => {
            put_u8(o, 8);
            enc_key(o, key);
            put_str(o, name);
            put_u64(o, *inode);
            put_bool(o, *expect_absent);
        }
        MetaOp::DirRemove { key, name } => {
            put_u8(o, 9);
            enc_key(o, key);
            put_str(o, name);
        }
        MetaOp::PathInsert {
            key,
            inode,
            expect_absent,
        } => {
            put_u8(o, 10);
            enc_key(o, key);
            put_u64(o, *inode);
            put_bool(o, *expect_absent);
        }
    }
}

pub(crate) fn dec_op(d: &mut Dec) -> std::result::Result<MetaOp, Corrupt> {
    match d.u8()? {
        0 => Ok(MetaOp::Put {
            key: dec_key(d)?,
            value: dec_value(d)?,
        }),
        1 => Ok(MetaOp::Delete { key: dec_key(d)? }),
        2 => Ok(MetaOp::RegionAppend {
            key: dec_key(d)?,
            entry: dec_region_entry(d)?,
        }),
        3 => Ok(MetaOp::RegionAppendEof {
            key: dec_key(d)?,
            data: dec_slice_data(d)?,
            len: d.u64()?,
            cap: d.u64()?,
        }),
        4 => Ok(MetaOp::RegionSwap {
            key: dec_key(d)?,
            expected_version: d.u64()?,
            region: dec_region(d)?,
        }),
        5 => Ok(MetaOp::InodeAdjustLinks {
            key: dec_key(d)?,
            delta: d.i64()?,
            mtime: d.u64()?,
        }),
        6 => Ok(MetaOp::InodeSetLenMax {
            key: dec_key(d)?,
            candidate: d.u64()?,
            highest_region: d.u32()?,
            mtime: d.u64()?,
        }),
        7 => Ok(MetaOp::InodeSetLenFromRegion {
            inode_key: dec_key(d)?,
            region_key: dec_key(d)?,
            region_base: d.u64()?,
            mtime: d.u64()?,
        }),
        8 => Ok(MetaOp::DirInsert {
            key: dec_key(d)?,
            name: d.str()?,
            inode: d.u64()?,
            expect_absent: d.bool()?,
        }),
        9 => Ok(MetaOp::DirRemove {
            key: dec_key(d)?,
            name: d.str()?,
        }),
        10 => Ok(MetaOp::PathInsert {
            key: dec_key(d)?,
            inode: d.u64()?,
            expect_absent: d.bool()?,
        }),
        t => Err(format!("invalid MetaOp tag {t}")),
    }
}

pub(crate) fn enc_entry(o: &mut Vec<u8>, e: &LogEntry) {
    put_u64(o, e.txn_id);
    put_u32(o, e.reads.len() as u32);
    for (k, v) in &e.reads {
        enc_key(o, k);
        put_u64(o, *v);
    }
    put_u32(o, e.ops.len() as u32);
    for op in &e.ops {
        enc_op(o, op);
    }
    match &e.kind {
        EntryKind::Apply => put_u8(o, 0),
        EntryKind::Prepare {
            participants,
            coordinator,
        } => {
            put_u8(o, 1);
            put_u32(o, *coordinator);
            put_u32(o, participants.len() as u32);
            for p in participants {
                put_u32(o, *p);
            }
        }
        EntryKind::Decide { commit } => {
            put_u8(o, 2);
            put_bool(o, *commit);
        }
        EntryKind::Batch(txns) => {
            put_u8(o, 3);
            put_u32(o, txns.len() as u32);
            for t in txns {
                enc_entry(o, t);
            }
        }
    }
}

pub(crate) fn dec_entry(d: &mut Dec) -> std::result::Result<LogEntry, Corrupt> {
    let txn_id = d.u64()?;
    let n = d.seq()?;
    let mut reads = Vec::with_capacity(n);
    for _ in 0..n {
        let k = dec_key(d)?;
        reads.push((k, d.u64()?));
    }
    let n = d.seq()?;
    let mut ops = Vec::with_capacity(n);
    for _ in 0..n {
        ops.push(dec_op(d)?);
    }
    let kind = match d.u8()? {
        0 => EntryKind::Apply,
        1 => {
            let coordinator = d.u32()?;
            let n = d.seq()?;
            let mut participants = Vec::with_capacity(n);
            for _ in 0..n {
                participants.push(d.u32()?);
            }
            EntryKind::Prepare {
                participants,
                coordinator,
            }
        }
        2 => EntryKind::Decide { commit: d.bool()? },
        3 => {
            let n = d.seq()?;
            let mut txns = Vec::with_capacity(n);
            for _ in 0..n {
                txns.push(dec_entry(d)?);
            }
            EntryKind::Batch(txns)
        }
        t => return Err(format!("invalid EntryKind tag {t}")),
    };
    Ok(LogEntry {
        txn_id,
        reads,
        ops,
        kind,
    })
}

// ---------------------------------------------------------------------
// WAL records and the checkpoint image.
// ---------------------------------------------------------------------

/// One durable event, logged before the acknowledgment it enables.
#[derive(Clone, Debug, PartialEq)]
pub enum WalRecord {
    /// Phase 1 granted: this replica promised `ballot` for `slot` and
    /// must never grant a lower ballot there again.
    Promise { slot: u64, ballot: Ballot },
    /// Phase 2 accepted: `entry` at `ballot` in `slot`; the value a
    /// later prepare round must adopt.
    Accept {
        slot: u64,
        ballot: Ballot,
        entry: LogEntry,
    },
    /// `slot` was decided as `entry` (the learn path, including 2PC
    /// `Prepare` intents and `Decide` records).
    Chosen { slot: u64, entry: LogEntry },
}

pub(crate) fn enc_record(o: &mut Vec<u8>, r: &WalRecord) {
    match r {
        WalRecord::Promise { slot, ballot } => {
            put_u8(o, 1);
            put_u64(o, *slot);
            enc_ballot(o, ballot);
        }
        WalRecord::Accept { slot, ballot, entry } => {
            put_u8(o, 2);
            put_u64(o, *slot);
            enc_ballot(o, ballot);
            enc_entry(o, entry);
        }
        WalRecord::Chosen { slot, entry } => {
            put_u8(o, 3);
            put_u64(o, *slot);
            enc_entry(o, entry);
        }
    }
}

pub(crate) fn dec_record(payload: &[u8]) -> std::result::Result<WalRecord, Corrupt> {
    let mut d = Dec::new(payload);
    let rec = match d.u8()? {
        1 => WalRecord::Promise {
            slot: d.u64()?,
            ballot: dec_ballot(&mut d)?,
        },
        2 => WalRecord::Accept {
            slot: d.u64()?,
            ballot: dec_ballot(&mut d)?,
            entry: dec_entry(&mut d)?,
        },
        3 => WalRecord::Chosen {
            slot: d.u64()?,
            entry: dec_entry(&mut d)?,
        },
        t => return Err(format!("invalid WalRecord tag {t}")),
    };
    d.done()?;
    Ok(rec)
}

/// One acceptor slot's durable image.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CkptSlot {
    pub promised: Ballot,
    pub accepted: Option<(Ballot, LogEntry)>,
}

/// One materialized key: value (`None` = deleted) plus its version
/// counter, which survives deletion (anti-ABA) and must be restored
/// exactly.
#[derive(Clone, Debug, PartialEq)]
pub struct CkptKv {
    pub key: Key,
    pub value: Option<Value>,
    pub version: u64,
}

/// One recorded apply result (`None` = deterministic abort).
#[derive(Clone, Debug, PartialEq)]
pub struct CkptResult {
    pub txn_id: u64,
    pub outcomes: Option<Vec<OpOutcome>>,
}

/// A staged yes-vote: the overlay a commit decision will flush.
#[derive(Clone, Debug, PartialEq)]
pub struct CkptStaged {
    pub overlay: Vec<(Key, Option<Value>)>,
    pub outcomes: Vec<OpOutcome>,
}

/// One pending 2PC intent.
#[derive(Clone, Debug, PartialEq)]
pub struct CkptIntent {
    pub txn_id: u64,
    pub coordinator: u32,
    pub participants: Vec<u32>,
    pub staged: Option<CkptStaged>,
}

/// The whole durable image of one replica at a checkpoint: acceptor
/// slots plus everything [`super::group::GroupReplica`] materializes
/// from its chosen log.  Loading a checkpoint and replaying the
/// post-checkpoint WAL suffix is indistinguishable from replaying the
/// full history.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Checkpoint {
    pub slots: Vec<CkptSlot>,
    pub log: Vec<LogEntry>,
    pub pending: Vec<(u64, LogEntry)>,
    pub kv: Vec<CkptKv>,
    pub applied: Vec<u64>,
    pub results: Vec<CkptResult>,
    pub intents: Vec<CkptIntent>,
    pub locks: Vec<(Key, u64)>,
    pub decisions: Vec<(u64, bool)>,
}

pub(crate) fn enc_checkpoint(o: &mut Vec<u8>, c: &Checkpoint) {
    put_u32(o, c.slots.len() as u32);
    for s in &c.slots {
        enc_ballot(o, &s.promised);
        match &s.accepted {
            Some((b, e)) => {
                put_u8(o, 1);
                enc_ballot(o, b);
                enc_entry(o, e);
            }
            None => put_u8(o, 0),
        }
    }
    put_u32(o, c.log.len() as u32);
    for e in &c.log {
        enc_entry(o, e);
    }
    put_u32(o, c.pending.len() as u32);
    for (slot, e) in &c.pending {
        put_u64(o, *slot);
        enc_entry(o, e);
    }
    put_u32(o, c.kv.len() as u32);
    for kv in &c.kv {
        enc_key(o, &kv.key);
        enc_opt_value(o, &kv.value);
        put_u64(o, kv.version);
    }
    put_u32(o, c.applied.len() as u32);
    for t in &c.applied {
        put_u64(o, *t);
    }
    put_u32(o, c.results.len() as u32);
    for r in &c.results {
        put_u64(o, r.txn_id);
        match &r.outcomes {
            Some(ocs) => {
                put_u8(o, 1);
                enc_outcomes(o, ocs);
            }
            None => put_u8(o, 0),
        }
    }
    put_u32(o, c.intents.len() as u32);
    for i in &c.intents {
        put_u64(o, i.txn_id);
        put_u32(o, i.coordinator);
        put_u32(o, i.participants.len() as u32);
        for p in &i.participants {
            put_u32(o, *p);
        }
        match &i.staged {
            Some(s) => {
                put_u8(o, 1);
                put_u32(o, s.overlay.len() as u32);
                for (k, v) in &s.overlay {
                    enc_key(o, k);
                    enc_opt_value(o, v);
                }
                enc_outcomes(o, &s.outcomes);
            }
            None => put_u8(o, 0),
        }
    }
    put_u32(o, c.locks.len() as u32);
    for (k, txn) in &c.locks {
        enc_key(o, k);
        put_u64(o, *txn);
    }
    put_u32(o, c.decisions.len() as u32);
    for (txn, commit) in &c.decisions {
        put_u64(o, *txn);
        put_bool(o, *commit);
    }
}

pub(crate) fn dec_checkpoint(payload: &[u8]) -> std::result::Result<Checkpoint, Corrupt> {
    let mut d = Dec::new(payload);
    let mut c = Checkpoint::default();
    let n = d.seq()?;
    for _ in 0..n {
        let promised = dec_ballot(&mut d)?;
        let accepted = match d.u8()? {
            0 => None,
            1 => {
                let b = dec_ballot(&mut d)?;
                Some((b, dec_entry(&mut d)?))
            }
            t => return Err(format!("invalid accepted tag {t}")),
        };
        c.slots.push(CkptSlot { promised, accepted });
    }
    let n = d.seq()?;
    for _ in 0..n {
        c.log.push(dec_entry(&mut d)?);
    }
    let n = d.seq()?;
    for _ in 0..n {
        let slot = d.u64()?;
        c.pending.push((slot, dec_entry(&mut d)?));
    }
    let n = d.seq()?;
    for _ in 0..n {
        let key = dec_key(&mut d)?;
        let value = dec_opt_value(&mut d)?;
        c.kv.push(CkptKv {
            key,
            value,
            version: d.u64()?,
        });
    }
    let n = d.seq()?;
    for _ in 0..n {
        c.applied.push(d.u64()?);
    }
    let n = d.seq()?;
    for _ in 0..n {
        let txn_id = d.u64()?;
        let outcomes = match d.u8()? {
            0 => None,
            1 => Some(dec_outcomes(&mut d)?),
            t => return Err(format!("invalid outcomes tag {t}")),
        };
        c.results.push(CkptResult { txn_id, outcomes });
    }
    let n = d.seq()?;
    for _ in 0..n {
        let txn_id = d.u64()?;
        let coordinator = d.u32()?;
        let np = d.seq()?;
        let mut participants = Vec::with_capacity(np);
        for _ in 0..np {
            participants.push(d.u32()?);
        }
        let staged = match d.u8()? {
            0 => None,
            1 => {
                let no = d.seq()?;
                let mut overlay = Vec::with_capacity(no);
                for _ in 0..no {
                    let k = dec_key(&mut d)?;
                    overlay.push((k, dec_opt_value(&mut d)?));
                }
                Some(CkptStaged {
                    overlay,
                    outcomes: dec_outcomes(&mut d)?,
                })
            }
            t => return Err(format!("invalid staged tag {t}")),
        };
        c.intents.push(CkptIntent {
            txn_id,
            coordinator,
            participants,
            staged,
        });
    }
    let n = d.seq()?;
    for _ in 0..n {
        let k = dec_key(&mut d)?;
        c.locks.push((k, d.u64()?));
    }
    let n = d.seq()?;
    for _ in 0..n {
        let txn = d.u64()?;
        c.decisions.push((txn, d.bool()?));
    }
    d.done()?;
    Ok(c)
}

// ---------------------------------------------------------------------
// Segment files, marker, checkpoint rotation, strict recovery.
// ---------------------------------------------------------------------

/// Where and how one replica logs: directory, fsync policy, checkpoint
/// cadence.  Plain data, retained across a crash so the replica can be
/// rebuilt from its directory alone.
#[derive(Clone, Debug)]
pub struct WalSetup {
    pub dir: PathBuf,
    pub sync: WalSync,
    /// Checkpoint (and truncate the WAL) every this many chosen
    /// records.  Must be >= 1 (validated by `Config::validate`).
    pub checkpoint_every: u64,
}

/// What [`ReplicaWal::open`] found on disk.
#[derive(Debug, Default)]
pub struct Recovered {
    /// True when the directory was newly stamped (nothing to replay and
    /// no pre-crash grants to hold off for).
    pub fresh: bool,
    /// The newest checkpoint image, if one was taken.
    pub checkpoint: Option<Checkpoint>,
    /// The post-checkpoint records, in append order.
    pub records: Vec<WalRecord>,
}

/// The open, append-position WAL of one replica.
#[derive(Debug)]
pub struct ReplicaWal {
    setup: WalSetup,
    shard: u32,
    replica: u32,
    /// Checkpoint generation: the live files are `seg-<gen>.wal` and
    /// (for gen > 0) `ckpt-<gen>.bin`.
    gen: u64,
    seg: File,
    chosen_since_ckpt: u64,
    unsynced: u64,
    /// Segment `fsync`s issued by the append path — the observable the
    /// fsync-group-commit bench rows compare (per-record vs batched).
    fsyncs: u64,
}

fn wal_corrupt(shard: u32, replica: u32, detail: impl Into<String>) -> Error {
    Error::WalCorrupt {
        shard,
        replica,
        detail: detail.into(),
    }
}

fn seg_path(dir: &Path, gen: u64) -> PathBuf {
    dir.join(format!("seg-{gen}.wal"))
}

fn ckpt_path(dir: &Path, gen: u64) -> PathBuf {
    dir.join(format!("ckpt-{gen}.bin"))
}

/// Root-level cluster marker payload: magic + format version + cluster
/// shape (shard count, replicas per group).  The store stamps this into
/// the WAL root on first boot so a differently-shaped cluster pointed at
/// the same directory refuses to interleave its segments with a
/// stranger's.
pub fn cluster_marker(shards: u32, replicas: u32) -> Vec<u8> {
    let mut out = Vec::with_capacity(16);
    out.extend_from_slice(MAGIC);
    put_u16(&mut out, FORMAT_VERSION);
    put_u32(&mut out, shards);
    put_u32(&mut out, replicas);
    out
}

/// Encode one marker payload (magic + version + identity).
fn marker_bytes(shard: u32, replica: u32) -> Vec<u8> {
    let mut out = Vec::with_capacity(16);
    out.extend_from_slice(MAGIC);
    put_u16(&mut out, FORMAT_VERSION);
    put_u32(&mut out, shard);
    put_u32(&mut out, replica);
    out
}

/// Frame `payload` as `[len][crc][payload]` and append it to `file`.
fn write_frame(file: &mut File, payload: &[u8]) -> std::io::Result<()> {
    let mut frame = Vec::with_capacity(payload.len() + 8);
    put_u32(&mut frame, payload.len() as u32);
    put_u32(&mut frame, crc32(payload));
    frame.extend_from_slice(payload);
    file.write_all(&frame)
}

/// Split a segment's bytes into validated frame payloads.  Strict: a
/// truncated header, a truncated payload, an oversized length, or a CRC
/// mismatch is corruption — the caller refuses to vote rather than
/// guess which suffix of its promises went missing.
fn decode_frames(buf: &[u8]) -> std::result::Result<Vec<&[u8]>, Corrupt> {
    let mut out = Vec::new();
    let mut pos = 0usize;
    while pos < buf.len() {
        if buf.len() - pos < 8 {
            return Err(format!("truncated frame header at offset {pos}"));
        }
        let len = u32::from_le_bytes(buf[pos..pos + 4].try_into().unwrap());
        let crc = u32::from_le_bytes(buf[pos + 4..pos + 8].try_into().unwrap());
        if len > MAX_FRAME {
            return Err(format!("oversized frame ({len} bytes) at offset {pos}"));
        }
        let start = pos + 8;
        let end = start + len as usize;
        if end > buf.len() {
            return Err(format!(
                "truncated frame payload at offset {pos}: need {len} bytes, have {}",
                buf.len() - start
            ));
        }
        let payload = &buf[start..end];
        if crc32(payload) != crc {
            return Err(format!("crc mismatch at offset {pos}"));
        }
        out.push(payload);
        pos = end;
    }
    Ok(out)
}

/// Fsync a directory so renames/creates inside it are durable.
fn sync_dir(dir: &Path) -> std::io::Result<()> {
    File::open(dir)?.sync_all()
}

impl ReplicaWal {
    /// Open (creating if absent) the WAL directory of `shard`/`replica`
    /// and strictly replay what it holds.  Any integrity failure —
    /// foreign or damaged marker, missing checkpoint, truncated or
    /// bit-flipped frame, undecodable payload — is
    /// [`Error::WalCorrupt`]; the caller must leave the replica dead.
    pub fn open(setup: WalSetup, shard: u32, replica: u32) -> Result<(ReplicaWal, Recovered)> {
        fs::create_dir_all(&setup.dir)?;
        let marker = setup.dir.join("MARKER");
        let expected = marker_bytes(shard, replica);
        let fresh = !marker.exists();
        if fresh {
            let mut f = File::create(&marker)?;
            f.write_all(&expected)?;
            f.sync_all()?;
            sync_dir(&setup.dir)?;
        } else {
            let found = fs::read(&marker)?;
            if found != expected {
                return Err(wal_corrupt(
                    shard,
                    replica,
                    format!(
                        "marker mismatch in {}: directory belongs to another \
                         replica, cluster, or format version",
                        setup.dir.display()
                    ),
                ));
            }
        }

        // The live generation is the highest numbered segment or
        // checkpoint present (they rotate together).
        let mut gen = 0u64;
        for entry in fs::read_dir(&setup.dir)? {
            let name = entry?.file_name();
            let name = name.to_string_lossy();
            let parsed = name
                .strip_prefix("seg-")
                .and_then(|s| s.strip_suffix(".wal"))
                .or_else(|| name.strip_prefix("ckpt-").and_then(|s| s.strip_suffix(".bin")));
            if let Some(n) = parsed.and_then(|s| s.parse::<u64>().ok()) {
                gen = gen.max(n);
            }
        }

        let checkpoint = {
            let path = ckpt_path(&setup.dir, gen);
            if path.exists() {
                let buf = fs::read(&path)?;
                let frames = decode_frames(&buf)
                    .map_err(|d| wal_corrupt(shard, replica, format!("checkpoint: {d}")))?;
                if frames.len() != 1 {
                    return Err(wal_corrupt(
                        shard,
                        replica,
                        format!("checkpoint holds {} frames, expected 1", frames.len()),
                    ));
                }
                let c = dec_checkpoint(frames[0])
                    .map_err(|d| wal_corrupt(shard, replica, format!("checkpoint: {d}")))?;
                Some(c)
            } else if gen > 0 {
                return Err(wal_corrupt(
                    shard,
                    replica,
                    format!("segment generation {gen} present but its checkpoint is missing"),
                ));
            } else {
                None
            }
        };

        let seg_file = seg_path(&setup.dir, gen);
        let mut records = Vec::new();
        if seg_file.exists() {
            let buf = fs::read(&seg_file)?;
            let frames = decode_frames(&buf)
                .map_err(|d| wal_corrupt(shard, replica, format!("segment {gen}: {d}")))?;
            for payload in frames {
                let rec = dec_record(payload)
                    .map_err(|d| wal_corrupt(shard, replica, format!("segment {gen}: {d}")))?;
                records.push(rec);
            }
        }
        let chosen_since_ckpt = records
            .iter()
            .filter(|r| matches!(r, WalRecord::Chosen { .. }))
            .count() as u64;

        let seg = OpenOptions::new().create(true).append(true).open(&seg_file)?;
        let wal = ReplicaWal {
            setup,
            shard,
            replica,
            gen,
            seg,
            chosen_since_ckpt,
            unsynced: 0,
            fsyncs: 0,
        };
        let recovered = Recovered {
            fresh,
            checkpoint,
            records,
        };
        Ok((wal, recovered))
    }

    /// Append one record, fsyncing per the configured [`WalSync`]
    /// policy, BEFORE the caller acknowledges the event it describes.
    pub fn append(&mut self, rec: &WalRecord) -> Result<()> {
        self.append_batch(std::slice::from_ref(rec))
    }

    /// Append a run of records that acknowledge together, applying the
    /// [`WalSync`] policy ONCE for the whole run — the fsync group
    /// commit: under `WalSync::Always` the batch pays one `sync_data`
    /// instead of one per record.  Safe because nothing in the batch is
    /// acknowledged until the batch returns: a crash mid-batch loses
    /// only never-acked records, exactly as with per-record appends.
    pub fn append_batch(&mut self, recs: &[WalRecord]) -> Result<()> {
        if recs.is_empty() {
            return Ok(());
        }
        let mut chosen = false;
        for rec in recs {
            let mut payload = Vec::new();
            enc_record(&mut payload, rec);
            write_frame(&mut self.seg, &payload)?;
            self.unsynced += 1;
            if matches!(rec, WalRecord::Chosen { .. }) {
                self.chosen_since_ckpt += 1;
                chosen = true;
            }
        }
        let sync = match self.setup.sync {
            WalSync::Always => true,
            // Batch: amortize — sync on decided entries (the client-
            // visible acks) and every BATCH_SYNC_EVERY appends; the
            // write itself still precedes every ack, so only an OS
            // crash inside the window can lose a suffix.
            WalSync::Batch => chosen || self.unsynced >= BATCH_SYNC_EVERY,
            WalSync::None => false,
        };
        if sync {
            self.seg.sync_data()?;
            self.unsynced = 0;
            self.fsyncs += 1;
        }
        Ok(())
    }

    /// Segment `fsync`s the append path has issued so far.
    pub fn fsyncs(&self) -> u64 {
        self.fsyncs
    }

    /// True once enough chosen records accumulated that the owner
    /// should take a checkpoint.
    pub fn checkpoint_due(&self) -> bool {
        self.chosen_since_ckpt >= self.setup.checkpoint_every
    }

    /// Chosen records appended since the last checkpoint (the records a
    /// restart would replay beyond the checkpoint image).
    pub fn chosen_since_checkpoint(&self) -> u64 {
        self.chosen_since_ckpt
    }

    /// Current checkpoint generation (observability/tests).
    pub fn generation(&self) -> u64 {
        self.gen
    }

    /// Write `image` as the next checkpoint generation and truncate:
    /// tmp-write + fsync + rename the checkpoint, open a fresh segment,
    /// fsync the directory, then delete the previous generation.  After
    /// this, recovery loads the image and replays only the new
    /// segment's records.
    pub fn install_checkpoint(&mut self, image: &Checkpoint) -> Result<()> {
        let next = self.gen + 1;
        let mut payload = Vec::new();
        enc_checkpoint(&mut payload, image);
        let tmp = self.setup.dir.join(format!("ckpt-{next}.tmp"));
        {
            let mut f = File::create(&tmp)?;
            write_frame(&mut f, &payload)?;
            f.sync_all()?;
        }
        fs::rename(&tmp, ckpt_path(&self.setup.dir, next))?;
        let seg = OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(true)
            .open(seg_path(&self.setup.dir, next))?;
        sync_dir(&self.setup.dir)?;
        let _ = fs::remove_file(seg_path(&self.setup.dir, self.gen));
        if self.gen > 0 {
            let _ = fs::remove_file(ckpt_path(&self.setup.dir, self.gen));
        }
        self.gen = next;
        self.seg = seg;
        self.chosen_since_ckpt = 0;
        self.unsynced = 0;
        Ok(())
    }

    /// The identity this WAL was stamped with.
    pub fn identity(&self) -> (u32, u32) {
        (self.shard, self.replica)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::TempDir;

    fn setup(dir: &Path) -> WalSetup {
        WalSetup {
            dir: dir.to_path_buf(),
            sync: WalSync::Always,
            checkpoint_every: 4,
        }
    }

    fn rich_entry(txn_id: u64) -> LogEntry {
        let ptrs = vec![SlicePtr {
            server: 3,
            backing: 1,
            offset: 4096,
            len: 128,
        }];
        let mut dir = DirEntries::new();
        dir.insert("a".into(), 7);
        dir.insert("b".into(), 9);
        LogEntry {
            txn_id,
            reads: vec![(Key::sys("r"), 5)],
            ops: vec![
                MetaOp::Put {
                    key: Key::sys("p"),
                    value: Value::Inode(Inode::new_file(11, 0o644, 2)),
                },
                MetaOp::Delete { key: Key::sys("d") },
                MetaOp::RegionAppend {
                    key: Key::new(Space::Region, "rg".into()),
                    entry: RegionEntry {
                        placement: Placement::At(64),
                        len: 128,
                        data: SliceData::Stored(ptrs.clone()),
                    },
                },
                MetaOp::RegionAppendEof {
                    key: Key::new(Space::Region, "rg".into()),
                    data: SliceData::Hole,
                    len: 32,
                    cap: 4096,
                },
                MetaOp::RegionSwap {
                    key: Key::new(Space::Region, "rg".into()),
                    expected_version: 3,
                    region: RegionMeta {
                        spill: Some(ptrs),
                        entries: vec![RegionEntry {
                            placement: Placement::Eof,
                            len: 16,
                            data: SliceData::Hole,
                        }],
                        eof: 144,
                    },
                },
                MetaOp::InodeAdjustLinks {
                    key: Key::new(Space::Inode, "i".into()),
                    delta: -1,
                    mtime: 99,
                },
                MetaOp::InodeSetLenMax {
                    key: Key::new(Space::Inode, "i".into()),
                    candidate: 1 << 20,
                    highest_region: 4,
                    mtime: 100,
                },
                MetaOp::InodeSetLenFromRegion {
                    inode_key: Key::new(Space::Inode, "i".into()),
                    region_key: Key::new(Space::Region, "rg".into()),
                    region_base: 1 << 16,
                    mtime: 101,
                },
                MetaOp::DirInsert {
                    key: Key::new(Space::Dir, "dd".into()),
                    name: "child".into(),
                    inode: 12,
                    expect_absent: true,
                },
                MetaOp::DirRemove {
                    key: Key::new(Space::Dir, "dd".into()),
                    name: "old".into(),
                },
                MetaOp::PathInsert {
                    key: Key::new(Space::Path, "/x".into()),
                    inode: 12,
                    expect_absent: false,
                },
            ],
            kind: EntryKind::Apply,
        }
    }

    fn roundtrip_entry(e: &LogEntry) -> LogEntry {
        let mut buf = Vec::new();
        enc_entry(&mut buf, e);
        let mut d = Dec::new(&buf);
        let back = dec_entry(&mut d).unwrap();
        d.done().unwrap();
        back
    }

    #[test]
    fn codec_roundtrips_every_op_and_kind() {
        let apply = rich_entry(1);
        assert_eq!(roundtrip_entry(&apply), apply);

        let prepare = LogEntry {
            kind: EntryKind::Prepare {
                participants: vec![0, 2, 5],
                coordinator: 0,
            },
            ..rich_entry(2)
        };
        assert_eq!(roundtrip_entry(&prepare), prepare);

        let decide = LogEntry::decide(2, true);
        assert_eq!(roundtrip_entry(&decide), decide);

        let batch = LogEntry::batch(9, vec![rich_entry(3), LogEntry::noop()]);
        assert_eq!(roundtrip_entry(&batch), batch);

        let dir_value = {
            let mut m = DirEntries::new();
            m.insert("n".into(), 42);
            Value::Dir(m)
        };
        for v in [
            Value::PathEntry(5),
            dir_value,
            Value::U64(77),
            Value::Bytes(vec![0, 255, 3]),
        ] {
            let mut buf = Vec::new();
            enc_value(&mut buf, &v);
            let mut d = Dec::new(&buf);
            assert_eq!(dec_value(&mut d).unwrap(), v);
        }
    }

    #[test]
    fn checkpoint_roundtrips() {
        let c = Checkpoint {
            slots: vec![
                CkptSlot {
                    promised: Ballot {
                        round: 3,
                        proposer: 1,
                    },
                    accepted: Some((
                        Ballot {
                            round: 3,
                            proposer: 1,
                        },
                        rich_entry(4),
                    )),
                },
                CkptSlot::default(),
            ],
            log: vec![rich_entry(1), LogEntry::decide(1, false)],
            pending: vec![(7, rich_entry(5))],
            kv: vec![
                CkptKv {
                    key: Key::sys("k"),
                    value: Some(Value::U64(9)),
                    version: 2,
                },
                CkptKv {
                    key: Key::sys("gone"),
                    value: None,
                    version: 5,
                },
            ],
            applied: vec![1, 4],
            results: vec![
                CkptResult {
                    txn_id: 1,
                    outcomes: Some(vec![OpOutcome::Done, OpOutcome::AppendedAt(64)]),
                },
                CkptResult {
                    txn_id: 4,
                    outcomes: None,
                },
            ],
            intents: vec![CkptIntent {
                txn_id: 8,
                coordinator: 0,
                participants: vec![0, 1],
                staged: Some(CkptStaged {
                    overlay: vec![(Key::sys("k"), Some(Value::U64(10)))],
                    outcomes: vec![OpOutcome::Done],
                }),
            }],
            locks: vec![(Key::sys("k"), 8)],
            decisions: vec![(1, false)],
        };
        let mut buf = Vec::new();
        enc_checkpoint(&mut buf, &c);
        assert_eq!(dec_checkpoint(&buf).unwrap(), c);
    }

    #[test]
    fn fresh_open_append_reopen_replays_in_order() {
        let t = TempDir::new("wtf-wal").unwrap();
        let (mut wal, rec) = ReplicaWal::open(setup(t.path()), 0, 1).unwrap();
        assert!(rec.fresh);
        assert!(rec.checkpoint.is_none() && rec.records.is_empty());

        let b = Ballot {
            round: 1,
            proposer: 0,
        };
        let records = vec![
            WalRecord::Promise { slot: 0, ballot: b },
            WalRecord::Accept {
                slot: 0,
                ballot: b,
                entry: rich_entry(1),
            },
            WalRecord::Chosen {
                slot: 0,
                entry: rich_entry(1),
            },
        ];
        for r in &records {
            wal.append(r).unwrap();
        }
        drop(wal);

        let (wal, rec) = ReplicaWal::open(setup(t.path()), 0, 1).unwrap();
        assert!(!rec.fresh, "a stamped directory is a restart");
        assert_eq!(rec.records, records);
        assert_eq!(wal.chosen_since_checkpoint(), 1);
    }

    #[test]
    fn batched_appends_share_one_fsync_under_always() {
        let t = TempDir::new("wtf-wal").unwrap();
        let s = || WalSetup {
            dir: t.path().to_path_buf(),
            sync: WalSync::Always,
            checkpoint_every: 1 << 30,
        };
        let (mut wal, _) = ReplicaWal::open(s(), 0, 0).unwrap();
        // Per-record appends: one fsync each.
        for i in 0..8 {
            wal.append(&WalRecord::Chosen {
                slot: i,
                entry: rich_entry(i + 1),
            })
            .unwrap();
        }
        assert_eq!(wal.fsyncs(), 8);
        // Records that acknowledge together sync together: one fsync
        // for the whole batch.
        let batch: Vec<WalRecord> = (8..16)
            .map(|i| WalRecord::Chosen {
                slot: i,
                entry: rich_entry(i + 1),
            })
            .collect();
        wal.append_batch(&batch).unwrap();
        assert_eq!(wal.fsyncs(), 9, "group commit shares one fsync");
        assert_eq!(wal.chosen_since_checkpoint(), 16);
        drop(wal);
        // Replay sees every record regardless of how it was synced.
        let (_, rec) = ReplicaWal::open(s(), 0, 0).unwrap();
        assert_eq!(rec.records.len(), 16);
    }

    #[test]
    fn marker_refuses_a_foreign_replica() {
        let t = TempDir::new("wtf-wal").unwrap();
        let (wal, _) = ReplicaWal::open(setup(t.path()), 0, 1).unwrap();
        drop(wal);
        let err = ReplicaWal::open(setup(t.path()), 0, 2).unwrap_err();
        assert!(
            matches!(err, Error::WalCorrupt { shard: 0, replica: 2, .. }),
            "foreign marker must be typed corruption, got {err:?}"
        );
    }

    #[test]
    fn bit_flip_and_truncation_are_corruption() {
        let t = TempDir::new("wtf-wal").unwrap();
        let (mut wal, _) = ReplicaWal::open(setup(t.path()), 0, 0).unwrap();
        for i in 0..3 {
            wal.append(&WalRecord::Chosen {
                slot: i,
                entry: rich_entry(i + 1),
            })
            .unwrap();
        }
        drop(wal);
        let seg = seg_path(t.path(), 0);
        let pristine = fs::read(&seg).unwrap();

        // Flip one payload byte mid-file.
        let mut flipped = pristine.clone();
        let mid = flipped.len() / 2;
        flipped[mid] ^= 0x40;
        fs::write(&seg, &flipped).unwrap();
        let err = ReplicaWal::open(setup(t.path()), 0, 0).unwrap_err();
        assert!(matches!(err, Error::WalCorrupt { .. }), "bit flip: {err:?}");

        // Truncate mid-record.
        fs::write(&seg, &pristine[..pristine.len() - 5]).unwrap();
        let err = ReplicaWal::open(setup(t.path()), 0, 0).unwrap_err();
        assert!(matches!(err, Error::WalCorrupt { .. }), "truncation: {err:?}");
    }

    #[test]
    fn checkpoint_rotates_and_truncates() {
        let t = TempDir::new("wtf-wal").unwrap();
        let (mut wal, _) = ReplicaWal::open(setup(t.path()), 2, 0).unwrap();
        for i in 0..4 {
            wal.append(&WalRecord::Chosen {
                slot: i,
                entry: rich_entry(i + 1),
            })
            .unwrap();
        }
        assert!(wal.checkpoint_due());
        let image = Checkpoint {
            log: (0..4).map(|i| rich_entry(i + 1)).collect(),
            ..Checkpoint::default()
        };
        wal.install_checkpoint(&image).unwrap();
        assert_eq!(wal.generation(), 1);
        assert!(!wal.checkpoint_due());
        assert!(
            !seg_path(t.path(), 0).exists(),
            "previous generation not truncated"
        );
        wal.append(&WalRecord::Chosen {
            slot: 4,
            entry: rich_entry(5),
        })
        .unwrap();
        drop(wal);

        let (_, rec) = ReplicaWal::open(setup(t.path()), 2, 0).unwrap();
        assert_eq!(rec.checkpoint, Some(image));
        assert_eq!(rec.records.len(), 1, "only the post-checkpoint suffix replays");
    }

    #[test]
    fn missing_checkpoint_for_a_rotated_segment_is_corruption() {
        let t = TempDir::new("wtf-wal").unwrap();
        let (mut wal, _) = ReplicaWal::open(setup(t.path()), 0, 0).unwrap();
        wal.append(&WalRecord::Chosen {
            slot: 0,
            entry: rich_entry(1),
        })
        .unwrap();
        wal.install_checkpoint(&Checkpoint::default()).unwrap();
        drop(wal);
        fs::remove_file(ckpt_path(t.path(), 1)).unwrap();
        let err = ReplicaWal::open(setup(t.path()), 0, 0).unwrap_err();
        assert!(matches!(err, Error::WalCorrupt { .. }), "{err:?}");
    }
}
