//! One metadata shard: a versioned key-value map replicated along a chain.
//!
//! HyperDex places each partition on an f+1 replica chain coordinated by
//! value-dependent chaining (§2.9); writes enter at the head and
//! propagate to the tail, reads are served by the tail.  In-process we
//! hold the whole chain of one shard under a single lock, which preserves
//! the observable semantics (linearizable per-shard ops, survival of f
//! replica failures, resync on recovery) without a wire protocol.
//!
//! Versions live beside the replicas and persist across deletions, so a
//! delete+recreate cannot produce an ABA false-validation of a
//! transaction's read set.

use crate::types::{Key, Value};
use std::sync::{Mutex, MutexGuard};
use std::collections::HashMap;

/// Stable FNV-1a shard placement, shared by the chain store and the
/// Paxos-replicated store — both backends MUST place a key identically
/// (independent of process hash seeds).
pub(crate) fn shard_of_key(key: &Key, shards: usize) -> usize {
    let mut h: u64 = 0xcbf29ce484222325;
    let mut feed = |b: u8| {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x100000001b3);
    };
    feed(key.space as u8);
    for b in key.key.as_bytes() {
        feed(*b);
    }
    (h % shards as u64) as usize
}

/// A single replica's versioned key-value state: one map plus the
/// per-key mutation counter (which survives deletion — anti-ABA, same
/// rule as the chain's shared version history below).
///
/// This is the unit a *Paxos group* replica materializes from its log
/// ([`crate::meta::ShardGroup`]); the chain replicas of [`ShardInner`]
/// keep their original shared-version layout.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct KvState {
    map: HashMap<Key, Value>,
    versions: HashMap<Key, u64>,
}

impl KvState {
    pub fn get(&self, key: &Key) -> Option<&Value> {
        self.map.get(key)
    }

    /// Current version of `key` (0 = never mutated).
    pub fn version(&self, key: &Key) -> u64 {
        self.versions.get(key).copied().unwrap_or(0)
    }

    /// Apply one mutation (`None` deletes) and bump the version.
    pub fn set(&mut self, key: &Key, value: Option<Value>) {
        match value {
            Some(v) => {
                self.map.insert(key.clone(), v);
            }
            None => {
                self.map.remove(key);
            }
        }
        *self.versions.entry(key.clone()).or_insert(0) += 1;
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&Key, &Value)> {
        self.map.iter()
    }

    /// Every key that ever mutated, with its current value (`None` =
    /// deleted) and version — the full durable image, including the
    /// deletion tombstones `iter()` cannot see.  WAL checkpoints
    /// persist exactly this so anti-ABA validation survives a restart.
    pub fn iter_versions(&self) -> impl Iterator<Item = (&Key, Option<&Value>, u64)> {
        self.versions
            .iter()
            .map(|(k, v)| (k, self.map.get(k), *v))
    }

    /// Restore one key from a checkpoint image WITHOUT bumping the
    /// version counter (the inverse of [`KvState::iter_versions`]).
    pub fn restore_entry(&mut self, key: &Key, value: Option<Value>, version: u64) {
        if let Some(v) = value {
            self.map.insert(key.clone(), v);
        }
        self.versions.insert(key.clone(), version);
    }
}

/// A replica's materialized state.
#[derive(Clone, Debug, Default)]
struct Replica {
    alive: bool,
    map: HashMap<Key, Value>,
}

/// Shard interior: the replica chain plus the version history.
#[derive(Debug, Default)]
pub struct ShardInner {
    replicas: Vec<Replica>,
    /// Mutation counter per key; survives deletion (anti-ABA).
    versions: HashMap<Key, u64>,
}

impl ShardInner {
    /// Current value as observed at the tail of the chain.
    pub fn get(&self, key: &Key) -> Option<&Value> {
        self.tail().and_then(|r| r.map.get(key))
    }

    /// Current version of `key` (0 = never mutated).
    pub fn version(&self, key: &Key) -> u64 {
        self.versions.get(key).copied().unwrap_or(0)
    }

    /// Apply one mutation to every live replica in chain order and bump
    /// the version.  `None` deletes.
    pub fn set(&mut self, key: &Key, value: Option<Value>) {
        for r in self.replicas.iter_mut().filter(|r| r.alive) {
            match &value {
                Some(v) => {
                    r.map.insert(key.clone(), v.clone());
                }
                None => {
                    r.map.remove(key);
                }
            }
        }
        *self.versions.entry(key.clone()).or_insert(0) += 1;
    }

    fn tail(&self) -> Option<&Replica> {
        self.replicas.iter().rev().find(|r| r.alive)
    }

    fn head(&self) -> Option<&Replica> {
        self.replicas.iter().find(|r| r.alive)
    }

    /// Number of live replicas.
    pub fn alive(&self) -> usize {
        self.replicas.iter().filter(|r| r.alive).count()
    }

    /// Keys present at the tail (for GC scans).
    pub fn iter_tail(&self) -> impl Iterator<Item = (&Key, &Value)> {
        self.tail().into_iter().flat_map(|r| r.map.iter())
    }
}

/// A shard handle; all access goes through [`Shard::lock`] so the
/// multi-shard commit protocol can hold several shards at once.
#[derive(Debug)]
pub struct Shard {
    inner: Mutex<ShardInner>,
}

/// Observability snapshot for one shard.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardStats {
    pub keys: usize,
    pub live_replicas: usize,
    pub total_replicas: usize,
}

impl Shard {
    /// A shard with `replicas` chain members, all initially alive.
    pub fn new(replicas: usize) -> Self {
        assert!(replicas >= 1, "a shard needs at least one replica");
        Shard {
            inner: Mutex::new(ShardInner {
                replicas: (0..replicas)
                    .map(|_| Replica {
                        alive: true,
                        map: HashMap::new(),
                    })
                    .collect(),
                versions: HashMap::new(),
            }),
        }
    }

    pub fn lock(&self) -> MutexGuard<'_, ShardInner> {
        self.inner.lock().unwrap()
    }

    /// Fail one chain member.  Ops keep flowing through the survivors; the
    /// shard is unavailable only when every replica is dead.
    pub fn kill_replica(&self, idx: usize) {
        let mut g = self.inner.lock().unwrap();
        if let Some(r) = g.replicas.get_mut(idx) {
            r.alive = false;
            r.map.clear(); // its state is gone
        }
    }

    /// Recover a chain member by resyncing its state from a live neighbor
    /// (the head, per value-dependent chaining's recovery).
    pub fn recover_replica(&self, idx: usize) {
        let mut g = self.inner.lock().unwrap();
        let Some(snapshot) = g.head().map(|h| h.map.clone()) else {
            return; // nothing alive to resync from
        };
        if let Some(r) = g.replicas.get_mut(idx) {
            r.map = snapshot;
            r.alive = true;
        }
    }

    pub fn stats(&self) -> ShardStats {
        let g = self.inner.lock().unwrap();
        ShardStats {
            keys: g.tail().map(|r| r.map.len()).unwrap_or(0),
            live_replicas: g.alive(),
            total_replicas: g.replicas.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::Space;

    fn k(s: &str) -> Key {
        Key::new(Space::Sys, s)
    }

    #[test]
    fn kv_state_versions_survive_delete() {
        let mut s = KvState::default();
        assert_eq!(s.version(&k("a")), 0);
        s.set(&k("a"), Some(Value::U64(1)));
        assert_eq!(s.get(&k("a")), Some(&Value::U64(1)));
        assert_eq!(s.version(&k("a")), 1);
        s.set(&k("a"), None);
        assert_eq!(s.get(&k("a")), None);
        assert_eq!(s.version(&k("a")), 2, "version outlives deletion");
        assert!(s.is_empty());
        s.set(&k("a"), Some(Value::U64(1)));
        assert_eq!(s.version(&k("a")), 3, "no ABA after recreate");
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn set_get_version() {
        let shard = Shard::new(2);
        let mut g = shard.lock();
        assert_eq!(g.version(&k("a")), 0);
        g.set(&k("a"), Some(Value::U64(1)));
        assert_eq!(g.get(&k("a")), Some(&Value::U64(1)));
        assert_eq!(g.version(&k("a")), 1);
        g.set(&k("a"), Some(Value::U64(2)));
        assert_eq!(g.version(&k("a")), 2);
    }

    #[test]
    fn versions_survive_delete_no_aba() {
        let shard = Shard::new(2);
        let mut g = shard.lock();
        g.set(&k("a"), Some(Value::U64(1)));
        g.set(&k("a"), None);
        assert_eq!(g.get(&k("a")), None);
        assert_eq!(g.version(&k("a")), 2);
        g.set(&k("a"), Some(Value::U64(1)));
        // A transaction that read version 1 must NOT validate now.
        assert_eq!(g.version(&k("a")), 3);
    }

    #[test]
    fn chain_survives_f_failures() {
        let shard = Shard::new(3);
        {
            let mut g = shard.lock();
            g.set(&k("a"), Some(Value::U64(7)));
        }
        shard.kill_replica(2); // tail dies
        {
            let g = shard.lock();
            assert_eq!(g.get(&k("a")), Some(&Value::U64(7)));
            assert_eq!(g.alive(), 2);
        }
        shard.kill_replica(0); // head dies too
        {
            let mut g = shard.lock();
            assert_eq!(g.get(&k("a")), Some(&Value::U64(7)));
            g.set(&k("b"), Some(Value::U64(8)));
            assert_eq!(g.get(&k("b")), Some(&Value::U64(8)));
        }
    }

    #[test]
    fn recovery_resyncs_from_head() {
        let shard = Shard::new(2);
        shard.kill_replica(1);
        {
            let mut g = shard.lock();
            g.set(&k("a"), Some(Value::U64(1)));
            g.set(&k("b"), Some(Value::U64(2)));
        }
        shard.recover_replica(1);
        shard.kill_replica(0); // now only the recovered replica remains
        {
            let g = shard.lock();
            assert_eq!(g.get(&k("a")), Some(&Value::U64(1)));
            assert_eq!(g.get(&k("b")), Some(&Value::U64(2)));
        }
    }

    #[test]
    fn stats_reflect_chain_state() {
        let shard = Shard::new(3);
        {
            let mut g = shard.lock();
            g.set(&k("x"), Some(Value::U64(1)));
        }
        shard.kill_replica(1);
        let s = shard.stats();
        assert_eq!(
            s,
            ShardStats {
                keys: 1,
                live_replicas: 2,
                total_replicas: 3
            }
        );
    }
}
