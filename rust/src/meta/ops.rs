//! Transaction operations and their apply-time semantics.
//!
//! Ops are designed so the common filesystem mutations are *blind* or
//! *conditional* — they carry enough context to validate and apply under
//! the shard locks without having been preceded by a conflicting read.
//! This is what lets concurrent appends to one file commute (§2.5) and is
//! the reason WTF transactions rarely abort.

use crate::error::{Error, Result};
use crate::types::{InodeId, Key, Placement, RegionEntry, RegionMeta, SliceData, Value};
use std::collections::HashMap;

/// One mutation inside a metadata transaction.
#[derive(Clone, Debug, PartialEq)]
pub enum MetaOp {
    /// Set `key` to `value` unconditionally.
    Put { key: Key, value: Value },
    /// Remove `key` (idempotent).
    Delete { key: Key },
    /// Blind append of an overlay entry to a region list (§2.1).  The
    /// entry's placement must be `At(_)`; `Eof` placements go through
    /// [`MetaOp::RegionAppendEof`].
    RegionAppend { key: Key, entry: RegionEntry },
    /// Conditional EOF-relative append (§2.5): appends at the region's
    /// current end iff `eof + len <= cap`; otherwise the whole transaction
    /// fails with [`Error::CondAppendFailed`] and the writer falls back to
    /// an explicit-offset write.
    RegionAppendEof {
        key: Key,
        data: SliceData,
        len: u64,
        cap: u64,
    },
    /// Compare-and-swap a whole region list — metadata compaction (§2.8).
    /// Fails the transaction with a conflict if the version moved.
    RegionSwap {
        key: Key,
        expected_version: u64,
        region: RegionMeta,
    },
    /// Blind link-count adjustment on an inode (hardlink/unlink, §2.4).
    InodeAdjustLinks { key: Key, delta: i64, mtime: u64 },
    /// Monotone-max update of an inode's length + highest-region hint.
    /// Concurrent writers race harmlessly: max is commutative.
    InodeSetLenMax {
        key: Key,
        candidate: u64,
        highest_region: u32,
        mtime: u64,
    },
    /// Set the inode length to `region_base + region.eof` *after* this
    /// transaction's region ops applied — used by EOF-relative appends
    /// whose final offset is unknown until commit.
    InodeSetLenFromRegion {
        inode_key: Key,
        region_key: Key,
        region_base: u64,
        mtime: u64,
    },
    /// Insert `name -> inode` into a directory; with `expect_absent`, fail
    /// the transaction with `AlreadyExists` if the name is taken.
    DirInsert {
        key: Key,
        name: String,
        inode: InodeId,
        expect_absent: bool,
    },
    /// Remove `name` from a directory; fails with `NotFound` if absent.
    DirRemove { key: Key, name: String },
    /// Insert a path-map entry iff absent (atomic create, §2.4).
    PathInsert {
        key: Key,
        inode: InodeId,
        expect_absent: bool,
    },
}

impl MetaOp {
    /// The key this op mutates (for `InodeSetLenFromRegion`, the inode).
    pub fn key(&self) -> &Key {
        match self {
            MetaOp::Put { key, .. }
            | MetaOp::Delete { key }
            | MetaOp::RegionAppend { key, .. }
            | MetaOp::RegionAppendEof { key, .. }
            | MetaOp::RegionSwap { key, .. }
            | MetaOp::InodeAdjustLinks { key, .. }
            | MetaOp::InodeSetLenMax { key, .. }
            | MetaOp::DirInsert { key, .. }
            | MetaOp::DirRemove { key, .. }
            | MetaOp::PathInsert { key, .. } => key,
            MetaOp::InodeSetLenFromRegion { inode_key, .. } => inode_key,
        }
    }

    /// All keys whose shards must be locked to apply this op.
    pub fn keys(&self) -> Vec<&Key> {
        match self {
            MetaOp::InodeSetLenFromRegion {
                inode_key,
                region_key,
                ..
            } => vec![inode_key, region_key],
            other => vec![other.key()],
        }
    }

    /// True when this op PUBLISHES a namespace root (a Path or Dir
    /// entry) that readers resolve other objects through.  Multi-shard
    /// commits propose such entries LAST so a gate-free read can never
    /// follow a fresh root to a referent that has not landed yet.
    pub(crate) fn inserts_namespace_root(&self) -> bool {
        matches!(
            self,
            MetaOp::PathInsert { .. } | MetaOp::DirInsert { .. }
        )
    }

    /// True when this op RETIRES a namespace root.  Multi-shard commits
    /// propose such entries FIRST, so the root disappears before its
    /// referent does.
    pub(crate) fn removes_namespace_root(&self) -> bool {
        match self {
            MetaOp::DirRemove { .. } => true,
            MetaOp::Delete { key } => key.space == crate::types::Space::Path,
            _ => false,
        }
    }

    /// True for ops on the namespace keyspaces themselves (Path/Dir) —
    /// the keys the reader-isolation entry hold covers while a mixed
    /// insert+remove multi-shard commit is in flight.
    pub(crate) fn touches_namespace(&self) -> bool {
        self.keys().iter().any(|k| {
            matches!(
                k.space,
                crate::types::Space::Path | crate::types::Space::Dir
            )
        })
    }
}

/// The per-op result surfaced to the committing client.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpOutcome {
    /// Nothing interesting to report.
    Done,
    /// An EOF-relative append landed at this region-relative offset.
    AppendedAt(u64),
}

/// Stage `ops_list` against the committed state with an overlay, so each
/// op observes its predecessors — THE commit semantics, shared by the
/// chain store's locked commit, the replicated front-end's staging, and
/// replica-side apply (one implementation, so conflict semantics cannot
/// diverge between backends).
///
/// `committed` resolves a key to its committed `(value, version)` in one
/// view read; validation always checks the *committed* version (CAS ops
/// compare against what their reads observed, not the overlay).  The
/// overlay holds staged values by *take* (not clone), so repeated ops on
/// one key — e.g. a concat appending thousands of entries to one region
/// — stay O(total entries), not O(n²).
///
/// `on_staged` runs after each op applies, with the overlay-aware peek —
/// the replicated front-end uses it to rewrite cross-shard ops with
/// their resolved values.
///
/// Returns the final overlay (to flush into the store) and the per-op
/// outcomes.  Any validation failure aborts with nothing to flush.
#[allow(clippy::type_complexity)]
pub(crate) fn stage(
    ops_list: &[MetaOp],
    committed: &dyn Fn(&Key) -> Result<(Option<Value>, u64)>,
    mut on_staged: impl FnMut(&MetaOp, &dyn Fn(&Key) -> Option<Value>),
) -> Result<(HashMap<Key, Option<Value>>, Vec<OpOutcome>)> {
    let mut overlay: HashMap<Key, Option<Value>> = HashMap::new();
    // Committed version per key, cached at first fetch: repeated ops on
    // one key take their staged value from the overlay and their version
    // from here — ONE committed read (and one value clone) per distinct
    // key, keeping bulk single-key transactions O(total entries).
    let mut versions: HashMap<Key, u64> = HashMap::new();
    let mut outcomes = Vec::with_capacity(ops_list.len());
    for op in ops_list {
        let key = op.key().clone();
        let (current, version): (Option<Value>, u64) = match overlay.remove(&key) {
            Some(staged) => {
                let v = *versions
                    .get(&key)
                    .expect("overlay-staged key always has a cached version");
                (staged, v)
            }
            None => {
                let (cv, v) = committed(&key)?;
                versions.insert(key.clone(), v);
                (cv, v)
            }
        };
        validate(op, current.as_ref(), version)?;
        // `apply`'s peek contract is infallible, so a failing view read
        // inside it is stashed and re-raised right after — an unreadable
        // key must abort the staging, never read as absent.
        let peek_failure: std::cell::RefCell<Option<Error>> = std::cell::RefCell::new(None);
        let peek = |k: &Key| match overlay.get(k) {
            Some(staged) => staged.clone(),
            None => match committed(k) {
                Ok((v, _)) => v,
                Err(e) => {
                    let mut slot = peek_failure.borrow_mut();
                    if slot.is_none() {
                        *slot = Some(e);
                    }
                    None
                }
            },
        };
        let (next, outcome) = apply(op, current, &peek)?;
        on_staged(op, &peek);
        if let Some(e) = peek_failure.borrow_mut().take() {
            return Err(e);
        }
        overlay.insert(key, next);
        outcomes.push(outcome);
    }
    Ok((overlay, outcomes))
}

/// Validate an op against the current value of its key *before* any
/// mutation is applied (all-or-nothing commit).  `version` is the current
/// version of the key (0 = absent).
pub fn validate(op: &MetaOp, current: Option<&Value>, version: u64) -> Result<()> {
    match op {
        MetaOp::Put { .. } | MetaOp::Delete { .. } => Ok(()),
        MetaOp::RegionAppend { key, entry } => {
            if matches!(entry.placement, Placement::Eof) {
                return Err(Error::CorruptMetadata(format!(
                    "RegionAppend with Eof placement on {key:?}; use RegionAppendEof"
                )));
            }
            expect_region_or_absent(key, current)
        }
        MetaOp::RegionAppendEof { key, len, cap, .. } => {
            let eof = match current {
                None => 0,
                Some(v) => region_of(key, v)?.eof,
            };
            if eof + len > *cap {
                return Err(Error::CondAppendFailed {
                    eof,
                    len: *len,
                    cap: *cap,
                });
            }
            Ok(())
        }
        MetaOp::RegionSwap {
            key,
            expected_version,
            ..
        } => {
            if version != *expected_version {
                return Err(Error::TxnConflict {
                    space: key.space,
                    key: key.key.clone(),
                });
            }
            expect_region_or_absent(key, current)
        }
        MetaOp::InodeAdjustLinks { key, .. }
        | MetaOp::InodeSetLenMax { key, .. }
        | MetaOp::InodeSetLenFromRegion {
            inode_key: key, ..
        } => match current {
            Some(Value::Inode(_)) => Ok(()),
            _ => Err(Error::CorruptMetadata(format!(
                "inode op on non-inode key {key:?}"
            ))),
        },
        MetaOp::DirInsert {
            key,
            name,
            expect_absent,
            ..
        } => {
            let dir = match current {
                None => return Ok(()), // created on apply
                Some(v) => dir_of(key, v)?,
            };
            if *expect_absent && dir.contains_key(name) {
                return Err(Error::AlreadyExists(name.clone()));
            }
            Ok(())
        }
        MetaOp::DirRemove { key, name } => {
            let dir = match current {
                None => return Err(Error::NotFound(name.clone())),
                Some(v) => dir_of(key, v)?,
            };
            if !dir.contains_key(name) {
                return Err(Error::NotFound(name.clone()));
            }
            Ok(())
        }
        MetaOp::PathInsert {
            key, expect_absent, ..
        } => {
            if *expect_absent && current.is_some() {
                return Err(Error::AlreadyExists(key.key.clone()));
            }
            Ok(())
        }
    }
}

/// Apply a validated op, returning the new value (None = delete) and the
/// outcome.  `current` is the pre-op value.
///
/// `region_peek` resolves the *current-transaction* state of another key
/// (used by `InodeSetLenFromRegion`, which must observe this commit's own
/// region appends).
pub fn apply(
    op: &MetaOp,
    current: Option<Value>,
    region_peek: &dyn Fn(&Key) -> Option<Value>,
) -> Result<(Option<Value>, OpOutcome)> {
    match op {
        MetaOp::Put { value, .. } => Ok((Some(value.clone()), OpOutcome::Done)),
        MetaOp::Delete { .. } => Ok((None, OpOutcome::Done)),
        MetaOp::RegionAppend { key, entry } => {
            let mut region = take_region_or_default(key, current)?;
            if let Placement::At(at) = entry.placement {
                region.eof = region.eof.max(at + entry.len);
            }
            region.entries.push(entry.clone());
            Ok((Some(Value::Region(region)), OpOutcome::Done))
        }
        MetaOp::RegionAppendEof { key, data, len, .. } => {
            let mut region = take_region_or_default(key, current)?;
            let at = region.eof;
            region.entries.push(RegionEntry {
                placement: Placement::At(at),
                len: *len,
                data: data.clone(),
            });
            region.eof = at + len;
            Ok((Some(Value::Region(region)), OpOutcome::AppendedAt(at)))
        }
        MetaOp::RegionSwap { region, .. } => {
            Ok((Some(Value::Region(region.clone())), OpOutcome::Done))
        }
        MetaOp::InodeAdjustLinks { key, delta, mtime } => {
            let mut inode = take_inode(key, current)?;
            let links = i64::from(inode.links) + delta;
            inode.links = u32::try_from(links.max(0)).unwrap_or(0);
            inode.mtime = inode.mtime.max(*mtime);
            if inode.links == 0 {
                // Last link dropped: the inode itself becomes garbage; the
                // GC scan reclaims its slices (§2.8).
                return Ok((None, OpOutcome::Done));
            }
            Ok((Some(Value::Inode(inode)), OpOutcome::Done))
        }
        MetaOp::InodeSetLenMax {
            key,
            candidate,
            highest_region,
            mtime,
        } => {
            let mut inode = take_inode(key, current)?;
            inode.len = inode.len.max(*candidate);
            inode.highest_region = inode.highest_region.max(*highest_region);
            inode.mtime = inode.mtime.max(*mtime);
            Ok((Some(Value::Inode(inode)), OpOutcome::Done))
        }
        MetaOp::InodeSetLenFromRegion {
            inode_key,
            region_key,
            region_base,
            mtime,
        } => {
            let mut inode = take_inode(inode_key, current)?;
            let eof = region_peek(region_key)
                .as_ref()
                .and_then(|v| v.as_region().map(|r| r.eof))
                .unwrap_or(0);
            inode.len = inode.len.max(region_base + eof);
            inode.mtime = inode.mtime.max(*mtime);
            Ok((Some(Value::Inode(inode)), OpOutcome::Done))
        }
        MetaOp::DirInsert {
            key, name, inode, ..
        } => {
            let mut dir = match current {
                None => Default::default(),
                Some(v) => match v {
                    Value::Dir(d) => d,
                    _ => {
                        return Err(Error::CorruptMetadata(format!(
                            "dir op on non-dir key {key:?}"
                        )))
                    }
                },
            };
            dir.insert(name.clone(), *inode);
            Ok((Some(Value::Dir(dir)), OpOutcome::Done))
        }
        MetaOp::DirRemove { key, name } => {
            let mut dir = match current {
                Some(Value::Dir(d)) => d,
                _ => {
                    return Err(Error::CorruptMetadata(format!(
                        "dir op on non-dir key {key:?}"
                    )))
                }
            };
            dir.remove(name);
            Ok((Some(Value::Dir(dir)), OpOutcome::Done))
        }
        MetaOp::PathInsert { inode, .. } => {
            Ok((Some(Value::PathEntry(*inode)), OpOutcome::Done))
        }
    }
}

fn expect_region_or_absent(key: &Key, current: Option<&Value>) -> Result<()> {
    match current {
        None | Some(Value::Region(_)) => Ok(()),
        _ => Err(Error::CorruptMetadata(format!(
            "region op on non-region key {key:?}"
        ))),
    }
}

fn region_of<'v>(key: &Key, v: &'v Value) -> Result<&'v RegionMeta> {
    v.as_region().ok_or_else(|| {
        Error::CorruptMetadata(format!("region op on non-region key {key:?}"))
    })
}

fn dir_of<'v>(key: &Key, v: &'v Value) -> Result<&'v crate::types::DirEntries> {
    v.as_dir()
        .ok_or_else(|| Error::CorruptMetadata(format!("dir op on non-dir key {key:?}")))
}

fn take_region_or_default(key: &Key, current: Option<Value>) -> Result<RegionMeta> {
    match current {
        None => Ok(RegionMeta::default()),
        Some(Value::Region(r)) => Ok(r),
        Some(_) => Err(Error::CorruptMetadata(format!(
            "region op on non-region key {key:?}"
        ))),
    }
}

fn take_inode(key: &Key, current: Option<Value>) -> Result<crate::types::Inode> {
    match current {
        Some(Value::Inode(i)) => Ok(i),
        _ => Err(Error::CorruptMetadata(format!(
            "inode op on non-inode key {key:?}"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{Inode, SlicePtr, Space};

    fn rkey() -> Key {
        Key::new(Space::Region, "r")
    }

    fn stored(len: u64) -> SliceData {
        SliceData::Stored(vec![SlicePtr {
            server: 1,
            backing: 0,
            offset: 0,
            len,
        }])
    }

    fn no_peek(_: &Key) -> Option<Value> {
        None
    }

    #[test]
    fn region_append_tracks_eof() {
        let op = MetaOp::RegionAppend {
            key: rkey(),
            entry: RegionEntry {
                placement: Placement::At(100),
                len: 50,
                data: stored(50),
            },
        };
        validate(&op, None, 0).unwrap();
        let (v, _) = apply(&op, None, &no_peek).unwrap();
        let r = v.unwrap();
        assert_eq!(r.as_region().unwrap().eof, 150);
    }

    #[test]
    fn eof_append_is_conditional() {
        let op = MetaOp::RegionAppendEof {
            key: rkey(),
            data: stored(60),
            len: 60,
            cap: 100,
        };
        validate(&op, None, 0).unwrap();
        let (v, outcome) = apply(&op, None, &no_peek).unwrap();
        assert_eq!(outcome, OpOutcome::AppendedAt(0));
        let region = v.clone().unwrap();
        // Second append of 60 exceeds cap=100 -> CondAppendFailed.
        let err = validate(&op, Some(&region), 1).unwrap_err();
        assert!(matches!(err, Error::CondAppendFailed { eof: 60, .. }));
    }

    #[test]
    fn region_swap_is_cas() {
        let op = MetaOp::RegionSwap {
            key: rkey(),
            expected_version: 3,
            region: RegionMeta::default(),
        };
        assert!(validate(&op, None, 3).is_ok());
        assert!(matches!(
            validate(&op, None, 4),
            Err(Error::TxnConflict { .. })
        ));
    }

    #[test]
    fn link_count_zero_deletes_inode() {
        let ikey = Key::inode(7);
        let inode = Value::Inode(Inode::new_file(7, 0o644, 2));
        let op = MetaOp::InodeAdjustLinks {
            key: ikey,
            delta: -1,
            mtime: 5,
        };
        let (v, _) = apply(&op, Some(inode), &no_peek).unwrap();
        assert!(v.is_none());
    }

    #[test]
    fn len_max_is_monotone() {
        let ikey = Key::inode(7);
        let mut inode = Inode::new_file(7, 0o644, 2);
        inode.len = 100;
        let op = MetaOp::InodeSetLenMax {
            key: ikey,
            candidate: 50,
            highest_region: 0,
            mtime: 1,
        };
        let (v, _) = apply(&op, Some(Value::Inode(inode)), &no_peek).unwrap();
        assert_eq!(v.unwrap().as_inode().unwrap().len, 100);
    }

    #[test]
    fn dir_insert_expect_absent() {
        let dkey = Key::dir(1);
        let op = MetaOp::DirInsert {
            key: dkey.clone(),
            name: "a".into(),
            inode: 2,
            expect_absent: true,
        };
        validate(&op, None, 0).unwrap();
        let (v, _) = apply(&op, None, &no_peek).unwrap();
        let err = validate(&op, v.as_ref(), 1).unwrap_err();
        assert!(matches!(err, Error::AlreadyExists(_)));
    }

    #[test]
    fn dir_remove_requires_presence() {
        let op = MetaOp::DirRemove {
            key: Key::dir(1),
            name: "missing".into(),
        };
        assert!(matches!(validate(&op, None, 0), Err(Error::NotFound(_))));
    }

    #[test]
    fn set_len_from_region_peeks_txn_state() {
        let ikey = Key::inode(7);
        let rkey = rkey();
        let inode = Value::Inode(Inode::new_file(7, 0o644, 2));
        let op = MetaOp::InodeSetLenFromRegion {
            inode_key: ikey,
            region_key: rkey.clone(),
            region_base: 1000,
            mtime: 1,
        };
        let peek = |k: &Key| {
            assert_eq!(k, &rkey);
            Some(Value::Region(RegionMeta {
                eof: 77,
                ..Default::default()
            }))
        };
        let (v, _) = apply(&op, Some(inode), &peek).unwrap();
        assert_eq!(v.unwrap().as_inode().unwrap().len, 1077);
    }

    #[test]
    fn type_mismatch_is_corrupt_metadata() {
        let op = MetaOp::RegionAppend {
            key: rkey(),
            entry: RegionEntry {
                placement: Placement::At(0),
                len: 1,
                data: stored(1),
            },
        };
        let bogus = Value::U64(1);
        assert!(matches!(
            validate(&op, Some(&bogus), 1),
            Err(Error::CorruptMetadata(_))
        ));
    }
}
