//! One metadata shard as a Paxos-replicated group.
//!
//! The paper keeps all slice metadata in a fault-tolerant coordination
//! service (§2.1, §2.9).  Here each metadata shard is an `n`-replica
//! group (paper-shaped default: 3) whose replicated log carries
//! [`LogEntry`] batches of [`MetaOp`]s.  The machinery:
//!
//! * **Replicas** ([`GroupReplica`]) serve Paxos phase 1/2, learn, lease
//!   and log-pull envelopes through the PR-1 [`Transport`] — the same
//!   scatter-gather fan-out the data plane uses, so one protocol phase
//!   costs ~1 wire round across the whole group instead of `r` serial
//!   rounds.  Each replica embeds an [`Acceptor`] (modeled as
//!   stable storage: it survives a crash) and a volatile materialized
//!   [`KvState`] + chosen log (wiped by a crash, rebuilt by replay).
//! * **Leader leases** ([`crate::coordinator::lease`]): a quorum grants
//!   the lowest live replica a time-bounded lease.  While it holds the
//!   lease, reads are served from its local state with no quorum round,
//!   and fresh log slots skip Paxos phase 1 (no competing proposer can
//!   collect grants until the lease expires).
//! * **Failover**: when the leader dies, its lease must run out before a
//!   successor can collect quorum grants; the new leader then catches up
//!   its chosen log and runs full prepare rounds for in-flight slots,
//!   adopting any value a quorum already accepted — this is what makes a
//!   committed entry survive the leader's death.
//! * **Exactly-once**: entries carry a transaction id; apply is
//!   deduplicated on it, so a commit retried across a failover can land
//!   in two slots but mutates state exactly once.
//! * **Rejoin**: a recovering replica pulls the leader's chosen log and
//!   replays it deterministically into a fresh [`KvState`].

use super::ops::{self, MetaOp, OpOutcome};
use super::shard::{KvState, ShardStats};
use super::wal::{
    Checkpoint, CkptIntent, CkptKv, CkptResult, CkptSlot, CkptStaged, ReplicaWal, WalRecord,
    WalSetup,
};
use crate::config::WalSync;
use crate::coordinator::lease::{holder_lease_bound, GrantState, LeaseClock};
use crate::coordinator::paxos::{Acceptor, Ballot, SlotSnapshot};
use crate::error::{Error, Result};
use crate::net::{Handler, Peer, Request, Response, Transport};
use crate::types::{Key, Space, Value};
use std::collections::{BTreeMap, HashMap, HashSet};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// How a [`LogEntry`] behaves when a replica applies it — the 2PC
/// layering over the plain replicated log.
#[derive(Clone, Debug, Default, PartialEq)]
pub enum EntryKind {
    /// Validate + apply immediately (single-group commits, and every
    /// multi-group commit when `Config::meta_2pc` is off).
    #[default]
    Apply,
    /// Phase 1 of a cross-group commit: stage the entry as a durable
    /// *intent* — validated and overlaid, but not applied — and lock its
    /// keys against readers and other entries until a decision record
    /// resolves it.  `participants` are every shard the transaction
    /// touches; `coordinator` (the lowest participant) is the group whose
    /// log holds the authoritative decision record.
    Prepare {
        participants: Vec<u32>,
        coordinator: u32,
    },
    /// The decision record / phase 2: resolve the pending intent for
    /// `txn_id` — flush its staged overlay on `commit`, discard it
    /// otherwise.  The FIRST `Decide` entry for a transaction in the
    /// coordinator group's log is the authoritative outcome; replays are
    /// absorbed by the txn-id dedup.
    Decide { commit: bool },
    /// A group commit: several concurrently-submitted single-shard
    /// transactions packed into ONE log slot — one Paxos round for the
    /// whole batch (`Config::group_commit_window`).  Each constituent
    /// entry is a plain `Apply` and is unpacked at apply time in order,
    /// with its own txn-id dedup and its own recorded outcome, exactly
    /// as if it had arrived alone; the wrapper entry carries its own
    /// transaction id so a retried batch dedups like any other entry.
    Batch(Vec<LogEntry>),
}

/// One replicated-log entry: a (sub-)transaction routed to this shard.
/// `txn_id` 0 is reserved for no-op filler entries.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LogEntry {
    pub txn_id: u64,
    /// Shard-local read set, re-validated deterministically at apply.
    pub reads: Vec<(Key, u64)>,
    /// Shard-local mutations, applied in order.
    pub ops: Vec<MetaOp>,
    /// Apply immediately, stage an intent, or resolve one.
    pub kind: EntryKind,
}

impl LogEntry {
    /// Filler decided when an in-flight slot turns out to be empty.
    pub fn noop() -> LogEntry {
        LogEntry::default()
    }

    /// A directly-applying entry (the pre-2PC shape).
    pub fn apply(txn_id: u64, reads: Vec<(Key, u64)>, ops: Vec<MetaOp>) -> LogEntry {
        LogEntry {
            txn_id,
            reads,
            ops,
            kind: EntryKind::Apply,
        }
    }

    /// A phase-2 decision entry (no reads/ops of its own — it resolves
    /// the staged intent recorded by the matching `Prepare`).
    pub fn decide(txn_id: u64, commit: bool) -> LogEntry {
        LogEntry {
            txn_id,
            reads: Vec::new(),
            ops: Vec::new(),
            kind: EntryKind::Decide { commit },
        }
    }

    /// A group-commit batch wrapping `txns` (each an `Apply`-kind entry
    /// carrying its own transaction id).  The wrapper takes a fresh id
    /// of its own so batch retries dedup like any other entry.
    pub fn batch(txn_id: u64, txns: Vec<LogEntry>) -> LogEntry {
        LogEntry {
            txn_id,
            reads: Vec::new(),
            ops: Vec::new(),
            kind: EntryKind::Batch(txns),
        }
    }

    pub fn is_noop(&self) -> bool {
        self.txn_id == 0
    }
}

/// Deterministically validate + stage one entry against a replica's
/// state, using the same shared staging as every other commit path
/// ([`ops::stage`]).  Returns the overlay to flush plus the per-op
/// outcomes; a validation failure is a deterministic abort (the same on
/// every replica) that stages nothing.
#[allow(clippy::type_complexity)]
pub(crate) fn stage_entry(
    state: &KvState,
    entry: &LogEntry,
) -> Result<(Vec<(Key, Option<Value>)>, Vec<OpOutcome>)> {
    for (key, observed) in &entry.reads {
        if state.version(key) != *observed {
            return Err(Error::TxnConflict {
                space: key.space,
                key: key.key.clone(),
            });
        }
    }
    let committed = |k: &Key| Ok((state.get(k).cloned(), state.version(k)));
    let (overlay, outcomes) = ops::stage(&entry.ops, &committed, |_, _| {})?;
    Ok((overlay.into_iter().collect(), outcomes))
}

/// Stage + flush in one step — the direct-apply path.
pub(crate) fn apply_entry(state: &mut KvState, entry: &LogEntry) -> Result<Vec<OpOutcome>> {
    let (overlay, outcomes) = stage_entry(state, entry)?;
    for (key, value) in overlay {
        state.set(&key, value);
    }
    Ok(outcomes)
}

/// A staged-but-undecided cross-group transaction on one replica: the
/// durable phase-1 intent.  `staged` is `Some((overlay, outcomes))` for a
/// yes vote — the exact mutation a commit decision will flush — and
/// `None` when staging deterministically failed (a no vote, identical on
/// every replica).  `participants` (from the Prepare entry) lets a
/// resolver settle the transaction's sibling groups in the same pass.
#[derive(Clone, Debug, PartialEq)]
struct Intent {
    coordinator: u32,
    participants: Vec<u32>,
    #[allow(clippy::type_complexity)]
    staged: Option<(Vec<(Key, Option<Value>)>, Vec<OpOutcome>)>,
}

/// What a proposed entry settled to, once it (or a competitor with the
/// same transaction id) was found in the log.
#[derive(Clone, Debug)]
pub(crate) enum Landed {
    /// The transaction applied (`Some`) or deterministically aborted /
    /// was decided-abort (`None`).
    Applied(Option<Vec<OpOutcome>>),
    /// A `Prepare` staged its intent; the participant's vote is `Some`
    /// (yes, with the outcomes a commit will record) or `None` (no).
    Voted(Option<Vec<OpOutcome>>),
}

/// One group's share of a cross-group batched proposal
/// (`Config::prepare_batching`): the phase-1-skipping accept is "armed"
/// — leader, slot, and ballot fixed under the commit gate — so the 2PC
/// front-end can ship EVERY participant group's accepts in one shared
/// transport scatter, then seal each group's slice of the responses.
#[derive(Debug)]
pub(crate) struct ArmedAccept {
    pub(crate) entry: LogEntry,
    leader_id: u32,
    slot: u64,
    ballot: Ballot,
}

/// What [`ShardGroup::arm_fast_accept`] found.
#[derive(Debug)]
pub(crate) enum ArmOutcome {
    /// The entry's transaction already settled here (dedup hit).
    Settled(Landed),
    /// Fast path armed: scatter the accepts, then seal.
    Armed(ArmedAccept),
    /// No fast path available (a fresh leader still owes a prepare
    /// round); the caller uses [`ShardGroup::propose_entry`].
    Slow,
}

/// A leaseholder read that may instead find the key covered by a pending
/// 2PC intent — the caller resolves the intent (via the coordinator
/// group's decision record, propagating to every participant) and
/// retries.
#[derive(Clone, Debug)]
pub(crate) enum LockedRead<R> {
    Clear(R),
    Locked {
        txn_id: u64,
        coordinator: u32,
        participants: Vec<u32>,
    },
}

/// Volatile replica state: lost on a crash, rebuilt by log replay.
#[derive(Debug, Default)]
struct ReplicaInner {
    alive: bool,
    /// Chosen entries, in slot order (a prefix of the group log).
    log: Vec<LogEntry>,
    /// Out-of-order learns, waiting for the gap to fill.
    pending: BTreeMap<u64, LogEntry>,
    /// Materialized key-value state (the shard's data).
    state: KvState,
    /// Applied transaction ids — the exactly-once guard across retries.
    applied_txns: HashSet<u64>,
    /// Authoritative per-transaction apply result: `Some(outcomes)` when
    /// the entry applied, `None` when it deterministically aborted.
    /// The proposer reports THESE to the client, never its pre-proposal
    /// staging — an indeterminate earlier commit recovered ahead of us
    /// can change what our entry actually did.
    txn_results: HashMap<u64, Option<Vec<OpOutcome>>>,
    /// Staged-but-undecided cross-group transactions (phase-1 intents),
    /// by transaction id.  Rebuilt by log replay like everything else.
    intents: HashMap<u64, Intent>,
    /// Key → pending intent holding it locked.  Leaseholder reads of a
    /// locked key resolve the intent (via its coordinator's decision
    /// record) instead of serving state the transaction may be about to
    /// change; other log entries touching a locked key deterministically
    /// abort, which is what lets a commit decision flush the prepare-time
    /// overlay verbatim.
    intent_locks: HashMap<Key, u64>,
    /// Decision records: transaction id → committed?  First `Decide`
    /// entry in the log wins; authoritative only in the transaction's
    /// coordinator group, informational elsewhere.
    decisions: HashMap<u64, bool>,
    /// Lease grant bookkeeping (volatile; hold-off applied on recovery).
    grant: GrantState,
}

impl ReplicaInner {
    /// True when `entry` touches a key locked by a DIFFERENT pending
    /// intent (the deterministic-abort condition for interlopers).
    fn crosses_lock(&self, entry: &LogEntry) -> bool {
        if self.intent_locks.is_empty() {
            return false;
        }
        entry
            .reads
            .iter()
            .map(|(k, _)| k)
            .chain(entry.ops.iter().flat_map(|op| op.keys()))
            .any(|k| {
                self.intent_locks
                    .get(k)
                    .is_some_and(|&txn| txn != entry.txn_id)
            })
    }

    fn wipe(&mut self) {
        self.log.clear();
        self.pending.clear();
        self.state = KvState::default();
        self.applied_txns.clear();
        self.txn_results.clear();
        self.intents.clear();
        self.intent_locks.clear();
        self.decisions.clear();
        self.grant = GrantState::default();
    }
}

/// One member of a shard group: Paxos acceptor + learner + materialized
/// state, addressed through the transport as a [`Handler`].
#[derive(Debug)]
pub struct GroupReplica {
    shard: u32,
    id: u32,
    clock: LeaseClock,
    /// In-memory mode: MODELED as stable storage (promises/accepts
    /// survive a crash, as Paxos requires).  In durable mode the model
    /// becomes real — every promise/accept is WAL-logged before it is
    /// acknowledged, and a durable crash wipes this too.
    acceptor: Acceptor<LogEntry>,
    inner: Mutex<ReplicaInner>,
    /// Open WAL handle in durable mode, `None` in in-memory mode (and
    /// while crashed).  Lock order: `inner` before `wal`.
    wal: Mutex<Option<ReplicaWal>>,
    /// Durable-mode configuration, retained across crashes so the
    /// replica can be rebuilt from its WAL directory alone.
    wal_setup: Mutex<Option<WalSetup>>,
}

impl GroupReplica {
    fn new(shard: u32, id: u32, clock: LeaseClock) -> Self {
        GroupReplica {
            shard,
            id,
            clock,
            acceptor: Acceptor::new(),
            inner: Mutex::new(ReplicaInner {
                alive: true,
                ..ReplicaInner::default()
            }),
            wal: Mutex::new(None),
            wal_setup: Mutex::new(None),
        }
    }

    pub fn id(&self) -> u32 {
        self.id
    }

    /// A replica hosted OUTSIDE any local [`ShardGroup`] — the server
    /// side of one `wtf-cluster meta` process, exposed to frontends
    /// through a socket server.  With `wal` set the replica comes up
    /// from its WAL directory (a first boot stamps a fresh one; a
    /// corrupt one is a typed error and the process should exit), so a
    /// SIGKILLed meta process restarted on the same directory rejoins
    /// with its acknowledged promises/accepts intact.
    pub fn standalone(
        shard: u32,
        id: u32,
        clock: LeaseClock,
        lease_ms: u64,
        wal: Option<WalSetup>,
    ) -> Result<Arc<GroupReplica>> {
        let replica = Arc::new(GroupReplica::new(shard, id, clock.clone()));
        if let Some(setup) = wal {
            let now = clock.now_ms();
            replica.attach_wal(setup, now, lease_ms.max(1))?;
        }
        Ok(replica)
    }

    /// Lock the volatile state, absorbing mutex poisoning as a crash: a
    /// panic mid-mutation (caught fail-stop by [`Handler::serve`]) left
    /// unknown state behind, so the replica marks itself dead — it can
    /// rejoin by log replay — instead of re-panicking every later
    /// caller on the poisoned lock.
    fn lock_inner(&self) -> std::sync::MutexGuard<'_, ReplicaInner> {
        match self.inner.lock() {
            Ok(g) => g,
            Err(poisoned) => {
                // One-shot: clear the flag so a later `restore` (log
                // replay) yields a healthy replica that is not re-wiped
                // on every subsequent lock.
                self.inner.clear_poison();
                let mut g = poisoned.into_inner();
                if g.alive {
                    g.alive = false;
                    g.wipe();
                }
                g
            }
        }
    }

    pub fn is_alive(&self) -> bool {
        self.lock_inner().alive
    }

    /// Crash: volatile state is wiped; the acceptor (stable storage)
    /// survives.  A dead replica answers every envelope with
    /// [`Error::ReplicaLost`], degrading its group's quorum.
    fn kill(&self) {
        let mut g = self.lock_inner();
        g.alive = false;
        g.wipe();
    }

    /// Rejoin with `entries` (the leader's chosen log), replayed
    /// deterministically into a fresh state.  Grants are held off for one
    /// lease window: whatever this replica granted before the crash is
    /// unknown and may still be live.
    fn restore(&self, entries: Vec<LogEntry>, now_ms: u64, lease_ms: u64) {
        let mut g = self.lock_inner();
        g.wipe();
        g.grant.hold_off(now_ms + lease_ms);
        for e in entries {
            Self::push_apply(&mut g, e);
        }
        g.alive = true;
    }

    /// Apply one chosen entry in log order.  Every branch is a pure
    /// function of (state so far, entry), so replicas replaying the same
    /// log converge bit-for-bit — including the 2PC intents and decision
    /// records.
    fn push_apply(g: &mut ReplicaInner, entry: LogEntry) {
        if entry.is_noop() {
            g.log.push(entry);
            return;
        }
        match &entry.kind {
            EntryKind::Apply => {
                if !g.applied_txns.contains(&entry.txn_id) {
                    // A deterministic apply-time abort (stale reads, a
                    // validation failure, or a key held by a pending
                    // intent) leaves state untouched and is identical on
                    // every replica.
                    let result = if g.crosses_lock(&entry) {
                        None
                    } else {
                        apply_entry(&mut g.state, &entry).ok()
                    };
                    g.applied_txns.insert(entry.txn_id);
                    g.txn_results.insert(entry.txn_id, result);
                }
            }
            EntryKind::Prepare {
                coordinator,
                participants,
            } => {
                // Stage exactly once: a prepare replayed into a second
                // slot (failover retry), or arriving after the decision
                // already resolved the transaction, changes nothing.
                if !g.applied_txns.contains(&entry.txn_id)
                    && !g.intents.contains_key(&entry.txn_id)
                {
                    let staged = if g.crosses_lock(&entry) {
                        None // vote no: another transaction holds a key
                    } else {
                        stage_entry(&g.state, &entry).ok()
                    };
                    if staged.is_some() {
                        for op in &entry.ops {
                            for k in op.keys() {
                                g.intent_locks.insert(k.clone(), entry.txn_id);
                            }
                        }
                    }
                    g.intents.insert(
                        entry.txn_id,
                        Intent {
                            coordinator: *coordinator,
                            participants: participants.clone(),
                            staged,
                        },
                    );
                }
            }
            EntryKind::Decide { commit } => {
                // First decision for a transaction wins (log order is
                // identical on every replica, so "first" is well-defined
                // group-wide).
                let commit = *g.decisions.entry(entry.txn_id).or_insert(*commit);
                if !g.applied_txns.contains(&entry.txn_id) {
                    let intent = g.intents.remove(&entry.txn_id);
                    g.intent_locks.retain(|_, txn| *txn != entry.txn_id);
                    let result = match intent {
                        Some(Intent {
                            staged: Some((overlay, outcomes)),
                            ..
                        }) if commit => {
                            for (key, value) in overlay {
                                g.state.set(&key, value);
                            }
                            Some(outcomes)
                        }
                        // Abort decision, a no-vote intent, or (never in
                        // a well-formed log) a decide without its
                        // prepare: nothing flushes.
                        _ => None,
                    };
                    g.applied_txns.insert(entry.txn_id);
                    g.txn_results.insert(entry.txn_id, result);
                }
            }
            EntryKind::Batch(txns) => {
                // Unpack in order, each constituent with its OWN dedup
                // and its own recorded outcome — deterministic on every
                // replica because the sub-entries ride in one chosen
                // slot.  A member that already landed alone (a failover
                // replay) is skipped; the rest apply exactly as if they
                // had occupied consecutive slots.
                if !g.applied_txns.contains(&entry.txn_id) {
                    for sub in txns {
                        if sub.is_noop() || g.applied_txns.contains(&sub.txn_id) {
                            continue;
                        }
                        let result = if g.crosses_lock(sub) {
                            None
                        } else {
                            apply_entry(&mut g.state, sub).ok()
                        };
                        g.applied_txns.insert(sub.txn_id);
                        g.txn_results.insert(sub.txn_id, result);
                    }
                    g.applied_txns.insert(entry.txn_id);
                    // The wrapper itself always "succeeds"; per-member
                    // verdicts live under the members' own ids.
                    g.txn_results.insert(entry.txn_id, Some(Vec::new()));
                }
            }
        }
        g.log.push(entry);
    }

    fn learn_locked(g: &mut ReplicaInner, slot: u64, entry: LogEntry) {
        let len = g.log.len() as u64;
        if slot < len {
            return; // already chosen here
        }
        if slot > len {
            g.pending.insert(slot, entry);
            return;
        }
        Self::push_apply(g, entry);
        while let Some(e) = {
            let next = g.log.len() as u64;
            g.pending.remove(&next)
        } {
            Self::push_apply(g, e);
        }
    }

    /// Append `rec` durably BEFORE acknowledging the event it records.
    /// A no-op in in-memory mode (the WAL slot is `None`), so the
    /// durability-off behavior is byte-identical to pre-WAL builds.  An
    /// append failure is fail-stop: a replica that cannot log must not
    /// acknowledge — it crashes (degrading the quorum) rather than risk
    /// forgetting an acknowledged promise after a restart.
    fn wal_log(&self, g: &mut ReplicaInner, rec: WalRecord) -> Result<()> {
        self.wal_log_batch(g, std::slice::from_ref(&rec))
    }

    /// Batch form of [`GroupReplica::wal_log`]: all records hit the log
    /// in one append — one sync decision, so records acknowledged
    /// together share one `fsync` under `WalSync::Always`.
    fn wal_log_batch(&self, g: &mut ReplicaInner, recs: &[WalRecord]) -> Result<()> {
        let mut wal = self.wal.lock().unwrap();
        let Some(w) = wal.as_mut() else {
            return Ok(());
        };
        match w.append_batch(recs) {
            Ok(()) => Ok(()),
            Err(e) => {
                *wal = None;
                drop(wal);
                g.alive = false;
                g.wipe();
                Err(e)
            }
        }
    }

    /// Learn one chosen entry with the durability hook: the `Chosen`
    /// record is appended (and synced per policy) BEFORE the learn is
    /// acknowledged.  Re-learns of already-chosen or already-parked
    /// slots change nothing and are not re-logged.
    fn learn_with_wal(&self, g: &mut ReplicaInner, slot: u64, entry: LogEntry) -> Result<()> {
        let novel = slot >= g.log.len() as u64 && !g.pending.contains_key(&slot);
        if novel {
            self.wal_log(
                g,
                WalRecord::Chosen {
                    slot,
                    entry: entry.clone(),
                },
            )?;
        }
        Self::learn_locked(g, slot, entry);
        if novel {
            self.maybe_checkpoint(g)?;
        }
        Ok(())
    }

    /// Checkpoint + truncate once enough chosen records accumulated
    /// (durable mode only).  A checkpoint failure is fail-stop like any
    /// other WAL error.
    fn maybe_checkpoint(&self, g: &mut ReplicaInner) -> Result<()> {
        let due = self
            .wal
            .lock()
            .unwrap()
            .as_ref()
            .is_some_and(|w| w.checkpoint_due());
        if !due {
            return Ok(());
        }
        let image = self.checkpoint_image(g);
        let mut wal = self.wal.lock().unwrap();
        let Some(w) = wal.as_mut() else {
            return Ok(());
        };
        match w.install_checkpoint(&image) {
            Ok(()) => Ok(()),
            Err(e) => {
                *wal = None;
                drop(wal);
                g.alive = false;
                g.wipe();
                Err(e)
            }
        }
    }

    /// Serialize this replica's whole durable image: acceptor slots plus
    /// everything materialized from the chosen log.  Unordered in-memory
    /// containers are sorted so identical replicas produce identical
    /// images.
    fn checkpoint_image(&self, g: &ReplicaInner) -> Checkpoint {
        let mut slots: Vec<CkptSlot> = self
            .acceptor
            .snapshot_slots()
            .into_iter()
            .map(|(promised, accepted)| CkptSlot { promised, accepted })
            .collect();
        // Canonicalize: a REJECTED prepare/accept extends the in-memory
        // slot table with default entries but is never logged (nothing
        // was acknowledged), so a replayed table can be shorter.  Trim
        // the meaningless tail so identical acknowledged states produce
        // identical images.
        while slots
            .last()
            .is_some_and(|s| s.promised == Ballot::default() && s.accepted.is_none())
        {
            slots.pop();
        }
        let mut kv: Vec<CkptKv> = g
            .state
            .iter_versions()
            .map(|(key, value, version)| CkptKv {
                key: key.clone(),
                value: value.cloned(),
                version,
            })
            .collect();
        kv.sort_by(|a, b| a.key.cmp(&b.key));
        let mut applied: Vec<u64> = g.applied_txns.iter().copied().collect();
        applied.sort_unstable();
        let mut results: Vec<CkptResult> = g
            .txn_results
            .iter()
            .map(|(&txn_id, outcomes)| CkptResult {
                txn_id,
                outcomes: outcomes.clone(),
            })
            .collect();
        results.sort_by_key(|r| r.txn_id);
        let mut intents: Vec<CkptIntent> = g
            .intents
            .iter()
            .map(|(&txn_id, i)| CkptIntent {
                txn_id,
                coordinator: i.coordinator,
                participants: i.participants.clone(),
                staged: i.staged.as_ref().map(|(overlay, outcomes)| CkptStaged {
                    overlay: overlay.clone(),
                    outcomes: outcomes.clone(),
                }),
            })
            .collect();
        intents.sort_by_key(|i| i.txn_id);
        let mut locks: Vec<(Key, u64)> = g
            .intent_locks
            .iter()
            .map(|(k, &txn)| (k.clone(), txn))
            .collect();
        locks.sort();
        let mut decisions: Vec<(u64, bool)> =
            g.decisions.iter().map(|(&t, &c)| (t, c)).collect();
        decisions.sort_unstable();
        Checkpoint {
            slots,
            log: g.log.clone(),
            pending: g.pending.iter().map(|(&s, e)| (s, e.clone())).collect(),
            kv,
            applied,
            results,
            intents,
            locks,
            decisions,
        }
    }

    /// The replica's full durable image — what a checkpoint taken right
    /// now would persist, sorted so identical acknowledged states
    /// produce identical images.  `None` while crashed.  Test
    /// observability for the bit-for-bit restart assertions.
    pub fn durable_image(&self) -> Option<Checkpoint> {
        let g = self.lock_inner();
        g.alive.then(|| self.checkpoint_image(&g))
    }

    /// Durable-mode crash: EVERYTHING in memory dies — the volatile
    /// state, the acceptor (its "modeled stable storage" is now the real
    /// WAL), and the open WAL handle.  Only the directory survives.
    fn crash_to_disk(&self) {
        let mut g = self.lock_inner();
        g.alive = false;
        g.wipe();
        self.acceptor.wipe();
        *self.wal.lock().unwrap() = None;
    }

    /// Enable durability: remember `setup` and bring the replica up from
    /// its WAL directory (a first boot stamps a fresh one).
    fn attach_wal(&self, setup: WalSetup, now_ms: u64, lease_ms: u64) -> Result<()> {
        *self.wal_setup.lock().unwrap() = Some(setup);
        self.recover_from_disk(now_ms, lease_ms)
    }

    fn has_wal_setup(&self) -> bool {
        self.wal_setup.lock().unwrap().is_some()
    }

    /// Restart from the WAL directory alone: load the newest checkpoint
    /// image, replay the post-checkpoint records in append order, and
    /// restore the acceptor table — a state indistinguishable from the
    /// pre-crash replica's acknowledged history.  On ANY integrity
    /// failure ([`Error::WalCorrupt`]) the replica stays dead: rejoining
    /// with partial state could re-promise a lower ballot.
    fn recover_from_disk(&self, now_ms: u64, lease_ms: u64) -> Result<()> {
        let setup = self.wal_setup.lock().unwrap().clone().ok_or_else(|| {
            Error::InvalidArgument(format!(
                "replica {} of shard {} has no WAL configured",
                self.id, self.shard
            ))
        })?;
        let (wal, recovered) = ReplicaWal::open(setup, self.shard, self.id)?;
        let mut g = self.lock_inner();
        g.wipe();
        let mut slots: Vec<SlotSnapshot<LogEntry>> = Vec::new();
        if let Some(c) = recovered.checkpoint {
            slots = c
                .slots
                .into_iter()
                .map(|s| (s.promised, s.accepted))
                .collect();
            g.log = c.log;
            g.pending = c.pending.into_iter().collect();
            for kv in c.kv {
                g.state.restore_entry(&kv.key, kv.value, kv.version);
            }
            g.applied_txns = c.applied.into_iter().collect();
            g.txn_results = c
                .results
                .into_iter()
                .map(|r| (r.txn_id, r.outcomes))
                .collect();
            for i in c.intents {
                g.intents.insert(
                    i.txn_id,
                    Intent {
                        coordinator: i.coordinator,
                        participants: i.participants,
                        staged: i.staged.map(|s| (s.overlay, s.outcomes)),
                    },
                );
            }
            g.intent_locks = c.locks.into_iter().collect();
            g.decisions = c.decisions.into_iter().collect();
        }
        // Replay the post-checkpoint suffix.  Nothing re-appends to the
        // WAL here — every record being replayed is already on disk.
        for rec in recovered.records {
            match rec {
                WalRecord::Promise { slot, ballot } => {
                    let s = slot as usize;
                    if slots.len() <= s {
                        slots.resize_with(s + 1, Default::default);
                    }
                    slots[s].0 = slots[s].0.max(ballot);
                }
                WalRecord::Accept { slot, ballot, entry } => {
                    let s = slot as usize;
                    if slots.len() <= s {
                        slots.resize_with(s + 1, Default::default);
                    }
                    slots[s].0 = slots[s].0.max(ballot);
                    // Later accepts overwrite earlier ones — records
                    // replay in append order, so the last one stands.
                    slots[s].1 = Some((ballot, entry));
                }
                WalRecord::Chosen { slot, entry } => {
                    Self::learn_locked(&mut g, slot, entry);
                }
            }
        }
        self.acceptor.restore_slots(slots);
        // Pre-crash lease grants are unknowable, so hold off one lease
        // window — unless the directory was freshly stamped (nothing was
        // ever granted).
        if !recovered.fresh {
            g.grant.hold_off(now_ms + lease_ms);
        }
        g.alive = true;
        *self.wal.lock().unwrap() = Some(wal);
        Ok(())
    }

    /// Feed one chosen entry from a live peer during durable-recovery
    /// catch-up — the same path as a transport learn, WAL included.
    pub(crate) fn learn_chosen(&self, slot: u64, entry: LogEntry) -> Result<()> {
        self.learn_chosen_batch(slot, vec![entry])
    }

    /// Learn a run of consecutive chosen entries starting at `from`,
    /// with ONE durability point: every novel `Chosen` record is
    /// appended in a single WAL batch — one `fsync` under
    /// `WalSync::Always` instead of one per record — before any learn
    /// is acknowledged.  Records that acknowledge together sync
    /// together (the fsync group commit of ROADMAP item 1); crash
    /// atomicity is unchanged because un-acked suffixes may always be
    /// lost.
    pub(crate) fn learn_chosen_batch(&self, from: u64, entries: Vec<LogEntry>) -> Result<()> {
        let mut g = self.lock_inner();
        if !g.alive {
            return Err(self.lost());
        }
        let recs: Vec<WalRecord> = entries
            .iter()
            .enumerate()
            .filter_map(|(i, e)| {
                let slot = from + i as u64;
                let novel = slot >= g.log.len() as u64 && !g.pending.contains_key(&slot);
                novel.then(|| WalRecord::Chosen {
                    slot,
                    entry: e.clone(),
                })
            })
            .collect();
        let any_novel = !recs.is_empty();
        if any_novel {
            self.wal_log_batch(&mut g, &recs)?;
        }
        for (i, e) in entries.into_iter().enumerate() {
            Self::learn_locked(&mut g, from + i as u64, e);
        }
        if any_novel {
            self.maybe_checkpoint(&mut g)?;
        }
        Ok(())
    }

    fn lost(&self) -> Error {
        Error::ReplicaLost {
            shard: self.shard,
            replica: self.id,
        }
    }

    /// `Some(len)` while alive; `None` after a crash (so a proposer never
    /// derives a slot number from a wiped log).
    fn log_len_if_alive(&self) -> Option<u64> {
        let g = self.lock_inner();
        g.alive.then_some(g.log.len() as u64)
    }

    /// The recorded apply result for `txn_id`: outer `None` = unknown
    /// here (not applied, or this replica is dead); `Some(None)` =
    /// applied as a deterministic abort; `Some(Some(outcomes))` =
    /// applied cleanly.
    fn txn_result(&self, txn_id: u64) -> Option<Option<Vec<OpOutcome>>> {
        if txn_id == 0 {
            return None;
        }
        let g = self.lock_inner();
        if !g.alive {
            return None;
        }
        g.txn_results.get(&txn_id).cloned()
    }

    /// Has `entry`'s transaction settled here?  Kind-aware: an applied or
    /// decided transaction settles any proposal for its id; a `Prepare`
    /// additionally settles once its intent is staged (its vote is the
    /// answer).  `None` = not landed yet (or this replica is dead).
    fn landed(&self, entry: &LogEntry) -> Option<Landed> {
        if entry.is_noop() {
            return None;
        }
        let g = self.lock_inner();
        if !g.alive {
            return None;
        }
        if let Some(result) = g.txn_results.get(&entry.txn_id) {
            return Some(Landed::Applied(result.clone()));
        }
        if matches!(entry.kind, EntryKind::Prepare { .. }) {
            if let Some(intent) = g.intents.get(&entry.txn_id) {
                return Some(Landed::Voted(
                    intent.staged.as_ref().map(|(_, outcomes)| outcomes.clone()),
                ));
            }
        }
        None
    }

    /// Read through the materialized state while alive.
    fn read_state<R>(&self, f: impl FnOnce(&KvState) -> R) -> Option<R> {
        self.read_inner(|g| f(&g.state))
    }

    /// Read through the whole volatile view while alive (state plus the
    /// 2PC intent/decision bookkeeping).
    fn read_inner<R>(&self, f: impl FnOnce(&ReplicaInner) -> R) -> Option<R> {
        let g = self.lock_inner();
        g.alive.then(|| f(&g))
    }

    fn dispatch(&self, req: &Request) -> Result<Response> {
        // Every arm holds the inner lock across its liveness check AND
        // the action, so a kill() cannot interleave between them.
        match req {
            Request::PaxosPrepare { slot, ballot, .. } => {
                let mut g = self.lock_inner();
                if !g.alive {
                    return Err(self.lost());
                }
                match self.acceptor.prepare(*slot as usize, *ballot) {
                    None => Err(self.lost()),
                    Some(Err(_)) => Ok(Response::Promised {
                        granted: false,
                        accepted: None,
                    }),
                    Some(Ok(p)) => {
                        // Durability boundary: the promise is on disk
                        // BEFORE it is granted — a restarted replica
                        // re-promises at least this ballot, never lower.
                        self.wal_log(
                            &mut g,
                            WalRecord::Promise {
                                slot: *slot,
                                ballot: *ballot,
                            },
                        )?;
                        Ok(Response::Promised {
                            granted: true,
                            accepted: p.accepted,
                        })
                    }
                }
            }
            Request::PaxosAccept {
                slot,
                ballot,
                entry,
                ..
            } => {
                let mut g = self.lock_inner();
                if !g.alive {
                    return Err(self.lost());
                }
                match self.acceptor.accept(*slot as usize, *ballot, entry.clone()) {
                    None => Err(self.lost()),
                    Some(ok) => {
                        if ok {
                            // Logged before the ack; a logged accept also
                            // implies promised >= ballot on replay.
                            // Refused accepts change nothing and are not
                            // logged.
                            self.wal_log(
                                &mut g,
                                WalRecord::Accept {
                                    slot: *slot,
                                    ballot: *ballot,
                                    entry: entry.clone(),
                                },
                            )?;
                        }
                        Ok(Response::Accepted(ok))
                    }
                }
            }
            Request::PaxosLearn { slot, entry, .. } => {
                let mut g = self.lock_inner();
                if !g.alive {
                    return Err(self.lost());
                }
                self.learn_with_wal(&mut g, *slot, entry.clone())?;
                Ok(Response::Learned)
            }
            Request::PaxosStatus { .. } => {
                let g = self.lock_inner();
                if !g.alive {
                    return Err(self.lost());
                }
                Ok(Response::LogLen(g.log.len() as u64))
            }
            Request::PaxosPull { from, .. } => {
                let g = self.lock_inner();
                if !g.alive {
                    return Err(self.lost());
                }
                let from = (*from as usize).min(g.log.len());
                Ok(Response::LogSuffix(g.log[from..].to_vec()))
            }
            Request::LeaseRequest {
                leader,
                until_ms,
                epoch,
                ..
            } => {
                let mut g = self.lock_inner();
                if !g.alive {
                    return Err(self.lost());
                }
                let now = self.clock.now_ms();
                Ok(Response::LeaseGranted(g.grant.grant(
                    now, *leader, *until_ms, *epoch,
                )))
            }
            other => Err(Error::Unsupported(format!(
                "metadata shard replica cannot serve {other:?}"
            ))),
        }
    }
}

impl Handler for GroupReplica {
    fn serve(&self, req: &Request) -> Result<Response> {
        // Fail-stop: a panic in here is a crashed replica, not a poisoned
        // client thread.
        crate::net::serve_fail_stop(self.shard, self.id, || self.dispatch(req))
    }
}

/// The proposing front-end of one shard group: leader bookkeeping plus
/// the scatter-gather Paxos rounds.  One instance per shard, shared by
/// every client of the deployment (proposals are serialized by the
/// commit gate in [`crate::meta::ReplicatedMetaStore`]).
#[derive(Debug)]
pub struct ShardGroup {
    shard: u32,
    replicas: Vec<Arc<GroupReplica>>,
    /// The wire addresses of the group members, one per replica slot.
    /// In the classic single-process deployment `peers[i]` is simply
    /// `replicas[i] as Peer`; in a multi-process deployment the local
    /// member keeps its direct handle while remote slots hold socket
    /// peers (their `replicas[i]` stand-ins stay permanently dead, so
    /// every LOCAL read/election/convergence path skips them).  All
    /// quorum scatters address `peers`, never `replicas`.
    peers: Vec<Peer>,
    transport: Arc<Transport>,
    clock: LeaseClock,
    lease_ms: u64,
    /// `Config::max_clock_skew` in ms: subtracted from the lease window
    /// the HOLDER publishes for itself (grants at the replicas keep the
    /// full window), so a leaseholder whose clock runs up to this much
    /// ahead of a replica's still steps down before the replica would
    /// re-grant.  Zero (the default) reproduces the single-process
    /// behavior, where one shared clock makes the bound vacuous.
    max_skew_ms: AtomicU64,
    view: Mutex<LeaderView>,
    /// Serializes commits to this group (and, taken in canonical order
    /// across groups, multi-shard commits).
    pub(crate) gate: Mutex<()>,
    elections: AtomicU64,
    lease_reads: AtomicU64,
    /// Monotone grant-round stamp carried in every `LeaseRequest`; a
    /// replica refuses to honor an epoch it already answered, so the
    /// network re-delivering a grant can never extend a lease.
    lease_epoch: AtomicU64,
    /// Times a published leaseholder stepped down because its lease no
    /// longer covered "now" at read time (a delayed refresh pushed past
    /// the window) — the read then re-establishes a quorum-granted
    /// lease instead of serving possibly-stale local state.
    stepdowns: AtomicU64,
}

#[derive(Debug, Default)]
struct LeaderView {
    leader: Option<u32>,
    /// Monotone ballot round; bumped on leader change and on every full
    /// prepare round.
    term: u64,
    lease_until: u64,
    /// The next proposal must run phase 1 (set after a leader change,
    /// when in-flight slots may hold quorum-accepted values).
    needs_prepare: bool,
}

impl ShardGroup {
    pub fn new(
        shard: u32,
        replicas: u8,
        transport: Arc<Transport>,
        clock: LeaseClock,
        lease_ms: u64,
    ) -> Self {
        let n = replicas.max(1) as u32;
        let replicas: Vec<Arc<GroupReplica>> = (0..n)
            .map(|id| Arc::new(GroupReplica::new(shard, id, clock.clone())))
            .collect();
        let peers = replicas.iter().map(|r| r.clone() as Peer).collect();
        ShardGroup {
            shard,
            replicas,
            peers,
            transport,
            clock,
            lease_ms: lease_ms.max(1),
            max_skew_ms: AtomicU64::new(0),
            view: Mutex::new(LeaderView::default()),
            gate: Mutex::new(()),
            elections: AtomicU64::new(0),
            lease_reads: AtomicU64::new(0),
            lease_epoch: AtomicU64::new(0),
            stepdowns: AtomicU64::new(0),
        }
    }

    /// A group whose replica 0 lives in THIS process (the frontend's
    /// local member — the only election candidate, so leaseholder reads
    /// stay local) and whose remaining members are reached through
    /// `remote` peers, one per replica id `1..=remote.len()` (socket
    /// peers to the per-role `wtf-cluster meta` processes).  The local
    /// stand-ins for remote slots are created permanently dead: quorum
    /// traffic goes over the wire via `peers`, while every local-state
    /// path (reads, candidate choice, convergence checks) sees only the
    /// genuinely local member.
    pub fn with_remote_members(
        shard: u32,
        transport: Arc<Transport>,
        clock: LeaseClock,
        lease_ms: u64,
        remote: Vec<Peer>,
    ) -> Self {
        let n = remote.len() as u32 + 1;
        let replicas: Vec<Arc<GroupReplica>> = (0..n)
            .map(|id| Arc::new(GroupReplica::new(shard, id, clock.clone())))
            .collect();
        for stand_in in &replicas[1..] {
            stand_in.kill();
        }
        let peers = std::iter::once(replicas[0].clone() as Peer)
            .chain(remote)
            .collect();
        ShardGroup {
            shard,
            replicas,
            peers,
            transport,
            clock,
            lease_ms: lease_ms.max(1),
            max_skew_ms: AtomicU64::new(0),
            view: Mutex::new(LeaderView::default()),
            gate: Mutex::new(()),
            elections: AtomicU64::new(0),
            lease_reads: AtomicU64::new(0),
            lease_epoch: AtomicU64::new(0),
            stepdowns: AtomicU64::new(0),
        }
    }

    /// Set the clock-skew allowance (`Config::max_clock_skew`) this
    /// group's leaseholder subtracts from its own published lease.
    pub fn set_max_clock_skew_ms(&self, ms: u64) {
        self.max_skew_ms.store(ms, Ordering::Relaxed);
    }

    fn max_clock_skew_ms(&self) -> u64 {
        self.max_skew_ms.load(Ordering::Relaxed)
    }

    pub fn shard(&self) -> u32 {
        self.shard
    }

    pub fn num_replicas(&self) -> usize {
        self.replicas.len()
    }

    fn quorum(&self) -> usize {
        self.replicas.len() / 2 + 1
    }

    /// A replica handle (tests and fault injection).
    pub fn replica(&self, idx: usize) -> Option<&Arc<GroupReplica>> {
        self.replicas.get(idx)
    }

    /// The current leaseholder, if its lease still covers now.
    pub fn leader(&self) -> Option<u32> {
        let v = self.view.lock().unwrap();
        let now = self.clock.now_ms();
        v.leader.filter(|&l| {
            now < v.lease_until && self.replicas[l as usize].is_alive()
        })
    }

    /// Leader elections performed so far (observability).
    pub fn elections(&self) -> u64 {
        self.elections.load(Ordering::Relaxed)
    }

    /// Reads served locally by a leaseholder, no quorum round.
    pub fn lease_reads(&self) -> u64 {
        self.lease_reads.load(Ordering::Relaxed)
    }

    /// Times a leaseholder stepped down instead of serving a local read
    /// past a lease it could not refresh (observability; chaos tests
    /// assert this fires under delay faults).
    pub fn stepdowns(&self) -> u64 {
        self.stepdowns.load(Ordering::Relaxed)
    }

    fn lowest_alive(&self) -> Option<u32> {
        self.replicas
            .iter()
            .position(|r| r.is_alive())
            .map(|i| i as u32)
    }

    fn invalidate_leader(&self, id: u32) {
        let mut v = self.view.lock().unwrap();
        if v.leader == Some(id) {
            v.leader = None;
        }
    }

    /// The live leaseholder, electing one if allowed.  With `auto_elect`
    /// off (the transport envelope path), a missing leader surfaces as
    /// [`Error::NotLeader`] so clients drive discovery themselves.
    fn ensure_leader(&self, auto_elect: bool) -> Result<u32> {
        {
            let v = self.view.lock().unwrap();
            if let Some(l) = v.leader {
                let now = self.clock.now_ms();
                // Renew before the lease gets too thin to finish a round.
                if now + self.lease_ms / 4 < v.lease_until
                    && self.replicas[l as usize].is_alive()
                {
                    return Ok(l);
                }
            }
        }
        if !auto_elect {
            return Err(Error::NotLeader {
                shard: self.shard,
                hint: self.lowest_alive(),
            });
        }
        self.elect()
    }

    /// Elect (or renew) the lowest live replica as leaseholder.  Blocks —
    /// bounded by the lease window — while an earlier lease runs out.
    fn elect(&self) -> Result<u32> {
        let total = self.replicas.len();
        let mut waited_ms = 0u64;
        loop {
            let cand = self.lowest_alive().ok_or(Error::NoQuorum { alive: 0, total })?;
            // The validity window is anchored at the instant BEFORE the
            // grant requests leave this process: however long the round
            // takes on the wire, the holder's published window only
            // shrinks.  The replicas grant the full `until`; the holder
            // additionally subtracts `max_clock_skew`, so even a holder
            // clock running fast by that much steps down before any
            // replica could re-grant (see the delayed-grant tests in
            // `coordinator::lease`).
            let pre_send = self.clock.now_ms();
            let until = pre_send + self.lease_ms;
            let holder_until = holder_lease_bound(pre_send, self.lease_ms, self.max_clock_skew_ms());
            // Every grant round gets a fresh epoch, so a replica can
            // tell this round's envelopes from network re-deliveries of
            // an earlier round (which must not extend anything).
            let epoch = self.lease_epoch.fetch_add(1, Ordering::Relaxed) + 1;
            let batch: Vec<(Peer, Request)> = self
                .peers
                .iter()
                .map(|p| {
                    (
                        p.clone(),
                        Request::LeaseRequest {
                            shard: self.shard,
                            leader: cand,
                            until_ms: until,
                            epoch,
                        },
                    )
                })
                .collect();
            let mut grants = 0usize;
            let mut reachable = 0usize;
            for res in self.transport.broadcast(batch) {
                match res.and_then(Response::into_lease_granted) {
                    Ok(true) => {
                        grants += 1;
                        reachable += 1;
                    }
                    Ok(false) => reachable += 1,
                    Err(_) => {} // dead replica: degrades the quorum
                }
            }
            if reachable < self.quorum() {
                return Err(Error::NoQuorum {
                    alive: reachable,
                    total,
                });
            }
            if grants >= self.quorum() {
                let changed = self.view.lock().unwrap().leader != Some(cand);
                if changed {
                    // Catch the candidate up BEFORE publishing it: a
                    // leader that could not recover the chosen log must
                    // never serve lease reads (they would miss
                    // acknowledged commits).  On failure the old view
                    // stands and the next caller re-elects.
                    self.catch_up_leader(cand)?;
                    self.elections.fetch_add(1, Ordering::Relaxed);
                }
                {
                    let mut v = self.view.lock().unwrap();
                    if changed {
                        v.term += 1;
                        v.needs_prepare = true;
                    }
                    v.leader = Some(cand);
                    v.lease_until = holder_until;
                }
                return Ok(cand);
            }
            // Denied: an earlier grant is unexpired somewhere.  Wait for
            // it to run out (manual clocks advance instead of blocking).
            waited_ms += 1;
            if waited_ms > self.lease_ms.saturating_mul(4) + 100 {
                return Err(Error::NotLeader {
                    shard: self.shard,
                    hint: Some(cand),
                });
            }
            self.clock.sleep_ms(1);
        }
    }

    /// Bring a new leader's chosen log up to the longest log any live
    /// replica holds, deciding each missing slot with a full round (which
    /// adopts whatever a quorum already accepted there).
    fn catch_up_leader(&self, leader: u32) -> Result<()> {
        let batch: Vec<(Peer, Request)> = self
            .peers
            .iter()
            .map(|p| (p.clone(), Request::PaxosStatus { shard: self.shard }))
            .collect();
        let max_len = self
            .transport
            .broadcast(batch)
            .into_iter()
            .filter_map(|res| res.and_then(Response::into_log_len).ok())
            .max()
            .unwrap_or(0);
        loop {
            let Some(have) = self.replicas[leader as usize].log_len_if_alive() else {
                return Err(Error::ReplicaLost {
                    shard: self.shard,
                    replica: leader,
                });
            };
            if have >= max_len {
                return Ok(());
            }
            self.decide_slot(have, LogEntry::noop(), leader)?;
        }
    }

    /// Drive `slot` to a decision with full prepare/accept rounds,
    /// learning the chosen entry group-wide.
    fn decide_slot(&self, slot: u64, default: LogEntry, proposer: u32) -> Result<LogEntry> {
        for _ in 0..16 {
            if let Some(chosen) = self.full_round(slot, default.clone(), proposer)? {
                self.learn_all(slot, &chosen);
                return Ok(chosen);
            }
        }
        Err(Error::NoQuorum {
            alive: 0,
            total: self.replicas.len(),
        })
    }

    /// One full Paxos round (phase 1 + 2) at a fresh, higher ballot.
    /// `proposer` is passed explicitly: during election catch-up the
    /// candidate is not yet published in the view.
    /// `Ok(None)` means the round lost (stale ballot) and may be retried.
    fn full_round(&self, slot: u64, value: LogEntry, proposer: u32) -> Result<Option<LogEntry>> {
        let ballot = {
            let mut v = self.view.lock().unwrap();
            v.term += 1;
            Ballot {
                round: v.term,
                proposer,
            }
        };
        let batch: Vec<(Peer, Request)> = self
            .peers
            .iter()
            .map(|p| {
                (
                    p.clone(),
                    Request::PaxosPrepare {
                        shard: self.shard,
                        slot,
                        ballot,
                    },
                )
            })
            .collect();
        let mut reachable = 0usize;
        let mut promised = 0usize;
        let mut adopted: Option<(Ballot, LogEntry)> = None;
        for res in self.transport.broadcast(batch) {
            match res.and_then(Response::into_promised) {
                Ok((granted, accepted)) => {
                    reachable += 1;
                    if granted {
                        promised += 1;
                        if let Some((b, e)) = accepted {
                            let better = match &adopted {
                                Some((ab, _)) => b > *ab,
                                None => true,
                            };
                            if better {
                                adopted = Some((b, e));
                            }
                        }
                    }
                }
                Err(_) => {}
            }
        }
        if reachable < self.quorum() {
            return Err(Error::NoQuorum {
                alive: reachable,
                total: self.replicas.len(),
            });
        }
        if promised < self.quorum() {
            return Ok(None);
        }
        let chosen = adopted.map(|(_, e)| e).unwrap_or(value);
        let acks = self.accept_round(slot, ballot, &chosen)?;
        if acks >= self.quorum() {
            Ok(Some(chosen))
        } else {
            Ok(None)
        }
    }

    /// Scatter phase-2 accepts; returns the ack count (errors if fewer
    /// than a quorum of replicas are even reachable).
    fn accept_round(&self, slot: u64, ballot: Ballot, entry: &LogEntry) -> Result<usize> {
        let batch: Vec<(Peer, Request)> = self
            .peers
            .iter()
            .map(|p| {
                (
                    p.clone(),
                    Request::PaxosAccept {
                        shard: self.shard,
                        slot,
                        ballot,
                        entry: entry.clone(),
                    },
                )
            })
            .collect();
        let mut acks = 0usize;
        let mut reachable = 0usize;
        for res in self.transport.broadcast(batch) {
            match res.and_then(Response::into_accepted) {
                Ok(true) => {
                    acks += 1;
                    reachable += 1;
                }
                Ok(false) => reachable += 1,
                Err(_) => {}
            }
        }
        if reachable < self.quorum() {
            return Err(Error::NoQuorum {
                alive: reachable,
                total: self.replicas.len(),
            });
        }
        Ok(acks)
    }

    /// Teach every live replica the chosen entry (the leader applies
    /// here too; dead replicas re-sync on recovery).
    fn learn_all(&self, slot: u64, chosen: &LogEntry) {
        let batch: Vec<(Peer, Request)> = self
            .peers
            .iter()
            .map(|p| {
                (
                    p.clone(),
                    Request::PaxosLearn {
                        shard: self.shard,
                        slot,
                        entry: chosen.clone(),
                    },
                )
            })
            .collect();
        for res in self.transport.broadcast(batch) {
            let _ = res;
        }
    }

    /// Commit `entry` to the replicated log, surviving leader failover,
    /// and apply it group-wide exactly once.  Returns the AUTHORITATIVE
    /// per-op outcomes recorded by the replicated apply — normally equal
    /// to what the proposer staged, but when an indeterminate earlier
    /// commit is recovered ahead of this entry, the entry may have
    /// aborted at apply (surfaced as [`Error::TxnAborted`]) or landed
    /// with different outcomes; the caller must report those, not its
    /// pre-proposal staging.
    ///
    /// Fast path (valid lease, settled log): skip phase 1 — one
    /// scatter-gathered accept round is the whole quorum commit.
    pub fn commit_entry(&self, entry: &LogEntry, auto_elect: bool) -> Result<Vec<OpOutcome>> {
        match self.propose_entry(entry, auto_elect)? {
            Landed::Applied(result) => Self::applied_or_aborted(result, entry),
            // Unreachable for Apply/Decide kinds (landed() only votes on
            // Prepare proposals); surface loudly rather than guessing.
            Landed::Voted(_) => Err(Error::CorruptMetadata(format!(
                "txn {} landed as a vote on a non-prepare proposal",
                entry.txn_id
            ))),
        }
    }

    /// The kind-aware proposal driver shared by direct commits, 2PC
    /// prepares, and 2PC decisions: drive `entry` into the replicated
    /// log through any failover, then report how its transaction settled
    /// on the leader.
    pub(crate) fn propose_entry(&self, entry: &LogEntry, auto_elect: bool) -> Result<Landed> {
        assert!(!entry.is_noop(), "txn_id 0 is reserved for noop filler");
        for _attempt in 0..64 {
            let leader_id = self.ensure_leader(auto_elect)?;
            let leader = &self.replicas[leader_id as usize];
            if let Some(landed) = leader.landed(entry) {
                // A previous attempt already landed (exactly-once).
                return Ok(landed);
            }
            let Some(slot) = leader.log_len_if_alive() else {
                self.invalidate_leader(leader_id);
                continue;
            };
            let needs_prepare = self.view.lock().unwrap().needs_prepare;
            let chosen = if needs_prepare {
                self.full_round(slot, entry.clone(), leader_id)?
            } else {
                let ballot = {
                    let v = self.view.lock().unwrap();
                    Ballot {
                        round: v.term,
                        proposer: leader_id,
                    }
                };
                match self.accept_round(slot, ballot, entry) {
                    Ok(acks) if acks >= self.quorum() => Some(entry.clone()),
                    Ok(_) => self.full_round(slot, entry.clone(), leader_id)?,
                    Err(e) => {
                        // The fast-path accept may have landed on a
                        // minority.  This ballot must NEVER be reused
                        // for a different value at this slot (one value
                        // per ballot is what prepare-adoption relies
                        // on), so force phase 1 — which takes a fresh,
                        // higher ballot — on the next proposal here.
                        self.view.lock().unwrap().needs_prepare = true;
                        return Err(e);
                    }
                }
            };
            let Some(chosen) = chosen else { continue };
            self.learn_all(slot, &chosen);
            self.view.lock().unwrap().needs_prepare = false;
            if chosen.txn_id == entry.txn_id {
                if let Some(landed) = self.replicas[leader_id as usize].landed(entry) {
                    return Ok(landed);
                }
                // Leader died between accept and learn, or the chosen
                // entry was a different KIND for the same transaction
                // (e.g. an adopted orphan prepare owning the slot our
                // decide aimed at): loop — the next round settles it.
                continue;
            }
            // A recovered in-flight entry owned this slot; ours goes next.
        }
        Err(Error::RetriesExhausted { attempts: 64 })
    }

    fn applied_or_aborted(
        result: Option<Vec<OpOutcome>>,
        entry: &LogEntry,
    ) -> Result<Vec<OpOutcome>> {
        result.ok_or_else(|| Error::TxnAborted {
            reason: format!(
                "txn {} aborted at replicated apply (an indeterminate \
                 earlier commit was recovered ahead of it)",
                entry.txn_id
            ),
        })
    }

    /// Try to arm the phase-1-skipping fast path for `entry` WITHOUT
    /// touching the wire: resolve the leaseholder, check the dedup, and
    /// fix the slot and ballot.  The caller then ships this group's
    /// [`ArmedAccept::accept_requests`] in a transport scatter SHARED
    /// with other groups' armed proposals (`Config::prepare_batching`),
    /// seals the gathered responses, and learns — two cross-group
    /// scatters where sequential proposals would pay two per group.
    /// `Slow` (a just-elected leader still owes a prepare round, or the
    /// leader died under us) leaves nothing in flight; the caller falls
    /// back to [`ShardGroup::propose_entry`].
    ///
    /// MUST be called with this group's commit gate held, like any
    /// proposal — the gate is what keeps the armed slot stable.
    pub(crate) fn arm_fast_accept(
        &self,
        entry: &LogEntry,
        auto_elect: bool,
    ) -> Result<ArmOutcome> {
        assert!(!entry.is_noop(), "txn_id 0 is reserved for noop filler");
        let leader_id = self.ensure_leader(auto_elect)?;
        let leader = &self.replicas[leader_id as usize];
        if let Some(landed) = leader.landed(entry) {
            return Ok(ArmOutcome::Settled(landed));
        }
        let Some(slot) = leader.log_len_if_alive() else {
            self.invalidate_leader(leader_id);
            return Ok(ArmOutcome::Slow);
        };
        let v = self.view.lock().unwrap();
        if v.needs_prepare {
            return Ok(ArmOutcome::Slow);
        }
        let ballot = Ballot {
            round: v.term,
            proposer: leader_id,
        };
        drop(v);
        Ok(ArmOutcome::Armed(ArmedAccept {
            entry: entry.clone(),
            leader_id,
            slot,
            ballot,
        }))
    }

    /// The accept envelopes an armed proposal scatters — one per
    /// replica, in replica order (the order [`ShardGroup::seal_fast_accept`]
    /// expects the responses back in).
    pub(crate) fn accept_requests(&self, armed: &ArmedAccept) -> Vec<(Peer, Request)> {
        self.peers
            .iter()
            .map(|p| {
                (
                    p.clone(),
                    Request::PaxosAccept {
                        shard: self.shard,
                        slot: armed.slot,
                        ballot: armed.ballot,
                        entry: armed.entry.clone(),
                    },
                )
            })
            .collect()
    }

    /// Tally this group's slice of the shared accept scatter, mirroring
    /// the fast path of [`ShardGroup::propose_entry`] exactly.
    /// `Ok(true)`: quorum accepted — learn next.  `Ok(false)`: the round
    /// lost cleanly (a reachable quorum, not enough accepts) — fall back
    /// to `propose_entry`, which may re-send the SAME ballot/value or
    /// run a full round.  `Err`: fewer than a quorum reachable — the
    /// accept may have landed on a minority, so the next proposal here
    /// MUST run phase 1 at a fresh ballot (one value per ballot).
    pub(crate) fn seal_fast_accept(
        &self,
        responses: Vec<Result<Response>>,
    ) -> Result<bool> {
        let mut acks = 0usize;
        let mut reachable = 0usize;
        for res in responses {
            match res.and_then(Response::into_accepted) {
                Ok(true) => {
                    acks += 1;
                    reachable += 1;
                }
                Ok(false) => reachable += 1,
                Err(_) => {}
            }
        }
        if reachable < self.quorum() {
            self.view.lock().unwrap().needs_prepare = true;
            return Err(Error::NoQuorum {
                alive: reachable,
                total: self.replicas.len(),
            });
        }
        Ok(acks >= self.quorum())
    }

    /// The learn envelopes that follow a quorum-accepted armed proposal.
    pub(crate) fn learn_requests(&self, armed: &ArmedAccept) -> Vec<(Peer, Request)> {
        self.peers
            .iter()
            .map(|p| {
                (
                    p.clone(),
                    Request::PaxosLearn {
                        shard: self.shard,
                        slot: armed.slot,
                        entry: armed.entry.clone(),
                    },
                )
            })
            .collect()
    }

    /// How the armed proposal's transaction settled after the learn
    /// scatter.  `None` = the leader died between accept and learn; the
    /// caller falls back to `propose_entry` (the dedup keeps the retry
    /// exactly-once).
    pub(crate) fn settled_after_learn(&self, armed: &ArmedAccept) -> Option<Landed> {
        self.replicas[armed.leader_id as usize].landed(&armed.entry)
    }

    /// The recorded apply result for `txn_id` per the leaseholder: outer
    /// `None` = not settled here; `Some(None)` = applied as a
    /// deterministic abort; `Some(Some(outcomes))` = applied cleanly.
    /// The group-commit front-end reads each batched transaction's
    /// individual verdict through this after the shared entry lands.
    #[allow(clippy::type_complexity)]
    pub(crate) fn txn_outcomes(
        &self,
        txn_id: u64,
        auto_elect: bool,
    ) -> Result<Option<Option<Vec<OpOutcome>>>> {
        self.local_read_inner(auto_elect, |g| g.txn_results.get(&txn_id).cloned())
    }

    /// Chosen-log length at the leaseholder.  Observability: one slot is
    /// one Paxos commit round consumed, so the delta across a workload
    /// counts its commit rounds (group commit packs many transactions
    /// into one slot).
    pub fn log_len(&self, auto_elect: bool) -> Result<u64> {
        self.local_read_inner(auto_elect, |g| g.log.len() as u64)
    }

    /// The transport every replica of this group is served through
    /// (shared deployment-wide; cross-group scatter batching rides it).
    pub(crate) fn transport(&self) -> &Arc<Transport> {
        &self.transport
    }

    /// Versioned point read served by the leaseholder's local state — the
    /// read-lease fast path: no quorum round.
    pub fn local_get(&self, key: &Key, auto_elect: bool) -> Result<Option<(Value, u64)>> {
        self.local_read(auto_elect, |s| {
            s.get(key).map(|v| (v.clone(), s.version(key)))
        })
    }

    /// Value AND version in one leaseholder read (absent keys still
    /// report their version) — the commit-staging view.
    pub fn local_entry(&self, key: &Key, auto_elect: bool) -> Result<(Option<Value>, u64)> {
        self.local_read(auto_elect, |s| (s.get(key).cloned(), s.version(key)))
    }

    /// Version of `key` without copying the value.
    pub fn local_version(&self, key: &Key, auto_elect: bool) -> Result<u64> {
        self.local_read(auto_elect, |s| s.version(key))
    }

    /// Leaseholder-local scan of one space.
    pub fn local_scan(&self, space: Space, auto_elect: bool) -> Result<Vec<(Key, Value)>> {
        self.local_read(auto_elect, |s| {
            s.iter()
                .filter(|(k, _)| k.space == space)
                .map(|(k, v)| (k.clone(), v.clone()))
                .collect()
        })
    }

    fn local_read<R>(&self, auto_elect: bool, f: impl Fn(&KvState) -> R) -> Result<R> {
        self.local_read_inner(auto_elect, |g| f(&g.state))
    }

    fn local_read_inner<R>(
        &self,
        auto_elect: bool,
        f: impl Fn(&ReplicaInner) -> R,
    ) -> Result<R> {
        loop {
            let leader = self.ensure_leader(auto_elect)?;
            // Step-down rule (network fault model, PR 8): immediately
            // before serving from local state, verify the published
            // lease still covers *now*.  A grant round whose envelopes
            // the network delayed can publish a lease that already
            // expired in flight — a holder that could not refresh within
            // its window must not serve leaseholder-local reads; it
            // steps down and the retry re-establishes a quorum-granted
            // lease (the election broadcast IS the quorum round).
            {
                let v = self.view.lock().unwrap();
                if v.leader != Some(leader) || self.clock.now_ms() >= v.lease_until {
                    drop(v);
                    self.stepdowns.fetch_add(1, Ordering::Relaxed);
                    self.invalidate_leader(leader);
                    continue;
                }
            }
            match self.replicas[leader as usize].read_inner(&f) {
                Some(out) => {
                    self.lease_reads.fetch_add(1, Ordering::Relaxed);
                    return Ok(out);
                }
                None => self.invalidate_leader(leader), // died under us
            }
        }
    }

    /// Leaseholder read that honors 2PC intent locks: if `key` is covered
    /// by a pending intent, return the lock (transaction id + its
    /// coordinator shard) instead of state the transaction is about to
    /// decide — the probe and the read are one atomic view, so a lock
    /// can never slip in between them.
    pub(crate) fn local_locked<R>(
        &self,
        key: &Key,
        auto_elect: bool,
        f: impl Fn(&KvState) -> R,
    ) -> Result<LockedRead<R>> {
        self.local_read_inner(auto_elect, |g| match g.intent_locks.get(key) {
            Some(&txn_id) => {
                let intent = g.intents.get(&txn_id);
                LockedRead::Locked {
                    txn_id,
                    coordinator: intent.map(|i| i.coordinator).unwrap_or(self.shard),
                    participants: intent.map(|i| i.participants.clone()).unwrap_or_default(),
                }
            }
            None => LockedRead::Clear(f(&g.state)),
        })
    }

    /// The recorded decision for `txn_id`, if any (authoritative in the
    /// transaction's coordinator group — the first `Decide` entry wins).
    pub(crate) fn decision(&self, txn_id: u64, auto_elect: bool) -> Result<Option<bool>> {
        self.local_read_inner(auto_elect, |g| g.decisions.get(&txn_id).copied())
    }

    /// How `txn_id` settled in this group, per the leaseholder:
    /// `Some(true)` = applied (mutations flushed), `Some(false)` =
    /// applied as an abort, `None` = not settled here (never proposed,
    /// or its intent is still pending).  Test/observability surface for
    /// the fault-schedule agreement assertions.
    pub(crate) fn txn_settled(&self, txn_id: u64, auto_elect: bool) -> Result<Option<bool>> {
        self.local_read_inner(auto_elect, |g| {
            g.txn_results.get(&txn_id).map(|r| r.is_some())
        })
    }

    /// Every pending (undecided) intent in this group, as
    /// `(txn_id, coordinator shard, participants)` — the
    /// orphan-resolution sweep and test observability.
    #[allow(clippy::type_complexity)]
    pub(crate) fn pending_intents(
        &self,
        auto_elect: bool,
    ) -> Result<Vec<(u64, u32, Vec<u32>)>> {
        self.local_read_inner(auto_elect, |g| {
            let mut out: Vec<(u64, u32, Vec<u32>)> = g
                .intents
                .iter()
                .map(|(&txn, i)| (txn, i.coordinator, i.participants.clone()))
                .collect();
            out.sort_unstable();
            out
        })
    }

    /// Fail one replica (crash-stop).  Its lease, if it led, must expire
    /// before a successor can be elected — the failover window.
    pub fn kill_replica(&self, idx: usize) {
        if let Some(r) = self.replicas.get(idx) {
            r.kill();
        }
    }

    /// Turn on durability for every replica of this group: each gets
    /// `<dir>/replica-<id>` and comes up from whatever that directory
    /// holds (a first boot stamps fresh markers; a restart replays).
    pub fn enable_wal(&self, dir: &Path, sync: WalSync, checkpoint_every: u64) -> Result<()> {
        let now = self.clock.now_ms();
        for r in &self.replicas {
            let setup = WalSetup {
                dir: dir.join(format!("replica-{}", r.id())),
                sync,
                checkpoint_every,
            };
            r.attach_wal(setup, now, self.lease_ms)?;
        }
        Ok(())
    }

    /// Whether this group's replicas carry on-disk WALs.
    pub fn is_durable(&self) -> bool {
        self.replicas.iter().any(|r| r.has_wal_setup())
    }

    /// Replica `idx`'s durable image (test observability; `None` while
    /// crashed or out of range).
    pub fn replica_durable_image(&self, idx: usize) -> Option<Checkpoint> {
        self.replicas.get(idx).and_then(|r| r.durable_image())
    }

    /// Restart one replica the durable way: tear it down to its WAL
    /// directory — memory AND the acceptor's modeled stable storage both
    /// die — then rebuild it from disk alone (plus best-effort catch-up
    /// on entries chosen while it was down).
    pub fn restart_replica(&self, idx: usize) -> Result<()> {
        let Some(r) = self.replicas.get(idx) else {
            return Ok(());
        };
        if !r.has_wal_setup() {
            return Err(Error::InvalidArgument(format!(
                "replica {idx} of shard {} has no WAL to restart from",
                self.shard
            )));
        }
        r.crash_to_disk();
        self.recover_replica(idx)
    }

    /// Rejoin a crashed replica.
    ///
    /// Durable mode: the WAL directory is the authority — the replica
    /// restarts from disk alone (a corrupt WAL is a typed error and the
    /// replica stays dead), then best-effort catches up on entries
    /// chosen while it was down by pulling its log suffix from the
    /// longest live peer (no live peer is fine: the disk state is a
    /// consistent prefix, and leader catch-up recovers the rest).
    ///
    /// In-memory mode: pull a chosen log through the transport and
    /// replay it deterministically into a fresh state.  Any live
    /// replica's log is a prefix of the group log, so the longest one is
    /// a safe replay source — rejoining a learner needs no quorum (its
    /// acceptor state survived the crash; only materialized state is
    /// rebuilt).  Entries chosen but not yet learned anywhere are
    /// recovered later by the next leader's prepare rounds.
    pub fn recover_replica(&self, idx: usize) -> Result<()> {
        let Some(r) = self.replicas.get(idx) else {
            return Ok(());
        };
        if r.is_alive() {
            return Ok(());
        }
        if r.has_wal_setup() {
            r.recover_from_disk(self.clock.now_ms(), self.lease_ms)?;
            let Some(from) = r.log_len_if_alive() else {
                return Ok(());
            };
            if let Some((len, src)) = self.longest_live_log(idx) {
                if len > from {
                    let peer = self.peers[src].clone();
                    let entries = self
                        .transport
                        .call(
                            peer,
                            Request::PaxosPull {
                                shard: self.shard,
                                from,
                            },
                        )?
                        .into_log_suffix()?;
                    // One WAL batch for the whole catch-up suffix: the
                    // entries acknowledge together, so they sync
                    // together.
                    r.learn_chosen_batch(from, entries)?;
                }
            }
            return Ok(());
        }
        let Some((_, src)) = self.longest_live_log(idx) else {
            return Err(Error::NoQuorum {
                alive: 0,
                total: self.replicas.len(),
            });
        };
        let peer = self.peers[src].clone();
        let entries = self
            .transport
            .call(
                peer,
                Request::PaxosPull {
                    shard: self.shard,
                    from: 0,
                },
            )?
            .into_log_suffix()?;
        r.restore(entries, self.clock.now_ms(), self.lease_ms);
        Ok(())
    }

    /// The longest chosen log among live replicas other than `except`:
    /// the safest replay/catch-up source.
    fn longest_live_log(&self, except: usize) -> Option<(u64, usize)> {
        let mut best: Option<(u64, usize)> = None;
        for (i, rep) in self.replicas.iter().enumerate() {
            if i == except {
                continue;
            }
            if let Some(len) = rep.log_len_if_alive() {
                let better = match best {
                    Some((b, _)) => len > b,
                    None => true,
                };
                if better {
                    best = Some((len, i));
                }
            }
        }
        best
    }

    /// Blocking leader discovery/renewal — what a client's retry layer
    /// calls after [`Error::NotLeader`].
    pub fn heal(&self) -> Result<u32> {
        self.ensure_leader(true)
    }

    /// Leader check honoring the caller's election policy (the
    /// replicated store's pre-flight before proposing anything).
    pub(crate) fn ensure(&self, auto_elect: bool) -> Result<u32> {
        self.ensure_leader(auto_elect)
    }

    /// All live replicas hold identical logs and states (test invariant).
    pub fn converged(&self) -> bool {
        let snapshots: Vec<(Vec<LogEntry>, KvState)> = self
            .replicas
            .iter()
            .filter_map(|r| {
                let g = r.lock_inner();
                g.alive.then(|| (g.log.clone(), g.state.clone()))
            })
            .collect();
        snapshots.windows(2).all(|w| w[0] == w[1])
    }

    /// Observability snapshot, shaped like the chain-mode stats.
    pub fn stats(&self) -> ShardStats {
        let keys = self
            .lowest_alive()
            .and_then(|l| self.replicas[l as usize].read_state(|s| s.len()))
            .unwrap_or(0);
        ShardStats {
            keys,
            live_replicas: self.replicas.iter().filter(|r| r.is_alive()).count(),
            total_replicas: self.replicas.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{SliceData, SlicePtr};

    fn group() -> ShardGroup {
        ShardGroup::new(
            0,
            3,
            Arc::new(Transport::instant()),
            LeaseClock::manual(),
            20,
        )
    }

    fn k(s: &str) -> Key {
        Key::sys(s)
    }

    fn put_entry(txn_id: u64, key: &Key, v: u64) -> LogEntry {
        LogEntry::apply(
            txn_id,
            vec![],
            vec![MetaOp::Put {
                key: key.clone(),
                value: Value::U64(v),
            }],
        )
    }

    fn eof_append_entry(txn_id: u64, key: &Key) -> LogEntry {
        LogEntry::apply(
            txn_id,
            vec![],
            vec![MetaOp::RegionAppendEof {
                key: key.clone(),
                data: SliceData::Stored(vec![SlicePtr {
                    server: 1,
                    backing: 0,
                    offset: 0,
                    len: 8,
                }]),
                len: 8,
                cap: 1 << 20,
            }],
        )
    }

    fn prepare_entry(txn_id: u64, ops: Vec<MetaOp>, coordinator: u32) -> LogEntry {
        LogEntry {
            txn_id,
            reads: vec![],
            ops,
            kind: EntryKind::Prepare {
                participants: vec![coordinator, 1],
                coordinator,
            },
        }
    }

    #[test]
    fn commit_applies_on_every_replica() {
        let g = group();
        g.commit_entry(&put_entry(1, &k("a"), 7), true).unwrap();
        assert!(g.converged());
        assert_eq!(g.local_get(&k("a"), true).unwrap(), Some((Value::U64(7), 1)));
        assert_eq!(g.elections(), 1);
        // Second commit rides the established lease (no new election).
        g.commit_entry(&put_entry(2, &k("b"), 8), true).unwrap();
        assert_eq!(g.elections(), 1);
        assert!(g.converged());
    }

    #[test]
    fn duplicate_txn_id_applies_exactly_once() {
        let g = group();
        let r = Key::new(Space::Region, "r");
        let e = eof_append_entry(5, &r);
        let first = g.commit_entry(&e, true).unwrap();
        assert_eq!(first, vec![OpOutcome::AppendedAt(0)]);
        // Retry of the same transaction (e.g. after a spurious failover):
        // dedup short-circuits, nothing re-applies, and the ORIGINAL
        // recorded outcomes come back.
        let second = g.commit_entry(&e, true).unwrap();
        assert_eq!(second, first);
        let (v, ver) = g.local_get(&r, true).unwrap().unwrap();
        assert_eq!(v.as_region().unwrap().eof, 8, "applied exactly once");
        assert_eq!(ver, 1);
    }

    #[test]
    fn follower_loss_still_commits_and_recovery_replays() {
        let g = group();
        g.commit_entry(&put_entry(1, &k("a"), 1), true).unwrap();
        g.kill_replica(2);
        g.commit_entry(&put_entry(2, &k("b"), 2), true).unwrap();
        assert_eq!(g.stats().live_replicas, 2);
        g.recover_replica(2).unwrap();
        assert!(g.converged(), "rejoined replica replayed the log");
        assert_eq!(g.stats().live_replicas, 3);
    }

    #[test]
    fn leader_death_fails_over_and_preserves_history() {
        let g = group();
        g.commit_entry(&put_entry(1, &k("a"), 1), true).unwrap();
        assert_eq!(g.leader(), Some(0));
        g.kill_replica(0);
        // Election waits out replica 0's lease (manual clock advances in
        // sleep_ms), then replica 1 takes over with the log intact.
        g.commit_entry(&put_entry(2, &k("b"), 2), true).unwrap();
        assert_eq!(g.leader(), Some(1));
        assert_eq!(g.elections(), 2);
        assert_eq!(g.local_get(&k("a"), true).unwrap(), Some((Value::U64(1), 1)));
        assert_eq!(g.local_get(&k("b"), true).unwrap(), Some((Value::U64(2), 1)));
        assert!(g.converged());
    }

    #[test]
    fn chosen_but_unlearned_entry_survives_failover() {
        let g = group();
        g.commit_entry(&put_entry(1, &k("a"), 1), true).unwrap();
        // Simulate a leader that died after winning phase 2 on a quorum
        // but before anyone learned: inject accepts at slot 1 on replicas
        // 1 and 2 only.
        let orphan = put_entry(9, &k("orphan"), 99);
        for idx in [1usize, 2] {
            let peer = g.replica(idx).unwrap().clone() as Peer;
            let resp = g
                .transport
                .call(
                    peer,
                    Request::PaxosAccept {
                        shard: 0,
                        slot: 1,
                        ballot: Ballot {
                            round: 3,
                            proposer: 0,
                        },
                        entry: orphan.clone(),
                    },
                )
                .unwrap();
            assert_eq!(resp, Response::Accepted(true));
        }
        g.kill_replica(0);
        // The next commit must first re-decide slot 1 — adopting the
        // orphan — and only then place itself.
        g.commit_entry(&put_entry(10, &k("next"), 5), true).unwrap();
        assert_eq!(
            g.local_get(&k("orphan"), true).unwrap(),
            Some((Value::U64(99), 1)),
            "quorum-accepted entry survived the leader's death"
        );
        assert_eq!(
            g.local_get(&k("next"), true).unwrap(),
            Some((Value::U64(5), 1))
        );
        assert!(g.converged());
    }

    #[test]
    fn read_lease_serves_locally_and_counts() {
        let g = group();
        g.commit_entry(&put_entry(1, &k("a"), 1), true).unwrap();
        let before = g.lease_reads();
        for _ in 0..10 {
            g.local_get(&k("a"), true).unwrap();
        }
        assert_eq!(g.lease_reads(), before + 10);
        assert_eq!(g.elections(), 1, "no quorum round per read");
    }

    #[test]
    fn not_leader_surfaces_without_auto_elect() {
        let g = group();
        g.commit_entry(&put_entry(1, &k("a"), 1), true).unwrap();
        g.kill_replica(0);
        let err = g.commit_entry(&put_entry(2, &k("b"), 2), false).unwrap_err();
        assert!(matches!(err, Error::NotLeader { shard: 0, hint: Some(1) }), "{err:?}");
        // Reads hit the same wall, then succeed once a leader is elected.
        assert!(matches!(
            g.local_get(&k("a"), false),
            Err(Error::NotLeader { .. })
        ));
        g.commit_entry(&put_entry(2, &k("b"), 2), true).unwrap();
        assert_eq!(g.local_get(&k("b"), false).unwrap(), Some((Value::U64(2), 1)));
    }

    #[test]
    fn no_quorum_is_a_hard_stop_until_a_replica_rejoins() {
        let g = group();
        g.commit_entry(&put_entry(1, &k("a"), 1), true).unwrap();
        g.kill_replica(1);
        g.kill_replica(2);
        assert!(matches!(
            g.commit_entry(&put_entry(2, &k("b"), 2), true),
            Err(Error::NoQuorum { .. })
        ));
        // Rejoining a learner needs no quorum: replay the survivor's log.
        g.recover_replica(1).unwrap();
        g.commit_entry(&put_entry(2, &k("b"), 2), true).unwrap();
        assert!(g.converged());
        assert_eq!(g.local_get(&k("a"), true).unwrap(), Some((Value::U64(1), 1)));
        assert_eq!(g.local_get(&k("b"), true).unwrap(), Some((Value::U64(2), 1)));
    }

    #[test]
    fn deterministic_abort_is_consistent_across_replicas() {
        let g = group();
        g.commit_entry(&put_entry(1, &k("a"), 1), true).unwrap();
        // A stale read set aborts deterministically at apply on every
        // replica — surfaced to the proposer as TxnAborted — and state
        // and versions stay identical everywhere.
        let stale = LogEntry::apply(
            2,
            vec![(k("a"), 0)],
            vec![MetaOp::Put {
                key: k("a"),
                value: Value::U64(9),
            }],
        );
        let err = g.commit_entry(&stale, true).unwrap_err();
        assert!(matches!(err, Error::TxnAborted { .. }), "{err:?}");
        assert!(g.converged());
        assert_eq!(g.local_get(&k("a"), true).unwrap(), Some((Value::U64(1), 1)));
    }

    // -----------------------------------------------------------------
    // 2PC entries: prepare stages + locks, decide resolves exactly once.
    // -----------------------------------------------------------------

    #[test]
    fn prepare_stages_without_applying_and_locks_the_key() {
        let g = group();
        let r = Key::new(Space::Region, "r");
        let prep = prepare_entry(
            7,
            vec![MetaOp::RegionAppendEof {
                key: r.clone(),
                data: SliceData::Stored(vec![SlicePtr {
                    server: 1,
                    backing: 0,
                    offset: 0,
                    len: 8,
                }]),
                len: 8,
                cap: 1 << 20,
            }],
            0,
        );
        let landed = g.propose_entry(&prep, true).unwrap();
        assert!(
            matches!(landed, Landed::Voted(Some(ref o)) if o == &vec![OpOutcome::AppendedAt(0)]),
            "{landed:?}"
        );
        // Nothing applied, but the key is locked against reads...
        assert!(matches!(
            g.local_locked(&r, true, |s| s.version(&r)).unwrap(),
            LockedRead::Locked {
                txn_id: 7,
                coordinator: 0,
                ..
            }
        ));
        // ...and the lock-blind read still sees pre-transaction state.
        assert_eq!(g.local_get(&r, true).unwrap(), None);
        assert_eq!(
            g.pending_intents(true).unwrap(),
            vec![(7, 0, vec![0, 1])],
            "intent carries its participant list"
        );

        // Commit decision flushes the staged overlay; replaying it (and
        // the prepare) changes nothing — exactly-once via txn-id dedup.
        let applied = g.commit_entry(&LogEntry::decide(7, true), true).unwrap();
        assert_eq!(applied, vec![OpOutcome::AppendedAt(0)]);
        let (v, ver) = g.local_get(&r, true).unwrap().unwrap();
        assert_eq!(v.as_region().unwrap().eof, 8);
        assert_eq!(ver, 1);
        let replay = g.commit_entry(&LogEntry::decide(7, true), true).unwrap();
        assert_eq!(replay, applied);
        assert!(matches!(
            g.propose_entry(&prep, true).unwrap(),
            Landed::Applied(Some(_))
        ));
        let (v, ver) = g.local_get(&r, true).unwrap().unwrap();
        assert_eq!(v.as_region().unwrap().eof, 8, "applied exactly once");
        assert_eq!(ver, 1);
        assert!(matches!(
            g.local_locked(&r, true, |_| ()).unwrap(),
            LockedRead::Clear(())
        ));
        assert!(g.pending_intents(true).unwrap().is_empty());
        assert_eq!(g.decision(7, true).unwrap(), Some(true));
        assert!(g.converged());
    }

    #[test]
    fn decide_abort_discards_the_intent_and_unlocks() {
        let g = group();
        let a = k("a");
        g.commit_entry(&put_entry(1, &a, 1), true).unwrap();
        let prep = prepare_entry(
            2,
            vec![MetaOp::Put {
                key: a.clone(),
                value: Value::U64(9),
            }],
            0,
        );
        assert!(matches!(
            g.propose_entry(&prep, true).unwrap(),
            Landed::Voted(Some(_))
        ));
        let err = g.commit_entry(&LogEntry::decide(2, false), true).unwrap_err();
        assert!(matches!(err, Error::TxnAborted { .. }), "{err:?}");
        assert_eq!(g.local_get(&a, true).unwrap(), Some((Value::U64(1), 1)));
        assert!(matches!(
            g.local_locked(&a, true, |_| ()).unwrap(),
            LockedRead::Clear(())
        ));
        assert_eq!(g.decision(2, true).unwrap(), Some(false));
        assert!(g.converged());
    }

    #[test]
    fn first_decision_wins_over_a_replayed_opposite() {
        let g = group();
        let a = k("a");
        let prep = prepare_entry(
            3,
            vec![MetaOp::Put {
                key: a.clone(),
                value: Value::U64(5),
            }],
            0,
        );
        g.propose_entry(&prep, true).unwrap();
        let _ = g.commit_entry(&LogEntry::decide(3, false), true);
        // A later commit-direction replay must NOT flip the outcome.
        let err = g.commit_entry(&LogEntry::decide(3, true), true).unwrap_err();
        assert!(matches!(err, Error::TxnAborted { .. }), "{err:?}");
        assert_eq!(g.decision(3, true).unwrap(), Some(false));
        assert_eq!(g.local_get(&a, true).unwrap(), None);
        assert!(g.converged());
    }

    #[test]
    fn interloper_on_a_locked_key_aborts_deterministically() {
        let g = group();
        let a = k("a");
        let prep = prepare_entry(
            4,
            vec![MetaOp::Put {
                key: a.clone(),
                value: Value::U64(1),
            }],
            0,
        );
        g.propose_entry(&prep, true).unwrap();
        // A direct-apply entry touching the locked key aborts (state is
        // frozen so the eventual commit decision can flush the staged
        // overlay verbatim); an entry on OTHER keys sails through.
        let err = g.commit_entry(&put_entry(5, &a, 9), true).unwrap_err();
        assert!(matches!(err, Error::TxnAborted { .. }), "{err:?}");
        g.commit_entry(&put_entry(6, &k("b"), 2), true).unwrap();
        g.commit_entry(&LogEntry::decide(4, true), true).unwrap();
        assert_eq!(g.local_get(&a, true).unwrap(), Some((Value::U64(1), 1)));
        assert_eq!(g.local_get(&k("b"), true).unwrap(), Some((Value::U64(2), 1)));
        assert!(g.converged());
    }

    #[test]
    fn rejoining_replica_replays_pending_intents_and_resolutions() {
        let g = group();
        let a = k("a");
        let b = k("b");
        // One resolved and one still-pending intent in the log.
        g.propose_entry(
            &prepare_entry(
                8,
                vec![MetaOp::Put {
                    key: a.clone(),
                    value: Value::U64(1),
                }],
                0,
            ),
            true,
        )
        .unwrap();
        g.commit_entry(&LogEntry::decide(8, true), true).unwrap();
        g.propose_entry(
            &prepare_entry(
                9,
                vec![MetaOp::Put {
                    key: b.clone(),
                    value: Value::U64(2),
                }],
                0,
            ),
            true,
        )
        .unwrap();

        g.kill_replica(2);
        g.recover_replica(2).unwrap();
        assert!(g.converged(), "replayed log rebuilds intents identically");
        let r2 = g.replica(2).unwrap();
        assert!(r2.is_alive());
        // The rejoined replica holds the pending intent (txn 9) and the
        // resolved state of txn 8.
        let locked = g.local_locked(&b, true, |_| ()).unwrap();
        assert!(matches!(locked, LockedRead::Locked { txn_id: 9, .. }));
        assert_eq!(g.local_get(&a, true).unwrap(), Some((Value::U64(1), 1)));
        // Resolve the straggler; everyone agrees.
        g.commit_entry(&LogEntry::decide(9, true), true).unwrap();
        assert_eq!(g.local_get(&b, true).unwrap(), Some((Value::U64(2), 1)));
        assert!(g.converged());
    }

    #[test]
    fn batch_entry_applies_members_individually_in_one_slot() {
        let g = group();
        let a = k("a");
        let b = k("b");
        // Txn 1 lands alone first — the batch replay of it must dedup.
        g.commit_entry(&put_entry(1, &a, 1), true).unwrap();
        let before = g.log_len(true).unwrap();
        // One batch: a dup of txn 1 (tries to clobber a=99), a fresh
        // txn 2, and a txn 3 whose read-set is stale (deterministic
        // per-member abort).
        let stale = LogEntry::apply(
            3,
            vec![(a.clone(), 0)], // a is at version 1 → conflict
            vec![MetaOp::Put {
                key: b.clone(),
                value: Value::U64(30),
            }],
        );
        let batch = LogEntry::batch(
            100,
            vec![put_entry(1, &a, 99), put_entry(2, &b, 2), stale],
        );
        assert_eq!(g.commit_entry(&batch, true).unwrap(), Vec::new());
        // Three member verdicts, ONE Paxos slot.
        assert_eq!(g.log_len(true).unwrap(), before + 1);
        // Dedup: txn 1's original apply stands, the replay was skipped.
        assert_eq!(g.local_get(&a, true).unwrap(), Some((Value::U64(1), 1)));
        assert_eq!(g.txn_outcomes(1, true).unwrap(), Some(Some(vec![OpOutcome::Done])));
        // Fresh member applied; aborted member recorded as Some(None).
        assert_eq!(g.local_get(&b, true).unwrap(), Some((Value::U64(2), 1)));
        assert!(matches!(g.txn_outcomes(2, true).unwrap(), Some(Some(_))));
        assert_eq!(g.txn_outcomes(3, true).unwrap(), Some(None));
        // The wrapper settles under its own id and the replicas agree.
        assert_eq!(g.txn_outcomes(100, true).unwrap(), Some(Some(Vec::new())));
        assert!(g.converged());
        // Retrying the whole batch is absorbed by the wrapper dedup.
        g.commit_entry(&batch, true).unwrap();
        assert_eq!(g.log_len(true).unwrap(), before + 1);
        assert_eq!(g.local_get(&b, true).unwrap(), Some((Value::U64(2), 1)));
    }

    #[test]
    fn batch_survives_leader_death_with_member_dedup() {
        let g = group();
        let a = k("a");
        let b = k("b");
        // Member txn 2 already applied alone on the group.
        g.commit_entry(&put_entry(2, &b, 7), true).unwrap();
        let batch = LogEntry::batch(50, vec![put_entry(1, &a, 1), put_entry(2, &b, 99)]);
        g.commit_entry(&batch, true).unwrap();
        // Kill the leader; the survivors already learned the batch.
        g.kill_replica(0);
        assert_eq!(g.local_get(&a, true).unwrap(), Some((Value::U64(1), 1)));
        assert_eq!(g.local_get(&b, true).unwrap(), Some((Value::U64(7), 1)));
        // A failover replay of the same batch changes nothing.
        g.commit_entry(&batch, true).unwrap();
        assert_eq!(g.local_get(&b, true).unwrap(), Some((Value::U64(7), 1)));
        g.recover_replica(0).unwrap();
        assert!(g.converged());
    }

    #[test]
    fn replayed_and_stale_paxos_envelopes_are_safe() {
        let g = group();
        g.commit_entry(&put_entry(1, &k("a"), 1), true).unwrap();
        let peer = g.replica(1).unwrap().clone() as Peer;
        let high = Ballot {
            round: 50,
            proposer: 0,
        };
        // Prepare, then the network re-delivers the same envelope: the
        // promise is already recorded, so the replay is stale-ballot
        // rejected — and a rejection changes nothing.
        let prepare = Request::PaxosPrepare {
            shard: 0,
            slot: 5,
            ballot: high,
        };
        let (granted, _) = g
            .transport
            .call(peer.clone(), prepare.clone())
            .unwrap()
            .into_promised()
            .unwrap();
        assert!(granted);
        let (replayed, _) = g
            .transport
            .call(peer.clone(), prepare)
            .unwrap()
            .into_promised()
            .unwrap();
        assert!(!replayed, "re-delivered prepare must not be re-granted");
        // A genuinely stale (lower) ballot is rejected the same way.
        let (low, _) = g
            .transport
            .call(
                peer.clone(),
                Request::PaxosPrepare {
                    shard: 0,
                    slot: 5,
                    ballot: Ballot {
                        round: 7,
                        proposer: 2,
                    },
                },
            )
            .unwrap()
            .into_promised()
            .unwrap();
        assert!(!low, "stale-ballot prepare rejected");
        // Accept at the promised ballot, then its re-delivery: both
        // ack, and the accepted value is simply re-recorded unchanged.
        let entry = put_entry(9, &k("dup"), 9);
        let accept = Request::PaxosAccept {
            shard: 0,
            slot: 5,
            ballot: high,
            entry: entry.clone(),
        };
        assert_eq!(
            g.transport.call(peer.clone(), accept.clone()).unwrap(),
            Response::Accepted(true)
        );
        assert_eq!(
            g.transport.call(peer.clone(), accept).unwrap(),
            Response::Accepted(true),
            "duplicate accept re-acks idempotently"
        );
        // A stale-ballot accept cannot clobber it.
        assert_eq!(
            g.transport
                .call(
                    peer.clone(),
                    Request::PaxosAccept {
                        shard: 0,
                        slot: 5,
                        ballot: Ballot {
                            round: 7,
                            proposer: 2,
                        },
                        entry: put_entry(66, &k("evil"), 6),
                    },
                )
                .unwrap(),
            Response::Accepted(false),
            "stale-ballot accept rejected"
        );
    }

    #[test]
    fn replayed_learn_applies_exactly_once() {
        let g = group();
        let r = k("r");
        let e = eof_append_entry(5, &r);
        g.commit_entry(&e, true).unwrap();
        // The network re-delivers the chosen entry to every replica —
        // including the ones that already learned it in the commit.
        for idx in 0..3 {
            let peer = g.replica(idx).unwrap().clone() as Peer;
            for _ in 0..2 {
                assert_eq!(
                    g.transport
                        .call(
                            peer.clone(),
                            Request::PaxosLearn {
                                shard: 0,
                                slot: 0,
                                entry: e.clone(),
                            },
                        )
                        .unwrap(),
                    Response::Learned
                );
            }
        }
        let (v, ver) = g.local_get(&r, true).unwrap().unwrap();
        assert_eq!(v.as_region().unwrap().eof, 8, "append applied exactly once");
        assert_eq!(ver, 1);
        assert_eq!(g.log_len(true).unwrap(), 1, "re-learns appended nothing");
        assert!(g.converged());
    }

    #[test]
    fn redelivered_lease_grant_does_not_extend_the_lease() {
        let g = group();
        g.commit_entry(&put_entry(1, &k("a"), 1), true).unwrap();
        let epoch_used = g.lease_epoch.load(Ordering::Relaxed);
        assert!(epoch_used >= 1, "election stamped an epoch");
        // Re-deliver the (already answered) grant envelope to replica 1
        // with a much later until_ms — a delayed retransmission.  The
        // holder is re-acked, but the recorded grant must not move.
        let peer = g.replica(1).unwrap().clone() as Peer;
        let replay = Request::LeaseRequest {
            shard: 0,
            leader: 0,
            until_ms: self::far_future_ms(),
            epoch: epoch_used,
        };
        assert_eq!(
            g.transport.call(peer.clone(), replay).unwrap(),
            Response::LeaseGranted(true),
            "same-holder replay is an idempotent ack"
        );
        let grant = g
            .replica(1)
            .unwrap()
            .read_inner(|inner| inner.grant.live_grant(g.clock.now_ms()))
            .unwrap()
            .expect("grant live");
        assert!(
            grant.until_ms <= g.clock.now_ms() + g.lease_ms,
            "replayed grant extended the lease to {}",
            grant.until_ms
        );
        // A different would-be leader replaying the same epoch is
        // refused outright.
        let takeover = Request::LeaseRequest {
            shard: 0,
            leader: 2,
            until_ms: self::far_future_ms(),
            epoch: epoch_used,
        };
        assert_eq!(
            g.transport.call(peer, takeover).unwrap(),
            Response::LeaseGranted(false),
            "stale-epoch takeover rejected"
        );
    }

    fn far_future_ms() -> u64 {
        1 << 40
    }
}
