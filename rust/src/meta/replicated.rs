//! The Paxos-replicated metadata store: [`MetaStore`]'s surface, served
//! by per-shard [`ShardGroup`]s instead of in-process chains.
//!
//! A [`Commit`] is validated and staged once at the front-end — under
//! the commit gates of every shard it touches, taken in canonical order,
//! which serializes validate→propose exactly like the chain store's
//! ordered shard locks — then split into per-shard [`LogEntry`] batches
//! and driven through each group's replicated log.  The one op that
//! reads across shards (`InodeSetLenFromRegion`) is rewritten at the
//! gate into its self-contained monotone-max form when its region lives
//! in a different group, so every entry is locally applicable and
//! deterministic.
//!
//! **Cross-group atomicity** comes in two strengths:
//!
//! * With `Config::meta_2pc` OFF (the default), multi-shard entries are
//!   proposed directly in dependency order (namespace-root inserts last,
//!   removals first), and a commit mixing insert+remove directions in
//!   one entry additionally registers a front-end *entry hold* on its
//!   namespace keys so gate-free reads cannot resolve a dangling
//!   reference mid-flight.  A quorum dying mid-sequence can still strand
//!   earlier groups' applied entries (surfaced, not hidden).
//! * With `Config::meta_2pc` ON, multi-shard commits run an
//!   intent-logged two-phase commit over the same replicated logs:
//!   phase 1 proposes a durable `Prepare` intent into every touched
//!   group (staged + key-locked, nothing applied), a `Decide` record
//!   replicated in the LOWEST-numbered participant group fixes the
//!   outcome (first decision in its log wins), and phase 2 flushes or
//!   discards each staged intent exactly once via the txn-id dedup.
//!   Leaseholder reads treat intent-locked keys as unreadable until
//!   they resolve the intent through the decision record.  Presumed
//!   abort is justified by the *coordinator claim* the front-end
//!   records in the coordinator group before its first prepare: the
//!   claim bounds (on the coordinator's own clock, padded by
//!   `Config::max_clock_skew` on the resolver's side) how long the
//!   coordinator may still decide, so "claim expired + no decision
//!   recorded" means the resolver's durable abort record wins the
//!   decision race — a rule that holds across real process boundaries,
//!   where the old "commit gate held + no decision" proof only covered
//!   front-ends sharing this process's mutexes.  A group that loses
//!   its quorum mid-commit therefore rejoins to the recorded decision
//!   instead of stranding a phantom entry.
//!
//! Invariants (asserted by the fault-injection suite):
//!
//! * a quorum-accepted entry survives its leader's death (the next
//!   leader's prepare rounds adopt it);
//! * a commit retried across failover applies **exactly once** (apply is
//!   deduplicated on the transaction id);
//! * reads are leaseholder-local — no quorum round — and never observe
//!   state a lease could not vouch for, nor a key a pending intent has
//!   locked;
//! * with a majority of a group dead, commits fail with `NoQuorum` and
//!   nothing is partially visible in that group;
//! * under `meta_2pc`, every participant of a cross-group transaction
//!   eventually agrees with its decision record — through coordinator
//!   death, participant quorum loss, and decision replay.
//!
//! (`scan_space` stays lock-blind on purpose: a pending intent has not
//! mutated state, so GC scans see the pre-transaction view — tolerable
//! staleness under the two-consecutive-scan rule.)
//!
//! Read-set validation is intentionally *origin-blind*: a `(key,
//! version)` pair observed from a leaseholder read and one replayed
//! from the client's versioned metadata cache (PR 9's transactional
//! read-through) are indistinguishable here, and both are rejected
//! with `TxnConflict` the moment the committed version moved.  That
//! makes this validation loop the single serializability backstop for
//! every cached read in the system — no cache-aware code exists on the
//! server side, and none may be added.
//!
//! [`MetaStore`]: super::MetaStore

use super::group::{
    ArmOutcome, ArmedAccept, Landed, LockedRead, LogEntry, EntryKind, ShardGroup,
};
use super::ops::{self, MetaOp, OpOutcome};
use super::shard::ShardStats;
use super::store::Commit;
use super::wal;
use crate::config::WalSync;
use crate::coordinator::lease::LeaseClock;
use crate::error::{Error, Result};
use crate::net::{Peer, Request, Transport};
use crate::types::{Key, Space, Value};
use std::collections::HashMap;
use std::io::{Read as _, Write as _};
use std::path::Path;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Proposal order for one shard's entry within a multi-shard commit:
/// namespace-root REMOVALS first (-1), plain data in the middle (0),
/// namespace-root INSERTS last (+1).  Readers resolve files through
/// Path/Dir entries and take no commit gate (reads are
/// leaseholder-local), so inserting those roots *after* their referents
/// — and removing them *before* — keeps the common create/unlink shapes
/// free of reader-visible dangling references while a multi-shard
/// commit is mid-flight.  (An entry mixing both directions cannot be
/// fully ordered; the non-2PC path covers it with an entry hold, and
/// the 2PC path with intent locks.)
fn entry_priority(ops: &[&MetaOp]) -> i32 {
    let mut pri = 0;
    for op in ops {
        if op.inserts_namespace_root() {
            pri = pri.max(1);
        }
        if op.removes_namespace_root() {
            pri = pri.min(-1);
        }
    }
    pri
}

/// High bit marking a coordinator-claim entry's txn id.  Claim entries
/// share each group's txn-id dedup space with real transactions;
/// `next_txn` allocates from 1 upward, so the top two bits are free to
/// namespace the bookkeeping entries a 2PC transaction rides along.
const CLAIM_TXN_BIT: u64 = 1 << 63;
/// High bit marking a claim-cleanup (delete) entry's txn id.
const CLAIM_DROP_BIT: u64 = 1 << 62;

/// Where txn `txn_id`'s coordinator claim lives in the coordinator
/// group's key space.
fn claim_key(txn_id: u64) -> Key {
    Key::sys(format!("txn-claim/{txn_id:016x}"))
}

/// Named instants of a multi-shard commit, exposed to the deterministic
/// fault-schedule driver in `tests/`.  The hook installed via
/// [`ReplicatedMetaStore::set_fault_hook`] fires at each point with the
/// transaction id; returning [`FaultAction::Abandon`] makes the
/// front-end stop dead (simulated coordinator death) with the commit's
/// gates released and its intents orphaned.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommitPhase {
    /// Gates held, read set validated, ops staged; nothing proposed yet.
    Staged,
    /// (non-2PC path) the shard's direct-apply entry is in its log.
    Proposed { shard: u32 },
    /// (2PC) the `Prepared` intent for `shard` is in its group's log.
    Prepared { shard: u32 },
    /// (2PC) every participant's intent is logged; no decision yet —
    /// the classic window a coordinator can die in.
    AllPrepared,
    /// (2PC) the decision record is replicated in the coordinator group.
    Decided { commit: bool },
    /// (2PC) the decision has been applied in `shard` (phase 2).
    Applied { shard: u32 },
}

/// What the fault hook tells the committing front-end to do next.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    Continue,
    /// Stop the commit here, as if the coordinating front-end died.
    /// Only honored between 2PC phases (the direct path must drive to
    /// completion once proposing — that gap is exactly what `meta_2pc`
    /// exists to close).
    Abandon,
}

/// The fault-schedule hook type (tests only; `None` in deployments).
pub type FaultHook = Arc<dyn Fn(CommitPhase, u64) -> FaultAction + Send + Sync>;

/// One gate-holding commit attempt's result: done, or blocked on an
/// orphaned intent that must be resolved outside the gates.
enum Attempt {
    Done(Vec<OpOutcome>),
    Blocked {
        txn_id: u64,
        coordinator: u32,
        shard: u32,
        participants: Vec<u32>,
    },
}

/// Front-end *entry holds*: while a non-2PC multi-shard commit whose
/// entries mix namespace inserts and removals is proposing, its
/// namespace keys are held here and gate-free reads wait the hold out —
/// the reader-isolation fix for the one shape dependency ordering
/// cannot cover.  (In-process state, like the commit gates themselves:
/// the wire-free metadata plane executes on the caller's thread, so a
/// blocked reader never starves the transport pool.)
#[derive(Debug, Default)]
struct Holds {
    /// Fast path: readers skip the map entirely while nothing is held.
    active: AtomicUsize,
    map: Mutex<HashMap<Key, u32>>,
    released: Condvar,
}

impl Holds {
    fn acquire(&self, keys: Vec<Key>) -> HoldGuard<'_> {
        if !keys.is_empty() {
            let mut g = self.map.lock().unwrap();
            self.active.fetch_add(1, Ordering::SeqCst);
            for k in &keys {
                *g.entry(k.clone()).or_insert(0) += 1;
            }
        }
        HoldGuard { holds: self, keys }
    }

    /// Is `key` held right now?  The reader's post-read validation: the
    /// writer inserts its hold keys BEFORE its first proposal (and the
    /// reader's leaseholder read synchronizes with the writer's apply
    /// through the replica lock), so a read that observed any
    /// mid-commit state of a held key is guaranteed to still find the
    /// key here — unless the commit already finished, in which case the
    /// read's value composes with post-commit state anyway.
    fn held(&self, key: &Key) -> bool {
        if self.active.load(Ordering::SeqCst) == 0 {
            return false;
        }
        self.map.lock().unwrap().contains_key(key)
    }

    /// Block while `key` is held.  Bounded (the hold window spans a few
    /// in-process log proposals — microseconds) so a bug can never hang
    /// a reader forever; on timeout the reader proceeds with the
    /// pre-hold semantics.
    fn wait_out(&self, key: &Key) {
        if self.active.load(Ordering::SeqCst) == 0 {
            return;
        }
        let mut g = self.map.lock().unwrap();
        let mut rounds = 0u32;
        while g.contains_key(key) && rounds < 400 {
            let (ng, _) = self
                .released
                .wait_timeout(g, Duration::from_millis(5))
                .unwrap();
            g = ng;
            rounds += 1;
        }
    }
}

struct HoldGuard<'h> {
    holds: &'h Holds,
    keys: Vec<Key>,
}

impl Drop for HoldGuard<'_> {
    fn drop(&mut self) {
        if self.keys.is_empty() {
            return;
        }
        let mut g = self.holds.map.lock().unwrap();
        for k in &self.keys {
            if let Some(n) = g.get_mut(k) {
                *n -= 1;
                if *n == 0 {
                    g.remove(k);
                }
            }
        }
        self.holds.active.fetch_sub(1, Ordering::SeqCst);
        self.holds.released.notify_all();
    }
}

/// One shard group's group-commit accumulator
/// (`Config::group_commit_window`): single-shard commits that arrive
/// while a batch is forming ride the SAME Paxos round.  The first
/// enqueuer of a batch becomes its collector — it waits out the window
/// (or a full batch), takes the queue, and flushes one shared
/// [`EntryKind::Batch`] entry under the group's commit gate; every
/// member then picks up its own individually-recorded outcome.  There is
/// no background thread: batching borrows the member threads themselves,
/// so an idle store costs nothing.
struct Batcher {
    window: Duration,
    max_txns: usize,
    state: Mutex<BatcherState>,
    /// Signals both queue growth (to the collecting member) and result
    /// publication (to waiting members).
    signal: Condvar,
}

#[derive(Default)]
struct BatcherState {
    /// Commits waiting for the forming batch.
    queue: Vec<QueuedCommit>,
    /// Is some member thread currently collecting + flushing?
    flushing: bool,
    /// Published outcomes by member transaction id.
    done: HashMap<u64, MemberOutcome>,
}

struct QueuedCommit {
    txn_id: u64,
    commit: Commit,
    auto_elect: bool,
}

enum MemberOutcome {
    /// The member rode a batch to its verdict.
    Done(Result<Vec<OpOutcome>>),
    /// The member could not ride this batch (an orphaned intent covers
    /// one of its keys); it re-runs through the unbatched path, which
    /// resolves the orphan and retries.
    Fallback,
}

/// How one queued commit fared while the collector staged its batch.
enum MemberPrep {
    /// Validated and staged: ride the shared entry as this sub-entry.
    Sub(LogEntry),
    /// An orphaned intent covers a touched key — unbatched fallback.
    Fallback,
    /// Deterministic rejection (stale read set, validation failure):
    /// the member fails without ever reaching the log.
    Fail(Error),
}

impl Batcher {
    fn new(window: Duration, max_txns: usize) -> Self {
        Batcher {
            window,
            max_txns,
            state: Mutex::new(BatcherState::default()),
            signal: Condvar::new(),
        }
    }
}

/// Duplicate a commit-path error for every member of a shared batch.
/// [`Error`] is not `Clone` (it can wrap an `io::Error`), but every
/// variant the metadata commit path produces is duplicable; anything
/// else degrades to a described [`Error::TxnAborted`].
fn dup_error(e: &Error) -> Error {
    match e {
        Error::TxnConflict { space, key } => Error::TxnConflict {
            space: *space,
            key: key.clone(),
        },
        Error::TxnAborted { reason } => Error::TxnAborted {
            reason: reason.clone(),
        },
        Error::RetriesExhausted { attempts } => Error::RetriesExhausted {
            attempts: *attempts,
        },
        Error::NoQuorum { alive, total } => Error::NoQuorum {
            alive: *alive,
            total: *total,
        },
        Error::NotLeader { shard, hint } => Error::NotLeader {
            shard: *shard,
            hint: *hint,
        },
        Error::ReplicaLost { shard, replica } => Error::ReplicaLost {
            shard: *shard,
            replica: *replica,
        },
        // Duplicated verbatim so every batch member classifies the
        // outcome as indeterminate, exactly like the lone-commit path.
        Error::Timeout { op, elapsed } => Error::Timeout {
            op,
            elapsed: *elapsed,
        },
        Error::CorruptMetadata(msg) => Error::CorruptMetadata(msg.clone()),
        Error::CondAppendFailed { eof, len, cap } => Error::CondAppendFailed {
            eof: *eof,
            len: *len,
            cap: *cap,
        },
        other => Error::TxnAborted {
            reason: format!("group-commit batch failed: {other}"),
        },
    }
}

/// The sharded, Paxos-replicated metadata store.
pub struct ReplicatedMetaStore {
    groups: Vec<ShardGroup>,
    /// The front-end's clock: claim expiries and claim-wait sleeps are
    /// measured on it (manual in tests, monotonic in deployments).
    clock: LeaseClock,
    /// Leader lease length, reused as the unit for coordinator-claim
    /// lifetimes (a claim outlives two lease terms plus the skew bound).
    lease_ms: u64,
    /// `Config::max_clock_skew` in ms: the cross-process clock-skew
    /// budget padded onto claim expiry checks.
    max_skew_ms: AtomicU64,
    next_inode: AtomicU64,
    next_txn: AtomicU64,
    /// Route multi-shard commits through the intent-logged 2PC
    /// (`Config::meta_2pc`).  Single-shard commits stay one-phase — one
    /// log entry is already atomic.
    two_pc: bool,
    /// Collapse one 2PC commit's per-group phase-1/phase-2 proposals
    /// into shared transport scatters (`Config::prepare_batching`).
    prepare_batching: bool,
    /// Per-shard group-commit accumulators
    /// (`Config::group_commit_window`); `None` = group commit off.
    batchers: Option<Vec<Batcher>>,
    /// Reader-isolation entry holds for the non-2PC path.
    holds: Holds,
    /// Test-only fault-schedule hook (see [`CommitPhase`]).
    fault_hook: Mutex<Option<FaultHook>>,
    /// Fast path for [`Self::fire`]: deployments never install a hook,
    /// so commits must not contend on the `fault_hook` mutex (a global
    /// serialization point) just to find it `None`.
    hook_installed: std::sync::atomic::AtomicBool,
}

impl std::fmt::Debug for ReplicatedMetaStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ReplicatedMetaStore")
            .field("groups", &self.groups)
            .field("two_pc", &self.two_pc)
            .finish_non_exhaustive()
    }
}

impl ReplicatedMetaStore {
    /// `shards` groups of `replicas_per_group` members each, proposing
    /// over `transport` with `lease_ms`-long leader leases.
    pub fn new(
        shards: u32,
        replicas_per_group: u8,
        transport: Arc<Transport>,
        clock: LeaseClock,
        lease_ms: u64,
    ) -> Self {
        assert!(shards >= 1);
        let groups = (0..shards)
            .map(|s| {
                ShardGroup::new(
                    s,
                    replicas_per_group,
                    transport.clone(),
                    clock.clone(),
                    lease_ms,
                )
            })
            .collect();
        Self::from_groups(groups, clock, lease_ms)
    }

    /// Wrap pre-built shard groups (the multi-process front end builds
    /// its groups with [`ShardGroup::with_remote_members`] and hands
    /// them over here; the single-process path goes through
    /// [`Self::new`]).
    pub fn from_groups(groups: Vec<ShardGroup>, clock: LeaseClock, lease_ms: u64) -> Self {
        assert!(!groups.is_empty());
        ReplicatedMetaStore {
            groups,
            clock,
            lease_ms,
            max_skew_ms: AtomicU64::new(0),
            // inode 1 is reserved for the root directory
            next_inode: AtomicU64::new(2),
            // txn 0 is the noop filler id
            next_txn: AtomicU64::new(1),
            two_pc: false,
            prepare_batching: false,
            batchers: None,
            holds: Holds::default(),
            fault_hook: Mutex::new(None),
            hook_installed: std::sync::atomic::AtomicBool::new(false),
        }
    }

    /// Set the cross-process clock-skew budget (`Config::max_clock_skew`
    /// in ms).  Leader leases are shortened holder-side by this much
    /// ([`crate::coordinator::lease::holder_lease_bound`]) and 2PC
    /// coordinator-claim expiry checks are padded by it.
    pub fn max_clock_skew(self, ms: u64) -> Self {
        self.max_skew_ms.store(ms, Ordering::Relaxed);
        for g in &self.groups {
            g.set_max_clock_skew_ms(ms);
        }
        self
    }

    /// Route multi-shard commits through the intent-logged two-phase
    /// commit (`Config::meta_2pc`).  Builder-style so existing
    /// construction sites stay unchanged.
    pub fn two_pc(mut self, on: bool) -> Self {
        self.two_pc = on;
        self
    }

    /// Whether multi-shard commits run the intent-logged 2PC.
    pub fn is_two_pc(&self) -> bool {
        self.two_pc
    }

    /// Collapse one 2PC commit's per-group phase-1 prepares (and its
    /// phase-2 decides) into shared transport scatters
    /// (`Config::prepare_batching`).  Builder-style, like
    /// [`Self::two_pc`].
    pub fn prepare_batching(mut self, on: bool) -> Self {
        self.prepare_batching = on;
        self
    }

    /// Whether 2PC phases batch their cross-group scatters.
    pub fn is_prepare_batching(&self) -> bool {
        self.prepare_batching
    }

    /// Enable Paxos group commit (`Config::group_commit_window`):
    /// single-shard commits arriving within `window` of each other are
    /// packed into ONE shared log entry — one Paxos round for the whole
    /// batch — bounded at `max_txns` members (a full batch flushes
    /// early).  `Duration::ZERO` turns it off.  Builder-style, like
    /// [`Self::two_pc`].
    pub fn group_commit(mut self, window: Duration, max_txns: usize) -> Self {
        self.batchers = (!window.is_zero()).then(|| {
            (0..self.groups.len())
                .map(|_| Batcher::new(window, max_txns.max(2)))
                .collect()
        });
        self
    }

    /// Whether single-shard commits ride the group-commit accumulator.
    pub fn is_group_commit(&self) -> bool {
        self.batchers.is_some()
    }

    /// Turn on durability (`Config::meta_durable`): every replica of
    /// every shard group gets an on-disk write-ahead log under `root`
    /// (`root/shard-<s>/replica-<r>/`) and comes up from whatever those
    /// directories already hold — a first boot stamps fresh markers, a
    /// restart replays.  Builder-style but fallible: the WAL root is
    /// stamped with a cluster marker (magic, format version, shard
    /// count, replicas per group) on first use, and a mismatching marker
    /// is refused so two differently-shaped clusters can never
    /// interleave their segments in one directory.
    pub fn durable(self, root: &Path, sync: WalSync, checkpoint_every: u64) -> Result<Self> {
        let replicas = self
            .groups
            .first()
            .map(|g| g.num_replicas() as u32)
            .unwrap_or(0);
        std::fs::create_dir_all(root)?;
        let expect = wal::cluster_marker(self.groups.len() as u32, replicas);
        let marker = root.join("CLUSTER");
        match std::fs::File::open(&marker) {
            Ok(mut f) => {
                let mut found = Vec::new();
                f.read_to_end(&mut found)?;
                if found != expect {
                    return Err(Error::InvalidArgument(format!(
                        "WAL root {} belongs to a different cluster \
                         (marker mismatch); refusing to interleave segments",
                        root.display()
                    )));
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                let mut f = std::fs::File::create(&marker)?;
                f.write_all(&expect)?;
                f.sync_all()?;
            }
            Err(e) => return Err(e.into()),
        }
        for (s, g) in self.groups.iter().enumerate() {
            g.enable_wal(&root.join(format!("shard-{s}")), sync, checkpoint_every)?;
        }
        Ok(self)
    }

    /// Whether the shard groups carry on-disk WALs.
    pub fn is_durable(&self) -> bool {
        self.groups.iter().any(|g| g.is_durable())
    }

    /// Total chosen-log slots across every shard group — the Paxos
    /// commit rounds this store has consumed (observability: group
    /// commit packs many transactions into one slot, so the delta
    /// across a workload is the headline write-path metric).
    pub fn commit_rounds(&self) -> u64 {
        self.groups
            .iter()
            .map(|g| g.log_len(true).unwrap_or(0))
            .sum()
    }

    /// Install (or clear) the deterministic fault-schedule hook.  Test
    /// infrastructure only: deployments leave it `None`.
    pub fn set_fault_hook(&self, hook: Option<FaultHook>) {
        let mut g = self.fault_hook.lock().unwrap();
        self.hook_installed
            .store(hook.is_some(), Ordering::SeqCst);
        *g = hook;
    }

    fn fire(&self, phase: CommitPhase, txn_id: u64) -> FaultAction {
        if !self.hook_installed.load(Ordering::Relaxed) {
            return FaultAction::Continue;
        }
        let hook = self.fault_hook.lock().unwrap().clone();
        match hook {
            Some(h) => h(phase, txn_id),
            None => FaultAction::Continue,
        }
    }

    fn abandoned(txn_id: u64, phase: CommitPhase) -> Error {
        Error::TxnAborted {
            reason: format!(
                "txn {txn_id}: commit abandoned at {phase:?} by the fault schedule"
            ),
        }
    }

    /// Stable FNV-1a shard placement (the same helper the chain store
    /// uses — both backends place every key identically).
    fn shard_of(&self, key: &Key) -> usize {
        super::shard::shard_of_key(key, self.groups.len())
    }

    pub fn num_shards(&self) -> usize {
        self.groups.len()
    }

    /// The group serving `key`'s shard (tests, observability).
    pub fn group_of(&self, key: &Key) -> &ShardGroup {
        &self.groups[self.shard_of(key)]
    }

    pub fn groups(&self) -> &[ShardGroup] {
        &self.groups
    }

    /// Allocate a fresh inode id.  Ids allocated by aborted transactions
    /// are simply never used — the allocator needs no transactionality
    /// (and therefore no quorum round).
    pub fn alloc_inode_id(&self) -> u64 {
        self.next_inode.fetch_add(1, Ordering::Relaxed)
    }

    /// Versioned point read from the shard leader's read-leased local
    /// state.  `auto_elect` controls leader discovery: on (direct calls)
    /// blocks through an election; off (the envelope path) surfaces
    /// [`Error::NotLeader`] for the client to handle.
    pub fn get(&self, key: &Key, auto_elect: bool) -> Result<Option<(Value, u64)>> {
        self.locked_read(key, auto_elect, |s| {
            s.get(key).map(|v| (v.clone(), s.version(key)))
        })
    }

    /// Version of `key` without copying the value.
    pub fn version(&self, key: &Key, auto_elect: bool) -> Result<u64> {
        self.locked_read(key, auto_elect, |s| s.version(key))
    }

    /// Value AND version in one leaseholder read (absent keys still
    /// report their version).
    pub fn entry(&self, key: &Key, auto_elect: bool) -> Result<(Option<Value>, u64)> {
        self.locked_read(key, auto_elect, |s| (s.get(key).cloned(), s.version(key)))
    }

    /// The isolation-aware leaseholder read behind `get`/`entry`/
    /// `version`: wait out any front-end entry hold on `key` (non-2PC
    /// mixed-direction commits), then read through the leaseholder —
    /// and if the key is covered by a pending 2PC intent, resolve that
    /// intent through its coordinator group's decision record and
    /// retry, so the read observes either the whole transaction or none
    /// of it, never a staged half.
    fn locked_read<R>(
        &self,
        key: &Key,
        auto_elect: bool,
        f: impl Fn(&super::shard::KvState) -> R,
    ) -> Result<R> {
        let gid = self.shard_of(key);
        for _ in 0..64 {
            self.holds.wait_out(key);
            match self.groups[gid].local_locked(key, auto_elect, &f)? {
                LockedRead::Clear(r) => {
                    // Validate AFTER the read: a hold on `key` still
                    // active now means a mixed-direction commit may
                    // have been mid-apply when we read (the wait-out
                    // above races the writer's acquire) — retry, which
                    // blocks the hold out.  If the hold was already
                    // released, the commit finished before this check,
                    // and the value composes with post-commit state
                    // like any read racing a completed atomic commit.
                    // The 2PC intent probe needs no such dance: the
                    // lock check and the read are one atomic view
                    // under the replica lock.
                    if !self.holds.held(key) {
                        return Ok(r);
                    }
                }
                LockedRead::Locked {
                    txn_id,
                    coordinator,
                    participants,
                } => {
                    self.resolve_intent(
                        txn_id,
                        coordinator,
                        gid as u32,
                        &participants,
                        auto_elect,
                    )?;
                }
            }
        }
        Err(Error::RetriesExhausted { attempts: 64 })
    }

    /// Resolve one pending intent in `shard` by consulting (and if
    /// necessary fixing) the decision record in the transaction's
    /// `coordinator` group, then propagating the decision to `shard`
    /// (fallibly — the caller needs it resolved) and to every other
    /// recorded participant (best-effort — a quorum-less sibling
    /// resolves on a later pass).  Returns the decision.
    ///
    /// MUST be called with no commit gates held: it takes the gates of
    /// the coordinator, the observing shard, and the sibling
    /// participants itself, in ascending order (the same global gate
    /// order every commit uses, so no deadlocks).  Holding them
    /// serializes this resolution against every proposer in THIS
    /// process, which is what keeps one-value-per-ballot intact — but
    /// it is no longer the presumed-abort proof, because the
    /// coordinating front-end may live in another process that holds no
    /// gate of ours.  The cross-process proof is the *coordinator
    /// claim* ([`claim_key`]) the 2PC path records before its first
    /// prepare: "claim expired (padded by `max_clock_skew`) + no
    /// decision recorded" means the coordinator can no longer win the
    /// decision race, so the presumed abort this function records is
    /// the first — and therefore the only — decision.  An unexpired
    /// claim is waited out first ([`Self::wait_out_claim`]); either
    /// way, the decision re-read below adopts whichever decision
    /// actually landed first.
    fn resolve_intent(
        &self,
        txn_id: u64,
        coordinator: u32,
        shard: u32,
        participants: &[u32],
        auto_elect: bool,
    ) -> Result<bool> {
        let c = coordinator as usize;
        let s = shard as usize;
        if c >= self.groups.len() {
            return Err(Error::CorruptMetadata(format!(
                "intent for txn {txn_id} names unknown coordinator shard {coordinator}"
            )));
        }
        let mut gated: Vec<usize> = participants
            .iter()
            .map(|&p| p as usize)
            .filter(|&p| p < self.groups.len())
            .chain([c, s])
            .collect();
        gated.sort_unstable();
        gated.dedup();
        let _gates: Vec<MutexGuard<'_, ()>> = gated
            .iter()
            .map(|&gid| self.groups[gid].gate.lock().unwrap())
            .collect();
        let commit = match self.groups[c].decision(txn_id, auto_elect)? {
            Some(d) => d,
            None => {
                // A coordinator in another process may still be alive
                // and deciding: its claim record bounds for how long.
                // Wait the claim out (no-op when absent or expired)
                // before presuming anything.
                self.wait_out_claim(c, txn_id, auto_elect)?;
                // Record the presumed abort durably FIRST — the first
                // decision in the coordinator's log wins, so once this
                // lands no replayed decide can flip the outcome.
                self.groups[c].propose_entry(&LogEntry::decide(txn_id, false), auto_elect)?;
                // Re-read rather than assuming `false`: our proposal's
                // prepare rounds may have adopted a minority-accepted
                // `Decide(commit)` left behind by the dead front-end —
                // or a live remote coordinator's decide may have landed
                // while we waited — in which case THAT is the recorded
                // (first) decision.
                self.groups[c]
                    .decision(txn_id, auto_elect)?
                    .unwrap_or(false)
            }
        };
        let decide = LogEntry::decide(txn_id, commit);
        if s != c {
            self.groups[s].propose_entry(&decide, auto_elect)?;
        }
        for &gid in &gated {
            if gid != c && gid != s {
                let _ = self.groups[gid].propose_entry(&decide, auto_elect);
            }
        }
        Ok(commit)
    }

    /// Block until txn `txn_id`'s coordinator claim in group `c` has
    /// expired, a decision lands, or the claim turns out to be absent.
    /// The expiry check pads the recorded bound (measured on the
    /// coordinator's clock) with `max_clock_skew`, so a coordinator
    /// whose clock runs behind ours by up to the budget still gets its
    /// full claim window.  Bounded: a claim covers at most two lease
    /// terms plus the skew budget, and the manual test clock *advances*
    /// on sleep instead of blocking.
    fn wait_out_claim(&self, c: usize, txn_id: u64, auto_elect: bool) -> Result<()> {
        let pad = self.max_skew_ms.load(Ordering::Relaxed);
        loop {
            let until = match self.groups[c].local_get(&claim_key(txn_id), auto_elect)? {
                Some((Value::U64(until), _)) => until.saturating_add(pad),
                // No claim: pre-claim log replay, or already cleaned up
                // after its decision — either way nothing to wait for.
                _ => return Ok(()),
            };
            let now = self.clock.now_ms();
            if now >= until {
                return Ok(());
            }
            self.clock.sleep_ms((until - now).min(self.lease_ms.max(1)));
            if self.groups[c].decision(txn_id, auto_elect)?.is_some() {
                // Decided while we waited; the caller's re-read adopts it.
                return Ok(());
            }
        }
    }

    /// Sweep every group for pending intents and resolve each through
    /// its coordinator's decision record (presumed abort when the
    /// record is absent).  Best-effort per intent — a group without a
    /// quorum is skipped and retried by the next sweep.  Returns how
    /// many intents were resolved.  Called after failover recovery so a
    /// quorum-loss mid-commit leaves no group permanently holding a
    /// phantom entry; also a test surface.
    pub fn resolve_orphans(&self) -> usize {
        let mut resolved = 0usize;
        for g in &self.groups {
            let Ok(pending) = g.pending_intents(true) else {
                continue;
            };
            for (txn_id, coordinator, participants) in pending {
                if self
                    .resolve_intent(txn_id, coordinator, g.shard(), &participants, true)
                    .is_ok()
                {
                    resolved += 1;
                }
            }
        }
        resolved
    }

    /// Every pending (undecided) intent across groups, as
    /// `(shard, txn_id, coordinator)` — test observability.
    pub fn pending_intents(&self) -> Vec<(u32, u64, u32)> {
        let mut out = Vec::new();
        for g in &self.groups {
            if let Ok(pending) = g.pending_intents(true) {
                out.extend(pending.into_iter().map(|(t, c, _)| (g.shard(), t, c)));
            }
        }
        out
    }

    /// How `txn_id` settled in `shard`: `Some(true)` applied,
    /// `Some(false)` applied as an abort, `None` not settled there
    /// (test observability for the agreement assertions).
    pub fn txn_outcome(&self, shard: u32, txn_id: u64) -> Option<bool> {
        self.groups
            .get(shard as usize)?
            .txn_settled(txn_id, true)
            .ok()
            .flatten()
    }

    /// The recorded decision for `txn_id` in `coordinator`'s log
    /// (authoritative there; test observability).
    pub fn decision_of(&self, coordinator: u32, txn_id: u64) -> Option<bool> {
        self.groups
            .get(coordinator as usize)?
            .decision(txn_id, true)
            .ok()
            .flatten()
    }

    /// Atomically commit `commit` through the replicated logs of every
    /// shard it touches.  See the module docs for the protocol.
    ///
    /// Retries around pending intents: an intent observed on a touched
    /// key under the commit gates always belongs to an ORPHANED
    /// cross-group transaction (a live one would itself be holding one
    /// of the gates we hold), so the commit releases its gates, resolves
    /// the orphan through its decision record, and starts over.
    pub fn commit(&self, commit: &Commit, auto_elect: bool) -> Result<Vec<OpOutcome>> {
        if commit.is_empty() {
            return Ok(Vec::new());
        }
        if let Some(sid) = self.batchable_shard(commit) {
            return self.commit_batched(sid, commit, auto_elect);
        }
        self.commit_unbatched(commit, auto_elect)
    }

    /// The pre-group-commit path: gate-holding attempts with orphaned
    /// intents resolved between them.  Also the fallback when a batch
    /// member finds its keys covered by an orphan (resolution cannot run
    /// under the gate the collector holds).
    fn commit_unbatched(&self, commit: &Commit, auto_elect: bool) -> Result<Vec<OpOutcome>> {
        let mut attempts = 0u32;
        loop {
            match self.try_commit(commit, auto_elect)? {
                Attempt::Done(outcomes) => return Ok(outcomes),
                Attempt::Blocked {
                    txn_id,
                    coordinator,
                    shard,
                    participants,
                } => {
                    attempts += 1;
                    if attempts > 16 {
                        return Err(Error::RetriesExhausted { attempts });
                    }
                    self.resolve_intent(txn_id, coordinator, shard, &participants, auto_elect)?;
                }
            }
        }
    }

    /// `Some(shard)` when group commit is on and every key `commit`
    /// touches (reads and ops) lives in one shard group — the only shape
    /// the accumulator packs.  Multi-shard commits keep their existing
    /// direct/2PC paths untouched.
    fn batchable_shard(&self, commit: &Commit) -> Option<usize> {
        self.batchers.as_ref()?;
        let mut sid: Option<usize> = None;
        for key in commit
            .reads
            .iter()
            .map(|(k, _)| k)
            .chain(commit.ops.iter().flat_map(|op| op.keys()))
        {
            let s = self.shard_of(key);
            if *sid.get_or_insert(s) != s {
                return None;
            }
        }
        sid
    }

    /// Commit through the shard's group-commit accumulator: enqueue, let
    /// one member thread collect the window and propose ONE shared
    /// [`EntryKind::Batch`] entry, then pick up this transaction's
    /// individually recorded outcome.  Exactly-once dedup and abort
    /// reporting are per member — each queued commit keeps its own
    /// transaction id through the batch.
    fn commit_batched(
        &self,
        sid: usize,
        commit: &Commit,
        auto_elect: bool,
    ) -> Result<Vec<OpOutcome>> {
        let b = &self.batchers.as_ref().expect("routed here only when enabled")[sid];
        let txn_id = self.next_txn.fetch_add(1, Ordering::Relaxed);
        let collect = {
            let mut st = b.state.lock().unwrap();
            st.queue.push(QueuedCommit {
                txn_id,
                commit: commit.clone(),
                auto_elect,
            });
            let collect = !st.flushing;
            if collect {
                st.flushing = true;
            }
            // Wake the collector: a filling queue can close the window
            // early once it reaches `max_txns`.
            b.signal.notify_all();
            collect
        };
        if collect {
            self.run_batches(sid, b);
        }
        let outcome = {
            let mut st = b.state.lock().unwrap();
            loop {
                if let Some(out) = st.done.remove(&txn_id) {
                    break out;
                }
                st = b.signal.wait(st).unwrap();
            }
        };
        match outcome {
            MemberOutcome::Done(result) => result,
            MemberOutcome::Fallback => self.commit_unbatched(commit, auto_elect),
        }
    }

    /// The collector loop: wait out the window (or a full batch), take
    /// the queue, flush it as one shared entry, repeat while new members
    /// arrived during the flush.  Runs on the first enqueuer's thread.
    fn run_batches(&self, sid: usize, b: &Batcher) {
        loop {
            let members: Vec<QueuedCommit> = {
                let mut st = b.state.lock().unwrap();
                let deadline = Instant::now() + b.window;
                while st.queue.len() < b.max_txns {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    let (g, _) = b.signal.wait_timeout(st, deadline - now).unwrap();
                    st = g;
                }
                let take = st.queue.len().min(b.max_txns);
                st.queue.drain(..take).collect()
            };
            if !members.is_empty() {
                self.flush_batch(sid, members);
            }
            let mut st = b.state.lock().unwrap();
            if st.queue.is_empty() {
                // Hand the collector role back before leaving: a member
                // enqueueing after this sees `flushing == false` and
                // collects its own batch.
                st.flushing = false;
                return;
            }
        }
    }

    /// Validate, stage, and propose one collected batch as a single
    /// shared log entry, then publish each member's individually
    /// recorded outcome.  Runs on the collecting member's thread,
    /// holding only the shard's commit gate (like any commit there).
    fn flush_batch(&self, sid: usize, members: Vec<QueuedCommit>) {
        let b = &self.batchers.as_ref().expect("enabled")[sid];
        let auto_elect = members.iter().any(|m| m.auto_elect);
        let mut results: Vec<(u64, MemberOutcome)> = Vec::with_capacity(members.len());
        'flush: {
            let _gate = self.groups[sid].gate.lock().unwrap();
            // Pre-flight exactly like the unbatched path: a leaderless
            // group aborts every member while nothing is proposed.
            if let Err(e) = self.groups[sid].ensure(auto_elect) {
                for m in &members {
                    results.push((m.txn_id, MemberOutcome::Done(Err(dup_error(&e)))));
                }
                break 'flush;
            }
            // Per-member validation + staging against the leader state
            // PLUS the batch's own overlay — the exact view the replicas
            // will apply the sub-entries under, in the same order.
            let mut overlay: HashMap<Key, (Option<Value>, u64)> = HashMap::new();
            let mut subs: Vec<LogEntry> = Vec::new();
            for m in &members {
                // Fault-schedule visibility: each member passes Staged
                // under the gate, exactly like an unbatched commit.
                if self.fire(CommitPhase::Staged, m.txn_id) == FaultAction::Abandon {
                    results.push((
                        m.txn_id,
                        MemberOutcome::Done(Err(Self::abandoned(
                            m.txn_id,
                            CommitPhase::Staged,
                        ))),
                    ));
                    continue;
                }
                match self.prep_member(sid, m, &mut overlay, auto_elect) {
                    MemberPrep::Sub(entry) => subs.push(entry),
                    MemberPrep::Fallback => {
                        results.push((m.txn_id, MemberOutcome::Fallback));
                    }
                    MemberPrep::Fail(e) => {
                        results.push((m.txn_id, MemberOutcome::Done(Err(e))));
                    }
                }
            }
            if subs.is_empty() {
                break 'flush;
            }
            // ONE shared Paxos round for every surviving member.
            let batch_txn = self.next_txn.fetch_add(1, Ordering::Relaxed);
            let entry = LogEntry::batch(batch_txn, subs.clone());
            match self.groups[sid].commit_entry(&entry, true) {
                Ok(_) => {
                    for sub in &subs {
                        let out = match self.groups[sid].txn_outcomes(sub.txn_id, true) {
                            Ok(Some(Some(outcomes))) => Ok(outcomes),
                            // Applied as a deterministic abort (a
                            // recovered entry raced ahead of the batch).
                            Ok(Some(None)) | Ok(None) => Err(Error::TxnAborted {
                                reason: format!(
                                    "txn {} aborted at replicated apply \
                                     (group-commit batch {batch_txn})",
                                    sub.txn_id
                                ),
                            }),
                            Err(e) => Err(e),
                        };
                        let _ = self.fire(
                            CommitPhase::Proposed { shard: sid as u32 },
                            sub.txn_id,
                        );
                        results.push((sub.txn_id, MemberOutcome::Done(out)));
                    }
                }
                Err(e) => {
                    // The shared entry may or may not have been chosen
                    // (quorum lost mid-round): indeterminate, exactly
                    // like a direct commit failing at propose.  Every
                    // member gets the error; none may replay under a
                    // fresh transaction id.
                    for sub in &subs {
                        results.push((sub.txn_id, MemberOutcome::Done(Err(dup_error(&e)))));
                    }
                }
            }
        }
        let mut st = b.state.lock().unwrap();
        for (txn, out) in results {
            st.done.insert(txn, out);
        }
        drop(st);
        b.signal.notify_all();
    }

    /// Validate and stage one queued member against the leader state
    /// plus `overlay` (the writes of earlier members in the same batch),
    /// mirroring the order the replicas will apply the sub-entries in.
    fn prep_member(
        &self,
        sid: usize,
        m: &QueuedCommit,
        overlay: &mut HashMap<Key, (Option<Value>, u64)>,
        auto_elect: bool,
    ) -> MemberPrep {
        // Orphaned-intent probe, as in the unbatched pre-flight.  A hit
        // cannot be resolved while the collector holds the gate, so the
        // member falls back to the unbatched path (which resolves it).
        if self.two_pc {
            let mut probe: Vec<&Key> = m
                .commit
                .reads
                .iter()
                .map(|(k, _)| k)
                .chain(m.commit.ops.iter().flat_map(|op| op.keys()))
                .collect();
            probe.sort_unstable();
            probe.dedup();
            for key in probe {
                match self.groups[sid].local_locked(key, auto_elect, |_| ()) {
                    Ok(LockedRead::Clear(())) => {}
                    Ok(LockedRead::Locked { .. }) => return MemberPrep::Fallback,
                    Err(e) => return MemberPrep::Fail(e),
                }
            }
        }
        // Read-set validation against this member's view: committed
        // state as amended by the batch members ahead of it.
        for (key, observed) in &m.commit.reads {
            let version = match overlay.get(key) {
                Some((_, v)) => *v,
                None => match self.groups[sid].local_version(key, auto_elect) {
                    Ok(v) => v,
                    Err(e) => return MemberPrep::Fail(e),
                },
            };
            if version != *observed {
                return MemberPrep::Fail(Error::TxnConflict {
                    space: key.space,
                    key: key.key.clone(),
                });
            }
        }
        // Stage through the shared overlay staging.  No cross-shard
        // rewrite can apply here (every key lives in `sid` — that is
        // what made the commit batchable).
        let committed = |k: &Key| match overlay.get(k) {
            Some(entry) => Ok(entry.clone()),
            None => self.groups[sid].local_entry(k, auto_elect),
        };
        match ops::stage(&m.commit.ops, &committed, |_, _| {}) {
            Ok((delta, _outcomes)) => {
                for (k, v) in delta {
                    let version = match overlay.get(&k) {
                        Some((_, ver)) => *ver,
                        None => self.groups[sid].local_version(&k, auto_elect).unwrap_or(0),
                    };
                    overlay.insert(k, (v, version + 1));
                }
                MemberPrep::Sub(LogEntry::apply(
                    m.txn_id,
                    m.commit.reads.clone(),
                    m.commit.ops.clone(),
                ))
            }
            Err(e) => MemberPrep::Fail(e),
        }
    }

    /// One gate-holding commit attempt.  `Attempt::Blocked` means an
    /// orphaned intent covers a touched key; the caller resolves it
    /// outside the gates (its coordinator's gate may be ordered before
    /// ours) and retries.
    fn try_commit(&self, commit: &Commit, auto_elect: bool) -> Result<Attempt> {
        // 1. Canonically ordered commit-gate acquisition over the
        //    touched shards (serializes validate→propose; no deadlocks).
        let mut shard_ids: Vec<usize> = commit
            .reads
            .iter()
            .map(|(k, _)| self.shard_of(k))
            .chain(
                commit
                    .ops
                    .iter()
                    .flat_map(|op| op.keys().into_iter().map(|k| self.shard_of(k))),
            )
            .collect();
        shard_ids.sort_unstable();
        shard_ids.dedup();
        let _gates: Vec<MutexGuard<'_, ()>> = shard_ids
            .iter()
            .map(|&sid| self.groups[sid].gate.lock().unwrap())
            .collect();

        // 2. Pre-flight: every touched group must have a live leased
        //    leader BEFORE anything is proposed — a leaderless or
        //    quorum-less group must abort the commit while it is still
        //    clean, not midway through the per-group proposals.  (Under
        //    `meta_2pc` a quorum dying mid-protocol is recoverable
        //    anyway; without it, this pre-flight is what shrinks the
        //    partial multi-shard window.)  Then check every touched key
        //    for a pending intent: any hit is an orphan — resolve it
        //    outside the gates and retry — and validate the read set
        //    against the leaders' leased state.
        for &sid in &shard_ids {
            self.groups[sid].ensure(auto_elect)?;
        }
        // (Probe only when 2PC can have left intents behind: with it
        // off, `Prepare` entries are never proposed, so the probe would
        // be a pure leader-read tax on every commit.)
        if self.two_pc {
            let mut probe_keys: Vec<&Key> = commit
                .reads
                .iter()
                .map(|(k, _)| k)
                .chain(commit.ops.iter().flat_map(|op| op.keys()))
                .collect();
            probe_keys.sort_unstable();
            probe_keys.dedup();
            for key in probe_keys {
                let gid = self.shard_of(key);
                if let LockedRead::Locked {
                    txn_id,
                    coordinator,
                    participants,
                } = self.groups[gid].local_locked(key, auto_elect, |_| ())?
                {
                    return Ok(Attempt::Blocked {
                        txn_id,
                        coordinator,
                        shard: gid as u32,
                        participants,
                    });
                }
            }
        }
        for (key, observed) in &commit.reads {
            let v = self.groups[self.shard_of(key)].local_version(key, auto_elect)?;
            if v != *observed {
                return Err(Error::TxnConflict {
                    space: key.space,
                    key: key.key.clone(),
                });
            }
        }

        // 3. Stage ops through the shared overlay staging ([`ops::stage`]
        //    — one value+version leader read per distinct key); a validation
        //    failure aborts with nothing proposed anywhere.  Cross-shard
        //    `InodeSetLenFromRegion` is rewritten into its
        //    self-contained monotone-max form via the staging hook,
        //    while this commit's own region appends are visible through
        //    the overlay-aware peek.
        let mut routed: Vec<MetaOp> = Vec::with_capacity(commit.ops.len());
        let committed =
            |k: &Key| self.groups[self.shard_of(k)].local_entry(k, auto_elect);
        let (_overlay, outcomes) = ops::stage(&commit.ops, &committed, |op, peek| {
            let routed_op = match op {
                MetaOp::InodeSetLenFromRegion {
                    inode_key,
                    region_key,
                    region_base,
                    mtime,
                } if self.shard_of(region_key) != self.shard_of(inode_key) => {
                    let eof = peek(region_key)
                        .as_ref()
                        .and_then(|v| v.as_region().map(|r| r.eof))
                        .unwrap_or(0);
                    MetaOp::InodeSetLenMax {
                        key: inode_key.clone(),
                        candidate: *region_base + eof,
                        highest_region: 0,
                        mtime: *mtime,
                    }
                }
                other => other.clone(),
            };
            routed.push(routed_op);
        })?;

        // 4. Plan one log entry per touched shard.  `commit_entry` /
        //    `propose_entry` survive leader failover and apply exactly
        //    once (txn-id dedup), so a retry after a mid-commit crash
        //    cannot double-apply.
        //
        //    NOTE: the proposals always run with blocking leader
        //    discovery, regardless of `auto_elect`.  `NotLeader` may
        //    only escape this function while nothing has been proposed
        //    (steps 2–3) — once the first entry is in flight, the commit
        //    must drive to completion through any election, or a client
        //    replay under a fresh transaction id could re-apply the
        //    groups that already accepted.
        let txn_id = self.next_txn.fetch_add(1, Ordering::Relaxed);
        let mut planned: Vec<(i32, usize, Vec<usize>)> = Vec::new();
        for &sid in &shard_ids {
            let idxs: Vec<usize> = routed
                .iter()
                .enumerate()
                .filter(|(_, op)| self.shard_of(op.key()) == sid)
                .map(|(i, _)| i)
                .collect();
            if idxs.is_empty() {
                continue; // read-only in this shard: validated above
            }
            let shard_ops: Vec<&MetaOp> = idxs.iter().map(|&i| &routed[i]).collect();
            planned.push((entry_priority(&shard_ops), sid, idxs));
        }
        if self.fire(CommitPhase::Staged, txn_id) == FaultAction::Abandon {
            // Nothing proposed yet: the "death" is a clean abort.
            return Err(Self::abandoned(txn_id, CommitPhase::Staged));
        }
        if self.two_pc && planned.len() > 1 {
            return self.commit_two_phase(txn_id, commit, &routed, planned, outcomes);
        }

        // 4a. Direct path: propose per-shard entries in dependency
        //     order (namespace roots last on insert, first on remove) so
        //     gate-free readers never resolve a dangling reference
        //     through a half-committed transaction — and when one commit
        //     mixes both directions (the shape no order can cover), hold
        //     its namespace keys so gate-free reads wait the whole
        //     proposal sequence out.
        planned.sort_by_key(|(pri, sid, _)| (*pri, *sid));
        let mixed = routed.iter().any(|op| op.inserts_namespace_root())
            && routed.iter().any(|op| op.removes_namespace_root());
        let _hold = (planned.len() > 1 && mixed).then(|| {
            let mut held: Vec<Key> = routed
                .iter()
                .filter(|op| op.touches_namespace())
                .flat_map(|op| op.keys().into_iter().cloned())
                .collect();
            held.sort_unstable();
            held.dedup();
            self.holds.acquire(held)
        });
        let mut final_outcomes = outcomes;
        for (_, sid, idxs) in planned {
            let entry = LogEntry::apply(
                txn_id,
                commit
                    .reads
                    .iter()
                    .filter(|(k, _)| self.shard_of(k) == sid)
                    .cloned()
                    .collect(),
                idxs.iter().map(|&i| routed[i].clone()).collect(),
            );
            let applied = self.groups[sid].commit_entry(&entry, true)?;
            // Observability only on the direct path: once proposing, the
            // commit must drive to completion (that gap is exactly what
            // `meta_2pc` exists to close), so Abandon is not honored.
            let _ = self.fire(CommitPhase::Proposed { shard: sid as u32 }, txn_id);
            // Report what the replicated apply actually recorded — it
            // diverges from the staging above only when an indeterminate
            // earlier commit was recovered ahead of this entry (in which
            // case an abort already surfaced as `TxnAborted` from
            // `commit_entry`).
            for (&i, o) in idxs.iter().zip(applied) {
                final_outcomes[i] = o;
            }
        }
        Ok(Attempt::Done(final_outcomes))
    }

    /// The intent-logged two-phase commit for a multi-shard transaction
    /// (`Config::meta_2pc`).  A lease-bounded *coordinator claim* is
    /// replicated into the coordinator group first (the cross-process
    /// presumed-abort bound — see [`Self::resolve_intent`]); phase 1
    /// then stages a durable `Prepare` intent in every participant's
    /// log (validated + key-locked, nothing applied); the `Decide`
    /// record replicated in the lowest-numbered participant group fixes
    /// the outcome — re-read after proposing, because a claim-expiry
    /// resolver may have recorded an abort first; phase 2 flushes or
    /// discards each staged intent exactly once via the txn-id dedup.
    /// A participant unreachable during phase 2 resolves later —
    /// through [`Self::resolve_orphans`] or a reader's intent
    /// resolution — because the decision record is already durable.
    fn commit_two_phase(
        &self,
        txn_id: u64,
        commit: &Commit,
        routed: &[MetaOp],
        planned: Vec<(i32, usize, Vec<usize>)>,
        mut outcomes: Vec<OpOutcome>,
    ) -> Result<Attempt> {
        let mut by_shard: Vec<(usize, Vec<usize>)> =
            planned.into_iter().map(|(_, sid, idxs)| (sid, idxs)).collect();
        by_shard.sort_unstable_by_key(|(sid, _)| *sid);
        let participants: Vec<u32> = by_shard.iter().map(|(sid, _)| *sid as u32).collect();
        let coordinator = participants[0];

        // Coordinator claim: before any intent exists anywhere, record
        // in the coordinator group's log how long this front-end may
        // still decide — the expiry is measured on OUR clock *before*
        // the claim is sent, so a resolver in another process (padding
        // the bound with its own skew budget) waits at least as long as
        // we could possibly act.  "Gate held + no decision" proves
        // coordinator death only in-process; "claim expired + no
        // decision" is the rule that survives real process boundaries.
        // A claim that cannot replicate is a clean abort: nothing has
        // been staged anywhere yet.
        let claim_until = self
            .clock
            .now_ms()
            .saturating_add(2 * self.lease_ms.max(1))
            .saturating_add(self.max_skew_ms.load(Ordering::Relaxed));
        let claim = LogEntry::apply(
            txn_id | CLAIM_TXN_BIT,
            Vec::new(),
            vec![MetaOp::Put {
                key: claim_key(txn_id),
                value: Value::U64(claim_until),
            }],
        );
        self.groups[coordinator as usize].propose_entry(&claim, true)?;

        // Phase 1: durable intents, in shard order.  Order is free here
        // — nothing applies until the decision, and the intent locks
        // keep every staged key unreadable until then.
        let mut vote_yes = true;
        let mut abort_cause: Option<Error> = None;
        let prepares: Vec<LogEntry> = by_shard
            .iter()
            .map(|(sid, idxs)| LogEntry {
                txn_id,
                reads: commit
                    .reads
                    .iter()
                    .filter(|(k, _)| self.shard_of(k) == *sid)
                    .cloned()
                    .collect(),
                ops: idxs.iter().map(|&i| routed[i].clone()).collect(),
                kind: EntryKind::Prepare {
                    participants: participants.clone(),
                    coordinator,
                },
            })
            .collect();
        if self.prepare_batching {
            // Batched phase 1 (`Config::prepare_batching`): every
            // participant's prepare rides ONE shared accept scatter and
            // ONE shared learn scatter instead of two per group.  The
            // per-group protocol — entry contents, intents, votes — is
            // identical; only the scatter shape changes.  Every
            // participant gets its intent even when another votes no
            // (the decide-abort below resolves them all), which the
            // sequential path's early break merely short-circuited.
            let targets: Vec<(usize, LogEntry)> = by_shard
                .iter()
                .map(|(sid, _)| *sid)
                .zip(prepares.iter().cloned())
                .collect();
            let landed = self.propose_scatter(targets);
            for ((sid, idxs), result) in by_shard.iter().zip(landed) {
                match result {
                    Ok(Landed::Voted(Some(shard_outcomes))) => {
                        for (&i, o) in idxs.iter().zip(shard_outcomes) {
                            outcomes[i] = o;
                        }
                    }
                    Ok(Landed::Voted(None)) => vote_yes = false,
                    Ok(Landed::Applied(_)) => {
                        return Err(Error::CorruptMetadata(format!(
                            "txn {txn_id} was resolved before its own prepare"
                        )));
                    }
                    Err(e) => {
                        vote_yes = false;
                        if abort_cause.is_none() {
                            abort_cause = Some(e);
                        }
                    }
                }
                let phase = CommitPhase::Prepared { shard: *sid as u32 };
                if self.fire(phase, txn_id) == FaultAction::Abandon {
                    return Err(Self::abandoned(txn_id, phase));
                }
            }
        } else {
            for ((sid, idxs), entry) in by_shard.iter().zip(&prepares) {
                match self.groups[*sid].propose_entry(entry, true) {
                    Ok(Landed::Voted(Some(shard_outcomes))) => {
                        for (&i, o) in idxs.iter().zip(shard_outcomes) {
                            outcomes[i] = o;
                        }
                    }
                    // A deterministic no-vote (stale reads or a key locked
                    // by another intent, identical on every replica).
                    Ok(Landed::Voted(None)) => vote_yes = false,
                    Ok(Landed::Applied(_)) => {
                        return Err(Error::CorruptMetadata(format!(
                            "txn {txn_id} was resolved before its own prepare"
                        )));
                    }
                    // The group cannot durably stage (quorum gone mid-phase
                    // 1): decide abort so no other participant strands a
                    // phantom entry — the close of ROADMAP gap (a).
                    Err(e) => {
                        vote_yes = false;
                        abort_cause = Some(e);
                    }
                }
                let phase = CommitPhase::Prepared { shard: *sid as u32 };
                if self.fire(phase, txn_id) == FaultAction::Abandon {
                    return Err(Self::abandoned(txn_id, phase));
                }
                if !vote_yes {
                    break; // further prepares would be pointless
                }
            }
        }
        if vote_yes && self.fire(CommitPhase::AllPrepared, txn_id) == FaultAction::Abandon {
            return Err(Self::abandoned(txn_id, CommitPhase::AllPrepared));
        }

        // The decision record: replicated in the coordinator group.
        // The moment it is chosen there, the transaction's outcome is
        // fixed cluster-wide (first decision in that log wins).
        let decide = LogEntry::decide(txn_id, vote_yes);
        match self.groups[coordinator as usize].propose_entry(&decide, true) {
            Ok(Landed::Applied(result)) => {
                // The coordinator is itself a participant: its decide IS
                // its phase 2.  Record the authoritative outcomes.
                if let Some(shard_outcomes) = result {
                    let idxs = &by_shard
                        .iter()
                        .find(|(sid, _)| *sid as u32 == coordinator)
                        .expect("coordinator is a participant")
                        .1;
                    for (&i, o) in idxs.iter().zip(shard_outcomes) {
                        outcomes[i] = o;
                    }
                }
            }
            Ok(Landed::Voted(_)) => {
                return Err(Error::CorruptMetadata(format!(
                    "txn {txn_id}: decision landed as a vote"
                )));
            }
            // The decision could not be replicated (coordinator quorum
            // gone): the transaction is UNRESOLVED — a minority-accepted
            // decide may yet be adopted — so intents stay pending and
            // resolution runs against the healed coordinator group.
            Err(e) => return Err(abort_cause.unwrap_or(e)),
        }
        // Adopt the RECORDED decision, not the local vote: a claim-
        // expiry resolver in another process may have recorded a
        // presumed abort first, in which case the proposal above merely
        // deduped against it and phase 2 must flush THAT outcome.
        let decided = self.groups[coordinator as usize]
            .decision(txn_id, true)?
            .unwrap_or(vote_yes);
        let decide = LogEntry::decide(txn_id, decided);
        let phase = CommitPhase::Decided { commit: decided };
        if self.fire(phase, txn_id) == FaultAction::Abandon {
            return Err(Self::abandoned(txn_id, phase));
        }

        // Phase 2: resolve every other participant.  The decision is
        // durable, so a group unreachable here merely resolves later
        // (recovery sweep or reader resolution) — its per-op outcomes
        // below are the vote-time staging, which is exactly what its
        // eventual commit flush applies.
        if self.prepare_batching {
            // Batched phase 2: every non-coordinator decide rides one
            // shared accept scatter + one shared learn scatter.  A
            // participant that misses it (aborted there, unreachable)
            // resolves later, same as the sequential path.
            let others: Vec<(usize, &Vec<usize>)> = by_shard
                .iter()
                .filter(|(sid, _)| *sid as u32 != coordinator)
                .map(|(sid, idxs)| (*sid, idxs))
                .collect();
            let landed = self.propose_scatter(
                others.iter().map(|(sid, _)| (*sid, decide.clone())).collect(),
            );
            for ((sid, idxs), result) in others.iter().zip(landed) {
                if let Ok(Landed::Applied(Some(shard_outcomes))) = result {
                    for (&i, o) in idxs.iter().zip(shard_outcomes) {
                        outcomes[i] = o;
                    }
                }
                let phase = CommitPhase::Applied { shard: *sid as u32 };
                if self.fire(phase, txn_id) == FaultAction::Abandon {
                    return Err(Self::abandoned(txn_id, phase));
                }
            }
        } else {
            for (sid, idxs) in &by_shard {
                if *sid as u32 == coordinator {
                    continue;
                }
                match self.groups[*sid].propose_entry(&decide, true) {
                    Ok(Landed::Applied(Some(shard_outcomes))) => {
                        for (&i, o) in idxs.iter().zip(shard_outcomes) {
                            outcomes[i] = o;
                        }
                    }
                    // Aborted there, or (Err) unreachable — resolved later.
                    Ok(_) | Err(_) => {}
                }
                let phase = CommitPhase::Applied { shard: *sid as u32 };
                if self.fire(phase, txn_id) == FaultAction::Abandon {
                    return Err(Self::abandoned(txn_id, phase));
                }
            }
        }
        // Best-effort claim cleanup — the claim did its job the moment
        // the decision record landed, and a leftover one only makes a
        // future resolver wait before its decision re-read
        // short-circuits anyway.
        let drop_claim = LogEntry::apply(
            txn_id | CLAIM_DROP_BIT,
            Vec::new(),
            vec![MetaOp::Delete {
                key: claim_key(txn_id),
            }],
        );
        let _ = self.groups[coordinator as usize].propose_entry(&drop_claim, true);
        if decided {
            Ok(Attempt::Done(outcomes))
        } else if vote_yes {
            // Every participant voted yes but the recorded decision is
            // an abort: a resolver presumed this front-end dead after
            // its claim expired.  The intents are discarded everywhere;
            // surface the loss of the race rather than fake a commit.
            Err(Error::TxnAborted {
                reason: format!(
                    "txn {txn_id}: coordinator claim expired before the decision was recorded"
                ),
            })
        } else {
            Err(abort_cause.unwrap_or(Error::TxnAborted {
                reason: format!("txn {txn_id}: a participant voted to abort at prepare"),
            }))
        }
    }

    /// Propose one entry per group with the fast-path accept and learn
    /// scatters COLLAPSED across groups (`Config::prepare_batching`):
    /// arm every group's phase-1-skipping accept, ship ALL the accepts
    /// in one transport broadcast, then all the learns in a second — two
    /// scatters for P groups where sequential proposals pay two per
    /// group.  Any group that cannot fast-path (fresh leader, dedup hit,
    /// lost round, leader death mid-flight) falls back to its own
    /// sequential [`ShardGroup::propose_entry`], preserving the
    /// per-group protocol exactly.  MUST run with the commit gates of
    /// every target group held, like any proposal.
    ///
    /// Returns one result per target, in target order.
    fn propose_scatter(&self, targets: Vec<(usize, LogEntry)>) -> Vec<Result<Landed>> {
        let n = targets.len();
        let mut results: Vec<Option<Result<Landed>>> = (0..n).map(|_| None).collect();
        // 1. Arm: fix (leader, slot, ballot) per group; no wire traffic.
        let mut armed: Vec<(usize, usize, ArmedAccept)> = Vec::new();
        let mut slow: Vec<(usize, usize, LogEntry)> = Vec::new();
        for (t, (sid, entry)) in targets.into_iter().enumerate() {
            match self.groups[sid].arm_fast_accept(&entry, true) {
                Ok(ArmOutcome::Settled(landed)) => results[t] = Some(Ok(landed)),
                Ok(ArmOutcome::Armed(a)) => armed.push((t, sid, a)),
                Ok(ArmOutcome::Slow) => slow.push((t, sid, entry)),
                Err(e) => results[t] = Some(Err(e)),
            }
        }
        if !armed.is_empty() {
            // 2. ONE shared accept scatter across every armed group.
            let mut batch: Vec<(Peer, Request)> = Vec::new();
            let mut lens: Vec<usize> = Vec::with_capacity(armed.len());
            for (_, sid, a) in &armed {
                let reqs = self.groups[*sid].accept_requests(a);
                lens.push(reqs.len());
                batch.extend(reqs);
            }
            let mut responses = self
                .transport()
                .broadcast(batch)
                .into_iter();
            // 3. Seal per group; quorum-accepted groups share ONE learn
            //    scatter.
            let mut learned: Vec<(usize, usize, ArmedAccept)> = Vec::new();
            let mut learn_batch: Vec<(Peer, Request)> = Vec::new();
            for ((t, sid, a), len) in armed.into_iter().zip(lens) {
                let slice: Vec<_> = responses.by_ref().take(len).collect();
                match self.groups[sid].seal_fast_accept(slice) {
                    Ok(true) => {
                        learn_batch.extend(self.groups[sid].learn_requests(&a));
                        learned.push((t, sid, a));
                    }
                    // Lost cleanly: the sequential driver may re-send
                    // the SAME ballot/value or run a full round.
                    Ok(false) => slow.push((t, sid, a.entry)),
                    Err(e) => results[t] = Some(Err(e)),
                }
            }
            if !learn_batch.is_empty() {
                for res in self.transport().broadcast(learn_batch) {
                    let _ = res;
                }
            }
            for (t, sid, a) in learned {
                match self.groups[sid].settled_after_learn(&a) {
                    Some(landed) => results[t] = Some(Ok(landed)),
                    // Leader died between accept and learn: the
                    // sequential driver settles it (dedup keeps the
                    // retry exactly-once).
                    None => slow.push((t, sid, a.entry)),
                }
            }
        }
        // 4. Sequential fallback for everything that missed the fast
        //    path — identical to the unbatched proposals.
        for (t, sid, entry) in slow {
            results[t] = Some(self.groups[sid].propose_entry(&entry, true));
        }
        results
            .into_iter()
            .map(|r| r.expect("every scatter target resolves"))
            .collect()
    }

    /// The deployment-wide transport (all groups share one).
    fn transport(&self) -> &Arc<Transport> {
        self.groups[0].transport()
    }

    /// Full scan of one space from the shard leaders (GC; not
    /// transactional — GC tolerates staleness by design).  An
    /// unreadable shard is an ERROR, never an empty result: GC decides
    /// slice liveness from this scan, and treating a quorum-less
    /// shard's keyspace as absent would reclaim live data.
    pub fn scan_space(&self, space: Space) -> Result<Vec<(Key, Value)>> {
        let mut out = Vec::new();
        for g in &self.groups {
            out.append(&mut g.local_scan(space, true)?);
        }
        out.sort_by(|a, b| a.0.cmp(&b.0));
        Ok(out)
    }

    /// Crash replica `idx` of every shard group (failure injection).  If
    /// it led a group, that group stalls until the lease expires, then
    /// fails over.
    pub fn kill_replica(&self, idx: usize) {
        for g in &self.groups {
            g.kill_replica(idx);
        }
    }

    /// Rejoin replica `idx` of every group by deterministic log replay.
    /// Best-effort across groups: every group is attempted even when an
    /// earlier one has no live replay source; the first error is
    /// reported after the sweep.
    pub fn recover_replica(&self, idx: usize) -> Result<()> {
        let mut first_err = None;
        for g in &self.groups {
            if let Err(e) = g.recover_replica(idx) {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Restart replica `idx` of every group the durable way: tear each
    /// incarnation down to its WAL directory — memory and modeled
    /// acceptor storage both die — and rebuild it from disk alone.
    /// Best-effort across groups, like [`Self::recover_replica`]: every
    /// group is attempted and the first error is reported after the
    /// sweep (a corrupt WAL kills one replica of one group, not the
    /// whole restart).
    pub fn restart_replica(&self, idx: usize) -> Result<()> {
        let mut first_err = None;
        for g in &self.groups {
            if let Err(e) = g.restart_replica(idx) {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Blocking leader (re-)discovery for one shard — what a client does
    /// after [`Error::NotLeader`].
    pub fn heal(&self, shard: u32) -> Result<u32> {
        match self.groups.get(shard as usize) {
            Some(g) => g.heal(),
            None => Err(Error::InvalidArgument(format!(
                "no metadata shard {shard}"
            ))),
        }
    }

    /// All live replicas of every group agree (test invariant).
    pub fn converged(&self) -> bool {
        self.groups.iter().all(|g| g.converged())
    }

    pub fn shard_stats(&self) -> Vec<ShardStats> {
        self.groups.iter().map(|g| g.stats()).collect()
    }

    /// Total leaseholder-local reads across groups (observability).
    pub fn lease_reads(&self) -> u64 {
        self.groups.iter().map(|g| g.lease_reads()).sum()
    }

    /// Total leader elections across groups (observability).
    pub fn elections(&self) -> u64 {
        self.groups.iter().map(|g| g.elections()).sum()
    }

    /// Total lease step-downs across groups: the leaseholder found its
    /// lease no longer covered a local read (e.g. the grant window ran
    /// out while a delayed quorum round was in flight) and fell back to
    /// a fresh quorum election instead of serving a possibly-stale read.
    pub fn stepdowns(&self) -> u64 {
        self.groups.iter().map(|g| g.stepdowns()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{Inode, Placement, RegionEntry, SliceData, SlicePtr};

    fn store() -> ReplicatedMetaStore {
        ReplicatedMetaStore::new(
            4,
            3,
            Arc::new(Transport::instant()),
            LeaseClock::manual(),
            20,
        )
    }

    fn skey(s: &str) -> Key {
        Key::sys(s)
    }

    fn put(key: &Key, v: Value) -> Commit {
        Commit {
            reads: vec![],
            ops: vec![MetaOp::Put {
                key: key.clone(),
                value: v,
            }],
        }
    }

    fn stored(len: u64) -> SliceData {
        SliceData::Stored(vec![SlicePtr {
            server: 1,
            backing: 0,
            offset: 0,
            len,
        }])
    }

    #[test]
    fn put_get_round_trip() {
        let s = store();
        let k = skey("a");
        s.commit(&put(&k, Value::U64(42)), true).unwrap();
        assert_eq!(s.get(&k, true).unwrap(), Some((Value::U64(42), 1)));
        assert!(s.converged());
    }

    #[test]
    fn multi_shard_commit_lands_everywhere() {
        let s = store();
        let keys: Vec<Key> = (0..16).map(|i| skey(&format!("k{i}"))).collect();
        let ops = keys
            .iter()
            .map(|k| MetaOp::Put {
                key: k.clone(),
                value: Value::U64(7),
            })
            .collect();
        s.commit(&Commit { reads: vec![], ops }, true).unwrap();
        for k in &keys {
            assert_eq!(s.get(k, true).unwrap().unwrap().0, Value::U64(7));
        }
        // Several distinct groups were involved.
        let touched: std::collections::HashSet<usize> =
            keys.iter().map(|k| s.shard_of(k)).collect();
        assert!(touched.len() > 1);
        assert!(s.converged());
    }

    #[test]
    fn stale_read_aborts_with_nothing_applied() {
        let s = store();
        let k = skey("a");
        s.commit(&put(&k, Value::U64(1)), true).unwrap();
        let stale = Commit {
            reads: vec![(k.clone(), 0)],
            ops: vec![MetaOp::Put {
                key: k.clone(),
                value: Value::U64(9),
            }],
        };
        assert!(matches!(
            s.commit(&stale, true),
            Err(Error::TxnConflict { .. })
        ));
        assert_eq!(s.get(&k, true).unwrap().unwrap().0, Value::U64(1));
    }

    #[test]
    fn failed_op_rolls_back_entire_commit() {
        let s = store();
        let a = skey("a");
        let c = Commit {
            reads: vec![],
            ops: vec![
                MetaOp::Put {
                    key: a.clone(),
                    value: Value::U64(1),
                },
                // Fails validation: inode op against a U64.
                MetaOp::InodeSetLenMax {
                    key: a.clone(),
                    candidate: 1,
                    highest_region: 0,
                    mtime: 0,
                },
            ],
        };
        assert!(s.commit(&c, true).is_err());
        assert_eq!(s.get(&a, true).unwrap(), None);
    }

    #[test]
    fn cross_shard_set_len_from_region_is_rewritten() {
        let s = store();
        // Find a region key on a different shard than the inode key.
        let ikey = Key::inode(9);
        let ishard = s.shard_of(&ikey);
        let rkey = (0..64)
            .map(|i| Key::new(Space::Region, format!("r{i}")))
            .find(|k| s.shard_of(k) != ishard)
            .expect("some region key lands on another shard");
        s.commit(&put(&ikey, Value::Inode(Inode::new_file(9, 0o644, 1))), true)
            .unwrap();
        let c = Commit {
            reads: vec![],
            ops: vec![
                MetaOp::RegionAppendEof {
                    key: rkey.clone(),
                    data: stored(10),
                    len: 10,
                    cap: 100,
                },
                MetaOp::InodeSetLenFromRegion {
                    inode_key: ikey.clone(),
                    region_key: rkey.clone(),
                    region_base: 1000,
                    mtime: 1,
                },
            ],
        };
        let outcomes = s.commit(&c, true).unwrap();
        assert_eq!(outcomes[0], OpOutcome::AppendedAt(0));
        // The inode observed this commit's own append through the overlay
        // even though the region lives in another group.
        let inode = s.get(&ikey, true).unwrap().unwrap().0;
        assert_eq!(inode.as_inode().unwrap().len, 1010);
        assert!(s.converged());
    }

    #[test]
    fn same_shard_set_len_from_region_stays_native() {
        let s = store();
        let ikey = Key::inode(7);
        let ishard = s.shard_of(&ikey);
        let rkey = (0..64)
            .map(|i| Key::new(Space::Region, format!("q{i}")))
            .find(|k| s.shard_of(k) == ishard)
            .expect("some region key lands on the inode's shard");
        s.commit(&put(&ikey, Value::Inode(Inode::new_file(7, 0o644, 1))), true)
            .unwrap();
        let c = Commit {
            reads: vec![],
            ops: vec![
                MetaOp::RegionAppend {
                    key: rkey.clone(),
                    entry: RegionEntry {
                        placement: Placement::At(0),
                        len: 25,
                        data: stored(25),
                    },
                },
                MetaOp::InodeSetLenFromRegion {
                    inode_key: ikey.clone(),
                    region_key: rkey.clone(),
                    region_base: 0,
                    mtime: 1,
                },
            ],
        };
        s.commit(&c, true).unwrap();
        let inode = s.get(&ikey, true).unwrap().unwrap().0;
        assert_eq!(inode.as_inode().unwrap().len, 25);
    }

    #[test]
    fn scan_space_aggregates_across_groups() {
        let s = store();
        for i in 0..12 {
            s.commit(&put(&skey(&format!("s{i}")), Value::U64(i)), true)
                .unwrap();
        }
        let all = s.scan_space(Space::Sys).unwrap();
        assert_eq!(all.len(), 12);
        assert!(all.windows(2).all(|w| w[0].0 <= w[1].0), "sorted");
    }

    #[test]
    fn inode_ids_are_unique_and_start_past_root() {
        let s = store();
        let a = s.alloc_inode_id();
        let b = s.alloc_inode_id();
        assert!(a >= 2);
        assert_ne!(a, b);
    }

    // -----------------------------------------------------------------
    // Intent-logged 2PC (`meta_2pc` on).
    // -----------------------------------------------------------------

    fn store_2pc() -> ReplicatedMetaStore {
        ReplicatedMetaStore::new(
            4,
            3,
            Arc::new(Transport::instant()),
            LeaseClock::manual(),
            20,
        )
        .two_pc(true)
    }

    /// Two keys guaranteed to live in different shard groups.
    fn cross_shard_keys(s: &ReplicatedMetaStore) -> (Key, Key) {
        let a = skey("a");
        let b = (0..64)
            .map(|i| skey(&format!("b{i}")))
            .find(|k| s.shard_of(k) != s.shard_of(&a))
            .expect("some key lands on another shard");
        (a, b)
    }

    fn put_both(a: &Key, b: &Key) -> Commit {
        Commit {
            reads: vec![],
            ops: vec![
                MetaOp::Put {
                    key: a.clone(),
                    value: Value::U64(1),
                },
                MetaOp::Put {
                    key: b.clone(),
                    value: Value::U64(2),
                },
            ],
        }
    }

    /// Install a hook that records the txn id and abandons at `at`.
    fn abandon_at(
        s: &ReplicatedMetaStore,
        at: fn(&CommitPhase) -> bool,
    ) -> Arc<Mutex<Option<u64>>> {
        let seen = Arc::new(Mutex::new(None));
        let tx = seen.clone();
        s.set_fault_hook(Some(Arc::new(move |phase, txn| {
            *tx.lock().unwrap() = Some(txn);
            if at(&phase) {
                FaultAction::Abandon
            } else {
                FaultAction::Continue
            }
        })));
        seen
    }

    #[test]
    fn two_pc_multi_shard_commit_applies_everywhere_and_unlocks() {
        let s = store_2pc();
        let keys: Vec<Key> = (0..16).map(|i| skey(&format!("k{i}"))).collect();
        let ops = keys
            .iter()
            .map(|k| MetaOp::Put {
                key: k.clone(),
                value: Value::U64(7),
            })
            .collect();
        s.commit(&Commit { reads: vec![], ops }, true).unwrap();
        for k in &keys {
            assert_eq!(s.get(k, true).unwrap().unwrap().0, Value::U64(7));
        }
        assert!(s.pending_intents().is_empty(), "every intent resolved");
        assert!(s.converged());
    }

    #[test]
    fn two_pc_coordinator_death_after_prepare_presumed_aborts_on_read() {
        let s = store_2pc();
        let (a, b) = cross_shard_keys(&s);
        let seen = abandon_at(&s, |p| matches!(p, CommitPhase::AllPrepared));
        let err = s.commit(&put_both(&a, &b), true).unwrap_err();
        assert!(matches!(err, Error::TxnAborted { .. }), "{err:?}");
        s.set_fault_hook(None);
        let txn = seen.lock().unwrap().expect("hook saw the txn");
        assert_eq!(s.pending_intents().len(), 2, "both intents orphaned");

        // A plain read of a locked key resolves the orphan: no decision
        // is recorded, so the resolution writes presumed-abort — and
        // the read then observes the pre-transaction state.  The intent
        // carries the participant list, so ONE resolution settles the
        // sibling group too.
        assert_eq!(s.get(&a, true).unwrap(), None);
        let coordinator = (s.shard_of(&a).min(s.shard_of(&b))) as u32;
        assert_eq!(s.decision_of(coordinator, txn), Some(false));
        assert!(
            s.pending_intents().is_empty(),
            "resolution propagated to every participant"
        );
        assert_eq!(s.get(&b, true).unwrap(), None);
        assert_eq!(s.txn_outcome(s.shard_of(&a) as u32, txn), Some(false));
        assert_eq!(s.txn_outcome(s.shard_of(&b) as u32, txn), Some(false));
        assert!(s.converged());
    }

    #[test]
    fn two_pc_death_after_decision_commits_via_resolution() {
        let s = store_2pc();
        let (a, b) = cross_shard_keys(&s);
        let seen = abandon_at(&s, |p| matches!(p, CommitPhase::Decided { .. }));
        let err = s.commit(&put_both(&a, &b), true).unwrap_err();
        assert!(matches!(err, Error::TxnAborted { .. }), "{err:?}");
        s.set_fault_hook(None);
        let txn = seen.lock().unwrap().unwrap();

        // The decision record made it into the coordinator group, so
        // the transaction IS committed — readers of every touched key
        // resolve to the new values, never a half state.
        assert_eq!(s.get(&a, true).unwrap().unwrap().0, Value::U64(1));
        assert_eq!(s.get(&b, true).unwrap().unwrap().0, Value::U64(2));
        assert!(s.pending_intents().is_empty());
        let coordinator = (s.shard_of(&a).min(s.shard_of(&b))) as u32;
        assert_eq!(s.decision_of(coordinator, txn), Some(true));
        assert!(s.converged());
    }

    #[test]
    fn two_pc_replayed_opposite_decision_cannot_flip_the_outcome() {
        let s = store_2pc();
        let (a, b) = cross_shard_keys(&s);
        let seen = abandon_at(&s, |p| matches!(p, CommitPhase::AllPrepared));
        let _ = s.commit(&put_both(&a, &b), true).unwrap_err();
        s.set_fault_hook(None);
        let txn = seen.lock().unwrap().unwrap();
        s.resolve_orphans();
        let coordinator = (s.shard_of(&a).min(s.shard_of(&b))) as u32;
        assert_eq!(s.decision_of(coordinator, txn), Some(false));

        // Replaying a commit-direction decide (e.g. a partitioned
        // front-end waking up) must not resurrect the transaction:
        // the FIRST decision in the coordinator's log won.
        for g in s.groups() {
            let _ = g.propose_entry(&LogEntry::decide(txn, true), true);
        }
        assert_eq!(s.decision_of(coordinator, txn), Some(false));
        assert_eq!(s.get(&a, true).unwrap(), None);
        assert_eq!(s.get(&b, true).unwrap(), None);
        assert!(s.converged());
    }

    #[test]
    fn two_pc_interrupted_commit_does_not_block_later_commits() {
        let s = store_2pc();
        let (a, b) = cross_shard_keys(&s);
        abandon_at(&s, |p| matches!(p, CommitPhase::AllPrepared));
        let _ = s.commit(&put_both(&a, &b), true).unwrap_err();
        s.set_fault_hook(None);

        // A later commit touching the same keys finds the orphaned
        // intents, resolves them (presumed abort), and lands.
        let c = Commit {
            reads: vec![],
            ops: vec![
                MetaOp::Put {
                    key: a.clone(),
                    value: Value::U64(10),
                },
                MetaOp::Put {
                    key: b.clone(),
                    value: Value::U64(20),
                },
            ],
        };
        s.commit(&c, true).unwrap();
        assert_eq!(s.get(&a, true).unwrap().unwrap().0, Value::U64(10));
        assert_eq!(s.get(&b, true).unwrap().unwrap().0, Value::U64(20));
        assert!(s.pending_intents().is_empty());
        assert!(s.converged());
    }

    #[test]
    fn two_pc_single_shard_commit_stays_one_phase() {
        let s = store_2pc();
        let k = skey("solo");
        // A single-shard commit proposes a plain Apply entry: no intent,
        // no decision record.
        s.commit(&put(&k, Value::U64(9)), true).unwrap();
        assert_eq!(s.get(&k, true).unwrap().unwrap().0, Value::U64(9));
        assert!(s.pending_intents().is_empty());
        let g = s.group_of(&k);
        assert_eq!(g.decision(1, true).unwrap(), None, "no decision record");
    }

    #[test]
    fn two_pc_stale_read_set_aborts_with_no_intents() {
        let s = store_2pc();
        let (a, b) = cross_shard_keys(&s);
        s.commit(&put(&a, Value::U64(1)), true).unwrap();
        let stale = Commit {
            reads: vec![(a.clone(), 0)], // stale: a is at version 1
            ops: vec![
                MetaOp::Put {
                    key: a.clone(),
                    value: Value::U64(5),
                },
                MetaOp::Put {
                    key: b.clone(),
                    value: Value::U64(5),
                },
            ],
        };
        assert!(matches!(
            s.commit(&stale, true),
            Err(Error::TxnConflict { .. })
        ));
        assert_eq!(s.get(&a, true).unwrap().unwrap().0, Value::U64(1));
        assert_eq!(s.get(&b, true).unwrap(), None);
        assert!(s.pending_intents().is_empty());
    }

    /// `n` distinct keys all living in the same shard group as `seed`.
    fn same_shard_keys(s: &ReplicatedMetaStore, seed: &str, n: usize) -> Vec<Key> {
        let sid = s.shard_of(&skey(seed));
        (0..)
            .map(|i| skey(&format!("{seed}{i}")))
            .filter(|k| s.shard_of(k) == sid)
            .take(n)
            .collect()
    }

    #[test]
    fn group_commit_single_caller_still_commits() {
        let s = store().group_commit(Duration::from_millis(2), 8);
        assert!(s.is_group_commit());
        let k = skey("a");
        // Routed through the accumulator (single-shard), still lands
        // with its own outcome and the usual read-your-write semantics.
        s.commit(&put(&k, Value::U64(42)), true).unwrap();
        assert_eq!(s.get(&k, true).unwrap(), Some((Value::U64(42), 1)));
        // Multi-shard commits bypass the accumulator entirely.
        let keys: Vec<Key> = (0..16).map(|i| skey(&format!("k{i}"))).collect();
        assert!(s
            .batchable_shard(&Commit {
                reads: vec![],
                ops: keys
                    .iter()
                    .map(|k| MetaOp::Put {
                        key: k.clone(),
                        value: Value::U64(7),
                    })
                    .collect(),
            })
            .is_none());
        assert!(s.converged());
    }

    #[test]
    fn group_commit_batch_applies_members_individually_in_one_round() {
        let s = store().group_commit(Duration::from_millis(2), 8);
        let keys = same_shard_keys(&s, "g", 3);
        let sid = s.shard_of(&keys[0]);
        // Key 0 starts at version 1.
        s.commit(&put(&keys[0], Value::U64(1)), true).unwrap();
        let rounds_before = s.groups[sid].log_len(true).unwrap();
        // Three members, staged as ONE batch: a clean overwrite of key
        // 0, a member whose read set is stale BECAUSE of the first
        // member (the in-batch overlay bumps key 0 to version 2), and
        // an independent put.
        let members = vec![
            QueuedCommit {
                txn_id: s.next_txn.fetch_add(1, Ordering::Relaxed),
                commit: Commit {
                    reads: vec![(keys[0].clone(), 1)],
                    ops: vec![MetaOp::Put {
                        key: keys[0].clone(),
                        value: Value::U64(10),
                    }],
                },
                auto_elect: true,
            },
            QueuedCommit {
                txn_id: s.next_txn.fetch_add(1, Ordering::Relaxed),
                commit: Commit {
                    reads: vec![(keys[0].clone(), 1)],
                    ops: vec![MetaOp::Put {
                        key: keys[1].clone(),
                        value: Value::U64(20),
                    }],
                },
                auto_elect: true,
            },
            QueuedCommit {
                txn_id: s.next_txn.fetch_add(1, Ordering::Relaxed),
                commit: put(&keys[2], Value::U64(30)),
                auto_elect: true,
            },
        ];
        let ids: Vec<u64> = members.iter().map(|m| m.txn_id).collect();
        s.flush_batch(sid, members);
        let mut st = s.batchers.as_ref().unwrap()[sid].state.lock().unwrap();
        assert!(matches!(
            st.done.remove(&ids[0]),
            Some(MemberOutcome::Done(Ok(_)))
        ));
        match st.done.remove(&ids[1]) {
            Some(MemberOutcome::Done(Err(Error::TxnConflict { .. }))) => {}
            _ => panic!("expected the in-batch overlay to fail member 1's stale read"),
        }
        assert!(matches!(
            st.done.remove(&ids[2]),
            Some(MemberOutcome::Done(Ok(_)))
        ));
        drop(st);
        // One Paxos slot for the whole batch; per-member effects exact.
        assert_eq!(s.groups[sid].log_len(true).unwrap(), rounds_before + 1);
        assert_eq!(s.get(&keys[0], true).unwrap(), Some((Value::U64(10), 2)));
        assert_eq!(s.get(&keys[1], true).unwrap(), None);
        assert_eq!(s.get(&keys[2], true).unwrap(), Some((Value::U64(30), 1)));
        assert!(s.converged());
    }

    #[test]
    fn group_commit_storm_packs_rounds() {
        // 8 concurrent single-shard commits share far fewer Paxos
        // rounds than 8 sequential ones would (the tentpole claim).
        let s = Arc::new(store().group_commit(Duration::from_millis(200), 8));
        let keys = same_shard_keys(&s, "w", 8);
        let sid = s.shard_of(&keys[0]);
        s.groups[sid].ensure(true).unwrap(); // warm the leader lease
        let rounds_before = s.groups[sid].log_len(true).unwrap();
        let handles: Vec<_> = keys
            .iter()
            .map(|k| {
                let s = s.clone();
                let c = put(k, Value::U64(9));
                std::thread::spawn(move || s.commit(&c, true).map(|_| ()))
            })
            .collect();
        for h in handles {
            h.join().unwrap().unwrap();
        }
        let rounds = s.groups[sid].log_len(true).unwrap() - rounds_before;
        assert!(rounds >= 1);
        assert!(
            rounds < 8,
            "8 concurrent commits consumed {rounds} rounds — group commit never packed"
        );
        for k in &keys {
            assert_eq!(s.get(k, true).unwrap(), Some((Value::U64(9), 1)));
        }
        assert!(s.converged());
    }

    #[test]
    fn prepare_batched_2pc_uses_fewer_scatters_than_sequential() {
        let run = |batched: bool| -> (u64, u64) {
            let t = Arc::new(Transport::instant());
            let s = ReplicatedMetaStore::new(
                4,
                3,
                t.clone(),
                LeaseClock::manual(),
                20,
            )
            .two_pc(true)
            .prepare_batching(batched);
            let (a, b) = cross_shard_keys(&s);
            // Warm both groups (election + first proposal run phase 1;
            // the fast path only exists on a settled leader).
            s.commit(&put(&a, Value::U64(1)), true).unwrap();
            s.commit(&put(&b, Value::U64(1)), true).unwrap();
            let before = (t.scatters_sent(), t.envelopes_sent());
            s.commit(&put_both(&a, &b), true).unwrap();
            assert_eq!(s.get(&a, true).unwrap().unwrap().0, Value::U64(1));
            assert!(s.pending_intents().is_empty());
            assert!(s.converged());
            (
                t.scatters_sent() - before.0,
                t.envelopes_sent() - before.1,
            )
        };
        let (seq_scatters, seq_env) = run(false);
        let (bat_scatters, bat_env) = run(true);
        // Same envelope count (the protocol is unchanged), strictly
        // fewer scatters (phases collapse into shared broadcasts).
        assert_eq!(seq_env, bat_env);
        assert!(
            bat_scatters < seq_scatters,
            "batched 2PC sent {bat_scatters} scatters vs sequential {seq_scatters}"
        );
    }

    #[test]
    fn prepare_batched_2pc_survives_leader_kill() {
        let s = store_2pc().prepare_batching(true);
        let (a, b) = cross_shard_keys(&s);
        s.commit(&put(&a, Value::U64(1)), true).unwrap();
        // Kill every group's replica 0: the batched phases must fall
        // back through elections (arm finds `needs_prepare` and defers
        // to the sequential driver) and still commit atomically.
        s.kill_replica(0);
        s.commit(&put_both(&a, &b), true).unwrap();
        assert_eq!(s.get(&a, true).unwrap().unwrap().0, Value::U64(1));
        assert_eq!(s.get(&b, true).unwrap().unwrap().0, Value::U64(1));
        assert!(s.pending_intents().is_empty());
        s.recover_replica(0).unwrap();
        assert!(s.converged());
    }
}
