//! The Paxos-replicated metadata store: [`MetaStore`]'s surface, served
//! by per-shard [`ShardGroup`]s instead of in-process chains.
//!
//! A [`Commit`] is validated and staged once at the front-end — under
//! the commit gates of every shard it touches, taken in canonical order,
//! which serializes validate→propose exactly like the chain store's
//! ordered shard locks — then split into per-shard [`LogEntry`] batches
//! and driven through each group's replicated log.  The one op that
//! reads across shards (`InodeSetLenFromRegion`) is rewritten at the
//! gate into its self-contained monotone-max form when its region lives
//! in a different group, so every entry is locally applicable and
//! deterministic.
//!
//! Invariants (asserted by the fault-injection suite):
//!
//! * a quorum-accepted entry survives its leader's death (the next
//!   leader's prepare rounds adopt it);
//! * a commit retried across failover applies **exactly once** (apply is
//!   deduplicated on the transaction id);
//! * reads are leaseholder-local — no quorum round — and never observe
//!   state a lease could not vouch for;
//! * with a majority of a group dead, commits fail with `NoQuorum` and
//!   nothing is partially visible in that group.
//!
//! [`MetaStore`]: super::MetaStore

use super::group::{LogEntry, ShardGroup};
use super::ops::{self, MetaOp, OpOutcome};
use super::shard::ShardStats;
use super::store::Commit;
use crate::coordinator::lease::LeaseClock;
use crate::error::{Error, Result};
use crate::net::Transport;
use crate::types::{Key, Space, Value};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, MutexGuard};

/// Proposal order for one shard's entry within a multi-shard commit:
/// namespace-root REMOVALS first (-1), plain data in the middle (0),
/// namespace-root INSERTS last (+1).  Readers resolve files through
/// Path/Dir entries and take no commit gate (reads are
/// leaseholder-local), so inserting those roots *after* their referents
/// — and removing them *before* — keeps the common create/unlink shapes
/// free of reader-visible dangling references while a multi-shard
/// commit is mid-flight.  (Entries mixing both directions cannot be
/// fully ordered; the residual window is recorded in ROADMAP.md.)
fn entry_priority(ops: &[&MetaOp]) -> i32 {
    let mut pri = 0;
    for op in ops {
        match op {
            MetaOp::PathInsert { .. } | MetaOp::DirInsert { .. } => pri = pri.max(1),
            MetaOp::DirRemove { .. } => pri = pri.min(-1),
            MetaOp::Delete { key } if key.space == Space::Path => pri = pri.min(-1),
            _ => {}
        }
    }
    pri
}

/// The sharded, Paxos-replicated metadata store.
#[derive(Debug)]
pub struct ReplicatedMetaStore {
    groups: Vec<ShardGroup>,
    next_inode: AtomicU64,
    next_txn: AtomicU64,
}

impl ReplicatedMetaStore {
    /// `shards` groups of `replicas_per_group` members each, proposing
    /// over `transport` with `lease_ms`-long leader leases.
    pub fn new(
        shards: u32,
        replicas_per_group: u8,
        transport: Arc<Transport>,
        clock: LeaseClock,
        lease_ms: u64,
    ) -> Self {
        assert!(shards >= 1);
        ReplicatedMetaStore {
            groups: (0..shards)
                .map(|s| {
                    ShardGroup::new(
                        s,
                        replicas_per_group,
                        transport.clone(),
                        clock.clone(),
                        lease_ms,
                    )
                })
                .collect(),
            // inode 1 is reserved for the root directory
            next_inode: AtomicU64::new(2),
            // txn 0 is the noop filler id
            next_txn: AtomicU64::new(1),
        }
    }

    /// Stable FNV-1a shard placement (the same helper the chain store
    /// uses — both backends place every key identically).
    fn shard_of(&self, key: &Key) -> usize {
        super::shard::shard_of_key(key, self.groups.len())
    }

    pub fn num_shards(&self) -> usize {
        self.groups.len()
    }

    /// The group serving `key`'s shard (tests, observability).
    pub fn group_of(&self, key: &Key) -> &ShardGroup {
        &self.groups[self.shard_of(key)]
    }

    pub fn groups(&self) -> &[ShardGroup] {
        &self.groups
    }

    /// Allocate a fresh inode id.  Ids allocated by aborted transactions
    /// are simply never used — the allocator needs no transactionality
    /// (and therefore no quorum round).
    pub fn alloc_inode_id(&self) -> u64 {
        self.next_inode.fetch_add(1, Ordering::Relaxed)
    }

    /// Versioned point read from the shard leader's read-leased local
    /// state.  `auto_elect` controls leader discovery: on (direct calls)
    /// blocks through an election; off (the envelope path) surfaces
    /// [`Error::NotLeader`] for the client to handle.
    pub fn get(&self, key: &Key, auto_elect: bool) -> Result<Option<(Value, u64)>> {
        self.groups[self.shard_of(key)].local_get(key, auto_elect)
    }

    /// Version of `key` without copying the value.
    pub fn version(&self, key: &Key, auto_elect: bool) -> Result<u64> {
        self.groups[self.shard_of(key)].local_version(key, auto_elect)
    }

    /// Value AND version in one leaseholder read (absent keys still
    /// report their version).
    pub fn entry(&self, key: &Key, auto_elect: bool) -> Result<(Option<Value>, u64)> {
        self.groups[self.shard_of(key)].local_entry(key, auto_elect)
    }

    /// Atomically commit `commit` through the replicated logs of every
    /// shard it touches.  See the module docs for the protocol.
    pub fn commit(&self, commit: &Commit, auto_elect: bool) -> Result<Vec<OpOutcome>> {
        if commit.is_empty() {
            return Ok(Vec::new());
        }
        // 1. Canonically ordered commit-gate acquisition over the
        //    touched shards (serializes validate→propose; no deadlocks).
        let mut shard_ids: Vec<usize> = commit
            .reads
            .iter()
            .map(|(k, _)| self.shard_of(k))
            .chain(
                commit
                    .ops
                    .iter()
                    .flat_map(|op| op.keys().into_iter().map(|k| self.shard_of(k))),
            )
            .collect();
        shard_ids.sort_unstable();
        shard_ids.dedup();
        let _gates: Vec<MutexGuard<'_, ()>> = shard_ids
            .iter()
            .map(|&sid| self.groups[sid].gate.lock().unwrap())
            .collect();

        // 2. Pre-flight: every touched group must have a live leased
        //    leader BEFORE anything is proposed — a leaderless or
        //    quorum-less group must abort the commit while it is still
        //    clean, not midway through the per-group proposals (the
        //    residual window, a quorum dying mid-propose, is the known
        //    gap recorded in ROADMAP.md).  Then validate the read set
        //    against the leaders' leased state.
        for &sid in &shard_ids {
            self.groups[sid].ensure(auto_elect)?;
        }
        for (key, observed) in &commit.reads {
            let v = self.groups[self.shard_of(key)].local_version(key, auto_elect)?;
            if v != *observed {
                return Err(Error::TxnConflict {
                    space: key.space,
                    key: key.key.clone(),
                });
            }
        }

        // 3. Stage ops through the shared overlay staging ([`ops::stage`]
        //    — one value+version leader read per distinct key); a validation
        //    failure aborts with nothing proposed anywhere.  Cross-shard
        //    `InodeSetLenFromRegion` is rewritten into its
        //    self-contained monotone-max form via the staging hook,
        //    while this commit's own region appends are visible through
        //    the overlay-aware peek.
        let mut routed: Vec<MetaOp> = Vec::with_capacity(commit.ops.len());
        let committed =
            |k: &Key| self.groups[self.shard_of(k)].local_entry(k, auto_elect);
        let (_overlay, outcomes) = ops::stage(&commit.ops, &committed, |op, peek| {
            let routed_op = match op {
                MetaOp::InodeSetLenFromRegion {
                    inode_key,
                    region_key,
                    region_base,
                    mtime,
                } if self.shard_of(region_key) != self.shard_of(inode_key) => {
                    let eof = peek(region_key)
                        .as_ref()
                        .and_then(|v| v.as_region().map(|r| r.eof))
                        .unwrap_or(0);
                    MetaOp::InodeSetLenMax {
                        key: inode_key.clone(),
                        candidate: *region_base + eof,
                        highest_region: 0,
                        mtime: *mtime,
                    }
                }
                other => other.clone(),
            };
            routed.push(routed_op);
        })?;

        // 4. One log entry per touched shard, proposed in dependency
        //    order (gates stay held throughout, so proposal order is
        //    free to differ from the canonical gate-acquisition order).
        //    `commit_entry` survives leader failover and applies exactly
        //    once (txn-id dedup), so a retry after a mid-commit crash
        //    cannot double-apply.
        //
        //    NOTE: the proposals always run with blocking leader
        //    discovery, regardless of `auto_elect`.  `NotLeader` may
        //    only escape this function while nothing has been proposed
        //    (steps 2–3) — once the first entry is in flight, the commit
        //    must drive to completion through any election, or a client
        //    replay under a fresh transaction id could re-apply the
        //    groups that already accepted.
        let txn_id = self.next_txn.fetch_add(1, Ordering::Relaxed);
        let mut final_outcomes = outcomes;
        // Plan the per-shard entries, then propose them in dependency
        // order (namespace roots last on insert, first on remove) so
        // gate-free readers never resolve a dangling reference through a
        // half-committed transaction.
        let mut planned: Vec<(i32, usize, Vec<usize>)> = Vec::new();
        for &sid in &shard_ids {
            let idxs: Vec<usize> = routed
                .iter()
                .enumerate()
                .filter(|(_, op)| self.shard_of(op.key()) == sid)
                .map(|(i, _)| i)
                .collect();
            if idxs.is_empty() {
                continue; // read-only in this shard: validated above
            }
            let shard_ops: Vec<&MetaOp> = idxs.iter().map(|&i| &routed[i]).collect();
            planned.push((entry_priority(&shard_ops), sid, idxs));
        }
        planned.sort_by_key(|(pri, sid, _)| (*pri, *sid));
        for (_, sid, idxs) in planned {
            let entry = LogEntry {
                txn_id,
                reads: commit
                    .reads
                    .iter()
                    .filter(|(k, _)| self.shard_of(k) == sid)
                    .cloned()
                    .collect(),
                ops: idxs.iter().map(|&i| routed[i].clone()).collect(),
            };
            let applied = self.groups[sid].commit_entry(&entry, true)?;
            // Report what the replicated apply actually recorded — it
            // diverges from the staging above only when an indeterminate
            // earlier commit was recovered ahead of this entry (in which
            // case an abort already surfaced as `TxnAborted` from
            // `commit_entry`).
            for (&i, o) in idxs.iter().zip(applied) {
                final_outcomes[i] = o;
            }
        }
        Ok(final_outcomes)
    }

    /// Full scan of one space from the shard leaders (GC; not
    /// transactional — GC tolerates staleness by design).  An
    /// unreadable shard is an ERROR, never an empty result: GC decides
    /// slice liveness from this scan, and treating a quorum-less
    /// shard's keyspace as absent would reclaim live data.
    pub fn scan_space(&self, space: Space) -> Result<Vec<(Key, Value)>> {
        let mut out = Vec::new();
        for g in &self.groups {
            out.append(&mut g.local_scan(space, true)?);
        }
        out.sort_by(|a, b| a.0.cmp(&b.0));
        Ok(out)
    }

    /// Crash replica `idx` of every shard group (failure injection).  If
    /// it led a group, that group stalls until the lease expires, then
    /// fails over.
    pub fn kill_replica(&self, idx: usize) {
        for g in &self.groups {
            g.kill_replica(idx);
        }
    }

    /// Rejoin replica `idx` of every group by deterministic log replay.
    /// Best-effort across groups: every group is attempted even when an
    /// earlier one has no live replay source; the first error is
    /// reported after the sweep.
    pub fn recover_replica(&self, idx: usize) -> Result<()> {
        let mut first_err = None;
        for g in &self.groups {
            if let Err(e) = g.recover_replica(idx) {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Blocking leader (re-)discovery for one shard — what a client does
    /// after [`Error::NotLeader`].
    pub fn heal(&self, shard: u32) -> Result<u32> {
        match self.groups.get(shard as usize) {
            Some(g) => g.heal(),
            None => Err(Error::InvalidArgument(format!(
                "no metadata shard {shard}"
            ))),
        }
    }

    /// All live replicas of every group agree (test invariant).
    pub fn converged(&self) -> bool {
        self.groups.iter().all(|g| g.converged())
    }

    pub fn shard_stats(&self) -> Vec<ShardStats> {
        self.groups.iter().map(|g| g.stats()).collect()
    }

    /// Total leaseholder-local reads across groups (observability).
    pub fn lease_reads(&self) -> u64 {
        self.groups.iter().map(|g| g.lease_reads()).sum()
    }

    /// Total leader elections across groups (observability).
    pub fn elections(&self) -> u64 {
        self.groups.iter().map(|g| g.elections()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{Inode, Placement, RegionEntry, SliceData, SlicePtr};

    fn store() -> ReplicatedMetaStore {
        ReplicatedMetaStore::new(
            4,
            3,
            Arc::new(Transport::instant()),
            LeaseClock::manual(),
            20,
        )
    }

    fn skey(s: &str) -> Key {
        Key::sys(s)
    }

    fn put(key: &Key, v: Value) -> Commit {
        Commit {
            reads: vec![],
            ops: vec![MetaOp::Put {
                key: key.clone(),
                value: v,
            }],
        }
    }

    fn stored(len: u64) -> SliceData {
        SliceData::Stored(vec![SlicePtr {
            server: 1,
            backing: 0,
            offset: 0,
            len,
        }])
    }

    #[test]
    fn put_get_round_trip() {
        let s = store();
        let k = skey("a");
        s.commit(&put(&k, Value::U64(42)), true).unwrap();
        assert_eq!(s.get(&k, true).unwrap(), Some((Value::U64(42), 1)));
        assert!(s.converged());
    }

    #[test]
    fn multi_shard_commit_lands_everywhere() {
        let s = store();
        let keys: Vec<Key> = (0..16).map(|i| skey(&format!("k{i}"))).collect();
        let ops = keys
            .iter()
            .map(|k| MetaOp::Put {
                key: k.clone(),
                value: Value::U64(7),
            })
            .collect();
        s.commit(&Commit { reads: vec![], ops }, true).unwrap();
        for k in &keys {
            assert_eq!(s.get(k, true).unwrap().unwrap().0, Value::U64(7));
        }
        // Several distinct groups were involved.
        let touched: std::collections::HashSet<usize> =
            keys.iter().map(|k| s.shard_of(k)).collect();
        assert!(touched.len() > 1);
        assert!(s.converged());
    }

    #[test]
    fn stale_read_aborts_with_nothing_applied() {
        let s = store();
        let k = skey("a");
        s.commit(&put(&k, Value::U64(1)), true).unwrap();
        let stale = Commit {
            reads: vec![(k.clone(), 0)],
            ops: vec![MetaOp::Put {
                key: k.clone(),
                value: Value::U64(9),
            }],
        };
        assert!(matches!(
            s.commit(&stale, true),
            Err(Error::TxnConflict { .. })
        ));
        assert_eq!(s.get(&k, true).unwrap().unwrap().0, Value::U64(1));
    }

    #[test]
    fn failed_op_rolls_back_entire_commit() {
        let s = store();
        let a = skey("a");
        let c = Commit {
            reads: vec![],
            ops: vec![
                MetaOp::Put {
                    key: a.clone(),
                    value: Value::U64(1),
                },
                // Fails validation: inode op against a U64.
                MetaOp::InodeSetLenMax {
                    key: a.clone(),
                    candidate: 1,
                    highest_region: 0,
                    mtime: 0,
                },
            ],
        };
        assert!(s.commit(&c, true).is_err());
        assert_eq!(s.get(&a, true).unwrap(), None);
    }

    #[test]
    fn cross_shard_set_len_from_region_is_rewritten() {
        let s = store();
        // Find a region key on a different shard than the inode key.
        let ikey = Key::inode(9);
        let ishard = s.shard_of(&ikey);
        let rkey = (0..64)
            .map(|i| Key::new(Space::Region, format!("r{i}")))
            .find(|k| s.shard_of(k) != ishard)
            .expect("some region key lands on another shard");
        s.commit(&put(&ikey, Value::Inode(Inode::new_file(9, 0o644, 1))), true)
            .unwrap();
        let c = Commit {
            reads: vec![],
            ops: vec![
                MetaOp::RegionAppendEof {
                    key: rkey.clone(),
                    data: stored(10),
                    len: 10,
                    cap: 100,
                },
                MetaOp::InodeSetLenFromRegion {
                    inode_key: ikey.clone(),
                    region_key: rkey.clone(),
                    region_base: 1000,
                    mtime: 1,
                },
            ],
        };
        let outcomes = s.commit(&c, true).unwrap();
        assert_eq!(outcomes[0], OpOutcome::AppendedAt(0));
        // The inode observed this commit's own append through the overlay
        // even though the region lives in another group.
        let inode = s.get(&ikey, true).unwrap().unwrap().0;
        assert_eq!(inode.as_inode().unwrap().len, 1010);
        assert!(s.converged());
    }

    #[test]
    fn same_shard_set_len_from_region_stays_native() {
        let s = store();
        let ikey = Key::inode(7);
        let ishard = s.shard_of(&ikey);
        let rkey = (0..64)
            .map(|i| Key::new(Space::Region, format!("q{i}")))
            .find(|k| s.shard_of(k) == ishard)
            .expect("some region key lands on the inode's shard");
        s.commit(&put(&ikey, Value::Inode(Inode::new_file(7, 0o644, 1))), true)
            .unwrap();
        let c = Commit {
            reads: vec![],
            ops: vec![
                MetaOp::RegionAppend {
                    key: rkey.clone(),
                    entry: RegionEntry {
                        placement: Placement::At(0),
                        len: 25,
                        data: stored(25),
                    },
                },
                MetaOp::InodeSetLenFromRegion {
                    inode_key: ikey.clone(),
                    region_key: rkey.clone(),
                    region_base: 0,
                    mtime: 1,
                },
            ],
        };
        s.commit(&c, true).unwrap();
        let inode = s.get(&ikey, true).unwrap().unwrap().0;
        assert_eq!(inode.as_inode().unwrap().len, 25);
    }

    #[test]
    fn scan_space_aggregates_across_groups() {
        let s = store();
        for i in 0..12 {
            s.commit(&put(&skey(&format!("s{i}")), Value::U64(i)), true)
                .unwrap();
        }
        let all = s.scan_space(Space::Sys).unwrap();
        assert_eq!(all.len(), 12);
        assert!(all.windows(2).all(|w| w[0].0 <= w[1].0), "sorted");
    }

    #[test]
    fn inode_ids_are_unique_and_start_past_root() {
        let s = store();
        let a = s.alloc_inode_id();
        let b = s.alloc_inode_id();
        assert!(a >= 2);
        assert_ne!(a, b);
    }
}
