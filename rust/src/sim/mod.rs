//! Discrete-event simulation of the paper's testbed (DESIGN.md §5).
//!
//! The evaluation ran on fifteen 2008-era servers: two Xeon L5420s,
//! 16 GB RAM, SATA spinning disks (~87 MB/s measured, Fig. 6), gigabit
//! ethernet through one ToR switch, HyperDex transactions with a ~3 ms
//! floor.  None of that hardware exists here, so the benchmark harness
//! regenerates the figures on a calibrated simulator: closed-loop
//! clients issuing operations against FIFO resources (disks, NICs, the
//! metadata service), processed in global time order.
//!
//! The simulator is intentionally *conservative*: single-server FIFO
//! resources, no preemption, deterministic RNG.  It reproduces the
//! paper's **shapes** (who wins, by what factor, where curves cross),
//! not its absolute numbers — see EXPERIMENTS.md for the comparison.

pub mod engine;
pub mod model;

pub use engine::{run_closed_loop, Nanos, ResourceId, Sim};
pub use model::{ClusterModel, Testbed};
