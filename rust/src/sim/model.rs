//! Calibrated model of the paper's cluster (§4 Setup) and the I/O paths
//! of both filesystems.
//!
//! Calibration constants come from the paper itself where possible:
//! 87 MB/s single-disk throughput (Fig. 6), ~3 ms HyperDex transaction
//! floor (§4.2), 4 MB HDFS readahead, gigabit NICs, twelve storage
//! servers + three metadata nodes.  The rest (seek time, per-op CPU)
//! are standard numbers for the hardware generation (SATA 7200 rpm,
//! 2008 Xeons).

use super::engine::{Nanos, ResourceId, Sim};
use crate::util::Rng;

const MS: u64 = 1_000_000;
const US: u64 = 1_000;

/// Calibration constants for the simulated testbed.
#[derive(Clone, Debug)]
pub struct Testbed {
    /// Storage servers (paper: 12).
    pub servers: usize,
    /// Metadata nodes (paper: 3 — HyperDex or the HDFS name node host).
    pub meta_nodes: usize,
    /// Single-disk streaming bandwidth, bytes/s (Fig. 6: 87 MB/s).
    pub disk_bw: u64,
    /// Average seek + rotational latency.
    pub disk_seek: Nanos,
    /// Per-endpoint NIC bandwidth, bytes/s (GbE payload: ~117 MB/s).
    pub nic_bw: u64,
    /// One-way network latency through the ToR switch.
    pub net_half_rtt: Nanos,
    /// HyperDex transaction latency floor (§4.2: ~3 ms).
    pub meta_txn_floor: Nanos,
    /// Metadata-server CPU occupancy per transaction.
    pub meta_txn_service: Nanos,
    /// Metadata GET (read path) service time.
    pub meta_get_service: Nanos,
    /// HDFS name-node op service time.
    pub namenode_service: Nanos,
    /// HDFS readahead window (§4.2: 4 MB).
    pub hdfs_readahead: u64,
    /// Slice replication factor (paper: 2).
    pub replication: usize,
}

impl Default for Testbed {
    fn default() -> Self {
        Testbed {
            servers: 12,
            meta_nodes: 3,
            disk_bw: 87 * 1_000_000,
            disk_seek: 8 * MS,
            nic_bw: 117 * 1_000_000,
            net_half_rtt: 100 * US,
            meta_txn_floor: 3 * MS,
            meta_txn_service: 200 * US,
            meta_get_service: 300 * US,
            namenode_service: 300 * US,
            hdfs_readahead: 4 * 1024 * 1024,
            replication: 2,
        }
    }
}

impl Testbed {
    fn disk_xfer(&self, bytes: u64) -> Nanos {
        bytes.saturating_mul(1_000_000_000) / self.disk_bw
    }
    fn nic_xfer(&self, bytes: u64) -> Nanos {
        bytes.saturating_mul(1_000_000_000) / self.nic_bw
    }
}

/// Resource layout for one simulated cluster + per-client stream state.
pub struct ClusterModel {
    pub tb: Testbed,
    sim: Sim,
    disks: Vec<ResourceId>,
    server_nics: Vec<ResourceId>,
    client_nics: Vec<ResourceId>,
    meta: Vec<ResourceId>,
    namenode: ResourceId,
    rng: Rng,
    /// Per-client prefetch state for the HDFS readahead model:
    /// (buffered bytes remaining, completion time of the inflight fetch).
    readahead: Vec<(u64, Nanos)>,
    /// Round-robin cursor for placement.
    cursor: usize,
}

/// What kind of operation a workload step is.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpKind {
    SeqWrite,
    RandWrite,
    SeqRead,
    RandRead,
}

impl ClusterModel {
    pub fn new(tb: Testbed, clients: usize, seed: u64) -> ClusterModel {
        let mut sim = Sim::new();
        let disks = (0..tb.servers).map(|_| sim.resource()).collect();
        let server_nics = (0..tb.servers).map(|_| sim.resource()).collect();
        let client_nics = (0..clients).map(|_| sim.resource()).collect();
        let meta = (0..tb.meta_nodes).map(|_| sim.resource()).collect();
        let namenode = sim.resource();
        ClusterModel {
            tb,
            sim,
            disks,
            server_nics,
            client_nics,
            meta,
            namenode,
            rng: Rng::new(seed),
            readahead: vec![(0, 0); clients],
            cursor: 0,
        }
    }

    pub fn sim(&self) -> &Sim {
        &self.sim
    }

    /// Aggregate throughput for `bytes_total` finishing at `makespan`.
    pub fn throughput(bytes_total: u64, makespan: Nanos) -> f64 {
        if makespan == 0 {
            return 0.0;
        }
        bytes_total as f64 / (makespan as f64 / 1e9)
    }

    fn pick_servers(&mut self, n: usize) -> Vec<usize> {
        // Consistent-hash spreading ≈ round-robin at this granularity;
        // replicas are spread half a ring apart (as distinct chain
        // positions are in practice) so consecutive operations' replica
        // sets do not systematically collide.
        let spread = (self.tb.servers / n.max(1)).max(1);
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            out.push((self.cursor + i * spread) % self.tb.servers);
        }
        self.cursor = (self.cursor + 1) % self.tb.servers;
        out
    }

    /// One WTF write of `bytes` from `client` (§2.1 write path): slices
    /// to R servers (client NIC serializes the copies; server NIC + disk
    /// per replica in parallel), then one metadata transaction.
    pub fn wtf_write(&mut self, client: usize, bytes: u64, kind: OpKind, now: Nanos) -> Nanos {
        self.wtf_write_op(client, bytes, kind, now).1
    }

    /// WTF write returning `(advance, completion)` for pipelined clients:
    /// the next write can be prepared once the data path drains; the
    /// metadata commit defines the operation's visible completion.
    pub fn wtf_write_op(
        &mut self,
        client: usize,
        bytes: u64,
        kind: OpKind,
        now: Nanos,
    ) -> (Nanos, Nanos) {
        let replicas = self.pick_servers(self.tb.replication);
        let mut data_done = now;
        let mut send_at = now;
        for &s in &replicas {
            // Client NIC sends each copy in turn.
            let sent = self
                .sim
                .serve(self.client_nics[client], send_at, self.tb.nic_xfer(bytes));
            send_at = sent;
            let arrived = sent + self.tb.net_half_rtt;
            let recvd = self
                .sim
                .serve(self.server_nics[s], arrived, self.tb.nic_xfer(bytes));
            // Backing files are append-only: no seek even for random
            // file offsets (§2.7 — the paper's key disk-layout point).
            let written = self.sim.serve(self.disks[s], recvd, self.tb.disk_xfer(bytes));
            data_done = data_done.max(written);
        }
        // Metadata transaction (floor + queueing at one of the meta
        // nodes).  Random-offset workloads hit a cold working set in
        // HyperDex: occasional slow transactions fatten the tail (§4.2,
        // Fig. 10).
        let mut service = self.tb.meta_txn_service;
        if kind == OpKind::RandWrite && self.rng.next_below(100) < 4 {
            service += (5 + self.rng.next_below(20)) * MS;
        }
        let meta_node = self.rng.next_below(self.meta.len() as u64) as usize;
        let committed = self.sim.serve(self.meta[meta_node], data_done, service);
        // Pipelining: the writer's send buffer is free once the client
        // NIC drains (`send_at`); visibility still waits for the commit.
        (send_at, committed + self.tb.meta_txn_floor)
    }

    /// One WTF read of `bytes` (§2.1 read path): metadata GET, then the
    /// slice from ONE replica (reads consult a single replica, §4.2).
    pub fn wtf_read(&mut self, client: usize, bytes: u64, kind: OpKind, now: Nanos) -> Nanos {
        self.wtf_read_op(client, bytes, kind, now).1
    }

    /// WTF read returning `(advance, completion)`: a double-buffering
    /// application (which the paper assumes for batch reads, §4.2) can
    /// issue its next read as soon as the metadata round-trip finishes.
    pub fn wtf_read_op(
        &mut self,
        client: usize,
        bytes: u64,
        kind: OpKind,
        now: Nanos,
    ) -> (Nanos, Nanos) {
        let completion = self.wtf_read_inner(client, bytes, kind, now);
        let advance = now + self.tb.meta_get_service + self.tb.net_half_rtt;
        (advance, completion)
    }

    fn wtf_read_inner(&mut self, client: usize, bytes: u64, kind: OpKind, now: Nanos) -> Nanos {
        // Metadata GETs are served by any replica in HyperDex's chain and
        // never contend with transaction commits, so they cost latency
        // but no shared occupancy.
        let meta_done = now + self.tb.meta_get_service + self.tb.net_half_rtt;
        let s = self.pick_servers(1)[0];
        // Sequential streams keep the disk arm in place; random reads pay
        // the seek.  Twelve interleaved sequential streams still seek
        // occasionally — charge a fractional seek per op.
        let seek = match kind {
            OpKind::RandRead => self.tb.disk_seek,
            _ => self.tb.disk_seek / 8,
        };
        let read = self
            .sim
            .serve(self.disks[s], meta_done, seek + self.tb.disk_xfer(bytes));
        let sent = self
            .sim
            .serve(self.server_nics[s], read, self.tb.nic_xfer(bytes));
        let recvd = self
            .sim
            .serve(self.client_nics[client], sent + self.tb.net_half_rtt, 0);
        recvd
    }

    /// One HDFS write (append + hflush): pipelined through the replica
    /// chain, then a name-node publish.  The pipeline streams, so the
    /// transfer completes at the bottleneck rate plus per-hop latency.
    pub fn hdfs_write(&mut self, client: usize, bytes: u64, now: Nanos) -> Nanos {
        self.hdfs_write_op(client, bytes, now).1
    }

    /// HDFS write returning `(advance, completion)`; see
    /// [`Self::wtf_write_op`].
    pub fn hdfs_write_op(&mut self, client: usize, bytes: u64, now: Nanos) -> (Nanos, Nanos) {
        let replicas = self.pick_servers(self.tb.replication);
        // Client NIC: one copy (the chain forwards).
        let sent = self
            .sim
            .serve(self.client_nics[client], now, self.tb.nic_xfer(bytes));
        // The datanode chain STREAMS: each hop forwards packets while
        // still receiving, so hop N+1's NIC transfer starts one packet
        // after hop N's, and every replica's disk write overlaps with
        // the transfer.  Completion is the max over replica disks.
        let mut nic_free = sent + self.tb.net_half_rtt;
        let mut done = sent;
        for &s in &replicas {
            let nic_done = self
                .sim
                .serve(self.server_nics[s], nic_free, self.tb.nic_xfer(bytes));
            let disk_done = self
                .sim
                .serve(self.disks[s], nic_done, self.tb.disk_xfer(bytes));
            // Next hop starts as soon as this hop begins forwarding
            // (≈ one packet after its NIC transfer starts).
            nic_free = nic_done - self.tb.nic_xfer(bytes) + self.tb.net_half_rtt / 4
                + self.tb.nic_xfer(bytes.min(64 * 1024));
            nic_free = nic_free.max(sent);
            done = done.max(disk_done);
        }
        // hflush: name-node visibility publish.
        let published = self.sim.serve(self.namenode, done, self.tb.namenode_service);
        (sent, published + self.tb.net_half_rtt)
    }

    /// One HDFS stream read with readahead: ops served from the prefetch
    /// buffer are nearly free; refills fetch `readahead` bytes and are
    /// double-buffered (issued one window ahead).
    pub fn hdfs_seq_read_op(&mut self, client: usize, bytes: u64, now: Nanos) -> (Nanos, Nanos) {
        let completion = self.hdfs_seq_read(client, bytes, now);
        (now + self.tb.net_half_rtt, completion)
    }

    pub fn hdfs_seq_read(&mut self, client: usize, bytes: u64, now: Nanos) -> Nanos {
        let (mut credit, fetch_done) = self.readahead[client];
        if credit < bytes {
            // Wait for the inflight window, then issue the next one.
            let window = self.tb.hdfs_readahead.max(bytes);
            let start = now.max(fetch_done);
            let done = self.fetch_window(client, window, start);
            credit += window;
            // Double-buffer: immediately issue the next window too.
            let next_done = self.fetch_window(client, window, done);
            self.readahead[client] = (credit + window - bytes, next_done);
            return done.max(now) + self.tb.nic_xfer(bytes);
        }
        self.readahead[client] = (credit - bytes, fetch_done.max(now));
        // Buffered: client-side copy only.
        now + self.tb.nic_xfer(bytes) / 4
    }

    /// One HDFS positional read (pread): no readahead reuse across ops in
    /// the random benchmark, but the server still fetches a full
    /// readahead window from disk (§4.2: "the readahead ... adds
    /// overhead to HDFS that WTF does not incur").
    pub fn hdfs_rand_read(&mut self, client: usize, bytes: u64, now: Nanos) -> Nanos {
        let window = bytes.max(self.tb.hdfs_readahead);
        let s = self.pick_servers(1)[0];
        let read = self.sim.serve(
            self.disks[s],
            now + self.tb.net_half_rtt,
            self.tb.disk_seek + self.tb.disk_xfer(window),
        );
        // Only the requested bytes cross the network.
        let sent = self
            .sim
            .serve(self.server_nics[s], read, self.tb.nic_xfer(bytes));
        self.sim
            .serve(self.client_nics[client], sent + self.tb.net_half_rtt, 0)
    }

    fn fetch_window(&mut self, client: usize, window: u64, at: Nanos) -> Nanos {
        let s = self.pick_servers(1)[0];
        let read = self.sim.serve(
            self.disks[s],
            at,
            self.tb.disk_seek / 8 + self.tb.disk_xfer(window),
        );
        let sent = self
            .sim
            .serve(self.server_nics[s], read, self.tb.nic_xfer(window));
        let _ = client;
        sent + self.tb.net_half_rtt
    }

    /// Reset per-client stream state (between benchmark phases).
    pub fn reset_streams(&mut self) {
        for s in &mut self.readahead {
            *s = (0, 0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::engine::{run_closed_loop, run_pipelined};
    use super::*;

    const MB: u64 = 1_000_000;

    fn run_writes(
        clients: usize,
        ops: usize,
        bytes: u64,
        kind: OpKind,
        hdfs: bool,
    ) -> (f64, Vec<Nanos>) {
        let mut model = ClusterModel::new(Testbed::default(), clients, 42);
        let (lat, makespan) = run_pipelined(clients, ops, |c, _, now| {
            if hdfs {
                model.hdfs_write_op(c, bytes, now)
            } else {
                model.wtf_write_op(c, bytes, kind, now)
            }
        });
        let total = (clients * ops) as u64 * bytes;
        (ClusterModel::throughput(total, makespan), lat)
    }

    #[test]
    fn both_systems_deliver_paper_scale_write_throughput() {
        // Fig. 7: ~400 MB/s goodput for twelve 4 MB writers.
        let (wtf, _) = run_writes(12, 40, 4 * MB, OpKind::SeqWrite, false);
        let (hdfs, _) = run_writes(12, 40, 4 * MB, OpKind::SeqWrite, true);
        assert!(
            wtf > 250e6 && wtf < 700e6,
            "wtf seq-write throughput {wtf:.0}"
        );
        assert!(
            hdfs > 250e6 && hdfs < 700e6,
            "hdfs seq-write throughput {hdfs:.0}"
        );
        // Same ballpark (paper: WTF ≈ 97% of HDFS at ≥1 MB).
        let ratio = wtf / hdfs;
        assert!((0.6..1.4).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn small_writes_cost_wtf_more_than_hdfs() {
        // Fig. 7/8: the 3 ms metadata floor dominates 256 kB writes.
        let (wtf, _) = run_writes(12, 60, 256 * 1024, OpKind::SeqWrite, false);
        let (hdfs, _) = run_writes(12, 60, 256 * 1024, OpKind::SeqWrite, true);
        assert!(wtf < hdfs, "wtf {wtf:.0} should trail hdfs {hdfs:.0} at 256 kB");
        assert!(wtf / hdfs > 0.5, "but not catastrophically: {}", wtf / hdfs);
    }

    #[test]
    fn random_writes_within_2x_of_sequential() {
        // Fig. 9: random ≥ half of sequential, converging by 8 MB.
        let (seq_small, _) = run_writes(12, 40, 1 * MB, OpKind::SeqWrite, false);
        let (rand_small, _) = run_writes(12, 40, 1 * MB, OpKind::RandWrite, false);
        assert!(rand_small * 2.0 >= seq_small, "{rand_small} vs {seq_small}");
        let (seq_big, _) = run_writes(12, 30, 8 * MB, OpKind::SeqWrite, false);
        let (rand_big, _) = run_writes(12, 30, 8 * MB, OpKind::RandWrite, false);
        assert!(rand_big / seq_big > 0.85, "{}", rand_big / seq_big);
    }

    #[test]
    fn random_reads_favor_wtf() {
        // Fig. 12: HDFS wastes a readahead window per small random read.
        let clients = 12;
        let bytes = 1 * MB;
        let mut model = ClusterModel::new(Testbed::default(), clients, 7);
        let (_, wtf_makespan) = run_closed_loop(clients, 30, |c, _, now| {
            model.wtf_read(c, bytes, OpKind::RandRead, now)
        });
        let mut model2 = ClusterModel::new(Testbed::default(), clients, 7);
        let (_, hdfs_makespan) =
            run_closed_loop(clients, 30, |c, _, now| model2.hdfs_rand_read(c, bytes, now));
        let total = (clients * 30) as u64 * bytes;
        let wtf = ClusterModel::throughput(total, wtf_makespan);
        let hdfs = ClusterModel::throughput(total, hdfs_makespan);
        assert!(
            wtf > 1.5 * hdfs,
            "wtf {wtf:.0} should beat hdfs {hdfs:.0} ~2.4x on 1 MB random reads"
        );
    }

    #[test]
    fn sequential_reads_are_comparable() {
        // Fig. 11: WTF ≥ 80% of HDFS on streaming reads.
        let clients = 12;
        let bytes = 4 * MB;
        let mut model = ClusterModel::new(Testbed::default(), clients, 7);
        let (_, wtf_mk) = run_closed_loop(clients, 40, |c, _, now| {
            model.wtf_read(c, bytes, OpKind::SeqRead, now)
        });
        let mut model2 = ClusterModel::new(Testbed::default(), clients, 7);
        let (_, hdfs_mk) =
            run_closed_loop(clients, 40, |c, _, now| model2.hdfs_seq_read(c, bytes, now));
        let total = (clients * 40) as u64 * bytes;
        let wtf = ClusterModel::throughput(total, wtf_mk);
        let hdfs = ClusterModel::throughput(total, hdfs_mk);
        assert!(wtf / hdfs > 0.65, "wtf/hdfs = {}", wtf / hdfs);
        assert!(wtf / hdfs < 1.5, "wtf/hdfs = {}", wtf / hdfs);
    }
}
