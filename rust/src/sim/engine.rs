//! The simulation core: virtual time, FIFO resources, closed-loop
//! clients.
//!
//! Workloads here are *closed-loop* (each client issues its next
//! operation only when the previous one completes), which admits a very
//! simple and exact scheme: keep one local clock per client, always
//! advance the client with the smallest clock, and serve its next
//! operation on the shared resources.  A FIFO resource is just
//! `next_free`: a request arriving at `t` with service time `s` starts
//! at `max(t, next_free)` and completes at `start + s`.  Because we
//! always process the globally-earliest client, arrival order at every
//! resource is globally time-ordered — the same schedule an event queue
//! would produce.

/// Index of a resource within a [`Sim`].
pub type ResourceId = usize;

/// Virtual time in nanoseconds.
pub type Nanos = u64;

/// The resource table.
#[derive(Clone, Debug, Default)]
pub struct Sim {
    next_free: Vec<Nanos>,
    /// Total busy time per resource (utilization accounting).
    busy: Vec<Nanos>,
}

impl Sim {
    pub fn new() -> Sim {
        Sim::default()
    }

    /// Register a new FIFO resource; returns its id.
    pub fn resource(&mut self) -> ResourceId {
        self.next_free.push(0);
        self.busy.push(0);
        self.next_free.len() - 1
    }

    /// Serve a request arriving at `at` needing `service` ns; returns the
    /// completion time.
    pub fn serve(&mut self, r: ResourceId, at: Nanos, service: Nanos) -> Nanos {
        let start = at.max(self.next_free[r]);
        self.next_free[r] = start + service;
        self.busy[r] += service;
        start + service
    }

    /// Completion time if the request were served, without reserving.
    pub fn peek(&self, r: ResourceId, at: Nanos, service: Nanos) -> Nanos {
        at.max(self.next_free[r]) + service
    }

    /// Total busy nanoseconds of `r`.
    pub fn busy_time(&self, r: ResourceId) -> Nanos {
        self.busy[r]
    }

    /// When `r` next becomes free.
    pub fn free_at(&self, r: ResourceId) -> Nanos {
        self.next_free[r]
    }
}

/// Run a closed-loop workload: `clients` independent sequences, each of
/// `ops` operations.  `op` is called as `(client, op_index, now)` and
/// returns the operation's completion time (typically by serving stages
/// on a shared [`Sim`] the closure captures).  Returns per-operation
/// latencies (ns) and the makespan.
pub fn run_closed_loop(
    clients: usize,
    ops: usize,
    mut op: impl FnMut(usize, usize, Nanos) -> Nanos,
) -> (Vec<Nanos>, Nanos) {
    run_pipelined(clients, ops, |c, i, now| {
        let fin = op(c, i, now);
        (fin, fin)
    })
}

/// Like [`run_closed_loop`], but the op returns `(advance, completion)`:
/// the client may issue its next operation at `advance` (when its send
/// buffer drains), with at most TWO operations in flight (the classic
/// double-buffered writer), while `completion` is what latency is
/// measured to — how buffered writers with visibility barriers behave.
pub fn run_pipelined(
    clients: usize,
    ops: usize,
    mut op: impl FnMut(usize, usize, Nanos) -> (Nanos, Nanos),
) -> (Vec<Nanos>, Nanos) {
    let mut clocks = vec![0u64; clients];
    // Completion of each client's previous op (depth-2 bound).
    let mut prev_completion = vec![0u64; clients];
    let mut done = vec![0usize; clients];
    let mut latencies = Vec::with_capacity(clients * ops);
    let mut makespan = 0;
    loop {
        // Earliest client with work left.
        let Some(cid) = (0..clients)
            .filter(|&c| done[c] < ops)
            .min_by_key(|&c| clocks[c])
        else {
            break;
        };
        let now = clocks[cid];
        let (advance, fin) = op(cid, done[cid], now);
        latencies.push(fin.saturating_sub(now));
        // Next issue: our buffer drained AND the op before last finished.
        clocks[cid] = advance.max(now).max(prev_completion[cid]);
        prev_completion[cid] = fin;
        done[cid] += 1;
        makespan = makespan.max(fin);
    }
    (latencies, makespan)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_resource_queues() {
        let mut sim = Sim::new();
        let r = sim.resource();
        assert_eq!(sim.serve(r, 0, 10), 10);
        assert_eq!(sim.serve(r, 0, 10), 20); // queued behind the first
        assert_eq!(sim.serve(r, 100, 10), 110); // idle gap
        assert_eq!(sim.busy_time(r), 30);
    }

    #[test]
    fn closed_loop_single_client_is_sequential() {
        let mut sim = Sim::new();
        let r = sim.resource();
        let (lat, makespan) = run_closed_loop(1, 5, |_, _, now| sim.serve(r, now, 7));
        assert_eq!(lat, vec![7; 5]);
        assert_eq!(makespan, 35);
    }

    #[test]
    fn contention_grows_latency_not_throughput() {
        // 4 clients sharing one resource: same makespan per total work,
        // 4x the latency.
        let mut sim = Sim::new();
        let r = sim.resource();
        let (lat, makespan) = run_closed_loop(4, 25, |_, _, now| sim.serve(r, now, 10));
        assert_eq!(makespan, 1000);
        let avg = lat.iter().sum::<u64>() / lat.len() as u64;
        assert!(avg >= 30, "queueing should inflate latency: {avg}");
    }

    #[test]
    fn independent_resources_scale() {
        let mut sim = Sim::new();
        let rs: Vec<_> = (0..4).map(|_| sim.resource()).collect();
        let (_, makespan) = run_closed_loop(4, 25, |c, _, now| sim.serve(rs[c], now, 10));
        assert_eq!(makespan, 250, "4 disjoint resources run in parallel");
    }
}
