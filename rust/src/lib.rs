//! # WTF — the Wave Transactional Filesystem, reproduced
//!
//! A from-scratch reproduction of *"The Design and Implementation of the
//! Wave Transactional Filesystem"* (Escriva & Sirer, 2015) as a
//! three-layer rust + JAX + Pallas stack.
//!
//! WTF is a distributed, transactional, POSIX-compatible filesystem built
//! around a *file slicing* API: files are sequences of immutable, byte
//! addressable **slices** held on storage servers, stitched together by
//! metadata lists held in a transactional key-value store ("hyperdex-lite"
//! here, HyperDex+Warp in the paper).  Because the data/metadata split is
//! total, filesystem-level transactions reduce to metadata transactions,
//! and applications can rearrange file contents (concat, copy, sort) by
//! rewriting *pointers*, never bytes.
//!
//! ## Layer map
//!
//! * [`meta`] — the transactional metadata store (HyperDex+Warp substrate).
//! * [`storage`] — slice storage servers: backing files, placement, GC.
//! * [`coordinator`] — the replicated coordinator (Replicant substrate).
//! * [`client`] — the WTF client library: POSIX + file slicing + txn retry.
//! * [`baseline`] — "hdfs-lite", the comparison filesystem of the paper.
//! * [`mapreduce`] — the sort application of §4.1, conventional vs slicing.
//! * [`runtime`] — PJRT execution of the AOT-compiled JAX/Pallas kernels.
//! * [`sim`] — discrete-event cluster simulator calibrated to the paper's
//!   testbed (used by the benchmark harness to regenerate figures).
//! * [`bench`] — workload generators, statistics and the per-figure harness.
//!
//! ## Quickstart
//!
//! ```no_run
//! # fn demo() -> wtf::Result<()> {
//! use wtf::cluster::Cluster;
//!
//! let cluster = Cluster::builder().storage_servers(4).build()?;
//! let client = cluster.client();
//! let mut fd = client.create("/hello")?;
//! client.write(&mut fd, b"Hello World")?;
//! let back = client.read_at(&fd, 0, 11)?;
//! assert_eq!(back, b"Hello World");
//! # Ok(()) }
//! ```

pub mod baseline;
pub mod bench;
pub mod client;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod mapreduce;
pub mod meta;
pub mod metrics;
pub mod net;
pub mod runtime;
pub mod sim;
pub mod storage;
pub mod types;
pub mod util;

pub use client::WtfClient;
pub use cluster::Cluster;
pub use config::Config;
pub use error::{Error, Result};
pub use types::{InodeId, RegionId, ServerId, SlicePtr};
