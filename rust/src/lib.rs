//! # WTF — the Wave Transactional Filesystem, reproduced
//!
//! A from-scratch reproduction of *"The Design and Implementation of the
//! Wave Transactional Filesystem"* (Escriva & Sirer, 2015) as a
//! three-layer rust + JAX + Pallas stack.
//!
//! WTF is a distributed, transactional, POSIX-compatible filesystem built
//! around a *file slicing* API: files are sequences of immutable, byte
//! addressable **slices** held on storage servers, stitched together by
//! metadata lists held in a transactional key-value store ("hyperdex-lite"
//! here, HyperDex+Warp in the paper).  Because the data/metadata split is
//! total, filesystem-level transactions reduce to metadata transactions,
//! and applications can rearrange file contents (concat, copy, sort) by
//! rewriting *pointers*, never bytes.
//!
//! ## Layer map
//!
//! * [`net`] — the `Transport` RPC layer: request/response envelopes, a
//!   worker-pool in-process implementation, scatter-gather
//!   `broadcast`/`join`, and the latency/bandwidth `LinkModel` it
//!   charges.  Every cross-component call below travels through it, and
//!   replica fan-out overlaps on its workers (a replication-`r` write
//!   costs ~1 wire time instead of `r`).
//! * [`meta`] — the transactional metadata store (HyperDex+Warp
//!   substrate); serves commits and versioned gets as transport
//!   envelopes.
//! * [`storage`] — slice storage servers: backing files, placement, GC;
//!   serve `CreateSlice`/`RetrieveSlice` envelopes.
//! * [`coordinator`] — the replicated coordinator (Replicant substrate).
//! * [`client`] — the WTF client library: POSIX + file slicing + txn
//!   retry; scatters all replica uploads and multi-region reads for one
//!   operation concurrently through the transport.
//! * [`baseline`] — "hdfs-lite", the comparison filesystem of the paper,
//!   ported to the same transport (its write pipeline stays a sequential
//!   replica chain — that is the protocol under comparison).
//! * [`mapreduce`] — the sort application of §4.1, conventional vs slicing.
//! * [`runtime`] — PJRT execution of the AOT-compiled JAX/Pallas kernels
//!   (behind the `xla-runtime` feature; a NativeCompute oracle otherwise).
//! * [`sim`] — discrete-event cluster simulator calibrated to the paper's
//!   testbed (used by the benchmark harness to regenerate figures).
//! * [`bench`] — workload generators, statistics and the per-figure harness.
//!
//! ## Quickstart
//!
//! ```no_run
//! # fn demo() -> wtf::Result<()> {
//! use wtf::cluster::Cluster;
//!
//! let cluster = Cluster::builder().storage_servers(4).build()?;
//! let client = cluster.client();
//! let mut fd = client.create("/hello")?;
//! client.write(&mut fd, b"Hello World")?;
//! let back = client.read_at(&fd, 0, 11)?;
//! assert_eq!(back, b"Hello World");
//! # Ok(()) }
//! ```

pub mod baseline;
pub mod bench;
pub mod client;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod deploy;
pub mod error;
pub mod mapreduce;
pub mod meta;
pub mod metrics;
pub mod net;
pub mod runtime;
pub mod sim;
pub mod storage;
pub mod types;
pub mod util;

pub use client::WtfClient;
pub use cluster::Cluster;
pub use config::Config;
pub use error::{Error, Result};
pub use types::{InodeId, RegionId, ServerId, SlicePtr};
