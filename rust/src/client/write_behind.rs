//! Opt-in client write-behind (`Config::write_behind`), after CannyFS
//! (arXiv 1612.06830): batch workloads tolerate assume-success writes
//! as long as failures reconcile at well-defined boundaries.
//!
//! `append_bytes` / `append_slice` / `write_at` enqueue to a background
//! flusher and return immediately with the offset the write is ASSUMED
//! to land at; the flusher performs the real storage uploads and
//! metadata commits off the caller's thread.  The contract:
//!
//! * **Visibility**: a reader (including this client) may observe the
//!   file WITHOUT queued writes until they flush; the returned offsets
//!   are only promises.  Write-behind is for single-writer batch
//!   pipelines, not shared mutable files.
//! * **Durability**: [`WtfClient::flush`] (and `close`, and a WTF
//!   transaction commit) blocks until the pipeline is empty and
//!   surfaces the FIRST deferred failure; after `Ok(())` every
//!   previously enqueued write is durably committed.
//! * **Fencing**: each file's queue captures the inode version at its
//!   first enqueue (the same single fetch that aims its appends —
//!   one aim fetch for K queued writes, not K).  If another writer
//!   moved the inode before the flush, the whole queue surfaces
//!   [`Error::TxnConflict`] at the boundary and the file's cached
//!   metadata is dropped, rather than landing writes against a file
//!   the caller never saw.

use super::{AppendAim, Slice, WtfClient};
use crate::error::{Error, Result};
use crate::types::{InodeId, Key, RegionId, Value};
use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};

/// One queued write operation.
pub(crate) enum QueuedWrite {
    /// EOF-relative byte append (aimed by the queue's shared aim).
    Append { data: Vec<u8> },
    /// EOF-relative zero-copy slice append.
    AppendSlice { slice: Slice },
    /// Explicit-offset write.
    WriteAt { offset: u64, data: Vec<u8> },
}

/// Per-file queue: ONE fresh inode fetch at first enqueue provides the
/// append aim, the version fence, and the assumed EOF for every write
/// queued behind it.
struct InodeQueue {
    aim: AppendAim,
    /// Inode version observed at the aim fetch — the flush fence.
    expected_version: u64,
    /// The EOF this client assumes after its queued writes.
    assumed_eof: u64,
    ops: Vec<QueuedWrite>,
}

#[derive(Default)]
struct WbState {
    queues: HashMap<InodeId, InodeQueue>,
    /// FIFO across files.
    order: Vec<InodeId>,
    /// Total queued (not yet taken) ops, for backpressure.
    queued_ops: usize,
    /// Ops the worker is currently flushing.
    inflight: usize,
    /// The file currently being flushed: enqueues to it wait, so a new
    /// queue never captures a version fence mid-flush (which would
    /// conflict against this client's own writes).
    inflight_inode: Option<InodeId>,
    /// First deferred failure since the last reconciliation.
    first_err: Option<Error>,
    worker_running: bool,
}

/// The shared write-behind pipeline (one per client family — clones of
/// a client share it, like the metadata cache).
pub(crate) struct WriteBehind {
    /// Pipeline bound (`Config::write_behind_max_ops`): enqueues block
    /// while this many writes are queued or in flight.
    max_ops: usize,
    state: Mutex<WbState>,
    /// Wakes the worker (new work) and enqueuers (room / fence clear).
    work: Condvar,
    /// Wakes [`WriteBehind::drain`] waiters when the pipeline empties.
    idle: Condvar,
}

impl WriteBehind {
    pub(crate) fn new(max_ops: usize) -> Self {
        WriteBehind {
            max_ops: max_ops.max(1),
            state: Mutex::new(WbState::default()),
            work: Condvar::new(),
            idle: Condvar::new(),
        }
    }

    pub(crate) fn enqueue_append(
        &self,
        client: &WtfClient,
        inode: InodeId,
        data: Vec<u8>,
    ) -> Result<u64> {
        self.enqueue(client, inode, QueuedWrite::Append { data })
    }

    pub(crate) fn enqueue_append_slice(
        &self,
        client: &WtfClient,
        inode: InodeId,
        slice: Slice,
    ) -> Result<u64> {
        self.enqueue(client, inode, QueuedWrite::AppendSlice { slice })
    }

    pub(crate) fn enqueue_write_at(
        &self,
        client: &WtfClient,
        inode: InodeId,
        offset: u64,
        data: Vec<u8>,
    ) -> Result<u64> {
        self.enqueue(client, inode, QueuedWrite::WriteAt { offset, data })
    }

    /// Queue `op`, creating the file's queue (one fresh fetch for aim +
    /// fence + assumed EOF) on first use.  Returns the assumed offset
    /// the op lands at (for appends: the assumed EOF before it).
    fn enqueue(&self, client: &WtfClient, inode: InodeId, op: QueuedWrite) -> Result<u64> {
        let mut st = self.state.lock().unwrap();
        loop {
            let full = st.queued_ops + st.inflight >= self.max_ops;
            let fenced = st.inflight_inode == Some(inode);
            if !full && !fenced {
                break;
            }
            st = self.work.wait(st).unwrap();
        }
        if !st.queues.contains_key(&inode) {
            // The single fetch that serves every write queued behind it
            // (the aim-hoist: K queued appends, one aim fetch).
            let (value, version) = client.meta_get(&Key::inode(inode))?;
            let i = match value {
                Some(Value::Inode(i)) => i,
                Some(_) => {
                    return Err(Error::CorruptMetadata(format!("inode {inode} wrong type")))
                }
                None => return Err(Error::NotFound(format!("inode {inode}"))),
            };
            st.queues.insert(
                inode,
                InodeQueue {
                    aim: AppendAim {
                        region_idx: i.highest_region,
                        replication: i.replication,
                    },
                    expected_version: version,
                    assumed_eof: i.len,
                    ops: Vec::new(),
                },
            );
            st.order.push(inode);
        }
        let q = st.queues.get_mut(&inode).unwrap();
        let at = match &op {
            QueuedWrite::Append { data } => {
                let at = q.assumed_eof;
                q.assumed_eof += data.len() as u64;
                at
            }
            QueuedWrite::AppendSlice { slice } => {
                let at = q.assumed_eof;
                q.assumed_eof += slice.len();
                at
            }
            QueuedWrite::WriteAt { offset, data } => {
                q.assumed_eof = q.assumed_eof.max(offset + data.len() as u64);
                *offset
            }
        };
        q.ops.push(op);
        st.queued_ops += 1;
        if !st.worker_running {
            st.worker_running = true;
            let me = client
                .write_behind
                .clone()
                .expect("enqueue implies write-behind enabled");
            let flusher = client.clone();
            std::thread::spawn(move || me.worker(flusher));
        }
        drop(st);
        self.work.notify_all();
        Ok(at)
    }

    /// Block until every queued write has flushed, then surface (and
    /// clear) the first deferred failure — THE reconciliation boundary
    /// (`flush()` / `close()` / transaction commit).
    pub(crate) fn drain(&self) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        while st.queued_ops > 0 || st.inflight > 0 {
            st = self.idle.wait(st).unwrap();
        }
        match st.first_err.take() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// The background flusher.  Detached and parked on the condvar when
    /// idle; it dies with the process (clients are deployment-scoped).
    fn worker(self: Arc<Self>, client: WtfClient) {
        loop {
            let (inode, queue) = {
                let mut st = self.state.lock().unwrap();
                loop {
                    if !st.order.is_empty() {
                        let inode = st.order.remove(0);
                        let q = st.queues.remove(&inode).expect("ordered queue exists");
                        st.queued_ops -= q.ops.len();
                        st.inflight = q.ops.len();
                        st.inflight_inode = Some(inode);
                        break (inode, q);
                    }
                    st = self.work.wait(st).unwrap();
                }
            };
            let flushed = Self::flush_queue(&client, inode, queue);
            let mut st = self.state.lock().unwrap();
            st.inflight = 0;
            st.inflight_inode = None;
            if let Err(e) = flushed {
                if st.first_err.is_none() {
                    st.first_err = Some(e);
                }
            }
            let empty = st.queued_ops == 0;
            drop(st);
            self.work.notify_all();
            if empty {
                self.idle.notify_all();
            }
        }
    }

    /// Flush one file's queue on the worker: check the version fence,
    /// then run each write through the DIRECT paths (the worker's
    /// client never re-enqueues) sharing the queue's single aim.
    fn flush_queue(client: &WtfClient, inode: InodeId, q: InodeQueue) -> Result<()> {
        let (_, version) = client.meta_get(&Key::inode(inode))?;
        // Every cached key this queue's writes could leave stale —
        // used both by the fence failure and by an indeterminate flush
        // failure below.
        let mut keys = vec![Key::inode(inode)];
        for op in &q.ops {
            match op {
                QueuedWrite::Append { .. } | QueuedWrite::AppendSlice { .. } => {
                    keys.push(Key::region(RegionId::new(inode, q.aim.region_idx)));
                }
                QueuedWrite::WriteAt { offset, data } => {
                    for (rid, _, _) in
                        client.split_range(inode, *offset, data.len() as u64)
                    {
                        keys.push(Key::region(rid));
                    }
                }
            }
        }
        if version != q.expected_version {
            // Another writer moved the file while the queue formed: the
            // deferred writes would land somewhere the caller never
            // intended.  Fail the whole queue and drop the file's
            // cached metadata so post-reconciliation reads refetch.
            client.metadata_cache().invalidate_keys(&keys);
            let k = Key::inode(inode);
            return Err(Error::TxnConflict {
                space: k.space,
                key: k.key.clone(),
            });
        }
        for op in q.ops {
            let landed = match op {
                QueuedWrite::Append { data } => {
                    client.append_bytes_aimed(inode, &data, q.aim).map(|_| ())
                }
                QueuedWrite::AppendSlice { slice } => {
                    client.append_slice_aimed(inode, &slice, q.aim).map(|_| ())
                }
                QueuedWrite::WriteAt { offset, data } => {
                    client.write_at_direct(inode, offset, &data)
                }
            };
            if let Err(e) = landed {
                // An INDETERMINATE failure (Timeout/NoQuorum/...) may
                // have landed the write anyway — the cached view of the
                // file is suspect either way, so drop it before the
                // boundary surfaces the error.  Determinate failures
                // changed nothing and keep the cache warm.
                if e.is_indeterminate() {
                    client.metadata_cache().invalidate_keys(&keys);
                }
                return Err(e);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::config::Config;

    fn wb_cluster() -> Cluster {
        let mut cfg = Config::test();
        cfg.write_behind = true;
        cfg.metadata_cache = true;
        Cluster::builder().config(cfg).build().unwrap()
    }

    #[test]
    fn flush_fence_surfaces_txn_conflict_and_drops_cached_keys() {
        let cl = wb_cluster();
        let c = cl.client();
        let fd = c.create("/wb").unwrap();

        // A queue exactly as enqueue would have built it: aim + fence
        // version captured from the file's CURRENT state.
        let (_, fence) = c.meta_get(&Key::inode(fd.inode())).unwrap();
        let aim = c.append_aim(fd.inode()).unwrap();
        let q = InodeQueue {
            aim,
            expected_version: fence,
            assumed_eof: 0,
            ops: vec![QueuedWrite::Append {
                data: b"deferred".to_vec(),
            }],
        };

        // Another writer moves the file before the flush runs (direct
        // path: the intruder is a synchronous client in this story).
        c.write_at_direct(fd.inode(), 0, b"intruder").unwrap();
        c.fetch_inode(fd.inode()).unwrap(); // warm the cache post-intrusion
        let inv_before = c.metadata_cache().invalidations();

        // The fence must fail the whole queue as a conflict and drop the
        // file's cached metadata — NOT land "deferred" against a file
        // the enqueuer never saw.
        let err = WriteBehind::flush_queue(&c, fd.inode(), q).unwrap_err();
        assert!(
            matches!(err, Error::TxnConflict { .. }),
            "fence failure must surface as TxnConflict, got {err}"
        );
        assert!(
            c.metadata_cache().invalidations() > inv_before,
            "fence failure must invalidate the file's cached keys"
        );
        assert_eq!(
            c.len(&c.open("/wb").unwrap()).unwrap(),
            8,
            "the fenced queue must not have written anything"
        );
    }

    #[test]
    fn drain_surfaces_the_first_deferred_failure_exactly_once() {
        let wb = WriteBehind::new(4);
        wb.state.lock().unwrap().first_err = Some(Error::TxnAborted {
            reason: "deferred by the flusher".into(),
        });
        assert!(
            matches!(wb.drain(), Err(Error::TxnAborted { .. })),
            "the boundary must report the hidden failure"
        );
        // Consumed: the NEXT boundary starts clean.
        wb.drain().unwrap();
    }

    #[test]
    fn pipeline_lands_appends_in_order_with_one_shared_aim() {
        let cl = wb_cluster();
        let c = cl.client();
        let fd = c.create("/pipe").unwrap();

        let mut expect = Vec::new();
        for i in 0..10u8 {
            let rec = [b'a' + i; 7];
            let at = c.append_bytes(&fd, &rec).unwrap();
            assert_eq!(at, u64::from(i) * 7, "assumed offset drifted");
            expect.extend_from_slice(&rec);
        }
        c.flush().unwrap();
        assert_eq!(c.read_at(&fd, 0, 70).unwrap(), expect);
        c.close(fd).unwrap();
    }
}
