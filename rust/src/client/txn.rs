//! WTF transactions and the retry layer (§2.6).
//!
//! A WTF transaction logs every application call with its arguments and
//! outcome.  All metadata mutations buffer into ONE underlying metadata
//! (HyperDex) transaction; data slices are written to the storage servers
//! eagerly — they are invisible until the metadata commits, and immutable
//! afterwards, so it is always safe to re-use them across retries.
//!
//! When the metadata transaction aborts on a conflict, the filesystem
//! state is unchanged, so the retry layer replays the op log in order
//! with the same arguments.  If every re-executed call completes with the
//! same application-visible outcome, the retry is invisible; if any
//! outcome differs (a read sees different slices, a create finds the name
//! taken), the transaction aborts to the application — the only aborts
//! WTF ever surfaces.  Crucially, outcomes are compared by *slice
//! pointer*, never by data bytes: a 100 MB write logs a few pointers
//! (§2.6's log-size optimization), and seek-to-EOF records no outcome at
//! all, which is what lets the paper's seek-and-append example commit
//! under concurrent appends.

use super::fs::{normalize, split_path};
use super::{SeekFrom, Slice, WtfClient};
use crate::error::{Error, Result};
use crate::meta::{MetaOp, MetaTxn};
use crate::types::{
    Inode, InodeId, Key, Placement, RegionEntry, RegionId, SliceData, Value,
};
use crate::util::unix_now;
use std::collections::HashMap;

/// A transaction-scoped file descriptor.
pub type TxnFd = usize;

/// One logged application call (arguments + recorded outcome).
#[derive(Clone, Debug)]
enum LoggedOp {
    Open { path: String, outcome: InodeId },
    Create { path: String, inode: InodeId },
    Seek { fd: TxnFd, from: SeekFrom },
    Write { fd: TxnFd, slice: Slice },
    Read { fd: TxnFd, len: u64, outcome: Vec<(u64, SliceData)> },
    Yank { fd: TxnFd, sz: u64, outcome: Vec<(u64, SliceData)> },
    Paste { fd: TxnFd, slice: Slice },
    Punch { fd: TxnFd, amount: u64 },
}

/// Mutable execution state, rebuilt from scratch on every replay.
struct TxnState {
    meta: MetaTxn,
    /// Read-your-writes overlay: entries this transaction appended.
    pending_regions: HashMap<RegionId, Vec<RegionEntry>>,
    /// Inode overlay (length updates, creations).
    pending_inodes: HashMap<InodeId, Inode>,
    /// Paths created by this transaction (open-after-create support).
    pending_paths: HashMap<String, InodeId>,
    fds: Vec<FdState>,
}

impl TxnState {
    fn fresh(client: &WtfClient) -> Self {
        TxnState {
            meta: client.meta_txn(),
            pending_regions: HashMap::new(),
            pending_inodes: HashMap::new(),
            pending_paths: HashMap::new(),
            fds: Vec::new(),
        }
    }
}

#[derive(Clone, Debug)]
struct FdState {
    inode: InodeId,
    offset: u64,
}

/// An in-flight WTF transaction.  Obtain via [`WtfClient::begin`]; all
/// calls go through this handle and commit atomically.
pub struct Transaction<'c> {
    client: &'c WtfClient,
    state: TxnState,
    log: Vec<LoggedOp>,
}

impl<'c> Transaction<'c> {
    pub(crate) fn new(client: &'c WtfClient) -> Self {
        Transaction {
            client,
            state: TxnState::fresh(client),
            log: Vec::new(),
        }
    }

    // ------------------------------------------------------------ public API

    /// Open an existing file within the transaction.
    pub fn open(&mut self, path: &str) -> Result<TxnFd> {
        let path = normalize(path)?;
        let inode = Self::exec_open(self.client, &mut self.state, &path)?;
        self.log.push(LoggedOp::Open {
            path,
            outcome: inode,
        });
        Ok(self.state.fds.len() - 1)
    }

    /// Create a file within the transaction (visible to others only at
    /// commit).
    pub fn create(&mut self, path: &str) -> Result<TxnFd> {
        let path = normalize(path)?;
        let inode = self.client.meta.alloc_inode_id();
        Self::exec_create(self.client, &mut self.state, &path, inode)?;
        self.log.push(LoggedOp::Create { path, inode });
        Ok(self.state.fds.len() - 1)
    }

    /// Move a cursor.  Deliberately returns no offset: the application
    /// never observes where `SeekFrom::End` landed, so concurrent length
    /// changes replay instead of aborting (§2.6's seek-and-write example).
    pub fn seek(&mut self, fd: TxnFd, from: SeekFrom) -> Result<()> {
        Self::exec_seek(self.client, &mut self.state, fd, from)?;
        self.log.push(LoggedOp::Seek { fd, from });
        Ok(())
    }

    /// Write at the cursor.  The data's slices are created on the storage
    /// servers now; only their pointers live in the transaction.
    pub fn write(&mut self, fd: TxnFd, data: &[u8]) -> Result<()> {
        let fds = &self.state.fds;
        let fd_state = fds.get(fd).ok_or_else(bad_fd)?.clone();
        let inode = fd_state.inode;
        let replication = self
            .state
            .pending_inodes
            .get(&inode)
            .map(|i| i.replication)
            .unwrap_or(self.client.config.replication);
        // Slice creation is offset-independent: split by region size only
        // for placement locality, using the *current* cursor as the hint.
        // Every replica of every part uploads in one transport scatter —
        // nothing is visible until commit, so full concurrency is safe.
        let mut payloads: Vec<(RegionId, std::sync::Arc<[u8]>)> = Vec::new();
        let mut cursor_off = fd_state.offset;
        let mut consumed = 0usize;
        while consumed < data.len() {
            let (idx, rel) = self.client.config.locate(cursor_off);
            let take = ((self.client.config.region_size - rel) as usize)
                .min(data.len() - consumed);
            payloads.push((
                RegionId::new(inode, idx),
                std::sync::Arc::from(&data[consumed..consumed + take]),
            ));
            consumed += take;
            cursor_off += take as u64;
        }
        let replica_sets = self.client.create_replicated_parts(&payloads, replication)?;
        let pieces = payloads
            .iter()
            .zip(replica_sets)
            .map(|((_, chunk), replicas)| (chunk.len() as u64, SliceData::Stored(replicas)))
            .collect();
        let slice = Slice { pieces };
        Self::exec_paste(self.client, &mut self.state, fd, &slice)?;
        self.log.push(LoggedOp::Write { fd, slice });
        Ok(())
    }

    /// Read at the cursor.  The outcome (the resolved slice pointers) is
    /// logged; a replay that resolves different pointers aborts.
    pub fn read(&mut self, fd: TxnFd, len: u64) -> Result<Vec<u8>> {
        let (pieces, data) =
            Self::exec_read(self.client, &mut self.state, fd, len, true)?;
        self.log.push(LoggedOp::Read {
            fd,
            len,
            outcome: pieces,
        });
        Ok(data)
    }

    /// Yank at the cursor: like read, but returns pointers, not bytes.
    pub fn yank(&mut self, fd: TxnFd, sz: u64) -> Result<Slice> {
        let (pieces, _) = Self::exec_read(self.client, &mut self.state, fd, sz, false)?;
        self.log.push(LoggedOp::Yank {
            fd,
            sz,
            outcome: pieces.clone(),
        });
        Ok(Slice { pieces })
    }

    /// Paste a slice at the cursor (metadata only).
    pub fn paste(&mut self, fd: TxnFd, slice: &Slice) -> Result<()> {
        Self::exec_paste(self.client, &mut self.state, fd, slice)?;
        self.log.push(LoggedOp::Paste {
            fd,
            slice: slice.clone(),
        });
        Ok(())
    }

    /// Punch a hole at the cursor.
    pub fn punch(&mut self, fd: TxnFd, amount: u64) -> Result<()> {
        Self::exec_punch(self.client, &mut self.state, fd, amount)?;
        self.log.push(LoggedOp::Punch { fd, amount });
        Ok(())
    }

    /// File length as observed inside the transaction.  NOTE: exposing
    /// the length makes it part of the application-visible state, but it
    /// is *not* logged as an outcome — WTF's contract is that only
    /// returned data/pointers are compared on replay.
    pub fn len(&mut self, fd: TxnFd) -> Result<u64> {
        let inode = self.state.fds.get(fd).ok_or_else(bad_fd)?.inode;
        Self::file_len(self.client, &mut self.state, inode)
    }

    /// Abort the transaction: nothing was published; eagerly-created
    /// slices become garbage for the next GC scan.
    pub fn abort(self) {}

    /// Commit.  Retries transparently on metadata conflicts by replaying
    /// the op log (§2.6); aborts to the application only when a replayed
    /// call's outcome diverges.
    ///
    /// Under `Config::rpc_deadline` the replay ladder is additionally
    /// wall-clock bounded: past the deadline the commit surfaces
    /// [`Error::Timeout`] — indeterminate only if the underlying failure
    /// was (a conflict is a clean abort; the deadline merely stops the
    /// healing).  `Config::retry_backoff` spaces the replays with
    /// jittered exponential backoff.  Both default OFF.
    pub fn commit(mut self) -> Result<()> {
        // Write-behind reconciliation boundary: a WTF transaction must
        // not commit over writes the background flusher hasn't landed
        // (or silently swallowed a failure for).
        self.client.flush()?;
        let budget = self.client.config.txn_retry_budget.max(1);
        let deadline = self.client.config.rpc_deadline;
        let started = std::time::Instant::now();
        let mut attempts = 0u32;
        loop {
            let state = std::mem::replace(&mut self.state, TxnState::fresh(self.client));
            match self.client.commit_txn(state.meta) {
                Ok(_) => return Ok(()),
                // `NotLeader` is a clean abort (the replicated store
                // proposes nothing before it has leaders): rediscover
                // the shard leader, then replay like any conflict.
                // Cache invalidation for both cases already happened
                // inside commit_txn (whole-cache drop on NotLeader,
                // stale-key drop on conflict); only heal/replay control
                // flow lives here.
                Err(e) if e.is_retryable() || matches!(e, Error::NotLeader { .. }) => {
                    if let Error::NotLeader { shard, .. } = e {
                        self.client.meta.heal(shard);
                    }
                    attempts += 1;
                    self.client.metrics.add_txn_retries(1);
                    if attempts >= budget {
                        return Err(Error::RetriesExhausted { attempts });
                    }
                    if !deadline.is_zero() && started.elapsed() >= deadline {
                        return Err(Error::Timeout {
                            op: "txn.commit",
                            elapsed: started.elapsed(),
                        });
                    }
                    let pause = crate::util::backoff_jitter(
                        self.client.config.retry_backoff,
                        attempts,
                    );
                    if !pause.is_zero() {
                        std::thread::sleep(pause);
                    }
                    // Replay the log against fresh state.
                    self.replay()?;
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Re-execute every logged call; abort on any outcome divergence.
    fn replay(&mut self) -> Result<()> {
        let client = self.client;
        for op in &self.log {
            match op {
                LoggedOp::Open { path, outcome } => {
                    let inode = Self::exec_open(client, &mut self.state, path)
                        .map_err(|e| diverged(format!("open({path}): {e}")))?;
                    if inode != *outcome {
                        return Err(diverged(format!(
                            "open({path}) resolved a different inode"
                        )));
                    }
                }
                LoggedOp::Create { path, inode } => {
                    Self::exec_create(client, &mut self.state, path, *inode)
                        .map_err(|e| diverged(format!("create({path}): {e}")))?;
                }
                LoggedOp::Seek { fd, from } => {
                    Self::exec_seek(client, &mut self.state, *fd, *from)
                        .map_err(|e| diverged(format!("seek: {e}")))?;
                }
                LoggedOp::Write { fd, slice } => {
                    // Re-paste the previously-created slices at the (new)
                    // cursor — no data is rewritten.
                    Self::exec_paste(client, &mut self.state, *fd, slice)
                        .map_err(|e| diverged(format!("write: {e}")))?;
                }
                LoggedOp::Read { fd, len, outcome } => {
                    let (pieces, _) =
                        Self::exec_read(client, &mut self.state, *fd, *len, false)
                            .map_err(|e| diverged(format!("read: {e}")))?;
                    if &pieces != outcome {
                        return Err(diverged(
                            "read observed different contents".to_string(),
                        ));
                    }
                }
                LoggedOp::Yank { fd, sz, outcome } => {
                    let (pieces, _) =
                        Self::exec_read(client, &mut self.state, *fd, *sz, false)
                            .map_err(|e| diverged(format!("yank: {e}")))?;
                    if &pieces != outcome {
                        return Err(diverged(
                            "yank observed different contents".to_string(),
                        ));
                    }
                }
                LoggedOp::Paste { fd, slice } => {
                    Self::exec_paste(client, &mut self.state, *fd, slice)
                        .map_err(|e| diverged(format!("paste: {e}")))?;
                }
                LoggedOp::Punch { fd, amount } => {
                    Self::exec_punch(client, &mut self.state, *fd, *amount)
                        .map_err(|e| diverged(format!("punch: {e}")))?;
                }
            }
        }
        Ok(())
    }

    // --------------------------------------------------------- executors
    // (associated functions so replay can call them without aliasing)

    fn exec_open(client: &WtfClient, state: &mut TxnState, path: &str) -> Result<InodeId> {
        let _ = client;
        // Paths created earlier in this transaction shadow the store.
        let inode = if let Some(id) = state.pending_paths.get(path) {
            *id
        } else {
            match state.meta.get(&Key::path(path))? {
                Some(Value::PathEntry(id)) => id,
                Some(_) => return Err(Error::CorruptMetadata(path.into())),
                None => return Err(Error::NotFound(path.into())),
            }
        };
        state.fds.push(FdState { inode, offset: 0 });
        Ok(inode)
    }

    fn exec_create(
        client: &WtfClient,
        state: &mut TxnState,
        path: &str,
        inode_id: InodeId,
    ) -> Result<()> {
        let (parent, name) = split_path(path)?;
        let parent_id = match state.meta.get(&Key::path(&parent))? {
            Some(Value::PathEntry(p)) => p,
            _ => return Err(Error::NotFound(parent)),
        };
        if state.pending_paths.contains_key(path)
            || state.meta.get(&Key::path(path))?.is_some()
        {
            return Err(Error::AlreadyExists(path.into()));
        }
        let inode = Inode::new_file(inode_id, 0o644, client.config.replication);
        state.meta.push(MetaOp::PathInsert {
            key: Key::path(path),
            inode: inode_id,
            expect_absent: true,
        });
        state.meta.push(MetaOp::Put {
            key: Key::inode(inode_id),
            value: Value::Inode(inode.clone()),
        });
        state.meta.push(MetaOp::DirInsert {
            key: Key::dir(parent_id),
            name,
            inode: inode_id,
            expect_absent: true,
        });
        state.pending_inodes.insert(inode_id, inode);
        state.pending_paths.insert(path.to_string(), inode_id);
        state.fds.push(FdState {
            inode: inode_id,
            offset: 0,
        });
        Ok(())
    }

    fn file_len(client: &WtfClient, state: &mut TxnState, inode: InodeId) -> Result<u64> {
        if let Some(i) = state.pending_inodes.get(&inode) {
            return Ok(i.len);
        }
        // Committed inode enters the read set: a concurrent length change
        // conflicts the metadata txn and triggers a replay.
        let mut i = match state.meta.get(&Key::inode(inode))? {
            Some(Value::Inode(i)) => i,
            _ => return Err(Error::NotFound(format!("inode {inode}"))),
        };
        // Overlay any pending appends (they only ever grow the file).
        for (rid, entries) in &state.pending_regions {
            if rid.inode != inode {
                continue;
            }
            let base = u64::from(rid.index) * client.config.region_size;
            for e in entries {
                if let Placement::At(at) = e.placement {
                    i.len = i.len.max(base + at + e.len);
                }
            }
        }
        state.pending_inodes.insert(inode, i.clone());
        Ok(i.len)
    }

    fn exec_seek(
        client: &WtfClient,
        state: &mut TxnState,
        fd: TxnFd,
        from: SeekFrom,
    ) -> Result<()> {
        let inode = state.fds.get(fd).ok_or_else(bad_fd)?.inode;
        let cur = state.fds[fd].offset;
        let new = match from {
            SeekFrom::Start(o) => o as i128,
            SeekFrom::Current(d) => cur as i128 + d as i128,
            SeekFrom::End(d) => Self::file_len(client, state, inode)? as i128 + d as i128,
        };
        if new < 0 {
            return Err(Error::InvalidArgument("seek before start".into()));
        }
        state.fds[fd].offset = new as u64;
        Ok(())
    }

    /// Region view inside the transaction: committed entries (read set)
    /// plus this transaction's pending appends.
    fn region_view(
        client: &WtfClient,
        state: &mut TxnState,
        rid: RegionId,
    ) -> Result<Vec<RegionEntry>> {
        let committed = match state.meta.get(&Key::region(rid))? {
            Some(Value::Region(r)) => client.region_entries(&r)?,
            Some(_) => return Err(Error::CorruptMetadata(format!("region {rid:?}"))),
            None => Vec::new(),
        };
        let mut all = committed;
        if let Some(pending) = state.pending_regions.get(&rid) {
            all.extend(pending.iter().cloned());
        }
        Ok(all)
    }

    fn exec_read(
        client: &WtfClient,
        state: &mut TxnState,
        fd: TxnFd,
        len: u64,
        fetch: bool,
    ) -> Result<(Vec<(u64, SliceData)>, Vec<u8>)> {
        let FdState { inode, offset } = state.fds.get(fd).ok_or_else(bad_fd)?.clone();
        let file_len = Self::file_len(client, state, inode)?;
        let len = if offset >= file_len {
            0
        } else {
            len.min(file_len - offset)
        };
        let mut pieces: Vec<(u64, SliceData)> = Vec::new();
        for (rid, rel, part_len) in client.split_range(inode, offset, len) {
            let entries = Self::region_view(client, state, rid)?;
            let extents = super::compact::resolve_entries(&entries);
            let window = super::compact::clip_extents(&extents, rel, rel + part_len);
            let mut cursor = rel;
            for e in window {
                if e.start > cursor {
                    pieces.push((e.start - cursor, SliceData::Hole));
                }
                pieces.push((e.len, e.data.clone()));
                cursor = e.end();
            }
            if cursor < rel + part_len {
                pieces.push((rel + part_len - cursor, SliceData::Hole));
            }
        }
        let mut data = Vec::new();
        if fetch {
            // One scatter for every stored piece (cross-server reads
            // pipeline through the transport).
            data = vec![0u8; len as usize];
            let mut dsts = Vec::new();
            let mut sets = Vec::new();
            let mut at = 0usize;
            for (plen, src) in &pieces {
                if let SliceData::Stored(replicas) = src {
                    dsts.push(at);
                    sets.push(replicas.clone());
                }
                at += *plen as usize;
            }
            for (dst, bytes) in dsts
                .into_iter()
                .zip(client.fetch_replicated_scatter(sets)?)
            {
                data[dst..dst + bytes.len()].copy_from_slice(&bytes);
            }
        }
        state.fds[fd].offset += len;
        Ok((pieces, data))
    }

    fn exec_paste(
        client: &WtfClient,
        state: &mut TxnState,
        fd: TxnFd,
        slice: &Slice,
    ) -> Result<()> {
        let FdState { inode, offset } = state.fds.get(fd).ok_or_else(bad_fd)?.clone();
        let mut cursor = offset;
        let mut highest = 0u32;
        for (len, data) in &slice.pieces {
            let mut remaining = *len;
            let mut piece_off = 0u64;
            while remaining > 0 {
                let (idx, rel) = client.config.locate(cursor);
                let take = (client.config.region_size - rel).min(remaining);
                let rid = RegionId::new(inode, idx);
                highest = highest.max(idx);
                let entry = RegionEntry {
                    placement: Placement::At(rel),
                    len: take,
                    data: data.slice(piece_off, piece_off + take),
                };
                state.meta.push(MetaOp::RegionAppend {
                    key: Key::region(rid),
                    entry: entry.clone(),
                });
                state.pending_regions.entry(rid).or_default().push(entry);
                cursor += take;
                piece_off += take;
                remaining -= take;
            }
        }
        let end = offset + slice.len();
        state.meta.push(MetaOp::InodeSetLenMax {
            key: Key::inode(inode),
            candidate: end,
            highest_region: highest,
            mtime: unix_now(),
        });
        if let Some(i) = state.pending_inodes.get_mut(&inode) {
            i.len = i.len.max(end);
            i.highest_region = i.highest_region.max(highest);
        }
        state.fds[fd].offset = end;
        Ok(())
    }

    fn exec_punch(
        client: &WtfClient,
        state: &mut TxnState,
        fd: TxnFd,
        amount: u64,
    ) -> Result<()> {
        let FdState { inode, offset } = state.fds.get(fd).ok_or_else(bad_fd)?.clone();
        let file_len = Self::file_len(client, state, inode)?;
        let in_file = amount.min(file_len.saturating_sub(offset));
        if in_file > 0 {
            let hole = Slice {
                pieces: vec![(in_file, SliceData::Hole)],
            };
            Self::exec_paste(client, state, fd, &hole)?;
        }
        state.fds[fd].offset = offset + amount;
        Ok(())
    }
}

fn bad_fd() -> Error {
    Error::InvalidArgument("bad transaction fd".into())
}

fn diverged(reason: String) -> Error {
    Error::TxnAborted { reason }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::testutil::small_cluster;

    #[test]
    fn transactional_write_is_atomic_and_isolated() {
        let cluster = small_cluster();
        let c = cluster.client();
        let mut t = c.begin();
        let fd = t.create("/t").unwrap();
        t.write(fd, b"atomic").unwrap();
        // Not visible before commit.
        assert!(!c.exists("/t"));
        t.commit().unwrap();
        let f = c.open("/t").unwrap();
        assert_eq!(c.read_at(&f, 0, 6).unwrap(), b"atomic");
    }

    #[test]
    fn abort_publishes_nothing() {
        let cluster = small_cluster();
        let c = cluster.client();
        let mut t = c.begin();
        let fd = t.create("/gone").unwrap();
        t.write(fd, b"data").unwrap();
        t.abort();
        assert!(!c.exists("/gone"));
    }

    #[test]
    fn read_your_writes_within_txn() {
        let cluster = small_cluster();
        let c = cluster.client();
        let mut f = c.create("/ryw").unwrap();
        c.write(&mut f, b"base").unwrap();
        let mut t = c.begin();
        let fd = t.open("/ryw").unwrap();
        t.seek(fd, SeekFrom::End(0)).unwrap();
        t.write(fd, b"+txn").unwrap();
        t.seek(fd, SeekFrom::Start(0)).unwrap();
        assert_eq!(t.read(fd, 8).unwrap(), b"base+txn");
        t.commit().unwrap();
        assert_eq!(c.read_at(&f, 0, 8).unwrap(), b"base+txn");
    }

    #[test]
    fn multi_file_transaction_commits_atomically() {
        let cluster = small_cluster();
        let c = cluster.client();
        let mut a = c.create("/a").unwrap();
        c.write(&mut a, b"AA").unwrap();
        let mut t = c.begin();
        let fa = t.open("/a").unwrap();
        let fb = t.create("/b").unwrap();
        let got = t.read(fa, 2).unwrap();
        t.write(fb, &got).unwrap();
        t.seek(fa, SeekFrom::End(0)).unwrap();
        t.write(fa, b"!").unwrap();
        t.commit().unwrap();
        assert_eq!(c.read_at(&c.open("/a").unwrap(), 0, 3).unwrap(), b"AA!");
        assert_eq!(c.read_at(&c.open("/b").unwrap(), 0, 2).unwrap(), b"AA");
    }

    #[test]
    fn seek_end_write_replays_instead_of_aborting() {
        // The paper's "Hello World" example: a concurrent append changes
        // the EOF between our seek and commit; the transaction must
        // replay and land the write at the NEW end, not abort.
        let cluster = small_cluster();
        let c = cluster.client();
        let mut f = c.create("/hello").unwrap();
        c.write(&mut f, b"0123").unwrap();

        let mut t = c.begin();
        let fd = t.open("/hello").unwrap();
        t.seek(fd, SeekFrom::End(0)).unwrap();
        t.write(fd, b"Hello World").unwrap();

        // Concurrent writer extends the file before we commit.
        c.append_bytes(&f, b"XYZ").unwrap();

        t.commit().unwrap();
        let f = c.open("/hello").unwrap();
        let len = c.len(&f).unwrap();
        assert_eq!(len, 4 + 3 + 11);
        assert_eq!(c.read_at(&f, 7, 11).unwrap(), b"Hello World");
        // And the retry counter moved.
        assert!(c.metrics().txn_retries() >= 1);
    }

    #[test]
    fn conflicting_read_aborts_to_application() {
        // If the transaction READ data that then changed, replay observes
        // a different outcome and must abort.
        let cluster = small_cluster();
        let c = cluster.client();
        let mut f = c.create("/contested").unwrap();
        c.write(&mut f, b"old!").unwrap();

        let mut t = c.begin();
        let fd = t.open("/contested").unwrap();
        let data = t.read(fd, 4).unwrap();
        assert_eq!(data, b"old!");
        t.write(fd, &data).unwrap(); // echo what we read

        // Concurrent writer overwrites what the transaction read.
        c.write_at(f.inode, 0, b"new!").unwrap();

        let err = t.commit().unwrap_err();
        assert!(matches!(err, Error::TxnAborted { .. }), "{err}");
    }

    #[test]
    fn create_conflict_aborts_on_replay() {
        let cluster = small_cluster();
        let c = cluster.client();
        let mut seed = c.create("/seed").unwrap();
        c.write(&mut seed, b"s").unwrap();

        let mut t = c.begin();
        // Force a read set entry so the concurrent write conflicts.
        let fs = t.open("/seed").unwrap();
        let _ = t.read(fs, 1).unwrap();
        let fd = t.create("/race").unwrap();
        t.write(fd, b"mine").unwrap();

        // Another client wins the name AND invalidates the read.
        c.create("/race").unwrap();
        c.write_at(seed.inode, 0, b"S").unwrap();

        let err = t.commit().unwrap_err();
        assert!(matches!(err, Error::TxnAborted { .. }), "{err}");
    }

    #[test]
    fn yank_paste_transactionally_rearranges() {
        let cluster = small_cluster();
        let c = cluster.client();
        let mut f = c.create("/recs").unwrap();
        c.write(&mut f, b"111222333").unwrap();
        let mut t = c.begin();
        let src = t.open("/recs").unwrap();
        let out = t.create("/sorted").unwrap();
        t.seek(src, SeekFrom::Start(6)).unwrap();
        let three = t.yank(src, 3).unwrap();
        t.seek(src, SeekFrom::Start(0)).unwrap();
        let one = t.yank(src, 3).unwrap();
        t.paste(out, &three).unwrap();
        t.paste(out, &one).unwrap();
        t.commit().unwrap();
        let out = c.open("/sorted").unwrap();
        assert_eq!(c.read_at(&out, 0, 6).unwrap(), b"333111");
    }

    #[test]
    fn punch_inside_txn() {
        let cluster = small_cluster();
        let c = cluster.client();
        let mut f = c.create("/pt").unwrap();
        c.write(&mut f, &vec![1u8; 20]).unwrap();
        let mut t = c.begin();
        let fd = t.open("/pt").unwrap();
        t.seek(fd, SeekFrom::Start(5)).unwrap();
        t.punch(fd, 10).unwrap();
        t.commit().unwrap();
        let back = c.read_at(&f, 0, 20).unwrap();
        assert_eq!(&back[..5], &[1u8; 5][..]);
        assert_eq!(&back[5..15], &[0u8; 10][..]);
        assert_eq!(&back[15..], &[1u8; 5][..]);
    }

    #[test]
    fn replayed_write_reuses_slices() {
        let cluster = small_cluster();
        let c = cluster.client();
        let mut f = c.create("/reuse").unwrap();
        c.write(&mut f, b"abc").unwrap();

        let mut t = c.begin();
        let fd = t.open("/reuse").unwrap();
        t.seek(fd, SeekFrom::End(0)).unwrap();
        t.write(fd, b"PAYLOAD").unwrap();
        let written_after_log = cluster.storage_bytes_written();

        // Trigger a conflict -> replay.
        c.append_bytes(&f, b"z").unwrap();
        let concurrent = 1 * c.config().replication as u64;
        t.commit().unwrap();
        // Replay did NOT rewrite PAYLOAD to the storage servers.
        assert_eq!(
            cluster.storage_bytes_written(),
            written_after_log + concurrent
        );
    }
}
