//! The read-side fetch planner (`Config::read_coalescing`).
//!
//! A gather-read resolves to a list of stored extents, each with a
//! replica list.  The seed path shipped one `RetrieveSlice` envelope per
//! extent; this planner instead:
//!
//! 1. **Dedupes** identical replica lists — a slice pasted into a file
//!    twice (the §4.1 sort's shuffled records constantly alias input
//!    slices) is fetched once and copied to every destination;
//! 2. **Groups** the unique extents by primary storage server and ships
//!    ONE [`Request::RetrieveMany`] envelope per server — scatter across
//!    servers, coalesce within a server;
//! 3. **Fails over per extent**: an unreachable server or a rejected
//!    pointer defers only the affected extents to their remaining
//!    replicas (§2.9: any replica serves), never the whole batch.
//!
//! Results come back in input order; bytes, failover semantics, and
//! error surface are identical to the per-extent path — only the
//! envelope count changes.

use super::WtfClient;
use crate::error::{Error, Result};
use crate::net::{Peer, Request, Response};
use crate::types::{ServerId, SlicePtr};
use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;

impl WtfClient {
    /// Coalesced scatter-fetch: one `RetrieveMany` envelope per primary
    /// server, per-extent replica failover, results in input order.
    pub(crate) fn fetch_coalesced(&self, sets: Vec<Vec<SlicePtr>>) -> Result<Vec<Vec<u8>>> {
        // 1. Dedupe identical replica lists.
        let mut index_of: HashMap<&[SlicePtr], usize> = HashMap::new();
        let mut unique: Vec<usize> = Vec::new(); // representative input index
        let mut route: Vec<usize> = Vec::with_capacity(sets.len());
        for (i, set) in sets.iter().enumerate() {
            if set.is_empty() {
                return Err(Error::InvalidArgument("no replicas".into()));
            }
            let next = unique.len();
            let u = *index_of.entry(set.as_slice()).or_insert_with(|| {
                unique.push(i);
                next
            });
            route.push(u);
        }

        // 2. Group unique extents by primary server (BTreeMap for a
        //    deterministic envelope order).
        let mut by_server: BTreeMap<ServerId, Vec<usize>> = BTreeMap::new();
        for (u, &i) in unique.iter().enumerate() {
            by_server.entry(sets[i][0].server).or_default().push(u);
        }

        // 3. One envelope per reachable server; a dead server defers its
        //    whole group to per-extent failover.  Each deferred extent
        //    carries the error its primary actually produced, so the
        //    surface matches the per-extent path when all replicas fail.
        let slice_not_found = |ptr: &SlicePtr| Error::SliceNotFound {
            server: ptr.server,
            backing: ptr.backing,
            offset: ptr.offset,
            len: ptr.len,
        };
        let mut batch: Vec<(Peer, Request)> = Vec::new();
        let mut members: Vec<Vec<usize>> = Vec::new();
        let mut deferred: Vec<(usize, Error)> = Vec::new();
        for (server, us) in by_server {
            match self.storage_peer(server) {
                Ok(peer) => {
                    let ptrs: Arc<[SlicePtr]> =
                        us.iter().map(|&u| sets[unique[u]][0]).collect();
                    batch.push((peer, Request::RetrieveMany { ptrs }));
                    members.push(us);
                }
                Err(_) => {
                    deferred
                        .extend(us.into_iter().map(|u| (u, Error::ServerUnavailable(server))));
                }
            }
        }
        let mut fetched: Vec<Option<Vec<u8>>> = vec![None; unique.len()];
        for (resp, us) in self.transport.broadcast(batch).into_iter().zip(members) {
            match resp.and_then(Response::into_bytes_many) {
                Ok(mut items) => {
                    for (slot, &u) in us.iter().enumerate() {
                        match items.get_mut(slot).and_then(Option::take) {
                            Some(b) => fetched[u] = Some(b),
                            // The server answered but rejected this
                            // pointer — the same failure retrieve_slice
                            // reports on the per-extent path.
                            None => deferred.push((u, slice_not_found(&sets[unique[u]][0]))),
                        }
                    }
                }
                // Envelope-level failure (server died mid-request):
                // every member fails over individually.
                Err(_) => {
                    let server = sets[unique[us[0]]][0].server;
                    deferred
                        .extend(us.into_iter().map(|u| (u, Error::ServerUnavailable(server))));
                }
            }
        }

        // 4. Per-extent failover across the remaining replicas (the
        //    ladder shared with the legacy scatter path).
        for (u, primary_err) in deferred {
            let bytes = self.fail_over_replicas(&sets[unique[u]], primary_err)?;
            fetched[u] = Some(bytes);
        }

        // 5. Deliver in input order.  Metrics count wire bytes, so a
        //    deduped slice is charged once however many destinations
        //    copy it; each buffer is MOVED to its last destination and
        //    cloned only for genuine duplicates.
        for b in fetched.iter().flatten() {
            self.metrics.add_bytes_read(b.len() as u64);
        }
        let mut refs = vec![0usize; unique.len()];
        for &u in &route {
            refs[u] += 1;
        }
        let mut out = Vec::with_capacity(route.len());
        for u in route {
            refs[u] -= 1;
            let b = if refs[u] == 0 {
                fetched[u].take()
            } else {
                fetched[u].clone()
            };
            out.push(b.expect("every unique extent resolved"));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use crate::client::WtfClient;
    use crate::cluster::Cluster;
    use crate::config::Config;
    use crate::storage::StorageCluster;
    use crate::types::SliceData;
    use crate::util::Rng;
    use std::sync::Arc;

    fn fast_cluster() -> Cluster {
        Cluster::builder()
            .config(Config::fast_read_test())
            .build()
            .unwrap()
    }

    #[test]
    fn coalesced_fetch_matches_per_extent_fetch() {
        let cluster = fast_cluster();
        let c = cluster.client();
        let mut fd = c.create("/f").unwrap();
        let mut data = vec![0u8; 10_000];
        Rng::new(21).fill_bytes(&mut data);
        c.write(&mut fd, &data).unwrap();
        // Same bytes whether or not the envelopes coalesce.
        assert_eq!(c.read_at(&fd, 0, 10_000).unwrap(), data);
        assert_eq!(c.read_at(&fd, 3_000, 4_000).unwrap(), &data[3_000..7_000]);
    }

    #[test]
    fn duplicate_slices_are_fetched_once() {
        let cluster = fast_cluster();
        let c = cluster.client();
        let mut src = c.create("/src").unwrap();
        c.write(&mut src, &[7u8; 512]).unwrap();
        // Paste the same slice four times: the destination is four
        // aliases of one stored extent.
        let slice = c.yank_at(src.inode(), 0, 512).unwrap();
        let mut dst = c.create("/dst").unwrap();
        for _ in 0..4 {
            c.paste(&mut dst, &slice).unwrap();
        }
        let read_before = cluster.storage_bytes_read();
        let back = c.read_at(&dst, 0, 4 * 512).unwrap();
        assert!(back.iter().all(|&b| b == 7));
        // The storage layer served the aliased extent ONCE, not four
        // times (dedup), so it read 512 bytes, not 2048.
        assert_eq!(cluster.storage_bytes_read() - read_before, 512);
    }

    #[test]
    fn coalesced_fetch_fails_over_per_extent() {
        let cluster = Cluster::builder()
            .config(Config::fast_read_test())
            .storage_servers(4)
            .replication(2)
            .build()
            .unwrap();
        let c = cluster.client();
        let mut fd = c.create("/dur").unwrap();
        let mut data = vec![0u8; 9_000];
        Rng::new(33).fill_bytes(&mut data);
        c.write(&mut fd, &data).unwrap();
        // Find a primary server actually referenced by the file, then
        // read through a degraded view without it: every extent whose
        // primary died must fail over to its second replica.
        let (region, _) = c
            .fetch_region_public(crate::types::RegionId::new(fd.inode(), 0))
            .unwrap();
        let primary = match &region.entries[0].data {
            SliceData::Stored(v) => v[0].server,
            _ => panic!("expected stored entry"),
        };
        let survivors: Vec<_> = cluster
            .storage()
            .iter()
            .filter(|s| s.id() != primary)
            .cloned()
            .collect();
        let degraded = Arc::new(StorageCluster::new(survivors));
        let c2 = WtfClient::new(
            cluster.config().clone(),
            cluster.meta().clone(),
            degraded,
            cluster.client().ring().clone(),
        );
        let fd2 = c2.open("/dur").unwrap();
        assert_eq!(c2.read_at(&fd2, 0, 9_000).unwrap(), data);
    }
}
