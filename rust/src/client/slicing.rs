//! The file slicing API (§2.5, Table 1): yank, paste, punch, append,
//! concat, copy.
//!
//! These calls manipulate subsequences of files *at the structural
//! level*: yank returns slice pointers, paste/append publish those
//! pointers into another file's metadata, and none of them move a single
//! data byte — the entire cost is borne by the metadata store.  This is
//! what lets the §4.1 sort application shuffle 100 GB with zero write
//! I/O.

use super::compact::clip_extents;
use super::fs::normalize;
use super::{FileHandle, Slice, WtfClient};
use crate::error::{Error, Result};
use crate::meta::{MetaOp, MetaTxn};
use crate::types::{InodeId, Key, Placement, RegionEntry, RegionId, SliceData, Value};
use crate::util::unix_now;

impl WtfClient {
    // ---------------------------------------------------------------- yank

    /// Copy `sz` bytes from the cursor as slice pointers, advancing the
    /// cursor.  No data is read; pass the result to [`Self::paste`] /
    /// [`Self::append_slice`] to write it elsewhere for free.
    pub fn yank(&self, fd: &mut FileHandle, sz: u64) -> Result<Slice> {
        let s = self.yank_at(fd.inode, fd.offset, sz)?;
        fd.offset += s.len();
        Ok(s)
    }

    /// Yank an explicit range (clamped to EOF).  Rides the same
    /// extent-window walk as the read path (`resolve_window`): the tiles
    /// exactly cover the range, with gaps and unwritten tails as holes,
    /// so the slice's length is exact.
    pub fn yank_at(&self, inode: InodeId, offset: u64, sz: u64) -> Result<Slice> {
        let file_len = self.fetch_inode(inode)?.len;
        if offset >= file_len {
            return Ok(Slice::default());
        }
        let sz = sz.min(file_len - offset);
        let tiles = self.resolve_window(inode, offset, sz)?;
        Ok(Slice {
            pieces: tiles.into_iter().map(|e| (e.len, e.data)).collect(),
        })
    }

    /// Yank and also fetch the underlying bytes (`yank` returns "slice
    /// pointers and optionally the data", Table 1).  ONE window resolve
    /// feeds both the slice and the data fetch — the pointers and bytes
    /// come from the same snapshot, and the metadata is walked once.
    pub fn yank_with_data(&self, fd: &mut FileHandle, sz: u64) -> Result<(Slice, Vec<u8>)> {
        let offset = fd.offset;
        let file_len = self.fetch_inode(fd.inode)?.len;
        if offset >= file_len {
            return Ok((Slice::default(), Vec::new()));
        }
        let sz = sz.min(file_len - offset);
        let tiles = self.resolve_window(fd.inode, offset, sz)?;
        let data = self.fetch_window(&tiles, offset, sz)?;
        let pieces = tiles.into_iter().map(|e| (e.len, e.data)).collect();
        fd.offset += sz;
        Ok((Slice { pieces }, data))
    }

    // --------------------------------------------------------------- paste

    /// Write `slice` at the cursor and advance it.  Bypasses the storage
    /// servers entirely: one blind metadata transaction.
    pub fn paste(&self, fd: &mut FileHandle, slice: &Slice) -> Result<()> {
        self.paste_at(fd.inode, fd.offset, slice)?;
        fd.offset += slice.len();
        Ok(())
    }

    /// Paste at an explicit offset.
    pub fn paste_at(&self, inode: InodeId, offset: u64, slice: &Slice) -> Result<()> {
        if slice.is_empty() {
            return Ok(());
        }
        self.with_retry("slicing.paste", || {
            let mut t = self.meta_txn();
            let highest = self.push_paste_ops(&mut t, inode, offset, slice);
            t.push(MetaOp::InodeSetLenMax {
                key: Key::inode(inode),
                candidate: offset + slice.len(),
                highest_region: highest,
                mtime: unix_now(),
            });
            self.commit_txn(t)?;
            Ok(())
        })
    }

    /// Queue the region-append ops for pasting `slice` at `offset`;
    /// returns the highest region index touched.  Shared with the
    /// transaction layer.
    pub(crate) fn push_paste_ops(
        &self,
        t: &mut MetaTxn,
        inode: InodeId,
        offset: u64,
        slice: &Slice,
    ) -> u32 {
        let mut highest = 0u32;
        let mut cursor = offset;
        for (len, data) in &slice.pieces {
            let mut remaining = *len;
            let mut piece_off = 0u64;
            while remaining > 0 {
                let (idx, rel) = self.config.locate(cursor);
                let take = (self.config.region_size - rel).min(remaining);
                let rid = RegionId::new(inode, idx);
                highest = highest.max(idx);
                t.push(MetaOp::RegionAppend {
                    key: Key::region(rid),
                    entry: RegionEntry {
                        placement: Placement::At(rel),
                        len: take,
                        data: data.slice(piece_off, piece_off + take),
                    },
                });
                cursor += take;
                piece_off += take;
                remaining -= take;
            }
        }
        highest
    }

    // --------------------------------------------------------------- punch

    /// Zero out `amount` bytes at the cursor, freeing the underlying
    /// storage (the old slices become garbage for the next GC scan), and
    /// advance the cursor.
    pub fn punch(&self, fd: &mut FileHandle, amount: u64) -> Result<()> {
        // A punch never extends the file; clamp to EOF.
        let file_len = self.fetch_inode(fd.inode)?.len;
        let amount_in_file = amount.min(file_len.saturating_sub(fd.offset));
        if amount_in_file > 0 {
            let hole = Slice {
                pieces: vec![(amount_in_file, SliceData::Hole)],
            };
            self.with_retry("slicing.punch", || {
                let mut t = self.meta_txn();
                self.push_paste_ops(&mut t, fd.inode, fd.offset, &hole);
                self.commit_txn(t)?;
                Ok(())
            })?;
        }
        fd.offset += amount;
        Ok(())
    }

    // -------------------------------------------------------------- append

    /// Append `slice` at the end of file via the conditional EOF-relative
    /// fast path (§2.5) — like [`Self::append_bytes`] but with zero
    /// storage I/O.
    pub fn append_slice(&self, fd: &FileHandle, slice: &Slice) -> Result<u64> {
        if slice.is_empty() {
            return self.len(fd);
        }
        if let Some(wb) = &self.write_behind {
            return wb.enqueue_append_slice(self, fd.inode, slice.clone());
        }
        // Fresh fetch for the same reason as `append_bytes`: a stale
        // `highest_region` must not aim the append into the interior.
        let aim = self.append_aim(fd.inode)?;
        self.append_slice_aimed(fd.inode, slice, aim)
    }

    /// The aimed body of [`Self::append_slice`] — shared with the
    /// write-behind flusher, which aims once per queued-file batch.
    pub(crate) fn append_slice_aimed(
        &self,
        inode: InodeId,
        slice: &Slice,
        aim: super::AppendAim,
    ) -> Result<u64> {
        let region_idx = aim.region_idx;
        loop {
            let rid = RegionId::new(inode, region_idx);
            let region_base = u64::from(region_idx) * self.config.region_size;
            let mut t = self.meta_txn();
            // All pieces go in one transaction: the append is atomic.
            for (len, data) in &slice.pieces {
                t.push(MetaOp::RegionAppendEof {
                    key: Key::region(rid),
                    data: data.clone(),
                    len: *len,
                    cap: self.config.region_size,
                });
            }
            t.push(MetaOp::InodeSetLenMax {
                key: Key::inode(inode),
                candidate: 0,
                highest_region: region_idx,
                mtime: unix_now(),
            });
            t.push(MetaOp::InodeSetLenFromRegion {
                inode_key: Key::inode(inode),
                region_key: Key::region(rid),
                region_base,
                mtime: unix_now(),
            });
            match self.commit_txn(t) {
                Ok(outcomes) => {
                    let at = outcomes
                        .iter()
                        .find_map(|o| match o {
                            crate::meta::OpOutcome::AppendedAt(a) => Some(*a),
                            _ => None,
                        })
                        .unwrap_or(0);
                    return Ok(region_base + at);
                }
                Err(Error::CondAppendFailed { .. }) => {
                    // Region full: §2.5 fallback — read the EOF inside a
                    // validated transaction and paste at that offset,
                    // filling the current region's remainder.
                    return self.append_at_eof_validated(inode, slice);
                }
                Err(Error::NotLeader { shard, .. }) => {
                    // Same as `append_bytes`: commit_txn dropped the
                    // cache; rediscover the leader and replay.
                    self.metrics.add_txn_retries(1);
                    self.meta.heal(shard);
                    continue;
                }
                Err(e) if e.is_retryable() => {
                    self.metrics.add_txn_retries(1);
                    continue;
                }
                Err(e) => return Err(e),
            }
        }
    }

    // -------------------------------------------------------------- concat

    /// Concatenate `sources` into a new file `dest` — pure metadata, in
    /// ONE transaction: if any source changes concurrently, the concat
    /// retries against the new state (§2.5, Table 1).
    pub fn concat(&self, sources: &[&str], dest: &str) -> Result<FileHandle> {
        let dest = normalize(dest)?;
        let (parent, name) = super::fs::split_path(&dest)?;
        let id = self.meta.alloc_inode_id();
        self.with_retry("slicing.concat", || {
            let mut t = self.meta_txn();
            let parent_id = match t.get(&Key::path(&parent))? {
                Some(Value::PathEntry(p)) => p,
                _ => return Err(Error::NotFound(parent.clone())),
            };
            // Snapshot-read every source through the transaction (its
            // regions enter the read set: concurrent modification aborts
            // and retries the whole concat).
            let mut pieces: Vec<(u64, SliceData)> = Vec::new();
            for src in sources {
                let src = normalize(src)?;
                let src_id = match t.get(&Key::path(&src))? {
                    Some(Value::PathEntry(p)) => p,
                    _ => return Err(Error::NotFound(src.clone())),
                };
                let src_inode = match t.get(&Key::inode(src_id))? {
                    Some(Value::Inode(i)) => i,
                    _ => return Err(Error::CorruptMetadata(src.clone())),
                };
                let mut remaining = src_inode.len;
                let mut region_idx = 0u32;
                while remaining > 0 {
                    let rid = RegionId::new(src_id, region_idx);
                    let region = match t.get(&Key::region(rid))? {
                        Some(Value::Region(r)) => r,
                        _ => Default::default(),
                    };
                    let extents = self.resolve_region(&region)?;
                    let part = remaining.min(self.config.region_size);
                    let window = clip_extents(&extents, 0, part);
                    let mut cursor = 0u64;
                    for e in window {
                        if e.start > cursor {
                            pieces.push((e.start - cursor, SliceData::Hole));
                        }
                        pieces.push((e.len, e.data.clone()));
                        cursor = e.end();
                    }
                    if cursor < part {
                        pieces.push((part - cursor, SliceData::Hole));
                    }
                    remaining -= part;
                    region_idx += 1;
                }
            }
            let slice = Slice { pieces };
            // Create dest and paste the combined slice, all in this txn.
            t.push(MetaOp::PathInsert {
                key: Key::path(&dest),
                inode: id,
                expect_absent: true,
            });
            let mut inode = crate::types::Inode::new_file(id, 0o644, self.config.replication);
            inode.len = slice.len();
            let highest = self.push_paste_ops(&mut t, id, 0, &slice);
            inode.highest_region = highest;
            inode.mtime = unix_now();
            t.push(MetaOp::Put {
                key: Key::inode(id),
                value: Value::Inode(inode),
            });
            t.push(MetaOp::DirInsert {
                key: Key::dir(parent_id),
                name: name.clone(),
                inode: id,
                expect_absent: true,
            });
            self.commit_txn(t)?;
            Ok(())
        })?;
        Ok(FileHandle {
            inode: id,
            path: dest,
            offset: 0,
        })
    }

    /// Copy `source` to `dest` using only the metadata (Table 1).
    pub fn copy(&self, source: &str, dest: &str) -> Result<FileHandle> {
        self.concat(&[source], dest)
    }
}

#[cfg(test)]
mod tests {
    use crate::client::testutil::small_cluster;
    use crate::util::Rng;

    #[test]
    fn yank_paste_round_trip_moves_no_data() {
        let cluster = small_cluster();
        let c = cluster.client();
        let mut src = c.create("/src").unwrap();
        let mut data = vec![0u8; 1000];
        Rng::new(2).fill_bytes(&mut data);
        c.write(&mut src, &data).unwrap();

        let written_before = cluster.storage_bytes_written();
        let mut src = c.open("/src").unwrap();
        let slice = c.yank(&mut src, 1000).unwrap();
        assert_eq!(slice.len(), 1000);
        let mut dst = c.create("/dst").unwrap();
        c.paste(&mut dst, &slice).unwrap();
        // ZERO bytes hit the storage servers.
        assert_eq!(cluster.storage_bytes_written(), written_before);
        assert_eq!(c.read_at(&dst, 0, 1000).unwrap(), data);
    }

    #[test]
    fn yank_subrange_and_rearrange() {
        let cluster = small_cluster();
        let c = cluster.client();
        let mut f = c.create("/f").unwrap();
        c.write(&mut f, b"AAAABBBBCCCC").unwrap();
        // Reverse the three 4-byte records using yank/paste only.
        let a = c.yank_at(f.inode, 0, 4).unwrap();
        let b = c.yank_at(f.inode, 4, 4).unwrap();
        let cc = c.yank_at(f.inode, 8, 4).unwrap();
        let mut out = c.create("/out").unwrap();
        c.paste(&mut out, &cc).unwrap();
        c.paste(&mut out, &b).unwrap();
        c.paste(&mut out, &a).unwrap();
        assert_eq!(c.read_at(&out, 0, 12).unwrap(), b"CCCCBBBBAAAA");
    }

    #[test]
    fn punch_zeroes_and_frees() {
        let cluster = small_cluster();
        let c = cluster.client();
        let mut f = c.create("/p").unwrap();
        c.write(&mut f, &vec![9u8; 100]).unwrap();
        c.seek(&mut f, crate::client::SeekFrom::Start(10)).unwrap();
        c.punch(&mut f, 30).unwrap();
        assert_eq!(f.offset, 40);
        let back = c.read_at(&f, 0, 100).unwrap();
        assert_eq!(&back[..10], &vec![9u8; 10][..]);
        assert_eq!(&back[10..40], &vec![0u8; 30][..]);
        assert_eq!(&back[40..], &vec![9u8; 60][..]);
        // Length unchanged.
        assert_eq!(c.len(&f).unwrap(), 100);
    }

    #[test]
    fn append_slice_is_metadata_only() {
        let cluster = small_cluster();
        let c = cluster.client();
        let mut src = c.create("/src").unwrap();
        c.write(&mut src, b"0123456789").unwrap();
        let dst = c.create("/dst").unwrap();
        let written_before = cluster.storage_bytes_written();
        let s1 = c.yank_at(src.inode, 0, 5).unwrap();
        let s2 = c.yank_at(src.inode, 5, 5).unwrap();
        assert_eq!(c.append_slice(&dst, &s2).unwrap(), 0);
        assert_eq!(c.append_slice(&dst, &s1).unwrap(), 5);
        assert_eq!(cluster.storage_bytes_written(), written_before);
        assert_eq!(c.read_at(&dst, 0, 10).unwrap(), b"5678901234");
    }

    #[test]
    fn concat_without_reading() {
        let cluster = small_cluster();
        let c = cluster.client();
        for (i, content) in [b"aaa".as_ref(), b"bb", b"cccc"].iter().enumerate() {
            let mut f = c.create(&format!("/part{i}")).unwrap();
            c.write(&mut f, content).unwrap();
        }
        let read_before = cluster.storage_bytes_read();
        let written_before = cluster.storage_bytes_written();
        let out = c.concat(&["/part0", "/part1", "/part2"], "/all").unwrap();
        assert_eq!(cluster.storage_bytes_read(), read_before);
        assert_eq!(cluster.storage_bytes_written(), written_before);
        assert_eq!(c.len(&out).unwrap(), 9);
        assert_eq!(c.read_at(&out, 0, 9).unwrap(), b"aaabbcccc");
    }

    #[test]
    fn concat_multi_region_sources() {
        let cluster = small_cluster();
        let c = cluster.client();
        let rs = c.config().region_size;
        let mut data = vec![0u8; (2 * rs + 17) as usize];
        Rng::new(5).fill_bytes(&mut data);
        let mut a = c.create("/a").unwrap();
        c.write(&mut a, &data).unwrap();
        let mut b = c.create("/b").unwrap();
        c.write(&mut b, b"tail").unwrap();
        let out = c.concat(&["/a", "/b"], "/joined").unwrap();
        let total = data.len() as u64 + 4;
        assert_eq!(c.len(&out).unwrap(), total);
        let back = c.read_at(&out, 0, total).unwrap();
        assert_eq!(&back[..data.len()], &data[..]);
        assert_eq!(&back[data.len()..], b"tail");
    }

    #[test]
    fn copy_shares_slices() {
        let cluster = small_cluster();
        let c = cluster.client();
        let mut f = c.create("/orig").unwrap();
        c.write(&mut f, b"copy me").unwrap();
        let written_before = cluster.storage_bytes_written();
        let copy = c.copy("/orig", "/copy").unwrap();
        assert_eq!(cluster.storage_bytes_written(), written_before);
        assert_eq!(c.read_at(&copy, 0, 7).unwrap(), b"copy me");
        // Mutating the copy must not disturb the original (immutability:
        // the copy's new write overlays new slices).
        c.write_at(copy.inode, 0, b"COPY").unwrap();
        assert_eq!(c.read_at(&copy, 0, 7).unwrap(), b"COPY me");
        let orig = c.open("/orig").unwrap();
        assert_eq!(c.read_at(&orig, 0, 7).unwrap(), b"copy me");
    }

    #[test]
    fn yank_of_sparse_range_preserves_holes() {
        let cluster = small_cluster();
        let c = cluster.client();
        let f = c.create("/sp").unwrap();
        c.write_at(f.inode, 50, b"xx").unwrap();
        let s = c.yank_at(f.inode, 0, 52).unwrap();
        assert_eq!(s.len(), 52);
        assert!(s.pieces[0].1.is_hole());
        let mut out = c.create("/sp2").unwrap();
        c.paste(&mut out, &s).unwrap();
        let back = c.read_at(&out, 0, 52).unwrap();
        assert_eq!(&back[..50], &vec![0u8; 50][..]);
        assert_eq!(&back[50..], b"xx");
    }
}
