//! The WTF client library — where metadata and data combine into a
//! coherent filesystem (§2, Fig. 1).
//!
//! The client owns most of the system's logic: it routes slice writes via
//! the placement ring, assembles file contents from region metadata,
//! implements the POSIX-style API ([`fs`]), the file-slicing API
//! ([`slicing`]: yank/paste/punch/append/concat/copy), the WTF
//! transaction with its conflict-replay retry layer ([`txn`], §2.6), and
//! metadata compaction/spilling ([`compact`], [`spill`], §2.8).

pub mod cache;
pub mod compact;
pub mod fetch;
pub mod maintenance;
pub mod fs;
pub mod slicing;
pub mod spill;
pub mod txn;
pub(crate) mod write_behind;

pub use cache::MetaCache;
pub use compact::Extent;
pub use txn::Transaction;

use crate::config::Config;
use crate::error::{Error, Result};
use crate::meta::{MetaService, MetaTxn};
use crate::metrics::Metrics;
use crate::net::{Peer, Request, Transport};
use crate::storage::{Ring, StorageCluster};
use crate::types::{
    Inode, InodeId, Key, RegionId, RegionMeta, ServerId, SliceData, SlicePtr, Value,
};
use std::sync::Arc;

/// An open file: inode + cursor.  Handles are plain values; sharing one
/// between threads is the application's business, exactly as with POSIX
/// file descriptors.
#[derive(Clone, Debug)]
pub struct FileHandle {
    pub(crate) inode: InodeId,
    pub(crate) path: String,
    /// Cursor for read/write/seek.
    pub offset: u64,
}

impl FileHandle {
    pub fn inode(&self) -> InodeId {
        self.inode
    }
    pub fn path(&self) -> &str {
        &self.path
    }
}

/// Cursor positioning for [`fs`] seek (mirrors `std::io::SeekFrom`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SeekFrom {
    Start(u64),
    End(i64),
    Current(i64),
}

/// The app-visible result of `yank`: an ordered list of byte sources that
/// can be pasted or appended elsewhere *without touching the data* (§2.5,
/// Table 1).  Pieces are `(len, source)`; `Hole` pieces read as zeros.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Slice {
    pub pieces: Vec<(u64, SliceData)>,
}

impl Slice {
    /// Total byte length.
    pub fn len(&self) -> u64 {
        self.pieces.iter().map(|(l, _)| l).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Number of distinct slice pointers (metadata cost of pasting this).
    pub fn fragmentation(&self) -> usize {
        self.pieces.len()
    }

    /// Concatenate two slices.
    pub fn extend(&mut self, other: &Slice) {
        self.pieces.extend(other.pieces.iter().cloned());
    }

    /// Arithmetic sub-slice `[from, to)` — no metadata or data access.
    /// This is how applications carve records out of a yanked range
    /// (e.g. the §4.1 sort rearranging records by permutation).
    pub fn sub(&self, from: u64, to: u64) -> Slice {
        assert!(from <= to && to <= self.len(), "sub-slice out of range");
        let mut pieces = Vec::new();
        let mut at = 0u64;
        for (len, data) in &self.pieces {
            let s = from.max(at);
            let e = to.min(at + len);
            if s < e {
                pieces.push((e - s, data.slice(s - at, e - at)));
            }
            at += len;
            if at >= to {
                break;
            }
        }
        Slice { pieces }
    }
}

/// The WTF client.
#[derive(Clone)]
pub struct WtfClient {
    pub(crate) config: Config,
    pub(crate) meta: Arc<MetaService>,
    pub(crate) storage: Arc<StorageCluster>,
    pub(crate) ring: Ring,
    pub(crate) metrics: Metrics,
    /// Every cross-component call goes through here: slice I/O scatters
    /// across replicas/regions, metadata txns travel as envelopes.
    pub(crate) transport: Arc<Transport>,
    /// The hot-read-path cache (`Config::metadata_cache` /
    /// `Config::readahead`) — inert unless enabled.  Shared by clones
    /// of this client, private to it otherwise.
    pub(crate) cache: Arc<MetaCache>,
    /// Opt-in write-behind pipeline (`Config::write_behind`): `None`
    /// unless enabled.  Shared by clones, like the cache, so the
    /// background flusher (itself a clone) feeds the same queues.
    pub(crate) write_behind: Option<Arc<write_behind::WriteBehind>>,
}

/// The EOF aim for an append: `highest_region` + replication captured
/// from a FRESH inode fetch (a stale aim lands bytes mid-file under the
/// sparse-file EOF rules).  Hoisted out of the append loops so a
/// write-behind flush of K queued appends pays ONE aim fetch, not K.
#[derive(Clone, Copy, Debug)]
pub(crate) struct AppendAim {
    pub(crate) region_idx: u32,
    pub(crate) replication: u8,
}

impl WtfClient {
    /// A client with its own instant-link transport (tests, tools).
    /// Deployments share one transport via [`Self::with_transport`].
    pub fn new(
        config: Config,
        meta: Arc<MetaService>,
        storage: Arc<StorageCluster>,
        ring: Ring,
    ) -> Self {
        let workers = config.transport_workers;
        Self::with_transport(
            config,
            meta,
            storage,
            ring,
            Arc::new(Transport::new(crate::net::LinkModel::instant(), workers)),
        )
    }

    /// A client bound to an existing deployment transport.
    pub fn with_transport(
        config: Config,
        meta: Arc<MetaService>,
        storage: Arc<StorageCluster>,
        ring: Ring,
        transport: Arc<Transport>,
    ) -> Self {
        let cache = Arc::new(MetaCache::new(&config));
        let wb = config
            .write_behind
            .then(|| Arc::new(write_behind::WriteBehind::new(config.write_behind_max_ops)));
        WtfClient {
            config,
            meta,
            storage,
            ring,
            metrics: Metrics::new(),
            transport,
            cache,
            write_behind: wb,
        }
    }

    /// Write-behind reconciliation boundary: block until every queued
    /// write has flushed and surface the first deferred failure.  A
    /// no-op `Ok(())` when write-behind is off (every write was
    /// already synchronous).
    pub fn flush(&self) -> Result<()> {
        match &self.write_behind {
            Some(wb) => wb.drain(),
            None => Ok(()),
        }
    }

    /// Close a handle.  Handles are plain values, so the only work is
    /// the write-behind contract: `close` is a reconciliation boundary
    /// and reports any failure the flusher deferred.
    pub fn close(&self, _fd: FileHandle) -> Result<()> {
        self.flush()
    }

    pub fn config(&self) -> &Config {
        &self.config
    }

    /// The transport this client scatters its I/O through.
    pub fn transport(&self) -> &Arc<Transport> {
        &self.transport
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// The client's read-path cache (observability/tests).
    pub fn metadata_cache(&self) -> &MetaCache {
        &self.cache
    }

    pub fn meta_service(&self) -> &Arc<MetaService> {
        &self.meta
    }

    /// The client's placement ring (observability/tests).
    pub fn ring(&self) -> &Ring {
        &self.ring
    }

    /// Begin a WTF transaction (§2.6): all operations performed through
    /// the returned handle commit atomically, with transparent retry on
    /// metadata conflicts.
    pub fn begin(&self) -> Transaction<'_> {
        Transaction::new(self)
    }

    // ------------------------------------------------------------------
    // Shared low-level plumbing used by fs/slicing/txn.
    // ------------------------------------------------------------------

    /// Retry `f` while it fails with a retryable metadata error (§2.6's
    /// guarantee for single-call operations: they never surface spurious
    /// aborts).  A replicated-metadata `NotLeader` is handled here too:
    /// the client rediscovers the shard leader (blocking through the
    /// election) and replays — leader failover must look like a
    /// transient conflict, not an application error.
    ///
    /// Two optional bounds harden the loop against a turbulent network
    /// (both default OFF, leaving the loop byte-identical to the
    /// historical one): `Config::rpc_deadline` caps the END-TO-END
    /// wall-clock of the whole retry ladder, surfacing
    /// [`Error::Timeout`] tagged with `op`; `Config::retry_backoff`
    /// inserts bounded exponential backoff with full jitter between
    /// attempts so retry storms decorrelate instead of hammering a
    /// healing shard in lockstep.
    pub(crate) fn with_retry<T>(
        &self,
        op: &'static str,
        mut f: impl FnMut() -> Result<T>,
    ) -> Result<T> {
        let budget = self.config.txn_retry_budget.max(1);
        let deadline = self.config.rpc_deadline;
        let started = std::time::Instant::now();
        let mut attempts = 0;
        loop {
            let outcome = f();
            // `Some(Some(shard))`: leaderless shard — heal, then retry.
            // `Some(None)`: plain retryable conflict.  `None`: done.
            // Commit-side cache invalidation (stale-key drop on a
            // conflict, whole-cache drop on NotLeader) already happened
            // inside commit_txn; this layer owns only heal/replay.
            let retry = match &outcome {
                Err(Error::NotLeader { shard, .. }) => Some(Some(*shard)),
                Err(e) if e.is_retryable() => Some(None),
                _ => None,
            };
            let Some(heal_shard) = retry else {
                return outcome;
            };
            attempts += 1;
            self.metrics.add_txn_retries(1);
            if attempts >= budget {
                return Err(Error::RetriesExhausted { attempts });
            }
            if !deadline.is_zero() && started.elapsed() >= deadline {
                // The operation itself did NOT commit (only retryable —
                // i.e. definitively-failed — outcomes reach here), but
                // callers must treat a deadline like any indeterminate
                // turbulence verdict, so it surfaces as Timeout rather
                // than the underlying conflict.
                return Err(Error::Timeout {
                    op,
                    elapsed: started.elapsed(),
                });
            }
            let pause = crate::util::backoff_jitter(self.config.retry_backoff, attempts);
            if !pause.is_zero() {
                std::thread::sleep(pause);
            }
            if let Some(shard) = heal_shard {
                // Leadership moved: every cached answer from the old
                // leader's tenure is suspect — drop the lot, then
                // rediscover (blocks until the old lease runs out and a
                // successor holds a quorum lease).
                self.cache.clear();
                self.meta.heal(shard);
            }
        }
    }

    /// Non-transactional versioned metadata read, as a transport
    /// envelope to the metadata service.  Rides the shared retry layer:
    /// a `NotLeader` answer heals the shard and replays; any other
    /// failure (e.g. `NoQuorum`) SURFACES — a read must never report a
    /// key absent just because its shard is unreadable.  Value and
    /// version come from one atomic view read (absent keys included).
    pub(crate) fn meta_get(&self, key: &Key) -> Result<(Option<Value>, u64)> {
        self.with_retry("meta_get", || {
            self.transport
                .call(
                    self.meta.clone(),
                    Request::MetaGet { key: key.clone() },
                )
                .and_then(crate::net::Response::into_meta_value)
        })
    }

    /// Direct (non-transactional) inode fetch, served from the read
    /// cache when enabled.  A fresh fetch records the inode at its
    /// authoritative version (and, via the cache's snapshot rule, drops
    /// the file's older cached regions).
    pub(crate) fn fetch_inode(&self, id: InodeId) -> Result<Arc<Inode>> {
        if let Some(i) = self.cache.get_inode(id) {
            return Ok(i);
        }
        self.fetch_inode_fresh(id)
    }

    /// Uncached inode fetch (it still refreshes the cache).  The append
    /// fast paths use this: an EOF-relative append aimed by a stale
    /// `highest_region` at an old, non-full region would land bytes in
    /// the file's interior instead of at EOF — so appends always aim
    /// with a fresh inode, exactly like the seed path.
    pub(crate) fn fetch_inode_fresh(&self, id: InodeId) -> Result<Arc<Inode>> {
        let as_of = self.cache.epoch();
        match self.meta_get(&Key::inode(id))? {
            (Some(Value::Inode(i)), version) => {
                let i = Arc::new(i);
                self.cache.put_inode(id, &i, version, as_of);
                Ok(i)
            }
            (Some(_), _) => Err(Error::CorruptMetadata(format!("inode {id} wrong type"))),
            (None, _) => Err(Error::NotFound(format!("inode {id}"))),
        }
    }

    /// Direct region fetch; absent regions read as empty.
    /// Public (observability/tests): a region's metadata + version.
    pub fn fetch_region_public(&self, rid: RegionId) -> Result<(RegionMeta, u64)> {
        let (region, version) = self.fetch_region(rid)?;
        Ok((region.as_ref().clone(), version))
    }

    /// Cached region fetch (the hot read path).  Absence is cached too —
    /// the version of absence is authoritative, same as a value's.
    /// `Arc`-shared: a warm hit never deep-clones the entry list.
    pub(crate) fn fetch_region(&self, rid: RegionId) -> Result<(Arc<RegionMeta>, u64)> {
        if let Some(hit) = self.cache.get_region(rid) {
            return Ok(hit);
        }
        let as_of = self.cache.epoch();
        let (region, version) = self.fetch_region_fresh(rid)?;
        let region = Arc::new(region);
        self.cache.put_region(rid, &region, version, as_of);
        Ok((region, version))
    }

    /// Uncached region fetch.  CAS maintenance (compact/spill) must see
    /// the authoritative version, or its `RegionSwap` could never
    /// succeed against a warm cache.
    pub(crate) fn fetch_region_fresh(&self, rid: RegionId) -> Result<(RegionMeta, u64)> {
        // Absent regions read as empty at the version the SAME view
        // read reported — no second version round-trip to race against
        // a concurrent commit.
        let (value, version) = self.meta_get(&Key::region(rid))?;
        match value {
            Some(Value::Region(r)) => Ok((r, version)),
            Some(_) => Err(Error::CorruptMetadata(format!(
                "region {rid:?} wrong type"
            ))),
            None => Ok((RegionMeta::default(), version)),
        }
    }

    /// Full entry list of a region including the spilled base (§2.8).
    pub(crate) fn region_entries(
        &self,
        region: &RegionMeta,
    ) -> Result<Vec<crate::types::RegionEntry>> {
        let mut entries = Vec::new();
        if let Some(replicas) = &region.spill {
            let bytes = self.fetch_replicated(replicas)?;
            entries.extend(spill::decode_entries(&bytes)?);
        }
        entries.extend(region.entries.iter().cloned());
        Ok(entries)
    }

    /// Resolve one region to disjoint extents, including spilled base.
    pub(crate) fn resolve_region(&self, region: &RegionMeta) -> Result<Vec<Extent>> {
        Ok(compact::resolve_entries(&self.region_entries(region)?))
    }

    /// THE extent-window walk shared by `read_inode_at` and `yank_at`:
    /// resolve `[offset, offset + len)` of a file into file-absolute
    /// tiles (stored extents and holes) that exactly cover the range,
    /// in order.  One region metadata round per region — zero with a
    /// warm cache.
    pub(crate) fn resolve_window(
        &self,
        inode: InodeId,
        offset: u64,
        len: u64,
    ) -> Result<Vec<Extent>> {
        let mut tiles = Vec::new();
        for (rid, rel, part_len) in self.split_range(inode, offset, len) {
            let (region, _) = self.fetch_region(rid)?;
            let extents = self.resolve_region(&region)?;
            let region_base = u64::from(rid.index) * self.config.region_size;
            for mut e in compact::tile_window(&extents, rel, rel + part_len) {
                e.start += region_base;
                tiles.push(e);
            }
        }
        Ok(tiles)
    }

    /// Fetch the stored tiles of a resolved window into a zero-filled
    /// buffer covering `[offset, offset + len)` (holes stay zero).
    pub(crate) fn fetch_window(
        &self,
        tiles: &[Extent],
        offset: u64,
        len: u64,
    ) -> Result<Vec<u8>> {
        let mut out = vec![0u8; len as usize];
        let mut dsts: Vec<usize> = Vec::new();
        let mut sets: Vec<Vec<SlicePtr>> = Vec::new();
        for e in tiles {
            if let SliceData::Stored(replicas) = &e.data {
                dsts.push((e.start - offset) as usize);
                sets.push(replicas.clone());
            }
        }
        for (dst, bytes) in dsts.into_iter().zip(self.fetch_replicated_scatter(sets)?) {
            out[dst..dst + bytes.len()].copy_from_slice(&bytes);
        }
        Ok(out)
    }

    /// Resolve a server id to a transport peer — in-process or (in the
    /// multi-process deployment) a registered socket peer.
    fn storage_peer(&self, id: ServerId) -> Result<Peer> {
        self.storage.peer(id)
    }

    /// Fetch bytes for a replicated slice, failing over across replicas
    /// (§2.9: readers may use any replica).
    pub(crate) fn fetch_replicated(&self, replicas: &[SlicePtr]) -> Result<Vec<u8>> {
        self.fetch_replicated_scatter(vec![replicas.to_vec()])?
            .pop()
            .ok_or_else(|| Error::InvalidArgument("no replicas".into()))
    }

    /// THE per-extent replica-failover ladder, shared by the coalesced
    /// planner and the legacy scatter path: after the primary failed
    /// with `last_err`, try the remaining replicas in order (§2.9: any
    /// replica serves); surface the most recent error when all fail.
    pub(crate) fn fail_over_replicas(
        &self,
        set: &[SlicePtr],
        mut last_err: Error,
    ) -> Result<Vec<u8>> {
        for ptr in set.iter().skip(1) {
            let attempt = self.storage_peer(ptr.server).and_then(|peer| {
                self.transport
                    .call(peer, Request::RetrieveSlice { ptr: *ptr })?
                    .into_bytes()
            });
            match attempt {
                Ok(b) => return Ok(b),
                Err(e) => last_err = e,
            }
        }
        Err(last_err)
    }

    /// Scatter-gather fetch: issue the primary replica of *every* slice
    /// concurrently through the transport (one wire time for the whole
    /// batch), then fail any stragglers over to their remaining replicas.
    /// Results come back in input order.  With `Config::read_coalescing`
    /// the same contract is served by the fetch planner instead: dedupe
    /// repeated pointers, one `RetrieveMany` envelope per server.
    pub(crate) fn fetch_replicated_scatter(
        &self,
        sets: Vec<Vec<SlicePtr>>,
    ) -> Result<Vec<Vec<u8>>> {
        if self.config.read_coalescing {
            return self.fetch_coalesced(sets);
        }
        // Scatter the primaries.  A dead primary server fails at peer
        // resolution, before anything is enqueued.
        let pending: Vec<Result<crate::net::Pending>> = sets
            .iter()
            .map(|set| {
                let first = set
                    .first()
                    .ok_or_else(|| Error::InvalidArgument("no replicas".into()))?;
                let peer = self.storage_peer(first.server)?;
                Ok(self
                    .transport
                    .send(peer, Request::RetrieveSlice { ptr: *first }))
            })
            .collect();
        // Gather; fail over sequentially on the (rare) failures.
        let mut out = Vec::with_capacity(sets.len());
        for (i, first_try) in pending.into_iter().enumerate() {
            let primary = first_try.and_then(|p| p.join()?.into_bytes());
            let bytes = match primary {
                Ok(b) => b,
                // Per-extent failover through the shared ladder (an
                // empty list has nothing to try and surfaces the
                // primary's error — no out-of-bounds slice).
                Err(last_err) => self.fail_over_replicas(&sets[i], last_err)?,
            };
            self.metrics.add_bytes_read(bytes.len() as u64);
            out.push(bytes);
        }
        Ok(out)
    }

    /// Create `replication` replicas of `data` for `region`, on distinct
    /// servers chosen by the placement ring (§2.7, §2.9), failing over to
    /// further ring successors when a server is down.  All replica
    /// uploads are issued concurrently through the transport.
    pub(crate) fn create_replicated(
        &self,
        data: &[u8],
        region: RegionId,
        replication: u8,
    ) -> Result<Vec<SlicePtr>> {
        self.create_replicated_parts(&[(region, Arc::from(data))], replication)?
            .pop()
            .ok_or_else(|| Error::InvalidArgument("no storage servers".into()))
    }

    /// Scatter-gather slice creation for a whole operation: every replica
    /// of every region part is uploaded in ONE transport broadcast (§2.1:
    /// slices are invisible until the metadata commit, so all uploads are
    /// safely concurrent — a replication-`r` write costs ~1 wire time,
    /// not `r`).  Per-part shortfalls fail over to further ring
    /// successors; degraded replication (fewer live servers than
    /// replicas) is allowed, as in the paper's failure model.
    pub(crate) fn create_replicated_parts(
        &self,
        parts: &[(RegionId, Arc<[u8]>)],
        replication: u8,
    ) -> Result<Vec<Vec<SlicePtr>>> {
        let want = replication.max(1) as usize;
        let fanout = self.ring.servers().len().min(want + 2);
        // Per-part candidate lists; the first `want` live candidates form
        // the scatter, the rest are failover spares.
        let mut candidates: Vec<Vec<ServerId>> = Vec::with_capacity(parts.len());
        for (region, _) in parts {
            candidates.push(self.ring.servers_for(*region, fanout));
        }
        let mut batch: Vec<(Peer, Request)> = Vec::new();
        let mut routes: Vec<usize> = Vec::new(); // batch index -> part index
        let mut next_candidate: Vec<usize> = vec![0; parts.len()];
        let mut last_err: Vec<Option<Error>> = Vec::with_capacity(parts.len());
        for (i, (region, data)) in parts.iter().enumerate() {
            let mut err = None;
            let mut enqueued = 0;
            while enqueued < want && next_candidate[i] < candidates[i].len() {
                let sid = candidates[i][next_candidate[i]];
                next_candidate[i] += 1;
                match self.storage_peer(sid) {
                    Ok(peer) => {
                        batch.push((
                            peer,
                            Request::CreateSlice {
                                hint: *region,
                                data: data.clone(),
                            },
                        ));
                        routes.push(i);
                        enqueued += 1;
                    }
                    Err(e) => err = Some(e),
                }
            }
            last_err.push(err);
        }
        let results = self.transport.broadcast(batch);

        let mut out: Vec<Vec<SlicePtr>> = vec![Vec::new(); parts.len()];
        for (slot, result) in routes.into_iter().zip(results) {
            match result.and_then(crate::net::Response::into_slice) {
                Ok(ptr) => {
                    self.metrics
                        .add_bytes_written(parts[slot].1.len() as u64);
                    out[slot].push(ptr);
                }
                Err(e) => last_err[slot] = Some(e),
            }
        }
        // Failover pass: top up parts that fell short, one spare at a
        // time (rare path, so sequential is fine).
        for i in 0..parts.len() {
            while out[i].len() < want && next_candidate[i] < candidates[i].len() {
                let sid = candidates[i][next_candidate[i]];
                next_candidate[i] += 1;
                let attempt = self.storage_peer(sid).and_then(|peer| {
                    self.transport
                        .call(
                            peer,
                            Request::CreateSlice {
                                hint: parts[i].0,
                                data: parts[i].1.clone(),
                            },
                        )?
                        .into_slice()
                });
                match attempt {
                    Ok(ptr) => {
                        self.metrics.add_bytes_written(parts[i].1.len() as u64);
                        out[i].push(ptr);
                    }
                    Err(e) => last_err[i] = Some(e),
                }
            }
            if out[i].is_empty() {
                return Err(last_err[i]
                    .take()
                    .unwrap_or_else(|| Error::InvalidArgument("no storage servers".into())));
            }
        }
        Ok(out)
    }

    /// Split a file-absolute byte range into per-region parts:
    /// `(region, region-relative offset, length)`.
    pub(crate) fn split_range(
        &self,
        inode: InodeId,
        offset: u64,
        len: u64,
    ) -> Vec<(RegionId, u64, u64)> {
        let mut parts = Vec::new();
        let mut off = offset;
        let end = offset + len;
        while off < end {
            let (idx, rel) = self.config.locate(off);
            let take = (self.config.region_size - rel).min(end - off);
            parts.push((RegionId::new(inode, idx), rel, take));
            off += take;
        }
        parts
    }

    /// A fresh metadata transaction builder, routed through the
    /// deployment transport and carrying this client's retry budget.
    /// Its internal NotLeader heals clear this client's read cache
    /// first — a heal the transaction performs on its own must honor
    /// the same invalidation trigger as every other heal path.
    ///
    /// With `Config::metadata_cache` on, the transaction also reads
    /// THROUGH the versioned cache (PR 9): warm inode/region/path keys
    /// cost zero envelopes and their cached versions enter the read
    /// set, so commit-time validation — not freshness at read time —
    /// guards serializability.
    pub(crate) fn meta_txn(&self) -> MetaTxn {
        let mut t = MetaTxn::with_transport(self.meta.clone(), self.transport.clone())
            .heal_budget(self.config.txn_retry_budget)
            .rpc_deadline(self.config.rpc_deadline)
            .retry_backoff(self.config.retry_backoff);
        if self.cache.is_active() {
            let cache = self.cache.clone();
            t = t.on_heal(Arc::new(move |_shard| cache.clear()));
            t = t.read_through(self.cache.clone());
        }
        t
    }

    /// Commit a metadata transaction; ALL commit-side cache
    /// invalidation lives here so every commit loop gets it for free:
    /// on success, drop every key the ops mutated (own-commit
    /// read-your-writes); on `NotLeader`, drop the whole cache (the
    /// caller will heal and retry); on `TxnConflict`, drop the named
    /// stale key before the caller's retry re-reads; on an
    /// INDETERMINATE failure ([`Error::is_indeterminate`]:
    /// `Timeout`/`NoQuorum`/`ReplicaLost`/`RetriesExhausted`
    /// mid-commit, or a 2PC left unresolved) the
    /// mutated keys are dropped too — the
    /// transaction may yet resolve to committed when the shard heals
    /// (an orphaned decision record can be adopted), and own-commit
    /// read-your-writes must hold even for that late resolution.
    /// Every client-side commit routes through here.
    pub(crate) fn commit_txn(&self, t: MetaTxn) -> Result<Vec<crate::meta::OpOutcome>> {
        let keys = if self.cache.is_active() {
            t.mutated_keys()
        } else {
            Vec::new()
        };
        let out = t.commit();
        match &out {
            Ok(_) => self.cache.invalidate_keys(&keys),
            Err(Error::NotLeader { .. }) => self.cache.clear(),
            Err(Error::TxnConflict { space, key }) => {
                // The named stale key must go; the mutated keys go too
                // so a replay whose reads overlapped its writes
                // (read-modify-write, the common shape) re-reads fresh
                // state instead of conflicting again off another warm
                // entry.
                self.cache.invalidate_key(&Key::new(*space, key.clone()));
                self.cache.invalidate_keys(&keys);
            }
            Err(e) if e.is_indeterminate() => self.cache.invalidate_keys(&keys),
            Err(_) => {}
        }
        out
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use crate::cluster::Cluster;
    use crate::config::Config;

    /// A small test cluster with tiny regions (multi-region paths get
    /// exercised with little data).
    pub fn small_cluster() -> Cluster {
        Cluster::builder()
            .config(Config::test())
            .build()
            .expect("test cluster")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slice_accounting() {
        let mut s = Slice::default();
        assert!(s.is_empty());
        s.pieces.push((10, SliceData::Hole));
        s.pieces.push((
            5,
            SliceData::Stored(vec![SlicePtr {
                server: 0,
                backing: 0,
                offset: 0,
                len: 5,
            }]),
        ));
        assert_eq!(s.len(), 15);
        assert_eq!(s.fragmentation(), 2);
        let t = s.clone();
        s.extend(&t);
        assert_eq!(s.len(), 30);
    }

    #[test]
    fn split_range_spans_regions() {
        let cluster = testutil::small_cluster();
        let client = cluster.client();
        let rs = client.config().region_size; // 4096 in test config
        let parts = client.split_range(7, rs - 10, 20);
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0], (RegionId::new(7, 0), rs - 10, 10));
        assert_eq!(parts[1], (RegionId::new(7, 1), 0, 10));
        let parts = client.split_range(7, 0, 3 * rs);
        assert_eq!(parts.len(), 3);
    }
}
