//! The POSIX-style filesystem API (§2.4): namespace operations via the
//! one-lookup pathname→inode map, plus read/write/seek over regions.
//!
//! Namespace changes (create, mkdir, link, unlink) are each one metadata
//! transaction that atomically updates the path map, the inode, and the
//! containing directory — the paper's hardlink example verbatim.
//!
//! Writes create slices on the storage servers *first*, then publish
//! them with blind region appends; any transaction that can observe the
//! metadata can already retrieve the immutable slices (§2.1).

use super::{FileHandle, SeekFrom, Slice, WtfClient};
use crate::error::{Error, Result};
use crate::meta::MetaOp;
use crate::types::{
    DirEntries, Inode, InodeId, Key, Placement, RegionEntry, RegionId, SliceData, Value,
};
use crate::util::unix_now;

/// Split an absolute path into `(parent, name)`.
pub(crate) fn split_path(path: &str) -> Result<(String, String)> {
    let path = normalize(path)?;
    if path == "/" {
        return Err(Error::InvalidArgument("cannot split root".into()));
    }
    let idx = path.rfind('/').unwrap();
    let parent = if idx == 0 { "/".to_string() } else { path[..idx].to_string() };
    Ok((parent, path[idx + 1..].to_string()))
}

/// Normalize an absolute path (no trailing slash except root, no empty
/// or dot components).
pub(crate) fn normalize(path: &str) -> Result<String> {
    if !path.starts_with('/') {
        return Err(Error::InvalidArgument(format!(
            "path must be absolute: {path}"
        )));
    }
    let mut out = String::from("/");
    for comp in path.split('/') {
        match comp {
            "" | "." => continue,
            ".." => {
                return Err(Error::InvalidArgument(format!(
                    "'..' not supported: {path}"
                )))
            }
            c => {
                if !out.ends_with('/') {
                    out.push('/');
                }
                out.push_str(c);
            }
        }
    }
    Ok(out)
}

impl WtfClient {
    // ------------------------------------------------------------ namespace

    /// Resolve a path to its inode id with ONE metadata lookup, no matter
    /// how deeply nested (§2.4).
    ///
    /// With the metadata cache enabled a warm lookup is ZERO lookups:
    /// path entries are cached versioned like inodes and regions, so
    /// repeated `open()`s of the same file stop paying the namespace
    /// round.  Absence is never cached — a racing `create` must become
    /// visible on the next plain lookup, not after a TTL.
    pub fn lookup(&self, path: &str) -> Result<InodeId> {
        let path = normalize(path)?;
        if let Some((id, _version)) = self.cache.get_path(&path) {
            return Ok(id);
        }
        let as_of = self.cache.epoch();
        let (value, version) = self.meta_get(&Key::path(&path))?;
        match value {
            Some(Value::PathEntry(id)) => {
                self.cache.put_path(&path, id, version, as_of);
                Ok(id)
            }
            Some(_) => Err(Error::CorruptMetadata(format!("path {path} wrong type"))),
            None => Err(Error::NotFound(path)),
        }
    }

    pub fn exists(&self, path: &str) -> bool {
        self.lookup(path).is_ok()
    }

    /// `stat`: the inode for a path.
    pub fn stat(&self, path: &str) -> Result<Inode> {
        Ok(self.fetch_inode(self.lookup(path)?)?.as_ref().clone())
    }

    /// Create a regular file.  One transaction: path-map insert (atomic
    /// create), inode put, directory-entry insert.
    pub fn create(&self, path: &str) -> Result<FileHandle> {
        self.create_with_replication(path, self.config.replication)
    }

    /// Create with an explicit replication factor (the sort benchmark
    /// writes intermediate files unreplicated, §4.1).
    pub fn create_with_replication(&self, path: &str, replication: u8) -> Result<FileHandle> {
        let path = normalize(path)?;
        let (parent, name) = split_path(&path)?;
        let id = self.meta.alloc_inode_id();
        self.with_retry("fs.create", || {
            let mut t = self.meta_txn();
            let parent_id = match t.get(&Key::path(&parent))? {
                Some(Value::PathEntry(p)) => p,
                _ => return Err(Error::NotFound(parent.clone())),
            };
            let parent_inode = match t.get(&Key::inode(parent_id))? {
                Some(Value::Inode(i)) => i,
                _ => return Err(Error::CorruptMetadata(parent.clone())),
            };
            if !parent_inode.is_dir() {
                return Err(Error::NotADirectory(parent.clone()));
            }
            t.push(MetaOp::PathInsert {
                key: Key::path(&path),
                inode: id,
                expect_absent: true,
            });
            t.push(MetaOp::Put {
                key: Key::inode(id),
                value: Value::Inode(Inode::new_file(id, 0o644, replication)),
            });
            t.push(MetaOp::DirInsert {
                key: Key::dir(parent_id),
                name: name.clone(),
                inode: id,
                expect_absent: true,
            });
            self.commit_txn(t)?;
            Ok(())
        })?;
        Ok(FileHandle {
            inode: id,
            path,
            offset: 0,
        })
    }

    /// Create a directory.
    pub fn mkdir(&self, path: &str) -> Result<()> {
        let path = normalize(path)?;
        let (parent, name) = split_path(&path)?;
        let id = self.meta.alloc_inode_id();
        self.with_retry("fs.mkdir", || {
            let mut t = self.meta_txn();
            let parent_id = match t.get(&Key::path(&parent))? {
                Some(Value::PathEntry(p)) => p,
                _ => return Err(Error::NotFound(parent.clone())),
            };
            t.push(MetaOp::PathInsert {
                key: Key::path(&path),
                inode: id,
                expect_absent: true,
            });
            t.push(MetaOp::Put {
                key: Key::inode(id),
                value: Value::Inode(Inode::new_directory(id, 0o755)),
            });
            t.push(MetaOp::Put {
                key: Key::dir(id),
                value: Value::Dir(DirEntries::new()),
            });
            t.push(MetaOp::DirInsert {
                key: Key::dir(parent_id),
                name: name.clone(),
                inode: id,
                expect_absent: true,
            });
            self.commit_txn(t)?;
            Ok(())
        })
    }

    /// Open an existing file.
    pub fn open(&self, path: &str) -> Result<FileHandle> {
        let path = normalize(path)?;
        let id = self.lookup(&path)?;
        let inode = self.fetch_inode(id)?;
        if inode.is_dir() {
            return Err(Error::IsDirectory(path));
        }
        Ok(FileHandle {
            inode: id,
            path,
            offset: 0,
        })
    }

    /// Open, creating if absent.
    pub fn open_or_create(&self, path: &str) -> Result<FileHandle> {
        match self.open(path) {
            Err(Error::NotFound(_)) => match self.create(path) {
                Err(Error::AlreadyExists(_)) => self.open(path),
                other => other,
            },
            other => other,
        }
    }

    /// Hard-link `existing` at `new_path`: atomically create the new path
    /// mapping, bump the link count, and insert the directory entry —
    /// the transaction spelled out in §2.4.
    pub fn link(&self, existing: &str, new_path: &str) -> Result<()> {
        let new_path = normalize(new_path)?;
        let (parent, name) = split_path(&new_path)?;
        let existing = normalize(existing)?;
        self.with_retry("fs.link", || {
            let mut t = self.meta_txn();
            let id = match t.get(&Key::path(&existing))? {
                Some(Value::PathEntry(p)) => p,
                _ => return Err(Error::NotFound(existing.clone())),
            };
            let parent_id = match t.get(&Key::path(&parent))? {
                Some(Value::PathEntry(p)) => p,
                _ => return Err(Error::NotFound(parent.clone())),
            };
            t.push(MetaOp::PathInsert {
                key: Key::path(&new_path),
                inode: id,
                expect_absent: true,
            });
            t.push(MetaOp::InodeAdjustLinks {
                key: Key::inode(id),
                delta: 1,
                mtime: unix_now(),
            });
            t.push(MetaOp::DirInsert {
                key: Key::dir(parent_id),
                name: name.clone(),
                inode: id,
                expect_absent: true,
            });
            self.commit_txn(t)?;
            Ok(())
        })
    }

    /// Atomically move `old_path` to `new_path` (files only; `new_path`
    /// must not exist).  One metadata transaction inserts the new path
    /// mapping and directory entry while removing the old ones — the
    /// canonical commit MIXING namespace inserts and removes, usually
    /// across shard groups.  On the replicated backend its atomicity is
    /// what the reader-isolation machinery guarantees (entry holds on
    /// the direct path, intent locks under `meta_2pc`): a concurrent
    /// reader observes the file at the old name or the new one, never
    /// at neither.
    pub fn rename(&self, old_path: &str, new_path: &str) -> Result<()> {
        let old_path = normalize(old_path)?;
        let new_path = normalize(new_path)?;
        let (old_parent, old_name) = split_path(&old_path)?;
        let (new_parent, new_name) = split_path(&new_path)?;
        self.with_retry("fs.rename", || {
            let mut t = self.meta_txn();
            let id = match t.get(&Key::path(&old_path))? {
                Some(Value::PathEntry(p)) => p,
                _ => return Err(Error::NotFound(old_path.clone())),
            };
            if old_path == new_path {
                // Self-rename of an EXISTING file is a no-op (checking
                // existence first, so a missing path still errors).
                return Ok(());
            }
            if let Some(Value::Inode(i)) = t.get(&Key::inode(id))? {
                if i.is_dir() {
                    return Err(Error::IsDirectory(old_path.clone()));
                }
            }
            if t.get(&Key::path(&new_path))?.is_some() {
                return Err(Error::AlreadyExists(new_path.clone()));
            }
            let old_parent_id = match t.get(&Key::path(&old_parent))? {
                Some(Value::PathEntry(p)) => p,
                _ => return Err(Error::NotFound(old_parent.clone())),
            };
            let new_parent_id = match t.get(&Key::path(&new_parent))? {
                Some(Value::PathEntry(p)) => p,
                _ => return Err(Error::NotFound(new_parent.clone())),
            };
            let new_parent_inode = match t.get(&Key::inode(new_parent_id))? {
                Some(Value::Inode(i)) => i,
                _ => return Err(Error::CorruptMetadata(new_parent.clone())),
            };
            if !new_parent_inode.is_dir() {
                return Err(Error::NotADirectory(new_parent.clone()));
            }
            t.push(MetaOp::PathInsert {
                key: Key::path(&new_path),
                inode: id,
                expect_absent: true,
            });
            t.push(MetaOp::Delete {
                key: Key::path(&old_path),
            });
            t.push(MetaOp::DirInsert {
                key: Key::dir(new_parent_id),
                name: new_name.clone(),
                inode: id,
                expect_absent: true,
            });
            t.push(MetaOp::DirRemove {
                key: Key::dir(old_parent_id),
                name: old_name.clone(),
            });
            self.commit_txn(t)?;
            Ok(())
        })
    }

    /// Remove a path; the inode is deleted when its last link drops and
    /// its slices become garbage for the GC scan (§2.8).
    pub fn unlink(&self, path: &str) -> Result<()> {
        let path = normalize(path)?;
        let (parent, name) = split_path(&path)?;
        self.with_retry("fs.unlink", || {
            let mut t = self.meta_txn();
            let id = match t.get(&Key::path(&path))? {
                Some(Value::PathEntry(p)) => p,
                _ => return Err(Error::NotFound(path.clone())),
            };
            if let Some(Value::Inode(i)) = t.get(&Key::inode(id))? {
                if i.is_dir() {
                    return Err(Error::IsDirectory(path.clone()));
                }
            }
            let parent_id = match t.get(&Key::path(&parent))? {
                Some(Value::PathEntry(p)) => p,
                _ => return Err(Error::NotFound(parent.clone())),
            };
            t.push(MetaOp::Delete {
                key: Key::path(&path),
            });
            t.push(MetaOp::InodeAdjustLinks {
                key: Key::inode(id),
                delta: -1,
                mtime: unix_now(),
            });
            t.push(MetaOp::DirRemove {
                key: Key::dir(parent_id),
                name: name.clone(),
            });
            self.commit_txn(t)?;
            Ok(())
        })
    }

    /// Enumerate one directory (§2.4's traditional-style directories).
    pub fn readdir(&self, path: &str) -> Result<Vec<(String, InodeId)>> {
        let id = self.lookup(path)?;
        let inode = self.fetch_inode(id)?;
        if !inode.is_dir() {
            return Err(Error::NotADirectory(path.into()));
        }
        match self.meta_get(&Key::dir(id))?.0 {
            Some(Value::Dir(d)) => Ok(d.into_iter().collect()),
            _ => Ok(Vec::new()),
        }
    }

    /// Current file length.
    pub fn len(&self, fd: &FileHandle) -> Result<u64> {
        Ok(self.fetch_inode(fd.inode)?.len)
    }

    // ------------------------------------------------------------ seek

    /// Move the cursor.  Returns the new offset.
    pub fn seek(&self, fd: &mut FileHandle, from: SeekFrom) -> Result<u64> {
        let new = match from {
            SeekFrom::Start(o) => o as i128,
            SeekFrom::Current(d) => fd.offset as i128 + d as i128,
            SeekFrom::End(d) => self.len(fd)? as i128 + d as i128,
        };
        if new < 0 {
            return Err(Error::InvalidArgument("seek before start".into()));
        }
        fd.offset = new as u64;
        Ok(fd.offset)
    }

    // ------------------------------------------------------------ write

    /// Write at the cursor and advance it.
    pub fn write(&self, fd: &mut FileHandle, data: &[u8]) -> Result<()> {
        self.write_at(fd.inode, fd.offset, data)?;
        fd.offset += data.len() as u64;
        Ok(())
    }

    /// Random-access write at an explicit offset (the operation HDFS
    /// cannot do at all, §4.2).  ONE transport scatter uploads every
    /// replica of every region part concurrently (§2.1: slices are
    /// invisible until the commit, so ~1 wire time total), then one blind
    /// metadata transaction publishes them.
    pub fn write_at(&self, inode: InodeId, offset: u64, data: &[u8]) -> Result<()> {
        if data.is_empty() {
            return Ok(());
        }
        if let Some(wb) = &self.write_behind {
            return wb.enqueue_write_at(self, inode, offset, data.to_vec()).map(|_| ());
        }
        self.write_at_direct(inode, offset, data)
    }

    /// The synchronous body of [`Self::write_at`] — also the flush path
    /// the write-behind worker drains queued writes through.
    pub(crate) fn write_at_direct(&self, inode: InodeId, offset: u64, data: &[u8]) -> Result<()> {
        let replication = self.fetch_inode(inode)?.replication;
        // 1. Slices first (§2.1): visible to nobody until the commit.
        let parts = self.split_range(inode, offset, data.len() as u64);
        let mut payloads: Vec<(RegionId, std::sync::Arc<[u8]>)> =
            Vec::with_capacity(parts.len());
        let mut cursor = 0usize;
        for (rid, _rel, len) in &parts {
            payloads.push((*rid, std::sync::Arc::from(&data[cursor..cursor + *len as usize])));
            cursor += *len as usize;
        }
        let replica_sets = self.create_replicated_parts(&payloads, replication)?;
        let created: Vec<(RegionId, u64, SliceData)> = parts
            .iter()
            .zip(replica_sets)
            .map(|((rid, rel, _), replicas)| (*rid, *rel, SliceData::Stored(replicas)))
            .collect();
        // 2. Publish with blind appends — no read set, so concurrent
        //    writers never conflict here.
        let end = offset + data.len() as u64;
        let highest = parts.last().map(|(r, _, _)| r.index).unwrap_or(0);
        self.with_retry("fs.write_at", || {
            let mut t = self.meta_txn();
            for (rid, rel, data) in &created {
                t.push(MetaOp::RegionAppend {
                    key: Key::region(*rid),
                    entry: RegionEntry {
                        placement: Placement::At(*rel),
                        len: data.len().unwrap_or(0),
                        data: data.clone(),
                    },
                });
            }
            t.push(MetaOp::InodeSetLenMax {
                key: Key::inode(inode),
                candidate: end,
                highest_region: highest,
                mtime: unix_now(),
            });
            self.commit_txn(t)?;
            Ok(())
        })
    }

    /// Append bytes at the end of file using the conditional EOF-relative
    /// fast path (§2.5): concurrent appends commute; only when a region
    /// fills does the writer fall back to an explicit-offset write.
    pub fn append_bytes(&self, fd: &FileHandle, data: &[u8]) -> Result<u64> {
        if data.is_empty() {
            return self.len(fd);
        }
        if let Some(wb) = &self.write_behind {
            return wb.enqueue_append(self, fd.inode, data.to_vec());
        }
        // Fresh fetch on purpose: aiming an EOF-relative append with a
        // stale `highest_region` at an old, non-full region would land
        // the bytes mid-file instead of at EOF.
        let aim = self.append_aim(fd.inode)?;
        self.append_bytes_aimed(fd.inode, data, aim)
    }

    /// One fresh inode fetch distilled to what an EOF append needs.
    /// Separated from [`Self::append_bytes_aimed`] so a write-behind
    /// flush of K queued appends can aim once for the whole queue.
    pub(crate) fn append_aim(&self, inode: InodeId) -> Result<super::AppendAim> {
        let i = self.fetch_inode_fresh(inode)?;
        Ok(super::AppendAim {
            region_idx: i.highest_region,
            replication: i.replication,
        })
    }

    /// The aimed body of [`Self::append_bytes`]: the conditional-append
    /// loop, with the region validation at commit keeping a stale `aim`
    /// safe (it falls back to the validated-EOF slow path, never lands
    /// bytes mid-file).
    pub(crate) fn append_bytes_aimed(
        &self,
        inode: InodeId,
        data: &[u8],
        aim: super::AppendAim,
    ) -> Result<u64> {
        let region_idx = aim.region_idx;
        let replication = aim.replication;
        loop {
            let rid = RegionId::new(inode, region_idx);
            let replicas = self.create_replicated(data, rid, replication)?;
            let region_base = u64::from(region_idx) * self.config.region_size;
            let mut t = self.meta_txn();
            t.push(MetaOp::RegionAppendEof {
                key: Key::region(rid),
                data: SliceData::Stored(replicas.clone()),
                len: data.len() as u64,
                cap: self.config.region_size,
            });
            t.push(MetaOp::InodeSetLenMax {
                key: Key::inode(inode),
                candidate: 0,
                highest_region: region_idx,
                mtime: unix_now(),
            });
            t.push(MetaOp::InodeSetLenFromRegion {
                inode_key: Key::inode(inode),
                region_key: Key::region(rid),
                region_base,
                mtime: unix_now(),
            });
            match self.commit_txn(t) {
                Ok(outcomes) => {
                    let at = outcomes
                        .iter()
                        .find_map(|o| match o {
                            crate::meta::OpOutcome::AppendedAt(a) => Some(*a),
                            _ => None,
                        })
                        .unwrap_or(0);
                    return Ok(region_base + at);
                }
                Err(Error::CondAppendFailed { .. }) => {
                    // Region full.  §2.5 fallback: read the end-of-file
                    // offset and perform an explicit write there (filling
                    // the remainder of this region, spilling into the
                    // next).  The EOF read is validated at commit, so a
                    // concurrent append conflicts and we retry.
                    let slice = Slice {
                        pieces: vec![(data.len() as u64, SliceData::Stored(replicas))],
                    };
                    return self.append_at_eof_validated(inode, &slice);
                }
                Err(Error::NotLeader { shard, .. }) => {
                    // Leadership moved mid-commit (commit_txn already
                    // dropped the cache): rediscover the leader and
                    // replay.
                    self.metrics.add_txn_retries(1);
                    self.meta.heal(shard);
                    continue;
                }
                Err(e) if e.is_retryable() => {
                    self.metrics.add_txn_retries(1);
                    continue;
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// §2.5 slow path shared by byte and slice appends: read the file
    /// length inside the metadata transaction (conflict-validated) and
    /// paste at exactly that offset.
    pub(crate) fn append_at_eof_validated(
        &self,
        inode: InodeId,
        slice: &Slice,
    ) -> Result<u64> {
        self.with_retry("fs.append", || {
            let mut t = self.meta_txn();
            let len = match t.get(&Key::inode(inode))? {
                Some(Value::Inode(i)) => i.len,
                _ => return Err(Error::NotFound(format!("inode {inode}"))),
            };
            let highest = self.push_paste_ops(&mut t, inode, len, slice);
            t.push(MetaOp::InodeSetLenMax {
                key: Key::inode(inode),
                candidate: len + slice.len(),
                highest_region: highest,
                mtime: unix_now(),
            });
            self.commit_txn(t)?;
            Ok(len)
        })
    }

    // ------------------------------------------------------------ read

    /// Read at the cursor and advance it.  Short reads happen only at
    /// EOF.  With `Config::readahead > 0`, each fetch extends past the
    /// requested range and the surplus serves subsequent sequential
    /// reads with zero metadata or storage envelopes; the buffer obeys
    /// the cache's invalidation triggers (own commit, heal, conflict).
    pub fn read(&self, fd: &mut FileHandle, len: u64) -> Result<Vec<u8>> {
        let ra = self.config.readahead;
        let out = if ra == 0 {
            self.read_inode_at(fd.inode, fd.offset, len)?
        } else if let Some(buffered) = self.cache.readahead_take(fd.inode, fd.offset, len) {
            buffered
        } else {
            let as_of = self.cache.epoch();
            let fetched = self.read_inode_at(fd.inode, fd.offset, len + ra)?;
            let serve = (len as usize).min(fetched.len());
            let (head, tail) = fetched.split_at(serve);
            self.cache
                .readahead_put(fd.inode, fd.offset + serve as u64, tail.to_vec(), as_of);
            head.to_vec()
        };
        fd.offset += out.len() as u64;
        Ok(out)
    }

    /// Read `[offset, offset+len)`, clamped to EOF.  Gaps and punched
    /// holes read as zeros.
    pub fn read_at(&self, fd: &FileHandle, offset: u64, len: u64) -> Result<Vec<u8>> {
        self.read_inode_at(fd.inode, offset, len)
    }

    /// Gather-read: resolve every region's extents (from the cache when
    /// warm — zero metadata rounds), then fetch ALL stored extents —
    /// across regions and storage servers — in one transport scatter,
    /// coalesced per server when `Config::read_coalescing` is on.
    /// Multi-region reads (and the sort's shuffle reads, whose buckets
    /// are slices spread over many servers) pipeline instead of paying
    /// one wire time per extent.
    pub(crate) fn read_inode_at(&self, inode: InodeId, offset: u64, len: u64) -> Result<Vec<u8>> {
        let file_len = self.fetch_inode(inode)?.len;
        if offset >= file_len {
            return Ok(Vec::new());
        }
        let len = len.min(file_len - offset);
        let tiles = self.resolve_window(inode, offset, len)?;
        self.fetch_window(&tiles, offset, len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::testutil::small_cluster;

    #[test]
    fn path_normalization() {
        assert_eq!(normalize("/a//b/").unwrap(), "/a/b");
        assert_eq!(normalize("/").unwrap(), "/");
        assert_eq!(normalize("/a/./b").unwrap(), "/a/b");
        assert!(normalize("relative").is_err());
        assert!(normalize("/a/../b").is_err());
        assert_eq!(
            split_path("/a/b/c").unwrap(),
            ("/a/b".to_string(), "c".to_string())
        );
        assert_eq!(split_path("/a").unwrap(), ("/".to_string(), "a".to_string()));
    }

    #[test]
    fn create_write_read_round_trip() {
        let cluster = small_cluster();
        let c = cluster.client();
        let mut fd = c.create("/f").unwrap();
        c.write(&mut fd, b"hello world").unwrap();
        assert_eq!(c.len(&fd).unwrap(), 11);
        assert_eq!(c.read_at(&fd, 0, 11).unwrap(), b"hello world");
        assert_eq!(c.read_at(&fd, 6, 100).unwrap(), b"world");
        assert_eq!(c.read_at(&fd, 11, 5).unwrap(), b"");
    }

    #[test]
    fn multi_region_write_and_read() {
        let cluster = small_cluster();
        let c = cluster.client();
        let rs = c.config().region_size;
        let mut fd = c.create("/big").unwrap();
        let mut data = vec![0u8; (3 * rs + 100) as usize];
        let mut rng = crate::util::Rng::new(1);
        rng.fill_bytes(&mut data);
        c.write(&mut fd, &data).unwrap();
        assert_eq!(c.len(&fd).unwrap(), data.len() as u64);
        let back = c.read_at(&fd, 0, data.len() as u64).unwrap();
        assert_eq!(back, data);
        // Cross-region window.
        let from = rs - 50;
        let to = rs + 50;
        assert_eq!(
            c.read_at(&fd, from, to - from).unwrap(),
            &data[from as usize..to as usize]
        );
    }

    #[test]
    fn random_writes_overlay_correctly() {
        let cluster = small_cluster();
        let c = cluster.client();
        let mut fd = c.create("/rw").unwrap();
        c.write(&mut fd, &vec![b'a'; 100]).unwrap();
        c.write_at(fd.inode, 20, &vec![b'b'; 30]).unwrap();
        c.write_at(fd.inode, 40, &vec![b'c'; 10]).unwrap();
        let back = c.read_at(&fd, 0, 100).unwrap();
        assert_eq!(&back[..20], &vec![b'a'; 20][..]);
        assert_eq!(&back[20..40], &vec![b'b'; 20][..]);
        assert_eq!(&back[40..50], &vec![b'c'; 10][..]);
        assert_eq!(&back[50..], &vec![b'a'; 50][..]);
    }

    #[test]
    fn sparse_files_read_zeros_in_gaps() {
        let cluster = small_cluster();
        let c = cluster.client();
        let fd = c.create("/sparse").unwrap();
        c.write_at(fd.inode, 100, b"xyz").unwrap();
        let back = c.read_at(&fd, 0, 103).unwrap();
        assert_eq!(&back[..100], &vec![0u8; 100][..]);
        assert_eq!(&back[100..], b"xyz");
    }

    #[test]
    fn seek_semantics() {
        let cluster = small_cluster();
        let c = cluster.client();
        let mut fd = c.create("/s").unwrap();
        c.write(&mut fd, b"0123456789").unwrap();
        assert_eq!(c.seek(&mut fd, SeekFrom::Start(3)).unwrap(), 3);
        assert_eq!(c.read(&mut fd, 2).unwrap(), b"34");
        assert_eq!(c.seek(&mut fd, SeekFrom::Current(-1)).unwrap(), 4);
        assert_eq!(c.seek(&mut fd, SeekFrom::End(-2)).unwrap(), 8);
        assert_eq!(c.read(&mut fd, 10).unwrap(), b"89");
        assert!(c.seek(&mut fd, SeekFrom::Current(-100)).is_err());
    }

    #[test]
    fn appends_see_sequential_offsets() {
        let cluster = small_cluster();
        let c = cluster.client();
        let fd = c.create("/log").unwrap();
        assert_eq!(c.append_bytes(&fd, b"aa").unwrap(), 0);
        assert_eq!(c.append_bytes(&fd, b"bb").unwrap(), 2);
        assert_eq!(c.read_at(&fd, 0, 4).unwrap(), b"aabb");
    }

    #[test]
    fn append_crosses_region_boundary() {
        let cluster = small_cluster();
        let c = cluster.client();
        let rs = c.config().region_size;
        let fd = c.create("/spill").unwrap();
        let chunk = vec![7u8; (rs / 2 + 1) as usize];
        // Region 0 cannot hold two of these: the second append falls back
        // to an explicit EOF write that STRADDLES the region boundary —
        // no gap is ever introduced (§2.5 fallback).
        assert_eq!(c.append_bytes(&fd, &chunk).unwrap(), 0);
        let second = c.append_bytes(&fd, &chunk).unwrap();
        assert_eq!(second, chunk.len() as u64);
        assert_eq!(c.len(&fd).unwrap(), 2 * chunk.len() as u64);
        let back = c.read_at(&fd, second, chunk.len() as u64).unwrap();
        assert_eq!(back, chunk);
        // The whole file is contiguous 7s.
        let all = c.read_at(&fd, 0, 2 * chunk.len() as u64).unwrap();
        assert!(all.iter().all(|&b| b == 7));
    }

    #[test]
    fn namespace_operations() {
        let cluster = small_cluster();
        let c = cluster.client();
        c.mkdir("/dir").unwrap();
        c.create("/dir/f").unwrap();
        assert!(c.exists("/dir/f"));
        assert!(matches!(c.create("/dir/f"), Err(Error::AlreadyExists(_))));
        assert!(matches!(c.create("/nodir/f"), Err(Error::NotFound(_))));
        let entries = c.readdir("/dir").unwrap();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].0, "f");
        // Root listing contains /dir.
        let root = c.readdir("/").unwrap();
        assert!(root.iter().any(|(n, _)| n == "dir"));
    }

    #[test]
    fn hardlinks_share_data_and_count_links() {
        let cluster = small_cluster();
        let c = cluster.client();
        let mut fd = c.create("/a").unwrap();
        c.write(&mut fd, b"shared").unwrap();
        c.link("/a", "/b").unwrap();
        assert_eq!(c.stat("/a").unwrap().links, 2);
        let fb = c.open("/b").unwrap();
        assert_eq!(c.read_at(&fb, 0, 6).unwrap(), b"shared");
        // Unlink one name: data still reachable through the other.
        c.unlink("/a").unwrap();
        assert!(!c.exists("/a"));
        assert_eq!(c.stat("/b").unwrap().links, 1);
        assert_eq!(c.read_at(&fb, 0, 6).unwrap(), b"shared");
        // Unlink the last name: inode is gone.
        c.unlink("/b").unwrap();
        assert!(matches!(c.stat("/b"), Err(Error::NotFound(_))));
    }

    #[test]
    fn unlink_directory_is_rejected() {
        let cluster = small_cluster();
        let c = cluster.client();
        c.mkdir("/d").unwrap();
        assert!(matches!(c.unlink("/d"), Err(Error::IsDirectory(_))));
        assert!(matches!(c.open("/d"), Err(Error::IsDirectory(_))));
    }

    #[test]
    fn rename_moves_atomically_across_directories() {
        let cluster = small_cluster();
        let c = cluster.client();
        c.mkdir("/src").unwrap();
        c.mkdir("/dst").unwrap();
        let mut fd = c.create("/src/f").unwrap();
        c.write(&mut fd, b"moved").unwrap();
        c.rename("/src/f", "/dst/g").unwrap();
        assert!(!c.exists("/src/f"));
        let fd2 = c.open("/dst/g").unwrap();
        assert_eq!(fd2.inode(), fd.inode(), "same inode, new name");
        assert_eq!(c.read_at(&fd2, 0, 5).unwrap(), b"moved");
        assert!(c.readdir("/src").unwrap().is_empty());
        assert_eq!(c.readdir("/dst").unwrap(), vec![("g".into(), fd.inode())]);
        // Error surface: missing source, taken destination, directories.
        assert!(matches!(c.rename("/src/f", "/x"), Err(Error::NotFound(_))));
        c.create("/taken").unwrap();
        assert!(matches!(
            c.rename("/dst/g", "/taken"),
            Err(Error::AlreadyExists(_))
        ));
        assert!(matches!(c.rename("/src", "/d2"), Err(Error::IsDirectory(_))));
        // The destination parent must be a directory, not a file.
        assert!(matches!(
            c.rename("/dst/g", "/taken/x"),
            Err(Error::NotADirectory(_))
        ));
        // Same-directory rename and self-rename.
        c.rename("/dst/g", "/dst/h").unwrap();
        assert!(c.exists("/dst/h") && !c.exists("/dst/g"));
        c.rename("/dst/h", "/dst/h").unwrap();
        assert!(c.exists("/dst/h"));
        // Self-rename of a MISSING path is still an error.
        assert!(matches!(
            c.rename("/dst/nope", "/dst/nope"),
            Err(Error::NotFound(_))
        ));
    }

    #[test]
    fn open_or_create_is_idempotent() {
        let cluster = small_cluster();
        let c = cluster.client();
        let a = c.open_or_create("/x").unwrap();
        let b = c.open_or_create("/x").unwrap();
        assert_eq!(a.inode(), b.inode());
    }

    #[test]
    fn cached_coalesced_read_issues_4x_fewer_envelopes() {
        // The acceptance bound: a warm cached+coalesced read of a
        // multi-region, multi-extent file must issue >= 4x fewer
        // transport envelopes than the seed path.
        use crate::cluster::Cluster;
        use crate::config::Config;
        let measure = |cfg: Config| {
            let cluster = Cluster::builder().config(cfg).build().unwrap();
            let c = cluster.client();
            let mut fd = c.create("/f").unwrap();
            // 4 regions x 4 extents: 16 x 1 KiB chunks into 4 KiB regions.
            for i in 0..16u8 {
                c.write(&mut fd, &[i; 1024]).unwrap();
            }
            let fd = c.open("/f").unwrap();
            let cold = c.read_at(&fd, 0, 16 * 1024).unwrap();
            let before = cluster.transport_envelopes();
            let warm = c.read_at(&fd, 0, 16 * 1024).unwrap();
            assert_eq!(cold, warm);
            (cluster.transport_envelopes() - before, warm)
        };
        let (seed_env, seed_data) = measure(Config::test());
        let (fast_env, fast_data) = measure(Config::fast_read_test());
        assert_eq!(seed_data, fast_data, "coalescing must not change bytes");
        // Seed: 1 inode MetaGet + 4 region MetaGets + 16 RetrieveSlice.
        assert_eq!(seed_env, 21, "seed warm-read envelope count moved");
        assert!(
            fast_env * 4 <= seed_env,
            "warm read envelopes: fast {fast_env} vs seed {seed_env} (< 4x)"
        );
    }

    #[test]
    fn readahead_serves_sequential_reads_without_envelopes() {
        use crate::cluster::Cluster;
        use crate::config::Config;
        let cluster = Cluster::builder()
            .config(Config::fast_read_test())
            .build()
            .unwrap();
        let c = cluster.client();
        let mut fd = c.create("/ra").unwrap();
        let mut data = vec![0u8; 12 * 1024];
        crate::util::Rng::new(3).fill_bytes(&mut data);
        c.write(&mut fd, &data).unwrap();

        let mut fd = c.open("/ra").unwrap();
        // First read fetches 1 KiB + the 8 KiB readahead window.
        let mut out = c.read(&mut fd, 1024).unwrap();
        assert_eq!(out.len(), 1024);
        let before = cluster.transport_envelopes();
        for _ in 0..8 {
            out.extend(c.read(&mut fd, 1024).unwrap());
        }
        assert_eq!(
            cluster.transport_envelopes(),
            before,
            "buffered sequential reads must issue no envelopes"
        );
        for _ in 0..3 {
            out.extend(c.read(&mut fd, 1024).unwrap());
        }
        assert_eq!(out, data);
        assert_eq!(c.read(&mut fd, 1024).unwrap(), b"", "clean EOF");
    }

    #[test]
    fn own_writes_invalidate_cache_and_readahead() {
        use crate::cluster::Cluster;
        use crate::config::Config;
        let cluster = Cluster::builder()
            .config(Config::fast_read_test())
            .build()
            .unwrap();
        let c = cluster.client();
        let mut fd = c.create("/rw").unwrap();
        c.write(&mut fd, &[b'a'; 4096]).unwrap();
        // Populate the metadata cache and the readahead buffer.
        let mut rfd = c.open("/rw").unwrap();
        assert_eq!(c.read(&mut rfd, 16).unwrap(), vec![b'a'; 16]);
        assert!(c.metadata_cache().hits() + c.metadata_cache().misses() > 0);
        // Overwrite through the SAME client: the commit must drop the
        // cached inode/region/readahead state...
        c.write_at(fd.inode(), 0, &[b'B'; 32]).unwrap();
        // ...so subsequent reads observe the write immediately.
        assert_eq!(c.read_at(&rfd, 0, 32).unwrap(), vec![b'B'; 32]);
        assert_eq!(c.read(&mut rfd, 16).unwrap(), vec![b'B'; 16]);
        // Length updates are read-your-writes too.
        c.append_bytes(&rfd, &[b'z'; 10]).unwrap();
        assert_eq!(c.len(&rfd).unwrap(), 4096 + 10);
        assert!(c.metadata_cache().invalidations() > 0);
    }

    #[test]
    fn indeterminate_txn_commit_drops_cache_and_readahead() {
        // PR-9 bugfix pin: a Transaction::commit that returns an
        // indeterminate error (here: meta ack loss -> Timeout) may have
        // LANDED server-side.  The cached inode/region entries AND the
        // readahead buffers for the mutated inodes must be dropped, or
        // the next read serves pre-commit bytes out of readahead.
        use crate::cluster::Cluster;
        use crate::config::Config;
        use crate::net::{CutMode, Peer, Turbulence};
        let cluster = Cluster::builder()
            .config(Config::fast_read_test())
            .build()
            .unwrap();
        let c = cluster.client();
        let mut fd = c.create("/ind").unwrap();
        c.write(&mut fd, &[b'a'; 12 * 1024]).unwrap();
        // Warm the metadata cache and the readahead buffer.
        let mut rfd = c.open("/ind").unwrap();
        assert_eq!(c.read(&mut rfd, 1024).unwrap(), vec![b'a'; 1024]);
        // Overwrite through a WTF transaction whose commit ack is lost:
        // the commit applies on the metadata server, the client times out.
        let chaos =
            Turbulence::new(29, crate::coordinator::lease::LeaseClock::manual());
        let meta_peer: Peer = cluster.meta().clone();
        chaos.cut(&meta_peer, CutMode::AckLoss);
        cluster.transport().set_turbulence(Some(chaos));
        let mut t = c.begin();
        let tfd = t.open("/ind").unwrap();
        t.write(tfd, &[b'B'; 2048]).unwrap();
        let err = t.commit().unwrap_err();
        assert!(
            err.is_indeterminate(),
            "expected indeterminate commit, got {err:?}"
        );
        cluster.transport().set_turbulence(None);
        // The write landed.  Reads must refetch — not serve the stale
        // readahead window filled before the commit.
        let before = cluster.transport_envelopes();
        assert_eq!(c.read_at(&rfd, 0, 2048).unwrap(), vec![b'B'; 2048]);
        assert!(
            cluster.transport_envelopes() > before,
            "post-indeterminate-commit read served from stale cache/readahead"
        );
        assert_eq!(c.read(&mut rfd, 1024).unwrap(), vec![b'B'; 1024]);
    }

    #[test]
    fn warm_transactional_reads_issue_no_metadata_envelopes() {
        // Tentpole contract in unit form: inside a WTF transaction, reads
        // of cache-warm metadata are served from the versioned cache with
        // their versions recorded in the read set — zero MetaGet
        // envelopes — and the commit still validates cleanly.
        use crate::cluster::Cluster;
        use crate::config::Config;
        use crate::net::Plane;
        let cluster = Cluster::builder()
            .config(Config::fast_read_test())
            .build()
            .unwrap();
        let c = cluster.client();
        let mut fd = c.create("/warm").unwrap();
        c.write(&mut fd, &[b'x'; 2048]).unwrap();
        // Warm path, inode, and region entries with a plain open + read.
        let rfd = c.open("/warm").unwrap();
        assert_eq!(c.read_at(&rfd, 0, 2048).unwrap(), vec![b'x'; 2048]);
        let before_meta = cluster.transport_envelopes_on(Plane::Meta);
        let mut t = c.begin();
        let tfd = t.open("/warm").unwrap();
        assert_eq!(t.len(tfd).unwrap(), 2048);
        assert_eq!(t.read(tfd, 2048).unwrap(), vec![b'x'; 2048]);
        assert_eq!(
            cluster.transport_envelopes_on(Plane::Meta),
            before_meta,
            "warm transactional reads must come from the versioned cache"
        );
        // Cached versions are current, so validation passes.
        t.commit().unwrap();
        // A second client's plain warm open is also envelope-free now
        // that path entries are cached.
        let before = cluster.transport_envelopes();
        let _ = c.open("/warm").unwrap();
        assert_eq!(
            cluster.transport_envelopes(),
            before,
            "warm open must be served by the path-entry + inode cache"
        );
    }

    #[test]
    fn concurrent_appends_from_threads_all_land() {
        let cluster = small_cluster();
        let c = cluster.client();
        c.create("/conc").unwrap();
        let threads: Vec<_> = (0..8)
            .map(|i| {
                let c = c.clone();
                std::thread::spawn(move || {
                    let fd = c.open("/conc").unwrap();
                    for _ in 0..16 {
                        c.append_bytes(&fd, &[b'0' + i as u8; 8]).unwrap();
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let fd = c.open("/conc").unwrap();
        let len = c.len(&fd).unwrap();
        assert_eq!(len, 8 * 16 * 8);
        // Every 8-byte record is intact (no torn appends).
        let data = c.read_at(&fd, 0, len).unwrap();
        for rec in data.chunks(8) {
            assert!(rec.iter().all(|&b| b == rec[0]), "torn record {rec:?}");
        }
    }
}
