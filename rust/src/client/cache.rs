//! The client's versioned metadata cache and readahead buffer — the hot
//! read path's answer to the per-`fetch_inode`/`fetch_region` metadata
//! wire round (`Config::metadata_cache`, `Config::readahead`).
//!
//! Every entry is stored with the authoritative version the `MetaGet`
//! envelope carried, so a cached value is always "this key at version v"
//! — never an unverifiable guess.  Serving policy and the coherence
//! contract (also recorded in ROADMAP "Hot read path"):
//!
//! * **What may be stale.**  Plain (non-transactional) reads —
//!   `read_at`, `yank_at`, `len`, `stat` — may serve metadata another
//!   client has since changed, bounded by the invalidation triggers
//!   below.  Lengths only ever grow (monotone max), so a cached length
//!   is always a length the file *had*; a reader's view never moves
//!   backwards.
//! * **What is never stale *at commit*.**  Transactional reads
//!   ([`crate::meta::MetaTxn::get`] and everything inside a WTF
//!   [`crate::client::Transaction`]) are served from this cache
//!   optimistically (PR 9): the cached version enters the read set, and
//!   commit-time validation rejects any read that was stale — a
//!   `TxnConflict` invalidates the key and the retry re-reads fresh
//!   state.  A stale cached read can therefore cost a retry, but can
//!   never commit — §3 serializability is untouched.  CAS maintenance
//!   (compact/spill) still uses uncached region fetches: a CAS against
//!   a cached version could never succeed once the region moved.
//! * **Snapshot rule.**  A freshly fetched inode drops the file's cached
//!   regions ([`MetaCache::put_inode`]): a read then never pairs a new
//!   length with older region metadata, exactly matching the uncached
//!   path's fetch order (inode first, regions after).  Torn tails —
//!   a length that claims bytes its regions don't yet show — cannot
//!   happen.
//! * **Invalidation.**  (1) Own-txn commit: every key a committed
//!   transaction mutated is dropped, so a client always reads its own
//!   writes.  (2) `NotLeader`/heal: leadership moved, the whole cache is
//!   dropped before the shard is healed.  (3) Version mismatch at
//!   validation time: a `TxnConflict` names the stale key; it is dropped
//!   before the retry re-reads.
//!
//! The readahead buffer holds *data* bytes fetched past a sequential
//! cursor read; it obeys the same invalidation triggers (a buffer is a
//! cached snapshot of one consistent fetch, so it can never serve a torn
//! record).

use crate::config::Config;
use crate::meta::TxnReadCache;
use crate::types::{Inode, InodeId, Key, RegionId, RegionMeta, Space, Value};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Most readahead buffers kept at once (one per actively streamed file).
const MAX_READAHEAD_BUFFERS: usize = 8;

/// One cached value plus the authoritative version it was read at.
/// Values are `Arc`-shared so a cache hit is O(1) — no deep clone of a
/// fragmented region's entry list under the cache mutex.
#[derive(Clone, Debug)]
struct Cached<T> {
    value: Arc<T>,
    version: u64,
    /// LRU clock tick of the last touch.
    used: u64,
    /// Hard lifetime bound (`Config::cache_ttl`); `None` = no expiry.
    /// A hit past this instant is a miss — the entry is dropped, never
    /// served.  This is what keeps the cache inside the GC two-scan
    /// grace window: a region entry can never outlive one scan
    /// interval, so the slice pointers it resolves are never reclaimed.
    expires: Option<Instant>,
}

impl<T> Cached<T> {
    fn expired(&self) -> bool {
        self.expires.is_some_and(|at| Instant::now() >= at)
    }
}

/// One file's readahead surplus: bytes `[start, start + data.len())`.
#[derive(Clone, Debug)]
struct ReadAhead {
    start: u64,
    data: Vec<u8>,
    used: u64,
}

#[derive(Debug, Default)]
struct Inner {
    inodes: HashMap<InodeId, Cached<Inode>>,
    regions: HashMap<RegionId, Cached<RegionMeta>>,
    /// Absolute pathname → inode id at the version `MetaGet` carried —
    /// `open()`/`lookup()` and namespace-transaction reads stop paying
    /// one namespace round per component (PR 9).
    paths: HashMap<String, Cached<InodeId>>,
    readahead: HashMap<InodeId, ReadAhead>,
    tick: u64,
    /// Bumped by every invalidation/clear.  Fetches snapshot it BEFORE
    /// going to the wire and their put is dropped if it moved — an
    /// in-flight fetch racing a same-client commit must never
    /// re-install pre-commit state (clones share this cache).
    epoch: u64,
}

impl Inner {
    fn bump(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    /// Keep the metadata maps under `capacity` entries by dropping the
    /// least-recently-used quarter when they overflow.
    fn evict(&mut self, capacity: usize) {
        let total = self.inodes.len() + self.regions.len() + self.paths.len();
        if total <= capacity.max(1) {
            return;
        }
        let mut ticks: Vec<u64> = self
            .inodes
            .values()
            .map(|c| c.used)
            .chain(self.regions.values().map(|c| c.used))
            .chain(self.paths.values().map(|c| c.used))
            .collect();
        ticks.sort_unstable();
        let cut = ticks[total / 4];
        self.inodes.retain(|_, c| c.used > cut);
        self.regions.retain(|_, c| c.used > cut);
        self.paths.retain(|_, c| c.used > cut);
    }

    fn drop_inode_state(&mut self, id: InodeId) {
        self.inodes.remove(&id);
        self.regions.retain(|rid, _| rid.inode != id);
        self.readahead.remove(&id);
    }
}

/// The per-client cache.  Clones of one [`crate::client::WtfClient`]
/// share it; independent clients each own their own (the invalidation
/// triggers are client-local by design).
#[derive(Debug)]
pub struct MetaCache {
    meta_enabled: bool,
    readahead_window: u64,
    capacity: usize,
    /// Lifetime bound on metadata entries (`Config::cache_ttl`);
    /// `ZERO` = entries live until invalidated or evicted.
    ttl: Duration,
    inner: Mutex<Inner>,
    hits: AtomicU64,
    misses: AtomicU64,
    invalidations: AtomicU64,
}

impl MetaCache {
    pub fn new(config: &Config) -> MetaCache {
        MetaCache {
            meta_enabled: config.metadata_cache,
            readahead_window: config.readahead,
            capacity: config.metadata_cache_entries.max(1),
            ttl: config.cache_ttl,
            inner: Mutex::new(Inner::default()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            invalidations: AtomicU64::new(0),
        }
    }

    /// Expiry instant for an entry installed now (`None` without a TTL).
    fn expiry(&self) -> Option<Instant> {
        if self.ttl.is_zero() {
            None
        } else {
            Some(Instant::now() + self.ttl)
        }
    }

    /// True when any cached state can exist (metadata entries or
    /// readahead bytes) — commit sites skip key bookkeeping otherwise.
    pub fn is_active(&self) -> bool {
        self.meta_enabled || self.readahead_window > 0
    }

    /// Cache hits served so far (tests/observability).
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn invalidations(&self) -> u64 {
        self.invalidations.load(Ordering::Relaxed)
    }

    // ------------------------------------------------------- metadata

    /// Invalidation epoch to snapshot BEFORE a fetch whose result will
    /// be `put_*` — the put is dropped if any invalidation lands in
    /// between (see `Inner::epoch`).
    pub fn epoch(&self) -> u64 {
        if !self.is_active() {
            return 0;
        }
        self.inner.lock().unwrap().epoch
    }

    pub fn get_inode(&self, id: InodeId) -> Option<Arc<Inode>> {
        self.get_inode_versioned(id).map(|(inode, _)| inode)
    }

    /// Like [`MetaCache::get_inode`] but also returns the authoritative
    /// version the entry was read at — what a transactional read records
    /// in its read set for commit-time validation (PR 9).
    pub fn get_inode_versioned(&self, id: InodeId) -> Option<(Arc<Inode>, u64)> {
        if !self.meta_enabled {
            return None;
        }
        let mut g = self.inner.lock().unwrap();
        let tick = g.bump();
        if g.inodes.get(&id).is_some_and(|c| c.expired()) {
            g.inodes.remove(&id);
        }
        match g.inodes.get_mut(&id) {
            Some(c) => {
                c.used = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some((c.value.clone(), c.version))
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Record a freshly fetched inode.  When the observed version moved
    /// (or the inode was not cached), the file's cached regions are
    /// dropped — the snapshot rule: region metadata served after this
    /// point must be at least as new as the inode, as in the uncached
    /// fetch order.
    /// `as_of` is the [`MetaCache::epoch`] snapshotted before the
    /// fetch; a stale snapshot drops the put (an invalidation won the
    /// race and this value may predate the invalidating commit).
    pub fn put_inode(&self, id: InodeId, inode: &Arc<Inode>, version: u64, as_of: u64) {
        if !self.meta_enabled {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        if g.epoch != as_of {
            return;
        }
        // Versions are per-key monotone in the store: never let a
        // slower, OLDER concurrent fetch overwrite a newer cached value
        // (a reader's view must not move backwards).
        if g.inodes.get(&id).is_some_and(|c| c.version > version) {
            return;
        }
        let same = g.inodes.get(&id).is_some_and(|c| c.version == version);
        if !same {
            g.regions.retain(|rid, _| rid.inode != id);
        }
        let used = g.bump();
        let expires = self.expiry();
        g.inodes.insert(
            id,
            Cached {
                value: Arc::clone(inode),
                version,
                used,
                expires,
            },
        );
        g.evict(self.capacity);
    }

    pub fn get_region(&self, rid: RegionId) -> Option<(Arc<RegionMeta>, u64)> {
        if !self.meta_enabled {
            return None;
        }
        let mut g = self.inner.lock().unwrap();
        let tick = g.bump();
        if g.regions.get(&rid).is_some_and(|c| c.expired()) {
            g.regions.remove(&rid);
        }
        match g.regions.get_mut(&rid) {
            Some(c) => {
                c.used = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some((c.value.clone(), c.version))
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    pub fn put_region(&self, rid: RegionId, region: &Arc<RegionMeta>, version: u64, as_of: u64) {
        if !self.meta_enabled {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        if g.epoch != as_of {
            return;
        }
        // Same monotonicity guard as `put_inode`: an older concurrent
        // fetch must not shadow a newer cached region (tile_window
        // would synthesize holes for bytes a newer length claims).
        if g.regions.get(&rid).is_some_and(|c| c.version > version) {
            return;
        }
        let used = g.bump();
        let expires = self.expiry();
        g.regions.insert(
            rid,
            Cached {
                value: Arc::clone(region),
                version,
                used,
                expires,
            },
        );
        g.evict(self.capacity);
    }

    // ----------------------------------------------------- path entries

    /// Cached pathname → `(inode id, version)` (PR 9): `lookup()` and
    /// namespace-transaction reads serve warm path components with zero
    /// envelopes.  Plain lookups inherit the may-be-stale contract;
    /// transactional reads record the version and validate at commit.
    pub fn get_path(&self, path: &str) -> Option<(InodeId, u64)> {
        if !self.meta_enabled {
            return None;
        }
        let mut g = self.inner.lock().unwrap();
        let tick = g.bump();
        if g.paths.get(path).is_some_and(|c| c.expired()) {
            g.paths.remove(path);
        }
        match g.paths.get_mut(path) {
            Some(c) => {
                c.used = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some((*c.value, c.version))
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Record a freshly resolved path entry.  Same guards as the other
    /// puts: the epoch snapshot drops puts that lost an invalidation
    /// race, and an older concurrent resolve never shadows a newer one.
    /// Absence is deliberately NOT cached: a negative entry would turn
    /// create/rename races into stale `NotFound`s with no version to
    /// validate against outside a transaction.
    pub fn put_path(&self, path: &str, id: InodeId, version: u64, as_of: u64) {
        if !self.meta_enabled {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        if g.epoch != as_of {
            return;
        }
        if g.paths.get(path).is_some_and(|c| c.version > version) {
            return;
        }
        let used = g.bump();
        let expires = self.expiry();
        g.paths.insert(
            path.to_string(),
            Cached {
                value: Arc::new(id),
                version,
                used,
                expires,
            },
        );
        g.evict(self.capacity);
    }

    // ---------------------------------------------------- invalidation

    /// Drop the cached state behind one metadata key.  An inode key
    /// drops the inode, all its regions, and its readahead; a region key
    /// drops that region and the file's readahead (its bytes may now be
    /// stale).  Non-inode/region spaces are never cached.
    pub fn invalidate_key(&self, key: &Key) {
        if !self.is_active() {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        self.invalidate_locked(&mut g, key);
    }

    /// Drop every key a committed transaction mutated (own-commit
    /// read-your-writes).
    pub fn invalidate_keys(&self, keys: &[Key]) {
        if !self.is_active() || keys.is_empty() {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        for key in keys {
            self.invalidate_locked(&mut g, key);
        }
    }

    fn invalidate_locked(&self, g: &mut Inner, key: &Key) {
        match key.space {
            Space::Inode => {
                g.epoch += 1;
                match parse_inode_key(&key.key) {
                    Some(id) => g.drop_inode_state(id),
                    // An inode key we cannot parse back to an id (e.g. a
                    // server-echoed conflict key in a future encoding)
                    // still invalidates conservatively: we cannot tell
                    // WHICH file's buffered bytes it covers, so no
                    // readahead buffer may survive it.  Leaving them
                    // intact would let a later sequential read serve
                    // pre-commit bytes with zero envelopes.
                    None => g.readahead.clear(),
                }
                self.invalidations.fetch_add(1, Ordering::Relaxed);
            }
            Space::Region => {
                g.epoch += 1;
                match parse_region_key(&key.key) {
                    Some(rid) => {
                        g.regions.remove(&rid);
                        g.readahead.remove(&rid.inode);
                    }
                    None => g.readahead.clear(),
                }
                self.invalidations.fetch_add(1, Ordering::Relaxed);
            }
            Space::Path => {
                g.epoch += 1;
                g.paths.remove(&key.key);
                self.invalidations.fetch_add(1, Ordering::Relaxed);
            }
            // Dir / Sys values are never cached here.
            _ => {}
        }
    }

    /// Drop everything — the `NotLeader`/heal trigger: once leadership
    /// moved, every answer from the old leader's tenure is suspect.
    pub fn clear(&self) {
        if !self.is_active() {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        g.epoch += 1;
        if !g.inodes.is_empty()
            || !g.regions.is_empty()
            || !g.paths.is_empty()
            || !g.readahead.is_empty()
        {
            self.invalidations.fetch_add(1, Ordering::Relaxed);
        }
        g.inodes.clear();
        g.regions.clear();
        g.paths.clear();
        g.readahead.clear();
    }

    // ------------------------------------------------------- readahead

    /// Serve `[offset, offset + len)` of `inode` from the readahead
    /// buffer when it is fully covered.  A partial overlap is a miss
    /// (the caller refetches, extending the buffer past the new cursor).
    pub fn readahead_take(&self, inode: InodeId, offset: u64, len: u64) -> Option<Vec<u8>> {
        if self.readahead_window == 0 || len == 0 {
            return None;
        }
        let mut g = self.inner.lock().unwrap();
        let tick = g.bump();
        let buf = g.readahead.get_mut(&inode)?;
        let end = buf.start + buf.data.len() as u64;
        if offset < buf.start || offset + len > end {
            return None;
        }
        buf.used = tick;
        let from = (offset - buf.start) as usize;
        self.hits.fetch_add(1, Ordering::Relaxed);
        Some(buf.data[from..from + len as usize].to_vec())
    }

    /// Stash the surplus bytes of an over-fetch for the next sequential
    /// read.  One buffer per inode, bounded count, LRU-evicted.
    pub fn readahead_put(&self, inode: InodeId, start: u64, data: Vec<u8>, as_of: u64) {
        if self.readahead_window == 0 || data.is_empty() {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        if g.epoch != as_of {
            return;
        }
        let used = g.bump();
        g.readahead.insert(inode, ReadAhead { start, data, used });
        if g.readahead.len() > MAX_READAHEAD_BUFFERS {
            let oldest = g
                .readahead
                .iter()
                .min_by_key(|(_, b)| b.used)
                .map(|(&id, _)| id);
            if let Some(oldest) = oldest {
                g.readahead.remove(&oldest);
            }
        }
    }
}

/// The versioned read-through contract for transactional reads (PR 9):
/// [`crate::meta::MetaTxn::get`] serves warm inode/region/path keys from
/// this cache with zero envelopes, recording the CACHED version in its
/// read set — commit-time validation catches staleness, so a stale hit
/// costs one conflict-retry, never serializability.  Dir/Sys keys are
/// never cached and always go to the wire.
impl TxnReadCache for MetaCache {
    fn lookup(&self, key: &Key) -> Option<(Option<Value>, u64)> {
        match key.space {
            Space::Inode => {
                let id = parse_inode_key(&key.key)?;
                self.get_inode_versioned(id)
                    .map(|(i, v)| (Some(Value::Inode((*i).clone())), v))
            }
            Space::Region => {
                let rid = parse_region_key(&key.key)?;
                self.get_region(rid)
                    .map(|(r, v)| (Some(Value::Region((*r).clone())), v))
            }
            Space::Path => self
                .get_path(&key.key)
                .map(|(id, v)| (Some(Value::PathEntry(id)), v)),
            _ => None,
        }
    }

    fn epoch(&self) -> u64 {
        MetaCache::epoch(self)
    }

    fn fill(&self, key: &Key, value: &Option<Value>, version: u64, as_of: u64) {
        match (key.space, value) {
            (Space::Inode, Some(Value::Inode(i))) => {
                if let Some(id) = parse_inode_key(&key.key) {
                    self.put_inode(id, &Arc::new(i.clone()), version, as_of);
                }
            }
            (Space::Region, Some(Value::Region(r))) => {
                if let Some(rid) = parse_region_key(&key.key) {
                    self.put_region(rid, &Arc::new(r.clone()), version, as_of);
                }
            }
            // Region absence is cached as an empty region at the
            // version of absence — the same convention as
            // `WtfClient::fetch_region` (an empty entry list and a
            // missing key resolve identically).
            (Space::Region, None) => {
                if let Some(rid) = parse_region_key(&key.key) {
                    self.put_region(rid, &Arc::new(RegionMeta::default()), version, as_of);
                }
            }
            (Space::Path, Some(Value::PathEntry(id))) => {
                self.put_path(&key.key, *id, version, as_of);
            }
            // Inode/path absence and Dir/Sys values are never cached
            // (a negative path entry would turn create/rename races
            // into stale NotFounds for plain lookups).
            _ => {}
        }
    }
}

/// Inverse of [`Key::inode`]'s `{id:016x}` encoding.
fn parse_inode_key(key: &str) -> Option<InodeId> {
    u64::from_str_radix(key, 16).ok()
}

/// Inverse of [`RegionId::key`]'s `{inode:016x}#{index:08x}` encoding.
fn parse_region_key(key: &str) -> Option<RegionId> {
    let (inode, index) = key.split_once('#')?;
    Some(RegionId::new(
        u64::from_str_radix(inode, 16).ok()?,
        u32::from_str_radix(index, 16).ok()?,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache() -> MetaCache {
        MetaCache::new(&Config::fast_read_test())
    }

    fn inode(id: InodeId) -> Arc<Inode> {
        Arc::new(Inode::new_file(id, 0o644, 2))
    }

    fn region() -> Arc<RegionMeta> {
        Arc::new(RegionMeta::default())
    }

    #[test]
    fn disabled_cache_is_inert() {
        let c = MetaCache::new(&Config::test());
        assert!(!c.is_active());
        c.put_inode(1, &inode(1), 5, c.epoch());
        assert!(c.get_inode(1).is_none());
        c.put_region(RegionId::new(1, 0), &region(), 1, c.epoch());
        assert!(c.get_region(RegionId::new(1, 0)).is_none());
        c.readahead_put(1, 0, vec![1, 2, 3], c.epoch());
        assert!(c.readahead_take(1, 0, 2).is_none());
        assert_eq!(c.hits() + c.misses(), 0);
    }

    #[test]
    fn put_get_round_trips_with_versions() {
        let c = cache();
        let mut i = Inode::new_file(7, 0o644, 2);
        i.len = 42;
        c.put_inode(7, &Arc::new(i), 3, c.epoch());
        assert_eq!(c.get_inode(7).unwrap().len, 42);
        let rid = RegionId::new(7, 1);
        c.put_region(rid, &region(), 9, c.epoch());
        assert_eq!(c.get_region(rid).unwrap().1, 9);
        assert_eq!(c.hits(), 2);
        assert!(c.get_inode(8).is_none());
        assert_eq!(c.misses(), 1);
    }

    #[test]
    fn fresh_inode_version_drops_the_files_regions() {
        let c = cache();
        c.put_inode(7, &inode(7), 1, c.epoch());
        c.put_region(RegionId::new(7, 0), &region(), 1, c.epoch());
        c.put_region(RegionId::new(8, 0), &region(), 1, c.epoch());
        // Same version: regions survive.
        c.put_inode(7, &inode(7), 1, c.epoch());
        assert!(c.get_region(RegionId::new(7, 0)).is_some());
        // New version: this file's regions are dropped, other files' stay.
        c.put_inode(7, &inode(7), 2, c.epoch());
        assert!(c.get_region(RegionId::new(7, 0)).is_none());
        assert!(c.get_region(RegionId::new(8, 0)).is_some());
    }

    #[test]
    fn key_invalidation_parses_the_store_encoding() {
        let c = cache();
        c.put_inode(0xab, &inode(0xab), 1, c.epoch());
        c.put_region(RegionId::new(0xab, 3), &region(), 1, c.epoch());
        c.readahead_put(0xab, 0, vec![1; 8], c.epoch());
        // A region key drops the region and the readahead, not the inode.
        c.invalidate_key(&Key::region(RegionId::new(0xab, 3)));
        assert!(c.get_region(RegionId::new(0xab, 3)).is_none());
        assert!(c.readahead_take(0xab, 0, 1).is_none());
        assert!(c.get_inode(0xab).is_some());
        // An inode key drops everything for the file.
        c.put_region(RegionId::new(0xab, 3), &region(), 2, c.epoch());
        c.invalidate_keys(&[Key::inode(0xab)]);
        assert!(c.get_inode(0xab).is_none());
        assert!(c.get_region(RegionId::new(0xab, 3)).is_none());
        assert!(c.invalidations() >= 2);
    }

    #[test]
    fn clear_drops_everything() {
        let c = cache();
        c.put_inode(1, &inode(1), 1, c.epoch());
        c.readahead_put(1, 0, vec![0; 4], c.epoch());
        c.clear();
        assert!(c.get_inode(1).is_none());
        assert!(c.readahead_take(1, 0, 4).is_none());
    }

    #[test]
    fn capacity_is_bounded() {
        let mut cfg = Config::fast_read_test();
        cfg.metadata_cache_entries = 64;
        let c = MetaCache::new(&cfg);
        for id in 0..1000u64 {
            c.put_inode(id, &inode(id), 1, c.epoch());
        }
        let g = c.inner.lock().unwrap();
        assert!(g.inodes.len() <= 64, "{} entries retained", g.inodes.len());
        // The most recent entries survive eviction.
        assert!(g.inodes.contains_key(&999));
    }

    #[test]
    fn older_concurrent_puts_never_downgrade_a_newer_version() {
        // Two threads of one clone-shared client fetch concurrently;
        // the older fetch's put lands last — it must be dropped, along
        // with its would-be region wipe.
        let c = cache();
        let e = c.epoch();
        c.put_inode(7, &inode(7), 5, e);
        c.put_region(RegionId::new(7, 0), &region(), 4, e);
        c.put_inode(7, &inode(7), 3, e); // slower, older fetch
        let g = c.inner.lock().unwrap();
        assert_eq!(g.inodes[&7].version, 5, "older inode put won");
        assert!(g.regions.contains_key(&RegionId::new(7, 0)), "regions wiped by stale put");
        drop(g);
        c.put_region(RegionId::new(7, 0), &region(), 2, e);
        assert_eq!(c.get_region(RegionId::new(7, 0)).unwrap().1, 4);
    }

    #[test]
    fn stale_epoch_puts_are_dropped() {
        // An in-flight fetch that started before an invalidation must
        // not re-install pre-commit state after it (clone-shared
        // clients race their own commits against reads).
        let c = cache();
        let as_of = c.epoch();
        c.invalidate_key(&Key::inode(7)); // the commit wins the race
        c.put_inode(7, &inode(7), 1, as_of); // the fetch's late put
        assert!(c.get_inode(7).is_none(), "stale put survived");
        c.put_region(RegionId::new(7, 0), &region(), 1, as_of);
        assert!(c.get_region(RegionId::new(7, 0)).is_none());
        c.readahead_put(7, 0, vec![1; 4], as_of);
        assert!(c.readahead_take(7, 0, 4).is_none());
        // A put with the CURRENT epoch lands.
        c.put_inode(7, &inode(7), 1, c.epoch());
        assert!(c.get_inode(7).is_some());
        // clear() also moves the epoch.
        let as_of = c.epoch();
        c.clear();
        c.put_inode(8, &inode(8), 1, as_of);
        assert!(c.get_inode(8).is_none());
    }

    #[test]
    fn ttl_expires_entries_into_misses() {
        let mut cfg = Config::fast_read_test();
        cfg.cache_ttl = Duration::from_millis(1);
        let c = MetaCache::new(&cfg);
        c.put_inode(7, &inode(7), 1, c.epoch());
        c.put_region(RegionId::new(7, 0), &region(), 1, c.epoch());
        c.put_path("/f", 7, 1, c.epoch());
        assert!(c.get_inode(7).is_some(), "fresh entry serves");
        std::thread::sleep(Duration::from_millis(3));
        assert!(c.get_inode(7).is_none(), "expired inode served");
        assert!(c.get_region(RegionId::new(7, 0)).is_none(), "expired region served");
        assert!(c.get_path("/f").is_none(), "expired path served");
        // A re-fill after expiry serves again (expiry is not poison).
        c.put_inode(7, &inode(7), 2, c.epoch());
        assert!(c.get_inode(7).is_some());
    }

    #[test]
    fn path_entries_round_trip_and_invalidate() {
        let c = cache();
        c.put_path("/a/b", 42, 7, c.epoch());
        assert_eq!(c.get_path("/a/b"), Some((42, 7)));
        // Version-monotone: an older concurrent resolve never wins.
        c.put_path("/a/b", 41, 6, c.epoch());
        assert_eq!(c.get_path("/a/b"), Some((42, 7)));
        // Path-key invalidation drops exactly that entry.
        c.put_path("/a/c", 43, 1, c.epoch());
        c.invalidate_key(&Key::path("/a/b"));
        assert!(c.get_path("/a/b").is_none());
        assert_eq!(c.get_path("/a/c"), Some((43, 1)));
        // clear() drops the rest; stale-epoch puts stay dropped.
        let as_of = c.epoch();
        c.clear();
        c.put_path("/a/d", 44, 1, as_of);
        assert!(c.get_path("/a/d").is_none(), "stale-epoch path put landed");
    }

    #[test]
    fn txn_read_through_serves_and_fills_by_key() {
        use crate::types::Value;
        let c = cache();
        let as_of = TxnReadCache::epoch(&c);
        // Wire-read fills route into the typed maps...
        let mut i = Inode::new_file(7, 0o644, 2);
        i.len = 99;
        c.fill(&Key::inode(7), &Some(Value::Inode(i)), 5, as_of);
        c.fill(&Key::path("/f"), &Some(Value::PathEntry(7)), 3, as_of);
        c.fill(&Key::region(RegionId::new(7, 0)), &None, 2, as_of);
        // ...and lookups come back as (value, version) read-set pairs.
        match c.lookup(&Key::inode(7)) {
            Some((Some(Value::Inode(i)), 5)) => assert_eq!(i.len, 99),
            other => panic!("inode lookup: {other:?}"),
        }
        assert_eq!(
            c.lookup(&Key::path("/f")),
            Some((Some(Value::PathEntry(7)), 3))
        );
        // Region absence round-trips as an empty region at the version
        // of absence.
        match c.lookup(&Key::region(RegionId::new(7, 0))) {
            Some((Some(Value::Region(r)), 2)) => assert!(r.entries.is_empty()),
            other => panic!("region lookup: {other:?}"),
        }
        // Never-cached spaces stay on the wire; absent inodes are not
        // negatively cached.
        assert!(c.lookup(&Key::dir(1)).is_none());
        c.fill(&Key::inode(8), &None, 1, as_of);
        assert!(c.lookup(&Key::inode(8)).is_none());
        // Invalidation is visible through the trait surface.
        c.invalidate_key(&Key::inode(7));
        assert!(c.lookup(&Key::inode(7)).is_none());
    }

    #[test]
    fn unparseable_invalidation_clears_readahead_conservatively() {
        let c = cache();
        c.readahead_put(5, 0, vec![1; 8], c.epoch());
        c.readahead_put(6, 0, vec![2; 8], c.epoch());
        let before = c.epoch();
        c.invalidate_key(&Key::new(Space::Inode, "not-hex"));
        assert!(c.epoch() > before, "epoch must move");
        assert!(
            c.readahead_take(5, 0, 1).is_none() && c.readahead_take(6, 0, 1).is_none(),
            "a buffer survived an unattributable inode invalidation"
        );
    }

    #[test]
    fn readahead_serves_only_fully_covered_windows() {
        let c = cache();
        c.readahead_put(5, 100, (0..50u8).collect(), c.epoch());
        assert_eq!(c.readahead_take(5, 110, 5).unwrap(), vec![10, 11, 12, 13, 14]);
        assert_eq!(c.readahead_take(5, 100, 50).unwrap().len(), 50);
        assert!(c.readahead_take(5, 99, 5).is_none(), "before the buffer");
        assert!(c.readahead_take(5, 148, 5).is_none(), "past the end");
        assert!(c.readahead_take(6, 100, 5).is_none(), "other file");
    }

    #[test]
    fn readahead_buffer_count_is_bounded() {
        let c = cache();
        for id in 0..(MAX_READAHEAD_BUFFERS as u64 + 4) {
            c.readahead_put(id, 0, vec![id as u8; 4], c.epoch());
        }
        let g = c.inner.lock().unwrap();
        assert!(g.readahead.len() <= MAX_READAHEAD_BUFFERS);
    }
}
