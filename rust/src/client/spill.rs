//! Tier-2 metadata garbage collection: spill a compacted-but-still-large
//! entry list into a slice on the storage servers and replace it with a
//! pointer (§2.8).  Random-write workloads defeat tier-1 compaction; this
//! keeps the metadata object small regardless.
//!
//! The encoding is a self-describing little-endian binary format (the
//! offline build has no serde); [`encode_entries`]/[`decode_entries`]
//! round-trip exactly.

use crate::error::{Error, Result};
use crate::types::{Placement, RegionEntry, SliceData, SlicePtr};

const MAGIC: &[u8; 8] = b"WTFSPILL";
const VERSION: u32 = 1;

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            return Err(Error::CorruptMetadata("truncated spill slice".into()));
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

/// Serialize an entry list (which must already be fully resolved —
/// `Placement::Eof` is rejected, it never appears in committed lists).
pub fn encode_entries(entries: &[RegionEntry]) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(16 + entries.len() * 48);
    out.extend_from_slice(MAGIC);
    put_u32(&mut out, VERSION);
    put_u32(&mut out, entries.len() as u32);
    for e in entries {
        match e.placement {
            Placement::At(at) => {
                out.push(0);
                put_u64(&mut out, at);
            }
            Placement::Eof => {
                return Err(Error::CorruptMetadata(
                    "cannot spill unresolved EOF-relative entry".into(),
                ))
            }
        }
        put_u64(&mut out, e.len);
        match &e.data {
            SliceData::Hole => out.push(1),
            SliceData::Stored(replicas) => {
                out.push(0);
                put_u32(&mut out, replicas.len() as u32);
                for p in replicas {
                    put_u32(&mut out, p.server);
                    put_u32(&mut out, p.backing);
                    put_u64(&mut out, p.offset);
                    put_u64(&mut out, p.len);
                }
            }
        }
    }
    Ok(out)
}

/// Inverse of [`encode_entries`].
pub fn decode_entries(bytes: &[u8]) -> Result<Vec<RegionEntry>> {
    let mut c = Cursor { b: bytes, i: 0 };
    if c.take(8)? != MAGIC {
        return Err(Error::CorruptMetadata("bad spill magic".into()));
    }
    let version = c.u32()?;
    if version != VERSION {
        return Err(Error::CorruptMetadata(format!(
            "unsupported spill version {version}"
        )));
    }
    let count = c.u32()? as usize;
    let mut entries = Vec::with_capacity(count.min(1 << 20));
    for _ in 0..count {
        let tag = c.u8()?;
        let placement = match tag {
            0 => Placement::At(c.u64()?),
            _ => return Err(Error::CorruptMetadata("bad placement tag".into())),
        };
        let len = c.u64()?;
        let data = match c.u8()? {
            1 => SliceData::Hole,
            0 => {
                let n = c.u32()? as usize;
                let mut replicas = Vec::with_capacity(n.min(64));
                for _ in 0..n {
                    replicas.push(SlicePtr {
                        server: c.u32()?,
                        backing: c.u32()?,
                        offset: c.u64()?,
                        len: c.u64()?,
                    });
                }
                SliceData::Stored(replicas)
            }
            _ => return Err(Error::CorruptMetadata("bad data tag".into())),
        };
        entries.push(RegionEntry {
            placement,
            len,
            data,
        });
    }
    if c.i != bytes.len() {
        return Err(Error::CorruptMetadata("trailing bytes in spill".into()));
    }
    Ok(entries)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<RegionEntry> {
        vec![
            RegionEntry {
                placement: Placement::At(0),
                len: 100,
                data: SliceData::Stored(vec![
                    SlicePtr {
                        server: 3,
                        backing: 1,
                        offset: 4096,
                        len: 100,
                    },
                    SlicePtr {
                        server: 7,
                        backing: 0,
                        offset: 0,
                        len: 100,
                    },
                ]),
            },
            RegionEntry {
                placement: Placement::At(100),
                len: 50,
                data: SliceData::Hole,
            },
            RegionEntry {
                placement: Placement::At(u64::MAX / 2),
                len: u64::MAX / 4,
                data: SliceData::Stored(vec![]),
            },
        ]
    }

    #[test]
    fn round_trip() {
        let entries = sample();
        let bytes = encode_entries(&entries).unwrap();
        assert_eq!(decode_entries(&bytes).unwrap(), entries);
    }

    #[test]
    fn empty_list_round_trips() {
        let bytes = encode_entries(&[]).unwrap();
        assert_eq!(decode_entries(&bytes).unwrap(), vec![]);
    }

    #[test]
    fn rejects_eof_entries() {
        let e = RegionEntry {
            placement: Placement::Eof,
            len: 1,
            data: SliceData::Hole,
        };
        assert!(encode_entries(&[e]).is_err());
    }

    #[test]
    fn rejects_corruption() {
        let entries = sample();
        let bytes = encode_entries(&entries).unwrap();
        assert!(decode_entries(&bytes[..bytes.len() - 1]).is_err());
        assert!(decode_entries(&bytes[1..]).is_err());
        let mut truncated = bytes.clone();
        truncated.truncate(10);
        assert!(decode_entries(&truncated).is_err());
        let mut extra = bytes.clone();
        extra.push(0);
        assert!(decode_entries(&extra).is_err());
        let mut bad_version = bytes;
        bad_version[8] = 99;
        assert!(decode_entries(&bad_version).is_err());
    }
}
