//! Metadata maintenance: the first two GC tiers of §2.8, driven through
//! the client.
//!
//! * **Tier 1** — [`WtfClient::compact_region`]: read the region list,
//!   compact it (pure metadata), and CAS it back in one transaction.  No
//!   storage I/O at all; the overlaid slices become garbage for tier 3.
//! * **Tier 2** — [`WtfClient::spill_region`]: when the *compacted* list
//!   is still too fragmented (random writes defeat locality), serialize
//!   it into a slice and swap a pointer into its place.

use super::compact;
use super::spill;
use super::WtfClient;
use crate::error::{Error, Result};
use crate::meta::MetaOp;
use crate::types::{InodeId, Key, RegionId, RegionMeta};

/// Outcome of one region compaction.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CompactReport {
    pub entries_before: usize,
    pub entries_after: usize,
    /// True when the region was spilled to a slice (tier 2).
    pub spilled: bool,
}

impl WtfClient {
    /// Tier-1 compaction of one region.  Retries the CAS on conflict.
    /// The fetch bypasses the read cache: a CAS against a cached
    /// version could never succeed once the region moved.
    pub fn compact_region(&self, rid: RegionId) -> Result<CompactReport> {
        self.with_retry("compact_region", || {
            let (region, version) = self.fetch_region_fresh(rid)?;
            let before = region.entries.len();
            let compacted = compact::compact(&region);
            let report = CompactReport {
                entries_before: before,
                entries_after: compacted.entries.len(),
                spilled: false,
            };
            if compacted.entries == region.entries {
                return Ok(report); // nothing to do
            }
            let mut t = self.meta_txn();
            t.push(MetaOp::RegionSwap {
                key: Key::region(rid),
                expected_version: version,
                region: compacted,
            });
            self.commit_txn(t)?;
            Ok(report)
        })
    }

    /// Tier-2 spill of one region: compact, serialize the entry list
    /// (including any previously spilled base) into a replicated slice,
    /// and swap the region for a pointer + empty list.
    pub fn spill_region(&self, rid: RegionId) -> Result<CompactReport> {
        self.with_retry("spill_region", || {
            let (region, version) = self.fetch_region_fresh(rid)?;
            let before = region.entries.len();
            // Materialize the full view (spilled base + live list), then
            // compact it to the minimal form.
            let entries = self.region_entries(&region)?;
            let resolved = compact::fuse_extents(compact::resolve_entries(&entries));
            let minimal: Vec<crate::types::RegionEntry> = resolved
                .into_iter()
                .map(|e| crate::types::RegionEntry {
                    placement: crate::types::Placement::At(e.start),
                    len: e.len,
                    data: e.data,
                })
                .collect();
            let bytes = spill::encode_entries(&minimal)?;
            let replicas =
                self.create_replicated(&bytes, rid, self.config.replication)?;
            let swapped = RegionMeta {
                spill: Some(replicas),
                entries: Vec::new(),
                eof: region.eof,
            };
            let mut t = self.meta_txn();
            t.push(MetaOp::RegionSwap {
                key: Key::region(rid),
                expected_version: version,
                region: swapped,
            });
            self.commit_txn(t)?;
            Ok(CompactReport {
                entries_before: before,
                entries_after: 0,
                spilled: true,
            })
        })
    }

    /// Compact every written region of a file; spill regions whose
    /// compacted form still exceeds `spill_threshold` entries.
    pub fn compact_file(&self, inode: InodeId, spill_threshold: usize) -> Result<Vec<CompactReport>> {
        let meta = self.fetch_inode(inode)?;
        let mut reports = Vec::new();
        for idx in 0..=meta.highest_region {
            let rid = RegionId::new(inode, idx);
            let r = self.compact_region(rid)?;
            if r.entries_after > spill_threshold {
                reports.push(self.spill_region(rid)?);
            } else {
                reports.push(r);
            }
        }
        Ok(reports)
    }

    /// Total metadata entries across a file's regions (fragmentation
    /// metric for the compaction benchmarks).
    pub fn file_fragmentation(&self, inode: InodeId) -> Result<usize> {
        let meta = self.fetch_inode(inode)?;
        let mut total = 0;
        for idx in 0..=meta.highest_region {
            let (region, _) = self.fetch_region(RegionId::new(inode, idx))?;
            total += region.entries.len();
        }
        Ok(total)
    }
}

// Re-export for bench/tests convenience.
pub use CompactReport as RegionCompactReport;

#[allow(unused_imports)]
use Error as _ErrorUnused;

#[cfg(test)]
mod tests {
    use crate::client::testutil::small_cluster;
    use crate::types::RegionId;
    use crate::util::Rng;

    #[test]
    fn compaction_shrinks_sequential_write_metadata() {
        let cluster = small_cluster();
        let c = cluster.client();
        let mut f = c.create("/seq").unwrap();
        // 32 sequential small writes into region 0.
        for i in 0..32 {
            c.write(&mut f, &[i as u8; 64]).unwrap();
        }
        let rid = RegionId::new(f.inode(), 0);
        let before = c.fetch_region(rid).unwrap().0.entries.len();
        assert_eq!(before, 32);
        let report = c.compact_region(rid).unwrap();
        assert_eq!(report.entries_before, 32);
        // Locality-aware placement makes sequential slices adjacent:
        // they fuse down to very few pointers.
        assert!(
            report.entries_after <= 4,
            "compacted to {}",
            report.entries_after
        );
        // Contents unchanged.
        let back = c.read_at(&f, 0, 32 * 64).unwrap();
        for i in 0..32 {
            assert!(back[i * 64..(i + 1) * 64].iter().all(|&b| b == i as u8));
        }
    }

    #[test]
    fn compaction_drops_overwritten_slices() {
        let cluster = small_cluster();
        let c = cluster.client();
        let f = c.create("/ow").unwrap();
        for _ in 0..10 {
            c.write_at(f.inode(), 0, &[7u8; 100]).unwrap();
        }
        let rid = RegionId::new(f.inode(), 0);
        let report = c.compact_region(rid).unwrap();
        assert_eq!(report.entries_before, 10);
        assert_eq!(report.entries_after, 1);
    }

    #[test]
    fn spill_preserves_contents() {
        let cluster = small_cluster();
        let c = cluster.client();
        let f = c.create("/frag").unwrap();
        let mut rng = Rng::new(11);
        let mut reference = vec![0u8; 2048];
        // Random writes -> fragmented metadata that compaction can't fuse.
        for _ in 0..40 {
            let off = rng.next_below(2048 - 32);
            let mut data = vec![0u8; 32];
            rng.fill_bytes(&mut data);
            c.write_at(f.inode(), off, &data).unwrap();
            reference[off as usize..off as usize + 32].copy_from_slice(&data);
        }
        // Pad reference to file length semantics (max end written).
        let flen = c.stat("/frag").unwrap().len;
        let rid = RegionId::new(f.inode(), 0);
        let report = c.spill_region(rid).unwrap();
        assert!(report.spilled);
        assert_eq!(c.fetch_region(rid).unwrap().0.entries.len(), 0);
        // Reads traverse the spilled base transparently.
        let back = c.read_at(&f, 0, flen).unwrap();
        assert_eq!(back, &reference[..flen as usize]);
        // Writes after the spill overlay on top of it.
        c.write_at(f.inode(), 0, b"!!").unwrap();
        let back = c.read_at(&f, 0, 2).unwrap();
        assert_eq!(back, b"!!");
    }

    #[test]
    fn compact_file_spills_only_fragmented_regions() {
        let cluster = small_cluster();
        let c = cluster.client();
        let mut f = c.create("/mixed").unwrap();
        let rs = c.config().region_size;
        // Region 0: sequential (compacts well). Region 1: random.
        for _ in 0..16 {
            c.write(&mut f, &[1u8; 64]).unwrap();
        }
        let mut rng = Rng::new(3);
        for _ in 0..16 {
            let off = rs + rng.next_below(1000);
            c.write_at(f.inode(), off, &[2u8; 16]).unwrap();
        }
        let reports = c.compact_file(f.inode(), 8).unwrap();
        assert_eq!(reports.len(), 2);
        assert!(!reports[0].spilled);
        assert!(reports[1].spilled);
    }

    #[test]
    fn fragmentation_metric_counts_entries() {
        let cluster = small_cluster();
        let c = cluster.client();
        let mut f = c.create("/fm").unwrap();
        for _ in 0..5 {
            c.write(&mut f, &[0u8; 10]).unwrap();
        }
        assert_eq!(c.file_fragmentation(f.inode()).unwrap(), 5);
    }
}
