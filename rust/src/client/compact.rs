//! Overlay resolution and metadata compaction (§2.1, Fig. 2; §2.8 tier 1).
//!
//! A region's metadata is an ordered list of slice entries; where entries
//! overlap, the *latest* wins.  [`resolve`] turns the list into the
//! minimal sorted, disjoint extent sequence needed to reconstruct the
//! region's bytes; [`compact`] rebuilds a `RegionMeta` from that sequence
//! — fusing slices that locality-aware placement made adjacent on disk —
//! and is the unit of tier-1 garbage collection (no storage I/O at all).

use crate::types::{Placement, RegionEntry, RegionMeta, SliceData};
use std::collections::BTreeMap;

/// One resolved extent of a region: bytes `[start, start+len)` come from
/// `data` (or are zeros for holes).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Extent {
    /// Region-relative start offset.
    pub start: u64,
    pub len: u64,
    pub data: SliceData,
}

impl Extent {
    pub fn end(&self) -> u64 {
        self.start + self.len
    }

    /// Sub-extent clipped to `[from, to)` (absolute region offsets).
    pub fn clip(&self, from: u64, to: u64) -> Option<Extent> {
        let s = self.start.max(from);
        let e = self.end().min(to);
        if s >= e {
            return None;
        }
        Some(Extent {
            start: s,
            len: e - s,
            data: self.data.slice(s - self.start, e - self.start),
        })
    }
}

/// Resolve an entry list (already including any spilled base, see
/// `client::spill`) into sorted, disjoint extents.  Later entries take
/// precedence over earlier ones.  `Placement::Eof` entries must have been
/// resolved to explicit offsets by the metadata store; they never appear
/// in committed lists.
pub fn resolve_entries(entries: &[RegionEntry]) -> Vec<Extent> {
    // Interval map keyed by start offset; values are extents.
    let mut map: BTreeMap<u64, Extent> = BTreeMap::new();
    for entry in entries {
        let at = match entry.placement {
            Placement::At(a) => a,
            Placement::Eof => {
                debug_assert!(false, "committed entry with unresolved Eof placement");
                continue;
            }
        };
        if entry.len == 0 {
            continue;
        }
        let (new_start, new_end) = (at, at + entry.len);

        // Find extents overlapping [new_start, new_end) and trim them.
        // Candidates: the last extent starting <= new_start, plus all
        // extents starting inside the new range.
        let mut to_remove: Vec<u64> = Vec::new();
        let mut to_insert: Vec<Extent> = Vec::new();
        // Left neighbor reaching into the new range.
        if let Some((&s, ext)) = map.range(..new_start).next_back() {
            if ext.end() > new_start {
                to_remove.push(s);
                // Left remainder survives.
                to_insert.push(Extent {
                    start: s,
                    len: new_start - s,
                    data: ext.data.slice(0, new_start - s),
                });
                // Right remainder survives if it extends past the new end.
                if ext.end() > new_end {
                    to_insert.push(Extent {
                        start: new_end,
                        len: ext.end() - new_end,
                        data: ext.data.slice(new_end - s, ext.end() - s),
                    });
                }
            }
        }
        // Extents starting inside the new range are (partially) shadowed.
        let inside: Vec<u64> = map.range(new_start..new_end).map(|(&s, _)| s).collect();
        for s in inside {
            let ext = &map[&s];
            to_remove.push(s);
            if ext.end() > new_end {
                to_insert.push(Extent {
                    start: new_end,
                    len: ext.end() - new_end,
                    data: ext.data.slice(new_end - s, ext.end() - s),
                });
            }
        }
        for s in to_remove {
            map.remove(&s);
        }
        for e in to_insert {
            map.insert(e.start, e);
        }
        map.insert(
            new_start,
            Extent {
                start: new_start,
                len: entry.len,
                data: entry.data.clone(),
            },
        );
    }
    map.into_values().collect()
}

/// Fuse adjacent resolved extents whose replica pointer lists are
/// pairwise adjacent on disk — the payoff of locality-aware placement
/// (§2.7): a sequential writer's many slices compact to one pointer.
pub fn fuse_extents(extents: Vec<Extent>) -> Vec<Extent> {
    let mut out: Vec<Extent> = Vec::with_capacity(extents.len());
    for e in extents {
        if let Some(last) = out.last_mut() {
            if last.end() == e.start {
                match (&last.data, &e.data) {
                    (SliceData::Hole, SliceData::Hole) => {
                        last.len += e.len;
                        continue;
                    }
                    (SliceData::Stored(a), SliceData::Stored(b))
                        if a.len() == b.len()
                            && a.iter().zip(b.iter()).all(|(x, y)| x.is_adjacent(y)) =>
                    {
                        let fused = a
                            .iter()
                            .zip(b.iter())
                            .map(|(x, y)| x.fuse(y))
                            .collect();
                        last.data = SliceData::Stored(fused);
                        last.len += e.len;
                        continue;
                    }
                    _ => {}
                }
            }
        }
        out.push(e);
    }
    out
}

/// Tier-1 compaction: resolved + fused extents re-encoded as the minimal
/// entry list.  The resulting region reconstructs identical bytes.
pub fn compact(region: &RegionMeta) -> RegionMeta {
    let extents = fuse_extents(resolve_entries(&region.entries));
    RegionMeta {
        spill: region.spill.clone(),
        entries: extents
            .into_iter()
            .map(|e| RegionEntry {
                placement: Placement::At(e.start),
                len: e.len,
                data: e.data,
            })
            .collect(),
        eof: region.eof,
    }
}

/// Clip resolved extents to the window `[from, to)`, preserving order.
pub fn clip_extents(extents: &[Extent], from: u64, to: u64) -> Vec<Extent> {
    extents.iter().filter_map(|e| e.clip(from, to)).collect()
}

/// Tile the window `[from, to)` of a resolved extent list: the clipped
/// stored/hole extents plus synthesized `Hole` tiles for every gap, so
/// the result covers the window exactly, in order, with no overlap.
/// This is the one extent-window walk shared by the read and yank paths
/// (`read_inode_at` / `yank_at` build on it via
/// [`crate::client::WtfClient`]'s `resolve_window`).
pub fn tile_window(extents: &[Extent], from: u64, to: u64) -> Vec<Extent> {
    let mut out = Vec::new();
    let mut cursor = from;
    for e in clip_extents(extents, from, to) {
        if e.start > cursor {
            out.push(Extent {
                start: cursor,
                len: e.start - cursor,
                data: SliceData::Hole,
            });
        }
        cursor = e.end();
        out.push(e);
    }
    if cursor < to {
        out.push(Extent {
            start: cursor,
            len: to - cursor,
            data: SliceData::Hole,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::SlicePtr;

    fn ptr(backing: u32, offset: u64, len: u64) -> SlicePtr {
        SlicePtr {
            server: 1,
            backing,
            offset,
            len,
        }
    }

    fn entry(at: u64, len: u64, backing: u32, off: u64) -> RegionEntry {
        RegionEntry {
            placement: Placement::At(at),
            len,
            data: SliceData::Stored(vec![ptr(backing, off, len)]),
        }
    }

    fn region(entries: Vec<RegionEntry>) -> RegionMeta {
        let eof = entries
            .iter()
            .map(|e| match e.placement {
                Placement::At(a) => a + e.len,
                Placement::Eof => 0,
            })
            .max()
            .unwrap_or(0);
        RegionMeta {
            spill: None,
            entries,
            eof,
        }
    }

    /// Reference implementation: byte-level overlay.
    fn resolve_bytewise(entries: &[RegionEntry], size: u64) -> Vec<Option<(usize, u64)>> {
        // For each byte: (entry index, offset within entry) of the winner.
        let mut bytes = vec![None; size as usize];
        for (i, e) in entries.iter().enumerate() {
            let Placement::At(at) = e.placement else {
                continue;
            };
            for b in 0..e.len {
                bytes[(at + b) as usize] = Some((i, b));
            }
        }
        bytes
    }

    /// Check `resolve_entries` against the byte-level oracle.
    fn check_against_oracle(entries: &[RegionEntry], size: u64) {
        let extents = resolve_entries(entries);
        let oracle = resolve_bytewise(entries, size);
        // Disjoint + sorted.
        for w in extents.windows(2) {
            assert!(w[0].end() <= w[1].start, "overlap: {w:?}");
        }
        // Every byte maps to the same source as the oracle.
        let mut covered = vec![false; size as usize];
        for e in &extents {
            for b in 0..e.len {
                let abs = (e.start + b) as usize;
                covered[abs] = true;
                let got = match &e.data {
                    SliceData::Stored(v) => Some(v[0].offset + b),
                    SliceData::Hole => None,
                };
                let want = oracle[abs].map(|(i, off)| match &entries[i].data {
                    SliceData::Stored(v) => v[0].offset + off,
                    SliceData::Hole => u64::MAX,
                });
                let want = match want {
                    Some(u64::MAX) => None,
                    w => w,
                };
                assert_eq!(got, want, "byte {abs}");
            }
        }
        for (i, c) in covered.iter().enumerate() {
            assert_eq!(*c, oracle[i].is_some(), "coverage at byte {i}");
        }
    }

    #[test]
    fn paper_figure_2_example() {
        // A@[0,2), B@[2,4), C@[1,3), D@[2,3), E@[2,3) (MB -> use bytes).
        let mb = 1u64; // scale factor irrelevant
        let entries = vec![
            entry(0, 2 * mb, 0, 0),   // A
            entry(2 * mb, 2 * mb, 0, 100), // B
            entry(1 * mb, 2 * mb, 0, 200), // C
            entry(2 * mb, 1 * mb, 0, 300), // D
            entry(2 * mb, 1 * mb, 0, 400), // E
        ];
        let extents = resolve_entries(&entries);
        // Compacted: A@[0,1), C@[1,2), E@[2,3), B@[3,4).
        assert_eq!(extents.len(), 4);
        assert_eq!(
            extents.iter().map(|e| (e.start, e.len)).collect::<Vec<_>>(),
            vec![(0, mb), (mb, mb), (2 * mb, mb), (3 * mb, mb)]
        );
        // Sources: A(0), C(200), E(400), B(101).
        let src = |e: &Extent| match &e.data {
            SliceData::Stored(v) => v[0].offset,
            _ => panic!(),
        };
        assert_eq!(src(&extents[0]), 0);
        assert_eq!(src(&extents[1]), 200);
        assert_eq!(src(&extents[2]), 400);
        assert_eq!(src(&extents[3]), 101);
        check_against_oracle(&entries, 4 * mb);
    }

    #[test]
    fn later_entries_win_and_split_earlier() {
        let entries = vec![entry(0, 100, 0, 0), entry(40, 20, 1, 0)];
        let extents = resolve_entries(&entries);
        assert_eq!(extents.len(), 3);
        assert_eq!((extents[0].start, extents[0].len), (0, 40));
        assert_eq!((extents[1].start, extents[1].len), (40, 20));
        assert_eq!((extents[2].start, extents[2].len), (60, 40));
        // Right remainder points into the original slice at offset 60.
        match &extents[2].data {
            SliceData::Stored(v) => assert_eq!(v[0].offset, 60),
            _ => panic!(),
        }
        check_against_oracle(&entries, 100);
    }

    #[test]
    fn gaps_are_preserved() {
        let entries = vec![entry(10, 5, 0, 0), entry(50, 5, 0, 100)];
        let extents = resolve_entries(&entries);
        assert_eq!(extents.len(), 2);
        assert_eq!(extents[0].start, 10);
        assert_eq!(extents[1].start, 50);
        check_against_oracle(&entries, 60);
    }

    #[test]
    fn holes_overlay_like_writes() {
        let entries = vec![
            entry(0, 100, 0, 0),
            RegionEntry {
                placement: Placement::At(20),
                len: 30,
                data: SliceData::Hole,
            },
        ];
        let extents = resolve_entries(&entries);
        assert_eq!(extents.len(), 3);
        assert!(extents[1].data.is_hole());
        check_against_oracle(&entries, 100);
    }

    #[test]
    fn fuse_rejoins_sequential_writes() {
        // Sequential writer: slices adjacent on disk (same backing).
        let entries = vec![
            entry(0, 10, 0, 0),
            entry(10, 10, 0, 10),
            entry(20, 10, 0, 20),
        ];
        let fused = fuse_extents(resolve_entries(&entries));
        assert_eq!(fused.len(), 1);
        assert_eq!(fused[0].len, 30);
        match &fused[0].data {
            SliceData::Stored(v) => {
                assert_eq!(v[0], ptr(0, 0, 30));
            }
            _ => panic!(),
        }
    }

    #[test]
    fn fuse_respects_non_adjacency() {
        let entries = vec![entry(0, 10, 0, 0), entry(10, 10, 0, 50)];
        let fused = fuse_extents(resolve_entries(&entries));
        assert_eq!(fused.len(), 2);
        let entries = vec![entry(0, 10, 0, 0), entry(10, 10, 1, 10)];
        assert_eq!(fuse_extents(resolve_entries(&entries)).len(), 2);
    }

    #[test]
    fn compact_preserves_contents_and_shrinks() {
        let mut entries = Vec::new();
        // 20 overlapping writes.
        for i in 0..20u64 {
            entries.push(entry(i * 5, 10, 0, i * 10));
        }
        let r = region(entries.clone());
        let c = compact(&r);
        assert!(c.entries.len() <= r.entries.len());
        assert_eq!(c.eof, r.eof);
        // Resolving the compacted region yields identical extents.
        assert_eq!(
            resolve_entries(&c.entries),
            fuse_extents(resolve_entries(&r.entries))
        );
        // Compaction is idempotent.
        let cc = compact(&c);
        assert_eq!(cc.entries, c.entries);
    }

    #[test]
    fn clip_extents_windows() {
        let entries = vec![entry(0, 100, 0, 0)];
        let extents = resolve_entries(&entries);
        let clipped = clip_extents(&extents, 30, 60);
        assert_eq!(clipped.len(), 1);
        assert_eq!((clipped[0].start, clipped[0].len), (30, 30));
        match &clipped[0].data {
            SliceData::Stored(v) => assert_eq!(v[0].offset, 30),
            _ => panic!(),
        }
        assert!(clip_extents(&extents, 100, 200).is_empty());
        assert!(clip_extents(&extents, 60, 60).is_empty());
    }

    #[test]
    fn tile_window_covers_exactly_with_holes() {
        let entries = vec![entry(10, 5, 0, 0), entry(50, 5, 0, 100)];
        let extents = resolve_entries(&entries);
        let tiles = tile_window(&extents, 0, 60);
        // hole[0,10) + stored[10,15) + hole[15,50) + stored[50,55) + hole[55,60)
        assert_eq!(tiles.len(), 5);
        let mut cursor = 0;
        for t in &tiles {
            assert_eq!(t.start, cursor, "tiles must cover without gaps");
            cursor = t.end();
        }
        assert_eq!(cursor, 60);
        assert!(tiles[0].data.is_hole() && tiles[2].data.is_hole() && tiles[4].data.is_hole());
        assert!(!tiles[1].data.is_hole() && !tiles[3].data.is_hole());
        // A window fully inside one extent tiles to just the clip.
        let inner = tile_window(&extents, 11, 14);
        assert_eq!(inner.len(), 1);
        assert_eq!((inner[0].start, inner[0].len), (11, 3));
        // An empty window tiles to nothing.
        assert!(tile_window(&extents, 20, 20).is_empty());
        // A window past every extent is one hole.
        let past = tile_window(&extents, 100, 110);
        assert_eq!(past.len(), 1);
        assert!(past[0].data.is_hole());
        assert_eq!((past[0].start, past[0].len), (100, 10));
    }

    #[test]
    fn randomized_overlays_match_bytewise_oracle() {
        let mut rng = crate::util::Rng::new(0xC0FFEE);
        for round in 0..50 {
            let n = 1 + (rng.next_below(30) as usize);
            let mut entries = Vec::new();
            for i in 0..n {
                let at = rng.next_below(200);
                let len = 1 + rng.next_below(50);
                if rng.next_below(5) == 0 {
                    entries.push(RegionEntry {
                        placement: Placement::At(at),
                        len,
                        data: SliceData::Hole,
                    });
                } else {
                    entries.push(entry(at, len, (i % 3) as u32, i as u64 * 1000));
                }
            }
            check_against_oracle(&entries, 256);
            // Compaction must preserve resolution exactly.
            let r = region(entries);
            let c = compact(&r);
            assert_eq!(
                resolve_entries(&c.entries),
                fuse_extents(resolve_entries(&r.entries)),
                "round {round}"
            );
        }
    }
}
