//! `repro` — the launcher for the WTF reproduction.
//!
//! Subcommands (argument parsing is hand-rolled: offline build, no clap):
//!
//! * `repro bench [--exp <id>] [--all] [--quick]` — regenerate the
//!   paper's tables/figures (DESIGN.md §4 maps ids to the paper).
//! * `repro sort [--records N] [--record-size B] [--mode slicing|conventional|hdfs] [--xla]`
//!   — run the §4.1 sort application end-to-end on a real in-process
//!   cluster and print stage timings + I/O counters.
//! * `repro smoke` — bring up a cluster, exercise the POSIX + slicing
//!   APIs, verify, and exit.
//! * `repro artifacts` — load and list the AOT kernel artifacts.

use std::process::ExitCode;
use wtf::bench::exps;
use wtf::bench::stats::{fmt_bytes, fmt_ns};
use wtf::cluster::Cluster;
use wtf::config::Config;
use wtf::mapreduce::bulkfs::BulkFs;
use wtf::mapreduce::records::{generate_records, is_sorted};
use wtf::mapreduce::{
    sort_conventional_probed, sort_slicing_probed, SortJob, SortStats,
};
use wtf::runtime::{NativeCompute, SortCompute, XlaRuntime};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let rest = &args[1.min(args.len())..];
    let result = match cmd {
        "bench" => cmd_bench(rest),
        "sort" => cmd_sort(rest),
        "smoke" => cmd_smoke(),
        "artifacts" => cmd_artifacts(),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            eprintln!("unknown command: {other}\n");
            print_help();
            return ExitCode::FAILURE;
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn print_help() {
    println!(
        "repro — Wave Transactional Filesystem reproduction\n\n\
         USAGE:\n  repro bench [--exp <id>] [--all] [--quick]\n  \
         repro sort [--records N] [--record-size B] [--buckets K] [--mode slicing|conventional|hdfs] [--xla]\n  \
         repro smoke\n  repro artifacts\n\n\
         experiments: {}",
        exps::all_experiments().join(", ")
    );
}

fn flag(rest: &[String], name: &str) -> bool {
    rest.iter().any(|a| a == name)
}

fn opt<'a>(rest: &'a [String], name: &str) -> Option<&'a str> {
    rest.iter()
        .position(|a| a == name)
        .and_then(|i| rest.get(i + 1))
        .map(String::as_str)
}

fn cmd_bench(rest: &[String]) -> wtf::Result<()> {
    let quick = flag(rest, "--quick");
    let ids: Vec<&str> = if flag(rest, "--all") || opt(rest, "--exp").is_none() {
        exps::all_experiments().to_vec()
    } else {
        vec![opt(rest, "--exp").unwrap()]
    };
    for id in ids {
        exps::run(id, quick)?.print();
    }
    Ok(())
}

fn cmd_sort(rest: &[String]) -> wtf::Result<()> {
    let records: u64 = opt(rest, "--records")
        .map(|v| v.parse().expect("--records"))
        .unwrap_or(4096);
    let record_size: usize = opt(rest, "--record-size")
        .map(|v| v.parse().expect("--record-size"))
        .unwrap_or(512);
    let buckets: usize = opt(rest, "--buckets")
        .map(|v| v.parse().expect("--buckets"))
        .unwrap_or(16);
    let mode = opt(rest, "--mode").unwrap_or("slicing");
    let use_xla = flag(rest, "--xla");

    let xla_runtime;
    let compute: &dyn SortCompute = if use_xla {
        xla_runtime = XlaRuntime::load_default()?;
        &xla_runtime
    } else {
        &NativeCompute
    };

    let mut job = SortJob::new(record_size, buckets);
    job.chunk_records = 256;
    let data = generate_records(records, job.fmt, 2015);
    println!(
        "sorting {} ({} records x {} B) via `{}` compute, mode={mode}",
        fmt_bytes(data.len() as u64),
        records,
        record_size,
        compute.name()
    );

    let (stats, read, written, check) = match mode {
        "hdfs" => {
            let cluster = wtf::baseline::HdfsCluster::new(
                wtf::baseline::HdfsConfig {
                    block_size: 1 << 20,
                    ..wtf::baseline::HdfsConfig::default()
                },
                None,
                wtf::net::LinkModel::instant(),
            )?;
            let c = cluster.client();
            c.write_file("/input", &data)?;
            let (r0, w0) = (cluster.bytes_read(), cluster.bytes_written());
            let probe = move || (cluster.bytes_read(), cluster.bytes_written());
            let stats = sort_conventional_probed(
                &c,
                compute,
                "/input",
                "/output",
                &job,
                Some(&probe),
            )?;
            let out = c.read_range("/output", 0, data.len() as u64)?;
            let (r1, w1) = probe();
            (stats, r1 - r0, w1 - w0, is_sorted(&out, job.fmt))
        }
        "conventional" | "slicing" => {
            let cluster = Cluster::builder()
                .config(Config {
                    region_size: 1 << 20,
                    ..Config::default()
                })
                .build()?;
            let c = cluster.client();
            c.write_file("/input", &data)?;
            let (r0, w0) = (cluster.storage_bytes_read(), cluster.storage_bytes_written());
            let probe = {
                let cl = &cluster;
                move || (cl.storage_bytes_read(), cl.storage_bytes_written())
            };
            let stats = if mode == "slicing" {
                sort_slicing_probed(&c, compute, "/input", "/output", &job, Some(&probe))?
            } else {
                sort_conventional_probed(
                    &c,
                    compute,
                    "/input",
                    "/output",
                    &job,
                    Some(&probe),
                )?
            };
            let out = c.read_range("/output", 0, data.len() as u64)?;
            let (r1, w1) = probe();
            (stats, r1 - r0, w1 - w0, is_sorted(&out, job.fmt))
        }
        other => {
            return Err(wtf::Error::InvalidArgument(format!("bad mode {other}")));
        }
    };
    print_sort_stats(&stats, read, written);
    println!("output sorted: {check}");
    if !check {
        return Err(wtf::Error::InvalidArgument("output NOT sorted".into()));
    }
    Ok(())
}

fn print_sort_stats(stats: &SortStats, read: u64, written: u64) {
    let pct = |d: std::time::Duration| {
        100.0 * d.as_secs_f64() / stats.total().as_secs_f64().max(1e-9)
    };
    println!(
        "  bucketing: {:>10}  ({:>5.1}%)  R={} W={}",
        fmt_ns(stats.bucketing.as_nanos() as u64),
        pct(stats.bucketing),
        fmt_bytes(stats.bucketing_io.0),
        fmt_bytes(stats.bucketing_io.1),
    );
    println!(
        "  sorting:   {:>10}  ({:>5.1}%)  R={} W={}",
        fmt_ns(stats.sorting.as_nanos() as u64),
        pct(stats.sorting),
        fmt_bytes(stats.sorting_io.0),
        fmt_bytes(stats.sorting_io.1),
    );
    println!(
        "  merging:   {:>10}  ({:>5.1}%)  R={} W={}",
        fmt_ns(stats.merging.as_nanos() as u64),
        pct(stats.merging),
        fmt_bytes(stats.merging_io.0),
        fmt_bytes(stats.merging_io.1),
    );
    println!(
        "  total:     {:>10}           R={} W={}",
        fmt_ns(stats.total().as_nanos() as u64),
        fmt_bytes(read),
        fmt_bytes(written)
    );
}

fn cmd_smoke() -> wtf::Result<()> {
    let cluster = Cluster::builder().config(Config::test()).build()?;
    let c = cluster.client();
    c.mkdir("/demo")?;
    let mut fd = c.create("/demo/file")?;
    c.write(&mut fd, b"Hello World")?;
    assert_eq!(c.read_at(&fd, 0, 11)?, b"Hello World");
    let slice = c.yank_at(fd.inode(), 6, 5)?;
    let mut out = c.create("/demo/world")?;
    c.paste(&mut out, &slice)?;
    assert_eq!(c.read_at(&out, 0, 5)?, b"World");
    let mut t = c.begin();
    let a = t.open("/demo/file")?;
    let b = t.create("/demo/txn")?;
    let data = t.read(a, 5)?;
    t.write(b, &data)?;
    t.commit()?;
    assert_eq!(c.read_at(&c.open("/demo/txn")?, 0, 5)?, b"Hello");
    cluster.run_gc()?;
    cluster.run_gc()?;
    println!(
        "smoke OK: {} storage servers, {} meta shards, coordinator epoch {}",
        cluster.storage().len(),
        cluster.meta_shard_stats().len(),
        cluster.coordinator().config()?.epoch
    );
    Ok(())
}

fn cmd_artifacts() -> wtf::Result<()> {
    let rt = XlaRuntime::load_default()?;
    println!("loaded artifacts from {}:", XlaRuntime::default_dir().display());
    for meta in rt.inventory() {
        println!(
            "  {:<28} entry={:<18} n={:<7} buckets={:?} block={:?}",
            meta.name, meta.entry, meta.n, meta.buckets, meta.block
        );
    }
    // Prove execution works.
    let (ids, hist) = rt.partition(&[5, 100, 7_000_000], &[10, 1_000_000])?;
    println!("partition probe: ids={ids:?} hist={hist:?}");
    let perm = rt.argsort(&[30, 10, 20])?;
    println!("argsort probe: perm={perm:?}");
    Ok(())
}
