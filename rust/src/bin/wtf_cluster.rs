//! `wtf-cluster` — per-role launcher for a multi-process WTF
//! deployment (see `docs/DEPLOY.md` for the walkthrough).
//!
//! Every role reads the same JSON deployment config:
//!
//! * `wtf-cluster meta --config c.json --replica <i> [--bind a:p] [--ready-file f]`
//!   — replica `i` (1-based; 0 is the frontend's) of every metadata
//!   shard group, serving the Paxos/lease plane over a socket.
//! * `wtf-cluster storage --config c.json --server <i> [--bind a:p] [--ready-file f]`
//!   — storage server `i`, serving the §2.2 data plane over a socket.
//! * `wtf-cluster frontend --config c.json [--demo]` — the client-side
//!   stack: local shard-group leaders, socket peers to every other
//!   process.  `--demo` runs a small create/write/read workload and
//!   exits; without it the frontend just verifies connectivity.
//!
//! Server roles run until killed.  With `--ready-file`, the bound
//! address is written there once the listener is up (bind port 0 for
//! an ephemeral port) — the multi-process integration test and the
//! walkthrough scripts use this as the readiness handshake.

use std::process::ExitCode;
use wtf::deploy::{run_frontend, run_meta, run_storage, DeployConfig};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let rest = &args[1.min(args.len())..];
    let result = match cmd {
        "meta" => cmd_meta(rest),
        "storage" => cmd_storage(rest),
        "frontend" => cmd_frontend(rest),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            eprintln!("unknown role: {other}\n");
            print_help();
            return ExitCode::FAILURE;
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn print_help() {
    println!(
        "wtf-cluster — per-role launcher for a multi-process WTF deployment\n\n\
         USAGE:\n  \
         wtf-cluster meta     --config <file> --replica <i> [--bind addr:port] [--ready-file <f>]\n  \
         wtf-cluster storage  --config <file> --server <i>  [--bind addr:port] [--ready-file <f>]\n  \
         wtf-cluster frontend --config <file> [--demo]\n\n\
         See docs/DEPLOY.md for a 3-process local cluster walkthrough."
    );
}

fn flag(rest: &[String], name: &str) -> bool {
    rest.iter().any(|a| a == name)
}

fn opt<'a>(rest: &'a [String], name: &str) -> Option<&'a str> {
    rest.iter()
        .position(|a| a == name)
        .and_then(|i| rest.get(i + 1))
        .map(String::as_str)
}

fn load_config(rest: &[String]) -> wtf::Result<DeployConfig> {
    let path = opt(rest, "--config")
        .ok_or_else(|| wtf::Error::InvalidArgument("--config <file> is required".into()))?;
    DeployConfig::load(std::path::Path::new(path))
}

fn index(rest: &[String], name: &str) -> wtf::Result<u32> {
    opt(rest, name)
        .ok_or_else(|| wtf::Error::InvalidArgument(format!("{name} <index> is required")))?
        .parse()
        .map_err(|_| wtf::Error::InvalidArgument(format!("{name} must be an integer")))
}

/// Write the bound address where the launcher is watching for it.  The
/// write is `tmp + rename` so a watcher never reads a half-written
/// address.
fn announce(ready_file: Option<&str>, addr: std::net::SocketAddr) -> wtf::Result<()> {
    if let Some(path) = ready_file {
        let tmp = format!("{path}.tmp");
        std::fs::write(&tmp, addr.to_string())?;
        std::fs::rename(&tmp, path)?;
    }
    println!("listening on {addr}");
    Ok(())
}

fn park_forever() -> ! {
    loop {
        std::thread::park();
    }
}

fn cmd_meta(rest: &[String]) -> wtf::Result<()> {
    let cfg = load_config(rest)?;
    let replica = index(rest, "--replica")?;
    // Default bind: the address the config assigns this replica.
    let assigned;
    let bind = match opt(rest, "--bind") {
        Some(b) => b,
        None => {
            assigned = cfg
                .meta
                .get(replica.wrapping_sub(1) as usize)
                .cloned()
                .ok_or_else(|| {
                    wtf::Error::InvalidArgument(format!("no meta address for replica {replica}"))
                })?;
            &assigned
        }
    };
    let node = run_meta(&cfg, replica, bind)?;
    announce(opt(rest, "--ready-file"), node.addr())?;
    park_forever()
}

fn cmd_storage(rest: &[String]) -> wtf::Result<()> {
    let cfg = load_config(rest)?;
    let id = index(rest, "--server")?;
    let assigned;
    let bind = match opt(rest, "--bind") {
        Some(b) => b,
        None => {
            assigned = cfg.storage.get(id as usize).cloned().ok_or_else(|| {
                wtf::Error::InvalidArgument(format!("no storage address for server {id}"))
            })?;
            &assigned
        }
    };
    let node = run_storage(&cfg, id, bind)?;
    announce(opt(rest, "--ready-file"), node.addr())?;
    park_forever()
}

fn cmd_frontend(rest: &[String]) -> wtf::Result<()> {
    let cfg = load_config(rest)?;
    let frontend = run_frontend(&cfg)?;
    let client = frontend.client();
    if !client.exists("/") {
        return Err(wtf::Error::NotFound("/ (is the meta plane up?)".into()));
    }
    println!("frontend up: / exists, {} shard group(s)", cfg.shards);
    if flag(rest, "--demo") {
        let path = "/wtf-cluster-demo";
        let mut fd = client.create(path)?;
        client.write(&mut fd, b"written across processes")?;
        let back = client.read_at(&fd, 0, 24)?;
        assert_eq!(back, b"written across processes");
        client.unlink(path)?;
        println!("demo ok: created, wrote, read back, unlinked {path}");
    }
    Ok(())
}
