//! Cluster and filesystem configuration.
//!
//! Defaults mirror the paper's evaluation deployment (§4): 64 MB regions
//! (after the HDFS block-size workaround), 2-way replication, twelve
//! storage servers with three metadata nodes, ~3 ms metadata transaction
//! floor.  In-process test clusters shrink these aggressively.


use std::path::PathBuf;
use std::time::Duration;

/// When the metadata replica WAL fsyncs ([`Config::wal_sync`]).  In
/// every mode the record is *written* before the acknowledgment it
/// enables; the modes only choose how much an OS crash can lose (a
/// process crash loses nothing — the page cache survives it).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum WalSync {
    /// fsync every record before acknowledging (durable against power
    /// loss; the paper-faithful default).
    #[default]
    Always,
    /// fsync on chosen (client-visible) records and every 32 appends,
    /// amortizing the sync cost across a group's promise/accept chatter.
    Batch,
    /// Never fsync explicitly; rely on the OS writeback.  For benches
    /// and tests that only model process crashes.
    None,
}

/// Top-level configuration for an in-process WTF deployment.
#[derive(Clone, Debug)]
pub struct Config {
    /// Size of one file region in bytes (§2.3). Paper evaluation: 64 MB.
    pub region_size: u64,
    /// Default replication factor for file slices (§2.9). Paper: 2.
    pub replication: u8,
    /// Number of storage servers.
    pub storage_servers: u32,
    /// Number of metadata shards (HyperDex partitions).
    pub meta_shards: u32,
    /// Replicas per metadata shard (HyperDex tolerates f failures with
    /// f+1-length value-dependent chains).
    pub meta_replicas: u8,
    /// Route metadata through per-shard Paxos groups instead of the
    /// in-process chains: each shard becomes a `meta_group_replicas`-way
    /// consensus group with leader leases and automatic failover.
    pub meta_paxos: bool,
    /// Members per metadata Paxos group (tolerates ⌊n/2⌋ failures;
    /// paper-shaped default: 3).
    pub meta_group_replicas: u8,
    /// Run multi-shard metadata commits as an intent-logged two-phase
    /// commit over the Paxos groups (requires `meta_paxos`): durable
    /// `Prepare` intents in every touched group, a decision record in
    /// the lowest-numbered participant group, exactly-once phase-2
    /// apply.  Closes the cross-group atomicity and reader-isolation
    /// gaps of the direct per-shard path — a quorum dying mid-commit
    /// can no longer strand applied entries in earlier groups, and
    /// leaseholder reads never observe a half-committed transaction.
    /// Off by default; single-shard commits stay one-phase either way.
    pub meta_2pc: bool,
    /// Leader lease duration for metadata shard groups.  Reads are
    /// leader-local inside the lease; failover waits out at most one
    /// lease window.
    pub meta_lease: Duration,
    /// Worst-case clock disagreement budgeted between any two processes
    /// of one deployment.  Leader-lease validity is bounded holder-side
    /// from *before* the grant request was sent, minus this budget, and
    /// 2PC coordinator-claim expiry checks are padded by it — so a
    /// lease or claim never looks live on one machine after it has
    /// expired on another whose clock runs up to this far apart.  Zero
    /// (the default) is correct single-process, where every component
    /// shares one clock; multi-process deployments must set it.
    /// `validate()` requires `2 * max_clock_skew < meta_lease` so the
    /// shortened holder lease keeps a usable window.
    pub max_clock_skew: Duration,
    /// Coordinator replicas (Replicant/Paxos group size).
    pub coordinator_replicas: u8,
    /// Backing files maintained per storage server (§2.2).
    pub backing_files_per_server: u32,
    /// Virtual nodes per server on the consistent-hash ring (§2.7).
    pub ring_vnodes: u32,
    /// Root directory for storage-server backing files; a tempdir when
    /// `None`.
    pub data_dir: Option<PathBuf>,
    /// Simulated latency floor for one metadata transaction (the paper
    /// observes ~3 ms per HyperDex transaction). Zero for unit tests and
    /// real-mode benchmarks.
    pub meta_txn_floor: Duration,
    /// Max transparent retries of a conflicted transaction before the
    /// retry layer reports `RetriesExhausted`.
    pub txn_retry_budget: u32,
    /// End-to-end deadline for one client-facing operation's retry loop
    /// (`with_retry`, `MetaTxn` heals, `Transaction::commit` replays).
    /// When the budget of transparent retries would carry an operation
    /// past this wall-clock bound, the loop stops and surfaces
    /// [`crate::Error::Timeout`] — an *indeterminate* outcome, handled
    /// exactly like `NoQuorum` by commit paths.  `Duration::ZERO` (the
    /// default) disables the deadline; retries are bounded only by
    /// `txn_retry_budget`.
    pub rpc_deadline: Duration,
    /// Base delay for bounded exponential backoff between transparent
    /// retries: attempt `n` sleeps a uniformly random duration in
    /// `[0, base * 2^(n-1))`, capped at 64x base (full jitter, so
    /// colliding clients decorrelate instead of re-colliding in
    /// lockstep).  `Duration::ZERO` (the default) disables backoff and
    /// keeps the historical retry-immediately behavior.
    pub retry_backoff: Duration,
    /// GC: storage servers start collecting above this garbage fraction.
    pub gc_high_watermark: f64,
    /// GC: and stop below this one (§2.8: 20%).
    pub gc_low_watermark: f64,
    /// Worker threads in the deployment's transport pool — the fan-out
    /// limit for scatter-gather slice I/O.  `0` degrades to inline
    /// (serial) execution on the caller thread.
    pub transport_workers: u32,
    /// Client-side versioned metadata cache for the hot read path:
    /// inode and region entries keyed by the authoritative versions
    /// `MetaGet` carries, invalidated on own-txn commit, on a
    /// `NotLeader` heal, and on a commit-time version mismatch.  Off by
    /// default — when enabled, *plain* (non-transactional) reads may
    /// serve another client's state as of the last invalidation point;
    /// transactional reads always validate real versions at commit.
    /// See ROADMAP "Hot read path" for the full coherence contract.
    pub metadata_cache: bool,
    /// Bounded entry count (inodes + regions + path entries) for the
    /// metadata cache.
    pub metadata_cache_entries: usize,
    /// Upper bound on the lifetime of one metadata-cache entry (inode,
    /// region, or path): a hit older than this is treated as a miss and
    /// refetched from the leaseholder.  `Duration::ZERO` (the default)
    /// disables expiry.  Whenever the cache runs alongside a scheduled
    /// GC (`gc_scan_interval` non-zero) this MUST be set strictly below
    /// the scan interval: a region entry that outlives one scan
    /// interval can resolve slice pointers whose backing bytes the
    /// two-consecutive-scan rule has already reclaimed (§2.8) —
    /// `Config::validate` rejects the combination and
    /// `storage/gc.rs` re-asserts the bound at every round start.
    pub cache_ttl: Duration,
    /// Declared cadence of storage GC scan rounds for this deployment
    /// (the operator drives [`crate::cluster::Cluster::run_gc`] every
    /// this often).  `Duration::ZERO` (the default) means GC is not
    /// scheduled; non-zero engages the cache/GC coexistence bound on
    /// `cache_ttl` above.
    pub gc_scan_interval: Duration,
    /// Group resolved extent fetches by storage server and ship one
    /// `RetrieveMany` envelope per server (deduping repeated slice
    /// pointers) instead of one `RetrieveSlice` envelope per extent.
    /// Same bytes, same per-extent replica failover — strictly fewer
    /// transport envelopes.
    pub read_coalescing: bool,
    /// Readahead window in bytes for sequential cursor reads
    /// ([`crate::client::WtfClient::read`]): each fetch extends past the
    /// requested range by this much and the surplus serves subsequent
    /// sequential reads with zero envelopes.  `0` disables.
    pub readahead: u64,
    /// Group-commit accumulation window for single-shard metadata
    /// commits (requires `meta_paxos`): commits to the same shard group
    /// that arrive within this window are packed into ONE shared log
    /// entry — one Paxos round for the whole batch — while each
    /// constituent transaction keeps its own id, exactly-once dedup,
    /// and individually recorded outcome.  `Duration::ZERO` (the
    /// default) disables batching entirely; multi-shard commits are
    /// never batched.
    pub group_commit_window: Duration,
    /// Upper bound on transactions packed into one group-commit entry;
    /// a full batch flushes immediately instead of waiting out the
    /// window.
    pub group_commit_max_txns: usize,
    /// Collapse a 2PC commit's per-group phase-1 `Prepare` proposals
    /// into a single transport scatter-gather across all participant
    /// groups (and likewise the phase-2 `Decide` fan-out), instead of
    /// one serial proposal round per group (requires `meta_2pc`).
    /// Protocol-equivalent: the same entries land in the same logs with
    /// the same intent/decision semantics — only the scatter shape
    /// changes.  Off by default.
    pub prepare_batching: bool,
    /// Opt-in client write-behind: `append_bytes` / `append_slice` /
    /// `write_at` enqueue to a per-client background flusher and return
    /// assuming success; the flusher batches the queued writes
    /// (sharing one inode aim fetch per file) and the client reconciles
    /// — surfacing the first hidden failure and dropping the affected
    /// cache keys — at `flush()` / `commit_txn()` / `close()`
    /// boundaries.  Off by default: it trades read-your-writes
    /// visibility for batch throughput (see ROADMAP "Write path").
    pub write_behind: bool,
    /// Bounded depth of the write-behind queue; an enqueue past this
    /// bound blocks until the flusher drains (backpressure, so a slow
    /// flusher cannot buffer unbounded dirty data).
    pub write_behind_max_ops: usize,
    /// Give every metadata Paxos replica a real on-disk write-ahead log
    /// (requires `meta_paxos` and `wal_dir`): promises, accepts, and
    /// chosen entries are logged before acknowledgment and replayed on
    /// restart, so a replica recovers from its WAL directory alone
    /// instead of rejoining by state pull.  Off by default — in-memory
    /// mode stays byte-identical to the pre-WAL behavior.
    pub meta_durable: bool,
    /// Root directory for replica WALs (one
    /// `shard-<s>/replica-<r>` subtree per replica, stamped with a
    /// cluster marker).  Required when `meta_durable` is on.
    pub wal_dir: Option<PathBuf>,
    /// fsync policy for WAL appends.
    pub wal_sync: WalSync,
    /// Checkpoint (snapshot state + truncate the log) every this many
    /// chosen records per replica.  Must be >= 1 when `meta_durable`.
    pub wal_checkpoint_every: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            region_size: 64 * 1024 * 1024,
            replication: 2,
            storage_servers: 12,
            meta_shards: 8,
            meta_replicas: 2,
            meta_paxos: false,
            meta_group_replicas: 3,
            meta_2pc: false,
            meta_lease: Duration::from_millis(50),
            max_clock_skew: Duration::ZERO,
            coordinator_replicas: 3,
            backing_files_per_server: 4,
            ring_vnodes: 64,
            data_dir: None,
            meta_txn_floor: Duration::ZERO,
            txn_retry_budget: 16,
            rpc_deadline: Duration::ZERO,
            retry_backoff: Duration::ZERO,
            gc_high_watermark: 0.5,
            gc_low_watermark: 0.2,
            transport_workers: 8,
            metadata_cache: false,
            metadata_cache_entries: 4096,
            cache_ttl: Duration::ZERO,
            gc_scan_interval: Duration::ZERO,
            read_coalescing: false,
            readahead: 0,
            group_commit_window: Duration::ZERO,
            group_commit_max_txns: 8,
            prepare_batching: false,
            write_behind: false,
            write_behind_max_ops: 64,
            meta_durable: false,
            wal_dir: None,
            wal_sync: WalSync::Always,
            wal_checkpoint_every: 128,
        }
    }
}

impl Config {
    /// A small, fast configuration for unit/integration tests: tiny
    /// regions so multi-region code paths are exercised with little data.
    pub fn test() -> Self {
        Config {
            region_size: 4096,
            replication: 2,
            storage_servers: 4,
            meta_shards: 4,
            meta_replicas: 2,
            coordinator_replicas: 3,
            backing_files_per_server: 2,
            ring_vnodes: 16,
            ..Default::default()
        }
    }

    /// [`Config::test`] with metadata served by 3-replica Paxos shard
    /// groups (short leases so failover tests run quickly).
    pub fn replicated_test() -> Self {
        Config {
            meta_paxos: true,
            meta_group_replicas: 3,
            meta_lease: Duration::from_millis(25),
            ..Config::test()
        }
    }

    /// [`Config::replicated_test`] with cross-group 2PC on: multi-shard
    /// commits run the intent-logged two-phase protocol.  The preset
    /// the fault-schedule and reader-isolation suites exercise.
    pub fn replicated_2pc_test() -> Self {
        Config {
            meta_2pc: true,
            ..Config::replicated_test()
        }
    }

    /// [`Config::test`] with the whole hot read path enabled: metadata
    /// caching, per-server fetch coalescing, and a two-region readahead
    /// window.  The preset the read-path coherence tests and benchmarks
    /// exercise.
    pub fn fast_read_test() -> Self {
        Config {
            metadata_cache: true,
            read_coalescing: true,
            readahead: 8192,
            ..Config::test()
        }
    }

    /// [`Config::replicated_2pc_test`] with the whole batched write
    /// path enabled: Paxos group commit (short window so lone commits
    /// flush fast) and single-scatter 2PC prepare/decide batching.
    /// `write_behind` stays OFF here — it changes client-visible
    /// read-after-write semantics, so the dedicated write-behind suites
    /// opt into it explicitly.
    pub fn write_path_test() -> Self {
        Config {
            group_commit_window: Duration::from_millis(1),
            group_commit_max_txns: 8,
            prepare_batching: true,
            ..Config::replicated_2pc_test()
        }
    }

    /// [`Config::replicated_2pc_test`] with durable replica WALs on and
    /// an aggressive checkpoint cadence (so truncation paths are
    /// exercised by short tests).  `wal_dir` is deliberately left
    /// `None`: each test supplies its own temp directory, and
    /// validation fails loudly if one forgets.
    pub fn durable_test() -> Self {
        Config {
            meta_durable: true,
            wal_sync: WalSync::Always,
            wal_checkpoint_every: 8,
            ..Config::replicated_2pc_test()
        }
    }

    /// The deployment preset — "the tested config IS the production
    /// config".  Paper-scale sizing from [`Config::default`] plus every
    /// knob the CI matrices have proven end to end: metadata served by
    /// 3-replica Paxos shard groups, multi-shard commits through the
    /// intent-logged 2PC, the versioned client cache (transactional
    /// reads validate cached versions at commit, PR 9) with per-server
    /// fetch coalescing, and GC on a 60 s scan cadence with
    /// `cache_ttl` strictly inside the two-scan reclamation window —
    /// the coexistence bound `validate()` enforces.
    pub fn production() -> Self {
        Config {
            meta_paxos: true,
            meta_group_replicas: 3,
            meta_2pc: true,
            // Multi-process sizing: leases long enough to absorb a
            // generous NTP-grade skew budget and still leave the holder
            // most of the window.
            meta_lease: Duration::from_secs(2),
            max_clock_skew: Duration::from_millis(250),
            metadata_cache: true,
            read_coalescing: true,
            cache_ttl: Duration::from_secs(30),
            gc_scan_interval: Duration::from_secs(60),
            ..Default::default()
        }
    }

    /// Region index + region-relative offset for an absolute file offset.
    pub fn locate(&self, offset: u64) -> (u32, u64) {
        ((offset / self.region_size) as u32, offset % self.region_size)
    }

    /// Validate invariants that the rest of the stack assumes.
    pub fn validate(&self) -> crate::Result<()> {
        if self.region_size == 0 {
            return Err(crate::Error::InvalidArgument("region_size == 0".into()));
        }
        if self.replication == 0 {
            return Err(crate::Error::InvalidArgument("replication == 0".into()));
        }
        if self.storage_servers == 0 {
            return Err(crate::Error::InvalidArgument("storage_servers == 0".into()));
        }
        if u32::from(self.replication) > self.storage_servers {
            return Err(crate::Error::InvalidArgument(format!(
                "replication {} exceeds storage servers {}",
                self.replication, self.storage_servers
            )));
        }
        if self.meta_shards == 0 {
            return Err(crate::Error::InvalidArgument("meta_shards == 0".into()));
        }
        if self.meta_paxos && self.meta_group_replicas == 0 {
            return Err(crate::Error::InvalidArgument(
                "meta_paxos requires meta_group_replicas >= 1".into(),
            ));
        }
        if self.meta_paxos && self.meta_lease.is_zero() {
            return Err(crate::Error::InvalidArgument(
                "meta_paxos requires a non-zero meta_lease".into(),
            ));
        }
        // A skew budget at or past half the lease would leave holders
        // with leases born (nearly) expired — elect/renew livelock.
        if self.meta_paxos
            && !self.max_clock_skew.is_zero()
            && self.max_clock_skew * 2 >= self.meta_lease
        {
            return Err(crate::Error::InvalidArgument(format!(
                "max_clock_skew ({:?}) must satisfy 2 * max_clock_skew < meta_lease \
                 ({:?}): the holder-side lease is shortened by the skew budget and \
                 must keep a usable window",
                self.max_clock_skew, self.meta_lease
            )));
        }
        if self.meta_2pc && !self.meta_paxos {
            return Err(crate::Error::InvalidArgument(
                "meta_2pc layers on the Paxos groups; enable meta_paxos".into(),
            ));
        }
        if !self.group_commit_window.is_zero() && !self.meta_paxos {
            return Err(crate::Error::InvalidArgument(
                "group_commit_window batches Paxos rounds; enable meta_paxos".into(),
            ));
        }
        if !self.group_commit_window.is_zero() && self.group_commit_max_txns < 2 {
            return Err(crate::Error::InvalidArgument(
                "group commit requires group_commit_max_txns >= 2".into(),
            ));
        }
        if self.prepare_batching && !self.meta_2pc {
            return Err(crate::Error::InvalidArgument(
                "prepare_batching batches the 2PC scatters; enable meta_2pc".into(),
            ));
        }
        if self.write_behind && self.write_behind_max_ops == 0 {
            return Err(crate::Error::InvalidArgument(
                "write_behind requires write_behind_max_ops >= 1".into(),
            ));
        }
        if self.meta_durable && !self.meta_paxos {
            return Err(crate::Error::InvalidArgument(
                "meta_durable logs the Paxos groups; enable meta_paxos".into(),
            ));
        }
        if self.meta_durable && self.wal_dir.is_none() {
            return Err(crate::Error::InvalidArgument(
                "meta_durable requires wal_dir (nowhere to put the WAL)".into(),
            ));
        }
        if self.meta_durable && self.wal_checkpoint_every == 0 {
            return Err(crate::Error::InvalidArgument(
                "meta_durable requires wal_checkpoint_every >= 1".into(),
            ));
        }
        if self.metadata_cache && self.metadata_cache_entries == 0 {
            return Err(crate::Error::InvalidArgument(
                "metadata_cache requires metadata_cache_entries >= 1".into(),
            ));
        }
        if !self.cache_ttl.is_zero() && !self.metadata_cache {
            return Err(crate::Error::InvalidArgument(
                "cache_ttl bounds the metadata cache; enable metadata_cache".into(),
            ));
        }
        // The reclaimed-slice hazard: a cached region older than one GC
        // scan interval can resolve slice pointers the two-consecutive-
        // scan rule has already reclaimed.  A deployment that schedules
        // GC must bound cache-entry lifetime strictly inside the window.
        if self.metadata_cache
            && !self.gc_scan_interval.is_zero()
            && (self.cache_ttl.is_zero() || self.cache_ttl >= self.gc_scan_interval)
        {
            return Err(crate::Error::InvalidArgument(format!(
                "metadata_cache alongside scheduled GC requires 0 < cache_ttl ({:?}) \
                 < gc_scan_interval ({:?}): an unexpired cache entry must never \
                 outlive the two-scan reclamation grace window",
                self.cache_ttl, self.gc_scan_interval
            )));
        }
        if !(0.0..=1.0).contains(&self.gc_low_watermark)
            || !(0.0..=1.0).contains(&self.gc_high_watermark)
            || self.gc_low_watermark > self.gc_high_watermark
        {
            return Err(crate::Error::InvalidArgument(
                "gc watermarks must satisfy 0 <= low <= high <= 1".into(),
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_paper_shaped() {
        let c = Config::default();
        assert_eq!(c.region_size, 64 << 20);
        assert_eq!(c.replication, 2);
        assert_eq!(c.storage_servers, 12);
        c.validate().unwrap();
    }

    #[test]
    fn locate_maps_offsets_to_regions() {
        let c = Config {
            region_size: 100,
            ..Config::test()
        };
        assert_eq!(c.locate(0), (0, 0));
        assert_eq!(c.locate(99), (0, 99));
        assert_eq!(c.locate(100), (1, 0));
        assert_eq!(c.locate(250), (2, 50));
    }

    #[test]
    fn replicated_preset_is_valid_and_paxos_backed() {
        let c = Config::replicated_test();
        assert!(c.meta_paxos);
        assert!(!c.meta_2pc, "2PC is opt-in on top of the Paxos preset");
        assert_eq!(c.meta_group_replicas, 3);
        c.validate().unwrap();
        let mut bad = Config::replicated_test();
        bad.meta_group_replicas = 0;
        assert!(bad.validate().is_err());
        let mut bad = Config::replicated_test();
        bad.meta_lease = Duration::ZERO;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn two_pc_preset_requires_paxos() {
        let c = Config::replicated_2pc_test();
        assert!(c.meta_paxos && c.meta_2pc);
        c.validate().unwrap();
        assert!(!Config::default().meta_2pc, "deployment default stays off");
        let mut bad = Config::replicated_2pc_test();
        bad.meta_paxos = false;
        assert!(bad.validate().is_err(), "2PC without Paxos groups");
    }

    #[test]
    fn defaults_leave_the_read_path_uncached() {
        let c = Config::default();
        assert!(!c.metadata_cache);
        assert!(!c.read_coalescing);
        assert_eq!(c.readahead, 0);
        let t = Config::test();
        assert!(!t.metadata_cache && !t.read_coalescing && t.readahead == 0);
        let f = Config::fast_read_test();
        assert!(f.metadata_cache && f.read_coalescing);
        assert_eq!(f.readahead, 2 * f.region_size);
        f.validate().unwrap();
        let mut bad = Config::fast_read_test();
        bad.metadata_cache_entries = 0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn write_path_preset_batches_but_defaults_stay_off() {
        let d = Config::default();
        assert!(d.group_commit_window.is_zero(), "group commit defaults off");
        assert!(!d.prepare_batching && !d.write_behind);
        let t = Config::test();
        assert!(t.group_commit_window.is_zero() && !t.prepare_batching && !t.write_behind);

        let w = Config::write_path_test();
        assert!(w.meta_paxos && w.meta_2pc);
        assert!(!w.group_commit_window.is_zero());
        assert!(w.group_commit_max_txns >= 2);
        assert!(w.prepare_batching);
        assert!(!w.write_behind, "write-behind is a separate opt-in");
        w.validate().unwrap();

        let mut bad = Config::write_path_test();
        bad.meta_paxos = false;
        bad.meta_2pc = false;
        bad.prepare_batching = false;
        assert!(bad.validate().is_err(), "group commit without Paxos groups");
        let mut bad = Config::write_path_test();
        bad.group_commit_max_txns = 1;
        assert!(bad.validate().is_err(), "a 1-txn batch is no batch");
        let mut bad = Config::write_path_test();
        bad.meta_2pc = false;
        assert!(bad.validate().is_err(), "prepare batching without 2PC");
        let mut bad = Config::test();
        bad.write_behind = true;
        bad.write_behind_max_ops = 0;
        assert!(bad.validate().is_err(), "unbounded write-behind queue");
    }

    #[test]
    fn durable_preset_requires_a_wal_dir() {
        let d = Config::default();
        assert!(!d.meta_durable, "durability defaults off");
        assert_eq!(d.wal_sync, WalSync::Always);
        let t = Config::replicated_2pc_test();
        assert!(!t.meta_durable, "2PC preset stays in-memory");

        let c = Config::durable_test();
        assert!(c.meta_paxos && c.meta_2pc && c.meta_durable);
        assert_eq!(c.wal_checkpoint_every, 8);
        assert!(
            c.validate().is_err(),
            "durable without wal_dir must fail loudly"
        );
        let mut ok = Config::durable_test();
        ok.wal_dir = Some(std::env::temp_dir());
        ok.validate().unwrap();

        let mut bad = Config::durable_test();
        bad.wal_dir = Some(std::env::temp_dir());
        bad.meta_paxos = false;
        bad.meta_2pc = false;
        assert!(bad.validate().is_err(), "durable without Paxos groups");
        let mut bad = Config::durable_test();
        bad.wal_dir = Some(std::env::temp_dir());
        bad.wal_checkpoint_every = 0;
        assert!(bad.validate().is_err(), "checkpoint interval 0");
    }

    #[test]
    fn deadlines_and_backoff_default_off() {
        // Knobs-off runs must behave byte-identically to the pre-chaos
        // tree: no deadline clock, no backoff sleeps.
        let d = Config::default();
        assert!(d.rpc_deadline.is_zero());
        assert!(d.retry_backoff.is_zero());
        let t = Config::test();
        assert!(t.rpc_deadline.is_zero() && t.retry_backoff.is_zero());
        let mut on = Config::replicated_2pc_test();
        on.rpc_deadline = Duration::from_secs(2);
        on.retry_backoff = Duration::from_millis(1);
        on.validate().unwrap();
    }

    #[test]
    fn production_preset_is_the_tested_shape() {
        let p = Config::production();
        assert!(p.meta_paxos && p.meta_2pc, "replicated 2PC metadata plane");
        assert!(p.metadata_cache && p.read_coalescing, "hot read path on");
        assert_eq!(p.region_size, 64 << 20, "paper-scale sizing retained");
        assert!(
            !p.cache_ttl.is_zero() && p.cache_ttl < p.gc_scan_interval,
            "cache lifetime strictly inside the GC two-scan window"
        );
        p.validate().unwrap();
        // Defaults stay conservative: production is an explicit choice.
        let d = Config::default();
        assert!(!d.meta_paxos && !d.metadata_cache);
        assert!(d.cache_ttl.is_zero() && d.gc_scan_interval.is_zero());
    }

    #[test]
    fn cache_alongside_gc_requires_a_ttl_inside_the_scan_window() {
        // The satellite-1 hazard: cache + scheduled GC with no TTL (or a
        // TTL at/past the scan interval) can serve reclaimed slices.
        let mut bad = Config::fast_read_test();
        bad.gc_scan_interval = Duration::from_secs(60);
        assert!(bad.validate().is_err(), "cache + GC without a cache_ttl");
        let mut bad = Config::fast_read_test();
        bad.gc_scan_interval = Duration::from_secs(60);
        bad.cache_ttl = Duration::from_secs(60);
        assert!(bad.validate().is_err(), "cache_ttl == scan interval");
        let mut bad = Config::fast_read_test();
        bad.gc_scan_interval = Duration::from_secs(60);
        bad.cache_ttl = Duration::from_secs(90);
        assert!(bad.validate().is_err(), "cache_ttl past the scan interval");

        let mut ok = Config::fast_read_test();
        ok.gc_scan_interval = Duration::from_secs(60);
        ok.cache_ttl = Duration::from_secs(30);
        ok.validate().unwrap();
        // TTL without the cache it bounds is a misconfiguration too.
        let mut bad = Config::test();
        bad.cache_ttl = Duration::from_secs(30);
        assert!(bad.validate().is_err(), "cache_ttl without metadata_cache");
        // Unscheduled GC (interval zero) keeps the historical shape:
        // cache without a TTL stays valid.
        Config::fast_read_test().validate().unwrap();
        let mut p = Config::production();
        p.gc_scan_interval = Duration::ZERO;
        p.validate().unwrap();
    }

    #[test]
    fn clock_skew_budget_defaults_zero_and_bounds_against_the_lease() {
        // Single-process presets share one clock: no budget needed.
        assert!(Config::default().max_clock_skew.is_zero());
        assert!(Config::test().max_clock_skew.is_zero());
        assert!(Config::replicated_2pc_test().max_clock_skew.is_zero());
        // The deployment preset budgets real inter-machine skew, well
        // inside its lease.
        let p = Config::production();
        assert!(!p.max_clock_skew.is_zero());
        assert!(p.max_clock_skew * 2 < p.meta_lease);
        p.validate().unwrap();
        // 25 ms lease: 13 ms of skew swallows the window, 12 ms fits.
        let mut bad = Config::replicated_test();
        bad.max_clock_skew = Duration::from_millis(13);
        assert!(bad.validate().is_err(), "2 * skew >= lease must fail");
        let mut ok = Config::replicated_test();
        ok.max_clock_skew = Duration::from_millis(12);
        ok.validate().unwrap();
    }

    #[test]
    fn validation_rejects_nonsense() {
        let mut c = Config::test();
        c.replication = 9;
        c.storage_servers = 2;
        assert!(c.validate().is_err());
        let mut c = Config::test();
        c.region_size = 0;
        assert!(c.validate().is_err());
        let mut c = Config::test();
        c.gc_low_watermark = 0.9;
        c.gc_high_watermark = 0.1;
        assert!(c.validate().is_err());
    }
}
