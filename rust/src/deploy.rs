//! Multi-process deployment roles: the pieces `wtf-cluster` assembles
//! into real OS processes connected by the socket transport.
//!
//! The single-process [`crate::cluster::Cluster`] stays the tested
//! default; this module splits the same components along the paper's
//! Fig. 1 boundaries:
//!
//! * **meta** (`wtf-cluster meta --replica i`): one process hosting
//!   replica `i` of EVERY metadata shard group — standalone
//!   [`GroupReplica`]s behind a [`ShardRouter`] that dispatches each
//!   Paxos/lease envelope on its shard id, served over a
//!   [`SocketServer`].  With a WAL root configured, each replica logs
//!   durably under `shard-<s>/replica-<i>` and recovers from disk on
//!   restart (PR 5 semantics, now across process boundaries).
//! * **storage** (`wtf-cluster storage --server i`): one
//!   [`StorageServer`] serving the two-call §2.2 data-plane API over a
//!   socket.
//! * **frontend** (`wtf-cluster frontend`): hosts replica 0 of every
//!   shard group in-process (the proposing leader) with
//!   [`SocketPeer`]s for the remote members
//!   ([`ShardGroup::with_remote_members`]), plus socket peers for
//!   every storage server — and hands out ordinary [`WtfClient`]s.
//!
//! Every process runs its own [`LeaseClock::auto_anchored`] clock;
//! `max_clock_skew_ms` is the budgeted disagreement between those
//! anchors (leases shrink holder-side by it, 2PC coordinator-claim
//! expiry checks pad by it).

use crate::client::WtfClient;
use crate::config::{Config, WalSync};
use crate::coordinator::lease::LeaseClock;
use crate::error::{Error, Result};
use crate::meta::{
    GroupReplica, MetaOp, MetaService, MetaTxn, ReplicatedMetaStore, ShardGroup, WalSetup,
};
use crate::metrics::Metrics;
use crate::net::{Handler, LinkModel, Peer, Request, Response, SocketPeer, SocketServer, Transport};
use crate::storage::{Ring, StorageCluster, StorageServer};
use crate::types::{DirEntries, Inode, Key, Value};
use crate::util::json::{self, Json};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

/// The shared deployment description every role reads (JSON — the
/// offline build carries its own parser in [`crate::util::json`]).
///
/// ```json
/// {
///   "shards": 2,
///   "replicas": 3,
///   "lease_ms": 2000,
///   "max_clock_skew_ms": 250,
///   "meta": ["127.0.0.1:7101", "127.0.0.1:7102"],
///   "storage": ["127.0.0.1:7201", "127.0.0.1:7202"],
///   "wal_dir": "/tmp/wtf/wal",
///   "data_dir": "/tmp/wtf/data"
/// }
/// ```
///
/// `meta[i]` is the address of the process hosting replica `i + 1` of
/// every shard (replica 0 lives in the frontend); `storage[i]` is the
/// address of storage server `i`.
#[derive(Clone, Debug)]
pub struct DeployConfig {
    pub shards: u32,
    /// Total replicas per shard group, INCLUDING the frontend-local
    /// replica 0.  `meta.len()` must equal `replicas - 1`.
    pub replicas: u32,
    pub lease_ms: u64,
    pub max_clock_skew_ms: u64,
    pub replication: u8,
    pub region_size: u64,
    /// Addresses of the meta replica processes, replicas `1..replicas`.
    pub meta: Vec<String>,
    /// Addresses of the storage server processes, server ids `0..len`.
    pub storage: Vec<String>,
    /// WAL root for meta replicas (`shard-<s>/replica-<r>` per
    /// replica); `None` = in-memory replicas.
    pub wal_dir: Option<PathBuf>,
    /// Backing-file root for storage servers; `None` = tempdirs.
    pub data_dir: Option<PathBuf>,
    pub wal_checkpoint_every: u64,
    pub backing_files: u32,
    pub ring_vnodes: u32,
}

impl DeployConfig {
    /// Parse and validate a deployment description.
    pub fn parse(text: &str) -> Result<DeployConfig> {
        let j = json::parse(text)
            .map_err(|e| Error::InvalidArgument(format!("deploy config: {e}")))?;
        let num = |key: &str, default: u64| -> Result<u64> {
            match j.get(key) {
                None => Ok(default),
                Some(v) => v.as_u64().ok_or_else(|| {
                    Error::InvalidArgument(format!("deploy config: \"{key}\" must be a non-negative integer"))
                }),
            }
        };
        let addrs = |key: &str| -> Result<Vec<String>> {
            match j.get(key) {
                None => Ok(Vec::new()),
                Some(v) => v
                    .as_arr()
                    .ok_or_else(|| {
                        Error::InvalidArgument(format!("deploy config: \"{key}\" must be an array"))
                    })?
                    .iter()
                    .map(|a| {
                        a.as_str().map(str::to_owned).ok_or_else(|| {
                            Error::InvalidArgument(format!(
                                "deploy config: \"{key}\" entries must be \"host:port\" strings"
                            ))
                        })
                    })
                    .collect(),
            }
        };
        let path = |key: &str| -> Result<Option<PathBuf>> {
            match j.get(key) {
                None | Some(Json::Null) => Ok(None),
                Some(v) => v.as_str().map(|s| Some(PathBuf::from(s))).ok_or_else(|| {
                    Error::InvalidArgument(format!("deploy config: \"{key}\" must be a path string"))
                }),
            }
        };
        let cfg = DeployConfig {
            shards: num("shards", 1)? as u32,
            replicas: num("replicas", 3)? as u32,
            lease_ms: num("lease_ms", 2000)?,
            max_clock_skew_ms: num("max_clock_skew_ms", 250)?,
            replication: num("replication", 2)? as u8,
            region_size: num("region_size", 4 << 20)?,
            meta: addrs("meta")?,
            storage: addrs("storage")?,
            wal_dir: path("wal_dir")?,
            data_dir: path("data_dir")?,
            wal_checkpoint_every: num("wal_checkpoint_every", 128)?,
            backing_files: num("backing_files", 4)? as u32,
            ring_vnodes: num("ring_vnodes", 64)?as u32,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load from a file.
    pub fn load(path: &std::path::Path) -> Result<DeployConfig> {
        let text = std::fs::read_to_string(path)?;
        DeployConfig::parse(&text)
    }

    fn validate(&self) -> Result<()> {
        if self.shards == 0 {
            return Err(Error::InvalidArgument("deploy config: shards == 0".into()));
        }
        if self.replicas < 2 {
            return Err(Error::InvalidArgument(
                "deploy config: a multi-process group needs replicas >= 2".into(),
            ));
        }
        if self.meta.len() as u32 != self.replicas - 1 {
            return Err(Error::InvalidArgument(format!(
                "deploy config: {} meta addresses for {} replicas (need replicas - 1 — \
                 replica 0 lives in the frontend)",
                self.meta.len(),
                self.replicas
            )));
        }
        if self.storage.is_empty() {
            return Err(Error::InvalidArgument(
                "deploy config: at least one storage address".into(),
            ));
        }
        if u32::from(self.replication) > self.storage.len() as u32 || self.replication == 0 {
            return Err(Error::InvalidArgument(format!(
                "deploy config: replication {} over {} storage servers",
                self.replication,
                self.storage.len()
            )));
        }
        if self.lease_ms == 0 {
            return Err(Error::InvalidArgument("deploy config: lease_ms == 0".into()));
        }
        if self.max_clock_skew_ms * 2 >= self.lease_ms {
            return Err(Error::InvalidArgument(format!(
                "deploy config: 2 * max_clock_skew_ms ({}) must stay below lease_ms ({})",
                self.max_clock_skew_ms, self.lease_ms
            )));
        }
        Ok(())
    }

    /// The [`Config`] a frontend client of this deployment runs with.
    pub fn client_config(&self) -> Config {
        Config {
            region_size: self.region_size,
            replication: self.replication,
            storage_servers: self.storage.len() as u32,
            meta_shards: self.shards,
            meta_paxos: true,
            meta_group_replicas: self.replicas as u8,
            meta_2pc: true,
            meta_lease: Duration::from_millis(self.lease_ms),
            max_clock_skew: Duration::from_millis(self.max_clock_skew_ms),
            backing_files_per_server: self.backing_files,
            ring_vnodes: self.ring_vnodes,
            ..Config::default()
        }
    }
}

/// The meta process's server side: one standalone replica per shard,
/// each envelope dispatched on its shard id.
pub struct ShardRouter {
    replicas: Vec<Arc<GroupReplica>>,
}

impl Handler for ShardRouter {
    fn serve(&self, req: &Request) -> Result<Response> {
        let shard = req.shard().ok_or_else(|| {
            Error::Unsupported(format!("meta replica cannot serve {}", req.op_name()))
        })?;
        let replica = self.replicas.get(shard as usize).ok_or_else(|| {
            Error::InvalidArgument(format!("unknown shard {shard} at this meta replica"))
        })?;
        replica.serve(req)
    }
}

/// A running meta replica process body: replica `id` of every shard,
/// serving until dropped.
pub struct MetaNode {
    server: SocketServer,
}

impl MetaNode {
    /// The bound listen address (write it to the ready file so a
    /// port-0 bind is discoverable).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.server.addr()
    }
}

/// Boot replica `replica` (1-based — 0 is the frontend's) of every
/// shard and serve the group plane at `bind`.
pub fn run_meta(cfg: &DeployConfig, replica: u32, bind: &str) -> Result<MetaNode> {
    if replica == 0 || replica >= cfg.replicas {
        return Err(Error::InvalidArgument(format!(
            "meta replica index {replica} outside 1..{}",
            cfg.replicas
        )));
    }
    let clock = LeaseClock::auto_anchored();
    let replicas: Vec<Arc<GroupReplica>> = (0..cfg.shards)
        .map(|shard| {
            let wal = cfg.wal_dir.as_ref().map(|root| WalSetup {
                dir: root
                    .join(format!("shard-{shard}"))
                    .join(format!("replica-{replica}")),
                sync: WalSync::Always,
                checkpoint_every: cfg.wal_checkpoint_every,
            });
            GroupReplica::standalone(shard, replica, clock.clone(), cfg.lease_ms, wal)
        })
        .collect::<Result<_>>()?;
    let router = Arc::new(ShardRouter { replicas }) as Peer;
    let server = SocketServer::serve(router, bind)?;
    Ok(MetaNode { server })
}

/// A running storage process body.
pub struct StorageNode {
    server: SocketServer,
}

impl StorageNode {
    pub fn addr(&self) -> std::net::SocketAddr {
        self.server.addr()
    }
}

/// Boot storage server `id` and serve the data plane at `bind`.
pub fn run_storage(cfg: &DeployConfig, id: u32, bind: &str) -> Result<StorageNode> {
    let dir = cfg.data_dir.as_ref().map(|d| d.join(format!("server-{id}")));
    let server = Arc::new(StorageServer::new(id, dir, cfg.backing_files)?);
    let server = SocketServer::serve(server as Peer, bind)?;
    Ok(StorageNode { server })
}

/// Build the frontend's replicated metadata store: replica 0 of every
/// shard lives here, remote members are reached through `remote` (one
/// peer per meta PROCESS — each serves all shards through its
/// [`ShardRouter`]).  Exposed separately from [`run_frontend`] so the
/// multi-process integration test can drive 2PC and fault hooks
/// against the store directly.
pub fn frontend_store(
    cfg: &DeployConfig,
    transport: Arc<Transport>,
    clock: LeaseClock,
    remote: Vec<Peer>,
) -> ReplicatedMetaStore {
    let groups = (0..cfg.shards)
        .map(|shard| {
            ShardGroup::with_remote_members(
                shard,
                transport.clone(),
                clock.clone(),
                cfg.lease_ms,
                remote.clone(),
            )
        })
        .collect();
    ReplicatedMetaStore::from_groups(groups, clock, cfg.lease_ms)
        .two_pc(true)
        .max_clock_skew(cfg.max_clock_skew_ms)
}

/// A running frontend: the full client stack over socket peers.
pub struct Frontend {
    config: Config,
    meta: Arc<MetaService>,
    storage: Arc<StorageCluster>,
    ring: Ring,
    transport: Arc<Transport>,
}

impl Frontend {
    pub fn client(&self) -> WtfClient {
        WtfClient::with_transport(
            self.config.clone(),
            self.meta.clone(),
            self.storage.clone(),
            self.ring.clone(),
            self.transport.clone(),
        )
    }

    pub fn meta(&self) -> &Arc<MetaService> {
        &self.meta
    }
}

/// Assemble a frontend from the deployment config: remote socket peers
/// for every meta replica and storage server, local shard-group
/// leaders, and the root directory created if this is a fresh
/// namespace.
pub fn run_frontend(cfg: &DeployConfig) -> Result<Frontend> {
    let config = cfg.client_config();
    config.validate()?;
    let transport = Arc::new(Transport::new(LinkModel::instant(), config.transport_workers));
    let clock = LeaseClock::auto_anchored();
    let remote: Vec<Peer> = cfg
        .meta
        .iter()
        .map(|a| Arc::new(SocketPeer::new(a.clone())) as Peer)
        .collect();
    let store = frontend_store(cfg, transport.clone(), clock, remote);
    let meta = Arc::new(MetaService::replicated(store, Duration::ZERO, Metrics::new()));

    let mut storage = StorageCluster::new(Vec::new());
    for (id, addr) in cfg.storage.iter().enumerate() {
        storage.set_remote(id as u32, Arc::new(SocketPeer::new(addr.clone())) as Peer);
    }
    let ids: Vec<u32> = (0..cfg.storage.len() as u32).collect();
    let ring = Ring::new(&ids, cfg.ring_vnodes);

    ensure_root(&meta)?;
    Ok(Frontend {
        config,
        meta,
        storage: Arc::new(storage),
        ring,
        transport,
    })
}

/// Create the root directory exactly once per namespace: a second
/// frontend (or a restart) finds it already present and moves on.
fn ensure_root(meta: &Arc<MetaService>) -> Result<()> {
    let root = Inode::new_directory(1, 0o755);
    let mut t = MetaTxn::new(meta.clone());
    t.push(MetaOp::PathInsert {
        key: Key::path("/"),
        inode: 1,
        expect_absent: true,
    });
    t.push(MetaOp::Put {
        key: Key::inode(1),
        value: Value::Inode(root),
    });
    t.push(MetaOp::Put {
        key: Key::dir(1),
        value: Value::Dir(DirEntries::new()),
    });
    match t.commit() {
        Ok(_) => Ok(()),
        Err(Error::AlreadyExists(_)) | Err(Error::TxnConflict { .. }) => Ok(()),
        Err(e) => Err(e),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"{
        "shards": 2,
        "replicas": 3,
        "lease_ms": 400,
        "max_clock_skew_ms": 50,
        "meta": ["127.0.0.1:7101", "127.0.0.1:7102"],
        "storage": ["127.0.0.1:7201", "127.0.0.1:7202"],
        "wal_dir": "/tmp/wtf-wal"
    }"#;

    #[test]
    fn parses_a_full_deployment() {
        let c = DeployConfig::parse(DOC).unwrap();
        assert_eq!(c.shards, 2);
        assert_eq!(c.replicas, 3);
        assert_eq!(c.meta.len(), 2);
        assert_eq!(c.storage.len(), 2);
        assert_eq!(c.wal_dir.as_deref(), Some(std::path::Path::new("/tmp/wtf-wal")));
        assert_eq!(c.data_dir, None);
        let cc = c.client_config();
        assert!(cc.meta_paxos && cc.meta_2pc);
        assert_eq!(cc.max_clock_skew, Duration::from_millis(50));
        cc.validate().unwrap();
    }

    #[test]
    fn rejects_mismatched_membership() {
        // Two meta addresses claim replicas 1 and 2; replicas: 2 leaves
        // one of them unaccounted for.
        let bad = DOC.replace("\"replicas\": 3", "\"replicas\": 2");
        assert!(DeployConfig::parse(&bad).is_err());
        // A skew budget that swallows the lease window.
        let bad = DOC.replace("\"max_clock_skew_ms\": 50", "\"max_clock_skew_ms\": 200");
        assert!(DeployConfig::parse(&bad).is_err());
        // Garbage JSON fails typed, not by panic.
        assert!(DeployConfig::parse("{").is_err());
        assert!(DeployConfig::parse("{\"meta\": 7}").is_err());
    }

    #[test]
    fn meta_replica_index_is_bounded() {
        let c = DeployConfig::parse(DOC).unwrap();
        assert!(run_meta(&c, 0, "127.0.0.1:0").is_err(), "0 is the frontend's");
        assert!(run_meta(&c, 3, "127.0.0.1:0").is_err(), "past the group");
    }

    #[test]
    fn one_process_cluster_round_trips_through_sockets() {
        // The whole Fig. 1 split, in one test process: two meta replica
        // "nodes", two storage nodes, and a frontend — every hop over
        // real loopback sockets.
        let tmp = crate::util::TempDir::new("wtf-deploy").unwrap();
        let mut c = DeployConfig::parse(DOC).unwrap();
        c.wal_dir = Some(tmp.path().join("wal"));
        c.data_dir = Some(tmp.path().join("data"));
        let m1 = run_meta(&c, 1, "127.0.0.1:0").unwrap();
        let m2 = run_meta(&c, 2, "127.0.0.1:0").unwrap();
        let s0 = run_storage(&c, 0, "127.0.0.1:0").unwrap();
        let s1 = run_storage(&c, 1, "127.0.0.1:0").unwrap();
        c.meta = vec![m1.addr().to_string(), m2.addr().to_string()];
        c.storage = vec![s0.addr().to_string(), s1.addr().to_string()];

        let f = run_frontend(&c).unwrap();
        let client = f.client();
        assert!(client.exists("/"));
        let mut fd = client.create("/multi").unwrap();
        client.write(&mut fd, b"process boundary").unwrap();
        assert_eq!(client.read_at(&fd, 0, 16).unwrap(), b"process boundary");
        assert!(client.exists("/multi"));
    }
}
