//! The central HDFS name node: all filesystem metadata in one process's
//! memory, the design WTF's §5 calls the "scalability bottleneck
//! inherent to the limits of a single server".

use crate::error::{Error, Result};
use crate::types::ServerId;
use std::collections::HashMap;
use std::sync::Mutex;

/// Identifier of one block.
pub type BlockId = u64;

/// Where a block lives and how many bytes of it are visible.
#[derive(Clone, Debug)]
pub struct BlockInfo {
    pub id: BlockId,
    /// Data nodes holding replicas (pipeline order).
    pub replicas: Vec<ServerId>,
    /// Visible length (grows on hflush up to the block size).
    pub len: u64,
}

#[derive(Clone, Debug, Default)]
struct FileMeta {
    blocks: Vec<BlockInfo>,
    /// Visible length (hflush-published).
    len: u64,
    under_construction: bool,
}

#[derive(Debug, Default)]
struct State {
    files: HashMap<String, FileMeta>,
    next_block: BlockId,
    rr_cursor: u32,
}

/// The name node.  One big lock, as in the original (the HDFS namesystem
/// lock is famously coarse).
#[derive(Debug)]
pub struct NameNode {
    block_size: u64,
    replication: u8,
    datanodes: u32,
    state: Mutex<State>,
}

impl NameNode {
    pub fn new(block_size: u64, replication: u8, datanodes: u32) -> Self {
        NameNode {
            block_size,
            replication,
            datanodes,
            state: Mutex::new(State::default()),
        }
    }

    pub fn block_size(&self) -> u64 {
        self.block_size
    }

    /// Create a file for writing; fails if it exists (HDFS create).
    pub fn create(&self, path: &str) -> Result<()> {
        let mut s = self.state.lock().unwrap();
        if s.files.contains_key(path) {
            return Err(Error::AlreadyExists(path.into()));
        }
        s.files.insert(
            path.to_string(),
            FileMeta {
                blocks: Vec::new(),
                len: 0,
                under_construction: true,
            },
        );
        Ok(())
    }

    /// Allocate the next block of `path`, choosing `replication` data
    /// nodes round-robin (HDFS's default placement modulo rack awareness).
    pub fn add_block(&self, path: &str) -> Result<BlockInfo> {
        let mut s = self.state.lock().unwrap();
        let id = s.next_block;
        s.next_block += 1;
        let want = (self.replication.max(1) as u32).min(self.datanodes) as usize;
        let mut replicas = Vec::with_capacity(want);
        for i in 0..want {
            replicas.push((s.rr_cursor + i as u32) % self.datanodes);
        }
        s.rr_cursor = (s.rr_cursor + 1) % self.datanodes;
        let info = BlockInfo {
            id,
            replicas,
            len: 0,
        };
        let file = s
            .files
            .get_mut(path)
            .ok_or_else(|| Error::NotFound(path.into()))?;
        if !file.under_construction {
            return Err(Error::Unsupported(
                "append to closed file requires reopen-for-append".into(),
            ));
        }
        file.blocks.push(info.clone());
        Ok(info)
    }

    /// Publish `new_len` bytes of the last block (hflush).
    pub fn publish(&self, path: &str, block: BlockId, block_len: u64) -> Result<()> {
        let mut s = self.state.lock().unwrap();
        let block_size = self.block_size;
        let file = s
            .files
            .get_mut(path)
            .ok_or_else(|| Error::NotFound(path.into()))?;
        let b = file
            .blocks
            .iter_mut()
            .find(|b| b.id == block)
            .ok_or_else(|| Error::CorruptMetadata(format!("block {block} not in {path}")))?;
        if block_len > block_size {
            return Err(Error::InvalidArgument("block overflow".into()));
        }
        b.len = b.len.max(block_len);
        file.len = file
            .blocks
            .iter()
            .map(|b| b.len)
            .sum();
        Ok(())
    }

    /// Close a file (no further appends without reopen).
    pub fn complete(&self, path: &str) -> Result<()> {
        let mut s = self.state.lock().unwrap();
        let file = s
            .files
            .get_mut(path)
            .ok_or_else(|| Error::NotFound(path.into()))?;
        file.under_construction = false;
        Ok(())
    }

    /// Reopen for append (HDFS append support, the feature whose bug
    /// forced the paper's 64 MB block-size workaround).
    pub fn reopen_for_append(&self, path: &str) -> Result<Option<BlockInfo>> {
        let mut s = self.state.lock().unwrap();
        let block_size = self.block_size;
        let file = s
            .files
            .get_mut(path)
            .ok_or_else(|| Error::NotFound(path.into()))?;
        file.under_construction = true;
        Ok(file
            .blocks
            .last()
            .filter(|b| b.len < block_size)
            .cloned())
    }

    /// Visible length of `path`.
    pub fn len(&self, path: &str) -> Result<u64> {
        let s = self.state.lock().unwrap();
        s.files
            .get(path)
            .map(|f| f.len)
            .ok_or_else(|| Error::NotFound(path.into()))
    }

    pub fn exists(&self, path: &str) -> bool {
        self.state.lock().unwrap().files.contains_key(path)
    }

    /// Block layout of `path` (for readers).
    pub fn blocks(&self, path: &str) -> Result<Vec<BlockInfo>> {
        let s = self.state.lock().unwrap();
        s.files
            .get(path)
            .map(|f| f.blocks.clone())
            .ok_or_else(|| Error::NotFound(path.into()))
    }

    pub fn delete(&self, path: &str) -> Result<()> {
        let mut s = self.state.lock().unwrap();
        s.files
            .remove(path)
            .map(|_| ())
            .ok_or_else(|| Error::NotFound(path.into()))
    }

    /// Number of files (observability).
    pub fn file_count(&self) -> usize {
        self.state.lock().unwrap().files.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn create_add_publish_len() {
        let nn = NameNode::new(100, 2, 4);
        nn.create("/f").unwrap();
        assert!(matches!(nn.create("/f"), Err(Error::AlreadyExists(_))));
        let b0 = nn.add_block("/f").unwrap();
        assert_eq!(b0.replicas.len(), 2);
        nn.publish("/f", b0.id, 60).unwrap();
        assert_eq!(nn.len("/f").unwrap(), 60);
        let b1 = nn.add_block("/f").unwrap();
        nn.publish("/f", b0.id, 100).unwrap();
        nn.publish("/f", b1.id, 30).unwrap();
        assert_eq!(nn.len("/f").unwrap(), 130);
    }

    #[test]
    fn closed_files_reject_new_blocks() {
        let nn = NameNode::new(100, 1, 2);
        nn.create("/f").unwrap();
        nn.complete("/f").unwrap();
        assert!(nn.add_block("/f").is_err());
        // Reopen-for-append restores writability.
        nn.reopen_for_append("/f").unwrap();
        assert!(nn.add_block("/f").is_ok());
    }

    #[test]
    fn publish_rejects_block_overflow() {
        let nn = NameNode::new(100, 1, 2);
        nn.create("/f").unwrap();
        let b = nn.add_block("/f").unwrap();
        assert!(nn.publish("/f", b.id, 101).is_err());
    }

    #[test]
    fn round_robin_spreads_blocks() {
        let nn = NameNode::new(10, 1, 3);
        nn.create("/f").unwrap();
        let mut seen = std::collections::HashSet::new();
        for _ in 0..3 {
            seen.insert(nn.add_block("/f").unwrap().replicas[0]);
        }
        assert_eq!(seen.len(), 3);
    }

    #[test]
    fn delete_removes() {
        let nn = NameNode::new(10, 1, 1);
        nn.create("/f").unwrap();
        nn.delete("/f").unwrap();
        assert!(!nn.exists("/f"));
        assert!(nn.delete("/f").is_err());
    }
}
