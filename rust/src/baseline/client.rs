//! The HDFS client: append-only writers with hflush, streaming readers
//! with readahead, and positional (random) reads.  Random WRITES are
//! structurally impossible — the API has no way to express them, exactly
//! like HDFS (§4.2: "applications that need to change a file must
//! rewrite the file in its entirety").

use super::datanode::DataNode;
use super::namenode::{BlockInfo, NameNode};
use super::HdfsConfig;
use crate::error::{Error, Result};
use crate::net::{Peer, Request, Transport};
use crate::types::ServerId;
use std::sync::Arc;

/// Client handle bound to one hdfs-lite deployment.  Block I/O goes
/// through the shared transport; the write pipeline remains a sequential
/// replica chain (the HDFS protocol under comparison), so unlike WTF it
/// pays one wire time per replica.
#[derive(Clone)]
pub struct HdfsClient {
    config: HdfsConfig,
    namenode: Arc<NameNode>,
    datanodes: Vec<Arc<DataNode>>,
    transport: Arc<Transport>,
}

impl HdfsClient {
    pub fn new(
        config: HdfsConfig,
        namenode: Arc<NameNode>,
        datanodes: Vec<Arc<DataNode>>,
        transport: Arc<Transport>,
    ) -> Self {
        HdfsClient {
            config,
            namenode,
            datanodes,
            transport,
        }
    }

    fn node(&self, id: ServerId) -> Result<&Arc<DataNode>> {
        self.datanodes
            .get(id as usize)
            .ok_or(Error::ServerUnavailable(id))
    }

    /// Append `data` to `block` on data node `id`, as an envelope.
    fn transport_append(&self, id: ServerId, block: u64, data: Arc<[u8]>) -> Result<u64> {
        let peer = self.node(id)?.clone() as Peer;
        self.transport
            .call(peer, Request::AppendBlock { block, data })?
            .into_block_len()
    }

    /// Positional block read on data node `id`, as an envelope.
    fn transport_read(&self, id: ServerId, block: u64, offset: u64, len: u64) -> Result<Vec<u8>> {
        let peer = self.node(id)?.clone() as Peer;
        self.transport
            .call(peer, Request::ReadBlock { block, offset, len })?
            .into_bytes()
    }

    /// Create a file and return its writer.
    pub fn create(&self, path: &str) -> Result<HdfsWriter> {
        self.namenode.create(path)?;
        Ok(HdfsWriter {
            client: self.clone(),
            path: path.to_string(),
            current: None,
            buffer: Vec::new(),
            closed: false,
        })
    }

    /// Reopen an existing file for appending at the end.
    pub fn append(&self, path: &str) -> Result<HdfsWriter> {
        let current = self.namenode.reopen_for_append(path)?;
        Ok(HdfsWriter {
            client: self.clone(),
            path: path.to_string(),
            current,
            buffer: Vec::new(),
            closed: false,
        })
    }

    /// Open a file for reading.
    pub fn open(&self, path: &str) -> Result<HdfsReader> {
        if !self.namenode.exists(path) {
            return Err(Error::NotFound(path.into()));
        }
        Ok(HdfsReader {
            client: self.clone(),
            path: path.to_string(),
            pos: 0,
            readahead: Vec::new(),
            readahead_at: 0,
        })
    }

    pub fn len(&self, path: &str) -> Result<u64> {
        self.namenode.len(path)
    }

    pub fn exists(&self, path: &str) -> bool {
        self.namenode.exists(path)
    }

    /// Delete a file and its blocks.
    pub fn delete(&self, path: &str) -> Result<()> {
        let blocks = self.namenode.blocks(path)?;
        self.namenode.delete(path)?;
        for b in blocks {
            for r in b.replicas {
                if let Ok(dn) = self.node(r) {
                    dn.delete_block(b.id);
                }
            }
        }
        Ok(())
    }

    /// Positional read without a stream (no readahead) — HDFS pread.
    pub fn read_at(&self, path: &str, offset: u64, len: u64) -> Result<Vec<u8>> {
        let file_len = self.namenode.len(path)?;
        if offset >= file_len {
            return Ok(Vec::new());
        }
        let len = len.min(file_len - offset);
        let blocks = self.namenode.blocks(path)?;
        let mut out = Vec::with_capacity(len as usize);
        let mut cursor = offset;
        let end = offset + len;
        while cursor < end {
            let (block, block_off) = locate(&blocks, cursor)?;
            let take = (block.len - block_off).min(end - cursor);
            let data = self.read_block_failover(block, block_off, take)?;
            out.extend_from_slice(&data);
            cursor += take;
        }
        Ok(out)
    }

    fn read_block_failover(&self, block: &BlockInfo, off: u64, len: u64) -> Result<Vec<u8>> {
        let mut last = Error::InvalidArgument("no replicas".into());
        for &r in &block.replicas {
            match self.transport_read(r, block.id, off, len) {
                Ok(d) => return Ok(d),
                Err(e) => last = e,
            }
        }
        Err(last)
    }
}

/// Map a file offset to `(block, offset in block)` using visible lengths.
fn locate(blocks: &[BlockInfo], offset: u64) -> Result<(&BlockInfo, u64)> {
    let mut base = 0u64;
    for b in blocks {
        if offset < base + b.len {
            return Ok((b, offset - base));
        }
        base += b.len;
    }
    Err(Error::InvalidArgument(format!(
        "offset {offset} beyond visible length"
    )))
}

/// Append-only writer with client-side buffering and hflush.
pub struct HdfsWriter {
    client: HdfsClient,
    path: String,
    /// Block currently being filled.
    current: Option<BlockInfo>,
    /// Bytes not yet pushed to the pipeline.
    buffer: Vec<u8>,
    closed: bool,
}

impl HdfsWriter {
    /// Buffer `data` (nothing is visible until [`Self::hflush`] /
    /// [`Self::close`]).
    pub fn write(&mut self, data: &[u8]) -> Result<()> {
        if self.closed {
            return Err(Error::InvalidArgument("write after close".into()));
        }
        self.buffer.extend_from_slice(data);
        // Flush full blocks eagerly to bound the buffer.
        while self.buffer.len() as u64 >= self.client.config.block_size {
            self.push_one_block()?;
        }
        Ok(())
    }

    /// Push buffered bytes up to one block boundary into the pipeline.
    fn push_one_block(&mut self) -> Result<()> {
        let block_size = self.client.config.block_size;
        // Allocate a block if needed.
        if self.current.is_none() {
            self.current = Some(self.client.namenode.add_block(&self.path)?);
        }
        let cur = self.current.as_ref().unwrap().clone();
        let room = block_size - self.client.node(cur.replicas[0])?.block_len(cur.id);
        let take = (room as usize).min(self.buffer.len());
        let chunk: Arc<[u8]> = self.buffer.drain(..take).collect::<Vec<u8>>().into();
        // Write pipeline: every replica, in order (HDFS datanode chain) —
        // deliberately NOT a scatter: store-and-forward replication is
        // the baseline behavior WTF's parallel fan-out is measured
        // against.
        let mut new_len = 0;
        for &r in &cur.replicas {
            new_len = self.client.transport_append(r, cur.id, chunk.clone())?;
        }
        self.client.namenode.publish(&self.path, cur.id, new_len)?;
        if new_len >= block_size {
            self.current = None; // next write allocates a fresh block
        }
        Ok(())
    }

    /// Make everything written so far visible to readers.  Matches HDFS
    /// hflush: durability is NOT promised, visibility is.
    pub fn hflush(&mut self) -> Result<()> {
        while !self.buffer.is_empty() {
            self.push_one_block()?;
        }
        Ok(())
    }

    /// Flush and seal the file.
    pub fn close(&mut self) -> Result<()> {
        if self.closed {
            return Ok(());
        }
        self.hflush()?;
        self.client.namenode.complete(&self.path)?;
        self.closed = true;
        Ok(())
    }
}

/// Streaming reader with readahead.
pub struct HdfsReader {
    client: HdfsClient,
    path: String,
    pos: u64,
    readahead: Vec<u8>,
    readahead_at: u64,
}

impl HdfsReader {
    /// Sequential read with readahead; short only at EOF.
    pub fn read(&mut self, len: u64) -> Result<Vec<u8>> {
        let mut out = Vec::with_capacity(len as usize);
        let mut remaining = len;
        while remaining > 0 {
            // Serve from the readahead buffer when possible.
            if self.pos >= self.readahead_at
                && self.pos < self.readahead_at + self.readahead.len() as u64
            {
                let start = (self.pos - self.readahead_at) as usize;
                let take = (self.readahead.len() - start).min(remaining as usize);
                out.extend_from_slice(&self.readahead[start..start + take]);
                self.pos += take as u64;
                remaining -= take as u64;
                continue;
            }
            // Refill: fetch max(requested, readahead) bytes.
            let file_len = self.client.namenode.len(&self.path)?;
            if self.pos >= file_len {
                break;
            }
            let fetch = remaining.max(self.client.config.readahead);
            let data = self.client.read_at(&self.path, self.pos, fetch)?;
            if data.is_empty() {
                break;
            }
            self.readahead_at = self.pos;
            self.readahead = data;
        }
        Ok(out)
    }

    /// Reposition the stream (reads only — this is HDFS).
    pub fn seek(&mut self, pos: u64) {
        self.pos = pos;
    }

    pub fn pos(&self) -> u64 {
        self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::super::{HdfsCluster, HdfsConfig};
    use crate::net::LinkModel;

    fn cluster() -> HdfsCluster {
        HdfsCluster::new(HdfsConfig::test(), None, LinkModel::instant()).unwrap()
    }

    #[test]
    fn write_spans_blocks_and_reads_back() {
        let cl = cluster();
        let c = cl.client();
        let mut w = c.create("/big").unwrap();
        let data: Vec<u8> = (0..3 * 4096 + 17).map(|i| (i % 251) as u8).collect();
        w.write(&data).unwrap();
        w.close().unwrap();
        assert_eq!(c.len("/big").unwrap(), data.len() as u64);
        let back = c.read_at("/big", 0, data.len() as u64).unwrap();
        assert_eq!(back, data);
        // Cross-block positional read.
        assert_eq!(
            c.read_at("/big", 4090, 12).unwrap(),
            &data[4090..4102]
        );
    }

    #[test]
    fn hflush_publishes_without_close() {
        let cl = cluster();
        let c = cl.client();
        let mut w = c.create("/f").unwrap();
        w.write(b"invisible").unwrap();
        assert_eq!(c.len("/f").unwrap(), 0, "buffered bytes invisible");
        w.hflush().unwrap();
        assert_eq!(c.len("/f").unwrap(), 9);
        let mut r = c.open("/f").unwrap();
        assert_eq!(r.read(9).unwrap(), b"invisible");
        w.close().unwrap();
    }

    #[test]
    fn reopen_for_append_continues_partial_block() {
        let cl = cluster();
        let c = cl.client();
        let mut w = c.create("/log").unwrap();
        w.write(b"first,").unwrap();
        w.close().unwrap();
        let mut w = c.append("/log").unwrap();
        w.write(b"second").unwrap();
        w.close().unwrap();
        assert_eq!(c.read_at("/log", 0, 12).unwrap(), b"first,second");
    }

    #[test]
    fn no_random_writes_by_construction() {
        // The writer API exposes only write/hflush/close: there is no
        // way to express a random write.  Verify append-only behavior.
        let cl = cluster();
        let c = cl.client();
        let mut w = c.create("/ro").unwrap();
        w.write(b"abc").unwrap();
        w.close().unwrap();
        assert!(w.write(b"late").is_err(), "write after close");
    }

    #[test]
    fn streaming_reader_with_readahead() {
        let cl = cluster();
        let c = cl.client();
        let mut w = c.create("/stream").unwrap();
        let data: Vec<u8> = (0..10_000).map(|i| (i % 256) as u8).collect();
        w.write(&data).unwrap();
        w.close().unwrap();
        let reads_before: u64 = cl.bytes_read();
        let mut r = c.open("/stream").unwrap();
        let mut got = Vec::new();
        // 100 tiny reads; readahead (1 KB in test config) batches them.
        for _ in 0..100 {
            got.extend(r.read(10).unwrap());
        }
        assert_eq!(&got[..], &data[..1000]);
        let fetched = cl.bytes_read() - reads_before;
        // Without readahead this would be 100 separate 10 B reads; with
        // it, we fetch ~1 KB chunks: roughly 1000 bytes total.
        assert!(fetched >= 1000 && fetched < 3000, "fetched {fetched}");
        // Seek + continue.
        r.seek(9990);
        assert_eq!(r.read(100).unwrap(), &data[9990..]);
    }

    #[test]
    fn delete_removes_blocks() {
        let cl = cluster();
        let c = cl.client();
        let mut w = c.create("/d").unwrap();
        w.write(&vec![1u8; 5000]).unwrap();
        w.close().unwrap();
        c.delete("/d").unwrap();
        assert!(!c.exists("/d"));
        assert!(c.read_at("/d", 0, 1).is_err());
    }
}
