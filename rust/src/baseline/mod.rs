//! "hdfs-lite": the comparison filesystem of the paper's evaluation (§4).
//!
//! A faithful-in-the-properties-that-matter model of HDFS 2.7:
//!
//! * **Central name node** holding all metadata in memory (the
//!   scalability bottleneck WTF's design removes).
//! * **Block-based data nodes** (64 MB default blocks, matching the
//!   paper's configuration workaround), each block replicated on R nodes
//!   via a write pipeline.
//! * **Append-only semantics** — no concurrent writers, no random
//!   writes; applications that modify a file must rewrite it entirely.
//! * **`hflush`** — publishes buffered writes to readers without fsync,
//!   the exact guarantee the paper equalizes against WTF writes.
//! * **Client + server readahead** (4 MB default) for streaming reads —
//!   the feature behind HDFS's large-block sequential-read edge and its
//!   small-random-read penalty (Figs. 11/12).

pub mod client;
pub mod datanode;
pub mod namenode;

pub use client::{HdfsClient, HdfsReader, HdfsWriter};
pub use datanode::DataNode;
pub use namenode::{BlockId, BlockInfo, NameNode};

use crate::error::Result;
use crate::net::{LinkModel, Transport};
use std::path::PathBuf;
use std::sync::Arc;

/// Configuration for an hdfs-lite deployment.
#[derive(Clone, Debug)]
pub struct HdfsConfig {
    pub block_size: u64,
    pub replication: u8,
    pub datanodes: u32,
    /// Client/server readahead for sequential reads.
    pub readahead: u64,
    /// Transport worker-pool size (matches the WTF default so the §4
    /// comparison runs both stacks on equal plumbing; `0` = inline).
    pub transport_workers: u32,
}

impl Default for HdfsConfig {
    fn default() -> Self {
        HdfsConfig {
            block_size: 64 * 1024 * 1024,
            replication: 2,
            datanodes: 12,
            readahead: 4 * 1024 * 1024,
            transport_workers: 8,
        }
    }
}

impl HdfsConfig {
    pub fn test() -> Self {
        HdfsConfig {
            block_size: 4096,
            replication: 2,
            datanodes: 4,
            readahead: 1024,
            ..Default::default()
        }
    }
}

/// An assembled hdfs-lite deployment.  Block I/O travels through the
/// same [`Transport`] the WTF stack uses, so the §4 comparison charges
/// both filesystems an identical wire model.
pub struct HdfsCluster {
    config: HdfsConfig,
    namenode: Arc<NameNode>,
    datanodes: Vec<Arc<DataNode>>,
    transport: Arc<Transport>,
}

impl HdfsCluster {
    pub fn new(config: HdfsConfig, data_dir: Option<PathBuf>, link: LinkModel) -> Result<Self> {
        let transport = Arc::new(Transport::new(link, config.transport_workers));
        let mut datanodes = Vec::with_capacity(config.datanodes as usize);
        for id in 0..config.datanodes {
            let dir = data_dir.as_ref().map(|d| d.join(format!("dn-{id}")));
            datanodes.push(Arc::new(DataNode::new(id, dir)?));
        }
        let namenode = Arc::new(NameNode::new(config.block_size, config.replication, config.datanodes));
        Ok(HdfsCluster {
            config,
            namenode,
            datanodes,
            transport,
        })
    }

    pub fn client(&self) -> HdfsClient {
        HdfsClient::new(
            self.config.clone(),
            self.namenode.clone(),
            self.datanodes.clone(),
            self.transport.clone(),
        )
    }

    pub fn config(&self) -> &HdfsConfig {
        &self.config
    }

    pub fn namenode(&self) -> &Arc<NameNode> {
        &self.namenode
    }

    /// Aggregate bytes written to data nodes.
    pub fn bytes_written(&self) -> u64 {
        self.datanodes.iter().map(|d| d.metrics().bytes_written()).sum()
    }

    /// Aggregate bytes read from data nodes.
    pub fn bytes_read(&self) -> u64 {
        self.datanodes.iter().map(|d| d.metrics().bytes_read()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_smoke() {
        let cluster = HdfsCluster::new(HdfsConfig::test(), None, LinkModel::instant()).unwrap();
        let c = cluster.client();
        let mut w = c.create("/f").unwrap();
        w.write(b"hello").unwrap();
        w.hflush().unwrap();
        // Visible to readers after hflush, before close.
        let mut r = c.open("/f").unwrap();
        assert_eq!(r.read(5).unwrap(), b"hello");
        w.close().unwrap();
    }
}
