//! HDFS data node: stores blocks as local files, supports append and
//! positional read.  Server-side readahead is modeled in the client's
//! read path (one buffer per stream, as HDFS does).
//!
//! Like the WTF storage servers, data nodes serve their block I/O as
//! transport envelopes ([`Handler`]) so the baseline pays the same wire
//! model — the apples-to-apples requirement of §4.  (The HDFS write
//! *pipeline* stays sequential per replica in the client: that chain is
//! the protocol being compared against.)

use crate::error::{Error, Result};
use crate::metrics::Metrics;
use crate::net::{Handler, Request, Response};
use crate::types::ServerId;
use crate::util::TempDir;
use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::os::unix::fs::FileExt;
use std::path::PathBuf;
use std::sync::Mutex;

use super::namenode::BlockId;

/// One data node.
#[derive(Debug)]
pub struct DataNode {
    id: ServerId,
    _tempdir: Option<TempDir>,
    dir: PathBuf,
    blocks: Mutex<HashMap<BlockId, BlockFile>>,
    metrics: Metrics,
}

#[derive(Debug)]
struct BlockFile {
    file: File,
    len: u64,
}

impl DataNode {
    pub fn new(id: ServerId, dir: Option<PathBuf>) -> Result<Self> {
        let (tempdir, dir) = match dir {
            Some(d) => {
                std::fs::create_dir_all(&d)?;
                (None, d)
            }
            None => {
                let t = TempDir::new(&format!("hdfs-dn-{id}"))?;
                let p = t.path().to_path_buf();
                (Some(t), p)
            }
        };
        Ok(DataNode {
            id,
            _tempdir: tempdir,
            dir,
            blocks: Mutex::new(HashMap::new()),
            metrics: Metrics::new(),
        })
    }

    pub fn id(&self) -> ServerId {
        self.id
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Append `data` to `block` (creating it on first write).  Returns
    /// the block's new length.
    pub fn append_block(&self, block: BlockId, data: &[u8]) -> Result<u64> {
        let mut g = self.blocks.lock().unwrap();
        let entry = match g.get_mut(&block) {
            Some(b) => b,
            None => {
                let path = self.dir.join(format!("blk_{block:016x}"));
                let file = OpenOptions::new()
                    .read(true)
                    .write(true)
                    .create(true)
                    .truncate(true)
                    .open(path)?;
                g.insert(block, BlockFile { file, len: 0 });
                g.get_mut(&block).unwrap()
            }
        };
        entry.file.write_all_at(data, entry.len)?;
        entry.len += data.len() as u64;
        self.metrics.add_bytes_written(data.len() as u64);
        self.metrics.add_ops_written(1);
        Ok(entry.len)
    }

    /// Positional read within a block.
    pub fn read_block(&self, block: BlockId, offset: u64, len: u64) -> Result<Vec<u8>> {
        let g = self.blocks.lock().unwrap();
        let entry = g.get(&block).ok_or(Error::SliceNotFound {
            server: self.id,
            backing: 0,
            offset,
            len,
        })?;
        let len = len.min(entry.len.saturating_sub(offset));
        let mut buf = vec![0u8; len as usize];
        entry.file.read_exact_at(&mut buf, offset)?;
        drop(g);
        self.metrics.add_bytes_read(len);
        self.metrics.add_ops_read(1);
        Ok(buf)
    }

    /// Stored length of a block (0 when absent).
    pub fn block_len(&self, block: BlockId) -> u64 {
        self.blocks
            .lock()
            .unwrap()
            .get(&block)
            .map(|b| b.len)
            .unwrap_or(0)
    }

    pub fn delete_block(&self, block: BlockId) {
        self.blocks.lock().unwrap().remove(&block);
    }
}

/// Transport server side: the baseline's block I/O envelopes.
impl Handler for DataNode {
    fn serve(&self, req: &Request) -> Result<Response> {
        match req {
            Request::AppendBlock { block, data } => {
                Ok(Response::BlockLen(self.append_block(*block, data)?))
            }
            Request::ReadBlock { block, offset, len } => {
                Ok(Response::Bytes(self.read_block(*block, *offset, *len)?))
            }
            other => Err(Error::Unsupported(format!(
                "data node cannot serve {other:?}"
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn append_and_read() {
        let dn = DataNode::new(0, None).unwrap();
        assert_eq!(dn.append_block(7, b"abc").unwrap(), 3);
        assert_eq!(dn.append_block(7, b"def").unwrap(), 6);
        assert_eq!(dn.read_block(7, 0, 6).unwrap(), b"abcdef");
        assert_eq!(dn.read_block(7, 2, 2).unwrap(), b"cd");
        // Reads past the stored length are clamped, as with short reads.
        assert_eq!(dn.read_block(7, 4, 100).unwrap(), b"ef");
        assert!(dn.read_block(9, 0, 1).is_err());
    }

    #[test]
    fn blocks_are_independent() {
        let dn = DataNode::new(0, None).unwrap();
        dn.append_block(1, b"one").unwrap();
        dn.append_block(2, b"two").unwrap();
        assert_eq!(dn.read_block(1, 0, 3).unwrap(), b"one");
        assert_eq!(dn.read_block(2, 0, 3).unwrap(), b"two");
        dn.delete_block(1);
        assert!(dn.read_block(1, 0, 1).is_err());
        assert_eq!(dn.block_len(2), 3);
    }
}
