//! Leader leases for replicated metadata shard groups.
//!
//! A leader holds a time-bounded lease granted by a quorum of its group.
//! While the lease is valid the leader may (a) serve reads from its local
//! state without a quorum round — the read-lease — and (b) skip Paxos
//! phase 1 for fresh log slots, because no competing proposer can obtain
//! quorum grants until the lease expires.  Safety therefore rests on two
//! rules encoded here:
//!
//! * a replica never grants overlapping leases to different leaders
//!   ([`GrantState::grant`]);
//! * a replica that crashed holds off granting for one full lease window
//!   after recovery ([`GrantState::hold_off`]), since its pre-crash
//!   grants are volatile and may still be live.
//!
//! Time is a [`LeaseClock`]: wall-clock by default, manually advanced in
//! unit tests so expiry is deterministic.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Millisecond clock shared by one shard group (leader, replicas, and the
/// proposing front-end all read the same instance, which is what makes
/// in-process lease reasoning sound).
#[derive(Clone, Debug)]
pub struct LeaseClock {
    inner: Arc<ClockInner>,
}

#[derive(Debug)]
struct ClockInner {
    manual: bool,
    base: Instant,
    offset_ms: AtomicU64,
}

impl LeaseClock {
    /// Wall-clock time (deployments, integration tests).
    pub fn auto() -> Self {
        LeaseClock {
            inner: Arc::new(ClockInner {
                manual: false,
                base: Instant::now(),
                offset_ms: AtomicU64::new(0),
            }),
        }
    }

    /// A monotonic clock anchored ONCE to the Unix wall clock at
    /// construction — the multi-process deployment clock.  Each process
    /// reads the wall clock exactly one time (here) and then advances by
    /// `Instant` alone, so an NTP step after boot can never move lease
    /// or hold-off reasoning; what remains is a fixed per-process anchor
    /// error, which is exactly the quantity `Config::max_clock_skew`
    /// budgets for.  Absolute `until_ms` values exchanged between
    /// processes (lease grants, coordinator claims) are comparable up to
    /// that bound; a plain [`LeaseClock::auto`] (ms since process start)
    /// would make them meaningless across processes.
    pub fn auto_anchored() -> Self {
        let anchor_ms = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_millis() as u64)
            .unwrap_or(0);
        LeaseClock {
            inner: Arc::new(ClockInner {
                manual: false,
                base: Instant::now(),
                offset_ms: AtomicU64::new(anchor_ms),
            }),
        }
    }

    /// A clock that only moves via [`LeaseClock::advance`] (unit tests).
    pub fn manual() -> Self {
        LeaseClock {
            inner: Arc::new(ClockInner {
                manual: true,
                base: Instant::now(),
                offset_ms: AtomicU64::new(0),
            }),
        }
    }

    pub fn now_ms(&self) -> u64 {
        let offset = self.inner.offset_ms.load(Ordering::Relaxed);
        if self.inner.manual {
            offset
        } else {
            self.inner.base.elapsed().as_millis() as u64 + offset
        }
    }

    /// Jump the clock forward (works in both modes; the only mover of a
    /// manual clock).
    pub fn advance(&self, ms: u64) {
        self.inner.offset_ms.fetch_add(ms, Ordering::Relaxed);
    }

    /// Wait for `ms` to pass: sleeps real time on an auto clock, advances
    /// a manual clock directly so election loops cannot deadlock in tests.
    pub fn sleep_ms(&self, ms: u64) {
        if self.inner.manual {
            self.advance(ms);
        } else {
            std::thread::sleep(std::time::Duration::from_millis(ms.max(1)));
        }
    }
}

impl Default for LeaseClock {
    fn default() -> Self {
        LeaseClock::auto()
    }
}

/// The validity bound a leaseholder may publish for ITSELF, for a grant
/// round whose requests left this process at `pre_send_ms`: anchored
/// BEFORE the round hits the wire (however long the grants dawdle in
/// flight, the holder's window only shrinks — a delayed grant can never
/// overstate it) and shrunk by the deployment's clock-skew allowance
/// (`Config::max_clock_skew`), so a holder clock running up to that much
/// fast still steps down before any replica's own clock would let it
/// re-grant.  Replicas record the full `pre_send_ms + lease_ms`; only
/// the holder's self-view is tightened.
pub fn holder_lease_bound(pre_send_ms: u64, lease_ms: u64, max_skew_ms: u64) -> u64 {
    (pre_send_ms + lease_ms).saturating_sub(max_skew_ms)
}

/// A granted (or observed) lease: `holder` leads until `until_ms`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Lease {
    pub holder: u32,
    pub until_ms: u64,
}

impl Lease {
    /// True while the lease still covers `now_ms`.
    pub fn covers(&self, now_ms: u64) -> bool {
        now_ms < self.until_ms
    }
}

/// One replica's grant bookkeeping: at most one live lease at a time,
/// plus the post-recovery hold-off window and the highest grant *epoch*
/// ever honored.  The epoch orders grant requests end-to-end: the
/// front-end stamps every election round with a fresh, strictly larger
/// epoch, so a duplicated or delayed-then-redelivered `LeaseRequest` is
/// recognizable as stale no matter when the network surfaces it.
#[derive(Clone, Copy, Debug, Default)]
pub struct GrantState {
    granted: Option<Lease>,
    hold_off_until: u64,
    last_epoch: u64,
}

impl GrantState {
    /// Grant (or renew) a lease to `leader` until `until_ms`, under
    /// grant-round `epoch`.  Refused while a different leader's grant is
    /// unexpired or during the post-recovery hold-off.  A fresh-epoch
    /// renewal by the same leader may extend; a **stale** epoch (replay
    /// of an envelope already answered) is acknowledged idempotently for
    /// the current holder but NEVER moves the recorded expiry, and is
    /// refused outright for anyone else — re-delivered grants must not
    /// extend leases.
    pub fn grant(&mut self, now_ms: u64, leader: u32, until_ms: u64, epoch: u64) -> bool {
        if now_ms < self.hold_off_until {
            return false;
        }
        if epoch <= self.last_epoch {
            // At-least-once delivery: this envelope was already answered
            // once.  Repeat the positive answer for the holder it went
            // to (the duplicate's response is discarded anyway), but the
            // stale evidence must not extend the lease or seat a new
            // holder.
            return matches!(self.granted, Some(l) if l.holder == leader);
        }
        match self.granted {
            Some(l) if l.holder != leader && l.covers(now_ms) => false,
            prior => {
                self.last_epoch = epoch;
                // A same-holder renewal never shrinks the recorded
                // expiry: concurrent renewals may arrive out of order.
                let until_ms = match prior {
                    Some(l) if l.holder == leader => l.until_ms.max(until_ms),
                    _ => until_ms,
                };
                self.granted = Some(Lease {
                    holder: leader,
                    until_ms,
                });
                true
            }
        }
    }

    /// Refuse all grants until `until_ms` — called on replica recovery,
    /// because whatever this replica granted before crashing is unknown
    /// and may still be live.
    pub fn hold_off(&mut self, until_ms: u64) {
        self.hold_off_until = until_ms;
        self.granted = None;
    }

    /// The current unexpired grant, if any.
    pub fn live_grant(&self, now_ms: u64) -> Option<Lease> {
        self.granted.filter(|l| l.covers(now_ms))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manual_clock_is_deterministic() {
        let c = LeaseClock::manual();
        assert_eq!(c.now_ms(), 0);
        c.advance(25);
        assert_eq!(c.now_ms(), 25);
        c.sleep_ms(5); // advances, never blocks
        assert_eq!(c.now_ms(), 30);
    }

    #[test]
    fn auto_clock_moves_forward() {
        let c = LeaseClock::auto();
        let a = c.now_ms();
        c.advance(10);
        assert!(c.now_ms() >= a + 10);
    }

    #[test]
    fn no_overlapping_grants_to_different_leaders() {
        let mut g = GrantState::default();
        assert!(g.grant(0, 1, 50, 1));
        assert!(!g.grant(10, 2, 60, 2), "overlapping grant to another leader");
        // Same leader renews freely under a fresh epoch.
        assert!(g.grant(10, 1, 80, 3));
        // After expiry anyone may acquire.
        assert!(g.grant(80, 2, 120, 4));
        assert_eq!(g.live_grant(90), Some(Lease { holder: 2, until_ms: 120 }));
    }

    #[test]
    fn recovery_hold_off_blocks_grants() {
        let mut g = GrantState::default();
        assert!(g.grant(0, 1, 50, 1));
        g.hold_off(100);
        assert!(!g.grant(60, 1, 120, 2), "hold-off refuses even the old holder");
        assert_eq!(g.live_grant(60), None, "pre-crash grant forgotten");
        assert!(g.grant(100, 2, 150, 3));
    }

    #[test]
    fn replayed_grant_acks_the_holder_but_never_extends() {
        let mut g = GrantState::default();
        assert!(g.grant(0, 1, 50, 7));
        // The network re-delivers the answered envelope — this time a
        // delayed retransmission carrying a later until_ms.  The holder
        // gets the same positive answer, but the lease must not move.
        assert!(g.grant(10, 1, 99, 7), "idempotent ack for the holder");
        assert_eq!(
            g.live_grant(10),
            Some(Lease { holder: 1, until_ms: 50 }),
            "a re-delivered grant extended the lease"
        );
        // An even staler epoch: same answer, same non-extension.
        assert!(g.grant(20, 1, 500, 3));
        assert_eq!(g.live_grant(20), Some(Lease { holder: 1, until_ms: 50 }));
    }

    #[test]
    fn stale_epoch_from_another_leader_is_rejected_even_after_expiry() {
        let mut g = GrantState::default();
        assert!(g.grant(0, 1, 50, 7));
        // Holder 1's lease has expired, but this envelope is a replay of
        // a grant round that already completed — a new holder may only
        // seat itself with fresh evidence.
        assert!(!g.grant(60, 2, 120, 7), "stale-epoch takeover");
        assert!(!g.grant(60, 2, 120, 2), "ancient-epoch takeover");
        assert_eq!(g.live_grant(60), None);
        // Fresh epoch after expiry: a normal handover.
        assert!(g.grant(60, 2, 120, 8));
        assert_eq!(g.live_grant(61), Some(Lease { holder: 2, until_ms: 120 }));
    }

    #[test]
    fn delayed_grant_publishes_only_the_pre_send_window() {
        // A 50 ms grant round leaves at t=100 and its replies are
        // delayed 40 ms on the wire.  The bug this pins against:
        // timestamping validity when the replies ARRIVE (t=140) would
        // publish until_ms=190, a 40 ms overstatement of what the
        // replicas actually granted relative to the request instant.
        let bound = holder_lease_bound(100, 50, 0);
        assert_eq!(bound, 150, "anchored at the pre-send instant");
        // With a 10 ms skew allowance the holder's own view shrinks
        // further: replicas record 150, the holder serves only to 140.
        let bound = holder_lease_bound(100, 50, 10);
        assert_eq!(bound, 140);
        let lease = Lease {
            holder: 0,
            until_ms: bound,
        };
        assert!(lease.covers(139));
        assert!(
            !lease.covers(140),
            "a holder running 10 ms fast has already stepped down when \
             a skew-lagged replica still sees 10 ms of grant left"
        );
    }

    #[test]
    fn holder_bound_never_underflows() {
        assert_eq!(holder_lease_bound(0, 5, 100), 0);
        let l = Lease {
            holder: 0,
            until_ms: holder_lease_bound(0, 5, 100),
        };
        assert!(!l.covers(0), "an all-skew lease is born expired");
    }

    #[test]
    fn anchored_clock_is_monotonic_and_absolute() {
        let c = LeaseClock::auto_anchored();
        let a = c.now_ms();
        // Anchored to the Unix epoch: any plausible run of this test is
        // far past 2020 in epoch-ms terms.
        assert!(a > 1_577_836_800_000, "epoch-anchored, got {a}");
        let b = c.now_ms();
        assert!(b >= a, "monotone");
    }

    #[test]
    fn lease_covers_half_open_interval() {
        let l = Lease {
            holder: 0,
            until_ms: 10,
        };
        assert!(l.covers(9));
        assert!(!l.covers(10));
    }
}
