//! The replicated coordinator: rendezvous point for every component (§2,
//! Fig. 1) and keeper of the storage-server list.
//!
//! The paper implements this as a ~960-line replicated object hosted by
//! Replicant, which Paxos-sequences function calls into the library.  We
//! do the same shape: [`CoordinatorState`] is the deterministic state
//! machine, [`paxos`] sequences [`CoordCmd`]s into a replicated log, and
//! every replica applies the log in order.  Clients read configuration
//! snapshots ([`ClusterConfig`]) tagged with an epoch; any config change
//! bumps the epoch.

pub mod lease;
pub mod paxos;

use crate::error::Result;
#[cfg(test)]
use crate::error::Error;
use crate::types::ServerId;
use std::sync::Mutex;

use std::collections::BTreeMap;

/// A function call into the replicated coordinator object.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub enum CoordCmd {
    /// Placeholder decided when a slot must be filled but no command is
    /// pending (never emitted by clients).
    #[default]
    Noop,
    /// A storage server announces itself.
    RegisterServer { id: ServerId, weight: u32 },
    /// Administratively (or via failure detection) take a server offline.
    OfflineServer { id: ServerId },
    /// Bring a previously-offline server back.
    OnlineServer { id: ServerId },
}

/// Status of one storage server in the configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ServerInfo {
    pub weight: u32,
    pub online: bool,
}

/// The deterministic state machine each replica applies.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CoordinatorState {
    pub epoch: u64,
    pub servers: BTreeMap<ServerId, ServerInfo>,
}

impl CoordinatorState {
    fn apply(&mut self, cmd: &CoordCmd) {
        match cmd {
            CoordCmd::Noop => {}
            CoordCmd::RegisterServer { id, weight } => {
                self.servers.insert(
                    *id,
                    ServerInfo {
                        weight: *weight,
                        online: true,
                    },
                );
                self.epoch += 1;
            }
            CoordCmd::OfflineServer { id } => {
                if let Some(s) = self.servers.get_mut(id) {
                    if s.online {
                        s.online = false;
                        self.epoch += 1;
                    }
                }
            }
            CoordCmd::OnlineServer { id } => {
                if let Some(s) = self.servers.get_mut(id) {
                    if !s.online {
                        s.online = true;
                        self.epoch += 1;
                    }
                }
            }
        }
    }
}

/// The configuration snapshot clients build their placement ring from.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ClusterConfig {
    pub epoch: u64,
    pub online_servers: Vec<ServerId>,
}

/// A Paxos-replicated coordinator deployment: `n` acceptors, `n` state
/// machine replicas, one shared command log.
#[derive(Debug)]
pub struct Coordinator {
    acceptors: Vec<paxos::Acceptor<CoordCmd>>,
    replicas: Vec<Mutex<ReplicaState>>,
    log: Mutex<Vec<CoordCmd>>,
}

#[derive(Debug, Default)]
struct ReplicaState {
    applied: usize,
    state: CoordinatorState,
}

impl Coordinator {
    /// A coordinator group with `replicas` members (paper default: 3+).
    pub fn new(replicas: u8) -> Self {
        let n = replicas.max(1) as usize;
        Coordinator {
            acceptors: (0..n).map(|_| paxos::Acceptor::new()).collect(),
            replicas: (0..n).map(|_| Mutex::new(ReplicaState::default())).collect(),
            log: Mutex::new(Vec::new()),
        }
    }

    /// Sequence `cmd` through Paxos and apply it on every replica.
    pub fn call(&self, cmd: CoordCmd) -> Result<ClusterConfig> {
        let slot = {
            let log = self.log.lock().unwrap();
            log.len()
        };
        let chosen = paxos::propose(&self.acceptors, slot, 0, cmd.clone())?;
        {
            let mut log = self.log.lock().unwrap();
            if log.len() == slot {
                log.push(chosen.clone());
            }
        }
        // If another proposal raced us into this slot, retry in the next.
        if chosen != cmd {
            return self.call(cmd);
        }
        self.catch_up_all();
        self.config()
    }

    fn catch_up_all(&self) {
        let log = self.log.lock().unwrap();
        for replica in &self.replicas {
            let mut r = replica.lock().unwrap();
            while r.applied < log.len() {
                let cmd = log[r.applied].clone();
                r.state.apply(&cmd);
                r.applied += 1;
            }
        }
    }

    /// Current configuration as served by the first live replica.
    pub fn config(&self) -> Result<ClusterConfig> {
        self.catch_up_all();
        let r = self.replicas[0].lock().unwrap();
        Ok(ClusterConfig {
            epoch: r.state.epoch,
            online_servers: r
                .state
                .servers
                .iter()
                .filter(|(_, info)| info.online)
                .map(|(id, _)| *id)
                .collect(),
        })
    }

    /// Failure injection: kill one acceptor.
    pub fn kill_acceptor(&self, idx: usize) {
        if let Some(a) = self.acceptors.get(idx) {
            a.set_alive(false);
        }
    }

    /// Recover one acceptor (its slot state was retained; real Replicant
    /// would resync from the log, which our shared log models).
    pub fn recover_acceptor(&self, idx: usize) {
        if let Some(a) = self.acceptors.get(idx) {
            a.set_alive(true);
        }
    }

    /// All replicas agree on the state (test invariant).
    pub fn replicas_converged(&self) -> bool {
        self.catch_up_all();
        let first = self.replicas[0].lock().unwrap().state.clone();
        self.replicas.iter().all(|r| r.lock().unwrap().state == first)
    }

    pub fn quorum_alive(&self) -> bool {
        let alive = self.acceptors.iter().filter(|a| a.is_alive()).count();
        alive > self.acceptors.len() / 2
    }
}

/// Convenience: register servers `0..servers` and return the coordinator.
pub fn bootstrap(replicas: u8, servers: u32) -> Result<Coordinator> {
    let c = Coordinator::new(replicas);
    for id in 0..servers {
        c.call(CoordCmd::RegisterServer { id, weight: 1 })?;
    }
    Ok(c)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registration_builds_config_with_epochs() {
        let c = bootstrap(3, 3).unwrap();
        let cfg = c.config().unwrap();
        assert_eq!(cfg.online_servers, vec![0, 1, 2]);
        assert_eq!(cfg.epoch, 3);
    }

    #[test]
    fn offline_online_cycle_bumps_epoch() {
        let c = bootstrap(3, 2).unwrap();
        let e0 = c.config().unwrap().epoch;
        let cfg = c.call(CoordCmd::OfflineServer { id: 1 }).unwrap();
        assert_eq!(cfg.online_servers, vec![0]);
        assert_eq!(cfg.epoch, e0 + 1);
        let cfg = c.call(CoordCmd::OnlineServer { id: 1 }).unwrap();
        assert_eq!(cfg.online_servers, vec![0, 1]);
        // Re-onlining an online server is a no-op for the epoch.
        c.call(CoordCmd::OnlineServer { id: 1 }).unwrap();
        assert_eq!(c.config().unwrap().epoch, e0 + 2);
    }

    #[test]
    fn survives_minority_acceptor_failure() {
        let c = bootstrap(3, 1).unwrap();
        c.kill_acceptor(0);
        c.call(CoordCmd::RegisterServer { id: 9, weight: 1 })
            .unwrap();
        assert!(c.config().unwrap().online_servers.contains(&9));
        assert!(c.replicas_converged());
    }

    #[test]
    fn no_quorum_no_progress() {
        let c = bootstrap(3, 1).unwrap();
        c.kill_acceptor(0);
        c.kill_acceptor(1);
        assert!(!c.quorum_alive());
        assert!(matches!(
            c.call(CoordCmd::RegisterServer { id: 9, weight: 1 }),
            Err(Error::NoQuorum { .. })
        ));
        c.recover_acceptor(0);
        assert!(c.quorum_alive());
        c.call(CoordCmd::RegisterServer { id: 9, weight: 1 })
            .unwrap();
    }

    #[test]
    fn replicas_converge_after_many_commands() {
        let c = Coordinator::new(5);
        for id in 0..20 {
            c.call(CoordCmd::RegisterServer { id, weight: 1 }).unwrap();
        }
        for id in (0..20).step_by(2) {
            c.call(CoordCmd::OfflineServer { id }).unwrap();
        }
        assert!(c.replicas_converged());
        assert_eq!(c.config().unwrap().online_servers.len(), 10);
    }

    #[test]
    fn unknown_server_transitions_are_noops() {
        let c = bootstrap(3, 1).unwrap();
        let e = c.config().unwrap().epoch;
        c.call(CoordCmd::OfflineServer { id: 99 }).unwrap();
        assert_eq!(c.config().unwrap().epoch, e);
    }
}
